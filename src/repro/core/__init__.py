"""Core numerics: the paper's contribution as composable JAX pieces."""
from . import floatsd, fp8, loss_scaling, policy, qsigmoid
from .floatsd import quantize as floatsd8_quantize
from .floatsd import quantize_ste as floatsd8_quantize_ste
from .fp8 import act_quant, grad_quant, quantize_fp8
from .policy import Policy, get_policy
from .qsigmoid import qsigmoid as quantized_sigmoid
from .qsigmoid import qtanh_fp8

__all__ = [
    "floatsd", "fp8", "loss_scaling", "policy", "qsigmoid",
    "floatsd8_quantize", "floatsd8_quantize_ste",
    "act_quant", "grad_quant", "quantize_fp8",
    "Policy", "get_policy", "qsigmoid", "qtanh_fp8",
]
