"""FloatSD4 number format (sub-byte serving variant — ours, not the paper's).

A 4-bit weight code indexing a 15-entry signed-digit mantissa grid, with a
*per-group shared exponent* instead of FloatSD8's per-code 3-bit exponent
field:

  * MSG  (2-digit group): one non-zero digit max -> m in {0, ±1, ±2}
  * 2nd  (1-digit group): s in {0, ±1}, placed two binary positions below
    the MSG unit, contributing s/4.

mantissa = m + s/4  -> 15 distinct values, range [-2.25, +2.25], at most
two non-zero SD digits per weight (same partial-product budget as
FloatSD8).  The 16th code (0xF) is spare and decodes to exactly 0.0, which
also makes an all-spare pad nibble safe.

value = mantissa * 2^e(group),  one int8 exponent per GROUP consecutive
rows (axis 0 — the contraction axis of a [K, N] weight) per column.

The format exists for serving density: two codes pack per byte, so a
packed [K, N] weight streams ceil(K/2)*N code bytes + ceil(K/GROUP)*N
exponent bytes — about half FloatSD8's K*N + 4.  It is derived offline
from a trained FloatSD8 master copy (``serving.weight_store
.pack_floatsd4``); there is no FloatSD4 training path.

Same bit-exactness discipline as :mod:`repro.core.floatsd`: scales are
built via ``exp2i`` (exact powers of two from exponent bits), the group
exponent fit is corrected with exact integer comparisons, and the grid is
dyadic, so ``decode(encode(w))`` is idempotent bit-identically — the
serving weight-store invariant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .floatsd import _count_idx, exp2i

__all__ = [
    "MANTISSA_VALUES",
    "ZERO_CODE",
    "SPARE_CODE",
    "GROUP",
    "TOP",
    "fit_group_exp",
    "quantize",
    "encode",
    "decode",
    "pack_nibbles",
    "unpack_nibbles",
    "decode_packed",
    "gather_decode",
]

GROUP = 32  # rows (axis 0) sharing one exponent; divides every pallas bk
TOP = 2.25  # largest representable |mantissa|


def _build_mantissas() -> np.ndarray:
    vals = sorted({m + s / 4.0 for m in (-2, -1, 0, 1, 2) for s in (-1, 0, 1)})
    arr = np.array(vals, dtype=np.float32)
    assert arr.size == 15, arr.size  # no collisions in this digit set
    return arr


MANTISSA_VALUES = _build_mantissas()
_MANTISSA_J = jnp.asarray(MANTISSA_VALUES)
_MANTISSA_MID = jnp.asarray((MANTISSA_VALUES[1:] + MANTISSA_VALUES[:-1]) / 2.0)

# code that decodes to exactly 0.0 at any exponent (index of 0.0 in the
# sorted symmetric grid) — the odd-K / tile padding convention
ZERO_CODE = int(np.searchsorted(MANTISSA_VALUES, 0.0))
assert ZERO_CODE == 7
# the unused 16th code; the decode LUT maps it to 0.0 as well
SPARE_CODE = 15

# 16-entry decode LUT (spare code -> 0.0) for the nibble-unpack kernels
LUT16 = np.zeros(16, dtype=np.float32)
LUT16[:15] = MANTISSA_VALUES
_LUT16_J = jnp.asarray(LUT16)


def _num_groups(k: int) -> int:
    return -(-k // GROUP)


def _expand_group_rows(e: jax.Array, k: int) -> jax.Array:
    """[G, ...] per-group array -> [k, ...] per-row (repeat + crop)."""
    return jnp.repeat(e, GROUP, axis=0)[:k]


def fit_group_exp(x: jax.Array) -> jax.Array:
    """Per-(group, column) exponent: put the group's max|x| in (1.125, 2.25]
    after scaling, i.e. the tightest e with TOP * 2^e >= max|x|.

    Exact by construction: the float log2 estimate is corrected with
    integer-exponent comparisons against ``TOP * exp2i(e)``, so the fit
    never lands off-by-one at a power-of-two boundary.  All-zero groups
    get e = 0.  Returns int8 of shape [ceil(K/GROUP), ...trailing dims].
    """
    xf = jnp.abs(x.astype(jnp.float32))
    k = x.shape[0]
    g = _num_groups(k)
    pad = g * GROUP - k
    if pad:
        xf = jnp.pad(xf, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    amax = xf.reshape((g, GROUP) + x.shape[1:]).max(axis=1)
    amax = jnp.where(jnp.isfinite(amax), amax, 0.0)
    raw = jnp.where(
        amax > 0,
        jnp.ceil(jnp.log2(jnp.maximum(amax, 1e-45) / TOP)).astype(jnp.int32),
        0,
    )
    # one-step exact correction of the float estimate
    raw = jnp.where(amax > TOP * exp2i(raw), raw + 1, raw)
    raw = jnp.where((amax > 0) & (amax <= TOP * exp2i(raw - 1)), raw - 1, raw)
    e = jnp.where(amax > 0, jnp.clip(raw, -126, 127), 0)
    return e.astype(jnp.int8)


def _round_codes(x: jax.Array, exps: jax.Array) -> jax.Array:
    """Nearest-grid-value code per element under the group exponents."""
    xf = x.astype(jnp.float32)
    scale = exp2i(_expand_group_rows(exps.astype(jnp.int32), x.shape[0]))
    n = jnp.clip(xf / scale, -TOP, TOP)
    return _count_idx(_MANTISSA_MID, n).astype(jnp.uint8)  # 0..14


def encode(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """FloatSD4-quantize ``x`` (axis 0 = grouped/contraction axis).

    Returns ``(codes, exps)``: unpacked uint8 codes in [0, 14] with the
    same shape as ``x``, and int8 exponents of shape
    ``[ceil(K/GROUP), ...]``.  Same finiteness precondition as FloatSD8's
    ``encode``: NaN/inf have no code; the deployment path
    (``serving.weight_store``) raises on nonfinite weights first.
    """
    exps = fit_group_exp(x)
    return _round_codes(x, exps), exps


def decode(codes: jax.Array, exps: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Decode unpacked uint8 FloatSD4 codes back to real values."""
    m = _LUT16_J[codes.astype(jnp.int32) & 0xF]
    scale = exp2i(_expand_group_rows(exps.astype(jnp.int32), codes.shape[0]))
    return (m * scale).astype(dtype)


def quantize(x: jax.Array, dtype=None) -> jax.Array:
    """Fake-quant convenience: decode(encode(x)) in one call."""
    codes, exps = encode(x)
    return decode(codes, exps, dtype=dtype or x.dtype)


# ---------------------------------------------------------------------------
# 2-codes/byte nibble packing (axis 0; low nibble = even row)
# ---------------------------------------------------------------------------


def pack_nibbles(codes: jax.Array) -> jax.Array:
    """[K, ...] uint8 codes -> [ceil(K/2), ...] bytes.

    byte[i] = codes[2i] | codes[2i+1] << 4.  Odd K pads one ZERO_CODE row
    (decodes to exact 0.0 at any exponent), so a pad byte is 0x77.
    """
    k = codes.shape[0]
    c = codes.astype(jnp.uint8)
    if k % 2:
        pad = jnp.full((1,) + codes.shape[1:], ZERO_CODE, jnp.uint8)
        c = jnp.concatenate([c, pad], axis=0)
    return (c[0::2] | (c[1::2] << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jax.Array, k: int) -> jax.Array:
    """[ceil(K/2), ...] bytes -> [k, ...] uint8 codes (bit-exact inverse)."""
    lo = packed & jnp.uint8(0xF)
    hi = (packed >> 4) & jnp.uint8(0xF)
    inter = jnp.stack([lo, hi], axis=1)
    return inter.reshape((2 * packed.shape[0],) + packed.shape[1:])[:k]


def decode_packed(packed: jax.Array, exps: jax.Array, k: int,
                  dtype=jnp.float32) -> jax.Array:
    """Decode a nibble-packed code stream back to a dense [k, ...] tensor."""
    return decode(unpack_nibbles(packed, k), exps, dtype=dtype)


def gather_decode(packed: jax.Array, exps: jax.Array, tokens: jax.Array,
                  dtype=jnp.float32) -> jax.Array:
    """Row-gather + decode for a nibble-packed [V, D] table (the packed
    embedding lookup): fetch byte row ``t // 2``, select the nibble by
    ``t % 2``, scale by exponent row ``t // GROUP``. Bit-identical to
    decode-then-gather (decode is element-wise) at half the gather
    traffic of the FloatSD8 path."""
    t = tokens.astype(jnp.int32)
    byte = jnp.take(packed, t // 2, axis=0)  # [..., D]
    code = (byte >> ((t % 2) * 4)[..., None].astype(jnp.uint8)) & jnp.uint8(0xF)
    m = _LUT16_J[code.astype(jnp.int32)]
    e = jnp.take(exps, t // GROUP, axis=0).astype(jnp.int32)
    return (m * exp2i(e)).astype(dtype)
