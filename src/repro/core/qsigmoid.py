"""Two-region FloatSD8 sigmoid quantization (paper §III-C, Eqs. 7-8).

    y = Q(sigma(x))          for x <= 0            (Eq. 7)
    y = 1 - Q(sigma(-x))     for x >  0            (Eq. 8)

Direct FloatSD8 quantization of sigma has unbalanced error between positive
and negative inputs (Fig. 4) because FloatSD is log-linear; mirroring the
quantizer around x=0 balances it (Fig. 5). For x > 0 the output is the sum of
two FloatSD8 numbers (1 is exactly representable), which the paper's MAC
handles natively; in this simulation the sum is a single real value.

The quantizer uses a FIXED exponent bias of -7: with it the non-positive
branch has exactly **42 distinct output values**, reproducing the paper's
"depth of the LUT can be reduced [to 42]" observation (verified in
tests/test_qsigmoid.py).

Gradients: straight-through — autodiff sees the exact sigmoid/tanh derivative
(implemented with the stop_gradient fake-quant identity).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import floatsd
from .fp8 import FP8_E5M2, quantize_fp8

__all__ = ["SIGMOID_LUT_BIAS", "qsigmoid", "qsigmoid_raw", "qtanh_fp8", "sigmoid_lut_values"]

SIGMOID_LUT_BIAS = -7  # gives the paper's 42-entry LUT for x <= 0


# --- octave-folded FloatSD8 quantizer for sigma in (0, 0.5] ----------------
# The FloatSD8 grid is octave-periodic above 2^2 (relative bias): normalizing
# n = m * 2^e with m in [1,2) reduces nearest-value rounding to a <=8-entry
# per-octave table, with three distinct tables for octave levels 0/1/2+
# (sparser mantissa sets at the bottom of the exponent range). This replaces
# the generic 64-midpoint compare-count, whose [B,S,d,64] intermediate
# dominated the rwkv6/lstm memory roofline (EXPERIMENTS.md §Perf HC3 it.2).
# Exactness vs floatsd.quantize is asserted in tests/test_qsigmoid.py over a
# dense sweep.
def _octave_tables():
    g = [float(v) for v in floatsd._GRID_POS]
    levels = []
    for e in range(3):  # level 2 == every higher octave (verified in tests)
        lo, hi = 2.0**e, 2.0 ** (e + 1)
        vals = sorted(v / lo for v in g if lo <= v < hi)
        ext = np.array(vals + [2.0], np.float32)  # boundary -> next octave
        mids = (ext[1:] + ext[:-1]) / 2
        # pad to fixed width 8 (mids +inf never counted; vals unreachable)
        pad = 8 - ext.size
        ext = np.pad(ext, (0, pad), constant_values=2.0)
        mids = np.pad(mids, (0, pad + 1), constant_values=np.inf)
        levels.append((ext, mids.astype(np.float32)))
    return (
        np.stack([l[0] for l in levels]),  # [3, 8]
        np.stack([l[1] for l in levels]),  # [3, 8]
    )


_OCT_VALS, _OCT_MIDS = _octave_tables()
_BOT_VALS = np.array([0.0, 0.25, 0.5, 0.75, 1.0], np.float32)
_BOT_MIDS = ((_BOT_VALS[1:] + _BOT_VALS[:-1]) / 2).astype(np.float32)


def _Q(v: jax.Array) -> jax.Array:
    """FloatSD8 quantize for v in [0, 0.5] at the fixed LUT bias (folded)."""
    n = v.astype(jnp.float32) * jnp.float32(2.0 ** (-SIGMOID_LUT_BIAS))
    e = jnp.clip(jnp.floor(jnp.log2(jnp.maximum(n, 1e-30))), 0.0, 6.0)
    m = n * jnp.exp2(-e)
    lvl = jnp.minimum(e, 2.0).astype(jnp.int32)
    mids = jnp.asarray(_OCT_MIDS)[lvl]  # [..., 8]
    idx = jnp.sum((m[..., None] > mids).astype(jnp.int32), -1)
    q_int = jnp.take_along_axis(
        jnp.asarray(_OCT_VALS)[lvl], idx[..., None], axis=-1
    )[..., 0] * jnp.exp2(e)
    bidx = jnp.sum((n[..., None] > jnp.asarray(_BOT_MIDS)).astype(jnp.int32), -1)
    q_bot = jnp.asarray(_BOT_VALS)[bidx]
    q = jnp.where(n >= 1.0, q_int, q_bot)
    return q * jnp.float32(2.0**SIGMOID_LUT_BIAS)


def qsigmoid_raw(x: jax.Array) -> jax.Array:
    """Pure quantized sigmoid, no gradient definition (kernel/LUT oracle)."""
    s_neg = _Q(jax.nn.sigmoid(-jnp.abs(x)))  # Q(sigma(x)) evaluated at -|x|
    return jnp.where(x > 0, 1.0 - s_neg, s_neg).astype(x.dtype)


def qsigmoid(x: jax.Array) -> jax.Array:
    """Quantized sigmoid with straight-through gradient (exact sigma')."""
    s = jax.nn.sigmoid(x)
    return s + jax.lax.stop_gradient(qsigmoid_raw(x) - s)


def qtanh_fp8(x: jax.Array) -> jax.Array:
    """tanh followed by FP8 activation quantization (the tanh LUT in the
    paper's neuron circuit emits FP8; only the three sigmoid gates get the
    FloatSD8 treatment)."""
    t = jnp.tanh(x)
    return t + jax.lax.stop_gradient(quantize_fp8(t, FP8_E5M2) - t)


def sigmoid_lut_values() -> np.ndarray:
    """The explicit non-positive-branch LUT (42 entries + 0), for the
    hardware model and for oracle tests."""
    grid = floatsd.floatsd8_value_grid(SIGMOID_LUT_BIAS)
    return grid[(grid >= 0) & (grid <= 0.5)]
