"""FP8 quantization (paper §III-D): 1-bit sign, 5-bit exponent, 2-bit
mantissa == IEEE-style ``float8_e5m2`` [Wang et al., NeurIPS'18].

Forward activations, backward activation-gradients, and weight gradients are
all quantized to FP8 with *regular* (round-to-nearest-even, hardware native)
rounding, per paper §III-D. ``float8_e4m3fn`` is available as a beyond-paper
option for inference activations.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "FP8_E5M2", "FP8_E4M3", "FP16",
    "quantize_fp8", "cast_fp8", "act_quant", "grad_quant",
]

FP8_E5M2 = jnp.float8_e5m2
FP8_E4M3 = jnp.float8_e4m3fn
FP16 = jnp.float16

_MAX = {FP8_E5M2: 57344.0, FP8_E4M3: 448.0, FP16: 65504.0}


def quantize_fp8(x: jax.Array, dtype=FP8_E5M2) -> jax.Array:
    """Round-trip cast x -> fp8 -> original dtype (fake-quant), saturating
    on *finite* overflow only.

    Saturation (rather than inf) keeps large loss-scaled gradients finite,
    matching hardware clamp behaviour — but genuinely nonfinite inputs must
    stay nonfinite: ``jnp.clip`` maps ``inf`` to the finite max, which
    would launder an overflowed gradient past the loss-scaler's
    ``unscale_and_check`` (the skip-and-backoff loop could then never
    fire on inf, only NaN). ``where(isfinite)`` preserves inf/NaN through
    the quantizer; the downstream finite check is the policy point that
    decides what happens to them.
    """
    if dtype is None:
        return x
    m = _MAX[dtype]
    xf = x.astype(jnp.float32)
    xc = jnp.where(jnp.isfinite(xf), jnp.clip(xf, -m, m), xf)
    return xc.astype(dtype).astype(x.dtype)


def cast_fp8(x: jax.Array, dtype=FP8_E5M2) -> jax.Array:
    """Real (storage) cast x -> fp8, saturating like ``quantize_fp8`` but
    returning the 1-byte array itself — the format the serving frontend
    stores cached LSTM states in. ``x.astype(back)`` recovers the
    fake-quant value exactly (fp8 -> wider float is lossless). Nonfinite
    inputs stay nonfinite (e4m3fn has no inf code, so inf lands on NaN —
    still detectable) rather than silently saturating to a finite code."""
    m = _MAX[dtype]
    xf = x.astype(jnp.float32)
    return jnp.where(jnp.isfinite(xf), jnp.clip(xf, -m, m), xf).astype(dtype)


def _make_roundtrip(fwd_dtype, bwd_dtype):
    @jax.custom_vjp
    def f(x):
        return quantize_fp8(x, fwd_dtype)

    def fwd(x):
        return quantize_fp8(x, fwd_dtype), None

    def bwd(_, g):
        return (quantize_fp8(g, bwd_dtype),)

    f.defvjp(fwd, bwd)
    return f


# cache of (fwd, bwd) -> function, keyed by dtype names so jit caching works
_CACHE: dict = {}


def act_quant(x: jax.Array, fwd_dtype=FP8_E5M2, bwd_dtype=FP8_E5M2) -> jax.Array:
    """Quantization node: forward activation -> fwd_dtype, incoming
    activation-gradient -> bwd_dtype (both fake-quant). Either may be None
    (pass-through) or jnp.float16 for the paper's last-layer FP16 setting."""
    key = (fwd_dtype, bwd_dtype)
    if key not in _CACHE:
        _CACHE[key] = _make_roundtrip(fwd_dtype, bwd_dtype)
    return _CACHE[key](x)


def grad_quant(tree, dtype=FP8_E5M2):
    """Quantize a (loss-scaled) gradient pytree to FP8 (fake-quant).

    Applied after backward, before the optimizer: the paper's weight update is
    'addition of the FP16 master copy weight and the FP8 gradient'.

    Under the fused-BPTT path the LSTM dW leaves arrive already ON the fp8
    grid (emitted by ``kernels.dispatch.matmul_dw`` at the kernel flush), so
    this pass is an exact no-op on them — ``quantize_fp8`` is idempotent —
    while still providing the paper's §III-D coverage (and overflow
    saturation) for params no kernel emits: biases, embedding tables, and
    the non-LSTM archs' direct-use params (rwkv decay/bonus, norms).
    """
    return jax.tree_util.tree_map(lambda g: quantize_fp8(g, dtype), tree)
