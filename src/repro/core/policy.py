"""Precision policies (paper Tables II & VI).

A ``Policy`` says, for every quantization *site* in a model, what to do:

  w  - weights at matmul sites          (floatsd8 | none)
  g  - weight gradients                 (fp8 | none)
  a  - inter-layer activations fwd/bwd  (fp8 | fp16 | none)
  o  - last-layer output activations    (fp16 in Table VI; fp8 in Table II)
  f  - first-layer (embedding output)   (fp8; Table V ablation varies this)
  m  - master copy of weights           (fp32 | fp16)
  s  - sigmoid gates                    (floatsd8 two-region | none)

plus the compute dtype the matmuls run in and the loss scale. Policies are
hashable (usable as jit static args) and threaded through every layer.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

__all__ = ["Policy", "FP32", "BF16", "FLOATSD8_TABLE2", "FLOATSD8_TABLE6", "get_policy"]

# sentinel dtype names
_DTYPES = {
    "fp8": jnp.float8_e5m2,
    "fp8_e4m3": jnp.float8_e4m3fn,
    "fp16": jnp.float16,
    "bf16": jnp.bfloat16,
    "fp32": jnp.float32,
    "none": None,
}


def _dt(name: str | None):
    if name is None:
        return None
    return _DTYPES[name]


@dataclasses.dataclass(frozen=True)
class Policy:
    name: str = "fp32"
    weight_quant: str = "none"  # "floatsd8" | "none"
    grad_quant: str = "none"  # "fp8" | "none"   (weight grads, post-backward)
    act_fwd: str = "none"  # inter-layer activations, forward
    act_bwd: str = "none"  # inter-layer activation-gradients, backward
    first_layer_act: str = "none"  # embedding output (Table V col 1)
    last_layer_act: str = "none"  # logits/output layer (Table V col 2)
    master_dtype: str = "fp32"  # optimizer master copy (Table IV col 4)
    sigmoid_quant: bool = False  # two-region FloatSD8 sigmoid (Eq. 7-8)
    compute_dtype: str = "fp32"  # dtype matmuls execute in
    param_dtype: str = "fp32"  # dtype quantized weights are materialized in
    loss_scale: float = 1.0

    # -- dtype accessors -------------------------------------------------
    def cdt(self):
        return _dt(self.compute_dtype)

    def pdt(self):
        return _dt(self.param_dtype)

    def mdt(self):
        return _dt(self.master_dtype)

    def act_dtypes(self, site: str = "hidden"):
        """(fwd_dtype, bwd_dtype) for an activation site:
        'first' | 'hidden' | 'last'."""
        fwd = {"first": self.first_layer_act, "last": self.last_layer_act}.get(
            site, self.act_fwd
        )
        return _dt(fwd), _dt(self.act_bwd)

    def replace(self, **kw) -> "Policy":
        return dataclasses.replace(self, **kw)


FP32 = Policy(name="fp32")

BF16 = Policy(name="bf16", compute_dtype="bf16", param_dtype="bf16")

# Table II: the original proposed scheme — FP32 master, FP8 everywhere.
FLOATSD8_TABLE2 = Policy(
    name="floatsd8_table2",
    weight_quant="floatsd8",
    grad_quant="fp8",
    act_fwd="fp8",
    act_bwd="fp8",
    first_layer_act="fp8",
    last_layer_act="fp8",
    master_dtype="fp32",
    sigmoid_quant=True,
    loss_scale=1024.0,
)

# Table VI: the modified scheme — FP16 master, FP16 last-layer activations.
FLOATSD8_TABLE6 = FLOATSD8_TABLE2.replace(
    name="floatsd8_table6",
    last_layer_act="fp16",
    master_dtype="fp16",
)

# TPU-production variant: identical quantization sites, bf16 matmul issue
# dtype so the MXU runs at full rate (DESIGN.md §3.3).
FLOATSD8_TPU = FLOATSD8_TABLE6.replace(
    name="floatsd8_tpu", compute_dtype="bf16", param_dtype="bf16"
)

_REGISTRY = {
    p.name: p for p in (FP32, BF16, FLOATSD8_TABLE2, FLOATSD8_TABLE6, FLOATSD8_TPU)
}


def get_policy(name: str, **overrides: Any) -> Policy:
    p = _REGISTRY[name]
    return p.replace(**overrides) if overrides else p
