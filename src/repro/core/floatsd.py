"""FloatSD8 number format (paper §III-A).

An 8-bit weight code: 3-bit exponent field | 5-bit mantissa code.

The mantissa encodes two signed-digit groups:
  * MSG  (3-digit group): one non-zero digit max -> {0, ±1, ±2, ±4}
  * 2nd  (2-digit group): one non-zero digit max -> {0, ±1, ±2}, placed two
    binary positions below the MSG unit, i.e. contributing s/4.

mantissa = m + s/4 with m in {0,±1,±2,±4}, s in {0,±1,±2}  -> 35 combos,
31 distinct values (collisions at ±0.5, ±1.5), range [-4.5, +4.5].

value = mantissa * 2^(e + bias),  e in [0, 7], per-tensor integer ``bias``.

The per-tensor bias is the one deviation from the paper's fixed-field circuit
(recorded in DESIGN.md §3.5a): the 3-bit exponent *field* is unchanged; the
bias is fitted once per tensor so the 8-bit code spends its dynamic range
(~2^11.2) on the tensor's actual magnitude window.

Everything here is pure jnp so it can serve as the oracle for the Pallas
kernels and run under jit on any backend.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MANTISSA_VALUES",
    "MANTISSA_TO_SD",
    "EXP_BITS",
    "EXP_LEVELS",
    "floatsd8_value_grid",
    "fit_bias",
    "quantize",
    "quantize_ste",
    "encode",
    "decode",
    "pack",
    "unpack",
    "partial_product_count",
]

EXP_BITS = 3
EXP_LEVELS = 1 << EXP_BITS  # 8

# ---------------------------------------------------------------------------
# Mantissa value set (31 distinct values; paper says "only 31 distinct
# combinations exist, making 5 bits enough").
# ---------------------------------------------------------------------------


def _build_mantissas() -> tuple[np.ndarray, dict[float, tuple[int, int]]]:
    vals: dict[float, tuple[int, int]] = {}
    for m in (-4, -2, -1, 0, 1, 2, 4):
        for s in (-2, -1, 0, 1, 2):
            v = m + s / 4.0
            # Prefer the decomposition with the fewest non-zero digits on
            # collisions (matches minimal partial-product hardware cost).
            if v not in vals or _nzd(m, s) < _nzd(*vals[v]):
                vals[v] = (m, s)
    keys = np.array(sorted(vals.keys()), dtype=np.float32)
    assert keys.size == 31, keys.size
    return keys, vals


def _nzd(m: int, s: int) -> int:
    return int(m != 0) + int(s != 0)


MANTISSA_VALUES, MANTISSA_TO_SD = _build_mantissas()
_MANTISSA_J = jnp.asarray(MANTISSA_VALUES)
# midpoints for nearest-value rounding over the 31-entry grid
_MANTISSA_MID = jnp.asarray((MANTISSA_VALUES[1:] + MANTISSA_VALUES[:-1]) / 2.0)


def _value_grid_np() -> np.ndarray:
    """All distinct non-negative representable values at bias=0, sorted."""
    g = np.unique(
        np.abs(MANTISSA_VALUES)[:, None] * (2.0 ** np.arange(EXP_LEVELS))[None, :]
    )
    return g.astype(np.float64)


_GRID_POS = _value_grid_np()  # includes 0
_GRID_MID = (_GRID_POS[1:] + _GRID_POS[:-1]) / 2.0


def floatsd8_value_grid(bias: int = 0) -> np.ndarray:
    """Every distinct non-negative value representable with this bias."""
    return _GRID_POS * (2.0**bias)


# Precompute, for every distinct grid value, a canonical (e, mantissa-index)
# pair used by ``encode``; chooses the smallest exponent (finest grid) that
# represents the value exactly.
def _grid_codes() -> tuple[np.ndarray, np.ndarray]:
    es = np.zeros(_GRID_POS.size, dtype=np.int8)
    mi = np.zeros(_GRID_POS.size, dtype=np.int8)
    for i, v in enumerate(_GRID_POS):
        found = False
        for e in range(EXP_LEVELS):
            m = v / (2.0**e)
            j = np.searchsorted(MANTISSA_VALUES, m)
            for jj in (j - 1, j, j + 1):
                if 0 <= jj < 31 and MANTISSA_VALUES[jj] == m:
                    es[i], mi[i] = e, jj
                    found = True
                    break
            if found:
                break
        assert found, v
    return es, mi


_GRID_E, _GRID_MIDX = _grid_codes()


class QuantResult(NamedTuple):
    values: jax.Array  # dequantized (same shape/dtype as input)
    bias: jax.Array  # scalar int32 per-tensor exponent bias


def exp2i(k: jax.Array) -> jax.Array:
    """Exact 2^k as f32 for integer k in the normal range [-126, 127].

    jnp.exp2 lowers to exp(k*ln2) on some backends and is ~1 ulp off even
    for integer arguments, which puts quantize() outputs slightly OFF the
    representable grid and breaks the serving weight-store invariant
    decode(encode(w)) == quantize(w).values. Building the float from its
    exponent bits is exact by construction. k is clamped to the normal
    range: below -126 the bit pattern would wrap into the sign bit (a
    fit_bias of ~-135 is reachable for tensors with max|x| ~1e-38), so tiny
    tensors saturate to 2^-126 instead of producing garbage scales.
    """
    k = jnp.clip(jnp.asarray(k, jnp.int32), -126, 127)
    return jax.lax.bitcast_convert_type(
        ((k + 127).astype(jnp.uint32) << 23), jnp.float32
    )


def _clamp_bias(bias) -> jax.Array:
    """Clamp the per-tensor bias so every reachable exponent e + bias
    (e in [0, 7]) stays in f32's normal range. Applied identically by
    quantize/encode/decode so the decode(encode(w)) == quantize(w).values
    invariant holds even for tensors with max|x| near the subnormal floor
    (fit_bias can otherwise reach < -126)."""
    return jnp.clip(jnp.asarray(bias, jnp.int32), -126, 127 - (EXP_LEVELS - 1))


def fit_bias(x: jax.Array) -> jax.Array:
    """Per-tensor exponent bias: put max|x| in the top exponent bin.

    4.5 * 2^(7+bias) >= max|x|  and as tight as possible.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    amax = jnp.where(jnp.isfinite(amax) & (amax > 0), amax, 1.0)
    raw = jnp.ceil(jnp.log2(amax / 4.5)).astype(jnp.int32) - (EXP_LEVELS - 1)
    return _clamp_bias(raw)


def _count_idx(mids: jax.Array, n: jax.Array) -> jax.Array:
    """index = #(mids < n), i.e. searchsorted(mids, n, side='left') — but as
    one broadcast compare-count instead of jnp.searchsorted. searchsorted
    lowers to a log2(len)-trip while loop whose body round-trips the full
    tensor each iteration (~7x the HBM traffic on activation-sized inputs,
    measured in EXPERIMENTS.md §Perf); the compare-count is a single fusion
    and is exactly what the Pallas quantize kernel does on TPU."""
    return jnp.sum(
        (n[..., None] > mids[(None,) * n.ndim]).astype(jnp.int32), axis=-1
    )


def _round_mantissa(m: jax.Array) -> jax.Array:
    """Nearest value in the 31-entry mantissa grid (regular rounding)."""
    idx = _count_idx(_MANTISSA_MID, m)
    return _MANTISSA_J[idx]


def quantize(x: jax.Array, bias: jax.Array | int | None = None) -> QuantResult:
    """Exact nearest-representable-value FloatSD8 quantization (fake-quant).

    Searches the full (exponent x mantissa) grid, which is necessary because
    the mantissa grid has a hole (2.5 -> 3.5): e.g. 3.0 is *exactly*
    representable as 1.5 * 2^1 but naive choose-smallest-exponent rounding
    would return 2.5 or 3.5.
    """
    if bias is None:
        bias = fit_bias(x)
    bias = _clamp_bias(bias)
    xf = x.astype(jnp.float32)
    scale = exp2i(bias)
    n = jnp.abs(xf) / scale
    # clamp into representable window, saturating rounding at the top
    top = _GRID_POS[-1]
    n = jnp.clip(n, 0.0, top)
    idx = _count_idx(jnp.asarray(_GRID_MID, jnp.float32), n)
    q = jnp.asarray(_GRID_POS, jnp.float32)[idx] * scale
    out = jnp.sign(xf) * q
    return QuantResult(out.astype(x.dtype), bias)


@jax.custom_vjp
def quantize_ste(x: jax.Array, bias: jax.Array) -> jax.Array:
    return quantize(x, bias).values


def _ste_fwd(x, bias):
    return quantize(x, bias).values, None


def _ste_bwd(_, g):
    return g, None  # straight-through: identity grad, no grad to bias


quantize_ste.defvjp(_ste_fwd, _ste_bwd)


# ---------------------------------------------------------------------------
# int8 encode / decode (storage + kernel path)
# ---------------------------------------------------------------------------


def encode(x: jax.Array, bias: jax.Array | int | None = None) -> tuple[jax.Array, jax.Array]:
    """Quantize and pack to int8 codes: sign<<7 | e<<5 ... actually the
    paper's layout is 3-bit exponent + 5-bit SD-group code. We use:

        code8 = (e << 5) | m_idx        (m_idx in [0, 30])

    with the sign folded into m_idx (the mantissa set is symmetric). Returns
    (codes uint8, bias int32).

    Precondition: ``x`` must be finite. uint8 codes carry no NaN/inf
    representation, and the grid-index search maps NaN to code 0 (every
    ``>`` comparison is False) — a silent finite encoding. Inf saturates
    to the top grid point (the documented clip behaviour). Callers that
    can see corrupt data must check first; ``serving.weight_store
    .pack_tree`` (the deployment path) raises on nonfinite weights before
    calling this.
    """
    if bias is None:
        bias = fit_bias(x)
    bias = _clamp_bias(bias)
    xf = x.astype(jnp.float32)
    scale = exp2i(bias)
    n = jnp.clip(jnp.abs(xf) / scale, 0.0, _GRID_POS[-1])
    gidx = _count_idx(jnp.asarray(_GRID_MID, jnp.float32), n)
    e = jnp.asarray(_GRID_E, jnp.int32)[gidx]
    midx = jnp.asarray(_GRID_MIDX, jnp.int32)[gidx]  # index of |mantissa|
    # map to signed mantissa index: grid is symmetric, index 15 == 0.0
    neg = xf < 0
    midx_signed = jnp.where(neg, 30 - midx, midx)
    # |mantissa| indices are in [15, 30]; negatives map to [0, 15]
    code = (e << 5) | midx_signed
    return code.astype(jnp.uint8), bias


def decode(codes: jax.Array, bias: jax.Array | int, dtype=jnp.float32) -> jax.Array:
    """Decode uint8 FloatSD8 codes back to real values."""
    c = codes.astype(jnp.int32)
    e = c >> 5
    midx = c & 0x1F
    m = _MANTISSA_J[jnp.clip(midx, 0, 30)]
    bias = _clamp_bias(bias)
    return (m * exp2i(e + bias)).astype(dtype)


# aliases used by the serving/storage path
pack = encode
unpack = decode


def partial_product_count(codes: jax.Array) -> jax.Array:
    """Number of non-zero SD digits (== partial products) per weight, <= 2.

    Used by the Table-VII complexity model.
    """
    midx = (codes.astype(jnp.int32)) & 0x1F
    m_abs = jnp.abs(_MANTISSA_J[jnp.clip(midx, 0, 30)])
    nz = jnp.asarray(
        [_nzd(*MANTISSA_TO_SD[float(v)]) for v in MANTISSA_VALUES], jnp.int32
    )
    idx = jnp.searchsorted(jnp.asarray(MANTISSA_VALUES), _MANTISSA_J[midx])
    del m_abs
    return nz[idx]


@functools.partial(jax.jit, static_argnames=("dtype",))
def fake_quant(x: jax.Array, dtype=None) -> jax.Array:
    """Convenience jitted fake-quant with auto bias (no STE)."""
    out = quantize(x).values
    return out if dtype is None else out.astype(dtype)
