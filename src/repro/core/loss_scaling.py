"""Loss scaling (paper §IV-A: 'a single scaling factor of 1024' [MPT]).

Static scaling is what the paper uses on all four tasks; dynamic scaling is
provided as the production default for beyond-paper runs (skip-on-overflow
with multiplicative backoff, jax.lax only — no python control flow, so it
lives happily inside a jitted, pjit-sharded train step).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["LossScaleState", "static_init", "dynamic_init", "scale_loss", "unscale_and_check", "adjust"]


class LossScaleState(NamedTuple):
    scale: jax.Array  # f32 scalar
    growth_counter: jax.Array  # int32
    dynamic: jax.Array  # bool scalar (static_arg-free dispatch)


def static_init(scale: float = 1024.0) -> LossScaleState:
    return LossScaleState(
        jnp.float32(scale), jnp.int32(0), jnp.asarray(False)
    )


def dynamic_init(init_scale: float = 2.0**15) -> LossScaleState:
    return LossScaleState(
        jnp.float32(init_scale), jnp.int32(0), jnp.asarray(True)
    )


def scale_loss(loss: jax.Array, st: LossScaleState) -> jax.Array:
    return loss * st.scale.astype(loss.dtype)


def _tree_finite(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    ok = jnp.asarray(True)
    for l in leaves:
        ok &= jnp.all(jnp.isfinite(l.astype(jnp.float32)))
    return ok


def unscale_and_check(grads, st: LossScaleState):
    """Unscale gradient pytree; returns (grads, all_finite)."""
    inv = (1.0 / st.scale).astype(jnp.float32)
    grads = jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * inv).astype(g.dtype), grads
    )
    return grads, _tree_finite(grads)


def adjust(
    st: LossScaleState,
    grads_finite: jax.Array,
    *,
    growth_interval: int = 2000,
    factor: float = 2.0,
    max_scale: float = 2.0**24,
    min_scale: float = 1.0,
) -> LossScaleState:
    """Dynamic-mode update; identity in static mode."""
    grow = grads_finite & (st.growth_counter + 1 >= growth_interval)
    new_scale = jnp.where(
        grads_finite,
        jnp.where(grow, jnp.minimum(st.scale * factor, max_scale), st.scale),
        jnp.maximum(st.scale / factor, min_scale),
    )
    new_counter = jnp.where(
        grads_finite, jnp.where(grow, 0, st.growth_counter + 1), 0
    ).astype(jnp.int32)
    return LossScaleState(
        jnp.where(st.dynamic, new_scale, st.scale),
        jnp.where(st.dynamic, new_counter, st.growth_counter),
        st.dynamic,
    )
