"""Whisper large-v3 [arXiv:2212.04356; unverified]: enc-dec, conv stub.

'32L' = 32 encoder + 32 decoder blocks (the published large-v3 layout).
The conv frontend is a STUB per the assignment: input_specs() feeds
precomputed 1500-frame embeddings straight to the encoder stack."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_large_v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, kv_heads=20, d_ff=5120, vocab=51866,
    rope="none", norm="layernorm", ffn_kind="gelu", qkv_bias=True,
    enc_layers=32, enc_seq=1500, tie_embeddings=True,
    supports_long=False,
    source="arXiv:2212.04356 (unverified)",
)
