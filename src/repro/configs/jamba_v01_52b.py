"""Jamba v0.1 52B [arXiv:2403.19887; hf]: Mamba+attention 1:7, MoE every 2."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba_v01_52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, kv_heads=8, d_ff=14336, vocab=65536,
    n_experts=16, top_k=2, moe_every=2, attn_every=8,
    rope="none",  # jamba uses no positional encoding (mamba provides order)
    supports_long=True,  # attention layers are 4/32; state dominates
    source="arXiv:2403.19887 (hf)",
    notes="period-8 groups: [mamba x3, attn, mamba x4], MoE on odd sub-layers.",
)
