"""RWKV-6 'Finch' 3B [arXiv:2404.05892; hf]: attention-free, O(1) state."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6_3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, kv_heads=0, d_ff=8960, vocab=65536,
    rope="none", rwkv_head_dim=64, norm="layernorm",
    supports_long=True,
    source="arXiv:2404.05892 (hf)",
    notes="receptance sigmoid is a native FloatSD8 q-sigmoid site.",
)
