"""Phi-4-mini 3.8B [arXiv:2412.08905; hf]: RoPE SwiGLU GQA, 200k vocab."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi4_mini_3p8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, kv_heads=8, d_ff=8192, vocab=200064,
    rope="rope", qkv_bias=False, tie_embeddings=True,
    supports_long=False,
    source="arXiv:2412.08905 (hf)",
    notes="200064-token vocab stresses the embedding/vocab-sharded logits path.",
)
