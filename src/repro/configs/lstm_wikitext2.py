"""The paper's own WikiText-2 LSTM LM (Table III: 84.98M params) as an arch.

embedding 33278x650-ish -> 2-layer LSTM(650) -> tied FC decoder. Sized to
match the 84.98M parameter count with the standard AWD-style 2x650 setup at
WikiText-2 vocab 33278: emb 33278*650 + 2 LSTM layers + decoder."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="lstm_wikitext2", family="lstm",
    n_layers=2, d_model=1024, n_heads=0, kv_heads=0, d_ff=0, vocab=33278,
    rope="none", supports_long=True,  # O(1) recurrent state
    tie_embeddings=True,
    source="paper Table III (WikiText-2, 84.98M params)",
    notes="2-layer LSTM hidden 1024, tied embeddings: 33278*1024*2 + 2*8*1024^2 ~= 85M.",
)
