"""DBRX 132B [hf:databricks/dbrx-base; unverified]: 16-expert top-4 MoE."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx_132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, kv_heads=8, d_ff=10752, vocab=100352,
    n_experts=16, top_k=4, rope="rope", supports_long=False,
    source="hf:databricks/dbrx-base (unverified)",
    notes="fine-grained MoE: every layer MoE, no shared expert.",
)
