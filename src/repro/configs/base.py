"""Architecture config schema + registry.

Every assigned architecture is a module in this package exposing ``CONFIG``;
``get_config(name)`` looks it up. ``ArchConfig.reduced()`` produces the small
same-family variant used by CPU smoke tests (the FULL config is exercised
only via the dry-run's ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

__all__ = ["ArchConfig", "get_config", "ARCH_IDS", "SHAPES", "shape_spec"]

ARCH_IDS = [
    "h2o_danube3_4b",
    "granite_20b",
    "stablelm_3b",
    "phi4_mini_3p8b",
    "kimi_k2_1t_a32b",
    "dbrx_132b",
    "jamba_v01_52b",
    "rwkv6_3b",
    "whisper_large_v3",
    "qwen2_vl_2b",
    "lstm_wikitext2",  # the paper's own largest model, as an arch config
]

# assigned input-shape set (LM family): name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def shape_spec(name: str):
    return SHAPES[name]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm | lstm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1  # MoE layer every k-th layer (jamba: 2)
    first_k_dense: int = 0  # leading dense layers (kimi-k2: 1)
    first_dense_ff: int = 0
    # --- attention flavor ---
    window: Optional[int] = None  # SWA
    rope: str = "rope"  # rope | mrope | none
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    norm: str = "rmsnorm"
    ffn_kind: str = "swiglu"
    tie_embeddings: bool = True
    # --- hybrid (jamba) ---
    attn_every: int = 0  # 1 attention layer per this many layers (0 = all attn)
    # --- ssm ---
    mamba_state: int = 16
    rwkv_head_dim: int = 64
    # --- enc-dec (whisper) ---
    enc_layers: int = 0
    enc_seq: int = 0  # stub frontend sequence length (1500 audio frames)
    # --- vlm ---
    n_patches: int = 0  # stub patch embeddings prepended to the sequence
    mrope_sections: tuple = (16, 24, 24)
    # --- bookkeeping ---
    supports_long: bool = False  # sub-quadratic path for long_500k
    source: str = ""
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def vocab_padded(self, multiple: int = 256) -> int:
        return -(-self.vocab // multiple) * multiple

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        heads = min(self.n_heads, 4)
        kvh = max(1, min(self.kv_heads, heads))
        while heads % kvh:
            kvh -= 1
        return dataclasses.replace(
            self,
            n_layers=max(2, 2 * max(self.moe_every, 1), self.attn_every or 2),
            d_model=128,
            n_heads=heads,
            kv_heads=kvh,
            head_dim=32,
            d_ff=256,
            vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            first_k_dense=min(self.first_k_dense, 1),
            first_dense_ff=256 if self.first_dense_ff else 0,
            window=64 if self.window else None,
            enc_layers=2 if self.enc_layers else 0,
            enc_seq=16 if self.enc_seq else 0,
            n_patches=16 if self.n_patches else 0,
            mrope_sections=(8, 4, 4) if self.rope == "mrope" else self.mrope_sections,
            rwkv_head_dim=32,
        )

    def skips(self, shape: str) -> str | None:
        """Return a reason string if this (arch, shape) cell is skipped."""
        if shape == "long_500k" and not self.supports_long:
            return "full quadratic attention at 524288 — sub-quadratic required (DESIGN.md §5)"
        return None


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.CONFIG
