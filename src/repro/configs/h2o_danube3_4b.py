"""H2O Danube-3 4B [arXiv:2401.16818; unverified]: llama+mistral mix w/ SWA."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o_danube3_4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, kv_heads=8, d_ff=10240, vocab=32000,
    head_dim=120, window=4096, rope="rope", rope_theta=10000.0,
    supports_long=True,  # sliding-window attention is sub-quadratic
    source="arXiv:2401.16818 (unverified)",
    notes="SWA window 4096; GQA kv=8.",
)
