"""StableLM 3B [hf:stabilityai/stablelm-2-1_6b family; unverified]: MHA kv=32."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm_3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, kv_heads=32, d_ff=6912, vocab=50304,
    rope="rope", norm="layernorm", qkv_bias=True,
    supports_long=False,
    source="hf:stabilityai/stablelm-2-1_6b (unverified)",
)
