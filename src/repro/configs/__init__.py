from .base import ARCH_IDS, SHAPES, ArchConfig, get_config
