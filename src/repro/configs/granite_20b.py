"""IBM Granite 20B code model [arXiv:2405.04324; hf]: llama-arch, MQA kv=1."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite_20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, kv_heads=1, d_ff=24576, vocab=49152,
    rope="rope", ffn_kind="gelu", norm="layernorm", qkv_bias=True,
    supports_long=False,
    source="arXiv:2405.04324 (hf)",
    notes="MQA (kv=1): kv projections replicate over the model axis.",
)
