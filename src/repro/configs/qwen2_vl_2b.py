"""Qwen2-VL 2B [arXiv:2409.12191; hf]: M-RoPE, dynamic-resolution ViT stub.

Backbone only per the assignment; input_specs() provides precomputed patch
embeddings occupying the first n_patches sequence positions."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_vl_2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, kv_heads=2, d_ff=8960, vocab=151936,
    rope="mrope", mrope_sections=(16, 24, 24), qkv_bias=True,
    n_patches=256, tie_embeddings=True,
    supports_long=False,
    source="arXiv:2409.12191 (hf)",
)
