"""Kimi K2 1T-A32B [arXiv:2501.kimi2; unverified]: 384-expert top-8 MoE.

61 layers, d_model 7168, expert FFN hidden 2048, first layer dense
(DeepSeek-V3-style first_k_dense_replace=1)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="kimi_k2_1t_a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, kv_heads=8, d_ff=2048, vocab=163840,
    head_dim=112, n_experts=384, top_k=8, first_k_dense=1, first_dense_ff=18432,
    rope="rope", supports_long=False,
    source="arXiv:2501.kimi2 (unverified, paper-table)",
    notes="~1T total params, ~32B active; EP over model axis + capacity routing.",
)
