"""repro — FloatSD8 low-complexity training/inference framework in JAX.

Implements Liu & Chiueh, "Low-Complexity LSTM Training and Inference with
FloatSD8 Weight Representation" (IJCNN 2020) as a production multi-pod
framework: the FloatSD8/FP8/FP16 precision stack is a first-class policy
usable by LSTMs and by the 10 assigned LM-family architectures.
"""
__version__ = "1.0.0"
