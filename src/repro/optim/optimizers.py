"""Optimizers with reduced-precision master copies (paper §III-B, §IV-B-b).

The master copy IS the param tree, stored at ``policy.master_dtype`` (FP16 in
Table VI). Updates are computed in f32 and added to the master in its own
dtype — 'addition of the FP16 master copy weight and the FP8 gradient'
(§IV-C). Adam/SGD cover the paper's four tasks; Adafactor-lite is the
factored-second-moment option that fits 1T-param training in HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["sgd", "adam", "adafactor", "Optimizer"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Any
    update: Any  # (grads, state, params, lr) -> (updates, state)


def _cast_like(src, ref):
    return jax.tree_util.tree_map(lambda s, r: s.astype(r.dtype), src, ref)


def sgd(momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, lr):
        g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        if momentum == 0.0:
            upd = jax.tree_util.tree_map(lambda g: -lr * g, g32)
            return upd, state
        buf = jax.tree_util.tree_map(lambda b, g: momentum * b + g, state, g32)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda b, g: -lr * (momentum * b + g), buf, g32)
        else:
            upd = jax.tree_util.tree_map(lambda b: -lr * b, buf)
        return upd, buf

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype)
        return AdamState(
            jax.tree_util.tree_map(z, params),
            jax.tree_util.tree_map(z, params),
            jnp.zeros((), jnp.int32),
        )

    def update(grads, state, params, lr):
        c = state.count + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd_mu(m, g):
            return (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(moment_dtype)

        def upd_nu(v, g):
            gf = g.astype(jnp.float32)
            return (b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf).astype(moment_dtype)

        mu = jax.tree_util.tree_map(upd_mu, state.mu, grads)
        nu = jax.tree_util.tree_map(upd_nu, state.nu, grads)

        def step(m, v):
            mh = m.astype(jnp.float32) / bc1
            vh = v.astype(jnp.float32) / bc2
            return -lr * mh / (jnp.sqrt(vh) + eps)

        return jax.tree_util.tree_map(step, mu, nu), AdamState(mu, nu, c)

    return Optimizer(init, update)


class FactorState(NamedTuple):
    row: Any  # factored second moments (or full for <2D)
    col: Any
    full: Any
    count: jax.Array


def adafactor(decay: float = 0.8, eps: float = 1e-30, clip: float = 1.0) -> Optimizer:
    """Factored second moment (Shazeer & Stern): O(n+m) optimizer state per
    (n x m) matrix — the memory-side enabler for kimi-k2 at 256 chips."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def rows(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) else ()

        def cols(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) if _factored(p) else ()

        def full(p):
            return () if _factored(p) else jnp.zeros(p.shape, jnp.float32)

        t = jax.tree_util.tree_map
        return FactorState(t(rows, params), t(cols, params), t(full, params),
                           jnp.zeros((), jnp.int32))

    def update(grads, state, params, lr):
        c = state.count + 1
        beta = 1.0 - c.astype(jnp.float32) ** -decay

        def one(g, r, cl, f):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if g.ndim >= 2:
                r2 = beta * r + (1 - beta) * jnp.mean(g2, axis=-1)
                c2 = beta * cl + (1 - beta) * jnp.mean(g2, axis=-2)
                rm = jnp.mean(r2, axis=-1, keepdims=True)
                v = (r2 / jnp.maximum(rm, eps))[..., None] * c2[..., None, :]
                upd = gf / jnp.sqrt(jnp.maximum(v, eps))
                new = (r2, cl * 0 + c2, f)
            else:
                f2 = beta * f + (1 - beta) * g2
                upd = gf / jnp.sqrt(jnp.maximum(f2, eps))
                new = (r, cl, f2)
            rms = jnp.sqrt(jnp.mean(upd * upd))
            upd = upd / jnp.maximum(1.0, rms / clip)
            return -lr * upd, new

        flat_g, td = jax.tree_util.tree_flatten(grads)
        flat_r = td.flatten_up_to(state.row)
        flat_c = td.flatten_up_to(state.col)
        flat_f = td.flatten_up_to(state.full)
        outs = [one(g, r, cc, f) for g, r, cc, f in zip(flat_g, flat_r, flat_c, flat_f)]
        upd = td.unflatten([o[0] for o in outs])
        row = td.unflatten([o[1][0] for o in outs])
        col = td.unflatten([o[1][1] for o in outs])
        full = td.unflatten([o[1][2] for o in outs])
        return upd, FactorState(row, col, full, c)

    return Optimizer(init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    return {"sgd": sgd, "adam": adam, "adafactor": adafactor}[name](**kw)
