"""FP8-compressed cross-pod gradient reduction (beyond-paper distributed
trick, directly licensed by the paper's 'all gradients are FP8' result).

With the ("pod","data","model") mesh, pjit's backward already reduces
gradients over "data" in full precision *within* a pod (cheap intra-pod ICI).
The expensive hop is pod<->pod (DCI). `pod_compressed_mean` shard_maps over
the pod axis only ("data"/"model" stay auto), casts the per-pod partial
gradient to FP8 with a per-tensor power-of-two scale, psums, and rescales —
halving (vs bf16) or quartering (vs f32) the cross-pod traffic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.fp8 import FP8_E5M2

__all__ = ["pod_compressed_mean", "fp8_psum"]


def _po2_scale(x):
    """power-of-two per-tensor scale placing max|x| near fp8 max/2."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    amax = jnp.where(amax > 0, amax, 1.0)
    return jnp.exp2(jnp.floor(jnp.log2(28672.0 / amax)))


def fp8_psum(x, axis_name: str):
    """Quantize to fp8-e5m2, all-reduce, rescale. Models each pod's
    contribution being transmitted in 8 bits."""
    s = _po2_scale(x)
    s = jax.lax.pmax(s, axis_name)  # consistent scale across pods
    xq = (x.astype(jnp.float32) * s).astype(FP8_E5M2)
    tot = jax.lax.psum(xq.astype(jnp.float32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (tot / (n * s)).astype(x.dtype)


def pod_compressed_mean(grads, mesh, pod_axis: str = "pod"):
    """Mean per-pod partial grads across pods with fp8 payloads.

    grads: pytree whose arrays are replicated (or sharded over data/model)
    within each pod but hold per-pod partial sums.
    """
    if pod_axis not in mesh.axis_names or mesh.shape[pod_axis] == 1:
        return grads

    def reduce_tree(g):
        return jax.tree_util.tree_map(lambda t: fp8_psum(t, pod_axis), g)

    other = tuple(a for a in mesh.axis_names if a != pod_axis)
    fn = jax.shard_map(
        reduce_tree,
        mesh=mesh,
        in_specs=P(),
        out_specs=P(),
        check_vma=False,
        axis_names={pod_axis},
    )
    return fn(grads)
