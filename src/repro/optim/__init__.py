"""Optimizers with FP16 master copies + FP8 gradient pipeline."""
from . import grad_compress, optimizers, train_state
from .optimizers import Optimizer, adafactor, adam, get_optimizer, sgd
from .train_state import TrainState, init_state, make_train_step

__all__ = [
    "grad_compress", "optimizers", "train_state",
    "Optimizer", "adafactor", "adam", "get_optimizer", "sgd",
    "TrainState", "init_state", "make_train_step",
]
