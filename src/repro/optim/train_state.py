"""TrainState: the paper's full update pipeline as one jittable step.

  loss*1024 -> backward (acts/act-grads FP8 inside the model) ->
  weight grads FP8 -> unscale f32, finite check ->
  optimizer update -> FP16 master add -> (re)quantize-at-use next step.

Two gradient paths, selected by ``make_train_step``:

  * **fused** (default when ``policy.grad_quant == 'fp8'``): the loss runs
    under ``grad_quant='fp8_kernel'`` — BPTT goes through the hand-written
    scan VJP and the LSTM gate matmuls emit their dW through the FP8
    quantizer *inside* the registered backward kernels
    (``kernels.dispatch.matmul_dw``). The ``grad_quant`` sweep below is an
    exact no-op on those leaves (fp8 is idempotent) and only provides the
    paper's §III-D coverage + overflow saturation for params no kernel
    emits (biases, embeddings, non-LSTM direct-use params) — it is a
    safety net, not the quantizer, on the hot leaves.
  * **autodiff baseline** (``fused=False`` or ``REPRO_FUSED_BPTT=0``): the
    pre-fusion behaviour — plain autodiff BPTT, with the same tree pass
    doing ALL the gradient quantization.

Skip-on-nonfinite keeps dynamic loss scaling sound; with static scaling
(paper) a nonfinite step is skipped the same way (equivalent to PyTorch's
GradScaler semantics the baselines use). The finite check, skip select, and
scale adjustment are all part of the single jitted step; ``donate=True``
additionally donates the TrainState argument so the params/optimizer
buffers are updated in place instead of copied every step.
"""
from __future__ import annotations

import os
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..core import loss_scaling as ls
from ..core.fp8 import grad_quant
from ..core.policy import Policy
from ..obs import telemetry as obs_telemetry
from .optimizers import Optimizer

__all__ = ["TrainState", "make_train_step"]


class TrainState(NamedTuple):
    step: jax.Array
    params: Any  # master copy (policy.master_dtype)
    opt_state: Any
    scale: ls.LossScaleState


def init_state(params, opt: Optimizer, policy: Policy, dynamic_scale=False) -> TrainState:
    mdt = policy.mdt()
    master = jax.tree_util.tree_map(lambda p: p.astype(mdt), params)
    st = (
        ls.dynamic_init() if dynamic_scale else ls.static_init(policy.loss_scale)
    )
    return TrainState(jnp.zeros((), jnp.int32), master, opt.init(master), st)


def make_train_step(loss_fn, opt: Optimizer, policy: Policy, lr: float = 1e-3,
                    grad_clip: float | None = 1.0, fused: bool | None = None,
                    donate: bool = False, telemetry: bool = False):
    """loss_fn(params, batch, policy) -> scalar. Returns a step fn.

    ``fused=None`` resolves to ``policy.grad_quant == 'fp8'`` unless
    ``REPRO_FUSED_BPTT=0`` (the killswitch restoring the tree-pass path).
    ``donate=True`` returns the step already jitted with the TrainState
    argument donated — callers must rebind ``state`` every step (every
    driver in this repo does).

    ``telemetry=True`` adds quantization-health stats (obs.telemetry) to
    the metrics dict under ``"tel"``: FP8 saturation/underflow/zero
    fractions measured on the loss-scaled grads at the §III-D sweep
    point, per-layer grad norms on the unscaled grads, and FloatSD
    carry/clamp fractions of the master-weight update. All computed
    inside the jitted step; feed the per-step dicts to a
    ``TelemetryLogger`` for aggregation + JSONL output.
    """
    if fused is None:
        fused = (
            policy.grad_quant == "fp8"
            and os.environ.get("REPRO_FUSED_BPTT", "1") != "0"
        )
    run_policy = (
        policy.replace(grad_quant="fp8_kernel")
        if fused and policy.grad_quant == "fp8"
        else policy
    )

    def step(state: TrainState, batch):
        def scaled_loss(p):
            l = loss_fn(p, batch, run_policy)
            return ls.scale_loss(l.astype(jnp.float32), state.scale), l

        grads, raw_loss = jax.grad(scaled_loss, has_aux=True)(state.params)
        # sweep-point telemetry: the loss-scaled values the FP8 quantizer
        # is about to see (saturation/underflow are scale-relative)
        tel = obs_telemetry.fp8_grad_stats(grads) if telemetry else None
        if run_policy.grad_quant in ("fp8", "fp8_kernel"):
            # paper §III-D: ALL gradients FP8. Idempotent (exact no-op) on
            # the leaves the fused backward kernels already emitted on the
            # fp8 grid; quantizes + saturates everything else.
            grads = grad_quant(grads)
        grads, finite = ls.unscale_and_check(grads, state.scale)
        if telemetry:
            tel["grad_norm"] = obs_telemetry.layer_grad_norms(grads)
        if grad_clip is not None:
            gnorm = jnp.sqrt(
                sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree_util.tree_leaves(grads)
                )
            )
            coef = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * coef.astype(g.dtype), grads)

        updates, new_opt = opt.update(grads, state.opt_state, state.params, lr)

        def apply(p, u):
            # FP16 master + update addition (f32 add, stored back at mdt)
            return (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype)

        new_params = jax.tree_util.tree_map(apply, state.params, updates)
        # skip-on-nonfinite: keep old state when grads overflowed
        new_params = jax.tree_util.tree_map(
            lambda n, o: jnp.where(finite, n, o), new_params, state.params
        )
        new_opt = jax.tree_util.tree_map(
            lambda n, o: jnp.where(finite, n, o) if isinstance(n, jax.Array) and n.shape == getattr(o, "shape", None) else n,
            new_opt, state.opt_state,
        )
        new_scale = ls.adjust(state.scale, finite)
        metrics = {
            "loss": raw_loss,
            "grads_finite": finite,
            "loss_scale": new_scale.scale,
        }
        if telemetry:
            # carry/clamp on the applied update (post skip-select, so a
            # skipped step honestly reports zero carries)
            tel.update(
                obs_telemetry.floatsd_update_stats(state.params, new_params)
            )
            metrics["tel"] = tel
        return TrainState(state.step + 1, new_params, new_opt, new_scale), metrics

    if donate:
        return jax.jit(step, donate_argnums=(0,))
    return step
