"""TrainState: the paper's full update pipeline as one jittable step.

  loss*1024 -> backward (acts/act-grads FP8 inside the model) ->
  weight grads FP8 (grad_quant) -> unscale f32, finite check ->
  optimizer update -> FP16 master add -> (re)quantize-at-use next step.

Skip-on-nonfinite keeps dynamic loss scaling sound; with static scaling
(paper) a nonfinite step is skipped the same way (equivalent to PyTorch's
GradScaler semantics the baselines use).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..core import loss_scaling as ls
from ..core.fp8 import grad_quant
from ..core.policy import Policy
from .optimizers import Optimizer

__all__ = ["TrainState", "make_train_step"]


class TrainState(NamedTuple):
    step: jax.Array
    params: Any  # master copy (policy.master_dtype)
    opt_state: Any
    scale: ls.LossScaleState


def init_state(params, opt: Optimizer, policy: Policy, dynamic_scale=False) -> TrainState:
    mdt = policy.mdt()
    master = jax.tree_util.tree_map(lambda p: p.astype(mdt), params)
    st = (
        ls.dynamic_init() if dynamic_scale else ls.static_init(policy.loss_scale)
    )
    return TrainState(jnp.zeros((), jnp.int32), master, opt.init(master), st)


def make_train_step(loss_fn, opt: Optimizer, policy: Policy, lr: float = 1e-3,
                    grad_clip: float | None = 1.0):
    """loss_fn(params, batch, policy) -> scalar. Returns jittable step fn."""

    def step(state: TrainState, batch):
        def scaled_loss(p):
            l = loss_fn(p, batch, policy)
            return ls.scale_loss(l.astype(jnp.float32), state.scale), l

        grads, raw_loss = jax.grad(scaled_loss, has_aux=True)(state.params)
        if policy.grad_quant == "fp8":
            # paper §III-D: ALL gradients FP8 (scaled into fp8 range by ls)
            grads = grad_quant(grads)
        grads, finite = ls.unscale_and_check(grads, state.scale)
        if grad_clip is not None:
            gnorm = jnp.sqrt(
                sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree_util.tree_leaves(grads)
                )
            )
            coef = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * coef.astype(g.dtype), grads)

        updates, new_opt = opt.update(grads, state.opt_state, state.params, lr)

        def apply(p, u):
            # FP16 master + update addition (f32 add, stored back at mdt)
            return (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype)

        new_params = jax.tree_util.tree_map(apply, state.params, updates)
        # skip-on-nonfinite: keep old state when grads overflowed
        new_params = jax.tree_util.tree_map(
            lambda n, o: jnp.where(finite, n, o), new_params, state.params
        )
        new_opt = jax.tree_util.tree_map(
            lambda n, o: jnp.where(finite, n, o) if isinstance(n, jax.Array) and n.shape == getattr(o, "shape", None) else n,
            new_opt, state.opt_state,
        )
        new_scale = ls.adjust(state.scale, finite)
        metrics = {
            "loss": raw_loss,
            "grads_finite": finite,
            "loss_scale": new_scale.scale,
        }
        return TrainState(state.step + 1, new_params, new_opt, new_scale), metrics

    return step
