"""GQA attention: chunked (flash-style) training path + KV-cache decode.

Supports: MHA/GQA (any kv_heads dividing heads), causal masking, sliding
window (SWA), cross-attention (whisper), RoPE / M-RoPE, fp8 KV-cache storage
(beyond-paper knob).

The training path streams KV in chunks with an online softmax (lax.scan),
bounding transient memory at seq 32k; kv heads are never materialized
group-expanded (GQA einsums keep the kv-head axis, so granite's kv=1 stays
replicated instead of broadcast-copied 48x).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.policy import Policy
from . import rotary
from .linear import QuantDense, quant_act

__all__ = ["Attention", "KVCache", "flash_attention"]

NEG_INF = -1e30


def _chunk(x, n):  # [B, S, ...] -> [n, B, C, ...]
    b, s = x.shape[:2]
    c = s // n
    return jnp.moveaxis(x.reshape(b, n, c, *x.shape[2:]), 1, 0)


def _split_chunks(sq, chunk, skv, kv_chunk):
    nq = max(1, sq // chunk)
    while sq % nq:
        nq -= 1
    nk = max(1, skv // kv_chunk)
    while skv % nk:
        nk -= 1
    return nq, nk


def _mask_tile(qp, kp, b, qc, kc, causal, window):
    mask = jnp.ones((b, qc, kc), bool)
    if causal:
        mask &= kp[:, None, :] <= qp[:, :, None]
    if window is not None:
        mask &= qp[:, :, None] - kp[:, None, :] < window
    return mask


def _flash_fwd(q, k, v, q_pos, k_pos, causal, window, chunk, kv_chunk):
    """Returns (out [B,Sq,Kh,G,D], lse [B,Kh,G,Sq])."""
    b, sq, kh, g, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    nq, nk = _split_chunks(sq, chunk, skv, kv_chunk)

    qs = _chunk(q, nq)  # [nq, B, qc, Kh, G, D]
    qps = _chunk(q_pos[..., None], nq)[..., 0]  # [nq, B, qc]
    ks = _chunk(k, nk)  # [nk, B, kc, Kh, D]
    vs = _chunk(v, nk)
    kps = _chunk(k_pos[..., None], nk)[..., 0]  # [nk, B, kc]
    qc = sq // nq

    def q_body(_, q_in):
        qi, qp = q_in
        qf = qi.astype(jnp.float32) * scale

        def kv_body(carry, inp):
            # named_scope 'flashable': every tensor in this block is a score/
            # probability tile the Pallas flash kernel (kernels/flash_attention)
            # keeps VMEM-resident on TPU. The roofline's kernel-substitution
            # model (analyze_hlo vmem_scopes) keys on this scope name.
            with jax.named_scope("flashable"):
                m, l, acc = carry
                kc_, vc, kp = inp
                s = jnp.einsum(
                    "bqkgd,bckd->bkgqc", qf, kc_.astype(jnp.float32),
                    preferred_element_type=jnp.float32,
                )  # [B,Kh,G,qc,kc]
                mask = _mask_tile(qp, kp, b, qc, kc_.shape[1], causal, window)
                s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + jnp.sum(p, axis=-1)
                pv = jnp.einsum(
                    "bkgqc,bckd->bkgqd",
                    p.astype(jnp.bfloat16), vc.astype(jnp.bfloat16),
                    preferred_element_type=jnp.float32,
                )
                acc_new = acc * alpha[..., None] + pv
                return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kh, g, qc, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (ks, vs, kps))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))  # [B,Kh,G,qc]
        return None, (jnp.moveaxis(out, 3, 1), lse)  # ([B,qc,Kh,G,D], ...)

    _, (outs, lses) = jax.lax.scan(q_body, None, (qs, qps))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, kh, g, d)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, kh, g, sq)  # [B,Kh,G,Sq]
    return out.astype(q.dtype), lse


def _flash_bwd(q, k, v, q_pos, k_pos, out, lse, do,
               causal, window, chunk, kv_chunk):
    """Flash backward: recompute score tiles per chunk (no T^2 residuals).

    Standard FA2 recipe: p = exp(s - lse) (normalized), dv = p^T do,
    dp = do v^T, ds = p * (dp - delta) with delta = rowsum(do * o),
    dq = scale * ds k, dk = scale * ds^T q.
    """
    b, sq, kh, g, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    nq, nk = _split_chunks(sq, chunk, skv, kv_chunk)
    qc, kc = sq // nq, skv // nk

    qs = _chunk(q, nq)  # [nq, B, qc, Kh, G, D]
    qps = _chunk(q_pos[..., None], nq)[..., 0]
    dos = _chunk(do, nq)
    # delta[b,h,g,q] = rowsum(do * o): q-sized, computed once up front
    delta_full = jnp.einsum(
        "bqkgd,bqkgd->bkgq", do.astype(jnp.float32), out.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )  # [B,Kh,G,Sq]
    deltas = jnp.moveaxis(delta_full.reshape(b, kh, g, nq, qc), 3, 0)
    lse_q = jnp.moveaxis(lse.reshape(b, kh, g, nq, qc), 3, 0)
    ks = _chunk(k, nk)  # [nk, B, kc, Kh, D]
    vs = _chunk(v, nk)
    kps = _chunk(k_pos[..., None], nk)[..., 0]

    def q_body(carry, q_in):
        dk_acc, dv_acc = carry  # [nk, B, kc, Kh, D] f32
        qi, qp, do_c, lse_c, delta = q_in
        qf = qi.astype(jnp.float32) * scale
        dof = do_c.astype(jnp.float32)  # [B, qc, Kh, G, D]
        with jax.named_scope("flashable"):
            def kv_body(dq_c, inp):
                kc_, vc, kp = inp
                s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kc_.astype(jnp.float32),
                               preferred_element_type=jnp.float32)
                mask = _mask_tile(qp, kp, b, qc, kc_.shape[1], causal, window)
                s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
                p = jnp.exp(s - lse_c[..., None])  # normalized [B,Kh,G,qc,kc]
                dv_c = jnp.einsum("bkgqc,bqkgd->bckd",
                                  p.astype(jnp.bfloat16), dof.astype(jnp.bfloat16),
                                  preferred_element_type=jnp.float32)
                dp = jnp.einsum("bqkgd,bckd->bkgqc", dof, vc.astype(jnp.float32),
                                preferred_element_type=jnp.float32)
                ds = p * (dp - delta[..., None]) * scale  # includes d/ds scale
                dsb = ds.astype(jnp.bfloat16)
                dq_c = dq_c + jnp.einsum("bkgqc,bckd->bqkgd", dsb,
                                         kc_.astype(jnp.bfloat16),
                                         preferred_element_type=jnp.float32)
                dk_c = jnp.einsum("bkgqc,bqkgd->bckd", dsb,
                                  qi.astype(jnp.bfloat16),  # raw q: scale in ds
                                  preferred_element_type=jnp.float32)
                return dq_c, (dk_c, dv_c)

            dq0 = jnp.zeros((b, qc, kh, g, d), jnp.float32)
            dq_c, (dks, dvs) = jax.lax.scan(kv_body, dq0, (ks, vs, kps))
        return (dk_acc + dks, dv_acc + dvs), dq_c

    z = jnp.zeros((nk, b, kc, kh, d), jnp.float32)
    (dk_s, dv_s), dqs = jax.lax.scan(
        q_body, (z, z), (qs, qps, dos, lse_q, deltas)
    )
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, sq, kh, g, d).astype(q.dtype)
    dk = jnp.moveaxis(dk_s, 0, 1).reshape(b, skv, kh, d).astype(k.dtype)
    dv = jnp.moveaxis(dv_s, 0, 1).reshape(b, skv, kh, d).astype(v.dtype)
    return dq, dk, dv


@functools.lru_cache(maxsize=None)
def _make_flash(causal, window, chunk, kv_chunk):
    @jax.custom_vjp
    def fa(q, k, v, q_pos, k_pos):
        out, _ = _flash_fwd(q, k, v, q_pos, k_pos, causal, window, chunk, kv_chunk)
        return out

    def fwd(q, k, v, q_pos, k_pos):
        out, lse = _flash_fwd(q, k, v, q_pos, k_pos, causal, window, chunk, kv_chunk)
        return out, (q, k, v, q_pos, k_pos, out, lse)

    def bwd(res, do):
        q, k, v, q_pos, k_pos, out, lse = res
        dq, dk, dv = _flash_bwd(
            q, k, v, q_pos, k_pos, out, lse, do, causal, window, chunk, kv_chunk
        )
        import numpy as _np

        f0 = lambda x: _np.zeros(x.shape, jax.dtypes.float0)
        return dq, dk, dv, f0(q_pos), f0(k_pos)

    fa.defvjp(fwd, bwd)
    return fa


# Perf A/B switch (EXPERIMENTS.md §Perf): True = custom flash VJP (backward
# recomputes tiles, no T^2 residuals); False = plain autodiff through the
# scan (saves stacked probability residuals — the pre-optimization baseline).
import os as _os

FLASH_VJP = _os.environ.get("REPRO_FLASH_VJP", "1") != "0"


def flash_attention(
    q: jax.Array,  # [B, Sq, Kh, G, D]
    k: jax.Array,  # [B, Skv, Kh, D]
    v: jax.Array,  # [B, Skv, Kh, D]
    q_pos: jax.Array,  # [B, Sq] int32
    k_pos: jax.Array,  # [B, Skv] int32
    *,
    causal: bool = True,
    window: int | None = None,
    chunk: int = 1024,  # q-chunk
    kv_chunk: int = 512,
) -> jax.Array:
    """Double-blocked online-softmax attention with a flash-style custom
    VJP: the backward recomputes score tiles per chunk instead of saving
    T^2 probability residuals (the XLA analogue of the FA2 kernel; the
    Pallas TPU kernel in kernels/flash_attention implements the same
    schedule in VMEM). Returns [B, Sq, Kh, G, D].
    """
    if FLASH_VJP:
        return _make_flash(causal, window, int(chunk), int(kv_chunk))(
            q, k, v, q_pos, k_pos
        )
    out, _ = _flash_fwd(q, k, v, q_pos, k_pos, causal, window,
                        int(chunk), int(kv_chunk))
    return out


class KVCache(NamedTuple):
    k: jax.Array  # [B, S_max, Kh, D]  (ring buffer if windowed)
    v: jax.Array
    pos: jax.Array  # [] int32 — absolute next position

    @staticmethod
    def init(batch, s_max, kv_heads, head_dim, dtype=jnp.bfloat16):
        z = jnp.zeros((batch, s_max, kv_heads, head_dim), dtype)
        return KVCache(z, z, jnp.int32(0))


@dataclasses.dataclass(frozen=True)
class Attention:
    dim: int
    heads: int
    kv_heads: int
    head_dim: int | None = None
    causal: bool = True
    window: int | None = None  # SWA
    rope: str = "rope"  # "rope" | "mrope" | "none"
    rope_theta: float = 10000.0
    mrope_sections: tuple = (16, 24, 24)
    qkv_bias: bool = False  # phi4/qwen2 style
    chunk: int = 1024
    name: str = "attn"

    @property
    def hd(self):
        return self.head_dim or self.dim // self.heads

    @property
    def groups(self):
        return self.heads // self.kv_heads

    def _dense(self, out_dim, out_axis, bias):
        return QuantDense(self.dim, out_dim, use_bias=bias, in_axis="embed", out_axis=out_axis)

    def init(self, key):
        ks = jax.random.split(key, 4)
        h, kh, d = self.heads, self.kv_heads, self.hd
        return {
            "wq": self._dense(h * d, "heads", self.qkv_bias).init(ks[0]),
            "wk": self._dense(kh * d, "kv_heads", self.qkv_bias).init(ks[1]),
            "wv": self._dense(kh * d, "kv_heads", self.qkv_bias).init(ks[2]),
            "wo": QuantDense(h * d, self.dim, use_bias=False, in_axis="heads", out_axis="embed").init(ks[3]),
        }

    def specs(self):
        return {
            "wq": self._dense(1, "heads", self.qkv_bias).specs(),
            "wk": self._dense(1, "kv_heads", self.qkv_bias).specs(),
            "wv": self._dense(1, "kv_heads", self.qkv_bias).specs(),
            "wo": {"w": ("heads", "embed")},
        }

    def _qkv(self, p, x, policy, positions):
        b, s, _ = x.shape
        h, kh, d = self.heads, self.kv_heads, self.hd
        q = self._dense(h * d, "heads", self.qkv_bias).apply(p["wq"], x, policy).reshape(b, s, h, d)
        k = self._dense(kh * d, "kv_heads", self.qkv_bias).apply(p["wk"], x, policy).reshape(b, s, kh, d)
        v = self._dense(kh * d, "kv_heads", self.qkv_bias).apply(p["wv"], x, policy).reshape(b, s, kh, d)
        if self.rope == "rope":
            q, k = rotary.apply_rope(q, k, positions, d, self.rope_theta)
        elif self.rope == "mrope":
            q, k = rotary.apply_mrope(q, k, positions, d, self.mrope_sections, self.rope_theta)
        return q, k, v

    def apply(self, p, x, policy: Policy, positions=None, kv=None, kv_positions=None):
        """Training / prefill path. x: [B,S,dim]. If kv given: cross-attn."""
        b, s, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        pos1d = positions if positions.ndim == 2 else positions[..., 0]
        q, k, v = self._qkv(p, x, policy, positions)
        if kv is not None:  # cross attention: keys/values from encoder states
            kx = kv
            bk, sk, _ = kx.shape
            kh, d = self.kv_heads, self.hd
            k = self._dense(kh * d, "kv_heads", self.qkv_bias).apply(p["wk"], kx, policy).reshape(bk, sk, kh, d)
            v = self._dense(kh * d, "kv_heads", self.qkv_bias).apply(p["wv"], kx, policy).reshape(bk, sk, kh, d)
            kpos = (
                kv_positions
                if kv_positions is not None
                else jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32), (bk, sk))
            )
            causal = False
        else:
            kpos = pos1d
            causal = self.causal
        qg = q.reshape(b, s, self.kv_heads, self.groups, self.hd)
        out = flash_attention(
            qg, k, v, pos1d, kpos,
            causal=causal, window=self.window, chunk=min(self.chunk, k.shape[1]),
        ).reshape(b, s, self.heads * self.hd)
        return QuantDense(self.heads * self.hd, self.dim, use_bias=False, in_axis="heads", out_axis="embed").apply(p["wo"], out, policy)

    def decode(self, p, x, cache: KVCache, policy: Policy, positions3=None):
        """One-token decode. x: [B,1,dim]. Returns (out, new_cache)."""
        b, s, _ = x.shape
        assert s == 1
        s_max = cache.k.shape[1]
        pos = cache.pos
        positions = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1))
        if self.rope == "mrope":
            # text continuation: t == h == w == pos (matches training path)
            rp = (
                positions3
                if positions3 is not None
                else jnp.broadcast_to(pos.astype(jnp.int32), (b, 1, 3))
            )
        else:
            rp = positions
        q, k, v = self._qkv(p, x, policy, rp)
        slot = (pos % s_max).astype(jnp.int32)  # ring buffer when windowed
        ck = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), slot, axis=1)
        # absolute positions stored in the ring: slot i holds pos p iff
        # p % s_max == i and p <= pos. Reconstruct:
        idx = jnp.arange(s_max, dtype=jnp.int32)
        wrap = (pos // s_max) - (idx > slot)
        abs_pos = wrap * s_max + idx  # [S_max], negative -> never written
        valid = (abs_pos >= 0) & (abs_pos <= pos)
        if self.window is not None:
            valid &= pos - abs_pos < self.window
        qg = q.reshape(b, 1, self.kv_heads, self.groups, self.hd).astype(jnp.float32)
        scale = 1.0 / jnp.sqrt(self.hd).astype(jnp.float32)
        sc = jnp.einsum(
            "bqkgd,bckd->bkgqc", qg * scale, ck.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        sc = jnp.where(valid[None, None, None, None, :], sc, NEG_INF)
        w = jax.nn.softmax(sc, axis=-1)
        out = jnp.einsum(
            "bkgqc,bckd->bqkgd", w, cv.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ).astype(x.dtype).reshape(b, 1, self.heads * self.hd)
        out = QuantDense(self.heads * self.hd, self.dim, use_bias=False, in_axis="heads", out_axis="embed").apply(p["wo"], out, policy)
        return out, KVCache(ck, cv, pos + 1)
