"""Minimal functional module substrate.

Every layer in `repro.nn` is a frozen dataclass with two methods:

    init(key)  -> params        (nested dict of jnp arrays)
    specs()    -> spec tree     (same structure; leaves = tuple of LOGICAL
                                 axis names, one per array dim)

Logical axis names are mapped to physical mesh axes by
``repro.distributed.sharding.logical_to_mesh`` — this is the MaxText-style
separation that lets one model definition run on any mesh.

Stacked (scanned) parameters get a leading "layers" axis; `stack_init` /
`scan_layers` handle stacking and remat.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "truncated_normal_init",
    "zeros_init",
    "ones_init",
    "uniform_init",
    "stack_init",
    "stack_specs",
    "scan_layers",
    "tree_size",
    "count_params",
]

Params = Any  # nested dict of arrays
Specs = Any  # nested dict of tuples


def truncated_normal_init(key, shape, stddev: float | None = None, dtype=jnp.float32):
    if stddev is None:  # fan-in scaling
        stddev = 1.0 / np.sqrt(shape[0] if len(shape) > 1 else shape[-1])
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def zeros_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)


def uniform_init(key, shape, scale, dtype=jnp.float32):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def stack_init(layer_init: Callable, n: int):
    """init for n stacked copies of a layer: vmap over split keys."""

    def init(key):
        keys = jax.random.split(key, n)
        return jax.vmap(layer_init)(keys)

    return init


def stack_specs(specs: Specs) -> Specs:
    """Prepend the scan axis name to every leaf spec."""
    return jax.tree_util.tree_map(
        lambda s: ("layers",) + tuple(s),
        specs,
        is_leaf=lambda s: type(s) is tuple,
    )


def scan_layers(
    body: Callable,
    stacked_params: Params,
    x: jax.Array,
    *,
    remat: str = "none",  # "none" | "full" | "dots"
    unroll: int = 1,
    extra_carry: Any = None,
):
    """x -> scan(body) over the leading 'layers' axis of stacked_params.

    body(carry, layer_params) -> (carry, None). carry is (x, extra_carry) if
    extra_carry is not None else x. Remat wraps the body — "full" recomputes
    everything in backward (min memory), "dots" saves matmul outputs
    (jax.checkpoint_policies.checkpoint_dots).
    """
    fn = body
    if remat == "full":
        fn = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        fn = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )
    carry = x if extra_carry is None else (x, extra_carry)
    carry, _ = jax.lax.scan(fn, carry, stacked_params, unroll=unroll)
    return carry


def tree_size(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(tree))


count_params = tree_size


def module(cls):
    """decorator: frozen dataclass."""
    return dataclasses.dataclass(frozen=True)(cls)
