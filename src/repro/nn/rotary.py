"""RoPE and M-RoPE (Qwen2-VL §2.1) position embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rope_freqs", "apply_rope", "apply_mrope"]


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def _rotate(x, sin, cos):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(q, k, positions, head_dim: int, theta: float = 10000.0):
    """q,k: [B, S, H, D]; positions: [B, S] int32."""
    freqs = rope_freqs(head_dim, theta)  # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, D/2]
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    q = _rotate(q.astype(jnp.float32), sin, cos).astype(q.dtype)
    k = _rotate(k.astype(jnp.float32), sin, cos).astype(k.dtype)
    return q, k


def apply_mrope(
    q,
    k,
    positions3,
    head_dim: int,
    sections=(16, 24, 24),
    theta: float = 10000.0,
):
    """Multimodal RoPE: positions3 [B, S, 3] = (t, h, w) ids; frequency
    channels are split into `sections` (in D/2 units), each section driven by
    its own position id. For pure-text, t == h == w == arange -> reduces to
    1-D RoPE exactly."""
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = rope_freqs(head_dim, theta)  # [D/2]
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=head_dim // 2
    )  # [D/2] in {0,1,2}
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, None, :], positions3.shape[:2] + (head_dim // 2,)).astype(jnp.int32),
        axis=-1,
    )  # [B, S, D/2]
    ang = pos * freqs
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    q = _rotate(q.astype(jnp.float32), sin, cos).astype(q.dtype)
    k = _rotate(k.astype(jnp.float32), sin, cos).astype(k.dtype)
    return q, k
