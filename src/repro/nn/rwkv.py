"""RWKV-6 "Finch" block (arXiv:2404.05892): attention-free time mixing with
data-dependent decay. The receptance gate is a *native* fit for the paper's
two-region FloatSD8 sigmoid (DESIGN.md §5). State is O(1) per token
([B, H, K, V]), which is why rwkv6 runs the 500k long-context shape.

Faithful simplifications (documented): the token-shift lerp uses a single
learned mix per projection (RWKV6's 5-way LoRA mix collapsed to its static
term); decay LoRA rank 64. Both preserve shapes, state layout, and FLOP
structure of the published block.
"""
from __future__ import annotations

import dataclasses
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.policy import Policy
from ..core.qsigmoid import qsigmoid
from . import module as M
from .linear import quant_act, quant_einsum

__all__ = ["RWKV6TimeMix", "RWKV6ChannelMix", "RWKVState"]

# Perf A/B switch (EXPERIMENTS.md §Perf hillclimb #3): chunked wkv evaluation
# (linear-attention chunkwise form — state hops HBM once per CHUNK tokens
# instead of once per token; intra-chunk is exact via a [L,L,K] log-decay
# tile, MXU-friendly). 0 = per-token sequential scan (paper-era baseline).
RWKV_CHUNK = int(os.environ.get("REPRO_RWKV_CHUNK", "16"))


class RWKVState(NamedTuple):
    s: jax.Array  # [B, H, K, V] wkv state
    x_tm: jax.Array  # [B, dim] prev token (time-mix shift)
    x_cm: jax.Array  # [B, dim] prev token (channel-mix shift)


def _sigmoid(x, q):
    return qsigmoid(x) if q else jax.nn.sigmoid(x)


@dataclasses.dataclass(frozen=True)
class RWKV6TimeMix:
    dim: int
    head_dim: int = 64
    decay_rank: int = 64
    name: str = "rwkv_tmix"

    @property
    def heads(self):
        return self.dim // self.head_dim

    def init(self, key):
        ks = jax.random.split(key, 8)
        d, r = self.dim, self.decay_rank
        h, hd = self.heads, self.head_dim
        return {
            "mix": M.uniform_init(ks[0], (5, d), 0.5) + 0.5,  # r,k,v,w,g lerps
            "wr": M.truncated_normal_init(ks[1], (d, d)),
            "wk": M.truncated_normal_init(ks[2], (d, d)),
            "wv": M.truncated_normal_init(ks[3], (d, d)),
            "wg": M.truncated_normal_init(ks[4], (d, d)),
            "wo": M.truncated_normal_init(ks[5], (d, d)),
            "w0": jnp.full((d,), -6.0, jnp.float32),  # decay base
            "w_lora_a": M.truncated_normal_init(ks[6], (d, r), 0.01),
            "w_lora_b": M.truncated_normal_init(ks[7], (r, d), 0.01),
            "u": jnp.zeros((h, hd), jnp.float32),  # bonus
            "ln_scale": jnp.ones((d,), jnp.float32),
        }

    def specs(self):
        return {
            "mix": (None, "embed"),
            "wr": ("embed", "heads"),
            "wk": ("embed", "heads"),
            "wv": ("embed", "heads"),
            "wg": ("embed", "heads"),
            "wo": ("heads", "embed"),
            "w0": ("heads",),
            "w_lora_a": ("embed", None),
            "w_lora_b": (None, "heads"),
            "u": ("kv_heads", None),
            "ln_scale": ("heads",),
        }

    def _proj(self, p, x, xprev, policy):
        """token-shift lerp + the five projections. x,xprev: [B,S,d]."""
        mix = p["mix"]

        def lerp(i):
            m = mix[i].astype(x.dtype)
            return x * m + xprev * (1 - m)

        r = quant_einsum("bsd,dk->bsk", lerp(0), p["wr"], policy)
        k = quant_einsum("bsd,dk->bsk", lerp(1), p["wk"], policy)
        v = quant_einsum("bsd,dk->bsk", lerp(2), p["wv"], policy)
        wl = jnp.einsum(
            "bsd,dr,rk->bsk",
            lerp(3).astype(jnp.float32), p["w_lora_a"], p["w_lora_b"],
        )
        w = jnp.exp(-jnp.exp(p["w0"] + wl))  # data-dependent decay in (0,1)
        g = quant_einsum("bsd,dk->bsk", lerp(4), p["wg"], policy)
        return r, k, v, w, g

    def _heads(self, t):
        b, s, d = t.shape
        return t.reshape(b, s, self.heads, self.head_dim)

    def _wkv_sequential(self, rh, kh, vh, wh, u, s0):
        """Per-token scan (baseline). rh/kh/vh/wh: [B,S,H,hd]."""

        def step(st, t):
            rt, kt, vt, wt = t  # [B,H,hd]
            kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32), vt.astype(jnp.float32))
            y = jnp.einsum(
                "bhk,bhkv->bhv", rt.astype(jnp.float32), st + u[None, :, :, None] * kv
            )
            st = st * wt[..., None] + kv
            return st, y

        sw = lambda t: jnp.swapaxes(t, 0, 1)  # [S,B,H,hd]
        s_fin, ys = jax.lax.scan(step, s0, (sw(rh), sw(kh), sw(vh), sw(wh)))
        return jnp.swapaxes(ys, 0, 1), s_fin

    def _wkv_chunked(self, rh, kh, vh, wh, u, s0, chunk: int):
        """Chunkwise-parallel wkv (hillclimb #3; exact — validated against
        the sequential scan in tests/test_rwkv_chunked.py).

        Recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T,
                    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T).
        With b_t = cumsum(log w) inside a chunk (b_{-1}=0):
          y_t   = (r_t . e^{b_{t-1}}) S_0                      (inter)
                + sum_{i<t} A_ti v_i,  A_ti = sum_k r_tk k_ik e^{b_{t-1,k}-b_{i,k}}
                + (r_t . u . k_t) v_t                          (bonus)
          S_L   = diag(e^{b_{L-1}}) S_0 + sum_i diag(e^{b_{L-1}-b_i}) k_i v_i
        All exponents in the inter/state terms are <= 0 (safe); the intra
        A-tile uses the exact [L,L,K] log-difference (no clamping), which is
        why the chunk stays small — its VMEM-scale tile is the thing a fused
        TPU kernel keeps on-chip ('flashable' scope).
        """
        b, s, h, hd = rh.shape
        nc = s // chunk
        shp = lambda t: t.reshape(b, nc, chunk, h, hd)
        rc = shp(rh.astype(jnp.float32))
        kc = shp(kh.astype(jnp.float32))
        vc = shp(vh.astype(jnp.float32))
        logw = shp(jnp.log(jnp.maximum(wh, 1e-38)))

        def chunk_body(st, t):
            rt, kt, vt, lw = t  # [B,L,H,K]
            with jax.named_scope("flashable"):
                bcum = jnp.cumsum(lw, axis=1)  # b_t, inclusive  [B,L,H,K]
                bprev = bcum - lw  # b_{t-1} (zero at t=0)
                blast = bcum[:, -1]  # [B,H,K]
                q_in = rt * jnp.exp(bprev)  # decayed receptance
                y_inter = jnp.einsum("blhk,bhkv->blhv", q_in, st)
                # intra-chunk: exact pairwise log-decay tile [B,H,L,L,K]
                ldiff = bprev[:, :, None] - bcum[:, None, :, :, :]  # t,i
                a = jnp.einsum(
                    "blhk,bihk,blihk->blih",
                    rt, kt, jnp.exp(jnp.minimum(ldiff, 0.0)),
                )
                mask = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
                a = jnp.where(mask[None, :, :, None], a, 0.0)
                y_intra = jnp.einsum("blih,bihv->blhv", a, vt)
                y_bonus = (
                    jnp.sum(rt * u[None, None] * kt, -1, keepdims=True) * vt
                )
                # chunk-end state: decays <= 0 -> safe factorization
                kd = kt * jnp.exp(blast[:, None] - bcum)
                st_new = st * jnp.exp(blast)[..., None] + jnp.einsum(
                    "blhk,blhv->bhkv", kd, vt
                )
            return st_new, y_inter + y_intra + y_bonus

        sw = lambda t: jnp.swapaxes(t, 0, 1)  # [NC,B,L,H,hd]
        s_fin, ys = jax.lax.scan(
            chunk_body, s0, (sw(rc), sw(kc), sw(vc), sw(logw))
        )
        y = jnp.swapaxes(ys, 0, 1).reshape(b, s, h, hd)
        return y, s_fin

    def apply(self, p, x, policy: Policy, state: RWKVState | None = None):
        """x: [B,S,d] -> ([B,S,d], final_state_s). wkv scan (chunked or
        sequential per RWKV_CHUNK)."""
        b, s, d = x.shape
        h, hd = self.heads, self.head_dim
        cdt = policy.cdt() or x.dtype
        xq = quant_act(x, policy)
        xprev = jnp.concatenate([jnp.zeros_like(xq[:, :1]), xq[:, :-1]], axis=1)
        if state is not None:
            xprev = xprev.at[:, 0].set(state.x_tm.astype(xq.dtype))
        r, k, v, w, g = self._proj(p, xq, xprev, policy)
        rh, kh, vh = map(self._heads, (r, k, v))
        wh = self._heads(w.astype(jnp.float32))
        u = p["u"]

        s0 = (
            state.s
            if state is not None
            else jnp.zeros((b, h, hd, hd), jnp.float32)
        )
        if RWKV_CHUNK and s % RWKV_CHUNK == 0 and s > 1:
            ys, s_fin = self._wkv_chunked(rh, kh, vh, wh, u, s0, RWKV_CHUNK)
        else:
            ys, s_fin = self._wkv_sequential(rh, kh, vh, wh, u, s0)
        y = ys.reshape(b, s, d)
        # group-norm per head then output gate
        yh = y.reshape(b, s, h, hd)
        yh = yh * jax.lax.rsqrt(jnp.mean(yh * yh, -1, keepdims=True) + 1e-6)
        y = (yh.reshape(b, s, d) * p["ln_scale"]).astype(cdt)
        y = y * _sigmoid(g, policy.sigmoid_quant)  # receptance-style gate
        out = quant_einsum("bsd,dk->bsk", y, p["wo"], policy)
        return out, (s_fin, xq[:, -1])


@dataclasses.dataclass(frozen=True)
class RWKV6ChannelMix:
    dim: int
    hidden: int
    name: str = "rwkv_cmix"

    def init(self, key):
        ks = jax.random.split(key, 3)
        return {
            "mix": M.uniform_init(ks[0], (2, self.dim), 0.5) + 0.5,
            "wk": M.truncated_normal_init(ks[1], (self.dim, self.hidden)),
            "wv": M.truncated_normal_init(ks[2], (self.hidden, self.dim)),
            "wr": M.truncated_normal_init(ks[0], (self.dim, self.dim)),
        }

    def specs(self):
        return {
            "mix": (None, "embed"),
            "wk": ("embed", "mlp"),
            "wv": ("mlp", "embed"),
            "wr": ("embed", "embed2"),
        }

    def apply(self, p, x, policy: Policy, x_prev_last=None):
        b, s, d = x.shape
        xq = quant_act(x, policy)
        xprev = jnp.concatenate([jnp.zeros_like(xq[:, :1]), xq[:, :-1]], axis=1)
        if x_prev_last is not None:
            xprev = xprev.at[:, 0].set(x_prev_last.astype(xq.dtype))
        m = p["mix"].astype(x.dtype)
        xk = xq * m[0] + xprev * (1 - m[0])
        xr = xq * m[1] + xprev * (1 - m[1])
        k = quant_einsum("bsd,dk->bsk", xk, p["wk"], policy)
        k = jnp.square(jax.nn.relu(k))
        kv = quant_einsum("bsh,hd->bsd", k, p["wv"], policy)
        # the paper's technique, natively: sigmoid receptance -> FloatSD8
        r = _sigmoid(
            quant_einsum("bsd,dk->bsk", xr, p["wr"], policy), policy.sigmoid_quant
        )
        return r * kv, xq[:, -1]
