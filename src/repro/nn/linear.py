"""Quantized dense / embedding layers — the paper's matmul site.

QuantDense implements the FloatSD8 x FP8 multiply of paper §III:
  * weights fake-quantized to FloatSD8 with straight-through gradients
    (master copy = the raw param; quantize-at-use == paper's re-quantize
    after update, since quantization is deterministic),
  * input activations quantized to the policy's (fwd, bwd) dtypes,
  * accumulation via ``preferred_element_type=float32`` (DESIGN.md §3.3).
"""
from __future__ import annotations

import dataclasses
import functools
import os

import jax
import jax.numpy as jnp

from ..core import floatsd, floatsd4
from ..core.fp8 import act_quant
from ..core.policy import Policy
from ..kernels import dispatch as kd
from . import module as M

__all__ = ["QuantDense", "QuantEmbedding", "quant_weight", "quant_einsum"]

# Perf A/B switch (EXPERIMENTS.md §Perf hillclimb #1/kimi): emit the
# weight-gradient dot in bf16 so the cross-shard gradient reduction (the
# all-reduce the SPMD partitioner inserts at that dot) moves half the bytes.
# Per-shard accumulation stays f32 inside the MXU; only the wire format
# narrows — the paper's FP8-gradient ethos applied at the reduction point.
# Active only for quantized policies (grad_quant != "none").
GRAD_REDUCE_BF16 = os.environ.get("REPRO_GRAD_REDUCE_BF16", "1") != "0"


@functools.lru_cache(maxsize=None)
def _make_einsum_gc(eq: str):
    """einsum with explicit-transpose VJP: dx keeps f32 accumulation; dw is
    emitted bf16 (the gradient-compression point). Supports the plain
    two-operand contractions used at every weight site (no repeated or
    diagonal labels)."""
    ins, out = eq.split("->")
    in1, in2 = ins.split(",")

    @jax.custom_vjp
    def f(x, w):
        return jnp.einsum(eq, x, w, preferred_element_type=jnp.float32)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        dx = jnp.einsum(
            f"{in2},{out}->{in1}", w, g, preferred_element_type=jnp.float32
        ).astype(x.dtype)
        dw = jnp.einsum(
            f"{in1},{out}->{in2}", x, g, preferred_element_type=jnp.bfloat16
        ).astype(w.dtype)
        return dx, dw

    f.defvjp(fwd, bwd)
    return f


def quant_weight(w: jax.Array, policy: Policy) -> jax.Array:
    """Apply the policy's weight quantizer (site: any matmul weight).

    PackedTensor weights (the serving deployment format) pass through: the
    codes ARE the quantized weights, and the matmul site dispatches them to
    the fused decode+matmul kernel (or decodes for the jnp oracle). Same
    for PackedTensor4 (the sub-byte serving format)."""
    if kd.is_packed(w) or kd.is_packed4(w):
        return w
    if policy.weight_quant == "floatsd8":
        bias = jax.lax.stop_gradient(floatsd.fit_bias(w))
        w = floatsd.quantize_ste(w, bias)
    return w.astype(policy.cdt() or w.dtype)


def quant_act(x: jax.Array, policy: Policy, site: str = "hidden") -> jax.Array:
    fwd, bwd = policy.act_dtypes(site)
    if fwd is None and bwd is None:
        return x
    return act_quant(x, fwd, bwd)


def policy_einsum(eq: str, x: jax.Array, w: jax.Array, policy: Policy):
    """The bare matmul primitive all weight sites share: f32 accumulation,
    bf16 dW emission when the policy quantizes gradients (GRAD_REDUCE_BF16).
    Operands must already be quantized/cast. Packed weights (either
    format) route to the kernel dispatch layer (inference-only: no VJP
    through codes)."""
    if kd.is_packed(w) or kd.is_packed4(w):
        return kd.packed_einsum(eq, x, w, cast_dtype=policy.cdt())
    if GRAD_REDUCE_BF16 and policy.grad_quant != "none":
        return _make_einsum_gc(eq)(x, w)
    return jnp.einsum(eq, x, w, preferred_element_type=jnp.float32)


def quant_einsum(eq: str, x: jax.Array, w: jax.Array, policy: Policy, site: str = "hidden"):
    """einsum with both operands quantized per policy; f32 accumulation."""
    xq = quant_act(x, policy, site)
    cdt = policy.cdt() or x.dtype
    if kd.is_packed(w) or kd.is_packed4(w):
        y = kd.packed_einsum(eq, xq.astype(cdt), w, cast_dtype=policy.cdt())
    else:
        wq = quant_weight(w, policy)
        y = policy_einsum(eq, xq.astype(cdt), wq.astype(cdt), policy)
    return y.astype(cdt)


@dataclasses.dataclass(frozen=True)
class QuantDense:
    in_dim: int
    out_dim: int
    use_bias: bool = True
    in_axis: str = "embed"
    out_axis: str = "mlp"
    name: str = "dense"

    def init(self, key):
        kw, _ = jax.random.split(key)
        p = {"w": M.truncated_normal_init(kw, (self.in_dim, self.out_dim))}
        if self.use_bias:
            p["b"] = jnp.zeros((self.out_dim,), jnp.float32)
        return p

    def specs(self):
        s = {"w": (self.in_axis, self.out_axis)}
        if self.use_bias:
            s["b"] = (self.out_axis,)
        return s

    def apply(self, p, x, policy: Policy, site: str = "hidden"):
        y = quant_einsum("...d,df->...f", x, p["w"], policy, site)
        if self.use_bias:
            y = y + p["b"].astype(y.dtype)
        return y


@dataclasses.dataclass(frozen=True)
class QuantEmbedding:
    vocab: int
    dim: int
    name: str = "embed"

    def init(self, key):
        return {"table": M.truncated_normal_init(key, (self.vocab, self.dim), 0.02)}

    def specs(self):
        return {"table": ("vocab", "embed")}

    def apply(self, p, tokens, policy: Policy):
        """tokens int32 -> embeddings. The embedding *output* is the paper's
        'first layer activation' site (Table V). A packed table gathers the
        1-byte codes first, then decodes only the gathered rows — same
        values as decode-then-gather (decode is element-wise), ~4x less
        gather traffic."""
        if kd.is_packed4(p["table"]):
            y = kd.inference_only(floatsd4.gather_decode(
                p["table"].codes, p["table"].exps, tokens,
                dtype=policy.cdt() or jnp.float32,
            ))
        elif kd.is_packed(p["table"]):
            codes = jnp.take(p["table"].codes, tokens, axis=0)
            y = kd.inference_only(floatsd.decode(
                codes, p["table"].bias, dtype=policy.cdt() or jnp.float32
            ))
        else:
            t = quant_weight(p["table"], policy)
            y = jnp.take(t, tokens, axis=0)
        return quant_act(y, policy, site="first")

    def attend(self, p, x, policy: Policy):
        """Tied-weight logits head: x @ table^T. This is the 'last layer'
        site — Table VI keeps it FP16."""
        y = quant_einsum("...d,vd->...v", x, p["table"], policy, site="last")
        return y
