"""Feed-forward blocks. SwiGLU's sigmoid can run through the paper's
two-region FloatSD8 quantizer (beyond-paper extension of §III-C, enabled by
``Policy.sigmoid_quant`` + ``FFN.quant_silu``)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.policy import Policy
from ..core.qsigmoid import qsigmoid
from .linear import QuantDense

__all__ = ["FFN"]


def _silu(x, quantized: bool):
    return x * (qsigmoid(x) if quantized else jax.nn.sigmoid(x))


@dataclasses.dataclass(frozen=True)
class FFN:
    dim: int
    hidden: int
    kind: str = "swiglu"  # "swiglu" | "gelu" | "geglu"
    quant_silu: bool = False  # FloatSD8 two-region sigmoid inside SiLU
    name: str = "ffn"

    def _in(self):
        return QuantDense(self.dim, self.hidden, use_bias=False, in_axis="embed", out_axis="mlp")

    def _out(self):
        return QuantDense(self.hidden, self.dim, use_bias=False, in_axis="mlp", out_axis="embed")

    def init(self, key):
        ks = jax.random.split(key, 3)
        p = {"wi": self._in().init(ks[0]), "wo": self._out().init(ks[1])}
        if self.kind in ("swiglu", "geglu"):
            p["wg"] = self._in().init(ks[2])
        return p

    def specs(self):
        s = {"wi": self._in().specs(), "wo": self._out().specs()}
        if self.kind in ("swiglu", "geglu"):
            s["wg"] = self._in().specs()
        return s

    def apply(self, p, x, policy: Policy):
        h = self._in().apply(p["wi"], x, policy)
        if self.kind == "swiglu":
            g = self._in().apply(p["wg"], x, policy)
            h = _silu(g, self.quant_silu and policy.sigmoid_quant) * h
        elif self.kind == "geglu":
            g = self._in().apply(p["wg"], x, policy)
            h = jax.nn.gelu(g) * h
        else:
            h = jax.nn.gelu(h)
        return self._out().apply(p["wo"], h, policy)
