"""NN layer library with FloatSD8/FP8 quantization hooks."""
from . import attention, ffn, linear, lstm, mamba, module, moe, norms, rotary, rwkv, transformer
from .attention import Attention, KVCache
from .ffn import FFN
from .linear import QuantDense, QuantEmbedding
from .lstm import BiLSTM, LSTMCell, LSTMLayer
from .mamba import Mamba
from .moe import MoE
from .norms import LayerNorm, RMSNorm
from .rwkv import RWKV6ChannelMix, RWKV6TimeMix
from .transformer import Block, Stack

__all__ = [
    "attention", "ffn", "linear", "lstm", "mamba", "module", "moe", "norms",
    "rotary", "rwkv", "transformer",
    "Attention", "KVCache", "FFN", "QuantDense", "QuantEmbedding",
    "BiLSTM", "LSTMCell", "LSTMLayer", "Mamba", "MoE", "LayerNorm", "RMSNorm",
    "RWKV6ChannelMix", "RWKV6TimeMix", "Block", "Stack",
]
