"""LSTM with FloatSD8 training semantics — the paper's core (Eqs. 1-6).

Quantization sites per §III:
  * all eight gate matmuls: FloatSD8 weights x FP8 activations (x_t and
    h_{t-1} both pass the activation quantizer),
  * f, i, o gates: two-region FloatSD8 sigmoid (Eqs. 7-8),
  * g gate and tanh(c_t): tanh LUT emitting FP8,
  * cell state c_t: kept FP16 (the MAC's accumulation format),
so every element-wise product in Eqs. (5)-(6) is FloatSD8 x FP — exactly the
multiplier the paper's MAC implements.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

import os

from ..core.policy import Policy
from ..core.qsigmoid import qsigmoid, qtanh_fp8
from ..kernels import dispatch as kd
from . import module as M
from .linear import quant_act, quant_einsum, quant_weight

__all__ = ["LSTMCell", "LSTMLayer", "BiLSTM", "LSTMState"]

# Perf A/B switch (EXPERIMENTS.md §Perf hillclimb #2): hoist the T-invariant
# weight fake-quantization out of the time-step scan. Numerically identical
# (fake-quant is deterministic); REPRO_LSTM_HOIST=0 restores the naive
# quantize-inside-step baseline.
HOIST_WQUANT = os.environ.get("REPRO_LSTM_HOIST", "1") != "0"


class LSTMState(NamedTuple):
    h: jax.Array  # [B, H]
    c: jax.Array  # [B, H]


@dataclasses.dataclass(frozen=True)
class LSTMCell:
    in_dim: int
    hidden: int
    name: str = "lstm_cell"

    def init(self, key):
        kx, kh = jax.random.split(key)
        h = self.hidden
        # gate order: i, f, g, o (forget-bias +1: standard, keeps parity
        # with the PyTorch baselines the paper trains against)
        b = jnp.zeros((4 * h,), jnp.float32).at[h : 2 * h].set(1.0)
        return {
            "wx": M.uniform_init(kx, (self.in_dim, 4 * h), 1.0 / h**0.5),
            "wh": M.uniform_init(kh, (h, 4 * h), 1.0 / h**0.5),
            "b": b,
        }

    def specs(self):
        return {"wx": ("embed", "hidden4"), "wh": ("hidden", "hidden4"), "b": ("hidden4",)}

    def step(self, p, x_t, state: LSTMState, policy: Policy,
             prequantized: bool = False, inference: bool = False):
        """One time step. x_t: [B, in_dim].

        `prequantized=True`: p["wx"]/p["wh"] already passed the weight
        quantizer (hoisted out of the time scan by LSTMLayer.apply — the
        quantize-at-use is T-invariant, so doing it per step is pure waste;
        EXPERIMENTS.md §Perf hillclimb #2). x_t is then also already
        act-quantized; h still quantizes per step (it changes each step).

        `inference=True` (the serving path): the element-wise gate stage
        runs through the kernel dispatch layer — the fused Pallas LSTM-cell
        kernel on TPU, the jnp oracle elsewhere (bit-identical values to
        the inline math; no gradients flow, so the STE wrappers aren't
        needed). Packed (FloatSD8-coded) wx/wh route the matmuls through
        the dispatched decode+matmul kernel via ``policy_einsum``.
        """
        h = self.hidden
        cdt = policy.cdt() or x_t.dtype
        # Eq. (1)-(4) matmuls: FloatSD8 weights, FP8 activations (x and h)
        if prequantized:
            from .linear import policy_einsum

            hq = quant_act(state.h.astype(x_t.dtype), policy)
            z = (
                policy_einsum("bd,dk->bk", x_t.astype(cdt), p["wx"], policy).astype(cdt)
                + policy_einsum("bd,dk->bk", hq.astype(cdt), p["wh"], policy).astype(cdt)
                + p["b"].astype(cdt)
            )
        else:
            z = (
                quant_einsum("bd,dk->bk", x_t, p["wx"], policy)
                + quant_einsum("bd,dk->bk", state.h.astype(x_t.dtype), p["wh"], policy)
                + p["b"].astype(cdt)
            )
        c_dt = jnp.float16 if policy.master_dtype == "fp16" else jnp.float32
        if inference:
            # dispatched fused element-wise stage (Eqs. 5-6 + gate LUTs)
            h_t, c_t = kd.lstm_cell(
                z, state.c, quantized=policy.sigmoid_quant, c_dtype=c_dt
            )
            return h_t, LSTMState(h_t, c_t)
        zi, zf, zg, zo = jnp.split(z, 4, axis=-1)
        if policy.sigmoid_quant:
            i_t, f_t, o_t = qsigmoid(zi), qsigmoid(zf), qsigmoid(zo)
            g_t = qtanh_fp8(zg)
        else:
            i_t, f_t, o_t = jax.nn.sigmoid(zi), jax.nn.sigmoid(zf), jax.nn.sigmoid(zo)
            g_t = jnp.tanh(zg)
        # Eq. (5): FloatSD8 (f,i) x FP products, FP16 cell state
        c_t = (f_t * state.c.astype(f_t.dtype) + i_t * g_t).astype(c_dt)
        # Eq. (6)
        tc = qtanh_fp8(c_t.astype(cdt)) if policy.sigmoid_quant else jnp.tanh(c_t.astype(cdt))
        h_t = (o_t * tc).astype(cdt)
        return h_t, LSTMState(h_t, c_t)


@dataclasses.dataclass(frozen=True)
class LSTMLayer:
    in_dim: int
    hidden: int
    reverse: bool = False
    name: str = "lstm"

    def init(self, key):
        return LSTMCell(self.in_dim, self.hidden).init(key)

    def specs(self):
        return LSTMCell(self.in_dim, self.hidden).specs()

    def apply(
        self,
        p,
        xs,
        policy: Policy,
        state: LSTMState | None = None,
        lengths: jax.Array | None = None,
        inference: bool = False,
    ):
        """xs: [B, S, in_dim] -> ([B, S, H], final_state).

        ``lengths`` (optional, [B] int32): per-lane count of valid positions.
        Lane b's recurrent state freezes once t >= lengths[b] — later
        positions are padding and must not perturb the carried state. This is
        the masking primitive behind the serving engine's chunked prefill,
        where one batched step advances every lane a *different* number of
        tokens (prefill lanes up to `chunk`, decode lanes exactly 1).
        Only meaningful for forward layers.

        ``inference=True`` routes the per-step compute (both the masked and
        unmasked scans) through the kernel dispatch layer; see
        ``LSTMCell.step``.
        """
        cell = LSTMCell(self.in_dim, self.hidden)
        b = xs.shape[0]
        cdt = policy.cdt() or xs.dtype
        c_dt = jnp.float16 if policy.master_dtype == "fp16" else jnp.float32
        if state is None:
            state = LSTMState(
                jnp.zeros((b, self.hidden), cdt), jnp.zeros((b, self.hidden), c_dt)
            )
        else:  # normalize external (cache) dtypes to the policy's
            state = LSTMState(state.h.astype(cdt), state.c.astype(c_dt))
        xs_t = jnp.swapaxes(quant_act(xs, policy), 0, 1)  # [S, B, D]

        if HOIST_WQUANT:
            # quantize-at-use ONCE, outside the scan (T-invariant); STE
            # gradients still flow to the raw master weights. Packed
            # (FloatSD8-coded) weights analogously hoist the decode when the
            # dispatch layer will run matmuls on the ref backend — and stay
            # packed for the pallas decode-in-VMEM path.
            pq = dict(p)
            pq["wx"] = kd.hoist_packed(quant_weight(p["wx"], policy), m=b,
                                       dtype=policy.cdt())
            pq["wh"] = kd.hoist_packed(quant_weight(p["wh"], policy), m=b,
                                       dtype=policy.cdt())
            prequantized = True
        else:
            pq = p
            prequantized = False

        if lengths is None:
            def body(st, x_t):
                h_t, st2 = cell.step(pq, x_t, st, policy,
                                     prequantized=prequantized, inference=inference)
                return st2, h_t

            final, hs = jax.lax.scan(body, state, xs_t, reverse=self.reverse)
        else:
            if self.reverse:
                raise ValueError("lengths-masked scan requires a forward layer")
            lens = jnp.asarray(lengths, jnp.int32)

            def body(carry, x_t):
                st, t = carry
                h_t, st2 = cell.step(pq, x_t, st, policy,
                                     prequantized=prequantized, inference=inference)
                keep = (t < lens)[:, None]
                st2 = LSTMState(
                    jnp.where(keep, st2.h, st.h), jnp.where(keep, st2.c, st.c)
                )
                return (st2, t + 1), h_t

            (final, _), hs = jax.lax.scan(
                body, (state, jnp.zeros((), jnp.int32)), xs_t
            )
        return jnp.swapaxes(hs, 0, 1), final


@dataclasses.dataclass(frozen=True)
class BiLSTM:
    in_dim: int
    hidden: int  # per direction
    name: str = "bilstm"

    def init(self, key):
        kf, kb = jax.random.split(key)
        return {
            "fwd": LSTMLayer(self.in_dim, self.hidden).init(kf),
            "bwd": LSTMLayer(self.in_dim, self.hidden, reverse=True).init(kb),
        }

    def specs(self):
        s = LSTMLayer(self.in_dim, self.hidden).specs()
        return {"fwd": s, "bwd": s}

    def apply(self, p, xs, policy: Policy):
        hf, _ = LSTMLayer(self.in_dim, self.hidden).apply(p["fwd"], xs, policy)
        hb, _ = LSTMLayer(self.in_dim, self.hidden, reverse=True).apply(p["bwd"], xs, policy)
        return jnp.concatenate([hf, hb], axis=-1)
