"""LSTM with FloatSD8 training semantics — the paper's core (Eqs. 1-6).

Quantization sites per §III:
  * all eight gate matmuls: FloatSD8 weights x FP8 activations (x_t and
    h_{t-1} both pass the activation quantizer),
  * f, i, o gates: two-region FloatSD8 sigmoid (Eqs. 7-8),
  * g gate and tanh(c_t): tanh LUT emitting FP8,
  * cell state c_t: kept FP16 (the MAC's accumulation format),
so every element-wise product in Eqs. (5)-(6) is FloatSD8 x FP — exactly the
multiplier the paper's MAC implements.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.fp8 import quantize_fp8
from ..core.policy import Policy
from ..core.qsigmoid import qsigmoid, qtanh_fp8
from ..kernels import dispatch as kd
from . import module as M
from .linear import quant_act, quant_einsum, quant_weight

__all__ = ["LSTMCell", "LSTMLayer", "BiLSTM", "LSTMState"]

# Perf A/B switch (EXPERIMENTS.md §Perf hillclimb #2): hoist the T-invariant
# weight fake-quantization out of the time-step scan. Numerically identical
# (fake-quant is deterministic); REPRO_LSTM_HOIST=0 restores the naive
# quantize-inside-step baseline.
HOIST_WQUANT = os.environ.get("REPRO_LSTM_HOIST", "1") != "0"

# Fused-BPTT remat (EXPERIMENTS.md §Perf hillclimb #5): drop the per-step z
# residual too and recompute ALL of zs in the backward as one batched pair
# of GEMMs over the saved h trajectory — residuals shrink to the cell-state
# trajectory alone (~4x below plain autodiff) for one extra forward-sized
# GEMM in the backward. REPRO_BPTT_REMAT=0 keeps zs saved instead.
BPTT_REMAT = os.environ.get("REPRO_BPTT_REMAT", "1") != "0"


class LSTMState(NamedTuple):
    h: jax.Array  # [B, H]
    c: jax.Array  # [B, H]


@dataclasses.dataclass(frozen=True)
class LSTMCell:
    in_dim: int
    hidden: int
    name: str = "lstm_cell"

    def init(self, key):
        kx, kh = jax.random.split(key)
        h = self.hidden
        # gate order: i, f, g, o (forget-bias +1: standard, keeps parity
        # with the PyTorch baselines the paper trains against)
        b = jnp.zeros((4 * h,), jnp.float32).at[h : 2 * h].set(1.0)
        return {
            "wx": M.uniform_init(kx, (self.in_dim, 4 * h), 1.0 / h**0.5),
            "wh": M.uniform_init(kh, (h, 4 * h), 1.0 / h**0.5),
            "b": b,
        }

    def specs(self):
        return {"wx": ("embed", "hidden4"), "wh": ("hidden", "hidden4"), "b": ("hidden4",)}

    def step(self, p, x_t, state: LSTMState, policy: Policy,
             prequantized: bool = False, inference: bool = False):
        """One time step. x_t: [B, in_dim].

        `prequantized=True`: p["wx"]/p["wh"] already passed the weight
        quantizer (hoisted out of the time scan by LSTMLayer.apply — the
        quantize-at-use is T-invariant, so doing it per step is pure waste;
        EXPERIMENTS.md §Perf hillclimb #2). x_t is then also already
        act-quantized; h still quantizes per step (it changes each step).

        `inference=True` (the serving path): the element-wise gate stage
        runs through the kernel dispatch layer — the fused Pallas LSTM-cell
        kernel on TPU, the jnp oracle elsewhere (bit-identical values to
        the inline math; no gradients flow, so the STE wrappers aren't
        needed). Packed (FloatSD8-coded) wx/wh route the matmuls through
        the dispatched decode+matmul kernel via ``policy_einsum``.

        The fused quantized-BPTT training path does NOT go through this
        method: ``LSTMLayer.apply`` routes whole-sequence training to the
        scan-level ``lstm_bptt`` engine below (same forward values,
        hand-written backward on the registered kernel pairs).
        """
        h = self.hidden
        cdt = policy.cdt() or x_t.dtype
        # Eq. (1)-(4) matmuls: FloatSD8 weights, FP8 activations (x and h)
        if prequantized:
            from .linear import policy_einsum

            hq = quant_act(state.h.astype(x_t.dtype), policy)
            z = (
                policy_einsum("bd,dk->bk", x_t.astype(cdt), p["wx"], policy).astype(cdt)
                + policy_einsum("bd,dk->bk", hq.astype(cdt), p["wh"], policy).astype(cdt)
                + p["b"].astype(cdt)
            )
        else:
            z = (
                quant_einsum("bd,dk->bk", x_t, p["wx"], policy)
                + quant_einsum("bd,dk->bk", state.h.astype(x_t.dtype), p["wh"], policy)
                + p["b"].astype(cdt)
            )
        c_dt = jnp.float16 if policy.master_dtype == "fp16" else jnp.float32
        if inference:
            # dispatched fused element-wise stage (Eqs. 5-6 + gate LUTs)
            h_t, c_t = kd.lstm_cell(
                z, state.c, quantized=policy.sigmoid_quant, c_dtype=c_dt
            )
            return h_t, LSTMState(h_t, c_t)
        zi, zf, zg, zo = jnp.split(z, 4, axis=-1)
        if policy.sigmoid_quant:
            i_t, f_t, o_t = qsigmoid(zi), qsigmoid(zf), qsigmoid(zo)
            g_t = qtanh_fp8(zg)
        else:
            i_t, f_t, o_t = jax.nn.sigmoid(zi), jax.nn.sigmoid(zf), jax.nn.sigmoid(zo)
            g_t = jnp.tanh(zg)
        # Eq. (5): FloatSD8 (f,i) x FP products, FP16 cell state
        c_t = (f_t * state.c.astype(f_t.dtype) + i_t * g_t).astype(c_dt)
        # Eq. (6)
        tc = qtanh_fp8(c_t.astype(cdt)) if policy.sigmoid_quant else jnp.tanh(c_t.astype(cdt))
        h_t = (o_t * tc).astype(cdt)
        return h_t, LSTMState(h_t, c_t)


# ---------------------------------------------------------------------------
# fused quantized-BPTT: a hand-written VJP over the WHOLE time scan
# ---------------------------------------------------------------------------
#
# Autodiff through the quantized step keeps ~13 per-gate residual tensors
# per time step and accumulates each weight gradient as S small [B,·]x[·,4H]
# outer products. This engine is the cuDNN-shaped alternative, built on the
# registered kernel pairs of kernels/dispatch.py:
#
#   forward  : the dispatched matmuls + fused cell, saving only zs [S,B,4H]
#              (or nothing, under BPTT_REMAT) and the cell-state trajectory
#              cs [S,B,H].
#   backward : one reverse scan running the recompute-gates cell kernel
#              (lstm_cell_grad) + the dh recurrence matmul; then dWx/dWh as
#              ONE [S*B,·]^T x [S*B,4H] GEMM each through matmul_dw — the
#              paper's FP8 weight-gradient quantizer applied at the
#              accumulator flush, inside the kernel — and dXs as one batched
#              matmul_dx. Per-step weight-sized work (S FP8 snaps, S small
#              GEMMs) collapses to one of each.
#
# Gradient semantics match the STE autodiff oracle (products use quantized
# values, derivative factors are smooth); the one recorded deviation is that
# the dc chain stays f32 where autodiff rounds through the fp16 cell state
# (tests/test_train_grad_parity.py pins both).


@functools.lru_cache(maxsize=None)
def _make_lstm_bptt(pol, packed, masked, reverse, quantized, c_dtype,
                    afwd, abwd, remat, w_dtype):
    """Build the custom-VJP scan engine for one static configuration.

    pol: resolved dispatch backend ("ref"/"pallas"/"auto"); packed: weights
    hoisted as PackedTensor (pallas) vs dense STE (ref); afwd/abwd: the
    activation fake-quant dtypes of the policy's hidden site (None = off);
    w_dtype: the dense masters' dtype (their cotangent dtype).
    """
    f32 = jnp.float32

    def q_act(h):
        return quantize_fp8(h, afwd) if afwd is not None else h

    def q_grad(g):
        return quantize_fp8(g, abwd) if abwd is not None else g

    def z_of(x_t, hq, wqx, wqh, b):
        if packed:
            return (
                kd.matmul(x_t, wqx.codes, wqx.bias, out_dtype=f32, backend=pol)
                + kd.matmul(hq, wqh.codes, wqh.bias, out_dtype=f32, backend=pol)
                + b
            )
        return (
            jnp.dot(x_t, wqx, preferred_element_type=f32)
            + jnp.dot(hq, wqh, preferred_element_type=f32)
            + b
        ).astype(f32)

    def forward(xs, h0, c0, wqx, wqh, b, lens):
        s = xs.shape[0]

        def body(st, inp):
            h_prev, c_prev = st
            x_t, t = inp
            hq = q_act(h_prev)
            z = z_of(x_t, hq, wqx, wqh, b)
            h_new, c_new = kd.lstm_cell(
                z, c_prev, quantized=quantized, c_dtype=c_dtype, backend=pol
            )
            h_new = h_new.astype(h_prev.dtype)
            if masked:
                keep = (t < lens)[:, None]
                h_t = jnp.where(keep, h_new, h_prev)
                c_t = jnp.where(keep, c_new, c_prev)
            else:
                h_t, c_t = h_new, c_new
            # ys h is the raw cell output (pre-mask), matching the inline
            # scan; the carry freezes, the emitted row does not. Masked
            # configs additionally save the entry state h_prev — the frozen
            # carry trajectory is NOT reconstructible from hs alone there.
            ys = [h_new, c_prev]
            if masked:
                ys.append(h_prev)
            if not remat:
                ys.append(z)
            return (h_t, c_t), tuple(ys)

        (hT, cT), ys = jax.lax.scan(
            body, (h0, c0), (xs, jnp.arange(s)), reverse=reverse
        )
        hs, cs_prev = ys[0], ys[1]
        hs_prev = ys[2] if masked else None
        zs = ys[-1] if not remat else None
        return hs, hT, cT, zs, cs_prev, hs_prev

    @jax.custom_vjp
    def engine(xs, h0, c0, wx, wh, wqx, wqh, b, lens):
        del wx, wh  # gradient targets only (packed path)
        hs, hT, cT, _, _, _ = forward(xs, h0, c0, wqx, wqh, b, lens)
        return hs, hT, cT

    def engine_fwd(xs, h0, c0, wx, wh, wqx, wqh, b, lens):
        del wx, wh
        hs, hT, cT, zs, cs_prev, hs_prev = forward(xs, h0, c0, wqx, wqh, b, lens)
        res = (xs, h0, c0, wqx, wqh, b, lens, zs, cs_prev, hs, hs_prev)
        return (hs, hT, cT), res

    def engine_bwd(res, cts):
        xs, h0, c0, wqx, wqh, b, lens, zs, cs_prev, hs, hs_prev = res
        g_hs, g_hT, g_cT = cts
        s, bsz, d = xs.shape
        h = hs.shape[-1]

        # the hq trajectory, recomputed in ONE batched fake-quant: step t
        # consumed Q(h_{t-1}) (forward) / Q(h_{t+1}) (reverse), h0 at the
        # end. Masked scans saved the (frozen-carry) entry states instead.
        if masked:
            prevs = hs_prev
        elif reverse:
            prevs = jnp.concatenate([hs[1:], h0[None]], axis=0)
        else:
            prevs = jnp.concatenate([h0[None], hs[:-1]], axis=0)
        hqs = q_act(prevs)
        if zs is None:  # BPTT_REMAT: recompute ALL of zs as one GEMM pair
            zs = z_of(
                xs.reshape(s * bsz, d), hqs.reshape(s * bsz, h), wqx, wqh, b
            ).reshape(s, bsz, 4 * h)
        wqh_t = None if packed else wqh.T  # hoisted out of the reverse scan

        def rbody(carry, inp):
            dh_rec, dc = carry  # f32 cotangents of the carried state
            z_t, c_prev_t, g_h_t, t = inp
            if masked:
                keep = (t < lens)[:, None]
                dh_cell = g_h_t.astype(f32) + jnp.where(keep, dh_rec, 0.0)
                dc_cell = jnp.where(keep, dc, 0.0)
                dh_pass = jnp.where(keep, 0.0, dh_rec)
                dc_pass = jnp.where(keep, 0.0, dc)
            else:
                dh_cell = g_h_t.astype(f32) + dh_rec
                dc_cell, dh_pass, dc_pass = dc, 0.0, 0.0
            dz, dc_prev = kd.lstm_cell_grad(
                z_t, c_prev_t.astype(f32), dh_cell, dc_cell,
                quantized=quantized, c_dtype=c_dtype, backend=pol,
            )
            # recurrence: cotangent of h_prev through the hq quantizer
            if packed:
                dhq = kd.matmul_dx(dz, wqh.codes, wqh.bias, backend=pol)
            else:
                dhq = jnp.dot(dz, wqh_t, preferred_element_type=f32)
            dh_prev = dh_pass + q_grad(dhq)
            dc_prev = dc_pass + dc_prev
            return (dh_prev, dc_prev), dz

        carry0 = (g_hT.astype(f32), g_cT.astype(f32))
        (dh0, dc0), dzs = jax.lax.scan(
            rbody, carry0, (zs, cs_prev, g_hs, jnp.arange(s)),
            reverse=not reverse,
        )

        # weight grads: ONE kernel call each over the whole sequence, FP8
        # emission at the accumulator flush; dXs batched the same way
        dzs_f = dzs.reshape(s * bsz, 4 * h)
        dwx = kd.matmul_dw(xs.reshape(s * bsz, d), dzs_f, backend=pol)
        dwh = kd.matmul_dw(hqs.reshape(s * bsz, h), dzs_f, backend=pol)
        if packed:
            dxs = kd.matmul_dx(dzs_f, wqx.codes, wqx.bias, backend=pol)
        else:
            dxs = jnp.dot(dzs_f, wqx.T, preferred_element_type=f32)
        dxs = dxs.reshape(s, bsz, d).astype(xs.dtype)
        db = jnp.sum(dzs_f, axis=0).astype(b.dtype)
        wdt = jnp.dtype(w_dtype)
        if packed:
            # FP8 dW straight-through to the dense masters (fp8 values are
            # exactly representable at any master dtype >= fp16)
            g_masters = (dwx.astype(wdt), dwh.astype(wdt))
            g_wq = (
                kd.PackedTensor(_f0(wqx.codes), _f0(wqx.bias)),
                kd.PackedTensor(_f0(wqh.codes), _f0(wqh.bias)),
            )
        else:
            # dW reaches the masters through the hoisted STE node on wq
            g_masters = (jnp.zeros(wqx.shape, wdt), jnp.zeros(wqh.shape, wdt))
            g_wq = (dwx.astype(wqx.dtype), dwh.astype(wqh.dtype))
        return (dxs, dh0.astype(h0.dtype), dc0.astype(c0.dtype),
                g_masters[0], g_masters[1], g_wq[0], g_wq[1], db, _f0(lens))

    engine.defvjp(engine_fwd, engine_bwd)
    return engine


def _f0(x):
    return np.zeros(np.shape(x), jax.dtypes.float0)


@dataclasses.dataclass(frozen=True)
class LSTMLayer:
    in_dim: int
    hidden: int
    reverse: bool = False
    name: str = "lstm"

    def init(self, key):
        return LSTMCell(self.in_dim, self.hidden).init(key)

    def specs(self):
        return LSTMCell(self.in_dim, self.hidden).specs()

    def apply(
        self,
        p,
        xs,
        policy: Policy,
        state: LSTMState | None = None,
        lengths: jax.Array | None = None,
        inference: bool = False,
    ):
        """xs: [B, S, in_dim] -> ([B, S, H], final_state).

        ``lengths`` (optional, [B] int32): per-lane count of valid positions.
        Lane b's recurrent state freezes once t >= lengths[b] — later
        positions are padding and must not perturb the carried state. This is
        the masking primitive behind the serving engine's chunked prefill,
        where one batched step advances every lane a *different* number of
        tokens (prefill lanes up to `chunk`, decode lanes exactly 1).
        Only meaningful for forward layers.

        ``inference=True`` routes the per-step compute (both the masked and
        unmasked scans) through the kernel dispatch layer; see
        ``LSTMCell.step``.
        """
        cell = LSTMCell(self.in_dim, self.hidden)
        b = xs.shape[0]
        cdt = policy.cdt() or xs.dtype
        c_dt = jnp.float16 if policy.master_dtype == "fp16" else jnp.float32
        if state is None:
            state = LSTMState(
                jnp.zeros((b, self.hidden), cdt), jnp.zeros((b, self.hidden), c_dt)
            )
        else:  # normalize external (cache) dtypes to the policy's
            state = LSTMState(state.h.astype(cdt), state.c.astype(c_dt))
        if lengths is not None and self.reverse:
            raise ValueError("lengths-masked scan requires a forward layer")
        xs_t = jnp.swapaxes(quant_act(xs, policy), 0, 1)  # [S, B, D]

        # fused quantized-BPTT: training-mode twin of the inference dispatch
        # (requires the hoist — the encode is T-invariant — and dense masters)
        fused = (
            not inference
            and policy.grad_quant == "fp8_kernel"
            and policy.weight_quant == "floatsd8"
            # the engine computes z/h in f32; bf16-compute policies (e.g.
            # floatsd8_tpu) round z to bf16 in the inline path, so they stay
            # on autodiff to keep REPRO_FUSED_BPTT=0 trajectory-equivalent
            and policy.cdt() in (None, jnp.float32)
            and HOIST_WQUANT
            and not (kd.is_packed(p["wx"]) or kd.is_packed(p["wh"])
                     or kd.is_packed4(p["wx"]) or kd.is_packed4(p["wh"]))
        )

        if fused:
            # ref backend: dense STE quantize-at-use hoisted out of BOTH
            # scans; pallas: codes stay packed for decode-in-VMEM fwd + bwd
            wqx = kd.hoist_train(p["wx"], dtype=policy.cdt())
            wqh = kd.hoist_train(p["wh"], dtype=policy.cdt())
            bq = p["b"].astype(cdt)
            afwd, abwd = policy.act_dtypes("hidden")
            engine = _make_lstm_bptt(
                kd.backend_policy(None), kd.is_packed(wqx),
                lengths is not None, self.reverse, policy.sigmoid_quant,
                c_dt, afwd, abwd, BPTT_REMAT, jnp.dtype(p["wx"].dtype).name,
            )
            lens_arr = (
                jnp.asarray(lengths, jnp.int32)
                if lengths is not None
                else jnp.zeros((b,), jnp.int32)
            )
            hs, h_f, c_f = engine(
                xs_t, state.h, state.c, p["wx"], p["wh"], wqx, wqh, bq,
                lens_arr,
            )
            return jnp.swapaxes(hs, 0, 1), LSTMState(h_f, c_f)

        if HOIST_WQUANT:
            # quantize-at-use ONCE, outside the scan (T-invariant); STE
            # gradients still flow to the raw master weights. Packed
            # (FloatSD8-coded) weights analogously hoist the decode when the
            # dispatch layer will run matmuls on the ref backend — and stay
            # packed for the pallas decode-in-VMEM path.
            pq = dict(p)
            pq["wx"] = kd.hoist_packed(quant_weight(p["wx"], policy), m=b,
                                       dtype=policy.cdt())
            pq["wh"] = kd.hoist_packed(quant_weight(p["wh"], policy), m=b,
                                       dtype=policy.cdt())
            prequantized = True
        else:
            pq = p
            prequantized = False

        if lengths is None:
            def body(st, x_t):
                h_t, st2 = cell.step(pq, x_t, st, policy,
                                     prequantized=prequantized,
                                     inference=inference)
                return st2, h_t

            final, hs = jax.lax.scan(body, state, xs_t, reverse=self.reverse)
        else:
            lens = jnp.asarray(lengths, jnp.int32)

            def body(carry, x_t):
                st, t = carry
                h_t, st2 = cell.step(pq, x_t, st, policy,
                                     prequantized=prequantized,
                                     inference=inference)
                keep = (t < lens)[:, None]
                st2 = LSTMState(
                    jnp.where(keep, st2.h, st.h), jnp.where(keep, st2.c, st.c)
                )
                return (st2, t + 1), h_t

            (final, _), hs = jax.lax.scan(
                body, (state, jnp.zeros((), jnp.int32)), xs_t
            )
        hs = jnp.swapaxes(hs, 0, 1)
        if (kd.is_packed(p["wx"]) or kd.is_packed(p["wh"])
                or kd.is_packed4(p["wx"]) or kd.is_packed4(p["wh"])):
            # packed layers are inference-only: a gradient through their
            # outputs must fail loudly (the hoisted decode severs the VJP to
            # the codes silently otherwise)
            hs = kd.inference_only(hs)
            final = LSTMState(kd.inference_only(final.h),
                              kd.inference_only(final.c))
        return hs, final


@dataclasses.dataclass(frozen=True)
class BiLSTM:
    in_dim: int
    hidden: int  # per direction
    name: str = "bilstm"

    def init(self, key):
        kf, kb = jax.random.split(key)
        return {
            "fwd": LSTMLayer(self.in_dim, self.hidden).init(kf),
            "bwd": LSTMLayer(self.in_dim, self.hidden, reverse=True).init(kb),
        }

    def specs(self):
        s = LSTMLayer(self.in_dim, self.hidden).specs()
        return {"fwd": s, "bwd": s}

    def apply(self, p, xs, policy: Policy):
        hf, _ = LSTMLayer(self.in_dim, self.hidden).apply(p["fwd"], xs, policy)
        hb, _ = LSTMLayer(self.in_dim, self.hidden, reverse=True).apply(p["bwd"], xs, policy)
        return jnp.concatenate([hf, hb], axis=-1)
