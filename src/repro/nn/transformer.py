"""Composable decoder blocks + scan-over-layers stacks.

A Stack is a list of homogeneous *groups*; each group scans a period of
sub-blocks (so Jamba's [mamba x7, attn x1] interleave with MoE every other
layer scans over 4 groups of 8 sub-layers). Dense/MoE/SSM stacks are the
degenerate 1-sub-block case. Remat policy applies to the scan body.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core.policy import Policy
from ..distributed.sharding import constrain
from . import module as M
from .attention import Attention, KVCache
from .ffn import FFN
from .lstm import LSTMState
from .mamba import Mamba, MambaCache
from .moe import MoE
from .norms import LayerNorm, RMSNorm
from .rwkv import RWKV6ChannelMix, RWKV6TimeMix, RWKVState

__all__ = ["Block", "Stack"]


def _norm(kind, dim):
    return RMSNorm(dim) if kind == "rmsnorm" else LayerNorm(dim)


@dataclasses.dataclass(frozen=True)
class Block:
    """One residual block: mixer (attn | mamba | rwkv) + mlp (ffn | moe)."""

    dim: int
    mixer: str  # "attn" | "attn_swa" | "mamba" | "rwkv"
    mlp: str  # "ffn" | "moe" | "none"  (rwkv has its own channel mix)
    attn: Attention | None = None
    mamba_mod: Mamba | None = None
    rwkv_mod: RWKV6TimeMix | None = None
    ffn_mod: FFN | None = None
    moe_mod: MoE | None = None
    cmix_mod: RWKV6ChannelMix | None = None
    norm: str = "rmsnorm"

    def init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"norm1": _norm(self.norm, self.dim).init(k1)}
        if self.mixer in ("attn", "attn_swa"):
            p["mixer"] = self.attn.init(k2)
        elif self.mixer == "mamba":
            p["mixer"] = self.mamba_mod.init(k2)
        else:
            p["mixer"] = self.rwkv_mod.init(k2)
        if self.mlp != "none":
            p["norm2"] = _norm(self.norm, self.dim).init(k3)
            p["mlp"] = (self.moe_mod if self.mlp == "moe" else self.ffn_mod).init(k4)
        elif self.mixer == "rwkv":
            p["norm2"] = _norm(self.norm, self.dim).init(k3)
            p["mlp"] = self.cmix_mod.init(k4)
        return p

    def specs(self):
        s = {"norm1": _norm(self.norm, self.dim).specs()}
        if self.mixer in ("attn", "attn_swa"):
            s["mixer"] = self.attn.specs()
        elif self.mixer == "mamba":
            s["mixer"] = self.mamba_mod.specs()
        else:
            s["mixer"] = self.rwkv_mod.specs()
        if self.mlp != "none":
            s["norm2"] = _norm(self.norm, self.dim).specs()
            s["mlp"] = (self.moe_mod if self.mlp == "moe" else self.ffn_mod).specs()
        elif self.mixer == "rwkv":
            s["norm2"] = _norm(self.norm, self.dim).specs()
            s["mlp"] = self.cmix_mod.specs()
        return s

    # ----- full-sequence path (train / prefill) --------------------------
    def apply(self, p, x, policy: Policy, positions=None):
        n1 = _norm(self.norm, self.dim)
        aux = jnp.float32(0.0)
        h = n1.apply(p["norm1"], x)
        if self.mixer in ("attn", "attn_swa"):
            mix = self.attn.apply(p["mixer"], h, policy, positions=positions)
        elif self.mixer == "mamba":
            mix = self.mamba_mod.apply(p["mixer"], h, policy)
        else:
            mix, _ = self.rwkv_mod.apply(p["mixer"], h, policy)
        x = x + mix
        x = constrain(x, ("batch", "seq", "act_embed"))
        if self.mlp != "none":
            h2 = _norm(self.norm, self.dim).apply(p["norm2"], x)
            if self.mlp == "moe":
                y, aux = self.moe_mod.apply(p["mlp"], h2, policy)
            else:
                y = self.ffn_mod.apply(p["mlp"], h2, policy)
            x = x + y
        elif self.mixer == "rwkv":
            h2 = _norm(self.norm, self.dim).apply(p["norm2"], x)
            y, _ = self.cmix_mod.apply(p["mlp"], h2, policy)
            x = x + y
        x = constrain(x, ("batch", "seq", "act_embed"))
        return x, aux

    # ----- cache structure for decode ------------------------------------
    def init_cache(self, batch, s_max, dtype=jnp.bfloat16):
        if self.mixer in ("attn", "attn_swa"):
            s_eff = min(s_max, self.attn.window or s_max)
            return KVCache.init(batch, s_eff, self.attn.kv_heads, self.attn.hd, dtype)
        if self.mixer == "mamba":
            m = self.mamba_mod
            return MambaCache(
                jnp.zeros((batch, m.d_inner, m.d_state), jnp.float32),
                jnp.zeros((batch, m.d_conv - 1, m.d_inner), dtype),
            )
        r = self.rwkv_mod
        return RWKVState(
            jnp.zeros((batch, r.heads, r.head_dim, r.head_dim), jnp.float32),
            jnp.zeros((batch, self.dim), dtype),
            jnp.zeros((batch, self.dim), dtype),
        )

    def cache_specs(self):
        """Logical-axis tuples mirroring init_cache (for decode sharding)."""
        if self.mixer in ("attn", "attn_swa"):
            return KVCache(
                ("batch", "seq", "act_kv_heads", None),
                ("batch", "seq", "act_kv_heads", None),
                (),
            )
        if self.mixer == "mamba":
            return MambaCache(("batch", "act_mlp", None), ("batch", None, "act_mlp"))
        return RWKVState(
            ("batch", "act_heads", None, None), ("batch", None), ("batch", None)
        )

    def decode(self, p, x, cache, policy: Policy, positions3=None):
        n1 = _norm(self.norm, self.dim)
        h = n1.apply(p["norm1"], x)
        if self.mixer in ("attn", "attn_swa"):
            mix, cache = self.attn.decode(p["mixer"], h, cache, policy, positions3)
        elif self.mixer == "mamba":
            mix, cache = self.mamba_mod.decode(p["mixer"], h, cache, policy)
        else:
            st = RWKVState(cache.s, cache.x_tm, cache.x_cm)
            mix, (s_new, x_tm) = self.rwkv_mod.apply(
                p["mixer"], h, policy, state=st
            )
            cache = RWKVState(s_new, x_tm, cache.x_cm)
        x = x + mix
        if self.mlp != "none":
            h2 = _norm(self.norm, self.dim).apply(p["norm2"], x)
            if self.mlp == "moe":
                y, _ = self.moe_mod.apply(p["mlp"], h2, policy)
            else:
                y = self.ffn_mod.apply(p["mlp"], h2, policy)
            x = x + y
        elif self.mixer == "rwkv":
            h2 = _norm(self.norm, self.dim).apply(p["norm2"], x)
            y, x_cm = self.cmix_mod.apply(p["mlp"], h2, policy, cache.x_cm)
            cache = RWKVState(cache.s, cache.x_tm, x_cm)
            x = x + y
        return x, cache


@dataclasses.dataclass(frozen=True)
class Stack:
    """n_groups x (period sub-blocks), scanned over groups."""

    blocks: tuple  # period sub-block definitions (len == period)
    n_groups: int
    remat: str = "dots"

    def init(self, key):
        def group_init(k):
            ks = jax.random.split(k, len(self.blocks))
            return {f"b{i}": b.init(ks[i]) for i, b in enumerate(self.blocks)}

        return M.stack_init(group_init, self.n_groups)(key)

    def specs(self):
        s = {f"b{i}": b.specs() for i, b in enumerate(self.blocks)}
        return M.stack_specs(s)

    def apply(self, p, x, policy: Policy, positions=None):
        def body(carry, gp):
            x, aux = carry
            for i, b in enumerate(self.blocks):
                x, a = b.apply(gp[f"b{i}"], x, policy, positions=positions)
                aux = aux + a
            return (x, aux), None

        fn = body
        if self.remat == "full":
            fn = jax.checkpoint(body, prevent_cse=False)
        elif self.remat == "dots":
            fn = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
                prevent_cse=False,
            )
        (x, aux), _ = jax.lax.scan(fn, (x, jnp.float32(0.0)), p)
        return x, aux

    def init_cache(self, batch, s_max, dtype=jnp.bfloat16):
        def one_group(_):
            return {
                f"b{i}": b.init_cache(batch, s_max, dtype)
                for i, b in enumerate(self.blocks)
            }

        caches = [one_group(g) for g in range(self.n_groups)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *caches)

    def cache_specs(self):
        one = {f"b{i}": b.cache_specs() for i, b in enumerate(self.blocks)}
        return M.stack_specs(one)

    def decode(self, p, x, caches, policy: Policy, positions3=None):
        def body(x, inp):
            gp, gc = inp
            new_c = {}
            for i, b in enumerate(self.blocks):
                x, c = b.decode(gp[f"b{i}"], x, gc[f"b{i}"], policy, positions3)
                new_c[f"b{i}"] = c
            return x, new_c

        x, new_caches = jax.lax.scan(body, x, (p, caches))
        return x, new_caches
