"""Normalization layers (kept in fp32 — norm stats are accumulation-
sensitive; the paper quantizes matmul operands, not norm internals)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["RMSNorm", "LayerNorm"]


@dataclasses.dataclass(frozen=True)
class RMSNorm:
    dim: int
    eps: float = 1e-6
    name: str = "rmsnorm"

    def init(self, key):
        del key
        return {"scale": jnp.ones((self.dim,), jnp.float32)}

    def specs(self):
        return {"scale": ("embed",)}

    def apply(self, p, x):
        dt = x.dtype
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jnp.reciprocal(jnp.sqrt(var + self.eps))
        return (y * p["scale"]).astype(dt)


@dataclasses.dataclass(frozen=True)
class LayerNorm:
    dim: int
    eps: float = 1e-5
    name: str = "layernorm"

    def init(self, key):
        del key
        return {
            "scale": jnp.ones((self.dim,), jnp.float32),
            "bias": jnp.zeros((self.dim,), jnp.float32),
        }

    def specs(self):
        return {"scale": ("embed",), "bias": ("embed",)}

    def apply(self, p, x):
        dt = x.dtype
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + self.eps))
        return (y * p["scale"] + p["bias"]).astype(dt)
