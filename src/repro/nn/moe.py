"""Mixture-of-Experts with grouped capacity-based sort/scatter dispatch.

Tokens are split into `dispatch_groups` groups (aligned with the data-parallel
mesh axis, so each group's routing — top-k, sort, scatter — is device-local
under pjit; no global sort). Per group, assignments are slotted into an
[E, C, d] buffer via scatter, batch-GEMMed ('gecd,edh->gech'), and gathered
back. The buffer is sharded E->"model" (expert parallelism) x group->"data";
XLA inserts the token<->expert all-to-alls from the sharding constraints.

Never materializes a [tokens, experts, capacity] dispatch tensor. Router
softmax/top-k stays fp32 (routing is not a matmul site in the paper's
scheme); expert weights are FloatSD8 like any other weight.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from ..core.policy import Policy
from . import module as M
from .ffn import _silu
from .linear import quant_act, quant_einsum

__all__ = ["MoE"]


@dataclasses.dataclass(frozen=True)
class MoE:
    dim: int
    hidden: int  # per-expert FFN hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    dispatch_groups: int = 16  # aligned with the "data" mesh axis
    kind: str = "swiglu"
    quant_silu: bool = False
    name: str = "moe"

    def init(self, key):
        kr, k1, k2, k3 = jax.random.split(key, 4)
        e, d, h = self.n_experts, self.dim, self.hidden
        return {
            "router": M.truncated_normal_init(kr, (d, e), 0.02),
            "wi": M.truncated_normal_init(k1, (e, d, h)),
            "wg": M.truncated_normal_init(k2, (e, d, h)),
            "wo": M.truncated_normal_init(k3, (e, h, d), 1.0 / h**0.5),
        }

    def specs(self):
        return {
            "router": ("embed", None),
            "wi": ("expert", "embed", "expert_inner"),
            "wg": ("expert", "embed", "expert_inner"),
            "wo": ("expert", "expert_inner", "embed"),
        }

    def capacity(self, n_tokens: int) -> int:
        c = int(self.capacity_factor * self.top_k * n_tokens / self.n_experts)
        return max(8, min(n_tokens * self.top_k, -(-c // 8) * 8))

    def _groups(self) -> int:
        """Dispatch groups = DP shard count of the active mesh (routing is
        then device-local); falls back to the static default."""
        from ..distributed.sharding import active_mesh

        mesh = active_mesh()
        if mesh is None:
            return self.dispatch_groups
        g = 1
        for a in ("pod", "data"):
            if a in mesh.shape:
                g *= mesh.shape[a]
        return g

    # ------------------------------------------------------------------
    def _dispatch_one(self, p, xg, policy: Policy, cap: int):
        """Route one token group. xg: [t, d] -> (y [t, d], aux)."""
        t, d = xg.shape
        e, k = self.n_experts, self.top_k

        logits = jnp.einsum(
            "td,de->te", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
        )
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert_idx = jax.lax.top_k(probs, k)  # [t, k]
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        # slot assignment: rank within expert via stable sort
        flat_expert = expert_idx.reshape(-1)  # [t*k]
        order = jnp.argsort(flat_expert, stable=True)
        sorted_e = flat_expert[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(e))
        rank_sorted = jnp.arange(t * k) - seg_start[sorted_e]
        rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
        keep = rank < cap  # capacity overflow dropped

        slot_e = jnp.where(keep, flat_expert, e)  # e == drop bucket
        slot_c = jnp.where(keep, rank, 0).astype(jnp.int32)

        xq = quant_act(xg, policy)  # fp8 activations entering expert matmuls
        # Dispatch buffer STORED in fp8 when the policy already quantizes
        # activations to fp8 — the values are on the fp8 grid, so the cast
        # is exact, and the scatter traffic + dispatch A2A bytes halve
        # (EXPERIMENTS.md §Perf HC4 it.4).
        bdt = policy.act_dtypes()[0] if policy.act_fwd == "fp8" else xq.dtype
        src = jnp.repeat(xq.astype(bdt), k, axis=0)
        # Scatter into a buffer that is REPLICATED over the model axis (the
        # constraint below); the EP reshard afterwards is then a local slice.
        # Without this, the SPMD partitioner emulates a cross-shard scatter
        # with [t*k, d]-sized u32/f32 all-reduces (fwd AND bwd) — measured
        # 2x ~35 s per step on kimi-k2 (EXPERIMENTS.md §Perf HC4 it.3).
        buf = jnp.zeros((e + 1, cap, d), bdt)
        buf = buf.at[slot_e, slot_c].set(src, mode="drop")
        buf = _shard(buf, (None, None, None))  # replicated scatter output

        # load-balance aux (Switch-style)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32), axis=0)
        aux = e * jnp.sum(me * ce)
        return buf[:e], (slot_e, slot_c, keep, gate), aux

    def _combine_one(self, out_e, route, cap: int):
        """Combine expert outputs back to token rows.

        Gate-scaling happens in EXPERT-land (local under EP) and the
        combine is a scatter-ADD into [t, d] token rows — so the SPMD
        partitioner's cross-shard reduction moves t rows, not t*k
        gather rows (8x for top-8; measured in EXPERIMENTS.md §Perf HC4).
        REPRO_MOE_GATHER_COMBINE=1 restores the gather-based baseline.
        """
        import os

        slot_e, slot_c, keep, gate = route
        t, k = gate.shape
        d = out_e.shape[-1]
        e = self.n_experts
        if os.environ.get("REPRO_MOE_GATHER_COMBINE", "0") == "1":
            padded = jnp.concatenate(
                [out_e, jnp.zeros((1, cap, d), out_e.dtype)], axis=0
            )
            gathered = padded[slot_e, slot_c]  # [t*k, d]
            w = jnp.where(keep, gate.reshape(-1), 0.0)[:, None].astype(gathered.dtype)
            return (gathered * w).reshape(t, k, d).sum(axis=1)

        # gate weights scattered to their slots: scale rows where they live
        gbuf = (
            jnp.zeros((e + 1, cap), out_e.dtype)
            .at[slot_e, slot_c]
            .set(jnp.where(keep, gate.reshape(-1), 0.0).astype(out_e.dtype),
                 mode="drop")
        )
        scaled = out_e * gbuf[:e, :, None]  # local under EP
        # token index of every slot (empty slots -> t, dropped)
        tok_buf = (
            jnp.full((e + 1, cap), t, jnp.int32)
            .at[slot_e, slot_c]
            .set(jnp.arange(t * k, dtype=jnp.int32) // k, mode="drop")
        )
        y = (
            jnp.zeros((t + 1, d), out_e.dtype)
            .at[tok_buf[:e].reshape(-1)]
            .add(scaled.reshape(-1, d), mode="drop")
        )
        return y[:t]

    def apply(self, p, x, policy: Policy):
        """x: [B, S, d] -> ([B, S, d], aux load-balance loss)."""
        b, s, d = x.shape
        t = b * s
        g = math.gcd(t, self._groups())
        tg = t // g
        cap = self.capacity(tg)
        xf = _shard(x.reshape(g, tg, d), ("batch", None, None))

        bufs, routes, auxs = jax.vmap(
            lambda xg: self._dispatch_one(p, xg, policy, cap)
        )(xf)
        bufs = _shard(bufs, ("batch", "expert", None, None))  # [g, E, C, d]

        cdt = policy.cdt() or x.dtype
        be = bufs.astype(cdt)
        hi = quant_einsum("gecd,edh->gech", be, p["wi"], policy)
        hg = quant_einsum("gecd,edh->gech", be, p["wg"], policy)
        act = _silu(hg, self.quant_silu and policy.sigmoid_quant) * hi
        out_e = quant_einsum("gech,ehd->gecd", act, p["wo"], policy)
        out_e = _shard(out_e, ("batch", "expert", None, None))

        y = jax.vmap(lambda o, r: self._combine_one(o, r, cap))(out_e, routes)
        y = _shard(y, ("batch", None, None))
        return y.reshape(b, s, d).astype(x.dtype), jnp.mean(auxs)


def _shard(x, logical_axes):
    from ..distributed.sharding import constrain

    return constrain(x, logical_axes)
