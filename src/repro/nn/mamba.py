"""Mamba (S6) block for the Jamba hybrid (arXiv:2403.19887 cfg: expand=2,
d_state=16, d_conv=4). Selective scan runs as a chunked lax.scan (sequential
across chunks, bounded transients) — the TPU-native replacement for the
paper's CUDA kernel (DESIGN.md §3). All projections are QuantDense sites, so
FloatSD8 weights + FP8 activations apply; the SiLU gates can use the
two-region quantized sigmoid."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.policy import Policy
from ..core.qsigmoid import qsigmoid
from . import module as M
from .linear import quant_act, quant_einsum

__all__ = ["Mamba", "MambaCache"]


def _silu(x, q):
    return x * (qsigmoid(x) if q else jax.nn.sigmoid(x))


class MambaCache(NamedTuple):
    ssm: jax.Array  # [B, d_inner, d_state]
    conv: jax.Array  # [B, d_conv-1, d_inner]


@dataclasses.dataclass(frozen=True)
class Mamba:
    dim: int
    expand: int = 2
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int | None = None
    quant_silu: bool = False
    name: str = "mamba"

    @property
    def d_inner(self):
        return self.expand * self.dim

    @property
    def rank(self):
        return self.dt_rank or max(1, self.dim // 16)

    def init(self, key):
        ks = jax.random.split(key, 7)
        di, ds, r = self.d_inner, self.d_state, self.rank
        a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None], (di, 1))
        return {
            "in_proj": M.truncated_normal_init(ks[0], (self.dim, 2 * di)),
            "conv_w": M.truncated_normal_init(ks[1], (self.d_conv, di), 0.5),
            "conv_b": jnp.zeros((di,), jnp.float32),
            "x_proj": M.truncated_normal_init(ks[2], (di, r + 2 * ds)),
            "dt_proj": M.truncated_normal_init(ks[3], (r, di)),
            "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
            "a_log": jnp.log(a),
            "d": jnp.ones((di,), jnp.float32),
            "out_proj": M.truncated_normal_init(ks[4], (di, self.dim)),
        }

    def specs(self):
        return {
            "in_proj": ("embed", "mlp"),
            "conv_w": (None, "mlp"),
            "conv_b": ("mlp",),
            "x_proj": ("mlp", None),
            "dt_proj": (None, "mlp"),
            "dt_bias": ("mlp",),
            "a_log": ("mlp", None),
            "d": ("mlp",),
            "out_proj": ("mlp", "embed"),
        }

    def _pre(self, p, u, policy):
        """Shared projections: u [B,S,dim] -> x,z,dt,Bm,Cm."""
        di, ds, r = self.d_inner, self.d_state, self.rank
        xz = quant_einsum("bsd,dk->bsk", u, p["in_proj"], policy)
        x, z = jnp.split(xz, 2, axis=-1)
        return x, z

    def _ssm_params(self, p, x, policy):
        ds, r = self.d_state, self.rank
        proj = quant_einsum("bsd,dk->bsk", x, p["x_proj"], policy)
        dt_r, bm, cm = jnp.split(proj, [r, r + ds], axis=-1)
        dt = quant_einsum("bsr,rd->bsd", dt_r, p["dt_proj"], policy)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        return dt, bm.astype(jnp.float32), cm.astype(jnp.float32)

    def apply(self, p, u, policy: Policy, chunk: int = 256):
        """u: [B, S, dim] -> [B, S, dim]."""
        b, s, _ = u.shape
        di, ds = self.d_inner, self.d_state
        cdt = policy.cdt() or u.dtype
        x, z = self._pre(p, quant_act(u, policy), policy)
        # causal depthwise conv, k=d_conv
        xp = jnp.pad(x, ((0, 0), (self.d_conv - 1, 0), (0, 0)))
        xc = sum(
            xp[:, i : i + s, :] * p["conv_w"][i].astype(x.dtype)
            for i in range(self.d_conv)
        ) + p["conv_b"].astype(x.dtype)
        x = _silu(xc, self.quant_silu and policy.sigmoid_quant)
        dt, bm, cm = self._ssm_params(p, x, policy)
        a = -jnp.exp(p["a_log"])  # [di, ds]

        n = max(1, s // chunk)
        while s % n:
            n -= 1
        csz = s // n

        def to_chunks(t):
            return jnp.moveaxis(t.reshape(b, n, csz, *t.shape[2:]), 1, 0)

        xs, dts, bs, cs = map(to_chunks, (x.astype(jnp.float32), dt, bm, cm))

        def chunk_body(h, inp):
            xch, dtc, bc, cc = inp  # [B,csz,...]

            def step(hh, t):
                xt, dtt, bt, ct = t
                da = jnp.exp(dtt[:, :, None] * a[None])  # [B,di,ds]
                hh = hh * da + (dtt * xt)[:, :, None] * bt[:, None, :]
                y = jnp.einsum("bdn,bn->bd", hh, ct)
                return hh, y

            h, ys = jax.lax.scan(
                step, h, tuple(jnp.swapaxes(t, 0, 1) for t in (xch, dtc, bc, cc))
            )
            return h, jnp.swapaxes(ys, 0, 1)  # [B,csz,di]

        h0 = jnp.zeros((b, di, ds), jnp.float32)
        _, ys = jax.lax.scan(chunk_body, h0, (xs, dts, bs, cs))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di)
        y = y + x.astype(jnp.float32) * p["d"]
        y = y.astype(cdt) * _silu(z, self.quant_silu and policy.sigmoid_quant)
        return quant_einsum("bsd,dk->bsk", y, p["out_proj"], policy)

    def decode(self, p, u, cache: MambaCache, policy: Policy):
        """One-token step. u: [B,1,dim] -> ([B,1,dim], new cache)."""
        b = u.shape[0]
        di, ds = self.d_inner, self.d_state
        cdt = policy.cdt() or u.dtype
        x, z = self._pre(p, quant_act(u, policy), policy)  # [B,1,di]
        x1 = x[:, 0]
        # conv ring: cache.conv holds previous d_conv-1 inputs
        window = jnp.concatenate([cache.conv, x1[:, None, :]], axis=1)  # [B,k,di]
        xc = (
            jnp.einsum("bkd,kd->bd", window.astype(jnp.float32), p["conv_w"])
            + p["conv_b"]
        ).astype(x.dtype)
        xa = _silu(xc, self.quant_silu and policy.sigmoid_quant)[:, None, :]
        dt, bm, cm = self._ssm_params(p, xa, policy)
        a = -jnp.exp(p["a_log"])
        da = jnp.exp(dt[:, 0, :, None] * a[None])
        h = cache.ssm * da + (dt[:, 0] * xa[:, 0].astype(jnp.float32))[:, :, None] * bm[:, 0][:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, cm[:, 0])
        y = y + xa[:, 0].astype(jnp.float32) * p["d"]
        y = (y.astype(cdt) * _silu(z[:, 0], self.quant_silu and policy.sigmoid_quant))[:, None, :]
        out = quant_einsum("bsd,dk->bsk", y, p["out_proj"], policy)
        return out, MambaCache(h, window[:, 1:])
