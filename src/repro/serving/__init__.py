"""repro.serving — continuous-batching FloatSD8 inference engine.

See README.md in this directory for the engine lifecycle and the packed
weight memory model.
"""
from .engine import Lane, ServeEngine
from .metrics import RequestRecord, ServeMetrics
from .scheduler import ADMISSION_POLICIES, Request, Scheduler, synthetic_prompts
from .state_pool import StatePool, masked_reset
from .weight_store import PackedTensor, WeightStore, pack_tree, tree_nbytes, unpack_tree

__all__ = [
    "ServeEngine", "Lane",
    "ServeMetrics", "RequestRecord",
    "Scheduler", "Request", "ADMISSION_POLICIES", "synthetic_prompts",
    "StatePool", "masked_reset",
    "WeightStore", "PackedTensor", "pack_tree", "unpack_tree", "tree_nbytes",
]
