"""repro.serving — continuous-batching FloatSD8 inference engine.

See README.md in this directory for the engine lifecycle and the packed
weight memory model.
"""
from .engine import Lane, ServeEngine
from .frontend import AsyncRouter, PrefixCache, RequestRejected, Router, Ticket
from .http import Client as HttpClient
from .http import HttpError, HttpServer
from .metrics import RequestRecord, ServeMetrics, phase_summary, tenant_summary
from .scheduler import (
    ADMISSION_POLICIES,
    Request,
    Scheduler,
    synthetic_prompts,
    zipf_prefix_prompts,
)
from .state_pool import StatePool, masked_reset
from .weight_store import (
    WEIGHT_FORMATS,
    PackedTensor,
    PackedTensor4,
    WeightStore,
    pack_floatsd4,
    pack_tree,
    tree_nbytes,
    unpack_tree,
)

__all__ = [
    "ServeEngine", "Lane",
    "ServeMetrics", "RequestRecord", "tenant_summary", "phase_summary",
    "Scheduler", "Request", "ADMISSION_POLICIES",
    "synthetic_prompts", "zipf_prefix_prompts",
    "StatePool", "masked_reset",
    "PrefixCache", "Router", "AsyncRouter", "Ticket", "RequestRejected",
    "HttpServer", "HttpClient", "HttpError",
    "WeightStore", "PackedTensor", "PackedTensor4", "WEIGHT_FORMATS",
    "pack_tree", "pack_floatsd4", "unpack_tree", "tree_nbytes",
]
