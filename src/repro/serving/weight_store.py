"""Packed FloatSD8 weight store — the serving deployment format.

Every matmul-site weight tensor (ndim >= 2, floating) is packed once at
engine construction to uint8 FloatSD8 codes + a per-tensor int32 exponent
bias via ``core.floatsd.encode``. The resident serving footprint is then
1 byte/weight (vs 4 for f32); ``unpack_tree`` is the jit-compatible
decode-at-use view — called inside the jitted serve step, the uint8 codes
are the long-lived buffers and the decoded f32 tensors are fused
temporaries, mirroring the paper PE's decode-in-VMEM datapath.

Round-trip guarantee (tested in tests/test_serving.py): for any tensor,
``decode(*encode(w)) == quantize(w).values`` exactly — encode picks the
canonical (exponent, mantissa-index) pair for the same nearest grid value
the fake-quant path rounds to, and both mantissa and 2^(e+bias) are exact
in f32. A model served from decoded codes therefore computes the same
function as the training-time fake-quant path (which is why the engine
drops the redundant ``weight_quant`` pass when serving packed).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core import floatsd
from ..kernels.dispatch import (
    PackedTensor,
    PackedTensor4,
    is_packed as _is_packed,
    is_packed4 as _is_packed4,
    pack4 as _pack4,
    unpack4 as _unpack4,
)

__all__ = [
    "PackedTensor", "PackedTensor4", "WeightStore", "WEIGHT_FORMATS",
    "pack_tree", "pack_floatsd4", "unpack_tree", "tree_nbytes",
]

#: serving weight formats: FloatSD8 (1 byte/weight, per-tensor bias) and
#: FloatSD4 (2 codes/byte + int8 group exponents, ~0.53 byte/weight),
#: the latter derived offline from the FloatSD8 master
WEIGHT_FORMATS = ("floatsd8", "floatsd4")


def _packable(x, min_ndim: int) -> bool:
    return (
        hasattr(x, "ndim")
        and x.ndim >= min_ndim
        and jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)
    )


def pack_tree(params: Any, min_ndim: int = 2) -> Any:
    """Dense param tree -> tree with PackedTensor at every packable leaf.

    ``min_ndim=2`` packs exactly the quantized matmul sites (weight
    matrices, embedding tables); 1-D biases and scalars stay dense, matching
    the policy's quantization sites.
    """

    def _pack(w):
        if _packable(w, min_ndim):
            # Eager finiteness guard: uint8 codes have no NaN/inf
            # representation, so ``encode`` would silently map a NaN
            # weight to grid point 0 — a corrupted checkpoint would then
            # serve a *finite but wrong* model with no error anywhere.
            # Packing happens once, host-side, at engine construction:
            # the one place this check is free and the failure actionable.
            if not bool(jnp.all(jnp.isfinite(jnp.asarray(w, jnp.float32)))):
                raise ValueError(
                    f"pack_tree: nonfinite values in weight tensor "
                    f"shape={tuple(w.shape)} — refusing to encode NaN/inf "
                    f"to a finite FloatSD8 code (corrupt checkpoint?)"
                )
            codes, bias = floatsd.encode(w)
            return PackedTensor(codes, bias)
        return w

    return jax.tree_util.tree_map(_pack, params)


def pack_floatsd4(tree: Any, min_ndim: int = 2) -> Any:
    """Trained FloatSD8 master -> FloatSD4 serving tree.

    Accepts either a dense param tree (routed through the FloatSD8 grid
    first — the format the model was trained against — so FloatSD4 is
    always a re-quantization of the *served* FloatSD8 values, never of
    raw f32 the FloatSD8 path would have rounded differently) or a tree
    that is already FloatSD8-packed. Packable leaves become
    :class:`PackedTensor4` (nibble-packed codes + group exponents).
    """
    t8 = pack_tree(tree, min_ndim=min_ndim)
    return jax.tree_util.tree_map(
        lambda x: _pack4(x) if _is_packed(x) else x, t8, is_leaf=_is_packed
    )


def _is_any_packed(x) -> bool:
    return _is_packed(x) or _is_packed4(x)


def unpack_tree(tree: Any, dtype=jnp.float32) -> Any:
    """Decode-at-use view: packed leaves (either format) -> dense
    ``dtype`` tensors.

    jit-compatible and a no-op on trees without packed leaves, so callers
    (e.g. ``WikiText2LM.decode_step``) can apply it unconditionally.
    """

    def _unpack(x):
        if _is_packed(x):
            return floatsd.decode(x.codes, x.bias, dtype=dtype)
        if _is_packed4(x):
            return _unpack4(x, dtype=dtype)
        return x

    return jax.tree_util.tree_map(_unpack, tree, is_leaf=_is_any_packed)


def tree_nbytes(tree: Any) -> int:
    """Total bytes of every array leaf (PackedTensor counts codes + bias)."""
    return sum(
        l.size * jnp.asarray(l).dtype.itemsize for l in jax.tree_util.tree_leaves(tree)
    )


@dataclasses.dataclass(frozen=True)
class WeightStore:
    """The packed serving weights plus size bookkeeping."""

    tree: Any  # pytree with PackedTensor/PackedTensor4 leaves at packed sites
    dense_nbytes: int
    n_packed: int  # number of tensors packed to codes
    fmt: str = "floatsd8"  # one of WEIGHT_FORMATS

    @classmethod
    def pack(cls, params: Any, min_ndim: int = 2,
             fmt: str = "floatsd8") -> "WeightStore":
        if fmt not in WEIGHT_FORMATS:
            raise ValueError(
                f"weight format must be one of {WEIGHT_FORMATS}, got {fmt!r}"
            )
        if fmt == "floatsd4":
            packed = pack_floatsd4(params, min_ndim=min_ndim)
        else:
            packed = pack_tree(params, min_ndim=min_ndim)
        n = sum(
            _is_any_packed(x)
            for x in jax.tree_util.tree_leaves(packed, is_leaf=_is_any_packed)
        )
        return cls(tree=packed, dense_nbytes=tree_nbytes(params),
                   n_packed=n, fmt=fmt)

    @property
    def packed_nbytes(self) -> int:
        return tree_nbytes(self.tree)

    @property
    def compression(self) -> float:
        return self.dense_nbytes / max(self.packed_nbytes, 1)

    def materialize(self, dtype=jnp.float32) -> Any:
        """Dense decoded params (mainly for tests / offline inspection)."""
        return unpack_tree(self.tree, dtype=dtype)
