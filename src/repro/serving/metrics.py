"""Serving metrics: per-request latency plus aggregate throughput/utilization.

Tracked per batched step (the engine's unit of device work):
  * steps / prefill_steps / decode_steps — a prefill step is any step whose
    token block is wider than one position;
  * token-slot accounting — each step offers B*S token slots; ``useful``
    slots actually advanced a lane (prompt tokens consumed or tokens
    generated), the rest were padding or idle lanes. ``slot_util`` is the
    fraction of device work that was useful — the number chunked prefill
    exists to raise;
  * lane occupancy — fraction of lanes bound to a request per step.

Per retired request: time-to-first-token (submit -> first generated token)
and total latency (submit -> retire), tagged with the request's tenant so
the frontend can report per-tenant percentiles.

Prefix-cache accounting (populated when the engine is given a cache):
  * cache_lookups / cache_hits / cache_full_hits — admission-time trie
    lookups and their outcomes (a full hit skips prefill entirely);
  * prefill_tokens_saved — prompt tokens NOT consumed because a cached
    state was injected at the match point.

All summary properties are total functions: with zero steps and zero
retired requests they return 0.0 (or empty aggregates), never raise.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Optional

import numpy as np

__all__ = [
    "RequestRecord",
    "ServeMetrics",
    "LatencyHistogram",
    "tenant_summary",
    "phase_summary",
    "RECORD_WINDOW",
    "LATENCY_BUCKETS_MS",
]

# Per-request records feed percentile summaries only, so they are kept in
# a sliding window: a long-lived server (launch/serve --http) retires
# requests forever, and an unbounded list would grow without limit while
# every /metrics scrape paid O(history) percentile math under the router
# pump lock. Totals ("requests" etc.) come from plain counters, not the
# window, so counter metrics stay monotonic after the window wraps.
RECORD_WINDOW = 4096

# Histogram bucket upper bounds (milliseconds) for TTFT and TPOT. Unlike the
# percentile summaries above these feed *cumulative* counters — they must
# never decrease, so they live outside the sliding record window and are
# safe to expose as Prometheus `_bucket{le=...}` series that `rate()` and
# `histogram_quantile()` can be run against.
LATENCY_BUCKETS_MS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
                      500.0, 1000.0, 2000.0, 5000.0)


@dataclasses.dataclass
class LatencyHistogram:
    """Monotonic latency histogram: per-bucket counts (last bucket is the
    +Inf overflow), running sum and count. ``report()`` is a plain dict so
    replica histograms can be summed elementwise by the router."""
    bounds: tuple = LATENCY_BUCKETS_MS
    counts: list = dataclasses.field(
        default_factory=lambda: [0] * (len(LATENCY_BUCKETS_MS) + 1)
    )
    sum_ms: float = 0.0
    count: int = 0

    def observe(self, ms: float) -> None:
        i = 0
        while i < len(self.bounds) and ms > self.bounds[i]:
            i += 1
        self.counts[i] += 1
        self.sum_ms += ms
        self.count += 1

    def report(self) -> dict:
        return {
            "buckets_ms": list(self.bounds),
            "counts": list(self.counts),
            "sum_ms": self.sum_ms,
            "count": self.count,
        }

    @staticmethod
    def merge_reports(reports) -> dict:
        """Elementwise sum of ``report()`` dicts (replica aggregation).
        Empty input yields an all-zero histogram with the default bounds."""
        out = LatencyHistogram().report()
        for r in reports:
            if not r or r.get("buckets_ms") != out["buckets_ms"]:
                continue  # bounds mismatch: skip rather than mis-sum
            out["counts"] = [a + b for a, b in zip(out["counts"], r["counts"])]
            out["sum_ms"] += r["sum_ms"]
            out["count"] += r["count"]
        return out


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    rid: int
    prompt_len: int
    new_tokens: int
    ttft: float  # submit -> first generated token (seconds)
    latency: float  # submit -> done (seconds)
    tenant: str = "default"
    # phase decomposition (seconds): queue_s + prefill_s == ttft and
    # queue_s + prefill_s + decode_s == latency, up to clock-read clamping
    queue_s: float = 0.0  # submit -> lane admission
    prefill_s: float = 0.0  # admission -> first generated token
    decode_s: float = 0.0  # first token -> retire
    cache_saved_tokens: int = 0  # prompt tokens skipped via prefix cache


def _pct(xs: np.ndarray, q: float) -> float:
    return float(np.percentile(xs, q)) if xs.size else 0.0


def phase_summary(records) -> dict:
    """Per-phase latency aggregates over RequestRecords: for each of
    queue/prefill/decode, mean/p50/p95 seconds — the warm-tail attribution
    the tracer exists for, as scrapeable numbers. Empty-safe."""
    out = {}
    for phase in ("queue", "prefill", "decode"):
        xs = np.array([getattr(r, phase + "_s") for r in records])
        out[phase] = {
            "mean_s": float(xs.mean()) if xs.size else 0.0,
            "p50_s": _pct(xs, 50),
            "p95_s": _pct(xs, 95),
        }
    return out


def tenant_summary(records) -> dict:
    """Group RequestRecords by tenant -> {tenant: ttft/latency percentiles}.
    Well-defined (empty dict) when no requests have retired."""
    by_tenant: dict = {}
    for r in records:
        by_tenant.setdefault(r.tenant, []).append(r)
    out = {}
    for tenant, rs in sorted(by_tenant.items()):
        ttfts = np.array([r.ttft for r in rs])
        lats = np.array([r.latency for r in rs])
        out[tenant] = {
            "requests": len(rs),
            "new_tokens": sum(r.new_tokens for r in rs),
            "ttft_mean_s": float(ttfts.mean()),
            "ttft_p50_s": _pct(ttfts, 50),
            "ttft_p95_s": _pct(ttfts, 95),
            "latency_mean_s": float(lats.mean()),
            "latency_p50_s": _pct(lats, 50),
            "latency_p95_s": _pct(lats, 95),
        }
    return out


@dataclasses.dataclass
class ServeMetrics:
    lanes: int
    steps: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0
    emitted: int = 0  # generated tokens
    prompt_tokens: int = 0  # prompt tokens consumed by prefill
    token_slots: int = 0  # sum over steps of B * S
    useful_slots: int = 0  # slots that advanced some lane
    lane_slots: int = 0  # sum over steps of B
    active_lane_slots: int = 0  # sum over steps of #active lanes
    cache_lookups: int = 0  # prefix-cache admission lookups
    cache_hits: int = 0  # ... that injected a cached state
    cache_full_hits: int = 0  # ... that skipped prefill entirely
    prefill_tokens_saved: int = 0  # prompt tokens not consumed due to hits
    retired: int = 0  # total retired requests (records is only a window)
    cancelled: int = 0  # requests cancelled before completing
    cancelled_by_reason: dict = dataclasses.field(default_factory=dict)
    numeric_errors: int = 0  # lanes retired on nonfinite logits
    preemptions: int = 0  # lanes snapshotted + requeued for shorter work
    resumes: int = 0  # preempted requests restored onto a lane
    records: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=RECORD_WINDOW)
    )
    # cumulative latency histograms (monotonic, unlike the record window)
    ttft_hist: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram
    )
    tpot_hist: LatencyHistogram = dataclasses.field(
        default_factory=LatencyHistogram
    )
    t_start: Optional[float] = None
    t_stop: Optional[float] = None

    # -- lifecycle -------------------------------------------------------
    def start(self) -> None:
        self.t_start = time.monotonic()

    def stop(self) -> None:
        self.t_stop = time.monotonic()

    @property
    def elapsed(self) -> float:
        if self.t_start is None:
            return 0.0  # never started; rate summaries report 0, not junk
        end = self.t_stop if self.t_stop is not None else time.monotonic()
        return max(end - self.t_start, 1e-9)

    # -- per-step / per-request hooks -----------------------------------
    def on_step(self, width: int, active: int, useful: int, any_prefill: bool) -> None:
        self.steps += 1
        if any_prefill:
            self.prefill_steps += 1
        else:
            self.decode_steps += 1
        self.token_slots += self.lanes * width
        self.useful_slots += useful
        self.lane_slots += self.lanes
        self.active_lane_slots += active

    def on_retire(self, req, now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        self.retired += 1
        t0 = req.t_submit if req.t_submit is not None else now
        t1 = req.t_first if req.t_first is not None else now
        t_admit = getattr(req, "t_admit", None)
        if t_admit is None:
            t_admit = t0  # admission never stamped: attribute all to prefill
        self.records.append(
            RequestRecord(
                rid=req.rid,
                prompt_len=req.prompt_len,
                new_tokens=len(req.out),
                ttft=t1 - t0,
                latency=now - t0,
                tenant=getattr(req, "tenant", "default"),
                queue_s=max(t_admit - t0, 0.0),
                prefill_s=max(t1 - t_admit, 0.0),
                decode_s=max(now - t1, 0.0),
                cache_saved_tokens=getattr(req, "cache_saved_tokens", 0),
            )
        )
        ttft = t1 - t0
        self.ttft_hist.observe(max(ttft, 0.0) * 1e3)
        new_tokens = len(req.out)
        if new_tokens >= 2:
            # time-per-output-token over the decode stretch: (latency -
            # ttft) spans the new_tokens - 1 inter-token gaps
            self.tpot_hist.observe(
                max(now - t1, 0.0) * 1e3 / (new_tokens - 1)
            )

    def on_cancel(self, req, reason: str) -> None:
        """A request left the engine without completing (client cancel,
        abandoned stream, mid-flight deadline). Counted separately from
        ``retired`` and kept OUT of the latency record window: a cancelled
        request has no honest TTFT/latency sample, and an abandoned one
        would otherwise poison the percentiles with its wall-clock age."""
        del req  # counters only; per-request data stays with the caller
        self.cancelled += 1
        self.cancelled_by_reason[reason] = (
            self.cancelled_by_reason.get(reason, 0) + 1
        )

    def on_numeric_error(self, req) -> None:
        """A lane hit nonfinite logits and was retired defensively. Like
        cancels, these carry no honest latency sample, so they are kept
        out of the percentile window — which also pins the empty-window
        safety property: a window where *every* request errored must
        still produce all-zero summaries, never a ZeroDivisionError."""
        del req
        self.numeric_errors += 1

    def on_cache_lookup(self, hit: bool, full: bool, saved: int) -> None:
        self.cache_lookups += 1
        if hit:
            self.cache_hits += 1
            self.prefill_tokens_saved += saved
        if full:
            self.cache_full_hits += 1

    # -- aggregation (all total: safe at steps == 0 / no requests) -------
    @property
    def slot_util(self) -> float:
        return self.useful_slots / self.token_slots if self.token_slots else 0.0

    @property
    def lane_occupancy(self) -> float:
        return self.active_lane_slots / self.lane_slots if self.lane_slots else 0.0

    @property
    def cache_hit_rate(self) -> float:
        return self.cache_hits / self.cache_lookups if self.cache_lookups else 0.0

    def per_tenant(self) -> dict:
        return tenant_summary(self.records)

    def report(self) -> dict:
        dt = self.elapsed
        ttfts = np.array([r.ttft for r in self.records])
        lats = np.array([r.latency for r in self.records])
        return {
            "requests": self.retired,
            "cancelled": self.cancelled,
            "cancelled_by_reason": dict(self.cancelled_by_reason),
            "numeric_errors": self.numeric_errors,
            "preemptions": self.preemptions,
            "resumes": self.resumes,
            "steps": self.steps,
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            "emitted_tokens": self.emitted,
            "prompt_tokens": self.prompt_tokens,
            "elapsed_s": dt,
            "gen_tok_per_s": self.emitted / dt if dt > 0 else 0.0,
            "total_tok_per_s": (
                (self.emitted + self.prompt_tokens) / dt if dt > 0 else 0.0
            ),
            "lane_occupancy": self.lane_occupancy,
            "slot_util": self.slot_util,
            "cache_lookups": self.cache_lookups,
            "cache_hits": self.cache_hits,
            "cache_full_hits": self.cache_full_hits,
            "cache_hit_rate": self.cache_hit_rate,
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "ttft_mean_s": float(ttfts.mean()) if ttfts.size else 0.0,
            "ttft_p95_s": _pct(ttfts, 95),
            "latency_mean_s": float(lats.mean()) if lats.size else 0.0,
            "latency_p95_s": _pct(lats, 95),
            "phases": phase_summary(self.records),
            "ttft_hist_ms": self.ttft_hist.report(),
            "tpot_hist_ms": self.tpot_hist.report(),
        }

    def format(self) -> str:
        r = self.report()
        line = (
            f"served {r['requests']} requests, {r['emitted_tokens']} tokens "
            f"(+{r['prompt_tokens']} prompt) in {r['elapsed_s']:.1f}s | "
            f"{r['gen_tok_per_s']:.1f} gen tok/s, {r['total_tok_per_s']:.1f} total tok/s | "
            f"{r['steps']} steps ({r['prefill_steps']} prefill / {r['decode_steps']} decode) | "
            f"lane occupancy {r['lane_occupancy']:.0%}, slot util {r['slot_util']:.0%} | "
            f"ttft mean {r['ttft_mean_s']*1e3:.0f}ms p95 {r['ttft_p95_s']*1e3:.0f}ms"
        )
        if r["cache_lookups"]:
            line += (
                f" | prefix cache {r['cache_hit_rate']:.0%} hit "
                f"({r['cache_full_hits']} full), "
                f"{r['prefill_tokens_saved']} prefill tok saved"
            )
        return line
