"""Serving metrics: per-request latency plus aggregate throughput/utilization.

Tracked per batched step (the engine's unit of device work):
  * steps / prefill_steps / decode_steps — a prefill step is any step whose
    token block is wider than one position;
  * token-slot accounting — each step offers B*S token slots; ``useful``
    slots actually advanced a lane (prompt tokens consumed or tokens
    generated), the rest were padding or idle lanes. ``slot_util`` is the
    fraction of device work that was useful — the number chunked prefill
    exists to raise;
  * lane occupancy — fraction of lanes bound to a request per step.

Per retired request: time-to-first-token (submit -> first generated token)
and total latency (submit -> retire).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

__all__ = ["RequestRecord", "ServeMetrics"]


@dataclasses.dataclass(frozen=True)
class RequestRecord:
    rid: int
    prompt_len: int
    new_tokens: int
    ttft: float  # submit -> first generated token (seconds)
    latency: float  # submit -> done (seconds)


@dataclasses.dataclass
class ServeMetrics:
    lanes: int
    steps: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0
    emitted: int = 0  # generated tokens
    prompt_tokens: int = 0  # prompt tokens consumed by prefill
    token_slots: int = 0  # sum over steps of B * S
    useful_slots: int = 0  # slots that advanced some lane
    lane_slots: int = 0  # sum over steps of B
    active_lane_slots: int = 0  # sum over steps of #active lanes
    records: list = dataclasses.field(default_factory=list)
    t_start: Optional[float] = None
    t_stop: Optional[float] = None

    # -- lifecycle -------------------------------------------------------
    def start(self) -> None:
        self.t_start = time.monotonic()

    def stop(self) -> None:
        self.t_stop = time.monotonic()

    @property
    def elapsed(self) -> float:
        if self.t_start is None:
            return 0.0
        end = self.t_stop if self.t_stop is not None else time.monotonic()
        return max(end - self.t_start, 1e-9)

    # -- per-step / per-request hooks -----------------------------------
    def on_step(self, width: int, active: int, useful: int, any_prefill: bool) -> None:
        self.steps += 1
        if any_prefill:
            self.prefill_steps += 1
        else:
            self.decode_steps += 1
        self.token_slots += self.lanes * width
        self.useful_slots += useful
        self.lane_slots += self.lanes
        self.active_lane_slots += active

    def on_retire(self, req, now: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        t0 = req.t_submit if req.t_submit is not None else now
        t1 = req.t_first if req.t_first is not None else now
        self.records.append(
            RequestRecord(
                rid=req.rid,
                prompt_len=req.prompt_len,
                new_tokens=len(req.out),
                ttft=t1 - t0,
                latency=now - t0,
            )
        )

    # -- aggregation -----------------------------------------------------
    def report(self) -> dict:
        dt = self.elapsed
        ttfts = np.array([r.ttft for r in self.records]) if self.records else np.zeros(0)
        lats = np.array([r.latency for r in self.records]) if self.records else np.zeros(0)
        return {
            "requests": len(self.records),
            "steps": self.steps,
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            "emitted_tokens": self.emitted,
            "prompt_tokens": self.prompt_tokens,
            "elapsed_s": dt,
            "gen_tok_per_s": self.emitted / dt,
            "total_tok_per_s": (self.emitted + self.prompt_tokens) / dt,
            "lane_occupancy": self.active_lane_slots / max(self.lane_slots, 1),
            "slot_util": self.useful_slots / max(self.token_slots, 1),
            "ttft_mean_s": float(ttfts.mean()) if ttfts.size else 0.0,
            "ttft_p95_s": float(np.percentile(ttfts, 95)) if ttfts.size else 0.0,
            "latency_mean_s": float(lats.mean()) if lats.size else 0.0,
        }

    def format(self) -> str:
        r = self.report()
        return (
            f"served {r['requests']} requests, {r['emitted_tokens']} tokens "
            f"(+{r['prompt_tokens']} prompt) in {r['elapsed_s']:.1f}s | "
            f"{r['gen_tok_per_s']:.1f} gen tok/s, {r['total_tok_per_s']:.1f} total tok/s | "
            f"{r['steps']} steps ({r['prefill_steps']} prefill / {r['decode_steps']} decode) | "
            f"lane occupancy {r['lane_occupancy']:.0%}, slot util {r['slot_util']:.0%} | "
            f"ttft mean {r['ttft_mean_s']*1e3:.0f}ms p95 {r['ttft_p95_s']*1e3:.0f}ms"
        )
