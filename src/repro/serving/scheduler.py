"""Continuous-batching request queue with pluggable admission policies.

Requests enter a queue and are *admitted* to a free decode lane by the
engine; a lane runs chunked prefill over the request's prompt, then decodes
until ``max_new`` tokens are emitted, then retires and frees the lane for
the next admission — other lanes never stall.

Admission policies:
  * ``fifo`` — arrival order (fair, default);
  * ``sjf``  — shortest-prompt-first (minimizes mean time-to-first-token
    when prompt lengths are skewed; classic shortest-job-first trade-off:
    long prompts can starve under sustained load);
  * ``edf``  — earliest-deadline-first (deadline-aware admission for the
    multi-tenant frontend; requests without a deadline sort last, ties
    break on arrival order);
  * ``sjf_work`` — shortest-*remaining-work*-first: sorts on the estimated
    device-token cost still ahead of the request, counting prompt tokens a
    prefix-cache hit would skip (``work_hint``, stamped by the router at
    submission) and prompt/output tokens already consumed (a preempted
    request re-entering the queue owes only its remaining decode). This is
    the scheduler-v2 policy for the warm-cache tail: a warm full hit costs
    ~``max_new`` tokens while a cold long prompt costs ``prompt + max_new``,
    and FIFO makes the cheap request wait behind the expensive one.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
import time
from typing import Optional

import numpy as np

__all__ = [
    "Request",
    "Scheduler",
    "ADMISSION_POLICIES",
    "synthetic_prompts",
    "zipf_prefix_prompts",
]

ADMISSION_POLICIES = ("fifo", "sjf", "edf", "sjf_work")


def synthetic_prompts(n, vocab, rng, lo=4, hi=24):
    """Synthetic request workload: n int32 prompt arrays with lengths in
    [lo, hi). Shared by the serve CLI, the serving benchmark, and tests so
    the three always sample the same distribution."""
    return [
        rng.integers(0, vocab, int(rng.integers(lo, hi))).astype(np.int32)
        for _ in range(n)
    ]


def zipf_prefix_prompts(
    n,
    vocab,
    rng,
    n_prefixes=4,
    prefix_len=24,
    suffix_lo=2,
    suffix_hi=10,
    alpha=1.1,
    prefix_seed=None,
):
    """Shared-system-prompt workload: each prompt is ``prefix + suffix``
    where the prefix is drawn zipf(alpha)-style from ``n_prefixes`` fixed
    "system prompts" of length ``prefix_len`` and the suffix is a fresh
    uniform sample of length in [suffix_lo, suffix_hi).

    This is the distribution the frontend's LSTM-state prefix cache exists
    for: the hot prefixes repeat across requests (and across tenants), so a
    cached ``(h, c)`` snapshot at the prefix boundary turns most of each
    prompt's prefill into a single state injection. Deterministic for a
    fixed ``rng``. Pass ``prefix_seed`` to pin the prefix pool
    independently of ``rng``: a warm-up workload and a measurement workload
    built with the same ``prefix_seed`` but different ``rng`` seeds share
    their system prompts while every suffix is fresh — the honest version
    of a warm cache (see benchmarks/bench_serving.py).
    """
    prng = np.random.default_rng(prefix_seed) if prefix_seed is not None else rng
    prefixes = [
        prng.integers(0, vocab, prefix_len).astype(np.int32)
        for _ in range(n_prefixes)
    ]
    ranks = np.arange(1, n_prefixes + 1, dtype=np.float64)
    probs = ranks**-alpha
    probs /= probs.sum()
    prompts = []
    for _ in range(n):
        k = int(rng.choice(n_prefixes, p=probs))
        suffix = rng.integers(
            0, vocab, int(rng.integers(suffix_lo, suffix_hi))
        ).astype(np.int32)
        prompts.append(np.concatenate([prefixes[k], suffix]))
    return prompts


@dataclasses.dataclass
class Request:
    """One generation request plus its lifecycle timestamps.

    The four timestamps split a request's wall-clock into the three phases
    the observability layer attributes latency to (see ``phases``):

        t_submit ──queue──▶ t_admit ──prefill──▶ t_first ──decode──▶ t_done

    ``t_submit`` is stamped once at first scheduler submission (preserved
    across router→engine resubmission), ``t_admit`` when the engine binds
    the request to a lane, ``t_first`` at the first generated token, and
    ``t_done`` at retire.
    """

    rid: int
    prompt: np.ndarray  # int32 [L], L >= 1
    max_new: int
    tenant: str = "default"
    deadline: Optional[float] = None  # absolute time.monotonic() deadline
    out: list = dataclasses.field(default_factory=list)
    t_submit: Optional[float] = None
    t_admit: Optional[float] = None  # bound to a lane (queue wait ends)
    t_first: Optional[float] = None  # first generated token (TTFT anchor)
    t_done: Optional[float] = None
    cache_hit: bool = False  # prefix-cache hit at admission
    cache_saved_tokens: int = 0  # prompt tokens skipped via state injection
    cache_saved_steps: int = 0  # ... as whole prefill steps at engine chunk
    status: str = "active"  # "active" | "done" | "cancelled"
    cancel_reason: Optional[str] = None  # set iff status == "cancelled"
    work_hint: Optional[int] = None  # prefix-cache match length, if probed
    preempt_count: int = 0  # times this request was preempted off a lane

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("empty prompt")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new

    @property
    def cancelled(self) -> bool:
        return self.status == "cancelled"

    def remaining_work(self) -> int:
        """Estimated device-token cost still ahead: unconsumed prompt
        tokens (minus the cached prefix the router probed into
        ``work_hint``) plus undecoded output tokens. Once decoding has
        started the prompt is fully paid for, so a preempted request owes
        only its remaining decode."""
        remaining_out = max(self.max_new - len(self.out), 0)
        if self.out:
            return remaining_out
        cached = self.work_hint if self.work_hint is not None else 0
        return max(self.prompt_len - cached, 0) + remaining_out

    def phases(self) -> Optional[dict]:
        """Per-request latency breakdown in milliseconds, or None until the
        request retires. This is the payload the HTTP layer returns under
        the ``debug`` flag and the benchmark turns into TTFT-breakdown
        columns; each phase is clamped at 0 so clock-read ordering noise
        can never produce a negative duration."""
        if self.t_submit is None or self.t_done is None:
            return None
        t0 = self.t_submit
        t_admit = self.t_admit if self.t_admit is not None else t0
        t1 = self.t_first if self.t_first is not None else self.t_done
        return {
            "queue_ms": max(t_admit - t0, 0.0) * 1e3,
            "prefill_ms": max(t1 - t_admit, 0.0) * 1e3,
            "decode_ms": max(self.t_done - t1, 0.0) * 1e3,
            "total_ms": max(self.t_done - t0, 0.0) * 1e3,
            "cache_hit": self.cache_hit,
            "cache_saved_tokens": self.cache_saved_tokens,
            "cache_saved_steps": self.cache_saved_steps,
        }

    def sort_key(self, policy: str) -> float:
        if policy == "sjf":
            return float(self.prompt_len)
        if policy == "sjf_work":
            return float(self.remaining_work())
        # edf: missing deadline == infinitely lax, served after all dated work
        return self.deadline if self.deadline is not None else float("inf")


class Scheduler:
    """Admission queue. ``submit`` enqueues; ``pop`` yields the next request
    to bind to a freed lane under the configured policy."""

    def __init__(self, policy: str = "fifo"):
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"choose from {ADMISSION_POLICIES}")
        self.policy = policy
        self._fifo: collections.deque = collections.deque()
        self._heap: list = []
        self._seq = itertools.count()

    def submit(self, req: Request, now: float | None = None) -> Request:
        # first submission stamps arrival; re-submission (router queue ->
        # engine queue) must NOT erase the time already spent waiting, or
        # TTFT/latency would exclude router queueing exactly under the
        # backlog conditions they exist to expose
        if req.t_submit is None:
            req.t_submit = time.monotonic() if now is None else now
        if self.policy == "fifo":
            self._fifo.append(req)
        else:  # sjf/edf: stable tie-break on arrival order
            heapq.heappush(
                self._heap, (req.sort_key(self.policy), next(self._seq), req)
            )
        return req

    def pop(self) -> Request | None:
        if self.policy == "fifo":
            return self._fifo.popleft() if self._fifo else None
        if self._heap:
            return heapq.heappop(self._heap)[2]
        return None

    def peek(self) -> Request | None:
        """Next request ``pop`` would return, without removing it — the
        engine's preemption check compares its remaining work against the
        lanes' without committing to an admission."""
        if self.policy == "fifo":
            return self._fifo[0] if self._fifo else None
        return self._heap[0][2] if self._heap else None

    def remove(self, rid: int) -> Request | None:
        """Remove and return the queued request with this rid, or None.
        O(queue) scan + (heap policies) re-heapify — cancellation is rare
        relative to queue churn and queues are bounded small, so linear
        cost beats maintaining a rid index on the hot submit/pop path."""
        for idx, r in enumerate(self._fifo):
            if r.rid == rid:
                del self._fifo[idx]
                return r
        for idx, (_, _, r) in enumerate(self._heap):
            if r.rid == rid:
                last = self._heap.pop()
                if idx < len(self._heap):
                    self._heap[idx] = last
                    heapq.heapify(self._heap)
                return r
        return None

    def __len__(self) -> int:
        return len(self._fifo) + len(self._heap)

    def __bool__(self) -> bool:
        return len(self) > 0
