"""Continuous-batching request queue with pluggable admission policies.

Requests enter a queue and are *admitted* to a free decode lane by the
engine; a lane runs chunked prefill over the request's prompt, then decodes
until ``max_new`` tokens are emitted, then retires and frees the lane for
the next admission — other lanes never stall.

Admission policies:
  * ``fifo`` — arrival order (fair, default);
  * ``sjf``  — shortest-prompt-first (minimizes mean time-to-first-token
    when prompt lengths are skewed; classic shortest-job-first trade-off:
    long prompts can starve under sustained load).
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
import time
from typing import Optional

import numpy as np

__all__ = ["Request", "Scheduler", "ADMISSION_POLICIES", "synthetic_prompts"]

ADMISSION_POLICIES = ("fifo", "sjf")


def synthetic_prompts(n, vocab, rng, lo=4, hi=24):
    """Synthetic request workload: n int32 prompt arrays with lengths in
    [lo, hi). Shared by the serve CLI, the serving benchmark, and tests so
    the three always sample the same distribution."""
    return [
        rng.integers(0, vocab, int(rng.integers(lo, hi))).astype(np.int32)
        for _ in range(n)
    ]


@dataclasses.dataclass
class Request:
    """One generation request plus its lifecycle timestamps."""

    rid: int
    prompt: np.ndarray  # int32 [L], L >= 1
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    t_submit: Optional[float] = None
    t_first: Optional[float] = None  # first generated token (TTFT anchor)
    t_done: Optional[float] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 1:
            raise ValueError("empty prompt")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.size)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class Scheduler:
    """Admission queue. ``submit`` enqueues; ``pop`` yields the next request
    to bind to a freed lane under the configured policy."""

    def __init__(self, policy: str = "fifo"):
        if policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"choose from {ADMISSION_POLICIES}")
        self.policy = policy
        self._fifo: collections.deque = collections.deque()
        self._heap: list = []
        self._seq = itertools.count()

    def submit(self, req: Request, now: float | None = None) -> Request:
        req.t_submit = time.monotonic() if now is None else now
        if self.policy == "fifo":
            self._fifo.append(req)
        else:  # sjf: stable tie-break on arrival order
            heapq.heappush(self._heap, (req.prompt_len, next(self._seq), req))
        return req

    def pop(self) -> Request | None:
        if self.policy == "fifo":
            return self._fifo.popleft() if self._fifo else None
        if self._heap:
            return heapq.heappop(self._heap)[2]
        return None

    def __len__(self) -> int:
        return len(self._fifo) + len(self._heap)

    def __bool__(self) -> bool:
        return len(self) > 0
