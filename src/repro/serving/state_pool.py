"""Preallocated per-lane recurrent-state slab with jitted masked reset.

A serving engine keeps B decode lanes alive for the whole process; each
lane's recurrent state (LSTM h/c — or a KV cache for attention models)
lives at a fixed batch index of one preallocated pytree of device arrays.
Re-arming a lane with a new request must zero exactly that lane's slices
without host round trips or disturbing its neighbours: ``masked_reset`` is
a pure tree_map the engine calls *inside* its jitted step so the zeroing
fuses with the step itself (generalizing the inline tree_map the old
launch/serve.py script hard-coded).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import fp8

__all__ = ["StatePool", "masked_reset"]


def masked_reset(caches: Any, mask: jax.Array) -> Any:
    """Zero lane b of every lane-major leaf where mask[b] != 0. jit-safe.

    Leaves whose leading dim is not the lane count (scalar position
    counters, layer-major stacks in some KV cache layouts) are passed
    through untouched — they are shared across lanes and cannot be reset
    per-lane; models relying on such leaves only get lockstep (chunk=1)
    serving from the engine.
    """
    mask = jnp.asarray(mask)
    lanes = mask.shape[0]

    def _z(c):
        if c.ndim == 0 or c.shape[0] != lanes:
            return c
        keep = (mask == 0).reshape((lanes,) + (1,) * (c.ndim - 1))
        return jnp.where(keep, c, jnp.zeros_like(c))

    return jax.tree_util.tree_map(_z, caches)


_jit_masked_reset = jax.jit(masked_reset)


class StatePool:
    """Owns the lane-state pytree and its lifecycle (allocate/reset/swap)."""

    def __init__(self, caches: Any, lanes: int):
        self.caches = caches
        self.lanes = lanes

    @classmethod
    def for_model(cls, model, lanes: int, policy=None, cache_len: int | None = None):
        """Allocate via the model's init_cache. LSTM-family models take the
        policy (state dtypes follow it); attention models take a max
        sequence length for their KV slab."""
        if cache_len is not None:
            caches = model.init_cache(lanes, cache_len)
        else:
            caches = model.init_cache(lanes, policy)
        return cls(caches, lanes)

    def reset(self, mask) -> None:
        """Eager (host-initiated) masked reset; the engine normally folds
        this into its jitted step instead."""
        self.caches = _jit_masked_reset(self.caches, jnp.asarray(mask))

    # -- per-lane snapshot I/O (the prefix-cache hooks) ------------------
    # Both assume every leaf is lane-major (leading dim == lanes) — the
    # same invariant the engine's `_rearmable` check establishes before it
    # enables continuous batching or prefix caching.

    def extract(self, lane: int) -> Any:
        """Lane `lane`'s state slices as a pytree of [leaf_shape[1:]]
        arrays (a constant-size summary of everything the lane consumed —
        the object the frontend's prefix cache stores)."""
        return jax.tree_util.tree_map(lambda c: c[lane], self.caches)

    def inject(self, lane: int, snapshot: Any) -> None:
        """Overwrite lane `lane`'s slice of every leaf with `snapshot`
        (same treedef as one extract()ed lane). Replaces ALL of the lane's
        state, so an injected lane must NOT also be masked-reset — the
        reset would zero the injection."""
        if not 0 <= lane < self.lanes:
            raise ValueError(f"inject: lane {lane} out of range [0, {self.lanes})")

        def _set(c, s):
            s = jnp.asarray(s)
            # A stale or damaged snapshot (config change, cache entry from
            # an older topology) must fail here with the shapes named, not
            # broadcast silently or die as an opaque XLA error mid-step.
            if s.shape != c.shape[1:]:
                raise ValueError(
                    f"inject: snapshot leaf shape {s.shape} does not match "
                    f"lane state shape {c.shape[1:]} (pool leaf {c.shape})"
                )
            return c.at[lane].set(s.astype(c.dtype))

        self.caches = jax.tree_util.tree_map(_set, self.caches, snapshot)

    def snapshot_fp8(self, lane: int, dtype=fp8.FP8_E4M3) -> tuple[Any, Any]:
        """Host-side FP8 copy of lane `lane`'s state plus the original leaf
        dtypes — the same storage format (and therefore the same saturating
        2^-4 relative-rounding bound) the frontend prefix cache uses for
        its entries. This is the engine's preemption snapshot: cheap to
        hold on the host, restored with ``inject_fp8``."""
        states = self.extract(lane)
        snap = jax.tree_util.tree_map(
            lambda x: np.asarray(fp8.cast_fp8(jnp.asarray(x), dtype)), states
        )
        dtypes = jax.tree_util.tree_map(lambda x: jnp.asarray(x).dtype, states)
        return snap, dtypes

    def inject_fp8(self, lane: int, snapshot: Any, dtypes: Any) -> None:
        """Dequantize a ``snapshot_fp8`` pytree back to the pool dtypes and
        overwrite lane `lane` (same no-masked-reset caveat as ``inject``)."""
        self.inject(
            lane,
            jax.tree_util.tree_map(
                lambda q, dt: jnp.asarray(q).astype(dt), snapshot, dtypes
            ),
        )

    def swap(self, new_caches: Any) -> None:
        """Install the post-step state (called once per engine step)."""
        self.caches = new_caches
