"""repro.serving.frontend — multi-tenant request routing with an FP8
LSTM-state prefix cache.

The batching engine (serving/engine.py) turns requests into device steps;
this package turns *traffic* into requests: bounded-queue admission with
deadline awareness, least-loaded dispatch across engine replicas,
streaming token callbacks, per-tenant accounting, and a shared prefix
cache that stores per-layer (h, c) snapshots in FP8 so repeated prompt
prefixes skip their prefill. See serving/README.md §Frontend.
"""
from .prefix_cache import CacheEntry, CacheHit, PrefixCache
from .router import AsyncRouter, RequestRejected, Router, Ticket

__all__ = [
    "PrefixCache", "CacheEntry", "CacheHit",
    "Router", "AsyncRouter", "Ticket", "RequestRejected",
]
