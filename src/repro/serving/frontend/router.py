"""Multi-tenant async request router over ServeEngine replicas.

The router is the traffic layer the ROADMAP's "millions of users" story
needs above the batching engine: requests arrive asynchronously, are
admitted through a bounded queue under a deadline-aware policy, dispatched
to the least-loaded engine replica, streamed back token by token, and
accounted per tenant. All engine replicas share one FP8 LSTM-state prefix
cache (see prefix_cache.py), so a prefix warmed by any replica accelerates
every replica.

Lifecycle of a submission:

  submit(prompt, tenant, deadline, on_token)
        │  validation / backpressure: reject-with-reason
        │  ("queue_full" | "tenant_quota" | "bad_request"), never raises
        ▼
  [bounded router queue]  — Scheduler policy: fifo | sjf | edf
        │  _dispatch(): expired deadlines rejected ("deadline_expired"),
        │  otherwise enqueued on the least-loaded replica with a free lane
        ▼
  engine replica: prefix-cache admission → chunked prefill → decode
        │  pump() advances every replica one batched step and delivers
        │  new tokens to each ticket's on_token callback
        ▼
  ticket.status == "done"  (tokens in ticket.tokens)

Cancellation: ``cancel(rid)`` (or an abandoned stream / a deadline that
expires mid-flight, both detected at the top of ``pump()``) removes the
request wherever it lives — router queue, engine queue, or a bound lane
(``ServeEngine.cancel`` folds the lane release into the step's reset
mask) — and flips the ticket to "cancelled" with a reason.

``Router.pump()`` is non-blocking-style single-stepping (drive it from any
event loop); ``drain()`` runs to completion; ``AsyncRouter`` wraps the
pump in asyncio for genuinely concurrent ``await generate(...)`` /
``async for tok in stream(...)`` clients.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from ...faults import FAULTS, ReplicaCrash
from ...kernels import dispatch as kernel_dispatch
from ...obs.trace import TRACER
from ..engine import ServeEngine
from ..metrics import LatencyHistogram, phase_summary, tenant_summary
from ..scheduler import Request, Scheduler

__all__ = ["Ticket", "Router", "AsyncRouter", "RequestRejected"]

REJECT_REASONS = (
    "queue_full", "tenant_quota", "bad_request", "deadline_expired",
    "no_healthy_replicas",
)


class RequestRejected(RuntimeError):
    """Raised by the asyncio streaming facade when admission rejects a
    submission; carries the rejected Ticket so callers (e.g. the HTTP
    layer) can map ``ticket.reason`` to a wire-level error."""

    def __init__(self, ticket: "Ticket"):
        super().__init__(f"request rejected: {ticket.reason}")
        self.ticket = ticket


@dataclasses.dataclass
class Ticket:
    """Caller-facing handle for one submission."""

    rid: int
    tenant: str
    # "queued" | "running" | "done" | "rejected" | "cancelled"
    # | "numeric_error" (the engine's nonfinite-logit guard retired it:
    # partial tokens are valid, the poisoned lane state was reset)
    status: str
    reason: Optional[str] = None  # set iff rejected/cancelled/numeric_error
    req: Optional[Request] = None
    on_token: Optional[Callable[[int], None]] = None
    sent: int = 0  # tokens already delivered to on_token
    t_done: Optional[float] = None
    abandoned: bool = False  # consumer gone: stop driving on its behalf

    @property
    def tokens(self) -> list:
        return list(self.req.out) if self.req is not None else []

    @property
    def ok(self) -> bool:
        return self.status != "rejected"


class Router:
    """Multi-tenant admission + dispatch over ServeEngine replicas.

    Lifecycle per submission: ``submit`` (non-blocking, reject-with-reason
    under backpressure) → bounded Scheduler queue → ``_dispatch`` to the
    least-loaded replica with a free lane → engine admission (prefix-cache
    lookup → ``StatePool.inject`` → chunked prefill from the match point)
    → per-token delivery via ``_deliver`` → ticket ``done``.

    Concurrency contract: the Router is **not thread-safe** and performs
    no internal locking. ``submit``/``pump``/``drain``/``report`` must be
    called from one thread at a time — either a single-threaded driver
    (the CLI's ``drain()`` loop) or externally serialized, which is
    exactly what ``AsyncRouter`` provides (one asyncio lock around every
    mutation, pumps executed in a worker thread while holding it).
    ``pump()`` itself never blocks on the network; one call is one
    scheduling round (dispatch + one batched device step per busy replica
    + token delivery), so drivers control latency/throughput trade-offs
    by how often they pump.
    """

    def __init__(
        self,
        engines: Sequence[ServeEngine],
        max_queue: int = 64,
        admission: str = "edf",
        tenant_quota: Optional[int] = None,
        drop_expired: bool = True,
        eject_after: int = 3,
        probe_every: int = 8,
    ):
        if not engines:
            raise ValueError("Router needs at least one engine replica")
        self.engines = list(engines)
        self.max_queue = max_queue
        self.tenant_quota = tenant_quota
        self.drop_expired = drop_expired
        self._queue = Scheduler(admission)
        self._queued_by_tenant: dict[str, int] = {}
        self._tickets: dict[int, Ticket] = {}
        self._inflight: dict[int, Ticket] = {}  # queued or running
        self._rid = 0
        self.tenants: dict[str, dict] = {}  # per-tenant accounting
        self.rejections: dict[str, int] = {}
        # post-admission terminations by reason:
        # "client_cancel" (explicit cancel/DELETE), "abandoned"
        # (streaming consumer disconnected), "deadline_expired" (mid-flight)
        self.cancellations: dict[str, int] = {}
        # -- per-replica health -------------------------------------------
        # A replica is ejected after `eject_after` consecutive step
        # failures (immediately on ReplicaCrash); ejected replicas are
        # probed every `probe_every` pumps and reinstated when a probe
        # step succeeds. Its live requests are resubmitted to the healthy
        # pool with their original t_submit (honest latency accounting)
        # and deduplicated delivery via each ticket's `sent` cursor.
        self.eject_after = eject_after
        self.probe_every = probe_every
        self._health = [
            {"healthy": True, "consecutive_failures": 0,
             "pumps_since_probe": 0, "last_error": None}
            for _ in self.engines
        ]
        self.ejections = 0
        self.reinstatements = 0
        self.resubmits = 0
        self.retries = 0  # admission retries noted by the HTTP layer
        for i, e in enumerate(self.engines):
            e.replica = i  # fault-rule / trace identity
            if e.metrics.t_start is None:
                e.metrics.start()

    @classmethod
    def build(
        cls,
        model,
        params,
        policy,
        replicas: int = 1,
        prefix_cache=None,
        router_kw: Optional[dict] = None,
        **engine_kw,
    ) -> "Router":
        """Convenience: `replicas` ServeEngines sharing one prefix cache."""
        engines = [
            ServeEngine(model, params, policy, prefix_cache=prefix_cache, **engine_kw)
            for _ in range(replicas)
        ]
        return cls(engines, **(router_kw or {}))

    # -- intake ----------------------------------------------------------
    def _tenant(self, name: str) -> dict:
        return self.tenants.setdefault(
            name,
            {"submitted": 0, "rejected": 0, "completed": 0, "tokens": 0},
        )

    def _reject(self, ticket: Ticket, reason: str) -> Ticket:
        ticket.status = "rejected"
        ticket.reason = reason
        self._tenant(ticket.tenant)["rejected"] += 1
        self.rejections[reason] = self.rejections.get(reason, 0) + 1
        self._inflight.pop(ticket.rid, None)
        self._tickets.pop(ticket.rid, None)  # caller holds the Ticket
        return ticket

    def submit(
        self,
        prompt,
        max_new: int = 32,
        tenant: str = "default",
        deadline: Optional[float] = None,
        on_token: Optional[Callable[[int], None]] = None,
    ) -> Ticket:
        """Non-blocking admission. Always returns a Ticket; overload and
        malformed input reject with a reason instead of raising."""
        rid = self._rid
        self._rid += 1
        ticket = Ticket(rid=rid, tenant=tenant, status="queued", on_token=on_token)
        self._tickets[rid] = ticket
        self._tenant(tenant)["submitted"] += 1
        if (
            self.drop_expired
            and deadline is not None
            and time.monotonic() > deadline
        ):
            return self._reject(ticket, "deadline_expired")  # dead on arrival
        if not any(h["healthy"] for h in self._health):
            # circuit breaker: every replica is ejected — fail fast with a
            # distinct reason instead of queueing work nobody can serve
            # (retry-with-backoff upstream is only worth it while at least
            # one healthy replica remains)
            return self._reject(ticket, "no_healthy_replicas")
        if len(self._queue) >= self.max_queue:
            # before bouncing a serviceable request, drop queued work whose
            # deadline already passed — under saturation the backlog is
            # where requests expire, and dead work must not hold the slots
            # that backpressure is rationing
            self._purge_expired()
        if len(self._queue) >= self.max_queue:
            return self._reject(ticket, "queue_full")
        if (
            self.tenant_quota is not None
            and self._queued_by_tenant.get(tenant, 0) >= self.tenant_quota
        ):
            return self._reject(ticket, "tenant_quota")
        try:
            req = Request(
                rid=rid,
                prompt=np.asarray(prompt),
                max_new=max_new,
                tenant=tenant,
                deadline=deadline,
            )
        except (ValueError, TypeError):
            return self._reject(ticket, "bad_request")
        ticket.req = req
        if self.prefix_cache is not None:
            # what the "sjf_work" policy sorts on: the cached prefix makes
            # remaining work knowable at admission time. Non-mutating probe
            # — queue inspection must not warm the cache LRU.
            req.work_hint = self.prefix_cache.match_len(req.prompt)
        self._queue.submit(req)
        self._queued_by_tenant[tenant] = self._queued_by_tenant.get(tenant, 0) + 1
        self._inflight[rid] = ticket
        if TRACER.enabled:
            TRACER.instant(
                "router.submit", cat="router", rid=rid, tenant=tenant,
                queued=len(self._queue),
            )
        return ticket

    # -- dispatch / progress ---------------------------------------------
    def _purge_expired(self) -> None:
        """Drop queued requests whose deadline has passed (reject with
        "deadline_expired"). O(queue), so only called when the queue is
        actually under pressure."""
        if not self.drop_expired:
            return
        now = time.monotonic()
        keep = []
        while self._queue:
            req = self._queue.pop()
            self._queued_by_tenant[req.tenant] -= 1
            if req.deadline is not None and now > req.deadline:
                self._reject(self._tickets[req.rid], "deadline_expired")
            else:
                keep.append(req)
        for req in keep:  # re-submit preserves t_submit and policy order
            self._queue.submit(req)
            self._queued_by_tenant[req.tenant] += 1

    def _dispatch(self) -> None:
        while self._queue:
            # An engine can absorb at most free_lanes requests before its
            # next step arms them; past that, handing it more would just
            # move the backlog into its internal FIFO — where the router's
            # admission policy, deadline dropping, and max_queue
            # backpressure no longer apply. Keep the excess here.
            free = [
                e for i, e in enumerate(self.engines)
                if self._health[i]["healthy"]
                and e.free_lanes > len(e.scheduler)
            ]
            if not free:
                return
            req = self._queue.pop()
            self._queued_by_tenant[req.tenant] -= 1
            ticket = self._tickets[req.rid]
            if (
                self.drop_expired
                and req.deadline is not None
                and time.monotonic() > req.deadline
            ):
                self._reject(ticket, "deadline_expired")
                continue
            # least-loaded balancing; ties go to the lowest replica index
            eng = min(free, key=lambda e: (e.load, self.engines.index(e)))
            eng.enqueue(req)
            ticket.status = "running"
            if TRACER.enabled:
                TRACER.instant(
                    "router.dispatch", cat="router", rid=req.rid,
                    replica=self.engines.index(eng),
                    queue_wait_ms=(
                        (time.monotonic() - req.t_submit) * 1e3
                        if req.t_submit is not None else 0.0
                    ),
                )

    # -- cancellation ----------------------------------------------------
    def cancel(self, rid: int, reason: str = "client_cancel") -> bool:
        """Terminally cancel an admitted request: queued at the router →
        scheduler removal; dispatched → ``ServeEngine.cancel`` (scheduler
        removal or masked lane release). Idempotent — unknown, finished,
        or rejected rids return False. The ticket flips to "cancelled"
        with the reason, and whatever tokens were already generated stay
        readable on it."""
        ticket = self._tickets.get(rid)
        if ticket is None or ticket.status in (
            "done", "rejected", "cancelled", "numeric_error"
        ):
            return False
        if ticket.status == "queued":
            req = self._queue.remove(rid)
            if req is None:
                return False  # submit raced a pump; next pump settles it
            self._queued_by_tenant[req.tenant] -= 1
            req.status = "cancelled"
            req.cancel_reason = reason
        elif not any(e.cancel(rid, reason=reason) for e in self.engines):
            return False  # retired this very pump round; ticket flips in _deliver
        ticket.status = "cancelled"
        ticket.reason = reason
        ticket.t_done = time.monotonic()
        ticket.on_token = None  # no more deliveries to a dead consumer
        acct = self._tenant(ticket.tenant)
        acct["cancelled"] = acct.get("cancelled", 0) + 1
        self.cancellations[reason] = self.cancellations.get(reason, 0) + 1
        self._inflight.pop(rid, None)
        self._tickets.pop(rid, None)  # caller holds the Ticket
        if TRACER.enabled:
            TRACER.instant(
                "router.cancel", cat="router", rid=rid, reason=reason,
            )
        return True

    def _cancel_stale(self) -> None:
        """Cancel in-flight work nobody can use anymore: abandoned tickets
        (the streaming consumer disconnected — before this existed they
        decoded to ``max_new`` on a lane nobody was reading) and running
        requests whose deadline expired after lane binding (deadlines were
        previously only enforced at submit and dispatch). Queued tickets
        with expired deadlines keep the established reject path in
        ``_dispatch``/``_purge_expired``."""
        now = time.monotonic()
        for ticket in list(self._inflight.values()):
            if ticket.abandoned:
                self.cancel(ticket.rid, reason="abandoned")
            elif (
                self.drop_expired
                and ticket.status == "running"
                and ticket.req is not None
                and ticket.req.deadline is not None
                and now > ticket.req.deadline
            ):
                self.cancel(ticket.rid, reason="deadline_expired")

    def _deliver(self) -> None:
        for ticket in list(self._inflight.values()):
            req = ticket.req
            if ticket.abandoned:
                # consumer is gone: feeding its queue would grow it
                # unbounded (the ticket itself is cancelled next pump)
                ticket.on_token = None
            if len(req.out) > ticket.sent:
                if ticket.on_token is not None:
                    for tok in req.out[ticket.sent :]:
                        ticket.on_token(tok)
                ticket.sent = len(req.out)
            if req.status == "numeric_error":
                # the engine's nonfinite guard retired it terminally; the
                # tokens generated BEFORE the poisoned step were delivered
                # above and stay valid (never the NaN-argmax token itself)
                ticket.status = "numeric_error"
                ticket.reason = req.cancel_reason or "nonfinite_logits"
                ticket.t_done = time.monotonic()
                acct = self._tenant(ticket.tenant)
                acct["numeric_error"] = acct.get("numeric_error", 0) + 1
                del self._inflight[ticket.rid]
                self._tickets.pop(ticket.rid, None)
            elif req.done:
                ticket.status = "done"
                ticket.t_done = time.monotonic()
                acct = self._tenant(ticket.tenant)
                acct["completed"] += 1
                acct["tokens"] += len(req.out)
                del self._inflight[ticket.rid]
                # drop our reference: a long-lived router must not retain
                # every finished request's tokens (the caller has the
                # Ticket; aggregates live in self.tenants / engine metrics)
                self._tickets.pop(ticket.rid, None)

    # -- replica health --------------------------------------------------
    @property
    def healthy_replicas(self) -> int:
        return sum(h["healthy"] for h in self._health)

    def _eject(self, i: int, reason: str) -> None:
        """Take replica ``i`` out of rotation and move its live requests
        (engine queue, bound lanes, preempted stash) back into the router
        queue for redispatch to the healthy pool. Resubmission preserves
        ``t_submit``/``t_first`` (latency stays honest) and relies on each
        ticket's ``sent`` cursor for idempotent delivery: greedy decode is
        deterministic, so a healthy replica regenerates the identical
        stream and already-delivered tokens are skipped."""
        h = self._health[i]
        h["healthy"] = False
        h["pumps_since_probe"] = 0
        self.ejections += 1
        if TRACER.enabled:
            TRACER.instant(
                "router.eject", cat="router", replica=i, reason=reason,
            )
        for req in self.engines[i].evacuate():
            ticket = self._tickets.get(req.rid)
            if ticket is None or ticket.status in (
                "done", "rejected", "cancelled", "numeric_error"
            ):
                continue
            self._queue.submit(req)  # t_submit preserved by the scheduler
            self._queued_by_tenant[req.tenant] = (
                self._queued_by_tenant.get(req.tenant, 0) + 1
            )
            ticket.status = "queued"
            self.resubmits += 1
            if TRACER.enabled:
                TRACER.instant(
                    "router.resubmit", cat="router", rid=req.rid,
                    replica=i, delivered=ticket.sent,
                )

    def _on_step_failure(self, i: int, exc: Exception) -> None:
        h = self._health[i]
        h["last_error"] = f"{type(exc).__name__}: {exc}"
        if isinstance(exc, ReplicaCrash):
            self._eject(i, reason="crash")
            return
        h["consecutive_failures"] += 1
        if h["consecutive_failures"] >= self.eject_after:
            self._eject(i, reason="consecutive_failures")

    def _maybe_probe(self, i: int) -> None:
        """Every ``probe_every`` pumps, try one (empty) step on an ejected
        replica; a clean return reinstates it. A crashed replica keeps
        raising and stays out of rotation."""
        h = self._health[i]
        h["pumps_since_probe"] += 1
        if h["pumps_since_probe"] < self.probe_every:
            return
        h["pumps_since_probe"] = 0
        try:
            self.engines[i].step_once()  # evacuated: probes the step path
        except Exception as exc:  # noqa: BLE001 - any failure keeps it out
            h["last_error"] = f"{type(exc).__name__}: {exc}"
            return
        h["healthy"] = True
        h["consecutive_failures"] = 0
        h["last_error"] = None
        self.reinstatements += 1
        if TRACER.enabled:
            TRACER.instant("router.reinstate", cat="router", replica=i)

    def pump(self) -> bool:
        """One scheduling round: dispatch queued work, advance every busy
        healthy replica one batched step, deliver new tokens, probe
        ejected replicas. Returns True while there is anything left to
        do."""
        with TRACER.span("router.pump", cat="router"):
            self._cancel_stale()
            if not any(h["healthy"] for h in self._health) and self._queue:
                # total outage: the breaker is open — bounce the backlog
                # with the distinct reason instead of holding requests
                # (and drain() loops) hostage to a probe that may never
                # succeed. New submissions are already rejected at intake.
                while self._queue:
                    req = self._queue.pop()
                    self._queued_by_tenant[req.tenant] -= 1
                    self._reject(self._tickets[req.rid],
                                 "no_healthy_replicas")
            self._dispatch()
            progressed = False
            for i, e in enumerate(self.engines):
                if not self._health[i]["healthy"]:
                    self._maybe_probe(i)
                    progressed = progressed or self._health[i]["healthy"]
                    continue
                if e.has_work():
                    try:
                        progressed = e.step_once() or progressed
                        self._health[i]["consecutive_failures"] = 0
                    except Exception as exc:  # noqa: BLE001 - health layer
                        # A replica failure must never take the router
                        # down: record it, maybe eject, and let the
                        # resubmitted work land on the healthy pool.
                        self._on_step_failure(i, exc)
                        progressed = True  # health state advanced
            self._deliver()
            if TRACER.enabled:
                # predicted-cost counter tracks (cost.<op>) alongside the
                # pump spans, so the trace viewer shows analytical
                # FLOPs/bytes accumulating against wall time
                kernel_dispatch.LEDGER.emit_counters(TRACER)
        return progressed or bool(self._queue) or bool(self._inflight)

    def drain(self) -> None:
        """Run to completion (the synchronous batch entry point)."""
        while self.pump():
            pass
        for e in self.engines:
            e.metrics.stop()

    def note_retry(self) -> None:
        """Count one admission retry performed by an upstream layer (the
        HTTP server's backoff loop) — surfaced as ``repro_retries_total``."""
        self.retries += 1

    # -- reporting -------------------------------------------------------
    @property
    def idle(self) -> bool:
        """True when nothing is queued or in flight — the drain condition
        the HTTP layer's /admin/drain waits on."""
        return not self._queue and not self._inflight

    @property
    def prefix_cache(self):
        """The prefix cache shared by every replica (or None). All
        replicas are built over one cache, so the first engine's is THE
        cache — surfaced for /metrics scrapes."""
        return self.engines[0].prefix_cache

    def stats(self) -> dict:
        """Cheap liveness snapshot (no percentile math, no record scans)
        for health endpoints: replica/lane capacity, backlog, in-flight
        count, and rejection counters."""
        return {
            "replicas": len(self.engines),
            "healthy_replicas": self.healthy_replicas,
            "lanes": sum(e.lanes_n for e in self.engines),
            "free_lanes": sum(e.free_lanes for e in self.engines),
            "queued": len(self._queue),
            "inflight": len(self._inflight),
            "tenants": len(self.tenants),
            "rejections": dict(self.rejections),
            "cancellations": dict(self.cancellations),
            "ejections": self.ejections,
            "reinstatements": self.reinstatements,
            "resubmits": self.resubmits,
            "retries": self.retries,
            "replica_health": [
                {
                    "replica": i,
                    "healthy": h["healthy"],
                    "consecutive_failures": h["consecutive_failures"],
                    "last_error": h["last_error"],
                }
                for i, h in enumerate(self._health)
            ],
            "faults": FAULTS.stats(),
        }

    def report(self) -> dict:
        """Aggregate across replicas + router-level accounting."""
        reps = [e.metrics.report() for e in self.engines]
        records = [r for e in self.engines for r in e.metrics.records]
        summed = {
            k: sum(r[k] for r in reps)
            for k in (
                "requests", "steps", "prefill_steps", "decode_steps",
                "emitted_tokens", "prompt_tokens", "cache_lookups",
                "cache_hits", "cache_full_hits", "prefill_tokens_saved",
                "cancelled", "preemptions", "resumes", "numeric_errors",
            )
        }
        summed["cancellations"] = dict(self.cancellations)
        summed["ejections"] = self.ejections
        summed["reinstatements"] = self.reinstatements
        summed["resubmits"] = self.resubmits
        summed["retries"] = self.retries
        summed["healthy_replicas"] = self.healthy_replicas
        summed["faults_injected"] = dict(FAULTS.injected)
        summed["cache_hit_rate"] = (
            summed["cache_hits"] / summed["cache_lookups"]
            if summed["cache_lookups"]
            else 0.0
        )
        ttfts = np.array([r.ttft for r in records])
        summed["ttft_mean_s"] = float(ttfts.mean()) if ttfts.size else 0.0
        summed["ttft_p95_s"] = (
            float(np.percentile(ttfts, 95)) if ttfts.size else 0.0
        )
        summed["replicas"] = len(self.engines)
        summed["queued"] = len(self._queue)
        summed["rejections"] = dict(self.rejections)
        percentiles = tenant_summary(records)  # one pass groups all tenants
        summed["tenants"] = {
            t: {**acct, **percentiles.get(t, {})}
            for t, acct in sorted(self.tenants.items())
        }
        summed["phases"] = phase_summary(records)
        # cumulative histograms sum elementwise across replicas (identical
        # bucket bounds), staying monotonic for Prometheus `le` series
        summed["ttft_hist_ms"] = LatencyHistogram.merge_reports(
            r.get("ttft_hist_ms") for r in reps
        )
        summed["tpot_hist_ms"] = LatencyHistogram.merge_reports(
            r.get("tpot_hist_ms") for r in reps
        )
        return summed

    def scrape(self) -> dict:
        """Everything a /metrics scrape reads, in one call: the aggregate
        report, the cheap liveness stats, and the shared prefix cache's
        stats. Like ``report``/``stats``, this iterates live collections
        (tenant dicts, metric record windows, the cache's LRU bookkeeping)
        and is therefore only safe while no pump is mutating them — HTTP
        scrape paths MUST call it through ``AsyncRouter.snapshot``.
        Bundling the three reads keeps every scrape consumer behind that
        single locked entry point instead of re-assembling the pieces
        (and forgetting the lock on one of them)."""
        cache = self.prefix_cache
        return {
            "report": self.report(),
            "stats": self.stats(),
            "cache": cache.stats() if cache is not None else None,
            # predicted-vs-measured kernel cost rows (process-global
            # dispatch ledger — the kernels this router's replicas ran)
            "cost": kernel_dispatch.LEDGER.rows(),
        }


class AsyncRouter:
    """asyncio facade: concurrent coroutines share one pump (serialized by
    a lock, executed off-loop in a worker thread so the event loop stays
    responsive while the device steps).

    The Router itself is NOT thread-safe; every mutation — submissions
    included — must happen under ``self._lock`` so a submit on the event
    loop can never interleave with a pump running in the worker thread
    (heapq operations are multi-step and would corrupt the queue)."""

    def __init__(self, router: Router):
        self.router = router
        self._lock = asyncio.Lock()

    async def _pump_once(self) -> None:
        """One pump in a worker thread. Caller MUST hold ``self._lock``."""
        fut = asyncio.ensure_future(asyncio.to_thread(self.router.pump))
        try:
            await asyncio.shield(fut)
        except asyncio.CancelledError:
            # cancelled (e.g. the caller cancelled generate()): the pump
            # thread is still mutating the router — wait for it before the
            # lock is released, THEN propagate
            await fut
            raise

    async def _drive(self, ticket: Ticket) -> Ticket:
        # NOT cancelled from outside: a cancel while the pump thread runs
        # would release the lock mid-pump and let a concurrent submit race
        # it. Early consumers set ticket.abandoned instead, bounding the
        # wait at one pump (one batched engine step), after which the loop
        # exits between pumps.
        terminal = ("done", "rejected", "cancelled", "numeric_error")
        while ticket.status not in terminal and not ticket.abandoned:
            async with self._lock:
                if ticket.status in terminal or ticket.abandoned:
                    break
                await self._pump_once()
        return ticket

    async def cancel(self, rid: int, reason: str = "client_cancel") -> bool:
        """Cancel an in-flight request by rid (the DELETE endpoint's
        backend). Serialized with pumps under the router lock, so the lane
        is released between batched steps — within one step of the
        request's next scheduling round."""
        async with self._lock:
            return self.router.cancel(rid, reason=reason)

    async def snapshot(self, fn):
        """Run ``fn(router)`` under the pump lock and return its result —
        the safe way to read aggregate state (``report()``/``stats()``)
        while pumps execute in a worker thread: iterating the tenant /
        record collections concurrently with a mutating pump is a data
        race. Keep ``fn`` host-side and cheap; it delays the next pump."""
        async with self._lock:
            return fn(self.router)

    async def join(self) -> None:
        """Pump until the router is fully idle (nothing queued, nothing in
        flight). The drain primitive: /admin/drain stops admission at the
        HTTP layer, then ``join()`` finishes every admitted request —
        including tickets whose streaming consumer disconnected and
        abandoned them."""
        while not self.router.idle:
            async with self._lock:
                if self.router.idle:
                    break
                await self._pump_once()

    async def generate(self, prompt, **kw) -> Ticket:
        """Submit and await completion; returns the finished Ticket (check
        ``ticket.ok`` / ``ticket.reason`` for rejection)."""
        async with self._lock:
            ticket = self.router.submit(prompt, **kw)
        if ticket.status == "rejected":
            return ticket
        return await self._drive(ticket)

    async def open_stream(self, prompt, **kw):
        """Submit for streaming; returns ``(ticket, token_iterator)``.

        On rejection the iterator is ``None`` and the ticket carries the
        reason — no exception, so protocol frontends can map the reason to
        a wire-level status *before* committing to a streaming response.
        The iterator (when present) yields tokens as they are produced and
        must be fully consumed or ``aclose()``d.
        """
        loop = asyncio.get_running_loop()
        q: asyncio.Queue = asyncio.Queue()
        done = object()  # completion sentinel: no polling, no tail latency
        async with self._lock:
            ticket = self.router.submit(
                prompt,
                on_token=lambda tok: loop.call_soon_threadsafe(q.put_nowait, tok),
                **kw,
            )
        if ticket.status == "rejected":
            return ticket, None

        async def tokens():
            async def drive():
                try:
                    await self._drive(ticket)
                finally:
                    # runs on the event loop AFTER any pending token
                    # callbacks scheduled from the pump thread (loop
                    # callbacks are FIFO)
                    q.put_nowait(done)

            task = asyncio.create_task(drive())
            try:
                while (tok := await q.get()) is not done:
                    yield tok
            finally:
                ticket.abandoned = True
                await task

        return ticket, tokens()

    async def stream(self, prompt, **kw):
        """Async generator of tokens as they are produced. Raises
        ``RequestRejected`` (carrying the ticket) on admission rejection.

        If the consumer exits early (break / connection drop), the ticket
        is marked abandoned: this coroutine stops driving it within one
        pump, and the next pump from any source cancels it inside the
        engine (``_cancel_stale`` → ``ServeEngine.cancel``), freeing its
        lane instead of decoding to ``max_new`` for nobody.
        """
        ticket, toks = await self.open_stream(prompt, **kw)
        if toks is None:
            raise RequestRejected(ticket)
        try:
            async for tok in toks:
                yield tok
        finally:
            # `async for` does not close a half-consumed inner generator on
            # early exit; closing it here is what flips ticket.abandoned
            await toks.aclose()
