"""FP8 LSTM-state prefix cache: token-trie keyed (h, c) snapshots.

The recurrent formulation gives LSTM serving a property transformer KV
caches lack: the per-layer ``(h, c)`` state after consuming a prefix is a
**constant-size** summary of that entire prefix. Caching it makes
repeated-prefix prefill free — inject the snapshot and start at the match
point — with a footprint of O(layers * hidden) bytes per entry instead of
O(prefix_len * layers * hidden).

Storage format. Snapshots are quantized to real FP8 arrays on insert
(``core.fp8.cast_fp8``, saturating) and dequantized back to the state
pool's dtypes on hit. Shin et al. and Ott et al. (PAPERS.md) show LSTM
states tolerate aggressive quantization; e4m3 (default) carries a 3-bit
mantissa, so each stored component has relative rounding error <= 2^-4
(6.25%), and the recurrent gates (sigmoid-bounded, forget-decayed)
contract the perturbation as decoding proceeds. Exactness where it
matters: a *full* hit replays the stored ``next_token`` — recorded from
the unperturbed run — so a fully cached prompt's first token is exact and
its TTFT is zero device steps.

Keying. Entries live in a token trie; ``lookup(tokens)`` walks the query
and returns the deepest stored snapshot whose key is a proper prefix of
the query (or the whole query, if that entry carries a ``next_token``).
Entries are inserted at three kinds of positions:
  * block boundaries during prefill (``wants_snapshot``: every ``block``
    tokens, only where the trie has no entry yet) — these are what make
    *shared-system-prompt* workloads hit, since two prompts sharing a
    prefix diverge at arbitrary points but agree on block boundaries
    below their divergence;
  * end of prefill (key = the whole prompt, with the first generated
    token as ``next_token``);
  * retire (key = prompt + generated[:-1], ``next_token`` = last
    generated token) — serves "continue this conversation" resubmissions.

Eviction is LRU under ``budget_bytes`` (the FP8 payload bytes); lookup
refreshes recency. The cache is a plain host-side object shared by every
engine replica behind a router.
"""
from __future__ import annotations

import collections
import dataclasses
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...core import fp8
from ...faults import CACHE_CORRUPT, FAULTS

__all__ = ["PrefixCache", "CacheEntry", "CacheHit", "entry_checksum"]


def entry_checksum(states_fp8, next_token: Optional[int]) -> int:
    """CRC32 over every stored FP8 leaf plus the continuation token.
    Entries are a few KB of host bytes, so this is cheap relative to the
    dequantize a hit pays anyway — and it is the only defense between a
    silently flipped bit and a poisoned lane injection."""
    crc = zlib.crc32(b"" if next_token is None else str(next_token).encode())
    for leaf in jax.tree_util.tree_leaves(states_fp8):
        crc = zlib.crc32(np.ascontiguousarray(leaf).view(np.uint8), crc)
    return crc


@dataclasses.dataclass
class CacheEntry:
    """One stored snapshot: FP8 state + the greedy continuation token."""

    key: tuple  # the token prefix this state summarizes (the LRU key)
    states_fp8: Any  # pytree of host fp8 arrays (same treedef as a lane)
    dtypes: Any  # pytree of original leaf dtypes (restored on hit)
    next_token: Optional[int]  # greedy argmax after this prefix, if known
    nbytes: int
    checksum: int = 0  # entry_checksum() at insert; verified on use

    @property
    def length(self) -> int:
        return len(self.key)


@dataclasses.dataclass(frozen=True)
class CacheHit:
    match_len: int
    states: Any  # dequantized pytree, ready for StatePool.inject
    next_token: Optional[int]  # set iff match_len == query length

    @property
    def full(self) -> bool:
        return self.next_token is not None


class _TrieNode:
    __slots__ = ("children", "entry")

    def __init__(self):
        self.children: dict[int, _TrieNode] = {}
        self.entry: Optional[CacheEntry] = None


class PrefixCache:
    """Token-trie keyed store of FP8 per-layer (h, c) snapshots, LRU under
    a byte budget. See the module docstring for keying/insertion/eviction
    semantics and the FP8 error bound.

    Concurrency contract: a plain host-side object with **no internal
    locking**. It is shared by every engine replica behind one Router,
    which is safe because all engine calls (admission lookups, insertions
    at prefill boundaries/retire) happen inside ``Router.pump()`` — and
    the Router serializes pumps (single-threaded driver or the
    AsyncRouter lock). Sharing one cache across *independently driven*
    routers or threads requires external locking. ``stats()`` reads plain
    counters and is safe anywhere.
    """

    def __init__(
        self,
        budget_bytes: int = 64 * 2**20,
        state_dtype=fp8.FP8_E4M3,
        block: int = 8,
    ):
        if block < 1:
            raise ValueError("block must be >= 1")
        self.budget_bytes = int(budget_bytes)
        self.state_dtype = state_dtype
        self.block = block
        self._root = _TrieNode()
        # LRU order: key tuple -> CacheEntry, oldest first
        self._lru: collections.OrderedDict = collections.OrderedDict()
        self.nbytes = 0
        self.hits = 0
        self.full_hits = 0
        self.misses = 0
        self.insertions = 0
        self.evictions = 0
        self.corruptions = 0  # checksum failures caught at lookup

    def __len__(self) -> int:
        return len(self._lru)

    # -- lookup ----------------------------------------------------------
    def lookup(self, tokens) -> Optional[CacheHit]:
        """Deepest usable snapshot for this prompt, or None.

        An entry at the *full* prompt length is usable only if it carries a
        ``next_token`` (there is no way to obtain the first generated token
        from a bare state without re-feeding a prompt token, which would
        corrupt the recurrence); otherwise the deepest strictly-shorter
        entry wins.
        """
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        node = self._root
        best: Optional[tuple[int, CacheEntry]] = None
        touched: list[CacheEntry] = []  # every entry on the matched path is hot
        depth = 0
        for t in toks:
            node = node.children.get(t)
            if node is None:
                break
            depth += 1
            e = node.entry
            if e is not None:
                touched.append(e)
                if depth < len(toks) or e.next_token is not None:
                    best = (depth, e)
        for e in touched:  # refresh recency even for unusable matches
            self._lru.move_to_end(e.key)
        if best is None:
            self.misses += 1
            return None
        match_len, entry = best
        if entry_checksum(entry.states_fp8, entry.next_token) != entry.checksum:
            # corrupt-as-miss: evict the damaged entry and report a miss —
            # injecting a bit-flipped state would silently corrupt every
            # token the lane goes on to decode. The shallower entries on
            # the path stay; the next identical lookup falls back to them.
            self.corruptions += 1
            self.misses += 1
            self._evict_key(entry.key)
            return None
        self.hits += 1
        full = match_len == len(toks)
        if full:
            self.full_hits += 1
        states = jax.tree_util.tree_map(
            lambda q, dt: jnp.asarray(q).astype(dt), entry.states_fp8, entry.dtypes
        )
        return CacheHit(
            match_len=match_len,
            states=states,
            next_token=entry.next_token if full else None,
        )

    def match_len(self, tokens) -> int:
        """Length of the prefix a ``lookup`` on these tokens would inject,
        with NO side effects: no hit/miss counters, no LRU refresh, no
        dequantization. This is the scheduler's remaining-work probe — the
        router calls it once per admission to stamp ``Request.work_hint``,
        and a probe that warmed the LRU would let queue *inspection*
        distort the eviction order that actual traffic earned."""
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        node = self._root
        best = 0
        depth = 0
        for t in toks:
            node = node.children.get(t)
            if node is None:
                break
            depth += 1
            e = node.entry
            if e is not None and (depth < len(toks) or e.next_token is not None):
                best = depth
        return best

    # -- insertion policy ------------------------------------------------
    def wants_snapshot(self, tokens, pos: int) -> bool:
        """Should the engine bother extracting a mid-prefill snapshot at
        position ``pos``? Block-aligned positions with no entry yet. Host
        trie walk only — cheap enough to call once per prefill chunk."""
        if pos < self.block or pos % self.block != 0:
            return False
        return self._entry_at(tokens, pos) is None

    def wants(self, tokens, pos: int) -> bool:
        """Like wants_snapshot but for semantic boundaries (end of prompt,
        retire) where any position is worth keeping — and where the caller
        knows the greedy continuation, so an existing next_token-less block
        snapshot at this key is worth upgrading (otherwise a prompt whose
        length coincides with a snapshotted block boundary could never gain
        the full-hit fast path)."""
        if pos < 1:
            return False
        e = self._entry_at(tokens, pos)
        return e is None or e.next_token is None

    def _entry_at(self, tokens, pos: int) -> Optional[CacheEntry]:
        node = self._root
        for t in np.asarray(tokens).reshape(-1)[:pos]:
            node = node.children.get(int(t))
            if node is None:
                return None
        return node.entry

    # -- insert / evict --------------------------------------------------
    def insert(self, tokens, states, next_token: Optional[int] = None) -> None:
        """Store the state reached after consuming ``tokens``.

        Quantizes to FP8 and copies to host immediately — the source arrays
        may alias the engine's donated state slab, which the next jitted
        step invalidates. Re-inserting an existing key refreshes it (and
        may upgrade a block snapshot with a ``next_token``).
        """
        toks = tuple(int(t) for t in np.asarray(tokens).reshape(-1))
        if not toks:
            return
        states_fp8 = jax.tree_util.tree_map(
            lambda x: np.asarray(fp8.cast_fp8(jnp.asarray(x), self.state_dtype)),
            states,
        )
        dtypes = jax.tree_util.tree_map(lambda x: jnp.asarray(x).dtype, states)
        nbytes = sum(
            a.nbytes for a in jax.tree_util.tree_leaves(states_fp8)
        ) + len(toks) * 4  # key tokens count against the budget too
        nt = None if next_token is None else int(next_token)
        entry = CacheEntry(
            key=toks,
            states_fp8=states_fp8,
            dtypes=dtypes,
            next_token=nt,
            nbytes=nbytes,
            checksum=entry_checksum(states_fp8, nt),
        )
        if FAULTS.enabled and FAULTS.fire(CACHE_CORRUPT) is not None:
            # flip one byte AFTER the checksum is recorded: a later lookup
            # must detect the mismatch and treat the entry as a miss. The
            # leaves are read-only device exports, so flip a copy.
            leaves, treedef = jax.tree_util.tree_flatten(entry.states_fp8)
            bad = leaves[0].copy()
            bad.view(np.uint8).reshape(-1)[0] ^= 0xFF
            entry = dataclasses.replace(
                entry,
                states_fp8=jax.tree_util.tree_unflatten(
                    treedef, [bad] + leaves[1:]
                ),
            )
        node = self._root
        for t in toks:
            node = node.children.setdefault(t, _TrieNode())
        if node.entry is not None:  # refresh in place
            self.nbytes -= node.entry.nbytes
            self._lru.pop(toks, None)
        node.entry = entry
        self._lru[toks] = entry
        self.nbytes += nbytes
        self.insertions += 1
        while self.nbytes > self.budget_bytes and self._lru:
            self._evict_lru()

    def _evict_key(self, key: tuple) -> None:
        """Targeted eviction (corrupt entry): rotate the key to the LRU
        front and reuse the pop-and-prune path."""
        if key in self._lru:
            self._lru.move_to_end(key, last=False)
            self._evict_lru()

    def _evict_lru(self) -> None:
        key, entry = self._lru.popitem(last=False)
        self.nbytes -= entry.nbytes
        self.evictions += 1
        # detach the entry, then prune now-empty trie branches
        path = [self._root]
        node = self._root
        for t in key:
            node = node.children[t]
            path.append(node)
        node.entry = None
        for parent, child_tok, child in zip(
            reversed(path[:-1]), reversed(key), reversed(path[1:])
        ):
            if child.entry is None and not child.children:
                del parent.children[child_tok]
            else:
                break

    # -- introspection ---------------------------------------------------
    def stats(self) -> dict:
        lookups = self.hits + self.misses
        return {
            "entries": len(self._lru),
            "nbytes": self.nbytes,
            "budget_bytes": self.budget_bytes,
            "hits": self.hits,
            "full_hits": self.full_hits,
            "misses": self.misses,
            "hit_rate": self.hits / lookups if lookups else 0.0,
            "insertions": self.insertions,
            "evictions": self.evictions,
            "corruptions": self.corruptions,
        }
