"""repro.serving.http — stdlib HTTP/SSE network service over the router.

The deployable face of the serving stack: ``HttpServer`` exposes
/v1/generate (JSON), /v1/stream (SSE), /healthz, /metrics (Prometheus),
and /admin/drain over ``asyncio.start_server``; ``Client`` is the
matching stdlib client. See serving/README.md §HTTP for the endpoint
reference, wire formats, and the operational runbook.
"""
from .client import Client, HttpError
from .prometheus import render_metrics
from .server import REASON_STATUS, HttpServer

__all__ = ["HttpServer", "Client", "HttpError", "REASON_STATUS", "render_metrics"]
