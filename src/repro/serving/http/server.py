"""HTTP/SSE network service over the frontend ``AsyncRouter``.

This is the first real network boundary over the whole stack: packed
FloatSD8 codes → dispatched kernels → batching engine → FP8 prefix cache
→ router → this server. Stdlib-only (``asyncio.start_server`` + the
protocol module in this package).

Endpoints:

* ``POST /v1/generate`` — JSON in/out, blocks until the request retires.
* ``POST /v1/stream``   — Server-Sent Events: a ``start`` event carrying
  the request id (so a client can cancel mid-stream), one frame per
  token, then a terminal ``done`` event (see serving/README.md for the
  wire format). Disconnecting mid-stream cancels the request inside the
  engine within one pump — the lane is freed, not decoded to ``max_new``.
* ``DELETE /v1/requests/{rid}`` — explicit cancellation of an in-flight
  request by id (200 with ``{"cancelled": true}``, or 404 if the rid is
  unknown or already finished).
* ``GET  /healthz``     — liveness + capacity snapshot (``Router.stats()``).
* ``GET  /metrics``     — Prometheus text exposition (engine counters,
  prefix-cache hit/saved counters, per-tenant percentiles).
* ``POST /admin/drain`` — graceful shutdown: stops admission (new
  submissions get 503 ``draining``), finishes every in-flight request via
  ``AsyncRouter.join()``, then exits ``serve_forever``.
* ``GET  /admin/trace`` — the request-lifecycle tracer's ring buffer as
  Chrome trace-event JSON (open in Perfetto / chrome://tracing; see
  docs/observability.md). The server enables the process tracer on
  ``start()`` unless constructed with ``trace=False``.

Request conventions: the tenant comes from the ``X-Tenant`` header
(default ``"default"``); the deadline from the JSON field ``deadline_ms``
(a relative budget, converted to the router's absolute monotonic
deadline at parse time); a boolean JSON field ``debug`` asks for the
per-request phase breakdown (``queue_ms``/``prefill_ms``/``decode_ms``/
``cache_saved_steps``…) in the ``/v1/generate`` response and the terminal
SSE ``done`` event. Router reject reasons map to distinct HTTP status
codes — see ``REASON_STATUS``.

Concurrency contract: one asyncio task per connection; every router
mutation goes through the ``AsyncRouter`` lock, and device steps run in a
worker thread (``asyncio.to_thread``) so the event loop keeps accepting
connections while the engine computes. The server object itself must be
used from a single event loop.
"""
from __future__ import annotations

import asyncio
import time
import traceback
from typing import Optional

from ...faults import FAULTS, SOCKET_DROP
from ...kernels import dispatch as kernel_dispatch
from ...obs.trace import TRACER
from ..frontend.router import AsyncRouter, Router, Ticket
from .protocol import (
    HttpRequest,
    ProtocolError,
    json_response,
    read_request,
    render_response,
    sse_event,
    sse_preamble,
)
from .prometheus import CONTENT_TYPE as PROM_CONTENT_TYPE
from .prometheus import render_metrics

__all__ = ["HttpServer", "REASON_STATUS"]

# Distinct status per reject reason (the acceptance bar). Note one
# deliberate choice: queue_full is the *server-wide* overload signal, so
# it maps to 503 + Retry-After (the standard load-shed answer), while 429
# is reserved for the caller-specific tenant_quota — this keeps all four
# reasons distinguishable by status code alone, not just by body.
REASON_STATUS = {
    "bad_request": 400,
    "tenant_quota": 429,
    "queue_full": 503,
    "deadline_expired": 504,
    # the router's circuit breaker: every replica ejected. 503 like
    # queue_full (the condition is transient — probes reinstate), but the
    # distinct body reason tells operators it is health, not load.
    "no_healthy_replicas": 503,
}
_RETRYABLE = (429, 503)


def _reject_response(reason: str, keep_alive: bool = True) -> bytes:
    status = REASON_STATUS.get(reason, 500)
    extra = [("Retry-After", "1")] if status in _RETRYABLE else []
    return json_response(
        status,
        {"error": reason},
        extra_headers=extra,
        keep_alive=keep_alive,
    )


class HttpServer:
    def __init__(
        self,
        router: Router,
        host: str = "127.0.0.1",
        port: int = 0,
        default_max_new: int = 32,
        max_new_cap: int = 1024,
        trace: bool = True,
        admit_retries: int = 2,
        retry_backoff_s: float = 0.05,
    ):
        self.router = router
        self.aroute = AsyncRouter(router)
        self.host = host
        self.port = port  # replaced by the bound port after start()
        self.default_max_new = default_max_new
        self.max_new_cap = max_new_cap
        # transient-rejection absorption: a "queue_full" bounce while at
        # least one replica is healthy is retried in-server with backoff
        # (admit_retries extra attempts) before the 503 reaches the wire —
        # the common cause is an ejection burst resubmitting a replica's
        # live requests into the router queue, which clears within pumps.
        self.admit_retries = admit_retries
        self.retry_backoff_s = retry_backoff_s
        self.trace = trace  # enable the process tracer on start()
        self.draining = False
        self.t_start: Optional[float] = None
        self._server: Optional[asyncio.base_events.Server] = None
        self._shutdown = asyncio.Event()
        self._conns: set = set()
        self._drain_task: Optional[asyncio.Task] = None
        self._admitting = 0  # handlers between their draining-check and submit
        self.http_requests = 0  # HTTP-level request counter (all endpoints)

    # -- lifecycle -------------------------------------------------------
    async def start(self) -> "HttpServer":
        if self.trace:
            # process-wide tracer (obs.trace.TRACER): /admin/trace serves
            # its ring buffer; the bounded ring makes always-on safe
            TRACER.enable()
        self._server = await asyncio.start_server(
            self._on_connection, self.host, self.port
        )
        self.port = self._server.sockets[0].getsockname()[1]
        self.t_start = time.monotonic()
        return self

    @property
    def uptime_s(self) -> float:
        return time.monotonic() - self.t_start if self.t_start else 0.0

    async def serve_forever(self) -> None:
        """Serve until /admin/drain completes (or ``shutdown()``)."""
        if self._server is None:
            await self.start()
        await self._shutdown.wait()
        self._server.close()
        await self._server.wait_closed()
        if self._conns:
            # in-flight work already drained (join); give response writers
            # a moment, then cancel idle keep-alive readers
            _done, pending = await asyncio.wait(self._conns, timeout=2.0)
            for t in pending:
                t.cancel()
            if pending:
                await asyncio.gather(*pending, return_exceptions=True)
        for e in self.router.engines:
            e.metrics.stop()

    def shutdown(self) -> None:
        self._shutdown.set()

    async def _do_drain(self) -> None:
        # A handler increments _admitting BEFORE checking self.draining
        # (both in one event-loop step, so the orderings can't interleave):
        # any handler that saw draining=False is therefore visible here,
        # and we keep joining until its submission has landed and drained
        # — closing the check-then-submit race where join() could observe
        # an idle router a moment before the late request entered it.
        try:
            while True:
                await self.aroute.join()
                if self._admitting == 0 and self.router.idle:
                    break
                await asyncio.sleep(0.01)
        except BaseException:
            # an engine failure mid-drain must not leave the server hung
            # with admission stopped and _shutdown never set — surface the
            # root cause (nothing awaits this background task) and exit
            traceback.print_exc()
            raise
        finally:
            self.shutdown()

    # -- connection plumbing ---------------------------------------------
    async def _on_connection(self, reader, writer) -> None:
        task = asyncio.current_task()
        self._conns.add(task)
        try:
            await self._connection_loop(reader, writer)
        except (
            ConnectionError,
            asyncio.IncompleteReadError,
            asyncio.CancelledError,
        ):
            pass  # client went away / shutdown: nothing to answer
        finally:
            self._conns.discard(task)
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _connection_loop(self, reader, writer) -> None:
        while True:
            try:
                req = await read_request(reader)
            except ProtocolError as e:
                writer.write(
                    json_response(
                        e.status, {"error": "protocol", "detail": e.detail},
                        keep_alive=False,
                    )
                )
                await writer.drain()
                return
            if req is None:
                return
            self.http_requests += 1
            # async scope on the shared event-loop thread: stamped as one
            # retroactive X event at completion (see Tracer.complete)
            t0_us = time.monotonic_ns() // 1000 if TRACER.enabled else 0
            try:
                close = await self._route(req, writer)
            except ProtocolError as e:
                writer.write(
                    json_response(
                        e.status, {"error": "protocol", "detail": e.detail}
                    )
                )
                close = False
            except (ConnectionError, asyncio.CancelledError):
                raise
            except Exception as e:  # handler bug: answer, then drop the conn
                writer.write(
                    json_response(
                        500,
                        {"error": "internal", "detail": f"{type(e).__name__}: {e}"},
                        keep_alive=False,
                    )
                )
                close = True
            await writer.drain()
            if TRACER.enabled:
                now_us = time.monotonic_ns() // 1000
                TRACER.complete(
                    "http.request", t0_us, now_us - t0_us, cat="http",
                    method=req.method, path=req.path,
                )
            if close or not req.keep_alive:
                return

    async def _route(self, req: HttpRequest, writer) -> bool:
        """Dispatch one request. Returns True when the connection must
        close (SSE streams, handler failures)."""
        route = (req.method, req.path)
        if route == ("POST", "/v1/generate"):
            writer.write(await self._generate(req))
            return False
        if route == ("POST", "/v1/stream"):
            return await self._stream(req, writer)
        if route == ("GET", "/healthz"):
            writer.write(await self._healthz())
            return False
        if route == ("GET", "/metrics"):
            writer.write(await self._metrics())
            return False
        if route == ("POST", "/admin/drain"):
            writer.write(await self._drain())
            return False
        if route == ("GET", "/admin/trace"):
            writer.write(self._trace())
            return False
        if req.path.startswith("/v1/requests/"):
            if req.method != "DELETE":
                writer.write(
                    json_response(
                        405, {"error": "method_not_allowed", "path": req.path}
                    )
                )
                return False
            writer.write(await self._cancel(req))
            return False
        known = {"/v1/generate", "/v1/stream", "/healthz", "/metrics",
                 "/admin/drain", "/admin/trace"}
        if req.path in known:
            writer.write(
                json_response(405, {"error": "method_not_allowed", "path": req.path})
            )
        else:
            writer.write(json_response(404, {"error": "not_found", "path": req.path}))
        return False

    # -- request parsing -------------------------------------------------
    def _parse_submission(self, req: HttpRequest) -> tuple[dict, bool]:
        """Returns (router submit kwargs, debug flag)."""
        body = req.json()
        if "prompt" not in body:
            raise ProtocolError(400, "missing required field 'prompt'")
        debug = body.get("debug", False)
        if not isinstance(debug, bool):
            raise ProtocolError(400, "'debug' must be a boolean")
        max_new = body.get("max_new", self.default_max_new)
        if not isinstance(max_new, int) or isinstance(max_new, bool):
            raise ProtocolError(400, "'max_new' must be an integer")
        if max_new > self.max_new_cap:
            raise ProtocolError(
                400, f"'max_new' exceeds the server cap of {self.max_new_cap}"
            )
        deadline = None
        if body.get("deadline_ms") is not None:
            d = body["deadline_ms"]
            if not isinstance(d, (int, float)) or isinstance(d, bool):
                raise ProtocolError(400, "'deadline_ms' must be a number")
            # relative budget on the wire -> absolute monotonic deadline
            deadline = time.monotonic() + float(d) / 1e3
        return (
            dict(
                prompt=body["prompt"],
                max_new=max_new,
                tenant=req.headers.get("x-tenant", "default"),
                deadline=deadline,
            ),
            debug,
        )

    def _retryable(self, ticket: Ticket) -> bool:
        """A rejection worth retrying in-server: transient backpressure
        ("queue_full" — e.g. an ejection burst just resubmitted a dead
        replica's requests into the router queue) while at least one
        healthy replica remains to clear it. Health/breaker rejections
        ("no_healthy_replicas") and caller errors go straight to the wire.
        Reads ``healthy_replicas`` without the pump lock: a stale-by-one-
        pump read only costs one extra (harmless) retry."""
        return (
            not ticket.ok
            and ticket.reason == "queue_full"
            and self.router.healthy_replicas > 0
            and not self.draining
        )

    async def _backoff(self, attempt: int) -> None:
        await self.aroute.snapshot(lambda r: r.note_retry())
        await asyncio.sleep(self.retry_backoff_s * (2 ** attempt))

    # -- endpoint handlers -----------------------------------------------
    async def _cancel(self, req: HttpRequest) -> bytes:
        """DELETE /v1/requests/{rid}: explicit engine-level cancellation.
        The rid comes from the generate/stream response (``rid`` field /
        the SSE ``start`` event)."""
        suffix = req.path[len("/v1/requests/"):]
        try:
            rid = int(suffix)
        except ValueError:
            raise ProtocolError(400, f"request id must be an integer, got {suffix!r}")
        cancelled = await self.aroute.cancel(rid)
        if not cancelled:
            # unknown, finished, or already cancelled: nothing to release
            return json_response(404, {"error": "unknown_request", "rid": rid})
        return json_response(200, {"rid": rid, "cancelled": True})

    async def _generate(self, req: HttpRequest) -> bytes:
        self._admitting += 1  # before the draining check: see _do_drain
        try:
            if self.draining:
                return json_response(
                    503, {"error": "draining"},
                    extra_headers=[("Retry-After", "5")],
                )
            kw, debug = self._parse_submission(req)
            for attempt in range(self.admit_retries + 1):
                ticket = await self.aroute.generate(**kw)
                if attempt >= self.admit_retries or not self._retryable(ticket):
                    break
                await self._backoff(attempt)
        finally:
            self._admitting -= 1
        if not ticket.ok:
            return _reject_response(ticket.reason)
        r = ticket.req
        if ticket.status == "cancelled" and ticket.reason == "deadline_expired":
            # the deadline expired after lane binding: same contract as a
            # queue-time expiry — the client asked for a budget we missed
            return _reject_response("deadline_expired")
        payload = {
            "rid": ticket.rid,
            "tenant": ticket.tenant,
            "tokens": ticket.tokens,
            "n_tokens": len(ticket.tokens),
            # a request cancelled before its first token has no TTFT
            "ttft_ms": (
                (r.t_first - r.t_submit) * 1e3 if r.t_first is not None else None
            ),
            "latency_ms": (ticket.t_done - r.t_submit) * 1e3,
        }
        if ticket.status == "cancelled":
            # explicit cancel mid-generate: 200 with the partial tokens —
            # the caller (or another connection) asked for this outcome
            payload["status"] = "cancelled"
            payload["reason"] = ticket.reason
        elif ticket.status == "numeric_error":
            # the engine's nonfinite-logit guard retired the request: the
            # partial tokens are valid (generated before the poisoned
            # step), the status tells the caller the tail is missing
            payload["status"] = "numeric_error"
            payload["reason"] = ticket.reason
        if debug:
            payload["phases"] = r.phases()
        return json_response(200, payload)

    async def _stream(self, req: HttpRequest, writer) -> bool:
        self._admitting += 1  # before the draining check: see _do_drain
        try:
            if self.draining:
                writer.write(
                    json_response(
                        503, {"error": "draining"},
                        extra_headers=[("Retry-After", "5")],
                    )
                )
                return False
            kw, debug = self._parse_submission(req)
            # submit BEFORE committing to a status line: a rejection must
            # reach the client as its mapped status, not a broken stream
            for attempt in range(self.admit_retries + 1):
                ticket, toks = await self.aroute.open_stream(**kw)
                if attempt >= self.admit_retries or not self._retryable(ticket):
                    break
                await self._backoff(attempt)
        finally:
            self._admitting -= 1
        if toks is None:
            writer.write(_reject_response(ticket.reason))
            return False
        writer.write(sse_preamble())
        # rid first: a streaming client can only DELETE /v1/requests/{rid}
        # mid-stream if it learns the rid before the tokens start
        writer.write(
            sse_event({"rid": ticket.rid, "tenant": ticket.tenant}, event="start")
        )
        await writer.drain()
        index = 0
        try:
            async for tok in toks:
                if FAULTS.enabled and FAULTS.fire(
                    SOCKET_DROP, key=ticket.rid, rid=ticket.rid
                ) is not None:
                    # abort the connection mid-stream: the finally below
                    # closes the token iterator, which abandons the ticket
                    # — the engine cancels it within one pump instead of
                    # decoding to max_new for a dead socket
                    raise ConnectionError("injected socket drop")
                writer.write(sse_event({"index": index, "token": int(tok)}))
                await writer.drain()
                index += 1
            if not ticket.ok:
                # rejected AFTER admission (deadline expired in the queue):
                # the 200 preamble is already on the wire, so the mapped
                # status travels as a terminal error event instead
                writer.write(
                    sse_event(
                        {
                            "error": ticket.reason,
                            "status": REASON_STATUS.get(ticket.reason, 500),
                        },
                        event="error",
                    )
                )
                await writer.drain()
                return True
            if ticket.status == "cancelled" and ticket.reason == "deadline_expired":
                # mid-flight deadline: surface the same 504 contract the
                # queue-time expiry uses, as a terminal error event
                writer.write(
                    sse_event(
                        {
                            "error": "deadline_expired",
                            "status": REASON_STATUS["deadline_expired"],
                            "n_tokens": len(ticket.tokens),
                        },
                        event="error",
                    )
                )
                await writer.drain()
                return True
            r = ticket.req
            done_payload = {
                "rid": ticket.rid,
                "tenant": ticket.tenant,
                "n_tokens": len(ticket.tokens),
                "ttft_ms": (
                    (r.t_first - r.t_submit) * 1e3 if r.t_first is not None else None
                ),
                "latency_ms": (ticket.t_done - r.t_submit) * 1e3,
            }
            if ticket.status == "cancelled":
                # explicit DELETE while streaming: terminal done frame with
                # the partial count — the consumer asked for this outcome
                done_payload["status"] = "cancelled"
                done_payload["reason"] = ticket.reason
            elif ticket.status == "numeric_error":
                # nonfinite-logit retire mid-stream: the tokens already on
                # the wire are valid; the terminal frame flags the cut
                done_payload["status"] = "numeric_error"
                done_payload["reason"] = ticket.reason
            if debug:
                done_payload["phases"] = r.phases()
            writer.write(sse_event(done_payload, event="done"))
            await writer.drain()
            if TRACER.enabled:
                TRACER.instant(
                    "http.sse_flush", cat="http", rid=ticket.rid,
                    frames=index + 1,
                )
        finally:
            # closing a half-consumed iterator abandons the ticket, so a
            # dropped connection stops burning device steps within one pump
            await toks.aclose()
        return True  # SSE streams are delimited by connection close

    # Aggregate reads go through AsyncRouter.snapshot (the pump lock):
    # report()/stats() iterate collections a worker-thread pump mutates.
    async def _healthz(self) -> bytes:
        stats = await self.aroute.snapshot(lambda r: r.stats())
        return json_response(
            200 if not self.draining else 503,
            {
                "status": "draining" if self.draining else "ok",
                "uptime_s": self.uptime_s,
                **stats,
            },
        )

    async def _metrics(self) -> bytes:
        # One consolidated scrape read under the pump lock: Router.scrape()
        # bundles report + stats + prefix-cache stats so no consumer can
        # re-assemble the pieces and miss the lock on one of them. Dispatch
        # and tracer stats are internally locked and safe to read here.
        scrape = await self.aroute.snapshot(lambda r: r.scrape())
        text = render_metrics(
            scrape["report"],
            scrape["stats"],
            cache_stats=scrape["cache"],
            draining=self.draining,
            uptime_s=self.uptime_s,
            http_requests=self.http_requests,
            dispatch_counts=kernel_dispatch.STATS.snapshot(),
            trace_stats=TRACER.stats(),
            cost_rows=scrape["cost"],
        )
        return render_response(
            200, text.encode("utf-8"), content_type=PROM_CONTENT_TYPE
        )

    def _trace(self) -> bytes:
        """GET /admin/trace: the tracer ring as Chrome trace-event JSON.
        The tracer snapshots under its own lock, so this does not need the
        pump lock (and must not hold it: the export can be MBs)."""
        return json_response(200, TRACER.chrome_trace())

    async def _drain(self) -> bytes:
        stats = await self.aroute.snapshot(lambda r: r.stats())
        if not self.draining:  # idempotent: repeat calls report progress
            self.draining = True
            self._drain_task = asyncio.get_running_loop().create_task(
                self._do_drain()
            )
        return json_response(
            200,
            {
                "status": "draining",
                "queued": stats["queued"],
                "inflight": stats["inflight"],
            },
        )
