"""Prometheus text exposition (version 0.0.4) for the serving stack.

Renders one scrape from three sources, all host-side dicts — no device
work happens on the scrape path:

* ``Router.report()`` — aggregate engine counters (requests, steps,
  tokens, cache hit/saved) and per-tenant TTFT/latency percentiles;
* ``Router.stats()`` — instantaneous gauges (free lanes, queue depth,
  in-flight) plus rejection counters by reason;
* ``PrefixCache.stats()`` — entry/byte occupancy and hit/eviction
  counters for the shared FP8 LSTM-state prefix cache;
* ``kernels.dispatch.STATS.snapshot()`` — per-(op, backend) kernel
  dispatch decisions, so a silent pallas→ref fallback shows up in the
  scrape instead of only in a perf regression;
* ``obs.trace.TRACER.stats()`` — tracer health (enabled, event/drop
  totals) and per-span-name counts + cumulative durations.

Percentiles are exported summary-style (``quantile`` label) because they
are computed router-side over retired-request records; counters follow
the ``_total`` naming convention. Everything is prefixed ``repro_`` so a
shared Prometheus can scrape several services without collisions. The
full name reference lives in docs/observability.md.
"""
from __future__ import annotations

from typing import Optional

__all__ = ["render_metrics", "CONTENT_TYPE"]

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _escape(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _Writer:
    def __init__(self):
        self.lines: list[str] = []

    def metric(self, name: str, mtype: str, help_: str) -> None:
        self.lines.append(f"# HELP {name} {help_}")
        self.lines.append(f"# TYPE {name} {mtype}")

    def sample(self, name: str, value, labels: Optional[dict] = None) -> None:
        label_s = ""
        if labels:
            inner = ",".join(
                f'{k}="{_escape(str(v))}"' for k, v in labels.items()
            )
            label_s = "{" + inner + "}"
        # integral values render as exact integers: '%g' would round
        # counters to 6 significant digits (1234567 -> 1.23457e+06),
        # corrupting rate() and scrape-diff arithmetic on busy servers
        v = float(value)
        rendered = str(int(v)) if v.is_integer() and abs(v) < 2**53 else repr(v)
        self.lines.append(f"{name}{label_s} {rendered}")

    def render(self) -> str:
        return "\n".join(self.lines) + "\n"


def _le(bound: float) -> str:
    """Prometheus `le` label: integral bounds render bare ("100"), the
    rest as repr — matching the sample-value convention above."""
    v = float(bound)
    return str(int(v)) if v.is_integer() else repr(v)


def _histogram(w: _Writer, name: str, help_: str, hist: Optional[dict]) -> None:
    """Render a LatencyHistogram.report() dict as a Prometheus histogram:
    cumulative `_bucket{le=...}` series (last per-bucket count is the +Inf
    overflow), plus `_sum` and `_count`."""
    if not hist or not hist.get("buckets_ms"):
        return
    w.metric(name, "histogram", help_)
    cum = 0
    for bound, n in zip(hist["buckets_ms"], hist["counts"]):
        cum += n
        w.sample(name + "_bucket", cum, {"le": _le(bound)})
    cum += hist["counts"][-1]
    w.sample(name + "_bucket", cum, {"le": "+Inf"})
    w.sample(name + "_sum", hist["sum_ms"])
    w.sample(name + "_count", hist["count"])


def render_metrics(
    report: dict,
    stats: dict,
    cache_stats: Optional[dict] = None,
    draining: bool = False,
    uptime_s: float = 0.0,
    http_requests: int = 0,
    dispatch_counts: Optional[dict] = None,
    trace_stats: Optional[dict] = None,
    cost_rows: Optional[list] = None,
) -> str:
    w = _Writer()

    # -- service-level gauges -------------------------------------------
    w.metric("repro_up", "gauge", "1 while the server accepts requests, 0 while draining.")
    w.sample("repro_up", 0.0 if draining else 1.0)
    w.metric("repro_uptime_seconds", "gauge", "Seconds since the HTTP server started.")
    w.sample("repro_uptime_seconds", uptime_s)
    w.metric("repro_replicas", "gauge", "Engine replicas behind the router.")
    w.sample("repro_replicas", stats["replicas"])
    w.metric("repro_lanes", "gauge", "Total decode lanes across replicas.")
    w.sample("repro_lanes", stats["lanes"])
    w.metric("repro_free_lanes", "gauge", "Currently unbound decode lanes.")
    w.sample("repro_free_lanes", stats["free_lanes"])
    w.metric("repro_queue_depth", "gauge", "Requests waiting in the router queue.")
    w.sample("repro_queue_depth", stats["queued"])
    w.metric("repro_inflight_requests", "gauge", "Requests admitted but not yet retired.")
    w.sample("repro_inflight_requests", stats["inflight"])
    w.metric("repro_http_requests_total", "counter",
             "HTTP requests handled across ALL endpoints (scrapes and "
             "rejections included) — distinguishes wire traffic from "
             "router admissions.")
    w.sample("repro_http_requests_total", http_requests)

    # -- engine counters -------------------------------------------------
    counters = (
        ("repro_requests_total", "requests", "Requests retired across all replicas."),
        ("repro_steps_total", "steps", "Batched device steps across all replicas."),
        ("repro_prefill_steps_total", "prefill_steps", "Steps whose token block was wider than one position."),
        ("repro_decode_steps_total", "decode_steps", "One-token decode steps."),
        ("repro_emitted_tokens_total", "emitted_tokens", "Generated tokens delivered to clients."),
        ("repro_prompt_tokens_total", "prompt_tokens", "Prompt tokens consumed by prefill."),
    )
    for name, key, help_ in counters:
        w.metric(name, "counter", help_)
        w.sample(name, report[key])

    w.metric("repro_rejections_total", "counter",
             "Admission rejections by reason (queue_full | tenant_quota | bad_request | deadline_expired).")
    for reason, n in sorted(stats["rejections"].items()):
        w.sample("repro_rejections_total", n, {"reason": reason})

    # -- cancellation / preemption --------------------------------------
    w.metric("repro_cancelled_total", "counter",
             "Post-admission cancellations by reason (client_cancel | "
             "abandoned | deadline_expired) — each one released its lane "
             "or queue slot instead of decoding to max_new.")
    for reason, n in sorted(stats.get("cancellations", {}).items()):
        w.sample("repro_cancelled_total", n, {"reason": reason})
    w.metric("repro_preemptions_total", "counter",
             "Decoding lanes snapshotted to host FP8 and requeued so "
             "shorter queued work could run first.")
    w.sample("repro_preemptions_total", report.get("preemptions", 0))
    w.metric("repro_resumes_total", "counter",
             "Preempted requests restored onto a lane from their FP8 snapshot.")
    w.sample("repro_resumes_total", report.get("resumes", 0))

    # -- replica health / fault injection --------------------------------
    w.metric("repro_healthy_replicas", "gauge",
             "Replicas currently in rotation (ejected replicas excluded). "
             "0 means the admission circuit breaker is open.")
    w.sample("repro_healthy_replicas", stats.get("healthy_replicas",
                                                 stats["replicas"]))
    w.metric("repro_replica_ejections_total", "counter",
             "Replicas taken out of rotation after a crash or repeated "
             "step failures; their live requests were resubmitted.")
    w.sample("repro_replica_ejections_total", stats.get("ejections", 0))
    w.metric("repro_replica_reinstatements_total", "counter",
             "Ejected replicas returned to rotation by a successful probe.")
    w.sample("repro_replica_reinstatements_total",
             stats.get("reinstatements", 0))
    w.metric("repro_resubmits_total", "counter",
             "In-flight requests moved off a dead replica back into the "
             "router queue (t_submit preserved, delivery deduplicated).")
    w.sample("repro_resubmits_total", stats.get("resubmits", 0))
    w.metric("repro_retries_total", "counter",
             "Admission retries performed by the HTTP layer's backoff loop "
             "on transient queue_full rejections.")
    w.sample("repro_retries_total", stats.get("retries", 0))
    w.metric("repro_numeric_errors_total", "counter",
             "Requests retired with nonfinite logits (status "
             "numeric_error): the lane was reset instead of sampling "
             "garbage from NaN.")
    w.sample("repro_numeric_errors_total", report.get("numeric_errors", 0))
    faults = stats.get("faults") or {}
    if faults.get("injected") or faults.get("enabled"):
        w.metric("repro_faults_injected_total", "counter",
                 "Deliberate fault injections fired by the armed "
                 "REPRO_FAULTS plan, by injection point (absent when the "
                 "fault layer has never been armed).")
        for point, n in sorted(faults.get("injected", {}).items()):
            w.sample("repro_faults_injected_total", n, {"point": point})

    # -- prefix cache ----------------------------------------------------
    w.metric("repro_cache_lookups_total", "counter", "Prefix-cache admission lookups.")
    w.sample("repro_cache_lookups_total", report["cache_lookups"])
    w.metric("repro_cache_hits_total", "counter", "Lookups that injected a cached FP8 state.")
    w.sample("repro_cache_hits_total", report["cache_hits"])
    w.metric("repro_cache_full_hits_total", "counter", "Hits that skipped prefill entirely.")
    w.sample("repro_cache_full_hits_total", report["cache_full_hits"])
    w.metric("repro_prefill_tokens_saved_total", "counter",
             "Prompt tokens never sent to the device thanks to cache injection.")
    w.sample("repro_prefill_tokens_saved_total", report["prefill_tokens_saved"])
    if cache_stats is not None:
        w.metric("repro_cache_entries", "gauge", "Live prefix-cache entries.")
        w.sample("repro_cache_entries", cache_stats["entries"])
        w.metric("repro_cache_bytes", "gauge", "FP8 payload bytes resident in the prefix cache.")
        w.sample("repro_cache_bytes", cache_stats["nbytes"])
        w.metric("repro_cache_budget_bytes", "gauge", "Prefix-cache byte budget (--cache-mb).")
        w.sample("repro_cache_budget_bytes", cache_stats["budget_bytes"])
        w.metric("repro_cache_evictions_total", "counter", "LRU evictions under the byte budget.")
        w.sample("repro_cache_evictions_total", cache_stats["evictions"])
        w.metric("repro_cache_corruptions_total", "counter",
                 "Entries whose stored checksum failed verification at "
                 "lookup — served as a miss and evicted, never injected.")
        w.sample("repro_cache_corruptions_total",
                 cache_stats.get("corruptions", 0))

    # -- request phase breakdown ----------------------------------------
    # queue + prefill == TTFT and queue + prefill + decode == latency, so
    # these decompose the tail metrics above into attributable phases.
    phases = report.get("phases")
    if phases:
        w.metric("repro_request_phase_seconds", "summary",
                 "Per-request latency by phase (queue | prefill | decode), "
                 "summary over the retired-request record window.")
        for phase, agg in phases.items():
            for q, key in (("0.5", "p50_s"), ("0.95", "p95_s")):
                w.sample(
                    "repro_request_phase_seconds",
                    agg[key],
                    {"phase": phase, "quantile": q},
                )
        w.metric("repro_request_phase_seconds_mean", "gauge",
                 "Mean per-request phase latency over the record window.")
        for phase, agg in phases.items():
            w.sample(
                "repro_request_phase_seconds_mean",
                agg["mean_s"],
                {"phase": phase},
            )

    # -- latency histograms ----------------------------------------------
    # cumulative across the server's lifetime (NOT the record window), so
    # rate() and histogram_quantile() are well-defined over scrape diffs
    _histogram(
        w, "repro_ttft_ms",
        "Time to first token in milliseconds (cumulative histogram over "
        "all retired requests).",
        report.get("ttft_hist_ms"),
    )
    _histogram(
        w, "repro_tpot_ms",
        "Time per output token in milliseconds — decode stretch divided "
        "by inter-token gaps, requests with >= 2 generated tokens "
        "(cumulative histogram).",
        report.get("tpot_hist_ms"),
    )

    # -- kernel cost ledger ----------------------------------------------
    if cost_rows:
        cost_counters = (
            ("repro_cost_calls_total", "calls",
             "Dispatch decisions accumulated into the cost ledger."),
            ("repro_cost_flops_total", "flops",
             "Predicted FLOPs from the analytical kernel cost model "
             "(2/MAC + documented per-element constants)."),
            ("repro_cost_macs_total", "macs",
             "Predicted multiply-accumulates (the paper's Table-7 unit)."),
            ("repro_cost_hbm_read_bytes_total", "hbm_read_bytes",
             "Predicted HBM operand traffic incl. pallas grid revisits."),
            ("repro_cost_hbm_write_bytes_total", "hbm_write_bytes",
             "Predicted HBM result traffic."),
            ("repro_cost_pad_waste_bytes_total", "pad_waste_bytes",
             "Predicted bytes spent on tile-alignment padding."),
            ("repro_cost_touched_bytes_total", "touched_bytes",
             "Measured unique ndarray bytes the dispatch actually handed "
             "to the backend (the ref-exactness cross-check)."),
        )
        for name, key, help_ in cost_counters:
            w.metric(name, "counter", help_)
            for r in cost_rows:
                w.sample(name, r[key], {"op": r["op"], "backend": r["backend"]})
        w.metric("repro_cost_arithmetic_intensity", "gauge",
                 "Predicted FLOPs per HBM byte (roofline x-coordinate).")
        for r in cost_rows:
            w.sample("repro_cost_arithmetic_intensity",
                     r["arithmetic_intensity"],
                     {"op": r["op"], "backend": r["backend"]})
        w.metric("repro_cost_vmem_bytes", "gauge",
                 "Predicted peak per-grid-step VMEM working set (0 on ref).")
        for r in cost_rows:
            w.sample("repro_cost_vmem_bytes", r["vmem_bytes"],
                     {"op": r["op"], "backend": r["backend"]})
        w.metric("repro_cost_bytes_rel_err", "gauge",
                 "(predicted - touched) / touched HBM bytes on the ref "
                 "backend — nonzero means the analytical model drifted "
                 "from the arrays actually moved.")
        for r in cost_rows:
            if r.get("bytes_rel_err") is not None:
                w.sample("repro_cost_bytes_rel_err", r["bytes_rel_err"],
                         {"op": r["op"], "backend": r["backend"]})

    # -- kernel dispatch decisions --------------------------------------
    if dispatch_counts is not None:
        w.metric("repro_dispatch_decisions_total", "counter",
                 "Kernel dispatch-layer backend decisions by (op, backend) "
                 "— a nonzero ref count where pallas was expected is a "
                 "silent-fallback alarm.")
        for (op, backend), n in sorted(dispatch_counts.items()):
            w.sample(
                "repro_dispatch_decisions_total",
                n,
                {"op": op, "backend": backend},
            )

    # -- tracer ----------------------------------------------------------
    if trace_stats is not None:
        w.metric("repro_trace_enabled", "gauge",
                 "1 while the request-lifecycle tracer is recording.")
        w.sample("repro_trace_enabled", 1.0 if trace_stats["enabled"] else 0.0)
        w.metric("repro_trace_events_total", "counter",
                 "Trace events emitted since the tracer was last cleared.")
        w.sample("repro_trace_events_total", trace_stats["emitted"])
        w.metric("repro_trace_dropped_total", "counter",
                 "Trace events evicted by the bounded ring buffer.")
        w.sample("repro_trace_dropped_total", trace_stats["dropped"])
        if trace_stats.get("spans"):
            w.metric("repro_trace_spans_total", "counter",
                     "Completed spans (and instants) by name.")
            w.metric("repro_trace_span_seconds_total", "counter",
                     "Cumulative duration inside each span name.")
            for name, agg in trace_stats["spans"].items():
                w.sample("repro_trace_spans_total", agg["count"], {"name": name})
                w.sample(
                    "repro_trace_span_seconds_total",
                    agg["total_s"],
                    {"name": name},
                )

    # -- per-tenant summaries -------------------------------------------
    w.metric("repro_tenant_requests_total", "counter", "Submissions by tenant.")
    w.metric("repro_tenant_completed_total", "counter", "Completed requests by tenant.")
    w.metric("repro_tenant_rejected_total", "counter", "Rejected submissions by tenant.")
    w.metric("repro_tenant_tokens_total", "counter", "Generated tokens by tenant.")
    for tenant, t in report.get("tenants", {}).items():
        lbl = {"tenant": tenant}
        w.sample("repro_tenant_requests_total", t.get("submitted", 0), lbl)
        w.sample("repro_tenant_completed_total", t.get("completed", 0), lbl)
        w.sample("repro_tenant_rejected_total", t.get("rejected", 0), lbl)
        w.sample("repro_tenant_tokens_total", t.get("tokens", 0), lbl)
    w.metric("repro_tenant_ttft_seconds", "summary",
             "Time to first token by tenant (summary over retired requests).")
    w.metric("repro_tenant_latency_seconds", "summary",
             "Submit-to-done latency by tenant (summary over retired requests).")
    for tenant, t in report.get("tenants", {}).items():
        for metric, stem in (
            ("repro_tenant_ttft_seconds", "ttft"),
            ("repro_tenant_latency_seconds", "latency"),
        ):
            for q, key in (("0.5", "p50"), ("0.95", "p95")):
                if f"{stem}_{key}_s" in t:
                    w.sample(
                        metric,
                        t[f"{stem}_{key}_s"],
                        {"tenant": tenant, "quantile": q},
                    )
    return w.render()
