"""Minimal HTTP/1.1 + Server-Sent-Events wire protocol over asyncio streams.

Dependency-free by design (ROADMAP constraint: no aiohttp/uvicorn in the
container): just enough of RFC 7230 to serve the four JSON/SSE endpoints —
request-line + header parsing, Content-Length bodies, keep-alive, and SSE
framing. Not a general web server: no chunked *request* bodies, no
multipart, no TLS (terminate upstream), request targets are matched
literally after stripping the query string.

Framing rules this module implements:

* Requests: ``METHOD /path HTTP/1.1`` + CRLF headers + optional body of
  exactly ``Content-Length`` bytes. Header names are lower-cased on parse.
* JSON responses carry ``Content-Length`` and keep the connection alive
  unless the client sent ``Connection: close``.
* SSE responses (``Content-Type: text/event-stream``) have no length and
  are terminated by connection close (``Connection: close`` is announced
  in the preamble); each event is ``[event: <name>\\n]data: <payload>\\n\\n``.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
from typing import Any, Iterable, Optional, Tuple

__all__ = [
    "HttpRequest",
    "ProtocolError",
    "read_request",
    "render_response",
    "json_response",
    "sse_preamble",
    "sse_event",
    "STATUS_PHRASES",
]

MAX_REQUEST_LINE = 8 * 1024
MAX_HEADER_BYTES = 64 * 1024
MAX_BODY_BYTES = 8 * 2**20

STATUS_PHRASES = {
    200: "OK",
    202: "Accepted",
    400: "Bad Request",
    404: "Not Found",
    405: "Method Not Allowed",
    413: "Payload Too Large",
    429: "Too Many Requests",
    500: "Internal Server Error",
    503: "Service Unavailable",
    504: "Gateway Timeout",
}


class ProtocolError(Exception):
    """Malformed or oversized request; ``status`` is the HTTP status the
    server should answer with before closing the connection."""

    def __init__(self, status: int, detail: str):
        super().__init__(detail)
        self.status = status
        self.detail = detail


@dataclasses.dataclass
class HttpRequest:
    method: str
    target: str  # raw request target, query string included
    headers: dict  # lower-cased names -> values
    body: bytes

    @property
    def path(self) -> str:
        return self.target.split("?", 1)[0]

    @property
    def keep_alive(self) -> bool:
        return self.headers.get("connection", "").lower() != "close"

    def json(self) -> Any:
        """Parse the body as a JSON object; raises ProtocolError(400)."""
        if not self.body:
            raise ProtocolError(400, "empty body: expected a JSON object")
        try:
            obj = json.loads(self.body.decode("utf-8"))
        except (ValueError, UnicodeDecodeError) as e:
            raise ProtocolError(400, f"malformed JSON body: {e}") from None
        if not isinstance(obj, dict):
            raise ProtocolError(400, "JSON body must be an object")
        return obj


async def read_request(reader: asyncio.StreamReader) -> Optional[HttpRequest]:
    """Read one request off the stream; None on clean EOF (client closed
    between keep-alive requests). Raises ProtocolError on malformed input
    and ConnectionError/IncompleteReadError on mid-request disconnects."""
    try:
        line = await reader.readuntil(b"\r\n")
    except asyncio.IncompleteReadError as e:
        if not e.partial:
            return None  # clean close between requests
        raise ConnectionError("connection closed mid request line") from None
    except asyncio.LimitOverrunError:
        raise ProtocolError(400, "request line too long") from None
    if len(line) > MAX_REQUEST_LINE:
        raise ProtocolError(400, "request line too long")
    parts = line.decode("latin-1").rstrip("\r\n").split()
    if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
        raise ProtocolError(400, f"malformed request line: {line!r}")
    method, target, _version = parts

    headers: dict = {}
    total = 0
    while True:
        try:
            line = await reader.readuntil(b"\r\n")
        except asyncio.IncompleteReadError:
            raise ConnectionError("connection closed mid headers") from None
        except asyncio.LimitOverrunError:
            # one header line longer than the StreamReader buffer limit:
            # answer 400 instead of killing the connection task
            raise ProtocolError(400, "header line too long") from None
        if line in (b"\r\n", b"\n"):
            break
        total += len(line)
        if total > MAX_HEADER_BYTES:
            raise ProtocolError(400, "headers too large")
        name, sep, value = line.decode("latin-1").partition(":")
        if not sep:
            raise ProtocolError(400, f"malformed header line: {line!r}")
        headers[name.strip().lower()] = value.strip()

    body = b""
    if "content-length" in headers:
        try:
            n = int(headers["content-length"])
        except ValueError:
            raise ProtocolError(400, "bad Content-Length") from None
        if n < 0:
            raise ProtocolError(400, "bad Content-Length")
        if n > MAX_BODY_BYTES:
            raise ProtocolError(413, f"body exceeds {MAX_BODY_BYTES} bytes")
        try:
            body = await reader.readexactly(n)
        except asyncio.IncompleteReadError:
            raise ConnectionError("connection closed mid body") from None
    elif headers.get("transfer-encoding", "").lower() == "chunked":
        raise ProtocolError(400, "chunked request bodies are not supported")
    return HttpRequest(method=method, target=target, headers=headers, body=body)


def render_response(
    status: int,
    body: bytes,
    content_type: str = "application/json",
    extra_headers: Iterable[Tuple[str, str]] = (),
    keep_alive: bool = True,
) -> bytes:
    phrase = STATUS_PHRASES.get(status, "Unknown")
    lines = [
        f"HTTP/1.1 {status} {phrase}",
        f"Content-Type: {content_type}",
        f"Content-Length: {len(body)}",
        f"Connection: {'keep-alive' if keep_alive else 'close'}",
    ]
    lines += [f"{k}: {v}" for k, v in extra_headers]
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1") + body


def json_response(
    status: int,
    obj: Any,
    extra_headers: Iterable[Tuple[str, str]] = (),
    keep_alive: bool = True,
) -> bytes:
    body = (json.dumps(obj) + "\n").encode("utf-8")
    return render_response(
        status, body, extra_headers=extra_headers, keep_alive=keep_alive
    )


def sse_preamble(status: int = 200) -> bytes:
    """Response head for a Server-Sent-Events stream. No Content-Length:
    the stream is delimited by connection close, announced up front."""
    phrase = STATUS_PHRASES.get(status, "Unknown")
    return (
        f"HTTP/1.1 {status} {phrase}\r\n"
        "Content-Type: text/event-stream\r\n"
        "Cache-Control: no-store\r\n"
        "Connection: close\r\n\r\n"
    ).encode("latin-1")


def sse_event(data: Any, event: Optional[str] = None) -> bytes:
    """One SSE frame. ``data`` is JSON-encoded (the wire format all repro
    clients parse); a named event becomes an ``event:`` field."""
    head = f"event: {event}\n" if event else ""
    return (head + f"data: {json.dumps(data)}\n\n").encode("utf-8")
