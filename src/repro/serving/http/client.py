"""Minimal asyncio HTTP/SSE client for the repro serving API.

Stdlib-only companion to ``server.py`` — the benchmark load generator,
the example demo, the smoke script, and the tests all speak to the
server through this module, so the wire format is exercised by one
implementation on each side.

``Client`` keeps one keep-alive connection for JSON endpoints and opens a
dedicated connection per SSE stream (the server delimits event streams by
connection close). Non-2xx responses raise ``HttpError`` carrying the
status and decoded body, so callers can assert on the reject mapping.

Failure semantics (every await is bounded — a dead server can never hang
a caller forever):

  * connects are retried up to ``connect_retries`` times with jittered
    exponential backoff before raising;
  * every response read is capped at ``timeout_s`` and raises
    ``asyncio.TimeoutError`` (the connection is torn down — a late
    response must not be misread as the answer to the *next* request);
  * a failed round trip is re-sent only when a REUSED pooled connection
    broke (stale keep-alive socket), never after a fresh-connection
    failure or a timeout — the server may already be executing the
    request, and blind re-sends would double the device work.
"""
from __future__ import annotations

import asyncio
import json
import random
from typing import Any, AsyncIterator, Optional, Tuple

__all__ = ["Client", "HttpError"]


class HttpError(Exception):
    def __init__(self, status: int, body: Any):
        reason = body.get("error") if isinstance(body, dict) else None
        super().__init__(f"HTTP {status}: {reason or body}")
        self.status = status
        self.body = body
        self.reason = reason


def _request_bytes(
    method: str,
    path: str,
    host: str,
    body: Optional[bytes],
    headers: Optional[dict],
) -> bytes:
    lines = [f"{method} {path} HTTP/1.1", f"Host: {host}"]
    if body is not None:
        lines.append("Content-Type: application/json")
        lines.append(f"Content-Length: {len(body)}")
    for k, v in (headers or {}).items():
        lines.append(f"{k}: {v}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1") + (body or b"")


async def _read_response_head(reader) -> Tuple[int, dict]:
    status_line = await reader.readuntil(b"\r\n")
    parts = status_line.decode("latin-1").split(None, 2)
    if len(parts) < 2 or not parts[0].startswith("HTTP/1."):
        raise ConnectionError(f"malformed status line: {status_line!r}")
    status = int(parts[1])
    headers: dict = {}
    while True:
        line = await reader.readuntil(b"\r\n")
        if line == b"\r\n":
            break
        name, _, value = line.decode("latin-1").partition(":")
        headers[name.strip().lower()] = value.strip()
    return status, headers


class Client:
    def __init__(
        self,
        host: str,
        port: int,
        tenant: Optional[str] = None,
        timeout_s: float = 30.0,
        connect_retries: int = 2,
        backoff_s: float = 0.05,
    ):
        self.host = host
        self.port = port
        self.tenant = tenant
        self.timeout_s = timeout_s
        self.connect_retries = connect_retries
        self.backoff_s = backoff_s
        self.retries = 0  # connect + stale-socket retries performed
        self._reader: Optional[asyncio.StreamReader] = None
        self._writer: Optional[asyncio.StreamWriter] = None

    # -- connection management ------------------------------------------
    async def _connect(self) -> Tuple[asyncio.StreamReader, asyncio.StreamWriter]:
        """Connect with bounded jittered-backoff retry: a server that is
        mid-restart (or a listen backlog burst) answers on the second
        attempt instead of failing the whole call."""
        for attempt in range(self.connect_retries + 1):
            try:
                return await asyncio.wait_for(
                    asyncio.open_connection(self.host, self.port),
                    self.timeout_s,
                )
            except (OSError, asyncio.TimeoutError):
                if attempt >= self.connect_retries:
                    raise
                self.retries += 1
                await asyncio.sleep(
                    self.backoff_s * (2 ** attempt) * (1 + random.random())
                )
        raise ConnectionError("unreachable")  # loop always returns/raises

    async def _keepalive(
        self,
    ) -> Tuple[asyncio.StreamReader, asyncio.StreamWriter, bool]:
        """Returns (reader, writer, reused): ``reused`` is True when an
        existing pooled connection was handed out — the only case a
        failed round trip may be retried (see ``request``)."""
        if self._writer is None or self._writer.is_closing():
            self._reader, self._writer = await self._connect()
            return self._reader, self._writer, False
        return self._reader, self._writer, True

    async def close(self) -> None:
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except (ConnectionError, OSError):
                pass
            self._reader = self._writer = None

    async def __aenter__(self) -> "Client":
        return self

    async def __aexit__(self, *exc) -> None:
        await self.close()

    def _headers(self, tenant: Optional[str]) -> dict:
        t = tenant if tenant is not None else self.tenant
        return {"X-Tenant": t} if t is not None else {}

    # -- plain JSON round trips -----------------------------------------
    async def request(
        self,
        method: str,
        path: str,
        body: Optional[dict] = None,
        headers: Optional[dict] = None,
    ) -> Tuple[int, dict, bytes]:
        """One keep-alive round trip: (status, headers, raw body bytes).

        Retried exactly once, and only when a REUSED pooled connection
        failed — the server closing an idle keep-alive socket between
        requests is indistinguishable from a send into a dead pipe, so
        the request is re-sent (after a jittered backoff) on a fresh
        connection. A failure on a fresh connection is never retried: for
        non-idempotent POSTs the first attempt may have executed
        server-side, and blind re-sends would double the device work.

        Every read is capped at ``timeout_s``: a server that accepted the
        request and then died mid-response raises ``asyncio.TimeoutError``
        here instead of hanging the caller forever. Timeouts are never
        retried (the request may be executing); the connection is closed
        so a late response cannot corrupt the next round trip.
        """
        payload = None if body is None else json.dumps(body).encode("utf-8")
        raw = _request_bytes(method, path, self.host, payload, headers)
        while True:
            reader, writer, reused = await self._keepalive()
            try:
                writer.write(raw)
                await writer.drain()
                status, hdrs = await asyncio.wait_for(
                    _read_response_head(reader), self.timeout_s
                )
                n = int(hdrs.get("content-length", 0))
                data = (
                    await asyncio.wait_for(reader.readexactly(n), self.timeout_s)
                    if n
                    else b""
                )
            except asyncio.TimeoutError:
                await self.close()
                raise
            except (ConnectionError, asyncio.IncompleteReadError, OSError):
                await self.close()
                if not reused:
                    raise
                self.retries += 1
                await asyncio.sleep(self.backoff_s * (1 + random.random()))
                continue  # stale pooled socket: one fresh-connection retry
            if hdrs.get("connection", "").lower() == "close":
                await self.close()
            return status, hdrs, data

    async def _json(
        self,
        method: str,
        path: str,
        body: Optional[dict] = None,
        headers: Optional[dict] = None,
    ) -> dict:
        status, _, data = await self.request(method, path, body, headers)
        obj = json.loads(data) if data else {}
        if status >= 400:
            raise HttpError(status, obj)
        return obj

    # -- API surface -----------------------------------------------------
    async def generate(
        self,
        prompt,
        max_new: Optional[int] = None,
        tenant: Optional[str] = None,
        deadline_ms: Optional[float] = None,
        debug: bool = False,
    ) -> dict:
        """``debug=True`` asks the server for the per-request phase
        breakdown (``phases`` key: queue/prefill/decode ms + cache
        savings) alongside the usual summary."""
        body: dict = {"prompt": [int(t) for t in prompt]}
        if max_new is not None:
            body["max_new"] = max_new
        if deadline_ms is not None:
            body["deadline_ms"] = deadline_ms
        if debug:
            body["debug"] = True
        return await self._json(
            "POST", "/v1/generate", body, self._headers(tenant)
        )

    async def stream(
        self,
        prompt,
        max_new: Optional[int] = None,
        tenant: Optional[str] = None,
        deadline_ms: Optional[float] = None,
        debug: bool = False,
    ) -> AsyncIterator[Tuple[str, dict]]:
        """Async iterator of SSE frames as ``(event, data)`` pairs: one
        ``("start", {"rid": ...})`` (the id to DELETE for a mid-stream
        ``cancel``), ``("message", {"index": i, "token": t})`` per token,
        then one ``("done", {...summary})`` — with ``"status":
        "cancelled"`` and the partial token count if the request was
        cancelled mid-stream. ``debug=True`` adds the ``phases``
        breakdown to the ``done`` payload. Raises HttpError on rejection
        — either pre-admission (the server answers with the mapped status
        instead of a stream) or post-admission (a terminal ``error``
        event carrying the mapped status, e.g. a deadline that expired
        while queued or mid-flight)."""
        body: dict = {"prompt": [int(t) for t in prompt]}
        if max_new is not None:
            body["max_new"] = max_new
        if deadline_ms is not None:
            body["deadline_ms"] = deadline_ms
        if debug:
            body["debug"] = True
        payload = json.dumps(body).encode("utf-8")
        reader, writer = await self._connect()  # dedicated conn per stream
        try:
            writer.write(
                _request_bytes(
                    "POST", "/v1/stream", self.host, payload,
                    self._headers(tenant),
                )
            )
            await writer.drain()
            status, hdrs = await asyncio.wait_for(
                _read_response_head(reader), self.timeout_s
            )
            if status >= 400:
                n = int(hdrs.get("content-length", 0))
                data = (
                    await asyncio.wait_for(reader.readexactly(n), self.timeout_s)
                    if n
                    else b""
                )
                raise HttpError(status, json.loads(data) if data else {})
            event, data_lines = "message", []
            while True:
                # per-frame cap: a server that dies (or a dropped socket
                # the kernel hasn't noticed) mid-stream surfaces as a
                # TimeoutError after timeout_s, not an eternal hang
                line = await asyncio.wait_for(reader.readline(), self.timeout_s)
                if not line:  # server closed: end of stream
                    return
                line = line.rstrip(b"\r\n").decode("utf-8")
                if not line:  # blank line terminates one SSE frame
                    if data_lines:
                        data = json.loads("\n".join(data_lines))
                        if event == "error":  # rejected after admission
                            raise HttpError(data.get("status", 500), data)
                        yield event, data
                        if event == "done":
                            return
                    event, data_lines = "message", []
                elif line.startswith("event:"):
                    event = line.split(":", 1)[1].strip()
                elif line.startswith("data:"):
                    data_lines.append(line.split(":", 1)[1].strip())
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def cancel(self, rid: int) -> dict:
        """DELETE /v1/requests/{rid}. Returns ``{"cancelled": true}`` on
        success; raises HttpError(404) for unknown/finished rids."""
        return await self._json("DELETE", f"/v1/requests/{int(rid)}")

    async def healthz(self) -> dict:
        status, _, data = await self.request("GET", "/healthz")
        obj = json.loads(data)
        if status >= 400 and obj.get("status") != "draining":
            raise HttpError(status, obj)
        return obj

    async def metrics(self) -> str:
        status, _, data = await self.request("GET", "/metrics")
        if status >= 400:
            raise HttpError(status, data.decode("utf-8", "replace"))
        return data.decode("utf-8")

    async def drain(self) -> dict:
        return await self._json("POST", "/admin/drain")

    async def trace(self) -> dict:
        """Chrome trace-event JSON from ``GET /admin/trace`` — dump it to
        a file and open in Perfetto (see docs/observability.md)."""
        return await self._json("GET", "/admin/trace")
