"""ServeEngine: continuous-batching inference over packed FloatSD8 weights.

Lifecycle per request: queue -> (admission to a free lane) -> chunked
prefill -> decode -> retire. All B lanes advance in ONE jitted step per
iteration:

  * each active lane contributes a per-lane length k: a prefilling lane
    consumes ``min(remaining_prompt, chunk)`` tokens, a decoding lane
    exactly 1;
  * the token block is [B, S] with S in {1, chunk} (bucketed so jit
    compiles at most two shapes); positions >= k are padding and the
    lengths-masked LSTM scan freezes that lane's state there;
  * lanes freshly re-armed get their state slab zeroed by a masked reset
    fused into the same step;
  * the step consuming a lane's final prompt token doubles as its first
    generation step (the last valid logit predicts token 1) — a prompt of
    length L costs ceil(L/chunk) steps instead of the L steps the old
    one-token-per-step force-feed loop paid.

Weights are served from the packed uint8 store by default (decode-at-use
inside the jitted step); ``packed=False`` keeps the seed's dense
fake-quant-at-use path for A/B comparison.
"""
from __future__ import annotations

import inspect
import time
from typing import Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..faults import (
    ENGINE_STEP_RAISE,
    ENGINE_STEP_SLOW,
    FAULTS,
    InjectedFault,
    NONFINITE_LOGITS,
    REPLICA_CRASH,
    ReplicaCrash,
)
from ..obs.trace import TRACER
from .metrics import ServeMetrics
from .scheduler import Request, Scheduler
from .state_pool import StatePool, masked_reset
from .weight_store import WEIGHT_FORMATS, WeightStore, unpack_tree

__all__ = ["ServeEngine", "Lane"]


class Lane:
    """Host-side bookkeeping for one decode lane."""

    __slots__ = ("req", "pos", "next_token")

    def __init__(self, req: Request):
        self.req = req
        self.pos = 0  # prompt tokens consumed so far
        self.next_token = 0  # token to feed when decoding

    @property
    def prefilling(self) -> bool:
        return self.pos < self.req.prompt_len


class ServeEngine:
    """Continuous-batching engine: one instance owns `lanes` decode lanes,
    a StatePool slab, and (packed) the uint8 WeightStore view of params.

    Admission → inject → prefill lifecycle (per request, the contract the
    frontend relies on): ``_arm_free_lanes`` binds the next scheduled
    request to a free lane; if a prefix cache is attached, admission does
    a trie ``lookup`` on the prompt first — on a hit the cached FP8 state
    is dequantized and **injected** into the lane's slab slice
    (``StatePool.inject``, replacing the masked reset) and prefill starts
    at the match point; a *full* hit replays the stored ``next_token`` at
    admission, so the request reaches first-token with zero device steps.
    Prefill then consumes ``min(remaining, chunk)`` prompt tokens per
    batched step (inserting block-boundary cache snapshots via
    ``wants_snapshot``), decode emits one token per step, and retire
    frees the lane and (``wants``) stores the final state keyed by
    prompt + generated[:-1].

    Cancellation (``cancel(rid)``) removes a request wherever it lives:
    scheduler removal while queued, masked lane release while bound (the
    free is folded into the step's existing reset mask — zero extra device
    steps). Preemption (``preempt=True``) lets ``step_once`` displace the
    longest-remaining decoding lane when the queue head owes much less
    work: the lane's (h, c) is snapshotted to host FP8 (the prefix cache's
    format and error bound), the victim requeued, and the snapshot
    restored on re-admission.

    Concurrency contract: the engine is **not thread-safe** — ``submit``
    / ``enqueue`` / ``step_once`` / ``run`` / ``cancel`` must be
    serialized by the caller (the Router calls them from its pump;
    AsyncRouter serializes pumps under its lock). ``step_once`` blocks the
    calling thread on one jitted device step; everything else is host-side
    bookkeeping. Load introspection (``free_lanes`` / ``load`` /
    ``has_work``) reads plain host state and is safe to call between
    steps.
    """

    def __init__(
        self,
        model,
        params,
        policy,
        lanes: int = 8,
        chunk: int = 8,
        admission: str = "fifo",
        packed: bool = True,
        cache_len: int | None = None,
        greedy: bool = True,
        prefix_cache=None,
        preempt: bool = False,
        preempt_margin: int = 8,
        preempt_max: int = 2,
        admit_pace: int | None = None,
        weight_format: str = "floatsd8",
    ):
        if chunk < 1:
            raise ValueError("chunk must be >= 1")
        if admit_pace is not None and admit_pace < 1:
            raise ValueError("admit_pace must be >= 1 (or None to disable)")
        if weight_format not in WEIGHT_FORMATS:
            raise ValueError(
                f"weight_format must be one of {WEIGHT_FORMATS}, "
                f"got {weight_format!r}"
            )
        del greedy  # argmax decoding only, for now
        self.model = model
        self.policy = policy
        self.lanes_n = lanes
        self.scheduler = Scheduler(admission)
        self.metrics = ServeMetrics(lanes)

        # Packed path: weights become uint8 codes; re-running the fake-quant
        # weight pass on already-decoded grid values would be redundant work
        # (decode(encode(w)) == quantize(w).values, see weight_store), so
        # the serving policy drops weight_quant.
        if packed and policy.weight_quant != "floatsd8":
            raise ValueError(
                f"packed=True serves FloatSD8-quantized weights, but policy "
                f"{policy.name!r} has weight_quant={policy.weight_quant!r} — "
                f"serving packed would silently change the model's outputs; "
                f"pass packed=False (CLI: --dense) for unquantized policies"
            )
        # weight_format="floatsd4" re-quantizes the FloatSD8 master to the
        # sub-byte format (2 codes/byte + group exponents): not
        # output-identical to the trained model — an explicit accuracy/
        # footprint trade, gated by the accuracy test in test_serving.py.
        self.weight_format = weight_format
        if packed:
            self.store: Optional[WeightStore] = WeightStore.pack(
                params, fmt=weight_format
            )
            self.serve_params = self.store.tree
            self.serve_policy = policy.replace(weight_quant="none")
        else:
            self.store = None
            self.serve_params = params
            self.serve_policy = policy

        # Models without lengths support (transformer KV decode) can only
        # advance lanes in lockstep -> force one-token steps.
        self._supports_lengths = (
            "lengths" in inspect.signature(model.decode_step).parameters
        )
        self.chunk = chunk if self._supports_lengths else 1

        self.pool = StatePool.for_model(model, lanes, policy, cache_len=cache_len)
        # Continuous batching (re-arming a used lane) requires every cache
        # leaf to be lane-major so masked_reset can actually clear it; a
        # cache with shared leaves (scalar positions, layer-major stacks)
        # would silently leak the previous request's state into the next.
        # Shape alone can't prove lane-majorness (a layer-major stack whose
        # group count happens to equal `lanes` would false-positive), so
        # require lengths support too: a model that freezes state per-lane
        # necessarily keeps its recurrent state lane-major.
        self._rearmable = self._supports_lengths and all(
            hasattr(l, "ndim") and l.ndim >= 1 and l.shape[0] == lanes
            for l in jax.tree_util.tree_leaves(self.pool.caches)
        )
        # Optional frontend prefix cache (duck-typed: lookup / insert /
        # wants / wants_snapshot — see serving/frontend/prefix_cache.py).
        # Injection overwrites a lane's whole state slice, which only makes
        # sense when every cache leaf is lane-major.
        if prefix_cache is not None and not self._rearmable:
            raise ValueError(
                "prefix caching requires a per-lane resettable (lane-major) "
                "state pool — an LSTM-family model with lengths support"
            )
        self.prefix_cache = prefix_cache
        # Lane preemption: snapshot a decoding lane's (h, c) to host FP8
        # (StatePool.snapshot_fp8 — the prefix cache's storage format and
        # error bound), requeue the request, hand the lane to shorter
        # queued work, and restore the snapshot when the request is
        # re-admitted. Same lane-major requirement as injection.
        if preempt and not self._rearmable:
            raise ValueError(
                "preempt=True requires a per-lane resettable (lane-major) "
                "state pool — an LSTM-family model with lengths support"
            )
        self.preempt = preempt
        self.preempt_margin = preempt_margin
        self.preempt_max = preempt_max
        self.admit_pace = admit_pace
        # rid -> (fp8 snapshot, dtypes, next_token, pos) for requests
        # preempted off a lane and waiting in the scheduler to resume
        self._preempted: dict[int, tuple] = {}
        self._lanes: list[Lane | None] = [None] * lanes
        self._lane_used = [False] * lanes
        self._reset = np.zeros((lanes,), np.int32)
        self._rid = 0
        # Replica identity (stamped by the Router) keys fault rules to a
        # specific engine; `_crashed` is the sticky replica_crash state —
        # once set, every step raises until the process restarts.
        self.replica: int | None = None
        self._crashed = False

        model_ = model
        pol = self.serve_policy
        supports_lengths = self._supports_lengths
        # Packed-aware models (supports_packed) consume PackedTensor leaves
        # at their weight sites through the kernel dispatch layer — the
        # engine hands them the 1-byte codes untouched and the dispatch
        # resolver picks decode-hoist (ref) or the fused decode-in-VMEM
        # Pallas matmul per site. Models without that flag get the legacy
        # whole-tree decode so arbitrary decode_steps keep working.
        unpack_in_step = packed and not getattr(model, "supports_packed", False)

        def _step(params, tokens, lengths, caches, reset_mask):
            caches = masked_reset(caches, reset_mask)
            if unpack_in_step:
                params = unpack_tree(params)
            if supports_lengths:
                logits, caches = model_.decode_step(
                    params, tokens, caches, pol, lengths=lengths
                )
                idx = jnp.clip(lengths - 1, 0, tokens.shape[1] - 1)
                last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0, :]
            else:
                logits, caches = model_.decode_step(params, tokens, caches, pol)
                last = logits[:, -1, :]
            nxt = jnp.argmax(last, -1).astype(jnp.int32)
            # Nonfinite guard: jnp.argmax over an all-NaN row silently
            # returns index 0 — a poisoned lane would emit token 0 forever
            # and its NaN state would bleed into the prefix cache. Flag
            # per-lane logit health here, where the logits still exist.
            ok = jnp.all(jnp.isfinite(last), axis=-1)
            return nxt, ok, caches

        # Donate the cache slab: the pre-step state is never read after the
        # call (pool.swap installs the result), so XLA can update the lane
        # state in place instead of keeping two copies live per step.
        self._step = jax.jit(_step, donate_argnums=(3,))

    # -- request intake --------------------------------------------------
    def submit(
        self,
        prompt,
        max_new: int = 32,
        tenant: str = "default",
        deadline: float | None = None,
    ) -> Request:
        req = Request(
            rid=self._rid,
            prompt=np.asarray(prompt),
            max_new=max_new,
            tenant=tenant,
            deadline=deadline,
        )
        self._rid += 1
        return self.enqueue(req)

    def enqueue(self, req: Request) -> Request:
        """Queue an externally constructed Request (the router path — the
        frontend owns rids/tenants/deadlines and load-balances across
        engine replicas)."""
        return self.scheduler.submit(req)

    def submit_all(self, prompts: Iterable, max_new: int = 32) -> list[Request]:
        return [self.submit(p, max_new) for p in prompts]

    # -- router-facing load introspection --------------------------------
    @property
    def free_lanes(self) -> int:
        return sum(l is None for l in self._lanes)

    @property
    def active_lanes(self) -> int:
        return self.lanes_n - self.free_lanes

    def has_work(self) -> bool:
        return self.active_lanes > 0 or bool(self.scheduler)

    @property
    def load(self) -> float:
        """Active lanes + backlog, per lane — the router's least-loaded
        balancing key."""
        return (self.active_lanes + len(self.scheduler)) / self.lanes_n

    # -- lane lifecycle --------------------------------------------------
    def _arm_free_lanes(self) -> None:
        armed = 0
        for i in range(self.lanes_n):
            # `while`, not `if`: a full prefix-cache hit with max_new == 1
            # retires at admission time without consuming a device step, so
            # the same slot can drain several queued requests in a row.
            while self._lanes[i] is None and self.scheduler:
                if self.admit_pace is not None and armed >= self.admit_pace:
                    # pacing: spread admissions over steps so a warm burst
                    # (cheap full hits arriving faster than lanes drain)
                    # cannot monopolize every freed lane in one round
                    return
                if self._lane_used[i] and not self._rearmable:
                    raise RuntimeError(
                        "cannot re-arm a used lane: this model's cache has "
                        "non-lane-major leaves that masked_reset cannot "
                        "clear per-lane; serve at most `lanes` requests per "
                        "engine (or use an LSTM-family model)"
                    )
                req = self.scheduler.pop()
                armed += 1
                # stamped per admission, not once per call: a slow cache
                # lookup for lane j would otherwise be billed to the queue
                # phase of every lane armed after it
                now = time.monotonic()
                if req.t_admit is None:
                    req.t_admit = now  # queue wait ends; prefill begins
                lane = Lane(req)
                self._lanes[i] = lane
                self._lane_used[i] = True
                stash = self._preempted.pop(req.rid, None)
                if stash is not None:
                    # resuming a preempted decode: restore the FP8 snapshot
                    # instead of reset-and-prefill; the request keeps its
                    # original t_admit/t_first so the preempted wait shows
                    # up in the decode phase it actually delayed
                    snap, dtypes, next_token, pos = stash
                    self.pool.inject_fp8(i, snap, dtypes)
                    self._reset[i] = 0
                    lane.pos = pos
                    lane.next_token = next_token
                    self.metrics.resumes += 1
                    if TRACER.enabled:
                        TRACER.instant(
                            "engine.resume", cat="engine", rid=req.rid,
                            lane=i, decoded=len(req.out),
                        )
                    break
                hit = None
                if self.prefix_cache is not None:
                    with TRACER.span("cache.lookup", cat="cache", rid=req.rid):
                        hit = self.prefix_cache.lookup(req.prompt)
                    self.metrics.on_cache_lookup(
                        hit=hit is not None,
                        full=hit is not None and hit.full,
                        saved=hit.match_len if hit is not None else 0,
                    )
                    if hit is not None:
                        req.cache_hit = True
                        req.cache_saved_tokens = hit.match_len
                        # whole prefill steps the injection replaced; the
                        # residual partial chunk merges into the suffix step
                        req.cache_saved_steps = hit.match_len // self.chunk
                if TRACER.enabled:
                    TRACER.instant(
                        "engine.admit", cat="engine", rid=req.rid, lane=i,
                        prompt_len=req.prompt_len,
                        cache=(
                            "full" if (hit is not None and hit.full)
                            else "hit" if hit is not None else "miss"
                        ),
                        saved_tokens=req.cache_saved_tokens,
                    )
                if hit is None:
                    self._reset[i] = 1  # zeroed inside the next jitted step
                    break
                # Inject the cached prefix state instead of resetting: the
                # snapshot overwrites every leaf of the lane slice, and a
                # masked reset afterwards would zero it again.
                self.pool.inject(i, hit.states)
                self._reset[i] = 0
                lane.pos = hit.match_len
                if hit.full:
                    # Whole prompt cached: the stored greedy continuation IS
                    # the first generated token — prefill is skipped
                    # entirely and TTFT costs zero device steps.
                    self._emit(lane, hit.next_token, now, first=True)
                    if req.done:
                        self._retire(i)
                        continue
                break

    def _retire(self, i: int, status: str = "done", reason: str | None = None) -> None:
        """Unbind lane ``i`` terminally. ``status="done"`` is the normal
        completion path; ``status="cancelled"`` is the same bookkeeping
        with cancel-side accounting — one retire path keeps the
        metrics/tracer/prefix-cache invariants identical either way."""
        lane = self._lanes[i]
        req = lane.req
        now = time.monotonic()
        req.t_done = now  # decode phase ends; req.phases() is now total
        req.status = status
        if status != "done":
            req.cancel_reason = reason
        if (
            self.prefix_cache is not None
            and status != "numeric_error"  # never cache a poisoned state
            and len(req.out) >= 2
            and not lane.prefilling
        ):
            # The lane's final state summarizes prompt + out[:-1] (the last
            # generated token was emitted but never fed back); out[-1] is
            # its exact greedy continuation. Serves resubmissions that
            # extend this conversation — and salvages the prefill a
            # cancelled request already paid for.
            key = np.concatenate(
                [req.prompt, np.asarray(req.out[:-1], np.int32)]
            )
            if self.prefix_cache.wants(key, len(key)):
                with TRACER.span("cache.insert", cat="cache", rid=req.rid):
                    self.prefix_cache.insert(
                        key, self.pool.extract(i), next_token=req.out[-1]
                    )
        if status == "done":
            self.metrics.on_retire(req, now)
        elif status == "numeric_error":
            self.metrics.on_numeric_error(req)
            self._reset[i] = 1  # wipe the poisoned state via the mask
        else:
            self.metrics.on_cancel(req, reason or "cancelled")
            # fold the lane release into the existing reset mask: the next
            # jitted step zeroes the dead state as part of work it was
            # doing anyway — cancellation costs zero extra device steps
            self._reset[i] = 1
        if TRACER.enabled:
            if status == "done":
                TRACER.instant(
                    "engine.retire", cat="engine", rid=req.rid, lane=i,
                    new_tokens=len(req.out),
                )
            elif status == "numeric_error":
                TRACER.instant(
                    "engine.numeric_error", cat="engine", rid=req.rid,
                    lane=i, new_tokens=len(req.out),
                    reason=reason or "nonfinite_logits",
                )
            else:
                TRACER.instant(
                    "engine.cancel", cat="engine", rid=req.rid, lane=i,
                    new_tokens=len(req.out), reason=reason or "cancelled",
                )
        self._lanes[i] = None

    # -- cancellation ----------------------------------------------------
    def cancel(self, rid: int, reason: str = "cancelled") -> bool:
        """Terminally remove a request wherever it currently lives: still
        queued → scheduler removal; bound to a lane → masked lane release
        (the free rides the reset mask of the next step, costing zero
        extra device work). Idempotent: unknown / already-finished rids
        return False. Host-side only — safe between steps under the same
        serialization contract as ``step_once``."""
        req = self.scheduler.remove(rid)
        if req is not None:
            self._preempted.pop(rid, None)  # preempted-and-requeued state
            req.status = "cancelled"
            req.cancel_reason = reason
            req.t_done = time.monotonic()
            self.metrics.on_cancel(req, reason)
            if TRACER.enabled:
                TRACER.instant(
                    "engine.cancel", cat="engine", rid=rid,
                    new_tokens=len(req.out), reason=reason,
                )
            return True
        for i, lane in enumerate(self._lanes):
            if lane is not None and lane.req.rid == rid:
                self._retire(i, status="cancelled", reason=reason)
                return True
        return False

    # -- preemption ------------------------------------------------------
    def _maybe_preempt(self) -> None:
        """If every lane is busy and the queue head owes far less work
        than some decoding lane, snapshot that lane to host FP8 and hand
        it over (SJF with bounded regret: the victim resumes later from
        the snapshot). Only decoding lanes with at least one emitted token
        are candidates — their TTFT is already banked, so preemption can
        only improve the first-token tail, never worsen it."""
        if not self.preempt or self.free_lanes > 0 or not self.scheduler:
            return
        cand = self.scheduler.peek()
        if cand is None:
            return
        if cand.work_hint is None and self.prefix_cache is not None:
            # the router stamps work_hint at submission; engine-direct
            # submissions get the same probe here (non-mutating)
            cand.work_hint = self.prefix_cache.match_len(cand.prompt)
        cand_work = cand.remaining_work()
        victim, victim_rem = None, -1
        for i, lane in enumerate(self._lanes):
            if lane is None or lane.prefilling or not lane.req.out:
                continue
            if lane.req.preempt_count >= self.preempt_max:
                continue  # bounded thrash: a request is displaced at most preempt_max times
            rem = lane.req.max_new - len(lane.req.out)
            if rem > victim_rem:
                victim, victim_rem = i, rem
        if victim is None or victim_rem < cand_work + self.preempt_margin:
            return
        self._preempt_lane(victim)

    def _preempt_lane(self, i: int) -> None:
        lane = self._lanes[i]
        req = lane.req
        snap, dtypes = self.pool.snapshot_fp8(i)
        self._preempted[req.rid] = (snap, dtypes, lane.next_token, lane.pos)
        req.preempt_count += 1
        self.metrics.preemptions += 1
        if TRACER.enabled:
            TRACER.instant(
                "engine.preempt", cat="engine", rid=req.rid, lane=i,
                decoded=len(req.out),
                remaining=req.max_new - len(req.out),
            )
        self._lanes[i] = None
        self._reset[i] = 1  # freed state is wiped by the next step's mask
        self.scheduler.submit(req)  # t_submit preserved; resumes via stash

    # -- replica failure -------------------------------------------------
    def _check_faults(self) -> None:
        """Injection points at the top of ``step_once`` — the boundary the
        Router's per-replica health watches. Only reached when a plan is
        armed (``FAULTS.enabled`` gates the call)."""
        if FAULTS.fire(REPLICA_CRASH, key=self.replica) is not None:
            self._crashed = True  # sticky: dead until process restart
        if self._crashed:
            raise ReplicaCrash(f"replica {self.replica} crashed")
        f = FAULTS.fire(ENGINE_STEP_SLOW, key=self.replica)
        if f is not None:
            time.sleep(float(f.get("ms", 50)) / 1000.0)
        if FAULTS.fire(ENGINE_STEP_RAISE, key=self.replica) is not None:
            raise InjectedFault(
                f"injected step error on replica {self.replica}"
            )

    def evacuate(self) -> list[Request]:
        """Strip every live request off this replica so the Router can
        resubmit them elsewhere (ejection path). Requests are rewound to
        their pre-admission state — generated tokens cleared (greedy
        decode is deterministic, so a healthy replica regenerates the
        identical stream and the ticket's ``sent`` cursor deduplicates
        delivery) — but keep their original ``t_submit``/``t_first`` so
        latency accounting stays honest across the move."""
        out: list[Request] = []
        while self.scheduler:
            out.append(self.scheduler.pop())
        for i, lane in enumerate(self._lanes):
            if lane is not None:
                out.append(lane.req)
                self._lanes[i] = None
        self._preempted.clear()
        for req in out:
            req.out.clear()
            req.status = "active"
            req.preempt_count = 0
        return out

    # -- the batched step ------------------------------------------------
    def step_once(self) -> bool:
        """Advance every active lane one scheduling quantum. Returns False
        when there is nothing left to do. Raises :class:`ReplicaCrash` /
        :class:`InjectedFault` under an armed fault plan — the Router's
        health layer catches these and ejects or retries."""
        if self._crashed:
            raise ReplicaCrash(f"replica {self.replica} crashed")
        if FAULTS.enabled:
            self._check_faults()
        self._maybe_preempt()
        self._arm_free_lanes()
        active = [i for i, l in enumerate(self._lanes) if l is not None]
        if not active:
            return False

        B, chunk = self.lanes_n, self.chunk
        ks = np.zeros((B,), np.int32)
        any_prefill = False
        for i in active:
            lane = self._lanes[i]
            if lane.prefilling:
                ks[i] = min(lane.req.prompt_len - lane.pos, chunk)
                if ks[i] > 1:
                    any_prefill = True
            else:
                ks[i] = 1
        # Bucket the block width to {1, chunk} so jit sees two shapes total.
        S = chunk if any_prefill else 1
        tokens = np.zeros((B, S), np.int32)
        for i in active:
            lane = self._lanes[i]
            k = int(ks[i])
            if lane.prefilling:
                tokens[i, :k] = lane.req.prompt[lane.pos : lane.pos + k]
            else:
                tokens[i, 0] = lane.next_token

        # Hand the device a buffer we will never touch again: jnp.asarray
        # can zero-copy ALIAS a numpy array on CPU, and jit dispatch is
        # async — mutating self._reset in place after the call would race
        # the computation reading it (observed: lost resets corrupting
        # re-armed lanes). A fresh zeros array per step sidesteps aliasing;
        # tokens/ks are likewise freshly allocated and never mutated.
        reset, self._reset = self._reset, np.zeros((B,), np.int32)
        # Per-lane attribution without per-lane cost: one span per batched
        # step (the engine's unit of device work) carrying the lane→rid map
        # and each lane's token count. Arg construction is guarded so the
        # disabled tracer costs one branch on this hot path.
        step_span = (
            TRACER.span(
                "engine.step", cat="engine",
                kind="prefill" if any_prefill else "decode",
                width=S, useful=int(ks.sum()),
                lanes={
                    str(i): {"rid": self._lanes[i].req.rid, "k": int(ks[i])}
                    for i in active
                },
            )
            if TRACER.enabled
            else TRACER.span("engine.step")
        )
        with step_span:
            nxt, ok, caches = self._step(
                self.serve_params,
                jnp.asarray(tokens),
                jnp.asarray(ks),
                self.pool.caches,
                jnp.asarray(reset),
            )
            nxt = np.asarray(nxt)  # sync point: step outputs materialized
            ok = np.asarray(ok)
        self.pool.swap(caches)
        if FAULTS.enabled and FAULTS.fire(NONFINITE_LOGITS, key=self.replica):
            # Poison the host copy of one active lane's health flag: the
            # recovery path below is identical to a real device-side NaN
            # (tests inject actual NaN params to pin the jnp.isfinite leg).
            # np.asarray of a device array is a read-only zero-copy view,
            # so take a writable copy here (off the fault-free hot path).
            ok = ok.copy()
            ok[active[0]] = False

        self.metrics.on_step(
            width=S,
            active=len(active),
            useful=int(ks.sum()),
            any_prefill=any_prefill,
        )
        now = time.monotonic()
        cache = self.prefix_cache
        for i in active:
            lane = self._lanes[i]
            if not ok[i]:
                # Nonfinite logits: never sample from NaN (the argmax
                # result is garbage), never let the poisoned state reach
                # the prefix cache or the next step — retire the request
                # with a distinct status and fold the lane wipe into the
                # reset mask, exactly like a cancel.
                self._retire(i, status="numeric_error",
                             reason="nonfinite_logits")
                continue
            if lane.prefilling:
                lane.pos += int(ks[i])
                self.metrics.prompt_tokens += int(ks[i])
                if not lane.prefilling:
                    # final prompt chunk consumed: this step's last valid
                    # logit is the first generated token
                    self._emit(lane, int(nxt[i]), now, first=True)
                    if cache is not None and cache.wants(
                        lane.req.prompt, lane.req.prompt_len
                    ):
                        # state after the whole prompt + its exact greedy
                        # continuation -> future identical prompts skip
                        # prefill entirely
                        cache.insert(
                            lane.req.prompt,
                            self.pool.extract(i),
                            next_token=int(nxt[i]),
                        )
                elif cache is not None and cache.wants_snapshot(
                    lane.req.prompt, lane.pos
                ):
                    # block-boundary snapshot mid-prefill: what makes
                    # *shared-prefix* (not just identical) prompts hit
                    cache.insert(
                        lane.req.prompt[: lane.pos], self.pool.extract(i)
                    )
            else:
                self._emit(lane, int(nxt[i]), now)
            if lane.req.done:
                self._retire(i)
        return True

    def _emit(self, lane: Lane, tok: int, now: float, first: bool = False) -> None:
        if first and lane.req.t_first is None:
            lane.req.t_first = now
        lane.req.out.append(tok)
        lane.next_token = tok
        self.metrics.emitted += 1

    # -- drain -----------------------------------------------------------
    def run(self) -> ServeMetrics:
        """Serve until the queue and all lanes are drained."""
        # Fail fast instead of raising mid-run (discarding finished work):
        # a non-rearmable cache can serve at most `lanes` requests total.
        outstanding = len(self.scheduler) + sum(
            l is not None for l in self._lanes
        )
        if not self._rearmable and outstanding > self.lanes_n:
            raise ValueError(
                f"{outstanding} requests queued but this model's cache "
                f"cannot be reset per-lane (non-lane-major leaves); submit "
                f"at most lanes={self.lanes_n} requests per engine, or use "
                f"an LSTM-family model for continuous batching"
            )
        self.metrics.start()
        while self.step_once():
            pass
        self.metrics.stop()
        return self.metrics
