"""Synthetic corpora for the paper's four NLP tasks (offline container —
DESIGN.md §2). Each generator is statistically shaped like its real dataset
(vocabulary sizes, sequence lengths, label structure) and *learnable*, so
FP32-vs-FloatSD8 training-curve comparisons exercise the same mechanics the
paper's Fig. 6 does: embedding lookups, recurrent credit assignment,
classification/seq2seq/LM losses.

  UDPOS      : tag follows word-class; word-class clusters the vocab ids.
  SNLI       : entailment iff hypothesis is a (noised) subset of premise;
               contradiction iff it overlaps a shuffled anti-premise.
  Multi30K   : 'translation' = deterministic vocab permutation + local
               reordering (captures alignment + reordering learning).
  WikiText-2 : Zipf-distributed 2nd-order Markov chain over 33278 tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["udpos", "snli", "multi30k", "wikitext2", "TaskSpec"]


@dataclasses.dataclass
class TaskSpec:
    name: str
    vocab: int
    n_labels: int
    batches: Iterator
    eval_batches: Iterator


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
def udpos(batch=64, seq=32, vocab=8000, n_tags=18, seed=0, eval_seed=10_000):
    """Words are drawn per-tag from disjoint-ish vocab bands with a tag
    bigram grammar — POS tagging reduced to its statistical core."""

    def gen(seed):
        r = _rng(seed)
        # tag transition grammar + per-tag word bands (with 10% band noise)
        trans = r.dirichlet(np.full(n_tags, 0.3), size=n_tags)
        band = vocab // n_tags
        while True:
            tags = np.zeros((batch, seq), np.int32)
            tags[:, 0] = r.integers(0, n_tags, batch)
            for t in range(1, seq):
                cum = trans[tags[:, t - 1]].cumsum(-1)
                tags[:, t] = (cum < r.random((batch, 1))).sum(-1)
            words = tags * band + r.integers(0, band, (batch, seq))
            noise = r.random((batch, seq)) < 0.10
            words = np.where(noise, r.integers(0, vocab, (batch, seq)), words)
            mask = np.ones((batch, seq), np.int32)
            yield {"tokens": words.astype(np.int32), "labels": tags, "mask": mask}

    return TaskSpec("udpos", vocab, n_tags, gen(seed), gen(eval_seed))


# ---------------------------------------------------------------------------
def snli(batch=128, seq=24, vocab=20000, seed=1, eval_seed=10_001):
    def gen(seed):
        r = _rng(seed)
        while True:
            prem = r.integers(4, vocab, (batch, seq)).astype(np.int32)
            label = r.integers(0, 3, batch).astype(np.int32)
            hyp = np.zeros_like(prem)
            for i in range(batch):
                if label[i] == 0:  # entailment: subset + noise
                    idx = r.permutation(seq)[: seq // 2]
                    hyp[i, : seq // 2] = prem[i, np.sort(idx)]
                    hyp[i, seq // 2 :] = prem[i, r.integers(0, seq, seq - seq // 2)]
                elif label[i] == 1:  # contradiction: anti-premise band
                    hyp[i] = (prem[i] + vocab // 2) % vocab
                else:  # neutral: unrelated
                    hyp[i] = r.integers(4, vocab, seq)
            yield {"premise": prem, "hypothesis": hyp, "label": label}

    return TaskSpec("snli", vocab, 3, gen(seed), gen(eval_seed))


# ---------------------------------------------------------------------------
def multi30k(batch=128, seq=20, vocab=8000, seed=2, eval_seed=10_002):
    def gen(seed):
        r = _rng(seed)
        perm = _rng(42).permutation(vocab)  # fixed "bilingual dictionary"
        while True:
            src = r.integers(4, vocab, (batch, seq)).astype(np.int32)
            tgt = perm[src].astype(np.int32)
            # local reordering: swap adjacent pairs at even positions
            tgt_r = tgt.copy()
            tgt_r[:, 0:-1:2], tgt_r[:, 1::2] = tgt[:, 1::2], tgt[:, 0:-1:2]
            bos = np.ones((batch, 1), np.int32)
            tgt_in = np.concatenate([bos, tgt_r[:, :-1]], axis=1)
            mask = np.ones((batch, seq), np.int32)
            yield {"src": src, "tgt_in": tgt_in, "tgt_out": tgt_r, "mask": mask}

    return TaskSpec("multi30k", vocab, vocab, gen(seed), gen(eval_seed))


# ---------------------------------------------------------------------------
def wikitext2(batch=64, seq=64, vocab=33278, seed=3, eval_seed=10_003,
              zipf_a=1.1, branch=64):
    """Zipf-weighted sparse 2nd-order Markov LM stream: each (prev2, prev1)
    context allows `branch` successors with Zipf-ish weights."""

    def gen(seed):
        r = _rng(seed)
        gbase = _rng(7)
        # successor table: context hash -> branch candidate tokens
        zipf_p = 1.0 / np.arange(1, branch + 1) ** zipf_a
        zipf_p /= zipf_p.sum()
        table = gbase.integers(0, vocab, (4096, branch))
        while True:
            toks = np.zeros((batch, seq + 1), np.int64)
            toks[:, 0] = r.integers(0, vocab, batch)
            toks[:, 1] = r.integers(0, vocab, batch)
            for t in range(2, seq + 1):
                ctx = (toks[:, t - 2] * 31 + toks[:, t - 1]) % 4096
                choice = r.choice(branch, size=batch, p=zipf_p)
                toks[:, t] = table[ctx, choice]
            yield {
                "tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32),
            }

    return TaskSpec("wikitext2", vocab, vocab, gen(seed), gen(eval_seed))
