"""Sharded input pipeline: host batches -> global device arrays.

Single-controller version of a multi-host pipeline: each step's global batch
is device_put with the ("pod","data") batch sharding (the same
`make_array_from_process_local_data` path a real multi-host job uses), with a
double-buffered prefetch thread so host data generation overlaps device
compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed import sharding as shd

__all__ = ["ShardedPipeline", "to_global"]


def to_global(batch: dict, mesh=None) -> dict:
    """numpy batch dict -> sharded jax arrays (batch dim over pod+data)."""
    mesh = mesh or shd.active_mesh()
    out = {}
    for k, v in batch.items():
        v = np.asarray(v)
        if mesh is not None:
            sh = shd.named_sharding(("batch",) + (None,) * (v.ndim - 1), v.shape, mesh)
            out[k] = jax.device_put(v, sh)
        else:
            out[k] = jnp.asarray(v)
    return out


class ShardedPipeline:
    """Wraps a host-batch iterator with prefetch + device placement."""

    def __init__(self, it: Iterator[dict], mesh=None, prefetch: int = 2):
        self._it = it
        self._mesh = mesh
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = False
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for b in self._it:
                if self._stop:
                    return
                self._q.put(to_global(b, self._mesh))
        except Exception as e:  # propagate into the consumer
            self._q.put(e)

    def __iter__(self):
        return self

    def __next__(self):
        x = self._q.get()
        if isinstance(x, Exception):
            raise x
        return x

    def close(self):
        self._stop = True
