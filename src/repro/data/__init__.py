"""Data substrate: synthetic task corpora + sharded pipeline."""
from . import pipeline, synthetic
from .pipeline import ShardedPipeline, to_global
from .synthetic import multi30k, snli, udpos, wikitext2

__all__ = ["pipeline", "synthetic", "ShardedPipeline", "to_global",
           "multi30k", "snli", "udpos", "wikitext2"]
