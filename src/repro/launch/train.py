"""Production training driver.

Drives any of the paper's four LSTM tasks (synthetic data, CPU-runnable) or a
reduced assigned-arch config, with the full distributed runtime: sharded data
pipeline, FP16-master FloatSD8 train step, atomic async checkpointing,
resume-from-latest, preemption handling, straggler monitoring.

  PYTHONPATH=src python -m repro.launch.train --task wikitext2 --steps 300 \
      --policy floatsd8_table6 --ckpt-dir /tmp/ckpt  [--full]
  PYTHONPATH=src python -m repro.launch.train --arch stablelm_3b --steps 20
      # reduced config of an assigned arch, causal-LM objective

Relaunching the same command after a crash resumes from the newest
checkpoint (RestartableLoop); --fail-at N demonstrates it.
"""
from __future__ import annotations

import argparse
import collections
import os
import time

import jax
import numpy as np

from ..configs.base import get_config
from ..core.policy import get_policy
from ..data import synthetic
from ..data.pipeline import ShardedPipeline
from ..distributed import sharding as shd
from ..distributed.checkpointing import CheckpointManager
from ..distributed.fault_tolerance import (
    PreemptionSignal,
    RestartableLoop,
    StragglerMonitor,
)
from ..models import build
from ..obs.telemetry import TelemetryLogger
from ..optim import adam, sgd
from ..optim.train_state import init_state, make_train_step

TASK_OPT = {"udpos": ("adam", 1e-3), "snli": ("adam", 1e-3),
            "multi30k": ("adam", 1e-3), "wikitext2": ("sgd", 0.5)}


def make_mesh_for_host():
    """All addressable devices as a ("data","model") mesh (model=1 on CPU)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def build_task(args):
    """Returns (model, batches_iter, opt, lr)."""
    if args.arch:
        cfg = get_config(args.arch).reduced()
        model = build(cfg)
        data = synthetic.wikitext2(batch=args.batch, seq=args.seq, vocab=cfg.vocab)
        return model, data.batches, adam(), args.lr or 1e-3
    from ..models.task_zoo import make_task

    model, data, opt, lr, _ = make_task(args.task, full=args.full)
    return model, data.batches, opt, args.lr or lr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="wikitext2",
                    choices=["udpos", "snli", "multi30k", "wikitext2"])
    ap.add_argument("--arch", default=None, help="assigned arch id (reduced cfg)")
    ap.add_argument("--policy", default="floatsd8_table6")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--full", action="store_true", help="paper-scale model")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", default="auto", choices=["auto", "never"],
                    help="auto: restore the newest valid checkpoint under "
                    "--ckpt-dir at startup (a SIGKILL'd run relaunched with "
                    "the same command continues bit-compatibly); never: "
                    "always start fresh")
    ap.add_argument("--dynamic-scale", action="store_true",
                    help="dynamic loss scaling: nonfinite grads skip the "
                    "update and halve the scale (backoff), sustained finite "
                    "windows double it — the recovery loop for fp8 "
                    "overflow, vs the default static scale")
    ap.add_argument("--no-fused", action="store_true",
                    help="disable the fused quantized-BPTT backward "
                    "(restores the autodiff + grad_quant tree-pass path)")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="drop quantization-health telemetry from the step")
    ap.add_argument("--telemetry-out", default=None,
                    help="JSONL file for TrainTelemetry records "
                    "(default: <ckpt-dir>/telemetry.jsonl)")
    args = ap.parse_args()

    policy = get_policy(args.policy)
    mesh = make_mesh_for_host()
    model, batches, opt, lr = build_task(args)

    with shd.use_mesh(mesh):
        # donated jitted step: params/opt buffers update in place; the
        # finite-check/skip logic is already fused inside the step
        step_fn = make_train_step(
            model.loss, opt, policy, lr=lr,
            fused=False if args.no_fused else None, donate=True,
            telemetry=not args.no_telemetry,
        )

        def init_fn():
            params = model.init(jax.random.PRNGKey(args.seed))
            return init_state(
                params, opt, policy, dynamic_scale=args.dynamic_scale
            )

        ckpt = CheckpointManager(args.ckpt_dir, keep=3)
        loop = RestartableLoop(
            ckpt, init_fn, save_every=args.save_every,
            preemption=PreemptionSignal(install_sigterm=True),
            straggler=StragglerMonitor(),
            resume=args.resume,
        )
        if loop.resumed:
            print(f"resumed from step {loop.start_step}", flush=True)

        pipeline = ShardedPipeline(batches, mesh)
        # bounded loss window: enough for the widest log average, never
        # unbounded growth over long runs
        hist = collections.deque(maxlen=max(args.log_every, 100))
        t_first_done = [None]  # wall time when the first (compile) step ends
        telemetry = None
        if not args.no_telemetry:
            tel_path = args.telemetry_out or os.path.join(
                args.ckpt_dir, "telemetry.jsonl"
            )
            os.makedirs(os.path.dirname(tel_path) or ".", exist_ok=True)
            telemetry = TelemetryLogger(path=tel_path)
            print(f"telemetry -> {tel_path}", flush=True)

        skipped = [0]  # nonfinite-grad steps (update skipped, scale backed off)

        def on_metrics(step, m):
            hist.append(float(m["loss"]))
            if not bool(m["grads_finite"]):
                skipped[0] += 1
            if t_first_done[0] is None:
                t_first_done[0] = time.time()
            if telemetry is not None:
                telemetry.update(step, m)
            if step % args.log_every == 0:
                window = list(hist)[-args.log_every:]
                print(
                    f"step {step:5d}  loss {np.mean(window):.4f}  "
                    f"scale {float(m['loss_scale']):.0f}  "
                    f"finite {bool(m['grads_finite'])}",
                    flush=True,
                )
                if telemetry is not None:
                    print(telemetry.format(telemetry.emit(step)), flush=True)

        t0 = time.time()
        state, last = loop.run(
            step_fn, pipeline, args.steps, fail_at=args.fail_at,
            on_metrics=on_metrics,
        )
        dt = time.time() - t0
        done = last - loop.start_step
        # warm rate excludes the first step of the run (jit compile)
        if t_first_done[0] is not None and done > 1:
            compile_s = t_first_done[0] - t0
            warm_dt = time.time() - t_first_done[0]
            rate = (
                f"compile {compile_s:.1f}s + {warm_dt/(done-1):.3f}s/step warm "
                f"({(done-1)/max(warm_dt,1e-9):.2f} steps/s)"
            )
        else:
            rate = f"{dt/max(done,1):.2f}s/step"
        print(
            f"trained {done} steps in {dt:.1f}s ({rate}); stragglers flagged: "
            f"{len(loop.straggler.flagged)}; nonfinite steps skipped: "
            f"{skipped[0]}",
            flush=True,
        )
        pipeline.close()
        ckpt.wait()


if __name__ == "__main__":
    main()
