"""Batched serving CLI — thin wrapper over ``repro.serving.ServeEngine``.

Continuous batching over a fixed pool of decode lanes, chunked prefill,
FIFO or shortest-prompt-first admission, and weights served from packed
uint8 FloatSD8 codes (1 byte/weight, decode-at-use — the paper PE's
deployment format). See src/repro/serving/README.md for the engine
lifecycle.

  PYTHONPATH=src python -m repro.launch.serve --requests 32 --batch 8 \
      --max-new 32 --policy floatsd8_table6            # reduced config
  ... --full                                            # paper-scale 85M LM
  ... --chunk 1 --dense                                 # seed-equivalent loop
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs.base import get_config
from ..core.policy import get_policy
from ..models import build
from ..serving import ADMISSION_POLICIES, ServeEngine, synthetic_prompts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lstm_wikitext2")
    ap.add_argument("--policy", default="floatsd8_table6")
    ap.add_argument("--batch", type=int, default=8, help="decode lanes")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=8,
                    help="prefill chunk: prompt tokens consumed per step")
    ap.add_argument("--admission", default="fifo", choices=ADMISSION_POLICIES)
    ap.add_argument("--dense", action="store_true",
                    help="serve dense f32 weights (fake-quant at use) "
                         "instead of packed uint8 codes")
    ap.add_argument("--full", action="store_true", help="paper-scale model")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.family == "lstm" and not args.full:
        import dataclasses

        cfg = dataclasses.replace(cfg, d_model=192, vocab=4000, n_layers=2)
    elif cfg.family != "lstm":
        cfg = cfg.reduced()
    policy = get_policy(args.policy)
    model = build(cfg)
    rng = np.random.default_rng(args.seed)
    params = model.init(jax.random.PRNGKey(args.seed))

    engine = ServeEngine(
        model,
        params,
        policy,
        lanes=args.batch,
        chunk=args.chunk,
        admission=args.admission,
        packed=not args.dense,
        cache_len=None if cfg.family == "lstm" else 2048,
    )
    if engine.store is not None:
        s = engine.store
        print(
            f"weights: {s.dense_nbytes/2**20:.1f} MiB dense -> "
            f"{s.packed_nbytes/2**20:.1f} MiB packed FloatSD8 "
            f"({s.compression:.2f}x smaller, {s.n_packed} tensors packed)",
            flush=True,
        )
    engine.submit_all(
        synthetic_prompts(args.requests, cfg.vocab, rng), max_new=args.max_new
    )
    metrics = engine.run()
    print(metrics.format(), flush=True)


if __name__ == "__main__":
    main()
