"""Batched serving CLI — thin wrapper over ``repro.serving``.

Continuous batching over a fixed pool of decode lanes, chunked prefill,
FIFO / shortest-prompt-first / earliest-deadline-first admission, and
weights served from packed uint8 FloatSD8 codes (1 byte/weight,
decode-at-use — the paper PE's deployment format). ``--frontend`` layers
the multi-tenant request router and the FP8 LSTM-state prefix cache on
top: engine replicas share one cache, requests carry tenants, and the
report includes hit rates and per-tenant latency percentiles. See
src/repro/serving/README.md for the engine and frontend lifecycles.

``--http`` goes one step further: instead of draining a synthetic
workload, the router is put behind the stdlib HTTP/SSE server
(serving/http/) and serves real sockets until POST /admin/drain — the
network-facing deployment of the whole stack. See serving/README.md §HTTP
for the endpoint reference and runbook.

  PYTHONPATH=src python -m repro.launch.serve --requests 32 --batch 8 \
      --max-new 32 --policy floatsd8_table6            # reduced config
  ... --full                                            # paper-scale 85M LM
  ... --chunk 1 --dense                                 # seed-equivalent loop
  ... --frontend --replicas 2 --workload zipf-prefix    # router + cache
  ... --http --port 8000 --replicas 2                   # network service
  ... --http --admission sjf_work --preempt             # scheduler v2
"""
from __future__ import annotations

import argparse
import asyncio

import jax
import numpy as np

from ..configs.base import get_config
from ..core.policy import get_policy
from ..models import build
from ..serving import (
    ADMISSION_POLICIES,
    HttpServer,
    PrefixCache,
    Router,
    ServeEngine,
    synthetic_prompts,
    zipf_prefix_prompts,
)


def _serve_http(router: Router, args) -> None:
    """Run the HTTP/SSE service until /admin/drain (or Ctrl-C), then print
    the final router report."""

    async def run():
        server = await HttpServer(
            router, host=args.host, port=args.port,
            default_max_new=args.max_new, trace=not args.no_trace,
        ).start()
        print(
            f"http: listening on http://{server.host}:{server.port} "
            f"({args.replicas} replica(s) x {args.batch} lanes, "
            f"admission={args.admission}); POST /admin/drain to stop",
            flush=True,
        )
        try:
            await server.serve_forever()
        except (KeyboardInterrupt, asyncio.CancelledError):
            pass

    try:
        asyncio.run(run())
    except KeyboardInterrupt:
        pass
    rep = router.report()
    print(
        f"http: served {rep['requests']} requests, "
        f"{rep['emitted_tokens']} tokens over {rep['replicas']} replica(s) | "
        f"cache hit rate {rep['cache_hit_rate']:.0%} "
        f"({rep['prefill_tokens_saved']} prefill tok saved) | "
        f"rejections {rep['rejections']}",
        flush=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lstm_wikitext2")
    ap.add_argument("--policy", default="floatsd8_table6")
    ap.add_argument("--batch", type=int, default=8, help="decode lanes per engine")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--chunk", type=int, default=8,
                    help="prefill chunk: prompt tokens consumed per step")
    ap.add_argument("--admission", default="fifo", choices=ADMISSION_POLICIES)
    ap.add_argument("--preempt", action="store_true",
                    help="scheduler v2: let engines preempt the "
                         "longest-remaining decoding lane (FP8 state "
                         "snapshot, resumed later) when the queue head "
                         "owes much less work — pair with "
                         "--admission sjf_work for the warm-tail win")
    ap.add_argument("--admit-pace", type=int, default=None,
                    help="scheduler v2: cap lane admissions per engine "
                         "step (spreads a warm burst; default unlimited)")
    ap.add_argument("--dense", action="store_true",
                    help="serve dense f32 weights (fake-quant at use) "
                         "instead of packed uint8 codes")
    ap.add_argument("--weight-format", choices=("floatsd8", "floatsd4"),
                    default="floatsd8",
                    help="packed serving format: floatsd8 (1 byte/weight, "
                         "output-identical to training) or floatsd4 "
                         "(2 codes/byte + group exponents, ~half the "
                         "resident bytes, re-quantized from the FloatSD8 "
                         "master)")
    ap.add_argument("--full", action="store_true", help="paper-scale model")
    ap.add_argument("--seed", type=int, default=0)
    # frontend (router + prefix cache) options
    ap.add_argument("--frontend", action="store_true",
                    help="serve through the multi-tenant router with a "
                         "shared FP8 LSTM-state prefix cache")
    ap.add_argument("--replicas", type=int, default=1,
                    help="frontend: engine replicas behind the router")
    ap.add_argument("--cache-mb", type=float, default=64.0,
                    help="frontend: prefix-cache byte budget (MiB); 0 "
                         "disables the cache")
    ap.add_argument("--tenants", type=int, default=2,
                    help="frontend: requests round-robin over this many "
                         "synthetic tenants")
    ap.add_argument("--workload", choices=["uniform", "zipf-prefix"],
                    default="uniform",
                    help="uniform prompt lengths, or shared-system-prompt "
                         "(zipf over a small prefix pool — what the prefix "
                         "cache is for)")
    # http (network service) options — implies the frontend router
    ap.add_argument("--http", action="store_true",
                    help="serve the frontend router over HTTP/SSE "
                         "(/v1/generate, /v1/stream, /healthz, /metrics, "
                         "/admin/drain) instead of draining a synthetic "
                         "workload; runs until POST /admin/drain")
    ap.add_argument("--host", default="127.0.0.1",
                    help="http: bind address")
    ap.add_argument("--port", type=int, default=8000,
                    help="http: bind port (0 picks an ephemeral port, "
                         "printed on startup)")
    ap.add_argument("--no-trace", action="store_true",
                    help="http: disable the request-lifecycle tracer "
                         "(GET /admin/trace then exports an empty trace)")
    args = ap.parse_args()
    if args.http:
        args.frontend = True  # the HTTP layer sits on the router

    cfg = get_config(args.arch)
    if cfg.family == "lstm" and not args.full:
        import dataclasses

        cfg = dataclasses.replace(cfg, d_model=192, vocab=4000, n_layers=2)
    elif cfg.family != "lstm":
        cfg = cfg.reduced()
    policy = get_policy(args.policy)
    model = build(cfg)
    rng = np.random.default_rng(args.seed)
    params = model.init(jax.random.PRNGKey(args.seed))

    if args.workload == "zipf-prefix":
        prompts = zipf_prefix_prompts(
            args.requests, cfg.vocab, rng, prefix_len=3 * args.chunk,
            prefix_seed=args.seed,
        )
    else:
        prompts = synthetic_prompts(args.requests, cfg.vocab, rng)

    engine_kw = dict(
        lanes=args.batch,
        chunk=args.chunk,
        packed=not args.dense,
        weight_format=args.weight_format,
        cache_len=None if cfg.family == "lstm" else 2048,
        # engines share the admission policy so the preemption check peeks
        # at the same ordering the router dispatches under
        admission=args.admission,
        preempt=args.preempt,
        admit_pace=args.admit_pace,
    )

    if args.frontend:
        if cfg.family != "lstm":
            # Non-LSTM caches are not lane-major, so replicas cannot re-arm
            # lanes (at most `lanes` requests per engine) and there is no
            # constant-size state to prefix-cache; failing here beats a
            # RuntimeError mid-drain after partial service.
            raise SystemExit(
                "--frontend serves LSTM-family models (continuous lane "
                "re-arming + prefix cache need lane-major recurrent state); "
                f"arch {args.arch!r} is family {cfg.family!r} — use the "
                "plain engine path instead"
            )
        cache = (
            PrefixCache(budget_bytes=int(args.cache_mb * 2**20), block=args.chunk)
            if args.cache_mb > 0
            else None
        )
        router = Router.build(
            model, params, policy,
            replicas=args.replicas,
            prefix_cache=cache,
            router_kw=dict(admission=args.admission, max_queue=args.requests),
            **engine_kw,
        )
        if args.http:
            _serve_http(router, args)
            return
        for i, p in enumerate(prompts):
            router.submit(p, max_new=args.max_new, tenant=f"tenant{i % args.tenants}")
        router.drain()
        rep = router.report()
        print(
            f"frontend: {rep['requests']} requests over {rep['replicas']} "
            f"replica(s), {rep['steps']} steps "
            f"({rep['prefill_steps']} prefill / {rep['decode_steps']} decode), "
            f"cache hit rate {rep['cache_hit_rate']:.0%} "
            f"({rep['prefill_tokens_saved']} prefill tok saved), "
            f"rejections {rep['rejections']}",
            flush=True,
        )
        for tenant, t in rep["tenants"].items():
            print(
                f"  {tenant}: {t['completed']} done / {t['tokens']} tok | "
                f"ttft p95 {t.get('ttft_p95_s', 0.0)*1e3:.0f}ms | "
                f"latency p95 {t.get('latency_p95_s', 0.0)*1e3:.0f}ms",
                flush=True,
            )
        return

    engine = ServeEngine(model, params, policy, **engine_kw)
    if engine.store is not None:
        s = engine.store
        print(
            f"weights: {s.dense_nbytes/2**20:.1f} MiB dense -> "
            f"{s.packed_nbytes/2**20:.1f} MiB packed "
            f"{'FloatSD4' if s.fmt == 'floatsd4' else 'FloatSD8'} "
            f"({s.compression:.2f}x smaller, {s.n_packed} tensors packed)",
            flush=True,
        )
    engine.submit_all(prompts, max_new=args.max_new)
    metrics = engine.run()
    print(metrics.format(), flush=True)


if __name__ == "__main__":
    main()
