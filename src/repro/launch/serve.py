"""Batched serving driver (the paper's inference-accelerator workload).

Serves the WikiText-2 LSTM LM (or a reduced assigned arch) with a
continuous-batching request loop: a fixed pool of B decode lanes, each lane
bound to a request; when a request finishes (EOS / max tokens) the lane is
re-armed with the next queued request without stalling the other lanes —
the recurrent state (LSTM) or KV cache (transformer) slot is reset in place
via a jitted masked-reset step (no per-lane host round trips).

Weights are served from FloatSD8 codes (1 byte/weight — the deployment
format; decode-at-use matches the PE's VMEM decode).

  PYTHONPATH=src python -m repro.launch.serve --requests 32 --batch 8 \
      --max-new 32 --policy floatsd8_table6            # reduced config
  ... --full                                            # paper-scale 85M LM
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_config
from ..core.policy import get_policy
from ..models import build


def sample_requests(n, vocab, rng, lo=4, hi=24):
    """Synthetic request stream: prompt token arrays."""
    for _ in range(n):
        plen = int(rng.integers(lo, hi))
        yield rng.integers(0, vocab, plen).astype(np.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lstm_wikitext2")
    ap.add_argument("--policy", default="floatsd8_table6")
    ap.add_argument("--batch", type=int, default=8, help="decode lanes")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--full", action="store_true", help="paper-scale model")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if cfg.family == "lstm" and not args.full:
        import dataclasses

        cfg = dataclasses.replace(cfg, d_model=192, vocab=4000, n_layers=2)
    elif cfg.family != "lstm":
        cfg = cfg.reduced()
    policy = get_policy(args.policy)
    model = build(cfg)
    rng = np.random.default_rng(args.seed)
    params = model.init(jax.random.PRNGKey(args.seed))

    B = args.batch
    caches = (
        model.init_cache(B, policy)
        if cfg.family == "lstm"
        else model.init_cache(B, 2048)
    )

    @jax.jit
    def step(params, tokens, caches, reset_mask):
        """One decode step; lanes with reset_mask=1 get zeroed state first."""
        caches = jax.tree_util.tree_map(
            lambda c: c * (1 - reset_mask.astype(c.dtype)).reshape(
                (B,) + (1,) * (c.ndim - 1)
            ),
            caches,
        )
        logits, caches = model.decode_step(params, tokens, caches, policy)
        return jnp.argmax(logits[:, -1, :], -1), caches

    queue = list(sample_requests(args.requests, cfg.vocab, rng))
    lanes = [None] * B  # per-lane request record or None
    cur = np.zeros((B, 1), np.int32)
    reset = np.zeros((B,), np.int32)
    done = emitted = steps = 0

    def arm(i):
        """Bind the next queued request to lane i (host-side bookkeeping)."""
        nonlocal lanes
        if queue:
            prompt = queue.pop(0)
            lanes[i] = {"prompt": prompt, "pos": 1, "out": [],
                        "remaining": args.max_new}
            cur[i, 0] = int(prompt[0])
            reset[i] = 1
        else:
            lanes[i] = None
            cur[i, 0] = 0

    for i in range(B):
        arm(i)

    t0 = time.time()
    while any(l is not None for l in lanes):
        nxt, caches = step(params, jnp.asarray(cur), caches, jnp.asarray(reset))
        nxt = np.asarray(nxt)
        reset[:] = 0
        steps += 1
        for i, l in enumerate(lanes):
            if l is None:
                continue
            if l["pos"] < len(l["prompt"]):  # still force-feeding the prompt
                cur[i, 0] = int(l["prompt"][l["pos"]])
                l["pos"] += 1
                continue
            tok = int(nxt[i])
            l["out"].append(tok)
            l["remaining"] -= 1
            emitted += 1
            if l["remaining"] <= 0:
                done += 1
                arm(i)
            else:
                cur[i, 0] = tok
    dt = time.time() - t0
    print(
        f"served {done} requests, {emitted} tokens in {dt:.1f}s "
        f"({emitted/dt:.1f} tok/s, {steps} batched steps, "
        f"lane util {emitted/max(steps*B,1):.0%})",
        flush=True,
    )


if __name__ == "__main__":
    main()
