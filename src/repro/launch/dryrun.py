"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the placeholder device count before ANY other import (jax locks the
device count on first init) — see the first two lines.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm_3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.base import ARCH_IDS, SHAPES, get_config
from ..distributed import sharding as shd
from .hlo_analysis import analyze_hlo
from .mesh import HW, make_production_mesh
from .specs import build_cell

__all__ = ["run_cell", "main", "count_active_params"]


def count_active_params(cfg, params_shape) -> tuple[int, int]:
    """(total, active) parameter counts; expert leaves scale by top_k/E.

    Expert weights are [E, d, h] — or [L, E, d, h] when the layer scan
    stacks them — so the expert dim may sit at axis 0 or 1.
    """
    total = active = 0
    for leaf in jax.tree_util.tree_leaves(params_shape):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        is_expert = (
            cfg.n_experts
            and leaf.ndim >= 3
            and cfg.n_experts in leaf.shape[:2]
        )
        if is_expert:
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    return int(total), int(active)


def _mem_stats(compiled):
    m = compiled.memory_analysis()
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_cell(
    arch: str,
    shape: str,
    multi_pod: bool = False,
    policy_name: str = "floatsd8_tpu",
    verbose: bool = True,
    **cell_kw,
) -> dict:
    save_hlo = cell_kw.pop("save_hlo", False)
    cfg = get_config(arch)
    skip = cfg.skips(shape)
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": "pod2x16x16" if multi_pod else "16x16",
        "policy": policy_name,
    }
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    # perf experiment (EXPERIMENTS.md §Perf HC2 it.4): REPRO_LSTM_TP0=1
    # replicates the small LSTM gate weights over the model axis instead of
    # TP-sharding hidden4 (the 85M model doesn't need TP; the per-step h
    # gathers it forces do not amortize).
    rules = None
    if os.environ.get("REPRO_LSTM_TP0") == "1" and cfg.family == "lstm":
        rules = {"hidden4": None, "act_mlp": None}
    try:
        with shd.use_mesh(mesh, rules=rules):
            t0 = time.time()
            cell = build_cell(arch, shape, mesh, policy_name=policy_name, **cell_kw)
            jf = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
            )
            lowered = jf.lower(*cell.args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()

        mem = _mem_stats(compiled)
        ca = compiled.cost_analysis() or {}
        hlo_text = compiled.as_text()
        if save_hlo:
            import gzip

            os.makedirs("results/hlo", exist_ok=True)
            with gzip.open(
                f"results/hlo/{arch}__{shape}__{rec['mesh']}.hlo.gz", "wt"
            ) as f:
                f.write(hlo_text)
        hlo = analyze_hlo(hlo_text, n_partitions=n_dev)
        # kernel-substitution variant: flash-attention tiles VMEM-resident
        hlo_fl = analyze_hlo(
            hlo_text, n_partitions=n_dev, vmem_scopes=("flashable",)
        )

        seq, gbatch, kind = SHAPES[shape]
        params_shape = jax.eval_shape(
            lambda k: cell.model.init(k), jax.random.PRNGKey(0)
        )
        total_p, active_p = count_active_params(cfg, params_shape)
        tokens = gbatch * (seq if kind != "decode" else 1)
        mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
        model_flops = mult * active_p * tokens / n_dev  # per device

        compute_s = hlo.flops / HW.PEAK_FLOPS_BF16
        memory_s = hlo.bytes_accessed / HW.HBM_BW
        coll_s = hlo.collective_bytes / HW.ICI_BW_PER_LINK
        dom = max(
            ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
            key=lambda kv: kv[1],
        )[0]
        memory_s_fl = hlo_fl.bytes_accessed / HW.HBM_BW
        top_bytes = sorted(hlo.detail.items(), key=lambda kv: -kv[1])[:20]
        rec.update(
            status="ok",
            lower_s=round(t1 - t0, 2),
            compile_s=round(t2 - t1, 2),
            kind=kind,
            n_devices=n_dev,
            memory=mem,
            xla_cost_analysis={
                k: float(ca[k]) for k in ("flops", "bytes accessed") if k in ca
            },
            hlo_flops=hlo.flops,
            hlo_dot_flops=hlo.dot_flops,
            hlo_bytes=hlo.bytes_accessed,
            collective_wire_bytes=hlo.collective_bytes,
            collective_raw_bytes=hlo.collective_raw,
            collective_breakdown={k: float(v) for k, v in hlo.collective_breakdown.items()},
            collective_count=hlo.collective_count,
            unknown_while=hlo.unknown_while,
            params_total=total_p,
            params_active=active_p,
            model_flops_per_device=model_flops,
            useful_flops_ratio=round(model_flops / hlo.flops, 4) if hlo.flops else None,
            roofline={
                "compute_s": compute_s,
                "memory_s": memory_s,
                "collective_s": coll_s,
                "dominant": dom,
            },
            # kernel-substitution variant (flash tiles VMEM-resident on TPU)
            roofline_flash={
                "memory_s": memory_s_fl,
                "bytes": hlo_fl.bytes_accessed,
                "discounted_bytes": hlo_fl.bytes_by_op.get(
                    "vmem-resident(discounted)", 0.0
                ),
            },
            bytes_by_op={k: float(v) for k, v in sorted(
                hlo.bytes_by_op.items(), key=lambda kv: -kv[1])},
            top_bytes_instrs=[[k, float(v)] for k, v in top_bytes],
        )
        if verbose:
            print(
                f"[{rec['mesh']}] {arch:20s} {shape:12s} OK  "
                f"compile={rec['compile_s']:7.1f}s  "
                f"C={compute_s*1e3:8.2f}ms M={memory_s*1e3:8.2f}ms "
                f"X={coll_s*1e3:8.2f}ms dom={dom:10s} "
                f"useful={rec['useful_flops_ratio']}",
                flush=True,
            )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[{rec['mesh']}] {arch:20s} {shape:12s} FAIL {rec['error'][:160]}", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--policy", default="floatsd8_tpu")
    ap.add_argument("--remat", default="dots")
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"skip existing {tag}", flush=True)
                    continue
                rec = run_cell(
                    arch, shape, multi_pod=mp, policy_name=args.policy,
                    remat=args.remat, attn_chunk=args.attn_chunk,
                    save_hlo=args.save_hlo,
                )
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                jax.clear_caches()  # keep host RAM bounded across the sweep


if __name__ == "__main__":
    main()
