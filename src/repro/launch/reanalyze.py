"""Recompute roofline terms in dry-run JSONs from saved HLO text
(results/hlo/*.hlo.gz) — no recompilation. Run after analyzer changes so
the whole table shares one accounting policy.

  PYTHONPATH=src python -m repro.launch.reanalyze --dir results/dryrun
"""
import argparse
import glob
import gzip
import json
import os

from .hlo_analysis import analyze_hlo
from .mesh import HW


def reanalyze_record(rec: dict, hlo_dir: str) -> bool:
    tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    path = os.path.join(hlo_dir, tag + ".hlo.gz")
    if rec.get("status") != "ok" or not os.path.exists(path):
        return False
    with gzip.open(path, "rt") as f:
        text = f.read()
    n = rec["n_devices"]
    h = analyze_hlo(text, n_partitions=n)
    hf = analyze_hlo(text, n_partitions=n, vmem_scopes=("flashable",))
    compute_s = h.flops / HW.PEAK_FLOPS_BF16
    memory_s = h.bytes_accessed / HW.HBM_BW
    coll_s = h.collective_bytes / HW.ICI_BW_PER_LINK
    dom = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", coll_s),
        key=lambda kv: kv[1],
    )[0]
    rec.update(
        hlo_flops=h.flops,
        hlo_dot_flops=h.dot_flops,
        hlo_bytes=h.bytes_accessed,
        collective_wire_bytes=h.collective_bytes,
        collective_raw_bytes=h.collective_raw,
        collective_breakdown={k: float(v) for k, v in h.collective_breakdown.items()},
        collective_count=h.collective_count,
        unknown_while=h.unknown_while,
        useful_flops_ratio=(
            round(rec["model_flops_per_device"] / h.flops, 4) if h.flops else None
        ),
        roofline={
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": dom,
        },
        roofline_flash={
            "memory_s": hf.bytes_accessed / HW.HBM_BW,
            "bytes": hf.bytes_accessed,
            "discounted_bytes": hf.bytes_by_op.get("vmem-resident(discounted)", 0.0),
        },
        bytes_by_op={k: float(v) for k, v in sorted(
            h.bytes_by_op.items(), key=lambda kv: -kv[1])},
        top_bytes_instrs=[
            [k, float(v)]
            for k, v in sorted(h.detail.items(), key=lambda kv: -kv[1])[:20]
        ],
    )
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--hlo-dir", default="results/hlo")
    a = ap.parse_args()
    for path in sorted(glob.glob(f"{a.dir}/*.json")):
        with open(path) as f:
            rec = json.load(f)
        if reanalyze_record(rec, a.hlo_dir):
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            rf = rec["roofline"]
            print(
                f"{os.path.basename(path):50s} C={rf['compute_s']*1e3:9.1f}ms "
                f"M={rf['memory_s']*1e3:9.1f}ms X={rf['collective_s']*1e3:9.1f}ms "
                f"dom={rf['dominant']}", flush=True,
            )


if __name__ == "__main__":
    main()
