"""Launch layer: meshes, dry-run, roofline, train/serve drivers.

Intentionally lazy: ``python -m repro.launch.dryrun`` must set
XLA_FLAGS (512 placeholder devices) before anything imports jax, so this
package imports nothing at module load.
"""
__all__ = ["hlo_analysis", "mesh", "specs", "dryrun"]
