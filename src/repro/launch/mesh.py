"""Production meshes.

Single pod = 16x16 = 256 chips (v5e pod), axes ("data", "model").
Multi-pod  = 2x16x16 = 512 chips, axes ("pod", "data", "model"): "pod" is
pure DP (FP8-compressed gradient hop), "data" is FSDP, "model" is TP/EP.

Functions, not module constants — importing this module never touches jax
device state.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import AxisType, Mesh

__all__ = ["make_production_mesh", "make_host_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh over host (CPU) devices for tests."""
    n = int(np.prod(shape))
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)


class HW:
    """TPU v5e-class hardware constants for the roofline (per chip)."""

    PEAK_FLOPS_BF16 = 197e12  # FLOP/s
    HBM_BW = 819e9  # B/s
    ICI_BW_PER_LINK = 50e9  # B/s (per link; wire bytes already per-device)
    HBM_BYTES = 16 * 1024**3
