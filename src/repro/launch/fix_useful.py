"""Recompute params_active / model_flops / useful_flops_ratio in dry-run
JSONs (eval_shape only — no recompile). Needed when count_active_params
changes after a campaign has run.

  PYTHONPATH=src python -m repro.launch.fix_useful --dir results/dryrun
"""
import argparse
import glob
import json

import jax

from ..configs.base import SHAPES, get_config
from ..models import build
from .dryrun import count_active_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    a = ap.parse_args()
    cache = {}
    for path in sorted(glob.glob(f"{a.dir}/*.json")):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        arch = rec["arch"]
        if arch not in cache:
            cfg = get_config(arch)
            model = build(cfg)
            pshape = jax.eval_shape(lambda m=model: m.init(jax.random.PRNGKey(0)))
            cache[arch] = (cfg, count_active_params(cfg, pshape))
        cfg, (total, active) = cache[arch]
        seq, gbatch, kind = SHAPES[rec["shape"]]
        tokens = gbatch * (seq if kind != "decode" else 1)
        mult = {"train": 6.0, "prefill": 2.0, "decode": 2.0}[kind]
        model_flops = mult * active * tokens / rec["n_devices"]
        old = rec.get("useful_flops_ratio")
        rec["params_total"] = total
        rec["params_active"] = active
        rec["model_flops_per_device"] = model_flops
        rec["useful_flops_ratio"] = (
            round(model_flops / rec["hlo_flops"], 4) if rec.get("hlo_flops") else None
        )
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if old != rec["useful_flops_ratio"]:
            print(f"{path}: useful {old} -> {rec['useful_flops_ratio']}")


if __name__ == "__main__":
    main()
