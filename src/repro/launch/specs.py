"""ShapeDtypeStruct input specs + sharding trees for every (arch x shape).

`build_cell(arch, shape, mesh, ...)` returns everything dryrun/train/serve
need: the function to jit, abstract args, and in/out shardings — with NO
device allocation (the shannon/kernels pattern: weak-type-correct,
shardable stand-ins).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES, ArchConfig, get_config
from ..core import loss_scaling as ls
from ..core.policy import Policy, get_policy
from ..distributed import sharding as shd
from ..models import build
from ..optim import adafactor, adam, sgd
from ..optim.optimizers import AdamState, FactorState, Optimizer
from ..optim.train_state import TrainState, make_train_step

__all__ = ["Cell", "build_cell", "batch_specs", "param_shardings", "state_shardings"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape_name: str, policy: Policy):
    """ShapeDtypeStructs + logical axis tuples for the input batch."""
    seq, gbatch, kind = SHAPES[shape_name]
    cdt = policy.cdt() or jnp.float32
    if kind == "train" or kind == "prefill":
        b = {
            "tokens": _sds((gbatch, seq), jnp.int32),
            "labels": _sds((gbatch, seq), jnp.int32),
        }
        s = {
            "tokens": ("batch", None),
            "labels": ("batch", None),
        }
        if cfg.family == "audio":
            b["frames"] = _sds((gbatch, cfg.enc_seq, cfg.d_model), cdt)
            s["frames"] = ("batch", None, None)
        if cfg.family == "vlm":
            b["patch_embeds"] = _sds((gbatch, cfg.n_patches, cfg.d_model), cdt)
            s["patch_embeds"] = ("batch", None, None)
        return b, s
    # decode: one new token against a seq_len cache
    b = {"tokens": _sds((gbatch, 1), jnp.int32)}
    s = {"tokens": ("batch", None)}
    return b, s


def param_shardings(model, mesh: Mesh, params_shape=None):
    if params_shape is None:
        params_shape = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    return shd.tree_shardings(model.specs(), params_shape, mesh), params_shape


def opt_specs(opt_name: str, param_specs):
    """Optimizer-state logical specs mirroring param specs."""
    if opt_name == "adam":
        return AdamState(param_specs, param_specs, ())
    if opt_name == "adafactor":
        def rows(s):
            return tuple(s[:-1]) if len(s) >= 2 else ()

        def cols(s):
            return tuple(s[:-2]) + tuple(s[-1:]) if len(s) >= 2 else ()

        def full(s):
            return () if len(s) >= 2 else tuple(s)

        t = functools.partial(
            jax.tree_util.tree_map, is_leaf=lambda x: type(x) is tuple
        )
        return FactorState(t(rows, param_specs), t(cols, param_specs), t(full, param_specs), ())
    if opt_name == "sgd":
        return ()  # plain sgd: no state
    raise ValueError(opt_name)


def state_shardings(model, opt_name: str, policy: Policy, mesh: Mesh, opt: Optimizer):
    """(TrainState shapes, TrainState shardings) without allocation."""
    from ..optim.train_state import init_state

    params_shape = jax.eval_shape(lambda k: model.init(k), jax.random.PRNGKey(0))
    state_shape = jax.eval_shape(
        lambda p: init_state(p, opt, policy), params_shape
    )
    pspecs = model.specs()
    specs = TrainState(
        step=(),
        params=pspecs,
        opt_state=opt_specs(opt_name, pspecs),
        scale=ls.LossScaleState((), (), ()),
    )
    shardings = shd.tree_shardings(specs, state_shape, mesh)
    return state_shape, shardings


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str  # train | prefill | decode
    fn: Callable  # function to jit
    args: tuple  # abstract args
    in_shardings: tuple
    out_shardings: Any
    cfg: ArchConfig
    model: Any
    policy: Policy


def _repl(mesh):
    return NamedSharding(mesh, P())


def build_cell(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    policy_name: str = "floatsd8_tpu",
    opt_name: str | None = None,
    remat: str = "dots",
    attn_chunk: int = 1024,
    cache_dtype=jnp.bfloat16,
) -> Cell:
    cfg = get_config(arch)
    policy = get_policy(policy_name)
    seq, gbatch, kind = SHAPES[shape_name]
    skip = cfg.skips(shape_name)
    if skip:
        raise ValueError(f"cell ({arch},{shape_name}) skipped: {skip}")

    if opt_name is None:
        # adafactor for the 1T model (factored moments; DESIGN.md §4), adam else
        opt_name = "adafactor" if cfg.n_experts >= 256 else "adam"
    opt = {"adam": adam(), "sgd": sgd(0.9), "adafactor": adafactor()}[opt_name]

    model = build(cfg, remat=remat, attn_chunk=attn_chunk) if cfg.family != "lstm" else build(cfg)
    if hasattr(model, "cache_dtype") and cfg.family != "lstm":
        model = dataclasses.replace(model, cache_dtype=cache_dtype)

    with shd.use_mesh(mesh):
        bspec, blog = batch_specs(cfg, shape_name, policy)
        bshard = shd.tree_shardings(blog, bspec, mesh)

        if kind == "train":
            state_shape, state_shard = state_shardings(model, opt_name, policy, mesh, opt)
            step = make_train_step(model.loss, opt, policy, lr=1e-4)
            fn = step
            args = (state_shape, bspec)
            in_sh = (state_shard, bshard)
            out_sh = (state_shard, _repl(mesh))
        elif kind == "prefill":
            pshard, pshape = param_shardings(model, mesh)

            def fn(params, batch):
                return model.prefill(params, batch, policy) if cfg.family != "audio" else _whisper_prefill(model, params, batch, policy)

            args = (pshape, bspec)
            in_sh = (pshard, bshard)
            # pass the logits shape so non-divisible axes drop (e.g. batch=1
            # over data=16, or vocab=33278 over model=16)
            out_sh = NamedSharding(
                mesh,
                shd.logical_to_spec(
                    ("batch", None, "vocab"), (gbatch, seq, cfg.vocab), mesh
                ),
            )
        else:  # decode
            pshard, pshape = param_shardings(model, mesh)
            if cfg.family == "lstm":
                cache_shape = jax.eval_shape(lambda: model.init_cache(gbatch, policy))
                cspecs = [
                    type(c)(("batch", "act_mlp"), ("batch", "act_mlp")) for c in cache_shape
                ]
            else:
                cache_shape = jax.eval_shape(lambda: model.init_cache(gbatch, seq))
                cspecs = model.cache_specs()
            cshard = shd.tree_shardings(cspecs, cache_shape, mesh)

            def fn(params, tokens, caches):
                return model.decode_step(params, tokens, caches, policy)

            args = (pshape, bspec["tokens"], cache_shape)
            in_sh = (pshard, bshard["tokens"], cshard)
            out_sh = (
                NamedSharding(
                    mesh,
                    shd.logical_to_spec(
                        ("batch", None, "vocab"), (gbatch, 1, cfg.vocab), mesh
                    ),
                ),
                cshard,
            )
    return Cell(arch, shape_name, kind, fn, args, in_sh, out_sh, cfg, model, policy)


def _whisper_prefill(model, params, batch, policy):
    enc = model.encode(params, batch["frames"], policy)
    return model.decode_seq(params, batch["tokens"], enc, policy)
