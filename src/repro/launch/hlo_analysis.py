"""Trip-count-aware HLO cost analysis for the roofline (DESIGN.md §9).

`compiled.cost_analysis()` does not multiply `while` (lax.scan) body costs by
trip count, and gives no collective-byte breakdown at all. This module parses
post-optimization HLO text (`compiled.as_text()`, per-device SPMD module) and
walks the computation graph:

  * dot FLOPs: 2 * prod(out) * contracted_size, x loop trip counts
  * elementwise/reduce FLOPs: prod(out) for a known op set (minor term)
  * bytes accessed: operands + outputs per instruction (fusion counted at
    its boundary, like HloCostAnalysis)
  * collective wire bytes per op kind with ring-algorithm factors:
      all-reduce      2 * (n-1)/n * size
      all-gather          (n-1)/n * out_size
      reduce-scatter      (n-1)/n * in_size
      all-to-all          (n-1)/n * size
      collective-permute  size
    (n = participants per replica group, parsed from `replica_groups`).

While trip counts come from the loop condition's comparison constant.
Cross-checked against cost_analysis() on scan-free modules in
tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e5m2": 1, "f8e4m3fn": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f4e2m1fn": 1, "u4": 1, "s4": 1,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "negate", "abs", "sqrt", "rsqrt", "select",
    "compare", "and", "or", "xor", "not", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "sign", "cosine", "sine", "atan2", "remainder",
    "exponential-minus-one", "log-plus-one", "clamp", "erf", "logistic",
}

_REDUCE_OPS = {"reduce", "reduce-window"}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str
    line: str


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0  # wire bytes with ring factors
    collective_raw: float = 0.0  # plain operand-size sum (spec formula)
    collective_breakdown: dict = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    transcendental: float = 0.0
    unknown_while: int = 0
    bytes_by_op: dict = dataclasses.field(default_factory=dict)
    detail: dict = dataclasses.field(default_factory=dict)  # instr -> bytes

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.dot_flops += other.dot_flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.collective_bytes += other.collective_bytes * mult
        self.collective_raw += other.collective_raw * mult
        self.collective_count += int(other.collective_count * mult)
        self.transcendental += other.transcendental * mult
        self.unknown_while += other.unknown_while
        for k, v in other.collective_breakdown.items():
            self.collective_breakdown[k] = self.collective_breakdown.get(k, 0.0) + v * mult
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v * mult
        for k, v in other.detail.items():
            self.detail[k] = self.detail.get(k, 0.0) + v * mult
        if len(self.detail) > 400:  # keep the heavy hitters only
            self.detail = dict(
                sorted(self.detail.items(), key=lambda kv: -kv[1])[:200]
            )


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*?)\)(.*)$"
)

_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*[({]")


def parse_hlo(text: str) -> dict:
    """-> {comp_name: {instr_name: Instr}, ...} plus '__entry__' key."""
    comps: dict = {}
    cur = None
    cur_name = None
    entry = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment_re.sub("", raw).rstrip()
        if cur is None:
            # computation header: "%name (params...) -> type {"  (top level,
            # no leading whitespace, ends with "{", no "=" before it)
            if line.endswith("{") and line and not line[0].isspace():
                head = line.split("{")[0]
                if "=" not in head:
                    m = _COMP_START_RE.match(line)
                    if m:
                        cur_name = m.group(2)
                        cur = {}
                        if m.group(1):
                            entry = cur_name
            continue
        if line.startswith("}"):
            comps[cur_name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, operands, attrs = m.groups()
        # Operand entries are "<type> %name" in post-optimization HLO text;
        # keep only the bare instruction name so type/def lookups resolve.
        ops = [o.strip().split()[-1].lstrip("%") for o in _split_top(operands)]
        cur[name] = Instr(name, type_str, opcode, ops, attrs, line)
    comps["__entry__"] = entry
    return comps


def _split_top(s: str):
    out, depth, buf = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    if buf:
        out.append("".join(buf))
    return [x for x in (b.strip() for b in out) if x]


def _group_size(attrs: str, default: int) -> int:
    # replica_groups=[2,4]<=[8]  -> groups of 4;  or explicit {{0,1},{2,3}}
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return default


def _trip_count(cond_comp: dict) -> int | None:
    """max integer constant compared against in the condition computation."""
    best = None
    for ins in cond_comp.values():
        if ins.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                v = int(m.group(1))
                if v >= 0 and (best is None or v > best):
                    best = v
    return best


def analyze_hlo(
    text: str,
    n_partitions: int | None = None,
    vmem_scopes: tuple[str, ...] = (),
) -> HloCost:
    """`vmem_scopes`: names of jax.named_scope regions whose intermediate
    tensors a Pallas kernel keeps VMEM-resident on the TPU target (kernel-
    substitution roofline model). Any instruction whose op_name metadata
    contains one of these scope strings contributes FLOPs but zero HBM
    bytes. Used for the flash-attention / fused-cell optimized variants;
    the unadjusted measurement is always reported alongside."""
    comps = parse_hlo(text)
    entry = comps.pop("__entry__")
    cache: dict[str, HloCost] = {}

    def _param_read_bytes(called: str) -> dict[int, float | None]:
        """Per-parameter effective read bytes inside a fused computation:
          * consumed only by slicing ops (dynamic-slice / slice / gather with
            the param as the sliced operand) -> read = slice sizes;
          * consumed only as a dynamic-update-slice *destination* (operand 0)
            -> read = 0 (in-place aliased buffer; the update operand carries
            the traffic). Mixed slice+DUS-dest uses sum the slice reads.
        None => read fully. This is what keeps scan-carried buffers (the
        lax.scan xs/ys and KV caches) from being recounted as full-tensor
        traffic on every loop iteration."""
        comp = comps.get(called)
        if comp is None:
            return {}
        users: dict[str, list[Instr]] = defaultdict(list)
        pidx: dict[str, int] = {}
        for ins in comp.values():
            if ins.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", ins.line)
                if m:
                    pidx[ins.name] = int(m.group(1))
            for o in ins.operands:
                users[o].append(ins)

        # dtype/layout round-trips (convert/bitcast/copy) are free on the TPU
        # target (the algebraic simplifier folds convert(DUS(convert(x),u)) ->
        # DUS(x,u)); treat them as transparent when classifying uses.
        _TRANSPARENT = ("convert", "bitcast", "copy", "reshape")

        def classify(tensor_name: str, seen=None) -> float | None:
            """Effective read bytes of `tensor_name` given its uses; None =>
            read fully."""
            seen = seen or set()
            if tensor_name in seen:
                return None
            seen.add(tensor_name)
            total = 0.0
            for u in users.get(tensor_name, []):
                if (
                    u.opcode in ("dynamic-slice", "slice", "gather")
                    and u.operands and u.operands[0] == tensor_name
                ):
                    total += _shape_bytes(u.type_str)
                elif (
                    u.opcode == "dynamic-update-slice"
                    and u.operands and u.operands[0] == tensor_name
                ):
                    continue  # aliased destination: no read
                elif u.opcode in _TRANSPARENT:
                    sub = classify(u.name, seen)
                    if sub is None:
                        return None
                    total += sub
                else:
                    return None
            return total

        out: dict[int, float | None] = {}
        for pname, i in pidx.items():
            out[i] = classify(pname) if users.get(pname) else None
        return out

    def _fusion_write_bytes(called: str, full_out: float) -> float:
        """Effective output bytes of a fusion: a dynamic-update-slice root
        writes only the update slice (the buffer is aliased in place);
        a tuple root sums per-element with the same rule."""
        comp = comps.get(called)
        if comp is None:
            return full_out

        def unwrap(ins: Instr, depth=0) -> Instr:
            """Follow transparent unary ops (convert/bitcast/copy/reshape) to
            the producing op — free on the TPU target."""
            while depth < 8 and ins.opcode in ("convert", "bitcast", "copy",
                                               "reshape") and ins.operands:
                nxt = comp.get(ins.operands[0])
                if nxt is None:
                    break
                ins = nxt
                depth += 1
            return ins

        def elem_bytes(ins: Instr) -> float:
            ins = unwrap(ins)
            if ins.opcode == "dynamic-update-slice" and len(ins.operands) > 1:
                upd = comp.get(ins.operands[1])
                return _shape_bytes(upd.type_str) if upd else _shape_bytes(ins.type_str)
            return _shape_bytes(ins.type_str)

        root = None
        for ins in comp.values():
            if "ROOT" in ins.line:
                root = ins
        if root is None:
            return full_out
        if root.opcode == "tuple":
            total = 0.0
            for o in root.operands:
                e = comp.get(o)
                total += elem_bytes(e) if e else 0.0
            return min(total, full_out)
        return min(elem_bytes(root), full_out)

    def comp_cost(name: str, fused: bool = False) -> HloCost:
        """`fused=True`: computation reached through a fusion boundary — its
        FLOPs count but its bytes are already covered by the boundary
        (operands+outputs); inner byte bumps are suppressed to avoid double
        counting (A3, EXPERIMENTS.md §Perf)."""
        key = (name, fused)
        if key in cache:
            return cache[key]
        cache[key] = HloCost()  # cycle guard
        comp = comps.get(name)
        if comp is None:
            return cache[key]
        cost = HloCost()
        types = {n: i.type_str for n, i in comp.items()}

        def operand_bytes(ins: Instr) -> float:
            return sum(_shape_bytes(types.get(o, "")) for o in ins.operands)

        _scope_skip = [False]  # per-instruction flag set in the walk loop

        def bump(op: str, nbytes: float, iname: str = ""):
            if fused:
                return  # bytes covered at the fusion boundary (A3)
            if _scope_skip[0]:
                cost.bytes_by_op["vmem-resident(discounted)"] = (
                    cost.bytes_by_op.get("vmem-resident(discounted)", 0.0) + nbytes
                )
                return
            cost.bytes_accessed += nbytes
            cost.bytes_by_op[op] = cost.bytes_by_op.get(op, 0.0) + nbytes
            if iname and nbytes > 0:
                key = f"{name}/{iname}"
                cost.detail[key] = cost.detail.get(key, 0.0) + nbytes

        def _scoped(ins: Instr) -> bool:
            """True if this instruction's tensors are VMEM-resident under the
            kernel-substitution model (op_name metadata hits a vmem scope).
            Fusions check their internal ops' metadata too."""
            if not vmem_scopes:
                return False
            if any(s in ins.attrs for s in vmem_scopes):
                return True
            if ins.opcode == "fusion":
                called = _attr_name(ins.attrs, "calls")
                comp_f = comps.get(called) if called else None
                if comp_f:
                    return any(
                        any(s in i2.attrs for s in vmem_scopes)
                        for i2 in comp_f.values()
                    )
            return False

        for ins in comp.values():
            op = ins.opcode
            out_b = _shape_bytes(ins.type_str)
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "partition-id", "replica-id"):
                continue
            _scope_skip[0] = op != "while" and _scoped(ins)
            if op == "while":
                cond_name = _attr_name(ins.attrs, "condition")
                body_name = _attr_name(ins.attrs, "body")
                trip = _trip_count(comps.get(cond_name, {}))
                if trip is None:
                    trip = 1
                    cost.unknown_while += 1
                body = comp_cost(body_name, fused) if body_name else HloCost()
                condc = comp_cost(cond_name, fused) if cond_name else HloCost()
                cost.add(body, trip)
                cost.add(condc, trip)
                continue
            if op in ("fusion", "call", "async-start", "custom-call"):
                called = _attr_name(ins.attrs, "calls") or _attr_name(ins.attrs, "to_apply")
                eff = _param_read_bytes(called) if called else {}
                if called:
                    cost.add(comp_cost(called, fused=True))
                rb = 0.0
                for i, o in enumerate(ins.operands):
                    full = _shape_bytes(types.get(o, ""))
                    e = eff.get(i)
                    rb += full if e is None else min(e, full)
                wb = _fusion_write_bytes(called, out_b) if called else out_b
                bump("fusion", wb + rb, ins.name)
                continue
            if op == "conditional":
                for branch in re.findall(r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w.\-]+)|false_computation=%?([\w.\-]+))", ins.attrs):
                    for b in branch:
                        if b:
                            for nm in b.split(","):
                                cost.add(comp_cost(nm.strip().lstrip("%"), fused))
                bump("conditional", out_b + operand_bytes(ins), ins.name)
                continue
            if op.startswith(_COLLECTIVES):
                size_in = operand_bytes(ins)
                size_out = out_b
                # XLA:CPU promotes bf16 all-reduces to f32 (reduction
                # computation renamed '*_promoted'); TPU runs them in bf16.
                # Count promoted f32 collectives at their original width.
                if "promoted" in ins.attrs and "f32[" in ins.type_str:
                    size_in *= 0.5
                    size_out *= 0.5
                n = _group_size(ins.attrs, n_partitions or 1)
                base = op.split("-start")[0].split("-done")[0]
                if "-done" in op:
                    continue  # counted at -start
                if base == "all-reduce":
                    wire = 2.0 * (n - 1) / max(n, 1) * size_in
                elif base == "all-gather":
                    wire = (n - 1) / max(n, 1) * size_out
                elif base == "reduce-scatter":
                    wire = (n - 1) / max(n, 1) * size_in
                elif base in ("all-to-all", "ragged-all-to-all"):
                    wire = (n - 1) / max(n, 1) * size_in
                else:  # collective-permute / broadcast
                    wire = size_in
                cost.collective_bytes += wire
                cost.collective_raw += max(size_in, size_out)
                cost.collective_count += 1
                cost.collective_breakdown[base] = cost.collective_breakdown.get(base, 0.0) + wire
                bump(base, size_in + size_out, ins.name)
                continue
            if op == "dot":
                dt, out_dims = _shape_dims(ins.type_str)
                lhs_t = types.get(ins.operands[0], "") if ins.operands else ""
                _, lhs_dims = _shape_dims(lhs_t)
                m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
                contract = 1
                if m and lhs_dims:
                    for d in m.group(1).split(","):
                        if d:
                            contract *= lhs_dims[int(d)]
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                f = 2.0 * out_elems * contract
                cost.flops += f
                cost.dot_flops += f
                bump("dot", out_b + operand_bytes(ins), ins.name)
                continue
            if op == "convolution":
                # rough: 2 * out_elems * (in_channels * kernel_spatial)
                dt, out_dims = _shape_dims(ins.type_str)
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                k = 1
                kt = types.get(ins.operands[1], "") if len(ins.operands) > 1 else ""
                _, kd = _shape_dims(kt)
                for d in kd[:-1]:
                    k *= d
                f = 2.0 * out_elems * max(k, 1)
                cost.flops += f
                cost.dot_flops += f
                bump("convolution", out_b + operand_bytes(ins), ins.name)
                continue
            # slicing family: reads are slice-sized, not whole-operand
            if op in ("dynamic-slice", "slice", "gather"):
                bump("dyn-slice", 2.0 * out_b, ins.name)
                cost.flops += 0
                continue
            if op == "dynamic-update-slice":
                upd = _shape_bytes(types.get(ins.operands[1], "")) if len(ins.operands) > 1 else out_b
                bump("dus", 2.0 * upd, ins.name)
                continue
            if op == "scatter":
                upd = _shape_bytes(types.get(ins.operands[-1], "")) if ins.operands else out_b
                bump("scatter", 3.0 * upd, ins.name)
                cost.flops += upd  # combiner adds
                continue
            # generic ops. Bytes policy ("perfect elementwise fusion"): bare
            # elementwise / layout ops are assumed fused into neighboring
            # kernels on TPU (CPU XLA leaves them unfused, which would
            # over-count HBM traffic ~10x — measured on stablelm train_4k).
            # Their FLOPs still count; their bytes don't. Materialization
            # points (dot/fusion/collective/slice/scatter/reduce/sort) carry
            # the traffic.
            if op in _ELEMENTWISE or op in _REDUCE_OPS or op in (
                "exponential", "sort", "iota", "map",
            ):
                dt, out_dims = _shape_dims(ins.type_str)
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                cost.flops += out_elems
                if op in ("exponential", "log", "tanh", "power", "rsqrt",
                          "sqrt", "cosine", "sine", "logistic", "erf"):
                    cost.transcendental += out_elems
                if op in _REDUCE_OPS or op == "sort":
                    bump("reduce", out_b + operand_bytes(ins), ins.name)
                continue
            if op in ("broadcast", "copy", "convert", "reshape", "transpose",
                      "reverse", "concatenate", "pad", "reduce-precision",
                      "rng", "rng-bit-generator", "optimization-barrier",
                      "custom-call", "get-dimension-size", "set-dimension-size",
                      "top-k", "dynamic-reshape", "copy-start", "copy-done"):
                continue  # layout/movement: fused or free in the TPU model
            bump(op, out_b + operand_bytes(ins), ins.name)
        cache[key] = cost
        return cost

    def _attr_name(attrs: str, key: str) -> str | None:
        m = re.search(rf"{key}=%?([\w.\-]+)", attrs)
        return m.group(1) if m else None

    # bind helper used before definition
    analyze_hlo_local = comp_cost
    return comp_cost(entry)
