"""Fault-tolerance runtime: restartable training, preemption hooks,
straggler detection, elastic re-shard.

Single-controller simulation of the multi-pod control plane:
  * RestartableLoop  — checkpoint cadence + resume-from-latest; any raised
    `SimulatedFailure` (or real crash + relaunch) resumes bitwise.
  * PreemptionSignal — SIGTERM-style flag the loop polls each step to
    checkpoint-and-exit inside the grace window (GCE/TPU preemption).
  * StragglerMonitor — robust z-score on per-step wall times; in a real
    fleet the callback would trigger hot-spare swap / re-shard. Here it
    feeds the elastic path: restore the same checkpoint onto a new mesh.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from .checkpointing import CheckpointManager

__all__ = ["SimulatedFailure", "PreemptionSignal", "StragglerMonitor", "RestartableLoop"]


class SimulatedFailure(RuntimeError):
    """Injected node failure for tests."""


class PreemptionSignal:
    def __init__(self, install_sigterm: bool = False):
        self._flag = False
        if install_sigterm:
            signal.signal(signal.SIGTERM, lambda *_: self.set())

    def set(self):
        self._flag = True

    def triggered(self) -> bool:
        return self._flag


@dataclass
class StragglerMonitor:
    window: int = 50
    threshold: float = 4.0  # robust z-score (MAD-based)
    times: list = field(default_factory=list)
    flagged: list = field(default_factory=list)

    def record(self, step: int, seconds: float) -> bool:
        self.times.append(seconds)
        hist = np.asarray(self.times[-self.window :])
        if hist.size < 8:
            return False
        med = np.median(hist[:-1])
        mad = np.median(np.abs(hist[:-1] - med)) + 1e-9
        z = (seconds - med) / (1.4826 * mad)
        if z > self.threshold:
            self.flagged.append((step, seconds, float(z)))
            return True
        return False


class RestartableLoop:
    """Drives `step_fn(state, batch) -> (state, metrics)` with checkpoint/
    restart semantics. Construction restores the newest checkpoint if one
    exists, so a crashed process relaunching with the same arguments
    continues exactly where it stopped."""

    def __init__(
        self,
        ckpt: CheckpointManager,
        init_state_fn: Callable[[], Any],
        save_every: int = 50,
        preemption: PreemptionSignal | None = None,
        straggler: StragglerMonitor | None = None,
        shardings: Any | None = None,
        resume: str = "auto",
    ):
        if resume not in ("auto", "never"):
            raise ValueError(f"resume must be 'auto' or 'never', got {resume!r}")
        self.ckpt = ckpt
        self.save_every = save_every
        self.preemption = preemption or PreemptionSignal()
        self.straggler = straggler or StragglerMonitor()
        latest = ckpt.latest_step() if resume == "auto" else None
        if latest is not None:
            template = init_state_fn()
            self.state, self.start_step = ckpt.restore(
                template, latest, shardings=shardings
            )
            self.resumed = True
        else:
            self.state = init_state_fn()
            self.start_step = 0
            self.resumed = False

    def run(
        self,
        step_fn,
        batches,
        n_steps: int,
        fail_at: int | None = None,
        on_metrics: Callable | None = None,
    ):
        """Returns (state, last_step_completed). `fail_at` injects a failure
        AFTER that step completes (post-checkpoint-cadence), testing resume."""
        step = self.start_step
        it = iter(batches)
        while step < n_steps:
            batch = next(it)
            t0 = time.perf_counter()
            self.state, metrics = step_fn(self.state, batch)
            dt = time.perf_counter() - t0
            step += 1
            self.straggler.record(step, dt)
            if on_metrics:
                on_metrics(step, metrics)
            if step % self.save_every == 0 or step == n_steps:
                self.ckpt.save(self.state, step)
            if self.preemption.triggered():
                self.ckpt.save(self.state, step)
                self.ckpt.wait()
                return self.state, step
            if fail_at is not None and step == fail_at:
                raise SimulatedFailure(f"injected failure at step {step}")
        self.ckpt.wait()
        return self.state, step
