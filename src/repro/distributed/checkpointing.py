"""Distributed checkpointing: atomic, async, keep-N, elastic reshard.

Layout:  <dir>/step_<n>/manifest.json + arrays.npz  (tmp-dir + rename for
atomicity; a crashed save can never shadow a good checkpoint). Restore
device_puts each leaf with the *target* sharding, so a checkpoint written on
one topology restores onto any other (elastic scaling) — leaves are saved as
full (addressable-gathered) arrays, the single-controller analogue of
per-shard writes + reshard-on-load.

Torn-write safety (the contract ``--resume auto`` depends on):

  * arrays are fsync'd and the manifest — which records a CRC32
    ``content_hash`` over the array payload — is written last inside the
    tmp dir, so a manifest's existence implies the arrays it describes
    were fully on disk *before* the publish rename;
  * the publish is a single ``os.rename`` of the tmp dir to a final name
    that never pre-exists for a new step (re-saving an existing step
    renames the old dir aside first and removes it only after the new one
    is live — there is no window where neither version exists);
  * ``latest_step`` ignores ``.tmp`` dirs and manifest-less dirs, and
    ``restore`` verifies the content hash — a SIGKILL at any byte of a
    save leaves the previous checkpoint as the newest *valid* one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any

import jax
import numpy as np

from ..faults import CKPT_TORN_WRITE, FAULTS, InjectedFault

__all__ = ["save", "restore", "latest_step", "CheckpointManager",
           "CheckpointCorrupt"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


class CheckpointCorrupt(RuntimeError):
    """The stored arrays do not match the manifest's content hash."""


def _fsync_file(p: str) -> None:
    fd = os.open(p, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _content_hash(npz_path: str) -> int:
    crc = 0
    with open(npz_path, "rb") as f:
        while chunk := f.read(1 << 20):
            crc = zlib.crc32(chunk, crc)
    return crc


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save(path: str, tree: Any, step: int, *, extra: dict | None = None) -> str:
    """Atomic synchronous save. Returns the final directory."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    keys, vals, _ = _flatten_with_paths(tree)
    host_vals = [np.asarray(jax.device_get(v)) for v in vals]
    arrays_path = os.path.join(tmp, _ARRAYS)
    np.savez(arrays_path, **dict(zip(keys, host_vals)))
    _fsync_file(arrays_path)
    if FAULTS.enabled and FAULTS.fire(CKPT_TORN_WRITE) is not None:
        # die between the arrays and the manifest: the tmp dir is left
        # torn and unpublished — latest_step must keep ignoring it
        raise InjectedFault("torn checkpoint write (injected)")
    manifest = {
        "step": step,
        "keys": keys,
        "dtypes": [str(v.dtype) for v in host_vals],
        "shapes": [list(v.shape) for v in host_vals],
        "time": time.time(),
        "content_hash": _content_hash(arrays_path),
        "extra": extra or {},
    }
    manifest_path = os.path.join(tmp, _MANIFEST)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    # Publish with a plain rename onto a name that does not exist: for a
    # new step that is the common case; when re-saving an existing step,
    # move the old dir aside first so there is never a moment where no
    # complete checkpoint dir carries this step's name.
    old = None
    if os.path.exists(final):
        old = final + ".old"
        if os.path.exists(old):
            shutil.rmtree(old)
        os.rename(final, old)
    os.rename(tmp, final)  # atomic publish
    if old is not None:
        shutil.rmtree(old, ignore_errors=True)
    return final


def latest_step(path: str) -> int | None:
    if not os.path.isdir(path):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(path)
        if d.startswith("step_")
        and not d.endswith(".tmp") and not d.endswith(".old")
        and os.path.exists(os.path.join(path, d, _MANIFEST))
    ]
    return max(steps) if steps else None


def restore(path: str, target: Any, step: int | None = None, shardings: Any | None = None):
    """Load into the structure of `target`; device_put with `shardings`
    (tree or single sharding) if given — elastic reshard happens here."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    want = manifest.get("content_hash")
    if want is not None and _content_hash(os.path.join(d, _ARRAYS)) != want:
        raise CheckpointCorrupt(
            f"checkpoint {d} arrays do not match manifest content_hash — "
            f"bit rot or a torn copy; restore an earlier step"
        )
    data = np.load(os.path.join(d, _ARRAYS))
    keys, vals, treedef = _flatten_with_paths(target)
    out = []
    for k, v in zip(keys, vals):
        arr = data[k]
        want = np.dtype(v.dtype) if hasattr(v, "dtype") else arr.dtype
        arr = arr.astype(want, copy=False)
        out.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    if shardings is not None:
        if jax.tree_util.tree_structure(shardings, is_leaf=lambda s: hasattr(s, "spec")) == jax.tree_util.tree_structure(tree):
            tree = jax.tree_util.tree_map(
                lambda a, s: jax.device_put(a, s), tree, shardings,
                is_leaf=lambda x: isinstance(x, np.ndarray),
            )
        else:
            tree = jax.tree_util.tree_map(lambda a: jax.device_put(a, shardings), tree)
    return tree, step


class CheckpointManager:
    """Async keep-N manager. save() snapshots to host synchronously (cheap)
    and writes on a worker thread (compute/IO overlap); wait() joins."""

    def __init__(self, path: str, keep: int = 3, async_write: bool = True):
        self.path = path
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(path, exist_ok=True)

    def save(self, tree: Any, step: int, extra: dict | None = None):
        host = jax.tree_util.tree_map(lambda v: np.asarray(jax.device_get(v)), tree)
        if self.async_write:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(host, step, extra), daemon=True
            )
            self._thread.start()
        else:
            self._write(host, step, extra)

    def _write(self, host, step, extra):
        save(self.path, host, step, extra=extra)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.path)
            if d.startswith("step_")
            and not d.endswith(".tmp") and not d.endswith(".old")
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"), ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def restore(self, target, step=None, shardings=None):
        self.wait()
        return restore(self.path, target, step, shardings)

    def latest_step(self):
        self.wait()
        return latest_step(self.path)
