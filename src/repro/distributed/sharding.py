"""Logical-axis -> mesh-axis mapping (MaxText-style).

Weight dims carry logical names; activations use ("batch", "seq", ...) names.
Rules below give FSDP over "data" (weights' embed dim), TP/EP over "model"
(heads / mlp / vocab / experts), pure DP over "pod" (batch only — gradients
cross pods once per step, FP8-compressed). A logical axis silently drops to
replicated when the dim isn't divisible by the mesh axis size (e.g. granite's
kv_heads=1), matching GSPMD practice.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "LOGICAL_RULES",
    "active_mesh",
    "use_mesh",
    "logical_to_spec",
    "named_sharding",
    "tree_shardings",
    "constrain",
]

# logical axis -> mesh axis (tuples shard one dim over several mesh axes)
LOGICAL_RULES: dict[str, Any] = {
    # --- weights ---
    "embed": "data",        # FSDP: params sharded over the data axis
    "embed2": None,
    "mlp": "model",         # TP
    "heads": "model",
    "kv_heads": "model",
    "vocab": "model",
    "expert": "model",      # EP
    "expert_inner": None,   # per-expert hidden dim (E already on model)
    "hidden": None,         # LSTM recurrent input dim (output dim shards)
    "hidden4": "model",
    "layers": None,         # scan axis
    # --- activations ---
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "model",   # sequence parallelism for long-context
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "expert_cap": "data",   # MoE buffer capacity dim
}

_STATE = threading.local()


def active_mesh() -> Mesh | None:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: dict | None = None):
    prev = getattr(_STATE, "mesh", None)
    prev_rules = getattr(_STATE, "rules", None)
    _STATE.mesh = mesh
    # nested use_mesh without explicit rules inherits the active overrides
    base = prev_rules if (rules is None and prev_rules) else LOGICAL_RULES
    _STATE.rules = {**base, **(rules or {})}
    try:
        with mesh:
            yield mesh
    finally:
        _STATE.mesh = prev
        _STATE.rules = prev_rules


def _rules() -> dict:
    return getattr(_STATE, "rules", None) or LOGICAL_RULES


def logical_to_spec(
    logical: Sequence[str | None], shape: Sequence[int] | None = None, mesh: Mesh | None = None
) -> P:
    """Map logical names to a PartitionSpec; drop non-divisible axes."""
    mesh = mesh or active_mesh()
    rules = _rules()
    out = []
    for i, name in enumerate(logical):
        ax = rules.get(name) if name else None
        if ax is not None and mesh is not None:
            # drop axes the mesh doesn't have (e.g. "pod" on single-pod)
            axes = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,)) if a in mesh.shape)
            ax = axes if len(axes) > 1 else (axes[0] if axes else None)
            if ax is not None and shape is not None:
                sizes = np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))])
                if shape[i] % int(sizes) != 0:
                    ax = None
        out.append(ax)
    return P(*out)


def named_sharding(logical, shape=None, mesh=None) -> NamedSharding:
    mesh = mesh or active_mesh()
    return NamedSharding(mesh, logical_to_spec(logical, shape, mesh))


def tree_shardings(spec_tree, shape_tree, mesh: Mesh | None = None):
    """specs (tuples of logical names) + shapes -> NamedSharding tree."""
    mesh = mesh or active_mesh()
    return jax.tree_util.tree_map(
        lambda s, x: named_sharding(s, getattr(x, "shape", x), mesh),
        spec_tree,
        shape_tree,
        is_leaf=lambda s: type(s) is tuple,
    )


def constrain(x: jax.Array, logical: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = active_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(logical, x.shape, mesh)
    )
