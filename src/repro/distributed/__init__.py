"""Distributed runtime: sharding rules, checkpointing, fault tolerance."""
from . import checkpointing, fault_tolerance, sharding
from .checkpointing import CheckpointManager
from .fault_tolerance import PreemptionSignal, RestartableLoop, SimulatedFailure, StragglerMonitor
from .sharding import constrain, logical_to_spec, named_sharding, tree_shardings, use_mesh

__all__ = [
    "checkpointing", "fault_tolerance", "sharding",
    "CheckpointManager", "PreemptionSignal", "RestartableLoop",
    "SimulatedFailure", "StragglerMonitor",
    "constrain", "logical_to_spec", "named_sharding", "tree_shardings", "use_mesh",
]
