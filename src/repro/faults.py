"""Seeded, deterministic fault injection for the serve/train stack.

Robustness work is untestable without a way to *cause* the failures on
demand, reproducibly. This module is the single switchboard: every layer
that can fail declares a **named injection point** and asks the process-
wide :data:`FAULTS` registry whether a fault fires at this arrival. When
no plan is armed the check is one attribute read (``FAULTS.enabled`` —
the same zero-overhead pattern as ``obs.trace.TRACER``), so production
paths pay nothing.

Injection points (the stable names callers and plans use):

=====================  ======================================================
``engine_step_raise``  ``ServeEngine.step_once`` raises ``InjectedFault``
                       before touching the device (transient replica error).
``engine_step_slow``   ``step_once`` sleeps ``ms`` before stepping
                       (straggling replica).
``replica_crash``      the engine marks itself crashed; every subsequent
                       step raises ``ReplicaCrash`` (sticky until the
                       process restarts — models a dead replica).
``cache_corrupt``      ``PrefixCache.insert`` flips one byte of the stored
                       FP8 snapshot *after* the checksum is computed, so a
                       later lookup must detect the corruption.
``nonfinite_logits``   ``step_once`` poisons one active lane's logits with
                       NaN on the host copy, exercising the engine's
                       nonfinite guard end to end.
``socket_drop``        the HTTP server aborts the connection mid-response.
``ckpt_torn_write``    ``checkpointing.save`` dies after writing arrays but
                       before publishing the manifest (torn checkpoint).
=====================  ======================================================

Plans are strings — CLI- and env-friendly (``REPRO_FAULTS=...``)::

    seed=42;replica_crash@6:key=1;cache_corrupt@2;engine_step_slow%0.1:ms=40:n=3

``;``-separated rules, each ``point`` plus modifiers:

  * ``@N``      fire on the Nth matching arrival (1-based), once.
  * ``%p``      fire each arrival with probability ``p`` (seeded, and
                deterministic given the arrival order).
  * ``:key=X``  only arrivals whose caller-supplied ``key`` equals ``X``
                count toward / trigger this rule (e.g. a replica index).
  * ``:n=K``    fire at most K times (default 1 for ``@``, unlimited
                for ``%``).
  * ``:<k>=<v>`` any other modifier is carried as a payload arg returned
                to the caller (e.g. ``ms=40`` for the slow fault).

Every fire increments ``injected[point]`` (exported as
``repro_faults_injected_total{point=...}``) and emits a ``fault.inject``
trace instant, so a chaos run's injections are visible in the same
Perfetto timeline as the recoveries they provoke.
"""
from __future__ import annotations

import os
import random
import threading
from typing import Optional

from .obs.trace import TRACER

__all__ = [
    "InjectedFault",
    "ReplicaCrash",
    "FaultRule",
    "FaultPlan",
    "Faults",
    "FAULTS",
    "ENGINE_STEP_RAISE",
    "ENGINE_STEP_SLOW",
    "REPLICA_CRASH",
    "CACHE_CORRUPT",
    "NONFINITE_LOGITS",
    "SOCKET_DROP",
    "CKPT_TORN_WRITE",
    "POINTS",
]

ENGINE_STEP_RAISE = "engine_step_raise"
ENGINE_STEP_SLOW = "engine_step_slow"
REPLICA_CRASH = "replica_crash"
CACHE_CORRUPT = "cache_corrupt"
NONFINITE_LOGITS = "nonfinite_logits"
SOCKET_DROP = "socket_drop"
CKPT_TORN_WRITE = "ckpt_torn_write"

#: Every known injection point; plans naming anything else are rejected
#: eagerly (a typo'd point would otherwise silently never fire).
POINTS = frozenset({
    ENGINE_STEP_RAISE,
    ENGINE_STEP_SLOW,
    REPLICA_CRASH,
    CACHE_CORRUPT,
    NONFINITE_LOGITS,
    SOCKET_DROP,
    CKPT_TORN_WRITE,
})


class InjectedFault(RuntimeError):
    """A deliberately injected, *recoverable* fault."""


class ReplicaCrash(InjectedFault):
    """The replica is gone for good — callers must eject, not retry."""


class FaultRule:
    """One parsed plan rule; tracks its own matching-arrival count."""

    def __init__(self, point: str, at: Optional[int] = None,
                 prob: Optional[float] = None, key: Optional[str] = None,
                 max_fires: Optional[int] = None, args: Optional[dict] = None):
        if point not in POINTS:
            raise ValueError(f"unknown fault point {point!r} "
                             f"(known: {', '.join(sorted(POINTS))})")
        if (at is None) == (prob is None):
            raise ValueError(f"rule for {point!r} needs exactly one of "
                             "@N (arrival) or %p (probability)")
        self.point = point
        self.at = at
        self.prob = prob
        self.key = key
        self.max_fires = max_fires if max_fires is not None else (
            1 if at is not None else None)
        self.args = dict(args or {})
        self.arrivals = 0
        self.fires = 0
        self._rng: Optional[random.Random] = None

    def seed(self, seed: int) -> None:
        # Per-rule stream: rules never perturb each other's draws, so
        # adding a rule to a plan does not reshuffle the others.
        self._rng = random.Random(f"{seed}:{self.point}:{self.key}")

    def matches(self, key) -> bool:
        return self.key is None or str(key) == self.key

    def check(self) -> bool:
        """Count one matching arrival; True iff the fault fires on it."""
        self.arrivals += 1
        if self.max_fires is not None and self.fires >= self.max_fires:
            return False
        if self.at is not None:
            hit = self.arrivals == self.at
        else:
            rng = self._rng or random.Random(f"0:{self.point}:{self.key}")
            self._rng = rng
            hit = rng.random() < (self.prob or 0.0)
        if hit:
            self.fires += 1
        return hit


def _parse_rule(text: str) -> FaultRule:
    head, *mods = text.split(":")
    at = prob = None
    if "@" in head:
        point, _, n = head.partition("@")
        at = int(n)
    elif "%" in head:
        point, _, p = head.partition("%")
        prob = float(p)
    else:
        raise ValueError(f"fault rule {text!r}: expected point@N or point%p")
    key = max_fires = None
    args: dict = {}
    for mod in mods:
        k, _, v = mod.partition("=")
        if not _ or not k:
            raise ValueError(f"fault rule {text!r}: bad modifier {mod!r}")
        if k == "key":
            key = v
        elif k == "n":
            max_fires = int(v)
        else:
            try:
                args[k] = float(v) if "." in v else int(v)
            except ValueError:
                args[k] = v
    return FaultRule(point.strip(), at=at, prob=prob, key=key,
                     max_fires=max_fires, args=args)


class FaultPlan:
    """A parsed, seeded set of rules. Immutable once built."""

    def __init__(self, rules, seed: int = 0):
        self.rules = list(rules)
        self.seed = seed
        for r in self.rules:
            r.seed(seed)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        seed = 0
        rules = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            if part.startswith("seed="):
                seed = int(part[5:])
            else:
                rules.append(_parse_rule(part))
        return cls(rules, seed=seed)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"FaultPlan(seed={self.seed}, rules={len(self.rules)})"


class Faults:
    """Process-wide fault switchboard.

    ``enabled`` is a plain bool attribute so the disabled fast path in hot
    loops is a single attribute read — identical to ``TRACER``'s contract.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self.enabled = False
        self._plan: Optional[FaultPlan] = None
        self.injected: dict = {}  # point -> fire count
        self.arrivals: dict = {}  # point -> matching-arrival count

    def arm(self, plan) -> None:
        """Arm a plan (a :class:`FaultPlan` or a spec string)."""
        if isinstance(plan, str):
            plan = FaultPlan.parse(plan)
        with self._lock:
            self._plan = plan
            self.injected = {}
            self.arrivals = {}
            self.enabled = bool(plan.rules)

    def disarm(self) -> None:
        with self._lock:
            self.enabled = False
            self._plan = None

    def fire(self, point: str, key=None, **ctx) -> Optional[dict]:
        """One arrival at ``point``. Returns the rule's payload args (a
        dict, never empty — it always carries ``point``) when a fault
        fires here, else ``None``. Callers gate on ``FAULTS.enabled``
        first so this is never reached with the layer off."""
        if not self.enabled:
            return None
        with self._lock:
            plan = self._plan
            if plan is None:
                return None
            fired = None
            for rule in plan.rules:
                if rule.point != point or not rule.matches(key):
                    continue
                self.arrivals[point] = self.arrivals.get(point, 0) + 1
                if rule.check():
                    fired = dict(rule.args, point=point)
                    self.injected[point] = self.injected.get(point, 0) + 1
                    break
        if fired is not None:
            TRACER.instant("fault.inject", cat="fault", point=point,
                           key=key, **ctx)
        return fired

    def stats(self) -> dict:
        with self._lock:
            return {
                "enabled": self.enabled,
                "injected": dict(self.injected),
                "arrivals": dict(self.arrivals),
            }


#: Process-wide switchboard, armed from ``REPRO_FAULTS`` at import so any
#: entry point (serve CLI, bench, smoke script) can inject via env alone.
FAULTS = Faults()

_env_plan = os.environ.get("REPRO_FAULTS", "")
if _env_plan and _env_plan != "0":
    FAULTS.arm(_env_plan)
