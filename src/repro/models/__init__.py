"""Model zoo: paper LSTMs + the 10 assigned architectures."""
from ..configs.base import ArchConfig
from .lm import CausalLM, cross_entropy
from .lstm_models import Multi30KSeq2Seq, SNLIClassifier, UDPOSTagger, WikiText2LM
from .whisper import Whisper


def build(cfg: ArchConfig, **kw):
    """Arch config -> model object with init/specs/loss/decode_step."""
    if cfg.family == "audio":
        kw.pop("attn_chunk", None)  # whisper uses its own fixed chunking
        return Whisper(cfg, **kw)
    if cfg.family == "lstm":
        return WikiText2LM(vocab=cfg.vocab, emb=cfg.d_model, hidden=cfg.d_model,
                           n_layers=cfg.n_layers)
    return CausalLM(cfg, **kw)


__all__ = [
    "build", "CausalLM", "Whisper", "cross_entropy",
    "UDPOSTagger", "SNLIClassifier", "Multi30KSeq2Seq", "WikiText2LM",
]
