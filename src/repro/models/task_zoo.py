"""The paper's four tasks as (model, data, optimizer) bundles (§IV-A).

`full=True` instantiates the paper-scale models (Table III parameter
counts); the default is a reduced configuration sized for the CPU container
that still exercises every quantization site, so FP32-vs-FloatSD8 curve
comparisons (Fig. 6 / Table IV) run anywhere.
"""
from __future__ import annotations

from ..data import synthetic
from ..optim import adam, sgd
from .lstm_models import (
    Multi30KSeq2Seq,
    SNLIClassifier,
    UDPOSTagger,
    WikiText2LM,
)

__all__ = ["make_task", "TASKS"]

TASKS = ("udpos", "snli", "multi30k", "wikitext2")


def make_task(name: str, full: bool = False):
    """Returns (model, data TaskSpec, optimizer, lr, metric attr name)."""
    if name == "udpos":
        model = UDPOSTagger() if full else UDPOSTagger(vocab=2000, emb=64, hidden=96)
        data = synthetic.udpos(batch=64, vocab=model.vocab, n_tags=model.n_tags)
        return model, data, adam(), 1e-3, "accuracy"
    if name == "snli":
        model = SNLIClassifier() if full else SNLIClassifier(
            vocab=4000, emb=96, proj=64, hidden=96
        )
        data = synthetic.snli(batch=128, vocab=model.vocab)
        return model, data, adam(), 1e-3, "accuracy"
    if name == "multi30k":
        model = Multi30KSeq2Seq() if full else Multi30KSeq2Seq(
            src_vocab=2000, tgt_vocab=2000, emb=96, hidden=128
        )
        data = synthetic.multi30k(batch=128, vocab=model.src_vocab)
        return model, data, adam(), 1e-3, "perplexity"
    if name == "wikitext2":
        model = WikiText2LM() if full else WikiText2LM(
            vocab=4000, emb=192, hidden=192, n_layers=2
        )
        data = synthetic.wikitext2(batch=64, seq=48, vocab=model.vocab)
        return model, data, sgd(0.9), 0.5 if full else 1.0, "perplexity"
    raise ValueError(name)
