"""The paper's four LSTM models (§IV-A), faithful to the described layouts.

  UDPOS     : embed -> 2-layer BiLSTM -> FC tagger            (ADAM, ls 1024)
  SNLI      : embed -> FC proj -> 1-layer BiLSTM -> 4xFC      (ADAM, ls 1024)
  Multi30K  : enc(embed+LSTM) -> dec(embed+LSTM+FC)           (ADAM, ls 1024)
  WikiText-2: embed -> 2-layer LSTM -> tied FC decoder        (SGD,  ls 1024)

All are built on the quantized LSTM/Dense sites, so swapping
Policy FP32 <-> FLOATSD8_TABLE2/6 reproduces Table IV's comparisons.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.policy import Policy
from ..nn.linear import QuantDense, QuantEmbedding
from ..nn.lstm import BiLSTM, LSTMLayer, LSTMState
from .lm import cross_entropy

__all__ = ["UDPOSTagger", "SNLIClassifier", "Multi30KSeq2Seq", "WikiText2LM"]


@dataclasses.dataclass(frozen=True)
class UDPOSTagger:
    vocab: int = 8000
    n_tags: int = 18
    emb: int = 100
    hidden: int = 128

    def init(self, key):
        ks = jax.random.split(key, 4)
        return {
            "embed": QuantEmbedding(self.vocab, self.emb).init(ks[0]),
            "bilstm1": BiLSTM(self.emb, self.hidden).init(ks[1]),
            "bilstm2": BiLSTM(2 * self.hidden, self.hidden).init(ks[2]),
            "out": QuantDense(2 * self.hidden, self.n_tags).init(ks[3]),
        }

    def specs(self):
        return {
            "embed": QuantEmbedding(self.vocab, self.emb).specs(),
            "bilstm1": BiLSTM(self.emb, self.hidden).specs(),
            "bilstm2": BiLSTM(2 * self.hidden, self.hidden).specs(),
            "out": QuantDense(2 * self.hidden, self.n_tags).specs(),
        }

    def logits(self, p, tokens, policy: Policy):
        x = QuantEmbedding(self.vocab, self.emb).apply(p["embed"], tokens, policy)
        x = BiLSTM(self.emb, self.hidden).apply(p["bilstm1"], x, policy)
        x = BiLSTM(2 * self.hidden, self.hidden).apply(p["bilstm2"], x, policy)
        return QuantDense(2 * self.hidden, self.n_tags).apply(p["out"], x, policy, site="last")

    def loss(self, p, batch, policy: Policy):
        lg = self.logits(p, batch["tokens"], policy)
        return cross_entropy(lg, batch["labels"], batch.get("mask"))

    def accuracy(self, p, batch, policy: Policy):
        lg = self.logits(p, batch["tokens"], policy)
        pred = jnp.argmax(lg, -1)
        m = batch.get("mask", jnp.ones_like(batch["labels"]))
        return jnp.sum((pred == batch["labels"]) * m) / jnp.maximum(jnp.sum(m), 1)


@dataclasses.dataclass(frozen=True)
class SNLIClassifier:
    vocab: int = 20000
    emb: int = 300
    proj: int = 200
    hidden: int = 300
    n_cls: int = 3

    def _mods(self):
        return (
            QuantEmbedding(self.vocab, self.emb),
            QuantDense(self.emb, self.proj),
            BiLSTM(self.proj, self.hidden),
            QuantDense(8 * self.hidden, 512),
            QuantDense(512, 512),
            QuantDense(512, 512),
            QuantDense(512, self.n_cls),
        )

    def init(self, key):
        emb, proj, lstm, f1, f2, f3, f4 = self._mods()
        ks = jax.random.split(key, 7)
        return {
            "embed": emb.init(ks[0]), "proj": proj.init(ks[1]),
            "bilstm": lstm.init(ks[2]),
            "fc1": f1.init(ks[3]), "fc2": f2.init(ks[4]),
            "fc3": f3.init(ks[5]), "fc4": f4.init(ks[6]),
        }

    def specs(self):
        emb, proj, lstm, f1, f2, f3, f4 = self._mods()
        return {
            "embed": emb.specs(), "proj": proj.specs(), "bilstm": lstm.specs(),
            "fc1": f1.specs(), "fc2": f2.specs(), "fc3": f3.specs(), "fc4": f4.specs(),
        }

    def _encode(self, p, tokens, policy):
        emb, proj, lstm, *_ = self._mods()
        x = emb.apply(p["embed"], tokens, policy)
        x = jax.nn.relu(proj.apply(p["proj"], x, policy))
        h = lstm.apply(p["bilstm"], x, policy)
        return jnp.max(h, axis=1)  # max-pool over time

    def logits(self, p, batch, policy: Policy):
        *_, f1, f2, f3, f4 = self._mods()
        u = self._encode(p, batch["premise"], policy)
        v = self._encode(p, batch["hypothesis"], policy)
        feat = jnp.concatenate([u, v, jnp.abs(u - v), u * v], axis=-1)
        h = jax.nn.relu(f1.apply(p["fc1"], feat, policy))
        h = jax.nn.relu(f2.apply(p["fc2"], h, policy))
        h = jax.nn.relu(f3.apply(p["fc3"], h, policy))
        return f4.apply(p["fc4"], h, policy, site="last")

    def loss(self, p, batch, policy: Policy):
        return cross_entropy(self.logits(p, batch, policy)[:, None, :], batch["label"][:, None])

    def accuracy(self, p, batch, policy: Policy):
        return jnp.mean(jnp.argmax(self.logits(p, batch, policy), -1) == batch["label"])


@dataclasses.dataclass(frozen=True)
class Multi30KSeq2Seq:
    src_vocab: int = 8000
    tgt_vocab: int = 8000
    emb: int = 256
    hidden: int = 512

    def _mods(self):
        return (
            QuantEmbedding(self.src_vocab, self.emb),
            LSTMLayer(self.emb, self.hidden),
            QuantEmbedding(self.tgt_vocab, self.emb),
            LSTMLayer(self.emb, self.hidden),
            QuantDense(self.hidden, self.tgt_vocab),
        )

    def init(self, key):
        se, sl, te, tl, out = self._mods()
        ks = jax.random.split(key, 5)
        return {
            "src_embed": se.init(ks[0]), "enc": sl.init(ks[1]),
            "tgt_embed": te.init(ks[2]), "dec": tl.init(ks[3]),
            "out": out.init(ks[4]),
        }

    def specs(self):
        se, sl, te, tl, out = self._mods()
        return {
            "src_embed": se.specs(), "enc": sl.specs(),
            "tgt_embed": te.specs(), "dec": tl.specs(), "out": out.specs(),
        }

    def logits(self, p, batch, policy: Policy):
        se, sl, te, tl, out = self._mods()
        xs = se.apply(p["src_embed"], batch["src"], policy)
        _, enc_state = sl.apply(p["enc"], xs, policy)
        xt = te.apply(p["tgt_embed"], batch["tgt_in"], policy)
        h, _ = tl.apply(p["dec"], xt, policy, state=enc_state)
        return out.apply(p["out"], h, policy, site="last")

    def loss(self, p, batch, policy: Policy):
        return cross_entropy(
            self.logits(p, batch, policy), batch["tgt_out"], batch.get("mask")
        )

    def perplexity(self, p, batch, policy: Policy):
        return jnp.exp(self.loss(p, batch, policy))


@dataclasses.dataclass(frozen=True)
class WikiText2LM:
    """Paper Table III: 84.98M params. vocab 33278, tied embeddings,
    2-layer LSTM hidden=1024 (33278*1024 tied + 2 * 4*(2*1024)*1024 ~ 85M).

    The embedding table is padded to a multiple of 256 so the vocab dim
    shards over the model axis (perf hillclimb #2b: the raw 33278 is not
    divisible by 16, which forces replicated logits + a [B,S,V] f32
    all-gather at 256-chip scale — measured in EXPERIMENTS.md §Perf).
    Padded logit columns are masked to -inf in the loss.
    REPRO_LSTM_PAD_VOCAB=0 restores the unpadded baseline.
    """

    vocab: int = 33278
    emb: int = 1024
    hidden: int = 1024
    n_layers: int = 2

    # every weight site (embedding gather/attend, LSTM gate matmuls, proj)
    # consumes PackedTensor leaves natively via the kernel dispatch layer,
    # so ServeEngine hands this model the packed tree as-is.
    supports_packed = True

    def _vp(self) -> int:
        import os

        if os.environ.get("REPRO_LSTM_PAD_VOCAB", "1") == "0":
            return self.vocab
        return -(-self.vocab // 256) * 256

    def _mods(self):
        proj = (
            QuantDense(self.hidden, self.emb, use_bias=False)
            if self.hidden != self.emb
            else None
        )
        return (
            QuantEmbedding(self._vp(), self.emb),
            [
                LSTMLayer(self.emb if i == 0 else self.hidden, self.hidden)
                for i in range(self.n_layers)
            ],
            proj,
        )

    def init(self, key):
        emb, layers, proj = self._mods()
        ks = jax.random.split(key, 2 + len(layers))
        p = {
            "embed": emb.init(ks[0]),
            **{f"lstm{i}": l.init(ks[1 + i]) for i, l in enumerate(layers)},
        }
        if proj is not None:
            p["proj"] = proj.init(ks[-1])
        return p

    def specs(self):
        emb, layers, proj = self._mods()
        s = {
            "embed": emb.specs(),
            **{f"lstm{i}": l.specs() for i, l in enumerate(layers)},
        }
        if proj is not None:
            s["proj"] = proj.specs()
        return s

    def logits(self, p, tokens, policy: Policy, states=None, lengths=None,
               inference=False):
        emb, layers, proj = self._mods()
        x = emb.apply(p["embed"], tokens, policy)
        new_states = []
        for i, l in enumerate(layers):
            x, st = l.apply(
                p[f"lstm{i}"], x, policy,
                None if states is None else states[i], lengths=lengths,
                inference=inference,
            )
            new_states.append(st)
        if proj is not None:
            x = proj.apply(p["proj"], x, policy)
        return emb.attend(p["embed"], x, policy), new_states

    def loss(self, p, batch, policy: Policy):
        from .lm import mask_padded_vocab

        lg, _ = self.logits(p, batch["tokens"], policy)
        lg = mask_padded_vocab(lg, self.vocab)
        return cross_entropy(lg, batch["labels"], batch.get("mask"))

    def perplexity(self, p, batch, policy: Policy):
        return jnp.exp(self.loss(p, batch, policy))

    def prefill(self, p, batch, policy: Policy):
        lg, _ = self.logits(p, batch["tokens"], policy)
        return lg

    # serve path: one token at a time with recurrent state as the "cache"
    def init_cache(self, batch, policy: Policy | None = None):
        emb, layers, _ = self._mods()
        hdt = (policy.cdt() if policy else None) or jnp.float32
        cdt = jnp.float16 if (policy and policy.master_dtype == "fp16") else jnp.float32
        return [
            LSTMState(
                jnp.zeros((batch, l.hidden), hdt),
                jnp.zeros((batch, l.hidden), cdt),
            )
            for l in layers
        ]

    def decode_step(self, p, tokens, states, policy: Policy, lengths=None):
        """One batched serving step over a [B, S] token block.

        ``p`` may be a dense param tree or a packed FloatSD8 weight-store
        tree (``kernels.dispatch.PackedTensor`` leaves, 1 byte/weight).
        Packed leaves are consumed at the weight sites themselves through
        the kernel dispatch layer: the embedding gathers codes and decodes
        only the gathered rows, and the gate matmuls either hoist one
        decode out of the time scan (ref backend) or feed the codes to the
        fused decode-in-VMEM Pallas matmul (pallas backend) — the paper
        PE's datapath. ``lengths`` ([B] int32) marks how many of the S
        positions are valid per lane (chunked prefill); the recurrent state
        freezes past each lane's length.
        """
        lg, new_states = self.logits(
            p, tokens, policy, states, lengths=lengths, inference=True
        )
        return lg, new_states
