"""CausalLM: one model class covering dense / moe / hybrid / ssm / vlm.

The family-specific structure lives entirely in `_period()` (which sub-blocks
a scan group contains); everything else — embedding, logits, loss, KV-cache
decode — is shared. The paper's precision policy threads through every
matmul site via `Policy`.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.policy import Policy
from ..distributed.sharding import constrain
from ..nn.attention import Attention
from ..nn.ffn import FFN
from ..nn.linear import QuantEmbedding, quant_act
from ..nn.mamba import Mamba
from ..nn.moe import MoE
from ..nn.norms import LayerNorm, RMSNorm
from ..nn.rwkv import RWKV6ChannelMix, RWKV6TimeMix
from ..nn.transformer import Block, Stack

__all__ = ["CausalLM", "cross_entropy", "mask_padded_vocab"]


def mask_padded_vocab(logits, vocab: int):
    """-inf the padded vocab tail without a scatter on the sharded dim."""
    if logits.shape[-1] == vocab:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, logits.shape[-1]), 2)
    return jnp.where(iota >= vocab, jnp.asarray(-1e30, logits.dtype), logits)


def cross_entropy(logits, labels, mask=None, z_loss: float = 0.0):
    """Vocab-parallel (Megatron-style) cross entropy: every reduction is
    over the (possibly model-sharded) vocab axis via max/exp/sum and a
    one-hot contraction — no gather/scatter on the sharded dim, so the
    partitioner never all-gathers the [B,S,V] logits. f32 reductions.
    """
    v = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    e = jnp.exp(lf - m)
    lse = jnp.log(jnp.sum(e, axis=-1)) + m[..., 0]
    onehot = (
        labels[..., None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, v), 2)
    )
    ll = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(nll)
    mk = mask.astype(jnp.float32)
    return jnp.sum(nll * mk) / jnp.maximum(jnp.sum(mk), 1.0)


@dataclasses.dataclass(frozen=True)
class CausalLM:
    cfg: ArchConfig
    remat: str = "dots"
    cache_dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024

    # ------------------------------------------------------------------
    def _attn(self, window=None):
        c = self.cfg
        return Attention(
            dim=c.d_model, heads=c.n_heads, kv_heads=c.kv_heads, head_dim=c.hd,
            window=window if window is not None else c.window,
            rope=c.rope, rope_theta=c.rope_theta,
            mrope_sections=c.mrope_sections, qkv_bias=c.qkv_bias,
            chunk=self.attn_chunk,
        )

    def _ffn(self, hidden=None):
        c = self.cfg
        return FFN(c.d_model, hidden or c.d_ff, kind=c.ffn_kind)

    def _moe(self):
        c = self.cfg
        return MoE(c.d_model, c.d_ff, c.n_experts, c.top_k)

    def _mamba(self):
        c = self.cfg
        return Mamba(c.d_model, d_state=c.mamba_state)

    def _period(self) -> tuple:
        """The sub-blocks of one scan group."""
        c = self.cfg
        if c.family in ("dense", "vlm", "audio"):
            return (Block(c.d_model, "attn", "ffn", attn=self._attn(), ffn_mod=self._ffn(), norm=c.norm),)
        if c.family == "moe":
            return (Block(c.d_model, "attn", "moe", attn=self._attn(), moe_mod=self._moe(), norm=c.norm),)
        if c.family == "hybrid":
            sub = []
            for i in range(c.attn_every):
                mixer = "attn" if i == c.attn_every // 2 - 1 else "mamba"
                mlp = "moe" if (i % c.moe_every == c.moe_every - 1) else "ffn"
                sub.append(
                    Block(
                        c.d_model, mixer, mlp,
                        attn=self._attn(), mamba_mod=self._mamba(),
                        ffn_mod=self._ffn(), moe_mod=self._moe(), norm=c.norm,
                    )
                )
            return tuple(sub)
        if c.family == "ssm":
            return (
                Block(
                    c.d_model, "rwkv", "none",
                    rwkv_mod=RWKV6TimeMix(c.d_model, c.rwkv_head_dim),
                    cmix_mod=RWKV6ChannelMix(c.d_model, c.d_ff), norm=c.norm,
                ),
            )
        raise ValueError(c.family)

    def _stack(self) -> Stack:
        c = self.cfg
        period = self._period()
        body_layers = c.n_layers - c.first_k_dense
        assert body_layers % len(period) == 0, (c.n_layers, len(period))
        return Stack(period, body_layers // len(period), remat=self.remat)

    def _head_blocks(self) -> tuple:
        """Unrolled leading dense layers (kimi first_k_dense)."""
        c = self.cfg
        return tuple(
            Block(c.d_model, "attn", "ffn", attn=self._attn(), ffn_mod=self._ffn(c.first_dense_ff or c.d_ff), norm=c.norm)
            for _ in range(c.first_k_dense)
        )

    def _embed(self):
        return QuantEmbedding(self.cfg.vocab_padded(), self.cfg.d_model)

    def _final_norm(self):
        return RMSNorm(self.cfg.d_model) if self.cfg.norm == "rmsnorm" else LayerNorm(self.cfg.d_model)

    # ------------------------------------------------------------------
    def init(self, key):
        ks = jax.random.split(key, 4 + self.cfg.first_k_dense)
        p = {
            "embed": self._embed().init(ks[0]),
            "stack": self._stack().init(ks[1]),
            "final_norm": self._final_norm().init(ks[2]),
        }
        for i, hb in enumerate(self._head_blocks()):
            p[f"head_block{i}"] = hb.init(ks[4 + i])
        if self.cfg.n_patches:
            p["patch_proj"] = {
                "w": jax.random.truncated_normal(ks[3], -2, 2, (self.cfg.d_model, self.cfg.d_model)) * 0.02
            }
        return p

    def specs(self):
        s = {
            "embed": self._embed().specs(),
            "stack": self._stack().specs(),
            "final_norm": self._final_norm().specs(),
        }
        for i, hb in enumerate(self._head_blocks()):
            s[f"head_block{i}"] = hb.specs()
        if self.cfg.n_patches:
            s["patch_proj"] = {"w": ("embed", "embed2")}
        return s

    # ------------------------------------------------------------------
    def _positions(self, batch_dict, b, s):
        c = self.cfg
        base = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if c.rope != "mrope":
            return base
        # M-RoPE: patches get (t=0, h, w) grid positions; text continues 1-D.
        npat = c.n_patches if "patch_embeds" in batch_dict else 0
        side = max(1, int(npat**0.5))
        t = jnp.where(base < npat, 0, base - npat + side)
        h = jnp.where(base < npat, (base % npat) // side, base - npat + side)
        w = jnp.where(base < npat, base % side, base - npat + side)
        return jnp.stack([t, h, w], axis=-1)

    def _embed_inputs(self, p, batch_dict, policy):
        c = self.cfg
        tokens = batch_dict["tokens"]
        x = self._embed().apply(p["embed"], tokens, policy)
        if c.n_patches and "patch_embeds" in batch_dict:
            pe = batch_dict["patch_embeds"].astype(x.dtype)  # [B, P, d]
            pe = jnp.einsum("bpd,de->bpe", pe, p["patch_proj"]["w"].astype(x.dtype))
            pad = x.shape[1] - pe.shape[1]
            is_patch = (jnp.arange(x.shape[1]) < c.n_patches)[None, :, None]
            pe_full = jnp.pad(pe, ((0, 0), (0, pad), (0, 0)))
            x = jnp.where(is_patch, pe_full, x)
        return x

    def forward(self, p, batch_dict, policy: Policy):
        """Full-sequence forward -> (logits, aux)."""
        c = self.cfg
        tokens = batch_dict["tokens"]
        b, s = tokens.shape
        x = self._embed_inputs(p, batch_dict, policy)
        x = constrain(x, ("batch", "seq", "act_embed"))
        pos = self._positions(batch_dict, b, s)
        aux = jnp.float32(0.0)
        for i, hb in enumerate(self._head_blocks()):
            x, a = hb.apply(p[f"head_block{i}"], x, policy, positions=pos)
            aux += a
        x, a = self._stack().apply(p["stack"], x, policy, positions=pos)
        aux += a
        x = self._final_norm().apply(p["final_norm"], x)
        logits = self._embed().attend(p["embed"], x, policy)
        logits = constrain(logits, ("batch", "seq", "vocab"))
        return logits, aux

    def loss(self, p, batch_dict, policy: Policy):
        logits, aux = self.forward(p, batch_dict, policy)
        logits = mask_padded_vocab(logits, self.cfg.vocab)
        ce = cross_entropy(logits, batch_dict["labels"], batch_dict.get("mask"))
        return ce + 0.01 * aux

    # ------------------------------------------------------------------
    def init_cache(self, batch, s_max):
        caches = {"stack": self._stack().init_cache(batch, s_max, self.cache_dtype)}
        for i, hb in enumerate(self._head_blocks()):
            caches[f"head_block{i}"] = hb.init_cache(batch, s_max, self.cache_dtype)
        return caches

    def cache_specs(self):
        specs = {"stack": self._stack().cache_specs()}
        for i, hb in enumerate(self._head_blocks()):
            specs[f"head_block{i}"] = hb.cache_specs()
        return specs

    def decode_step(self, p, tokens, caches, policy: Policy):
        """tokens [B,1] -> (logits [B,1,V], new caches). serve_step."""
        c = self.cfg
        b = tokens.shape[0]
        x = self._embed().apply(p["embed"], tokens, policy)
        pos3 = None
        new_caches = dict(caches)
        for i, hb in enumerate(self._head_blocks()):
            x, new_caches[f"head_block{i}"] = hb.decode(
                p[f"head_block{i}"], x, caches[f"head_block{i}"], policy, pos3
            )
        x, new_caches["stack"] = self._stack().decode(
            p["stack"], x, caches["stack"], policy, pos3
        )
        x = self._final_norm().apply(p["final_norm"], x)
        logits = self._embed().attend(p["embed"], x, policy)
        return logits, new_caches

    def prefill(self, p, batch_dict, policy: Policy):
        """Teacher-forced pass producing logits; inference-prefill shape.

        (KV-cache materialization for subsequent decode reuses decode_step's
        ring-buffer layout; the prefill compute cost — what the roofline
        measures — is the full forward.)
        """
        logits, _ = self.forward(p, batch_dict, policy)
        return logits
