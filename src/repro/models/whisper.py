"""Whisper large-v3 backbone: 32-layer encoder + 32-layer decoder.

Per the assignment the audio frontend (mel + two convs) is a STUB:
``input_specs()`` feeds precomputed 1500-frame embeddings [B, 1500, d] to the
encoder stack directly. Decoder = causal self-attn + cross-attn + GELU FFN,
all matmuls FloatSD8xFP8 sites.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..core.policy import Policy
from ..distributed.sharding import constrain
from ..nn import module as M
from ..nn.attention import Attention, KVCache
from ..nn.ffn import FFN
from ..nn.linear import QuantEmbedding
from ..nn.norms import LayerNorm
from .lm import cross_entropy

__all__ = ["Whisper"]


@dataclasses.dataclass(frozen=True)
class Whisper:
    cfg: ArchConfig
    remat: str = "dots"
    cache_dtype: Any = jnp.bfloat16

    def _attn(self, causal):
        c = self.cfg
        return Attention(
            dim=c.d_model, heads=c.n_heads, kv_heads=c.kv_heads, head_dim=c.hd,
            causal=causal, rope="none", qkv_bias=c.qkv_bias, chunk=512,
        )

    def _ffn(self):
        return FFN(self.cfg.d_model, self.cfg.d_ff, kind="gelu")

    # ----- layers ------------------------------------------------------
    def _enc_layer_init(self, key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "ln1": LayerNorm(self.cfg.d_model).init(k1),
            "attn": self._attn(False).init(k2),
            "ln2": LayerNorm(self.cfg.d_model).init(k3),
            "ffn": self._ffn().init(k4),
        }

    def _enc_layer_specs(self):
        return {
            "ln1": LayerNorm(self.cfg.d_model).specs(),
            "attn": self._attn(False).specs(),
            "ln2": LayerNorm(self.cfg.d_model).specs(),
            "ffn": self._ffn().specs(),
        }

    def _dec_layer_init(self, key):
        ks = jax.random.split(key, 6)
        return {
            "ln1": LayerNorm(self.cfg.d_model).init(ks[0]),
            "self_attn": self._attn(True).init(ks[1]),
            "ln_x": LayerNorm(self.cfg.d_model).init(ks[2]),
            "cross_attn": self._attn(False).init(ks[3]),
            "ln2": LayerNorm(self.cfg.d_model).init(ks[4]),
            "ffn": self._ffn().init(ks[5]),
        }

    def _dec_layer_specs(self):
        return {
            "ln1": LayerNorm(self.cfg.d_model).specs(),
            "self_attn": self._attn(True).specs(),
            "ln_x": LayerNorm(self.cfg.d_model).specs(),
            "cross_attn": self._attn(False).specs(),
            "ln2": LayerNorm(self.cfg.d_model).specs(),
            "ffn": self._ffn().specs(),
        }

    # ----- init ----------------------------------------------------------
    def init(self, key):
        c = self.cfg
        ks = jax.random.split(key, 5)
        return {
            "embed": QuantEmbedding(c.vocab_padded(), c.d_model).init(ks[0]),
            "pos_dec": M.truncated_normal_init(ks[1], (4096, c.d_model), 0.01),
            "pos_enc": M.truncated_normal_init(ks[2], (c.enc_seq, c.d_model), 0.01),
            "enc": M.stack_init(self._enc_layer_init, c.enc_layers)(ks[3]),
            "dec": M.stack_init(self._dec_layer_init, c.n_layers)(ks[4]),
            "ln_enc": LayerNorm(c.d_model).init(ks[0]),
            "ln_dec": LayerNorm(c.d_model).init(ks[1]),
        }

    def specs(self):
        c = self.cfg
        return {
            "embed": QuantEmbedding(c.vocab_padded(), c.d_model).specs(),
            "pos_dec": (None, "act_embed"),
            "pos_enc": (None, "act_embed"),
            "enc": M.stack_specs(self._enc_layer_specs()),
            "dec": M.stack_specs(self._dec_layer_specs()),
            "ln_enc": LayerNorm(c.d_model).specs(),
            "ln_dec": LayerNorm(c.d_model).specs(),
        }

    # ----- forward -------------------------------------------------------
    def encode(self, p, frames, policy: Policy):
        """frames: [B, enc_seq, d] stub embeddings -> encoder states."""
        c = self.cfg
        x = frames + p["pos_enc"].astype(frames.dtype)[None]
        ln1, ln2 = LayerNorm(c.d_model), LayerNorm(c.d_model)
        attn, ffn = self._attn(False), self._ffn()

        def body(x, lp):
            h = attn.apply(lp["attn"], ln1.apply(lp["ln1"], x), policy)
            x = x + h
            x = x + ffn.apply(lp["ffn"], ln2.apply(lp["ln2"], x), policy)
            return x, None

        fn = jax.checkpoint(body, prevent_cse=False) if self.remat != "none" else body
        x, _ = jax.lax.scan(fn, x, p["enc"])
        return LayerNorm(c.d_model).apply(p["ln_enc"], x)

    def decode_seq(self, p, tokens, enc_states, policy: Policy):
        """Teacher-forced decoder pass -> logits [B, S, V]."""
        c = self.cfg
        emb = QuantEmbedding(c.vocab_padded(), c.d_model)
        x = emb.apply(p["embed"], tokens, policy)
        s = tokens.shape[1]
        pos_table = p["pos_dec"]
        if s > pos_table.shape[0]:  # extend by tiling for the 32k shapes
            reps = -(-s // pos_table.shape[0])
            pos_table = jnp.tile(pos_table, (reps, 1))
        x = x + pos_table[:s].astype(x.dtype)[None]
        x = constrain(x, ("batch", "seq", "act_embed"))
        ln1, lnx, ln2 = LayerNorm(c.d_model), LayerNorm(c.d_model), LayerNorm(c.d_model)
        sattn, xattn, ffn = self._attn(True), self._attn(False), self._ffn()

        def body(x, lp):
            x = x + sattn.apply(lp["self_attn"], ln1.apply(lp["ln1"], x), policy)
            x = x + xattn.apply(
                lp["cross_attn"], lnx.apply(lp["ln_x"], x), policy, kv=enc_states
            )
            x = x + ffn.apply(lp["ffn"], ln2.apply(lp["ln2"], x), policy)
            return x, None

        fn = jax.checkpoint(body, prevent_cse=False) if self.remat != "none" else body
        x, _ = jax.lax.scan(fn, x, p["dec"])
        x = LayerNorm(c.d_model).apply(p["ln_dec"], x)
        return emb.attend(p["embed"], x, policy)

    def loss(self, p, batch_dict, policy: Policy):
        enc = self.encode(p, batch_dict["frames"], policy)
        logits = self.decode_seq(p, batch_dict["tokens"], enc, policy)
        from .lm import mask_padded_vocab

        logits = mask_padded_vocab(logits, self.cfg.vocab)
        return cross_entropy(logits, batch_dict["labels"], batch_dict.get("mask"))

    # ----- incremental decode ---------------------------------------------
    def init_cache(self, batch, s_max):
        c = self.cfg
        self_c = [
            KVCache.init(batch, s_max, c.kv_heads, c.hd, self.cache_dtype)
            for _ in range(c.n_layers)
        ]
        cross_k = jnp.zeros((c.n_layers, batch, c.enc_seq, c.kv_heads, c.hd), self.cache_dtype)
        return {
            "self": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *self_c),
            "cross_k": cross_k,
            "cross_v": cross_k,
        }

    def cache_specs(self):
        from ..nn.module import stack_specs

        self_spec = KVCache(
            ("layers", "batch", "seq", "act_kv_heads", None),
            ("layers", "batch", "seq", "act_kv_heads", None),
            ("layers",),
        )
        cross = ("layers", "batch", None, "act_kv_heads", None)
        return {"self": self_spec, "cross_k": cross, "cross_v": cross}

    def prefill_cross(self, p, frames, caches, policy: Policy):
        """Run encoder once; fill per-layer cross-attn KV caches."""
        c = self.cfg
        enc = self.encode(p, frames, policy)
        xattn = self._attn(False)

        def body(_, lp):
            kh, hd = c.kv_heads, c.hd
            b, sk, _ = enc.shape
            k = xattn._dense(kh * hd, "kv_heads", c.qkv_bias).apply(lp["cross_attn"]["wk"], enc, policy).reshape(b, sk, kh, hd)
            v = xattn._dense(kh * hd, "kv_heads", c.qkv_bias).apply(lp["cross_attn"]["wv"], enc, policy).reshape(b, sk, kh, hd)
            return None, (k.astype(self.cache_dtype), v.astype(self.cache_dtype))

        _, (ks, vs) = jax.lax.scan(body, None, p["dec"])
        return {**caches, "cross_k": ks, "cross_v": vs}

    def decode_step(self, p, tokens, caches, policy: Policy):
        """One decoder token step against cached self/cross KV."""
        c = self.cfg
        emb = QuantEmbedding(c.vocab_padded(), c.d_model)
        x = emb.apply(p["embed"], tokens, policy)
        pos = caches["self"].pos[0]  # all layers share the same position
        x = x + jnp.take(
            p["pos_dec"], pos % p["pos_dec"].shape[0], axis=0
        ).astype(x.dtype)
        ln1, lnx, ln2 = LayerNorm(c.d_model), LayerNorm(c.d_model), LayerNorm(c.d_model)
        sattn, xattn, ffn = self._attn(True), self._attn(False), self._ffn()

        def body(x, inp):
            lp, sc, ck, cv = inp
            h, sc2 = sattn.decode(lp["self_attn"], ln1.apply(lp["ln1"], x), sc, policy)
            x = x + h
            # cross-attn against cached enc KV (no causal mask)
            hq = lnx.apply(lp["ln_x"], x)
            b = hq.shape[0]
            q = xattn._dense(c.n_heads * c.hd, "heads", c.qkv_bias).apply(lp["cross_attn"]["wq"], hq, policy)
            q = q.reshape(b, 1, c.kv_heads, c.n_heads // c.kv_heads, c.hd).astype(jnp.float32)
            sc_ = jnp.einsum("bqkgd,bckd->bkgqc", q / jnp.sqrt(c.hd), ck.astype(jnp.float32))
            w = jax.nn.softmax(sc_, axis=-1)
            o = jnp.einsum("bkgqc,bckd->bqkgd", w, cv.astype(jnp.float32)).reshape(b, 1, c.n_heads * c.hd).astype(x.dtype)
            from ..nn.linear import QuantDense

            o = QuantDense(c.n_heads * c.hd, c.d_model, use_bias=False, in_axis="heads", out_axis="embed").apply(
                lp["cross_attn"]["wo"], o, policy
            )
            x = x + o
            x = x + ffn.apply(lp["ffn"], ln2.apply(lp["ln2"], x), policy)
            return x, sc2

        x, new_self = jax.lax.scan(
            body, x, (p["dec"], caches["self"], caches["cross_k"], caches["cross_v"])
        )
        x = LayerNorm(c.d_model).apply(p["ln_dec"], x)
        logits = emb.attend(p["embed"], x, policy)
        return logits, {**caches, "self": new_self}
