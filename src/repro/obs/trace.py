"""Bounded ring-buffer span tracer with Chrome trace-event export.

Stdlib-only on purpose: the serving hot path (engine worker thread, the
asyncio pump, the HTTP handlers) imports this module, so it must never
pull jax — telemetry code that needs jnp lives in ``obs.telemetry``.

Design points:

  * **Bounded**: events land in a ``collections.deque(maxlen=capacity)``;
    when the ring wraps, the oldest events are dropped (and counted in
    ``dropped``). A long-lived server can leave tracing on forever and the
    buffer stays O(capacity).
  * **Thread/async-safe**: one ``threading.Lock`` guards the ring and the
    aggregate table. Events record ``threading.get_ident()`` as their
    ``tid``, so spans emitted concurrently from the engine worker thread
    and the asyncio event loop land on separate tracks and never pair
    against each other.
  * **Monotonic clock**: timestamps are ``time.monotonic_ns() // 1000``
    (microseconds) — the unit Chrome trace-event JSON expects — so traces
    are immune to wall-clock steps.
  * **~zero cost when disabled**: every emitting entry point checks
    ``self._enabled`` first and returns a cached no-op context manager, so
    a disabled tracer costs one attribute load, one branch, and whatever
    the caller spent building kwargs (callers on hot paths guard arg
    construction with ``TRACER.enabled``). See tests/test_obs.py for the
    measured bound.
  * **Export-time sanitization**: ``chrome_trace()`` drops orphan ``E``
    events (whose ``B`` was evicted by the ring) and unterminated ``B``
    events (spans still open at export), so every exported trace has
    matched B/E pairs and loads cleanly in Perfetto / chrome://tracing.

A module-level ``TRACER`` is the instance the whole stack shares; the
``REPRO_TRACE=1`` environment variable enables it at import time.
"""
from __future__ import annotations

import collections
import os
import threading
import time

__all__ = ["Tracer", "TRACER", "span", "instant", "counter"]

_DEFAULT_CAPACITY = 65536


class _NullSpan:
    """Reused no-op context manager: the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Live span: emits a matched B/E pair and feeds the aggregate table."""

    __slots__ = ("_tr", "_name", "_cat", "_args", "_t0")

    def __init__(self, tr: "Tracer", name: str, cat: str, args: dict):
        self._tr = tr
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._t0 = time.monotonic_ns() // 1000
        self._tr._push(
            {
                "name": self._name,
                "cat": self._cat,
                "ph": "B",
                "ts": self._t0,
                "pid": self._tr.pid,
                "tid": threading.get_ident(),
                "args": self._args,
            }
        )
        return self

    def __exit__(self, *exc):
        t1 = time.monotonic_ns() // 1000
        tr = self._tr
        tr._push(
            {
                "name": self._name,
                "cat": self._cat,
                "ph": "E",
                "ts": t1,
                "pid": tr.pid,
                "tid": threading.get_ident(),
            }
        )
        with tr._lock:
            cnt, tot = tr._agg.get(self._name, (0, 0))
            tr._agg[self._name] = (cnt + 1, tot + (t1 - self._t0))
        return False


class Tracer:
    """Thread-safe bounded tracer. See module docstring for semantics."""

    def __init__(self, capacity: int = _DEFAULT_CAPACITY, pid: int = 0):
        if capacity < 2:
            raise ValueError("capacity must hold at least one B/E pair")
        self.capacity = capacity
        self.pid = pid
        self._events: collections.deque = collections.deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._enabled = False
        self.emitted = 0  # total events pushed since last clear()
        self.dropped = 0  # ... of which the ring evicted
        # per-span-name aggregates survive ring eviction: name -> (count,
        # total duration in us). Powers /metrics span totals.
        self._agg: dict = {}

    # -- switches --------------------------------------------------------
    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._agg = {}
            self.emitted = 0
            self.dropped = 0

    # -- emission --------------------------------------------------------
    def _push(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(ev)
            self.emitted += 1

    def span(self, name: str, cat: str = "repro", **args):
        """Context manager emitting a matched B/E pair around the body."""
        if not self._enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args)

    def complete(
        self, name: str, ts_us: int, dur_us: int, cat: str = "repro", **args
    ) -> None:
        """Retroactive complete event (``ph: "X"``) for scopes that await:
        a ``span()`` on the asyncio event loop would interleave its B/E
        with other coroutines on the same thread and break nesting, so
        async scopes take a start stamp (``time.monotonic_ns() // 1000``)
        and emit one X event with an explicit duration at completion —
        X events need no pairing and tolerate same-tid overlap."""
        if not self._enabled:
            return
        self._push(
            {
                "name": name,
                "cat": cat,
                "ph": "X",
                "ts": ts_us,
                "dur": max(int(dur_us), 0),
                "pid": self.pid,
                "tid": threading.get_ident(),
                "args": args,
            }
        )
        with self._lock:
            cnt, tot = self._agg.get(name, (0, 0))
            self._agg[name] = (cnt + 1, tot + max(int(dur_us), 0))

    def instant(self, name: str, cat: str = "repro", **args) -> None:
        """Point event (``ph: "i"``) — admissions, retires, flushes."""
        if not self._enabled:
            return
        self._push(
            {
                "name": name,
                "cat": cat,
                "ph": "i",
                "s": "t",  # thread-scoped instant
                "ts": time.monotonic_ns() // 1000,
                "pid": self.pid,
                "tid": threading.get_ident(),
                "args": args,
            }
        )
        with self._lock:
            cnt, tot = self._agg.get(name, (0, 0))
            self._agg[name] = (cnt + 1, tot)

    def counter(self, name: str, cat: str = "repro", **values) -> None:
        """Counter-track sample (``ph: "C"``) — queue depth over time."""
        if not self._enabled:
            return
        self._push(
            {
                "name": name,
                "cat": cat,
                "ph": "C",
                "ts": time.monotonic_ns() // 1000,
                "pid": self.pid,
                "tid": threading.get_ident(),
                "args": values,
            }
        )

    # -- export / introspection -----------------------------------------
    def events(self) -> list:
        """Raw snapshot of the ring (unsanitized), oldest first."""
        with self._lock:
            return list(self._events)

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON document, sanitized so every B has a
        matching E on the same tid (ring eviction can orphan either end;
        see module docstring)."""
        events = self.events()
        # X events are pushed at completion but stamped with their start
        # ts; a stable sort restores global ts order (ties keep push
        # order, so a B still precedes its same-microsecond E).
        events.sort(key=lambda e: e["ts"])
        keep = [True] * len(events)
        open_b: dict = {}  # tid -> stack of indices of open B events
        for i, ev in enumerate(events):
            ph = ev["ph"]
            if ph == "B":
                open_b.setdefault(ev["tid"], []).append(i)
            elif ph == "E":
                stack = open_b.get(ev["tid"])
                if stack:
                    stack.pop()
                else:
                    keep[i] = False  # orphan E: its B was evicted
        for stack in open_b.values():
            for i in stack:
                keep[i] = False  # span still open at export time
        return {
            "traceEvents": [ev for i, ev in enumerate(events) if keep[i]],
            "displayTimeUnit": "ms",
        }

    def stats(self) -> dict:
        """Aggregates for /metrics: totals plus per-span-name counts and
        cumulative durations (seconds). Cheap; safe to call while tracing."""
        with self._lock:
            agg = dict(self._agg)
            return {
                "enabled": self._enabled,
                "emitted": self.emitted,
                "dropped": self.dropped,
                "buffered": len(self._events),
                "spans": {
                    name: {"count": cnt, "total_s": tot / 1e6}
                    for name, (cnt, tot) in sorted(agg.items())
                },
            }


#: Process-wide tracer shared by every layer of the stack.
TRACER = Tracer()

if os.environ.get("REPRO_TRACE", "") not in ("", "0"):
    TRACER.enable()


def span(name: str, cat: str = "repro", **args):
    return TRACER.span(name, cat, **args)


def instant(name: str, cat: str = "repro", **args) -> None:
    TRACER.instant(name, cat, **args)


def counter(name: str, cat: str = "repro", **values) -> None:
    TRACER.counter(name, cat, **values)
