"""Quantization-health telemetry for FloatSD8/FP8 training (paper §III).

The paper's training scheme lives or dies by a handful of numerical
events that a loss curve cannot show:

  * **FP8 grad saturation / underflow** — loss-scaled gradients that clamp
    at the e5m2 max (±57344) or round to zero below the subnormal floor at
    the §III-D ``grad_quant`` sweep. Sustained saturation means the loss
    scale is too high; a growing underflow fraction means it is too low.
  * **FloatSD carry / clamp** — master-weight updates large enough to move
    a weight to a different FloatSD8 grid point (a signed-digit group
    carry in the paper's circuit), and weights pinned at the top of the
    exponent-biased grid (saturating rounding in ``core.floatsd.quantize``).
  * **Loss-scale adjustments** and per-layer grad-norm snapshots.

``make_train_step(..., telemetry=True)`` computes the jnp-side stats below
inside the jitted step and returns them under ``metrics["tel"]``;
``TelemetryLogger`` aggregates those per-step dicts host-side into
``TrainTelemetry`` records and appends them to a JSONL events file.

``KERNEL_STATS`` is the host-side sink for the in-kernel FP8 flush hook:
``kernels.dispatch.matmul_dw`` reports saturation/zero fractions of every
flushed dW via ``jax.debug.callback`` when the sink is enabled (a
trace-time switch: enable it *before* the first step compiles).

This module may import jax (unlike ``obs.trace``, which stays stdlib-only
for the serving hot path).
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..core import floatsd
from ..core.fp8 import FP8_E5M2, _MAX

__all__ = [
    "FP8_SAT_THRESHOLD",
    "FP8_UNDERFLOW_THRESHOLD",
    "fp8_grad_stats",
    "layer_grad_norms",
    "floatsd_update_stats",
    "KernelStats",
    "KERNEL_STATS",
    "TrainTelemetry",
    "TelemetryLogger",
]

#: e5m2 saturating clamp value (``core.fp8.quantize_fp8``).
FP8_SAT_THRESHOLD = float(_MAX[FP8_E5M2])
#: Below half the smallest e5m2 subnormal (2^-16), round-to-nearest-even
#: sends a nonzero gradient to exactly zero.
FP8_UNDERFLOW_THRESHOLD = 2.0 ** -17


def fp8_grad_stats(tree) -> dict:
    """Saturation/underflow/zero fractions over a (loss-scaled) grad tree.

    Evaluated at the §III-D ``grad_quant`` sweep point, i.e. on the values
    the FP8 quantizer sees. On leaves the fused backward kernels already
    emitted on the fp8 grid, ``sat_frac`` counts values sitting AT the
    clamp (post-quant) and ``underflow_frac`` is zero by construction —
    underflowed values are already exact zeros, counted by ``zero_frac``.
    Returns f32 scalars (jit-safe).
    """
    n = jnp.zeros((), jnp.float32)
    sat = jnp.zeros((), jnp.float32)
    under = jnp.zeros((), jnp.float32)
    zero = jnp.zeros((), jnp.float32)
    for g in jax.tree_util.tree_leaves(tree):
        a = jnp.abs(g.astype(jnp.float32))
        n += a.size
        sat += jnp.sum(a >= FP8_SAT_THRESHOLD).astype(jnp.float32)
        under += jnp.sum(
            (a > 0) & (a < FP8_UNDERFLOW_THRESHOLD)
        ).astype(jnp.float32)
        zero += jnp.sum(a == 0).astype(jnp.float32)
    n = jnp.maximum(n, 1.0)
    return {
        "fp8_sat_frac": sat / n,
        "fp8_underflow_frac": under / n,
        "fp8_zero_frac": zero / n,
    }


def layer_grad_norms(grads) -> dict:
    """Per-top-level-key L2 norms of a grad tree (f32 scalars).

    Keyed by the model's parameter groups (the dict ``model.init`` returns);
    a non-dict tree gets a single ``"all"`` entry.
    """
    def _norm(sub) -> jax.Array:
        sq = sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(sub)
        )
        return jnp.sqrt(jnp.asarray(sq, jnp.float32))

    if isinstance(grads, dict):
        return {str(k): _norm(v) for k, v in sorted(grads.items())}
    return {"all": _norm(grads)}


def floatsd_update_stats(old_params, new_params) -> dict:
    """FloatSD carry/clamp fractions for one master-weight update.

    Over every weight-matrix leaf (ndim >= 2 — the tensors the models
    FloatSD8-quantize at use):

      * ``sd_carry_frac`` — fraction of weights whose nearest FloatSD8 grid
        point changed between the old and new master value (quantized on a
        shared bias so the comparison is grid-aligned). In the paper's
        circuit this is exactly an SD mantissa-group update, carries
        included.
      * ``sd_clamp_frac`` — fraction of new weights at/beyond the top of
        the exponent-biased grid, where ``quantize``'s saturating rounding
        clamps them.
    """
    top = float(floatsd._GRID_POS[-1])
    n = jnp.zeros((), jnp.float32)
    carried = jnp.zeros((), jnp.float32)
    clamped = jnp.zeros((), jnp.float32)
    old_leaves = jax.tree_util.tree_leaves(old_params)
    new_leaves = jax.tree_util.tree_leaves(new_params)
    for o, w in zip(old_leaves, new_leaves):
        if w.ndim < 2:
            continue
        bias = floatsd.fit_bias(w)  # the quantize-at-use bias
        q_old = floatsd.quantize(o.astype(jnp.float32), bias).values
        q_new = floatsd.quantize(w.astype(jnp.float32), bias).values
        n += w.size
        carried += jnp.sum(q_old != q_new).astype(jnp.float32)
        scale = floatsd.exp2i(bias)
        clamped += jnp.sum(
            jnp.abs(w.astype(jnp.float32)) >= top * scale
        ).astype(jnp.float32)
    n = jnp.maximum(n, 1.0)
    return {"sd_carry_frac": carried / n, "sd_clamp_frac": clamped / n}


class KernelStats:
    """Host-side sink for in-kernel quantizer events.

    ``kernels.dispatch.matmul_dw`` calls ``record`` through
    ``jax.debug.callback`` when ``enabled`` at trace time — the check is
    staged out of compiled code, so enable the sink before the first step
    compiles (re-tracing after a toggle also works: the flag is read when
    the op is traced). Thread-safe; jax may run callbacks off-thread.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._enabled = False
        self._data: dict = {}

    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        self._enabled = True

    def disable(self) -> None:
        self._enabled = False

    def reset(self) -> None:
        with self._lock:
            self._data = {}

    def record(self, op: str, elems: int, saturated, zeros) -> None:
        """One kernel flush: total element count plus saturated/zero counts
        (arrive as 0-d arrays from the debug callback)."""
        with self._lock:
            d = self._data.setdefault(
                op, {"calls": 0, "elems": 0, "saturated": 0, "zeros": 0}
            )
            d["calls"] += 1
            d["elems"] += int(elems)
            d["saturated"] += int(saturated)
            d["zeros"] += int(zeros)

    def snapshot(self) -> dict:
        with self._lock:
            out = {}
            for op, d in sorted(self._data.items()):
                e = max(d["elems"], 1)
                out[op] = dict(
                    d,
                    sat_frac=d["saturated"] / e,
                    zero_frac=d["zeros"] / e,
                )
            return out


#: Process-wide kernel-event sink (see class docstring for the trace-time
#: enable caveat).
KERNEL_STATS = KernelStats()


@dataclasses.dataclass
class TrainTelemetry:
    """One aggregated telemetry record: the window since the last emit."""

    step: int
    window_steps: int
    loss_mean: float
    loss_scale: float
    scale_ups: int  # cumulative loss-scale increases since logger start
    scale_downs: int  # ... and decreases (overflow backoffs)
    nonfinite_steps: int  # cumulative skipped steps
    fp8_sat_frac: float  # window means of the per-step fractions
    fp8_underflow_frac: float
    fp8_zero_frac: float
    sd_carry_frac: float
    sd_clamp_frac: float
    grad_norms: dict  # last snapshot in the window, per layer
    kernel: dict  # KERNEL_STATS.snapshot() (cumulative), may be empty

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class TelemetryLogger:
    """Host-side aggregator: feed every step's metrics via ``update``,
    ``emit`` at each ``--log-every`` boundary to get a ``TrainTelemetry``
    record (appended as one JSONL line when ``path`` is set)."""

    _FRACS = (
        "fp8_sat_frac", "fp8_underflow_frac", "fp8_zero_frac",
        "sd_carry_frac", "sd_clamp_frac",
    )

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.scale_ups = 0
        self.scale_downs = 0
        self.nonfinite_steps = 0
        self._last_scale: Optional[float] = None
        self._reset_window()

    def _reset_window(self) -> None:
        self._n = 0
        self._loss_sum = 0.0
        self._frac_sums = {k: 0.0 for k in self._FRACS}
        self._grad_norms: dict = {}
        self._scale = 0.0

    def update(self, step: int, metrics: dict) -> None:
        """Accumulate one step. ``metrics`` is the train-step output —
        jax scalars are pulled to host here (one device_get per step on
        values the driver prints anyway)."""
        m = jax.device_get(metrics)
        self._n += 1
        self._loss_sum += float(m["loss"])
        self._scale = float(m["loss_scale"])
        if not bool(m["grads_finite"]):
            self.nonfinite_steps += 1
        if self._last_scale is not None and self._scale != self._last_scale:
            if self._scale > self._last_scale:
                self.scale_ups += 1
            else:
                self.scale_downs += 1
        self._last_scale = self._scale
        tel = m.get("tel")
        if tel:
            for k in self._FRACS:
                if k in tel:
                    self._frac_sums[k] += float(tel[k])
            if "grad_norm" in tel:
                self._grad_norms = {
                    k: float(v) for k, v in tel["grad_norm"].items()
                }

    def emit(self, step: int) -> TrainTelemetry:
        """Close the window: build the record, append JSONL, reset."""
        n = max(self._n, 1)
        rec = TrainTelemetry(
            step=int(step),
            window_steps=self._n,
            loss_mean=self._loss_sum / n,
            loss_scale=self._scale,
            scale_ups=self.scale_ups,
            scale_downs=self.scale_downs,
            nonfinite_steps=self.nonfinite_steps,
            fp8_sat_frac=self._frac_sums["fp8_sat_frac"] / n,
            fp8_underflow_frac=self._frac_sums["fp8_underflow_frac"] / n,
            fp8_zero_frac=self._frac_sums["fp8_zero_frac"] / n,
            sd_carry_frac=self._frac_sums["sd_carry_frac"] / n,
            sd_clamp_frac=self._frac_sums["sd_clamp_frac"] / n,
            grad_norms=self._grad_norms,
            kernel=KERNEL_STATS.snapshot(),
        )
        if self.path:
            with open(self.path, "a") as f:
                f.write(json.dumps(rec.to_dict()) + "\n")
        self._reset_window()
        return rec

    def format(self, rec: TrainTelemetry) -> str:
        """One compact human line for the training log."""
        line = (
            f"tel: sat {rec.fp8_sat_frac:.2e} under {rec.fp8_underflow_frac:.2e} "
            f"zero {rec.fp8_zero_frac:.3f} | sd carry {rec.sd_carry_frac:.3f} "
            f"clamp {rec.sd_clamp_frac:.2e} | scale {rec.loss_scale:.0f} "
            f"(+{rec.scale_ups}/-{rec.scale_downs}, {rec.nonfinite_steps} skipped)"
        )
        if rec.grad_norms:
            top = max(rec.grad_norms.items(), key=lambda kv: kv[1])
            line += f" | max layer gnorm {top[0]}={top[1]:.3g}"
        return line
