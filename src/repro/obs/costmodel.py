"""Analytical kernel cost model: the op/byte ledger behind the observatory.

Every registered kernel package contributes a :class:`CostSpec` — a
closed-form model of FLOPs, HBM bytes read/written, and VMEM working set as
functions of the call shape, tile config, and compute dtype — registered
alongside its ops in ``kernels/dispatch.py``. Each dispatch ``Decision``
then carries a :class:`Cost`, and :class:`CostLedger` joins the predicted
side (accumulated at trace time in ``dispatch.STATS``) with the measured
side (wall-time fed by the benchmarks, unique bytes touched computed from
the actual arrays) into one table per ``(op, backend)``.

Model conventions (the "CostSpec contract", see kernels/README.md):

  * **HBM bytes count operands and results only** — packed FloatSD8 codes
    are 1 byte/weight, FP8 state blobs 1 byte/element, and XLA-fusible
    intermediates (the ref oracle's decode, score matrices) are excluded.
    On the **ref backend the model is exact**: predicted read+write equals
    the ``nbytes`` of the ndarrays the dispatch actually handed to the
    oracle plus its outputs (asserted by the parity grid and a hypothesis
    property test, tolerance 0).
  * **Pallas traffic includes grid revisits**: a tile re-fetched once per
    grid step that revisits it is charged each time (e.g. the matmul
    kernel's x tile is fetched once per N-block). Padded dims are charged
    in full, with the delta vs the exact shape attributed to
    ``pad_waste_*`` explicitly.
  * **FLOPs are model constants, not measurements**: 2 FLOPs per MAC plus
    documented per-element constants for LUT/transcendental work. ``macs``
    is kept as its own field because the paper's Table 7 argues in MACs —
    ``benchmarks/table7_mac.py`` and this module must agree (tested).
  * **VMEM working set** is the peak resident bytes per grid step: input
    tiles + output tile + scratch accumulators + the largest intermediate
    the kernel materializes. Zero on ref (XLA owns the working set).

Stdlib + dataclasses only: ``kernels/dispatch.py`` imports this module at
import time, but the serving scrape path also reads ledgers host-side, so
it must stay jax-free (same rule as ``obs/trace.py``).
"""
from __future__ import annotations

import dataclasses
import json
import threading
from typing import Any, Callable, Optional

__all__ = ["Cost", "CostSpec", "CostLedger", "merge_costs", "ZERO_COST"]


@dataclasses.dataclass(frozen=True)
class Cost:
    """Predicted cost of one op call (or a sum of calls; ``vmem_bytes``
    merges as a max — it is a per-call peak, not a flow)."""

    flops: int = 0  # total floating-point ops (2 per MAC + model constants)
    macs: int = 0  # multiply-accumulates (the paper's Table-7 unit)
    hbm_read_bytes: int = 0  # operand traffic incl. grid revisits
    hbm_write_bytes: int = 0  # result traffic
    vmem_bytes: int = 0  # peak per-grid-step working set (0 on ref)
    pad_waste_flops: int = 0  # flops spent on tile-alignment padding
    pad_waste_bytes: int = 0  # unique padded bytes beyond the exact shape

    @property
    def hbm_bytes(self) -> int:
        return self.hbm_read_bytes + self.hbm_write_bytes

    @property
    def arithmetic_intensity(self) -> float:
        """FLOPs per HBM byte — the roofline x-coordinate."""
        return self.flops / self.hbm_bytes if self.hbm_bytes else 0.0

    def __add__(self, other: "Cost") -> "Cost":
        return merge_costs(self, other)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hbm_bytes"] = self.hbm_bytes
        d["arithmetic_intensity"] = self.arithmetic_intensity
        return d


ZERO_COST = Cost()


def merge_costs(a: Cost, b: Cost) -> Cost:
    """Accumulate two costs: flows sum, the VMEM peak takes the max."""
    return Cost(
        flops=a.flops + b.flops,
        macs=a.macs + b.macs,
        hbm_read_bytes=a.hbm_read_bytes + b.hbm_read_bytes,
        hbm_write_bytes=a.hbm_write_bytes + b.hbm_write_bytes,
        vmem_bytes=max(a.vmem_bytes, b.vmem_bytes),
        pad_waste_flops=a.pad_waste_flops + b.pad_waste_flops,
        pad_waste_bytes=a.pad_waste_bytes + b.pad_waste_bytes,
    )


@dataclasses.dataclass(frozen=True)
class CostSpec:
    """The declarative cost model one kernel package registers.

    ``fn`` is the package's cost function (``<package>/cost.py``); its
    signature is op-specific — shape dims plus ``backend=`` and whatever
    tile/dtype knobs the dispatch resolved — and it must return a
    :class:`Cost`. ``notes`` documents the model's assumptions (revisit
    factors, per-element FLOP constants) for the ledger reader."""

    op: str
    fn: Callable[..., Cost]
    notes: str = ""


class CostLedger:
    """Joins predicted (dispatch-time) and measured (bench-time) cost per
    ``(op, backend)``.

    The predicted side accumulates in the stats sink as ops are traced;
    the measured side is optional — per-op wall-time is only honest at
    microbenchmark granularity, so ``bench_kernels.py --ledger`` feeds it
    via ``STATS.add_time`` while serving/training ledgers carry the
    predicted columns and the unique-bytes cross-check only."""

    def __init__(self, stats: Any):
        self._stats = stats  # duck-typed: DispatchStats-shaped
        self._lock = threading.Lock()

    # -- joined rows ------------------------------------------------------
    def rows(self) -> list[dict]:
        """One dict per (op, backend), sorted, with predicted totals,
        touched-byte cross-check, and measured wall-time when present."""
        snap = self._stats.cost_snapshot()
        out = []
        for (op, backend) in sorted(snap.keys()):
            entry = snap[(op, backend)]
            cost: Cost = entry["cost"]
            calls = entry["calls"]
            touched = entry["touched_bytes"]
            timed_calls, wall_s = entry["timed_calls"], entry["wall_s"]
            row = {
                "op": op,
                "backend": backend,
                "calls": calls,
                **cost.to_dict(),
                "touched_bytes": touched,
            }
            # predicted-vs-touched delta is only meaningful on ref, where
            # the model counts each operand exactly once (no revisits)
            if backend == "ref" and touched:
                row["bytes_rel_err"] = (cost.hbm_bytes - touched) / touched
            else:
                row["bytes_rel_err"] = None
            row["timed_calls"] = timed_calls
            row["wall_s"] = wall_s
            if timed_calls and wall_s > 0 and calls:
                per_call = cost.flops / calls
                row["measured_flops_per_s"] = per_call * timed_calls / wall_s
                per_call_b = cost.hbm_bytes / calls
                row["measured_bytes_per_s"] = per_call_b * timed_calls / wall_s
            else:
                row["measured_flops_per_s"] = None
                row["measured_bytes_per_s"] = None
            out.append(row)
        return out

    # -- trace counter tracks ---------------------------------------------
    def emit_counters(self, tracer=None) -> int:
        """Emit one ``cost.<op>`` counter sample per op (summed across
        backends) onto the trace — monotone totals, so Perfetto renders
        cumulative FLOP/byte tracks next to the span rows. Returns the
        number of tracks emitted."""
        if tracer is None:
            from .trace import TRACER as tracer  # lazy: avoid import cycles
        if not tracer.enabled:
            return 0
        per_op: dict[str, dict] = {}
        for row in self.rows():
            agg = per_op.setdefault(
                row["op"], {"flops": 0, "hbm_bytes": 0, "calls": 0}
            )
            agg["flops"] += row["flops"]
            agg["hbm_bytes"] += row["hbm_bytes"]
            agg["calls"] += row["calls"]
        for op, agg in sorted(per_op.items()):
            tracer.counter(f"cost.{op}", "cost", **agg)
        return len(per_op)

    # -- human / machine output -------------------------------------------
    def table(self) -> str:
        """Aligned text table (the ``--ledger`` console artifact)."""
        rows = self.rows()
        if not rows:
            return "(cost ledger empty: no dispatch decisions recorded)"
        headers = [
            "op", "backend", "calls", "GFLOP", "MB read", "MB write",
            "AI", "waste%", "VMEM KB", "GFLOP/s", "bytes ok",
        ]
        body = []
        for r in rows:
            waste = (
                r["pad_waste_bytes"] / r["hbm_bytes"] * 100
                if r["hbm_bytes"] else 0.0
            )
            meas = r["measured_flops_per_s"]
            if r["bytes_rel_err"] is None:
                ok = "-"
            else:
                ok = f"{r['bytes_rel_err']:+.1%}" if r["bytes_rel_err"] else "exact"
            body.append([
                r["op"], r["backend"], str(r["calls"]),
                f"{r['flops'] / 1e9:.3f}",
                f"{r['hbm_read_bytes'] / 1e6:.3f}",
                f"{r['hbm_write_bytes'] / 1e6:.3f}",
                f"{r['arithmetic_intensity']:.2f}",
                f"{waste:.1f}",
                f"{r['vmem_bytes'] / 1024:.1f}",
                f"{meas / 1e9:.2f}" if meas else "-",
                ok,
            ])
        widths = [
            max(len(h), *(len(row[i]) for row in body))
            for i, h in enumerate(headers)
        ]
        fmt = "  ".join(f"{{:<{w}}}" for w in widths)
        lines = [fmt.format(*headers), fmt.format(*("-" * w for w in widths))]
        lines += [fmt.format(*row) for row in body]
        return "\n".join(lines)

    def to_json(self, meta: Optional[dict] = None) -> dict:
        """The ``--ledger`` JSON artifact (and ``check_bench.py`` input)."""
        return {"meta": meta or {}, "rows": self.rows()}

    def dump(self, path: str, meta: Optional[dict] = None) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(meta), f, indent=1, sort_keys=True)
            f.write("\n")
