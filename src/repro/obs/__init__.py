"""repro.obs — tracing + quantization-health telemetry.

Two halves, split by dependency weight:

  * ``obs.trace`` (stdlib-only): the span tracer the serving stack threads
    through the request lifecycle, exported as Chrome trace-event JSON via
    ``GET /admin/trace``.
  * ``obs.telemetry`` (imports jax): FP8/FloatSD quantization-health stats
    computed inside the train step, the host-side kernel-event sink, and
    the ``TrainTelemetry`` JSONL logger.
  * ``obs.costmodel`` (stdlib-only): the analytical kernel cost model —
    ``Cost``/``CostSpec``/``CostLedger`` — joined per (op, backend) with
    the measured side in ``kernels.dispatch.LEDGER``.

Import the submodules directly on hot paths (``from repro.obs import
trace``); this package root re-exports the common names for convenience
and therefore pulls jax.
"""
from .costmodel import Cost, CostLedger, CostSpec  # noqa: F401
from .trace import TRACER, Tracer  # noqa: F401
from .telemetry import (  # noqa: F401
    KERNEL_STATS,
    KernelStats,
    TelemetryLogger,
    TrainTelemetry,
    floatsd_update_stats,
    fp8_grad_stats,
    layer_grad_norms,
)

__all__ = [
    "TRACER",
    "Tracer",
    "Cost",
    "CostSpec",
    "CostLedger",
    "KERNEL_STATS",
    "KernelStats",
    "TelemetryLogger",
    "TrainTelemetry",
    "floatsd_update_stats",
    "fp8_grad_stats",
    "layer_grad_norms",
]
