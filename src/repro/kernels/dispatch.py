"""Kernel dispatch layer: one registry routing every hot-path op to its
Pallas kernel or its jnp oracle.

The FloatSD8 kernels (``floatsd_matmul``, ``lstm_cell``, ``floatsd_quantize``,
``qsigmoid``) each register a ``ref`` oracle and a ``pallas`` implementation.
Resolution per call site weighs three things:

  * **backend policy** — ``REPRO_KERNEL_BACKEND=ref|pallas|auto`` (env), a
    ``use_backend(...)`` context override, or an explicit ``backend=``
    argument; precedence: argument > context > env; default ``auto``.
  * **platform** — Pallas runs compiled on TPU and in ``interpret=True``
    validation mode everywhere else (``REPRO_KERNEL_INTERPRET=0|1``
    overrides). ``auto`` therefore resolves to ``ref`` off-TPU — the
    interpreter is a correctness tool, not a fast path — and ``pallas`` on
    TPU. ``backend="pallas"`` forces the kernel path anywhere (interpreted
    off-TPU), which is how the parity suite exercises it.
  * **shape divisibility** — inputs the tiling doesn't divide are padded up
    to tile multiples (zero activations x zero-code weights contribute an
    exact 0.0) when the padded work stays under ``PAD_WASTE_MAX`` x the
    exact work, instead of silently falling back to the oracle.

Every resolution is recorded in ``STATS``: per-``(op, backend)`` counters
plus the last ``Decision`` per op. Tests assert on these, so a tiling
regression cannot quietly turn every call into jnp. Jit caveat: inside a
jitted caller the resolver runs at trace time, so the counters count
(shape-distinct) traces, not executions — which is exactly the granularity
at which the backend choice is made.

``PackedTensor`` lives here (re-exported by ``serving.weight_store``) so the
nn layer can consume packed weights without depending on the serving stack.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import os
import threading
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import floatsd, floatsd4
from ..obs import costmodel
from ..obs import telemetry as obs_telemetry
from .flash_attention import cost as fa_cost
from .flash_attention.kernel import flash_attention_pallas
from .flash_attention.ops import flash_attention_kernel, flash_tiles
from .flash_attention.ref import flash_attention_ref
from .floatsd_matmul import cost as fm_cost
from .floatsd_matmul.bwd import (
    matmul_dw_pallas,
    matmul_dw_ref,
    matmul_dx_pallas,
    matmul_dx_ref,
)
from .floatsd_matmul.kernel import floatsd_matmul_pallas
from .floatsd_matmul.ref import floatsd_matmul_ref
from .floatsd4_matmul import cost as fm4_cost
from .floatsd4_matmul.kernel import floatsd4_matmul_pallas
from .floatsd4_matmul.ref import floatsd4_matmul_ref
from .floatsd_quantize import cost as fq_cost
from .floatsd_quantize.kernel import quantize_pallas
from .lstm_cell import cost as lc_cost
from .lstm_cell.bwd import lstm_cell_bwd_pallas, lstm_cell_bwd_ref
from .lstm_cell.kernel import lstm_cell_pallas
from .lstm_cell.ref import lstm_cell_ref
from .qsigmoid import cost as qs_cost
from .qsigmoid.kernel import qsigmoid_pallas
from .qsigmoid.ref import qsigmoid_ref
from .rwkv_wkv import cost as wkv_cost
from .rwkv_wkv.kernel import wkv_pallas
from .rwkv_wkv.ops import wkv as wkv_op
from .rwkv_wkv.ref import wkv_ref

__all__ = [
    "BACKENDS", "PAD_WASTE_MAX", "PackedTensor", "PackedTensor4", "Decision",
    "DispatchStats", "STATS", "LEDGER", "record", "backend_policy",
    "use_backend", "interpret_mode", "matmul", "matmul4", "lstm_cell",
    "quantize", "qsigmoid", "packed_einsum", "hoist_packed", "matmul_tiles",
    "lstm_tiles", "row_tile", "matmul_dx", "matmul_dw", "lstm_cell_grad",
    "train_matmul", "lstm_cell_train", "pack_train", "hoist_train",
    "inference_only", "is_packed", "is_packed4", "pack4", "unpack4",
    "rwkv_wkv", "flash_attention", "OpSpec", "REGISTRY",
]

BACKENDS = ("ref", "pallas", "auto")

# auto mode pads to tile multiples only while padded_work / exact_work stays
# under this; beyond it the oracle is the better deal (forced pallas always
# pads).
PAD_WASTE_MAX = 2.0

# uint8 code that decodes to exactly 0.0 at any bias: e=0, mantissa index of
# 0.0 in the symmetric 31-entry grid.
ZERO_CODE = int(np.searchsorted(floatsd.MANTISSA_VALUES, 0.0))


class PackedTensor(NamedTuple):
    """A FloatSD8-packed tensor: uint8 codes + scalar int32 exponent bias.

    NamedTuple => a pytree node, so packed trees pass through jit/tree_map
    transparently with codes/bias as leaves.
    """

    codes: jax.Array  # uint8, same shape as the dense tensor
    bias: jax.Array  # int32 scalar (per-tensor exponent bias)


def is_packed(x: Any) -> bool:
    return isinstance(x, PackedTensor)


# byte whose both nibbles are the FloatSD4 zero code: the tile-padding
# constant for nibble-packed code streams (decodes to exact 0.0 anywhere)
ZERO_BYTE4 = (floatsd4.ZERO_CODE << 4) | floatsd4.ZERO_CODE


@jax.tree_util.register_pytree_node_class
class PackedTensor4:
    """A FloatSD4-packed tensor: nibble-packed uint8 codes (2 codes/byte
    along axis 0) + int8 per-(GROUP x column) exponents + the true axis-0
    length ``k``.

    ``k`` rides as static pytree aux data, not a leaf: the unpack crop of
    an odd-K tensor needs it at trace time, and it is metadata, not data.
    Registered as a pytree node so packed trees pass through jit/tree_map
    with codes/exps as leaves, exactly like :class:`PackedTensor`.
    """

    __slots__ = ("codes", "exps", "k")

    def __init__(self, codes: jax.Array, exps: jax.Array, k: int):
        self.codes = codes  # uint8 [ceil(k/2), ...] nibble-packed
        self.exps = exps  # int8 [ceil(k/GROUP), ...]
        self.k = int(k)

    @property
    def shape(self) -> tuple:
        return (self.k,) + tuple(self.codes.shape[1:])

    def tree_flatten(self):
        return (self.codes, self.exps), self.k

    @classmethod
    def tree_unflatten(cls, k, children):
        return cls(children[0], children[1], k)

    def __repr__(self) -> str:
        return (
            f"PackedTensor4(codes={getattr(self.codes, 'shape', None)}, "
            f"exps={getattr(self.exps, 'shape', None)}, k={self.k})"
        )


def is_packed4(x: Any) -> bool:
    return isinstance(x, PackedTensor4)


def pack4(w) -> PackedTensor4:
    """FloatSD4-encode a weight (dense array or FloatSD8 PackedTensor —
    the serving conversion decodes the FloatSD8 master first)."""
    if is_packed(w):
        w = floatsd.decode(w.codes, w.bias, dtype=jnp.float32)
    codes, exps = floatsd4.encode(w)
    return PackedTensor4(floatsd4.pack_nibbles(codes), exps, w.shape[0])


def unpack4(w4: PackedTensor4, dtype=jnp.float32) -> jax.Array:
    """Decode a PackedTensor4 back to a dense tensor."""
    return floatsd4.decode_packed(w4.codes, w4.exps, w4.k, dtype=dtype)


# ---------------------------------------------------------------------------
# backend policy + decision record
# ---------------------------------------------------------------------------


class Decision(NamedTuple):
    op: str
    backend: str  # "ref" | "pallas"
    interpret: bool
    padded: bool
    reason: str
    # predicted cost of THIS call (costmodel.Cost) — attached by the
    # dispatched entry point once the resolved backend/tiling is known
    cost: Any = None


class DispatchStats:
    """Per-(op, backend) resolution counters, the last Decision per op,
    and the cost-ledger accumulators.

    Three sinks beyond the decision counters feed ``LEDGER``:

      * ``costs`` — predicted :class:`~repro.obs.costmodel.Cost` totals,
        accumulated from each recorded Decision (trace time);
      * ``touched`` — unique bytes of the ndarrays the dispatch actually
        handed to the backend plus its outputs, computed from array
        metadata (``size * itemsize`` — works on tracers). On ref this is
        the measurement the predicted bytes must match exactly;
      * ``wall`` — measured (timed_calls, seconds) per (op, backend), fed
        by ``bench_kernels.py --ledger`` via :meth:`add_time` — per-op
        wall attribution is only honest at microbenchmark granularity.

    Lock-guarded: resolutions happen at trace time on whatever thread is
    tracing (the serving pump worker, a test thread), while the /metrics
    scrape path reads ``snapshot()`` from the HTTP event loop — iterating
    the Counter during a concurrent ``record`` would be a data race."""

    def __init__(self):
        self.counts: collections.Counter = collections.Counter()
        self.last: dict[str, Decision] = {}
        self.costs: dict[tuple[str, str], costmodel.Cost] = {}
        self.touched: collections.Counter = collections.Counter()
        self.wall: dict[tuple[str, str], list] = {}
        self._lock = threading.Lock()

    def record(self, d: Decision) -> None:
        with self._lock:
            key = (d.op, d.backend)
            self.counts[key] += 1
            self.last[d.op] = d
            if d.cost is not None:
                self.costs[key] = costmodel.merge_costs(
                    self.costs.get(key, costmodel.ZERO_COST), d.cost
                )

    def add_touched(self, op: str, backend: str, nbytes: int) -> None:
        with self._lock:
            self.touched[(op, backend)] += int(nbytes)

    def add_time(self, op: str, backend: str, seconds: float,
                 calls: int = 1) -> None:
        """Attribute measured wall time to (op, backend) — the ledger's
        measured column. Callers time *executions*; the predicted side
        counts *traces*, so the ledger normalizes both per call."""
        with self._lock:
            entry = self.wall.setdefault((op, backend), [0, 0.0])
            entry[0] += int(calls)
            entry[1] += float(seconds)

    def count(self, op: str | None = None, backend: str | None = None) -> int:
        with self._lock:
            return sum(
                n for (o, b), n in self.counts.items()
                if (op is None or o == op) and (backend is None or b == backend)
            )

    def reset(self) -> None:
        with self._lock:
            self.counts.clear()
            self.last.clear()
            self.costs.clear()
            self.touched.clear()
            self.wall.clear()

    def snapshot(self) -> dict:
        """{(op, backend): resolutions} — what /metrics exports as
        ``repro_dispatch_decisions_total{op,backend}``."""
        with self._lock:
            return dict(self.counts)

    def cost_snapshot(self) -> dict:
        """{(op, backend): {cost, calls, touched_bytes, timed_calls,
        wall_s}} — the CostLedger's raw join input."""
        with self._lock:
            keys = (
                set(self.counts) | set(self.costs) | set(self.touched)
                | set(self.wall)
            )
            return {
                key: {
                    "cost": self.costs.get(key, costmodel.ZERO_COST),
                    "calls": self.counts.get(key, 0),
                    "touched_bytes": self.touched.get(key, 0),
                    "timed_calls": self.wall.get(key, (0, 0.0))[0],
                    "wall_s": self.wall.get(key, (0, 0.0))[1],
                }
                for key in keys
            }


STATS = DispatchStats()

#: Predicted-vs-measured cost ledger over STATS — the observatory's
#: joined view (trace counter tracks, /metrics export, --ledger artifacts).
LEDGER = costmodel.CostLedger(STATS)


def record(op: str, backend: str, *, interpret: bool = False,
           padded: bool = False, reason: str = "",
           cost: costmodel.Cost | None = None) -> Decision:
    d = Decision(op, backend, interpret, padded, reason, cost)
    STATS.record(d)
    return d


def _nbytes(*arrays) -> int:
    """Sum of ``size * itemsize`` over arrays/tracers/scalars — the
    unique-bytes-touched measurement the ref cost model must reproduce."""
    total = 0
    for a in arrays:
        if a is None:
            continue
        dt = getattr(a, "dtype", None)
        if dt is None:
            a = np.asarray(a)
            dt = a.dtype
        total += int(getattr(a, "size", 1)) * jnp.dtype(dt).itemsize
    return total


_OVERRIDE: list[str] = []  # use_backend() stack


def backend_policy(backend: str | None = None) -> str:
    """Effective policy: explicit argument > use_backend() > env > auto."""
    pol = backend or (_OVERRIDE[-1] if _OVERRIDE else None) or os.environ.get(
        "REPRO_KERNEL_BACKEND", "auto"
    ).lower()
    if pol not in BACKENDS:
        raise ValueError(f"REPRO_KERNEL_BACKEND must be one of {BACKENDS}, got {pol!r}")
    return pol


@contextlib.contextmanager
def use_backend(name: str):
    """Force a backend for all dispatch resolutions inside the context."""
    if name not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {name!r}")
    _OVERRIDE.append(name)
    try:
        yield
    finally:
        _OVERRIDE.pop()


def interpret_mode() -> bool:
    """Pallas execution mode for this process: compiled on TPU, interpreted
    elsewhere. REPRO_KERNEL_INTERPRET=0|1 overrides."""
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"


def _decide(op: str, native: bool, waste: float, backend: str | None) -> Decision:
    """Pure resolution (no recording). ``native``: tiling divides as-is;
    ``waste``: padded/exact work ratio if padding were used."""
    pol = backend_policy(backend)
    interp = interpret_mode()
    if pol == "ref":
        return Decision(op, "ref", False, False, "policy:ref")
    if pol == "pallas":
        if native:
            return Decision(op, "pallas", interp, False, "policy:pallas")
        return Decision(
            op, "pallas", interp, True, f"policy:pallas, padded ({waste:.2f}x work)"
        )
    # auto
    if interp:
        return Decision(
            op, "ref", False, False, "auto:off-tpu (interpret is validation-only)"
        )
    if native:
        return Decision(op, "pallas", False, False, "auto:tpu, native tiles")
    if waste <= PAD_WASTE_MAX:
        return Decision(
            op, "pallas", False, True,
            f"auto:tpu, padded ({waste:.2f}x <= {PAD_WASTE_MAX}x)",
        )
    return Decision(
        op, "ref", False, False,
        f"auto:padding waste {waste:.2f}x > {PAD_WASTE_MAX}x",
    )


def _choose(op: str, native: bool, waste: float, backend: str | None) -> Decision:
    d = _decide(op, native, waste, backend)
    STATS.record(d)
    return d


# ---------------------------------------------------------------------------
# tile planning (shared with the per-kernel ops wrappers)
# ---------------------------------------------------------------------------


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def matmul_tiles(m: int, n: int, k: int) -> tuple[int, int, int]:
    """Largest power-of-two-halved MXU-aligned blocks dividing (m, n, k)."""
    bm = max(8, min(256, m))
    bn = min(256, n)
    bk = min(512, k)
    while m % bm:
        bm //= 2
    while n % bn:
        bn //= 2
    while k % bk:
        bk //= 2
    return bm, bn, bk


def lstm_tiles(b: int, h: int) -> tuple[int, int]:
    bb = 8
    while b % bb == 0 and bb < 128:
        bb *= 2
    if b % bb:
        bb //= 2
    bh = 128
    while h % bh == 0 and bh < 512:
        bh *= 2
    if h % bh:
        bh //= 2
    return bb, bh


def row_tile(rows: int) -> int:
    """Largest block <= 256 that divides ``rows`` by repeated halving (the
    flattened-2D elementwise kernels: quantize, qsigmoid)."""
    bm = min(256, rows)
    while rows % bm:
        bm //= 2
    return max(bm, 1)


def _matmul_geometry(m: int, k: int, n: int):
    """(native, padded-work ratio, padded dims) for an [M,K]x[K,N] call —
    the single source of the alignment arithmetic, shared by ``matmul`` and
    ``hoist_packed`` so the hoist prediction can never diverge from the
    per-call decision."""
    mp, kp, np_ = _ceil_to(max(m, 1), 8), _ceil_to(k, 128), _ceil_to(n, 128)
    native = (mp, kp, np_) == (m, k, n)
    waste = (mp * kp * np_) / max(m * k * n, 1)
    return native, waste, (mp, kp, np_)


# ---------------------------------------------------------------------------
# dispatched ops
# ---------------------------------------------------------------------------


def matmul(x, codes, bias, *, out_dtype=jnp.float32, precise: bool = True,
           compute_dtype=None, backend: str | None = None):
    """x [..., K] @ decode(codes [K, N]) -> [..., N], backend-resolved.

    ``precise=True`` issues the kernel's MXU dot in f32 (parity with the
    oracle to ~1e-6 relative); ``precise=False`` uses the bf16 issue dtype
    (full MXU rate, the paper's accumulate-in-f32 datapath). An explicit
    ``compute_dtype`` (e.g. a bf16-compute policy's cdt) overrides both.
    """
    if compute_dtype is None:
        compute_dtype = jnp.float32 if precise else jnp.bfloat16
    k = x.shape[-1]
    k2, n = codes.shape
    assert k == k2, (x.shape, codes.shape)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    native, waste, (mp, kp, np_) = _matmul_geometry(m, k, n)
    dec = _decide("floatsd_matmul", native, waste, backend)
    x_bytes = jnp.dtype(x.dtype).itemsize
    o_bytes = jnp.dtype(out_dtype).itemsize
    if dec.backend == "ref":
        y = floatsd_matmul_ref(x2, codes, bias, out_dtype)
        cost = fm_cost.matmul_fwd_cost(
            m, k, n, backend="ref", x_bytes=x_bytes, out_bytes=o_bytes,
        )
        touched = _nbytes(x2, codes, bias, y)
        out = y
    else:
        xx, cc = x2, codes
        if dec.padded:
            xx = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
            cc = jnp.pad(codes, ((0, kp - k), (0, np_ - n)), constant_values=ZERO_CODE)
        bm, bn, bk = matmul_tiles(xx.shape[0], cc.shape[1], xx.shape[1])
        y = floatsd_matmul_pallas(
            xx, cc, bias, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
            compute_dtype=compute_dtype,
            interpret=dec.interpret,
        )
        cost = fm_cost.matmul_fwd_cost(
            m, k, n, backend="pallas", x_bytes=x_bytes, out_bytes=o_bytes,
            compute_bytes=jnp.dtype(compute_dtype).itemsize,
            padded=(mp, kp, np_), tiles=(bm, bn, bk),
        )
        touched = _nbytes(xx, cc, bias, y)
        out = y[:m, :n] if dec.padded else y
    STATS.record(dec._replace(cost=cost))
    STATS.add_touched("floatsd_matmul", dec.backend, touched)
    return out.reshape(*lead, n)


def matmul4(x, w4: PackedTensor4, *, out_dtype=jnp.float32,
            precise: bool = True, compute_dtype=None,
            backend: str | None = None):
    """x [..., K] @ decode4(w4 [K, N]) -> [..., N], backend-resolved.

    The FloatSD4 sibling of :func:`matmul`: the weight operand is a
    :class:`PackedTensor4` (nibble-packed codes + group exponents), so the
    pallas path streams ~half the weight bytes of the FloatSD8 kernel.
    Padding uses the zero-code convention: code columns/rows pad with
    ``ZERO_BYTE4`` (both nibbles the zero code) and exponent rows with 0 —
    both decode to exact 0.0, so padded lanes contribute nothing.
    """
    if compute_dtype is None:
        compute_dtype = jnp.float32 if precise else jnp.bfloat16
    k = x.shape[-1]
    assert w4.k == k, (x.shape, w4.shape)
    n = w4.codes.shape[1]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    native, waste, (mp, kp, np_) = _matmul_geometry(m, k, n)
    dec = _decide("floatsd4_matmul", native, waste, backend)
    x_bytes = jnp.dtype(x.dtype).itemsize
    o_bytes = jnp.dtype(out_dtype).itemsize
    if dec.backend == "ref":
        y = floatsd4_matmul_ref(x2, w4.codes, w4.exps, k, out_dtype)
        cost = fm4_cost.matmul4_fwd_cost(
            m, k, n, backend="ref", x_bytes=x_bytes, out_bytes=o_bytes,
        )
        touched = _nbytes(x2, w4.codes, w4.exps, y)
        out = y
    else:
        xx, cc, ee = x2, w4.codes, w4.exps
        if dec.padded:
            xx = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
            cc = jnp.pad(
                cc, ((0, kp // 2 - cc.shape[0]), (0, np_ - n)),
                constant_values=ZERO_BYTE4,
            )
            ee = jnp.pad(
                ee, ((0, kp // floatsd4.GROUP - ee.shape[0]), (0, np_ - n))
            )
        bm, bn, bk = matmul_tiles(xx.shape[0], cc.shape[1], xx.shape[1])
        y = floatsd4_matmul_pallas(
            xx, cc, ee, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
            compute_dtype=compute_dtype, interpret=dec.interpret,
        )
        cost = fm4_cost.matmul4_fwd_cost(
            m, k, n, backend="pallas", x_bytes=x_bytes, out_bytes=o_bytes,
            compute_bytes=jnp.dtype(compute_dtype).itemsize,
            padded=(mp, kp, np_), tiles=(bm, bn, bk),
        )
        touched = _nbytes(xx, cc, ee, y)
        out = y[:m, :n] if dec.padded else y
    STATS.record(dec._replace(cost=cost))
    STATS.add_touched("floatsd4_matmul", dec.backend, touched)
    return out.reshape(*lead, n)


def lstm_cell(z, c_prev, *, quantized: bool = True, c_dtype=jnp.float16,
              backend: str | None = None):
    """Fused gates -> (h, c), backend-resolved. z: [B, 4H] (i|f|g|o)."""
    b, h4 = z.shape
    h = h4 // 4
    bp, hp = _ceil_to(max(b, 1), 8), _ceil_to(max(h, 1), 128)
    native = (bp, hp) == (b, h)
    waste = (bp * hp) / max(b * h, 1)
    dec = _decide("lstm_cell", native, waste, backend)
    dtypes = dict(
        z_bytes=jnp.dtype(z.dtype).itemsize,
        c_in_bytes=jnp.dtype(c_prev.dtype).itemsize,
    )
    if dec.backend == "ref":
        h_t, c_t = lstm_cell_ref(z, c_prev, quantized, c_dtype=c_dtype)
        cost = lc_cost.lstm_cell_cost(
            b, h, backend="ref",
            h_out_bytes=jnp.dtype(h_t.dtype).itemsize,
            c_out_bytes=jnp.dtype(c_t.dtype).itemsize, **dtypes,
        )
        touched = _nbytes(z, c_prev, h_t, c_t)
        STATS.record(dec._replace(cost=cost))
        STATS.add_touched("lstm_cell", "ref", touched)
        return h_t, c_t
    zz, cc = z, c_prev
    if dec.padded:
        zz = jnp.pad(
            z.reshape(b, 4, h), ((0, bp - b), (0, 0), (0, hp - h))
        ).reshape(bp, 4 * hp)
        cc = jnp.pad(c_prev, ((0, bp - b), (0, hp - h)))
    bb, bh = lstm_tiles(bp, hp)
    h_t, c_t = lstm_cell_pallas(
        zz, cc, bb=bb, bh=bh, quantized=quantized, c_dtype=c_dtype,
        interpret=dec.interpret,
    )
    cost = lc_cost.lstm_cell_cost(
        b, h, backend="pallas",
        h_out_bytes=jnp.dtype(h_t.dtype).itemsize,
        c_out_bytes=jnp.dtype(c_t.dtype).itemsize,
        padded=(bp, hp), tiles=(bb, bh), **dtypes,
    )
    STATS.record(dec._replace(cost=cost))
    STATS.add_touched("lstm_cell", "pallas", _nbytes(zz, cc, h_t, c_t))
    if dec.padded:
        h_t, c_t = h_t[:b, :h], c_t[:b, :h]
    return h_t, c_t


def quantize(x, bias=None, *, backend: str | None = None):
    """Any-shape tensor -> (uint8 FloatSD8 codes, int32 bias), resolved."""
    if bias is None:
        bias = floatsd.fit_bias(x)
    n = x.size
    # native = reshapes to [8k, 256] — rows a multiple of 8 so the layout is
    # TPU-tileable (f32 min tile is 8x128); anything else pads to that
    np_ = _ceil_to(max(n, 1), 8 * 256)
    native = n > 0 and n % (8 * 256) == 0
    waste = np_ / max(n, 1)
    dec = _decide("floatsd_quantize", native, waste, backend)
    x_bytes = jnp.dtype(x.dtype).itemsize
    if dec.backend == "ref":
        codes, _ = floatsd.encode(x, bias)
        cost = fq_cost.quantize_cost(n, backend="ref", x_bytes=x_bytes)
        STATS.record(dec._replace(cost=cost))
        STATS.add_touched("floatsd_quantize", "ref", _nbytes(x, bias, codes))
        return codes, bias
    flat = x.reshape(-1)
    if dec.padded:
        flat = jnp.pad(flat, (0, np_ - n))
    x2 = flat.reshape(-1, 256)
    tile_rows = row_tile(x2.shape[0])
    codes2 = quantize_pallas(
        x2, bias, bm=tile_rows, bn=256, interpret=dec.interpret
    )
    cost = fq_cost.quantize_cost(
        n, backend="pallas", x_bytes=x_bytes, padded_n=np_,
        tile_rows=tile_rows,
    )
    STATS.record(dec._replace(cost=cost))
    STATS.add_touched("floatsd_quantize", "pallas", _nbytes(x2, bias, codes2))
    return codes2.reshape(-1)[:n].reshape(x.shape), bias


def qsigmoid(x, *, backend: str | None = None):
    """Two-region FloatSD8 sigmoid for any-shape tensors, resolved."""
    n = x.size
    np_ = _ceil_to(max(n, 1), 8 * 256)
    native = n > 0 and n % (8 * 256) == 0
    waste = np_ / max(n, 1)
    dec = _decide("qsigmoid", native, waste, backend)
    x_bytes = jnp.dtype(x.dtype).itemsize
    if dec.backend == "ref":
        y = qsigmoid_ref(x)
        cost = qs_cost.qsigmoid_cost(
            n, backend="ref", x_bytes=x_bytes,
            y_bytes=jnp.dtype(y.dtype).itemsize,
        )
        STATS.record(dec._replace(cost=cost))
        STATS.add_touched("qsigmoid", "ref", _nbytes(x, y))
        return y
    flat = x.reshape(-1)
    if dec.padded:
        flat = jnp.pad(flat, (0, np_ - n))
    x2 = flat.reshape(-1, 256)
    tile_rows = row_tile(x2.shape[0])
    y2 = qsigmoid_pallas(x2, bm=tile_rows, bn=256, interpret=dec.interpret)
    cost = qs_cost.qsigmoid_cost(
        n, backend="pallas", x_bytes=x_bytes,
        y_bytes=jnp.dtype(y2.dtype).itemsize, padded_n=np_,
        tile_rows=tile_rows,
    )
    STATS.record(dec._replace(cost=cost))
    STATS.add_touched("qsigmoid", "pallas", _nbytes(x2, y2))
    return y2.reshape(-1)[:n].reshape(x.shape)


# ---------------------------------------------------------------------------
# backward ops (the training hot path: fused quantized BPTT)
# ---------------------------------------------------------------------------


def matmul_dx(g, codes, bias, *, backend: str | None = None):
    """Activation gradient of the FloatSD8 matmul, backend-resolved:
    g [..., N] x decode(codes [K, N])^T -> [..., K] in f32 (the precise
    datapath — FP8 act-grad quantization lives at the act_quant STE nodes,
    not here). Pallas path reuses the forward decode-in-VMEM kernel on the
    transposed 1-byte codes."""
    k, n = codes.shape
    lead = g.shape[:-1]
    g2 = g.reshape(-1, n)
    m = g2.shape[0]
    # output [m, k], contraction over n
    native, waste, (mp, np_, kp) = _matmul_geometry(m, n, k)
    dec = _decide("floatsd_matmul_dx", native, waste, backend)
    g_bytes = jnp.dtype(g.dtype).itemsize
    if dec.backend == "ref":
        dx = matmul_dx_ref(g2, codes, bias)
        cost = fm_cost.matmul_dx_cost(
            m, n, k, backend="ref", g_bytes=g_bytes,
            out_bytes=jnp.dtype(dx.dtype).itemsize,
        )
        touched = _nbytes(g2, codes, bias, dx)
        out = dx
    else:
        gg, cc = g2, codes
        if dec.padded:
            gg = jnp.pad(g2, ((0, mp - m), (0, np_ - n)))
            cc = jnp.pad(codes, ((0, kp - k), (0, np_ - n)), constant_values=ZERO_CODE)
        bm, bn, bk = matmul_tiles(mp, kp, np_)
        dx = matmul_dx_pallas(gg, cc, bias, bm=bm, bn=bn, bk=bk,
                              interpret=dec.interpret)
        cost = fm_cost.matmul_dx_cost(
            m, n, k, backend="pallas", g_bytes=g_bytes,
            out_bytes=jnp.dtype(dx.dtype).itemsize,
            padded=(mp, np_, kp), tiles=(bm, bn, bk),
        )
        touched = _nbytes(gg, cc, bias, dx)
        out = dx[:m, :k] if dec.padded else dx
    STATS.record(dec._replace(cost=cost))
    STATS.add_touched("floatsd_matmul_dx", dec.backend, touched)
    return out.reshape(*lead, k)


def _dw_flush_telemetry(dw, quant: bool):
    """Quantizer-health hook at the matmul_dw flush: when the telemetry
    sink is enabled (checked at trace time — see ``KernelStats``), count
    saturated (|dw| at the e5m2 clamp) and zero (true zeros + underflow,
    already collapsed by the in-kernel quantizer) elements of the flushed
    dW and report them host-side via ``jax.debug.callback``."""
    if not (quant and obs_telemetry.KERNEL_STATS.enabled):
        return dw
    sat = jnp.sum(jnp.abs(dw) >= obs_telemetry.FP8_SAT_THRESHOLD)
    zero = jnp.sum(dw == 0)
    jax.debug.callback(
        functools.partial(
            obs_telemetry.KERNEL_STATS.record, "floatsd_matmul_dw", dw.size
        ),
        sat,
        zero,
    )
    return dw


def matmul_dw(x, g, *, quant: bool = True, backend: str | None = None):
    """Weight gradient of the FloatSD8 matmul, backend-resolved:
    x [..., K]^T x g [..., N] -> [K, N], f32 accumulation, the paper's FP8
    weight-gradient quantizer applied at the accumulator flush *inside* the
    kernel (``quant=False`` gives the raw f32 dw for parity oracles)."""
    k = x.shape[-1]
    n = g.shape[-1]
    x2 = x.reshape(-1, k)
    g2 = g.reshape(-1, n)
    m = x2.shape[0]
    assert g2.shape[0] == m, (x.shape, g.shape)
    # output [k, n], contraction over m (rows pad to 8, lanes to 128)
    native, waste, (kp, mp, np_) = _matmul_geometry(k, m, n)
    dec = _decide("floatsd_matmul_dw", native, waste, backend)
    xg_bytes = dict(
        x_bytes=jnp.dtype(x.dtype).itemsize,
        g_bytes=jnp.dtype(g.dtype).itemsize,
    )
    if dec.backend == "ref":
        dw = matmul_dw_ref(x2, g2, quant=quant)
        cost = fm_cost.matmul_dw_cost(
            k, m, n, backend="ref", quant=quant,
            out_bytes=jnp.dtype(dw.dtype).itemsize, **xg_bytes,
        )
        STATS.record(dec._replace(cost=cost))
        STATS.add_touched("floatsd_matmul_dw", "ref", _nbytes(x2, g2, dw))
        return _dw_flush_telemetry(dw, quant)
    xx, gg = x2, g2
    if dec.padded:
        xx = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
        gg = jnp.pad(g2, ((0, mp - m), (0, np_ - n)))
    bm, bn, bk = matmul_tiles(kp, np_, mp)
    dw = matmul_dw_pallas(xx, gg, bm=bm, bn=bn, bk=bk, quant=quant,
                          interpret=dec.interpret)
    cost = fm_cost.matmul_dw_cost(
        k, m, n, backend="pallas", quant=quant,
        out_bytes=jnp.dtype(dw.dtype).itemsize,
        padded=(kp, mp, np_), tiles=(bm, bn, bk), **xg_bytes,
    )
    STATS.record(dec._replace(cost=cost))
    STATS.add_touched("floatsd_matmul_dw", "pallas", _nbytes(xx, gg, dw))
    if dec.padded:
        dw = dw[:k, :n]
    return _dw_flush_telemetry(dw, quant)


def lstm_cell_grad(z, c_prev, dh, dc, *, quantized: bool = True,
                   c_dtype=jnp.float16, backend: str | None = None):
    """Recompute-gates backward of the fused cell, backend-resolved.
    z: [B, 4H], c_prev/dh/dc: [B, H] -> (dz [B, 4H] f32, dc_prev [B, H]).
    The only residuals it needs are (z, c_prev) — see kernels README,
    'backward ops'."""
    b, h4 = z.shape
    h = h4 // 4
    bp, hp = _ceil_to(max(b, 1), 8), _ceil_to(max(h, 1), 128)
    native = (bp, hp) == (b, h)
    waste = (bp * hp) / max(b * h, 1)
    dec = _decide("lstm_cell_grad", native, waste, backend)
    in_bytes = dict(
        z_bytes=jnp.dtype(z.dtype).itemsize,
        c_in_bytes=jnp.dtype(c_prev.dtype).itemsize,
        dh_bytes=jnp.dtype(dh.dtype).itemsize,
        dc_bytes=jnp.dtype(dc.dtype).itemsize,
    )
    if dec.backend == "ref":
        dz, dcp = lstm_cell_bwd_ref(z, c_prev, dh, dc, quantized,
                                    c_dtype=c_dtype)
        cost = lc_cost.lstm_cell_grad_cost(
            b, h, backend="ref",
            dz_bytes=jnp.dtype(dz.dtype).itemsize,
            dcp_bytes=jnp.dtype(dcp.dtype).itemsize, **in_bytes,
        )
        STATS.record(dec._replace(cost=cost))
        STATS.add_touched("lstm_cell_grad", "ref",
                          _nbytes(z, c_prev, dh, dc, dz, dcp))
        return dz, dcp
    zz, cc, dhh, dcc = z, c_prev, dh, dc
    if dec.padded:
        zz = jnp.pad(
            z.reshape(b, 4, h), ((0, bp - b), (0, 0), (0, hp - h))
        ).reshape(bp, 4 * hp)
        cc = jnp.pad(c_prev, ((0, bp - b), (0, hp - h)))
        dhh = jnp.pad(dh, ((0, bp - b), (0, hp - h)))
        dcc = jnp.pad(dc, ((0, bp - b), (0, hp - h)))
    bb, bh = lstm_tiles(bp, hp)
    dz, dcp = lstm_cell_bwd_pallas(
        zz, cc, dhh, dcc, bb=bb, bh=bh, quantized=quantized, c_dtype=c_dtype,
        interpret=dec.interpret,
    )
    cost = lc_cost.lstm_cell_grad_cost(
        b, h, backend="pallas",
        dz_bytes=jnp.dtype(dz.dtype).itemsize,
        dcp_bytes=jnp.dtype(dcp.dtype).itemsize,
        padded=(bp, hp), tiles=(bb, bh), **in_bytes,
    )
    STATS.record(dec._replace(cost=cost))
    STATS.add_touched("lstm_cell_grad", "pallas",
                      _nbytes(zz, cc, dhh, dcc, dz, dcp))
    if dec.padded:
        dz = dz.reshape(bp, 4, hp)[:b, :, :h].reshape(b, 4 * h)
        dcp = dcp[:b, :h]
    return dz, dcp


# ---------------------------------------------------------------------------
# sequence-mixing ops (model-zoo hot paths): rwkv wkv + flash attention.
# These kernels have no padded path — indivisible shapes fall back to the
# oracle (recorded, never silent), matching their ops.py wrappers.
# ---------------------------------------------------------------------------


def _decide_fallback(op: str, native: bool, why: str,
                     backend: str | None) -> Decision:
    """Resolution for ops without a padding path: pallas only when the
    tiling divides natively, ref otherwise — with the fallback reason
    recorded so a shape regression shows up in STATS, not in silence."""
    pol = backend_policy(backend)
    interp = interpret_mode()
    if pol == "ref":
        return Decision(op, "ref", False, False, "policy:ref")
    if pol == "pallas":
        if native:
            return Decision(op, "pallas", interp, False, "policy:pallas")
        return Decision(op, "ref", False, False,
                        f"policy:pallas, but {why} -> ref oracle (no padded path)")
    if interp:
        return Decision(op, "ref", False, False,
                        "auto:off-tpu (interpret is validation-only)")
    if native:
        return Decision(op, "pallas", False, False, "auto:tpu, native tiles")
    return Decision(op, "ref", False, False,
                    f"auto:{why} -> ref oracle (no padded path)")


def rwkv_wkv(r, k, v, w, u, *, chunk: int = 16, backend: str | None = None):
    """Chunked RWKV-6 wkv, backend-resolved: r/k/w [BH, S, K], v [BH, S, V],
    u [BH, K] -> [BH, S, V]. Pallas keeps the [K, V] state in VMEM across
    chunk steps; indivisible S falls back to the per-token oracle."""
    bh, s, dk = r.shape
    dv = v.shape[-1]
    native = s > 0 and s % chunk == 0
    dec = _decide_fallback("rwkv_wkv", native, f"S={s} % chunk={chunk}", backend)
    cost = wkv_cost.wkv_cost(
        bh, s, dk, dv, backend=dec.backend, chunk=chunk,
        elem_bytes=jnp.dtype(r.dtype).itemsize,
    )
    y = wkv_op(r, k, v, w, u, chunk=chunk,
               use_kernel=dec.backend == "pallas", interpret=dec.interpret)
    STATS.record(dec._replace(cost=cost))
    STATS.add_touched("rwkv_wkv", dec.backend, _nbytes(r, k, v, w, u, y))
    return y


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    backend: str | None = None):
    """Flash attention forward, backend-resolved: q [BH, Sq, D],
    k/v [BH, Skv, D] -> [BH, Sq, D]. Pallas streams KV tiles against
    VMEM-resident (m, l, acc) state; misaligned dims fall back to the
    materialized-scores oracle."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    native = sq > 0 and sq % 8 == 0 and skv % 128 == 0 and d % 8 == 0
    dec = _decide_fallback(
        "flash_attention", native, f"Sq={sq}/Skv={skv}/D={d} misaligned",
        backend,
    )
    bq, bk = flash_tiles(sq, skv) if native else (None, None)
    cost = fa_cost.flash_attention_cost(
        bh, sq, skv, d, backend=dec.backend, causal=causal, window=window,
        elem_bytes=jnp.dtype(q.dtype).itemsize, bq=bq, bk=bk,
    )
    o = flash_attention_kernel(
        q, k, v, causal=causal, window=window,
        use_kernel=dec.backend == "pallas", interpret=dec.interpret,
    )
    STATS.record(dec._replace(cost=cost))
    STATS.add_touched("flash_attention", dec.backend, _nbytes(q, k, v, o))
    return o


# ---------------------------------------------------------------------------
# custom-VJP training entry points: the whole train step resolves to
# registered kernels, forward AND backward
# ---------------------------------------------------------------------------


def pack_train(w) -> PackedTensor:
    """Encode a dense master weight to FloatSD8 codes for the fused training
    path (hoisted outside the time scan — encode is T-invariant). The codes
    carry the exact forward values: decode(encode(w)) == quantize(w).values
    bit-identically, so the fused path's loss trajectory matches the
    fake-quant STE path's. Gradients do not flow through the (integer)
    codes; ``train_matmul`` routes dw straight to the dense master (STE)."""
    codes, bias = floatsd.encode(jax.lax.stop_gradient(w))
    return PackedTensor(codes, bias)


def hoist_train(w, *, dtype=None, backend: str | None = None):
    """Scan-loop hoist for the fused TRAINING path — the gradient-side twin
    of ``hoist_packed``. When the resolved backend is ``ref``, the codes
    would be decoded per time step in BOTH scans (forward and backward), so
    quantize-at-use once outside the scan wins: returns the dense
    STE-fake-quantized weight (bit-identical values to decode(encode(w))).
    On the pallas path returns the ``PackedTensor`` — decode-in-VMEM per
    tile is the kernel's whole point, forward and backward alike."""
    pol = backend_policy(backend)
    ref = pol == "ref" or (pol == "auto" and interpret_mode())
    if ref:
        bias = jax.lax.stop_gradient(floatsd.fit_bias(w))
        wq = floatsd.quantize_ste(w, bias)
        return wq.astype(dtype or jnp.float32)
    return pack_train(w)


def _float0(x):
    return np.zeros(np.shape(x), jax.dtypes.float0)


@functools.lru_cache(maxsize=None)
def _make_train_matmul_packed(backend: str | None, w_dtype: str):
    """custom-VJP matmul over (x, w_master, codes, bias): forward is the
    dispatched decode+matmul on the codes; backward is the registered
    (floatsd_matmul_dx, floatsd_matmul_dw) op pair — dx f32, dw emitted
    through the FP8 gradient quantizer in-kernel and routed straight-through
    to the dense master weight."""

    @jax.custom_vjp
    def f(x, w, codes, bias):
        del w  # forward runs on the codes; w is the gradient target (STE)
        return matmul(x, codes, bias, out_dtype=jnp.float32, backend=backend)

    def fwd(x, w, codes, bias):
        del w
        y = matmul(x, codes, bias, out_dtype=jnp.float32, backend=backend)
        return y, (x, codes, bias)

    def bwd(res, g):
        x, codes, bias = res
        dx = matmul_dx(g, codes, bias, backend=backend).astype(x.dtype)
        dw = matmul_dw(x, g, backend=backend).astype(jnp.dtype(w_dtype))
        return dx, dw, _float0(codes), _float0(bias)

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _make_train_matmul_dense(backend: str | None):
    """Dense-hoisted variant (the ref backend): forward is a plain f32 dot
    on the pre-quantized weight (decode hoisted out of the scan by
    ``hoist_train``); backward keeps the fused-BPTT contract — dx in f32,
    dw through the FP8 gradient quantizer (the registered op's oracle),
    flowing to the master via the hoisted STE node."""

    @jax.custom_vjp
    def f(x, wq):
        return jnp.dot(x, wq, preferred_element_type=jnp.float32).astype(
            jnp.float32
        )

    def fwd(x, wq):
        return f(x, wq), (x, wq)

    def bwd(res, g):
        x, wq = res
        m = g.size // g.shape[-1]
        k2, n2 = wq.shape
        record(
            "floatsd_matmul_dx", "ref", reason="train:hoisted-dense",
            cost=fm_cost.matmul_like_cost(
                m, n2, k2, backend="ref", a_bytes=4,
                b_bytes=jnp.dtype(wq.dtype).itemsize, bias_bytes=0,
                decode=False, o_bytes=jnp.dtype(x.dtype).itemsize,
            ),
        )
        STATS.add_touched("floatsd_matmul_dx", "ref",
                          _nbytes(g, wq) + m * k2 * jnp.dtype(x.dtype).itemsize)
        dx = jnp.dot(g, wq.T, preferred_element_type=jnp.float32).astype(x.dtype)
        dw = matmul_dw(x, g, backend=backend).astype(wq.dtype)
        return dx, dw

    f.defvjp(fwd, bwd)
    return f


def train_matmul(x, w, wq, *, backend: str | None = None):
    """Training-path matmul: x [..., K] @ quantized(w) with the fused
    backward contract. ``w`` is the dense master weight the FP8 dw flows
    to; ``wq`` is its hoisted quantization from ``hoist_train`` — a
    ``PackedTensor`` on the pallas path (decode-in-VMEM, in-kernel FP8 dw)
    or the dense STE value on ref (plain dots, oracle FP8 dw; dw reaches
    ``w`` through the hoisted STE node, so ``w`` itself is unused here)."""
    pol = backend_policy(backend)
    if is_packed(wq):
        return _make_train_matmul_packed(pol, jnp.dtype(w.dtype).name)(
            x, w, wq.codes, wq.bias
        )
    k2, n2 = wq.shape
    m = x.size // max(k2, 1)
    record(
        "floatsd_matmul", "ref", reason="train:hoisted-dense",
        cost=fm_cost.matmul_like_cost(
            m, k2, n2, backend="ref",
            a_bytes=jnp.dtype(x.dtype).itemsize,
            b_bytes=jnp.dtype(wq.dtype).itemsize, bias_bytes=0,
            decode=False, o_bytes=4,
        ),
    )
    STATS.add_touched("floatsd_matmul", "ref", _nbytes(x, wq) + m * n2 * 4)
    return _make_train_matmul_dense(pol)(x, wq)




@functools.lru_cache(maxsize=None)
def _make_lstm_cell_train(quantized: bool, c_dtype, backend: str | None):
    @jax.custom_vjp
    def f(z, c_prev):
        return lstm_cell(z, c_prev, quantized=quantized, c_dtype=c_dtype,
                         backend=backend)

    def fwd(z, c_prev):
        # residual contract: ONLY (z, c_prev); gates are recomputed in bwd
        return f(z, c_prev), (z, c_prev)

    def bwd(res, ct):
        z, c_prev = res
        dh, dc = ct
        dz, dc_prev = lstm_cell_grad(
            z, c_prev, dh, dc, quantized=quantized, c_dtype=c_dtype,
            backend=backend,
        )
        return dz.astype(z.dtype), dc_prev

    f.defvjp(fwd, bwd)
    return f


def lstm_cell_train(z, c_prev, *, quantized: bool = True,
                    c_dtype=jnp.float16, backend: str | None = None):
    """The fused cell with the recompute-gates custom VJP — the training
    twin of ``lstm_cell``: forward values identical (same dispatched op),
    backward is the registered ``lstm_cell_grad`` op pair, saving only
    (z, c_prev) instead of autodiff's ~13 per-gate residuals."""
    pol = backend_policy(backend)
    return _make_lstm_cell_train(quantized, c_dtype, pol)(z, c_prev)


# ---------------------------------------------------------------------------
# packed weights are inference-only: gradients must fail loudly
# ---------------------------------------------------------------------------

_PACKED_GRAD_MSG = (
    "packed FloatSD8 weights are inference-only: jax.grad reached a "
    "PackedTensor weight site. The uint8 codes have no VJP — train on dense "
    "master weights (Policy.weight_quant='floatsd8' fake-quant, or the "
    "fused train_matmul path) and pack with WeightStore.pack for serving."
)


@jax.custom_vjp
def inference_only(y):
    """Identity whose backward raises: marks values computed from packed
    (FloatSD8-coded) weights, where a silent zero/missing gradient would
    otherwise be the failure mode."""
    return y


def _io_fwd(y):
    return y, None


def _io_bwd(_, g):
    raise TypeError(_PACKED_GRAD_MSG)


inference_only.defvjp(_io_fwd, _io_bwd)


# ---------------------------------------------------------------------------
# packed-weight entry points (the nn/serving hot paths)
# ---------------------------------------------------------------------------


def packed_einsum(eq: str, x, packed, *, out_dtype=jnp.float32,
                  cast_dtype=None, backend: str | None = None):
    """The weight-site einsums over a PackedTensor / PackedTensor4,
    backend-resolved.

    Supports the two-operand contractions used at every weight site:
    ``...d,df->...f`` / ``bd,dk->bk`` (contract w's first axis) and
    ``...d,vd->...v`` (contract w's second axis — tied logits head). The
    ref path decodes and einsums (bit-identical to the old unpack-then-
    einsum serving step); the pallas path feeds the codes to the fused
    decode+matmul kernel, transposing the (1-byte) codes when w is stored
    [free, contract]. FloatSD4 codes are nibble-packed along axis 0 and
    cannot be transposed at byte granularity, so the transposed layout
    decodes + einsums on every backend (recorded, never silent).
    """
    ins, out = eq.replace(" ", "").split("->")
    xl, wl = ins.split(",")
    cl = xl[-1]  # contraction label: x's last axis
    if len(wl) != 2 or cl not in wl:
        raise NotImplementedError(f"packed_einsum does not support {eq!r}")
    transpose = wl[1] == cl  # w stored [free, contract], e.g. "vd"
    wf = wl[0] if transpose else wl[1]
    if out != xl[:-1] + wf:
        raise NotImplementedError(f"packed_einsum does not support {eq!r}")
    if is_packed4(packed):
        return _packed4_einsum(
            eq, x, packed, transpose, out_dtype=out_dtype,
            cast_dtype=cast_dtype, backend=backend,
        )
    dec_backend = backend_policy(backend)
    if dec_backend == "ref" or (dec_backend == "auto" and interpret_mode()):
        c = x.shape[-1]
        n_free = packed.codes.shape[0 if transpose else 1]
        record(
            "floatsd_matmul", "ref",
            reason=f"policy:{dec_backend} (packed einsum)",
            cost=fm_cost.matmul_fwd_cost(
                x.size // max(c, 1), c, n_free, backend="ref",
                x_bytes=jnp.dtype(x.dtype).itemsize,
                out_bytes=jnp.dtype(out_dtype).itemsize,
            ),
        )
        w = floatsd.decode(packed.codes, packed.bias, dtype=cast_dtype or jnp.float32)
        y = jnp.einsum(
            eq, x, w, preferred_element_type=jnp.float32
        ).astype(out_dtype)
        STATS.add_touched("floatsd_matmul", "ref",
                          _nbytes(x, packed.codes, packed.bias, y))
        return inference_only(y)
    codes = packed.codes.T if transpose else packed.codes
    # a non-f32 compute policy (e.g. floatsd8_tpu's bf16) keeps its issue
    # dtype on the kernel path too, matching the ref branch's decode cast
    cd = None if cast_dtype in (None, jnp.float32) else cast_dtype
    return inference_only(matmul(
        x, codes, packed.bias, out_dtype=out_dtype, compute_dtype=cd,
        backend=backend,
    ))


def _packed4_einsum(eq: str, x, packed: PackedTensor4, transpose: bool, *,
                    out_dtype, cast_dtype, backend):
    """FloatSD4 arm of :func:`packed_einsum` (eq already validated)."""
    dec_backend = backend_policy(backend)
    ref_policy = dec_backend == "ref" or (
        dec_backend == "auto" and interpret_mode()
    )
    if ref_policy or transpose:
        c = x.shape[-1]
        n_free = packed.shape[0 if transpose else 1]
        reason = (
            f"policy:{dec_backend} (packed4 einsum)" if ref_policy
            else "packed4 transpose: nibble stream not byte-transposable, "
                 "decode+einsum"
        )
        record(
            "floatsd4_matmul", "ref", reason=reason,
            cost=fm4_cost.matmul4_fwd_cost(
                x.size // max(c, 1), c, n_free, backend="ref",
                x_bytes=jnp.dtype(x.dtype).itemsize,
                out_bytes=jnp.dtype(out_dtype).itemsize,
                wt_nbytes=_nbytes(packed.codes, packed.exps),
            ),
        )
        w = floatsd4.decode_packed(
            packed.codes, packed.exps, packed.k,
            dtype=cast_dtype or jnp.float32,
        )
        y = jnp.einsum(
            eq, x, w, preferred_element_type=jnp.float32
        ).astype(out_dtype)
        STATS.add_touched("floatsd4_matmul", "ref",
                          _nbytes(x, packed.codes, packed.exps, y))
        return inference_only(y)
    cd = None if cast_dtype in (None, jnp.float32) else cast_dtype
    return inference_only(matmul4(
        x, packed, out_dtype=out_dtype, compute_dtype=cd, backend=backend,
    ))


def hoist_packed(w, *, m: int | None = None, dtype=None,
                 backend: str | None = None):
    """Loop-hoist hint for packed weights used inside a time scan.

    When the per-call resolution will execute the matmuls on the ``ref``
    backend, decoding the codes once *outside* the scan beats decode-at-use
    every step; returns the dense decode then. On the pallas path the codes
    stay packed — decode-in-VMEM per tile is the kernel's whole point (2x
    less HBM weight traffic per step). Non-packed inputs pass through.

    ``m`` is the batch rows the scan-body matmuls will see; with it the
    prediction runs the SAME geometry rule as ``matmul`` (including the
    auto-mode padding-waste fallback), so a call site that would fall back
    to ref can never be left packed and pay a full decode per time step.
    """
    if not (is_packed(w) or is_packed4(w)):
        return w
    op = "floatsd4_matmul" if is_packed4(w) else "floatsd_matmul"
    if m is not None:
        k, n = w.shape if is_packed4(w) else w.codes.shape
        native, waste, _ = _matmul_geometry(m, k, n)
        d = _decide(op, native, waste, backend)
    else:  # coarse: platform/policy only
        pol = backend_policy(backend)
        ref = pol == "ref" or (pol == "auto" and interpret_mode())
        d = Decision(op, "ref" if ref else "pallas", False, False, "")
    if d.backend == "ref":
        # the decoded dense weight still came from inference-only codes: a
        # gradient reaching it must fail loudly, not silently vanish
        if is_packed4(w):
            return inference_only(unpack4(w, dtype=dtype or jnp.float32))
        return inference_only(
            floatsd.decode(w.codes, w.bias, dtype=dtype or jnp.float32)
        )
    return w


# ---------------------------------------------------------------------------
# op registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One dispatched op: its oracle, its Pallas kernel, the resolved
    public entry point (what the hot paths call), and its declarative
    cost model (the CostSpec contract — see kernels/README.md)."""

    name: str
    ref: Callable
    pallas: Callable
    dispatch: Callable
    cost: costmodel.CostSpec | None = None


REGISTRY: dict[str, OpSpec] = {}


def register(name: str, ref: Callable, pallas: Callable, dispatch: Callable,
             cost: costmodel.CostSpec | None = None) -> None:
    REGISTRY[name] = OpSpec(name, ref, pallas, dispatch, cost)


register(
    "floatsd_matmul", floatsd_matmul_ref, floatsd_matmul_pallas, matmul,
    cost=costmodel.CostSpec(
        "floatsd_matmul", fm_cost.matmul_fwd_cost,
        "decode-in-VMEM GEMM: codes 1 byte/weight; pallas refetches x per "
        "N-block and codes per M-block",
    ),
)
register(
    "floatsd4_matmul", floatsd4_matmul_ref, floatsd4_matmul_pallas, matmul4,
    cost=costmodel.CostSpec(
        "floatsd4_matmul", fm4_cost.matmul4_fwd_cost,
        "sub-byte decode-in-VMEM GEMM: 2 codes/byte along K + int8 group "
        "exponents (~0.53 byte/weight); pallas refetches x per N-block "
        "and the packed stream per M-block",
    ),
)
register(
    "lstm_cell", lstm_cell_ref, lstm_cell_pallas, lstm_cell,
    cost=costmodel.CostSpec(
        "lstm_cell", lc_cost.lstm_cell_cost,
        "elementwise single-pass; 3 MACs/elem (Table-7 Eq.5-6 lanes), "
        "c state in c_dtype (f16 blob)",
    ),
)
register(
    "floatsd_quantize",
    lambda x, bias=None: floatsd.encode(x, bias),
    quantize_pallas,
    quantize,
    cost=costmodel.CostSpec(
        "floatsd_quantize", fq_cost.quantize_cost,
        "elementwise encode f32 -> 1-byte codes, single pass",
    ),
)
register(
    "qsigmoid", qsigmoid_ref, qsigmoid_pallas, qsigmoid,
    cost=costmodel.CostSpec(
        "qsigmoid", qs_cost.qsigmoid_cost,
        "elementwise two-region LUT sigmoid, single pass",
    ),
)
# backward op pairs: the training path's VJPs resolve through these, so the
# whole BPTT step — not just inference — runs on registered kernels
register(
    "floatsd_matmul_dx", matmul_dx_ref, matmul_dx_pallas, matmul_dx,
    cost=costmodel.CostSpec(
        "floatsd_matmul_dx", fm_cost.matmul_dx_cost,
        "forward kernel on transposed codes; f32 compute",
    ),
)
register(
    "floatsd_matmul_dw", matmul_dw_ref, matmul_dw_pallas, matmul_dw,
    cost=costmodel.CostSpec(
        "floatsd_matmul_dw", fm_cost.matmul_dw_cost,
        "dense f32 GEMM, M innermost, FP8-e5m2 quantizer at the flush",
    ),
)
register(
    "lstm_cell_grad", lstm_cell_bwd_ref, lstm_cell_bwd_pallas, lstm_cell_grad,
    cost=costmodel.CostSpec(
        "lstm_cell_grad", lc_cost.lstm_cell_grad_cost,
        "recompute-gates backward; residuals are (z, c_prev) only",
    ),
)
# sequence mixers from the model zoo: dispatched + costed like the LSTM
# ops, but with oracle fallback (no padding path) on indivisible shapes
register(
    "rwkv_wkv", wkv_ref, wkv_pallas, rwkv_wkv,
    cost=costmodel.CostSpec(
        "rwkv_wkv", wkv_cost.wkv_cost,
        "chunked scan, [K, V] f32 state resident in VMEM; single-pass HBM",
    ),
)
register(
    "flash_attention", flash_attention_ref, flash_attention_pallas,
    flash_attention,
    cost=costmodel.CostSpec(
        "flash_attention", fa_cost.flash_attention_cost,
        "online softmax; KV refetched per Q-block; masked-out pairs "
        "charged to pad_waste_flops (kernel visits every tile)",
    ),
)
