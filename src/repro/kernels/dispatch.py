"""Kernel dispatch layer: one registry routing every hot-path op to its
Pallas kernel or its jnp oracle.

The FloatSD8 kernels (``floatsd_matmul``, ``lstm_cell``, ``floatsd_quantize``,
``qsigmoid``) each register a ``ref`` oracle and a ``pallas`` implementation.
Resolution per call site weighs three things:

  * **backend policy** — ``REPRO_KERNEL_BACKEND=ref|pallas|auto`` (env), a
    ``use_backend(...)`` context override, or an explicit ``backend=``
    argument; precedence: argument > context > env; default ``auto``.
  * **platform** — Pallas runs compiled on TPU and in ``interpret=True``
    validation mode everywhere else (``REPRO_KERNEL_INTERPRET=0|1``
    overrides). ``auto`` therefore resolves to ``ref`` off-TPU — the
    interpreter is a correctness tool, not a fast path — and ``pallas`` on
    TPU. ``backend="pallas"`` forces the kernel path anywhere (interpreted
    off-TPU), which is how the parity suite exercises it.
  * **shape divisibility** — inputs the tiling doesn't divide are padded up
    to tile multiples (zero activations x zero-code weights contribute an
    exact 0.0) when the padded work stays under ``PAD_WASTE_MAX`` x the
    exact work, instead of silently falling back to the oracle.

Every resolution is recorded in ``STATS``: per-``(op, backend)`` counters
plus the last ``Decision`` per op. Tests assert on these, so a tiling
regression cannot quietly turn every call into jnp. Jit caveat: inside a
jitted caller the resolver runs at trace time, so the counters count
(shape-distinct) traces, not executions — which is exactly the granularity
at which the backend choice is made.

``PackedTensor`` lives here (re-exported by ``serving.weight_store``) so the
nn layer can consume packed weights without depending on the serving stack.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import os
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import floatsd
from .floatsd_matmul.kernel import floatsd_matmul_pallas
from .floatsd_matmul.ref import floatsd_matmul_ref
from .floatsd_quantize.kernel import quantize_pallas
from .lstm_cell.kernel import lstm_cell_pallas
from .lstm_cell.ref import lstm_cell_ref
from .qsigmoid.kernel import qsigmoid_pallas
from .qsigmoid.ref import qsigmoid_ref

__all__ = [
    "BACKENDS", "PAD_WASTE_MAX", "PackedTensor", "Decision", "DispatchStats",
    "STATS", "record", "backend_policy", "use_backend", "interpret_mode",
    "matmul", "lstm_cell", "quantize", "qsigmoid", "packed_einsum",
    "hoist_packed", "matmul_tiles", "lstm_tiles", "row_tile",
    "OpSpec", "REGISTRY",
]

BACKENDS = ("ref", "pallas", "auto")

# auto mode pads to tile multiples only while padded_work / exact_work stays
# under this; beyond it the oracle is the better deal (forced pallas always
# pads).
PAD_WASTE_MAX = 2.0

# uint8 code that decodes to exactly 0.0 at any bias: e=0, mantissa index of
# 0.0 in the symmetric 31-entry grid.
ZERO_CODE = int(np.searchsorted(floatsd.MANTISSA_VALUES, 0.0))


class PackedTensor(NamedTuple):
    """A FloatSD8-packed tensor: uint8 codes + scalar int32 exponent bias.

    NamedTuple => a pytree node, so packed trees pass through jit/tree_map
    transparently with codes/bias as leaves.
    """

    codes: jax.Array  # uint8, same shape as the dense tensor
    bias: jax.Array  # int32 scalar (per-tensor exponent bias)


def is_packed(x: Any) -> bool:
    return isinstance(x, PackedTensor)


# ---------------------------------------------------------------------------
# backend policy + decision record
# ---------------------------------------------------------------------------


class Decision(NamedTuple):
    op: str
    backend: str  # "ref" | "pallas"
    interpret: bool
    padded: bool
    reason: str


class DispatchStats:
    """Per-(op, backend) resolution counters + the last Decision per op."""

    def __init__(self):
        self.counts: collections.Counter = collections.Counter()
        self.last: dict[str, Decision] = {}

    def record(self, d: Decision) -> None:
        self.counts[(d.op, d.backend)] += 1
        self.last[d.op] = d

    def count(self, op: str | None = None, backend: str | None = None) -> int:
        return sum(
            n for (o, b), n in self.counts.items()
            if (op is None or o == op) and (backend is None or b == backend)
        )

    def reset(self) -> None:
        self.counts.clear()
        self.last.clear()

    def snapshot(self) -> dict:
        return dict(self.counts)


STATS = DispatchStats()


def record(op: str, backend: str, *, interpret: bool = False,
           padded: bool = False, reason: str = "") -> Decision:
    d = Decision(op, backend, interpret, padded, reason)
    STATS.record(d)
    return d


_OVERRIDE: list[str] = []  # use_backend() stack


def backend_policy(backend: str | None = None) -> str:
    """Effective policy: explicit argument > use_backend() > env > auto."""
    pol = backend or (_OVERRIDE[-1] if _OVERRIDE else None) or os.environ.get(
        "REPRO_KERNEL_BACKEND", "auto"
    ).lower()
    if pol not in BACKENDS:
        raise ValueError(f"REPRO_KERNEL_BACKEND must be one of {BACKENDS}, got {pol!r}")
    return pol


@contextlib.contextmanager
def use_backend(name: str):
    """Force a backend for all dispatch resolutions inside the context."""
    if name not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {name!r}")
    _OVERRIDE.append(name)
    try:
        yield
    finally:
        _OVERRIDE.pop()


def interpret_mode() -> bool:
    """Pallas execution mode for this process: compiled on TPU, interpreted
    elsewhere. REPRO_KERNEL_INTERPRET=0|1 overrides."""
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"


def _decide(op: str, native: bool, waste: float, backend: str | None) -> Decision:
    """Pure resolution (no recording). ``native``: tiling divides as-is;
    ``waste``: padded/exact work ratio if padding were used."""
    pol = backend_policy(backend)
    interp = interpret_mode()
    if pol == "ref":
        return Decision(op, "ref", False, False, "policy:ref")
    if pol == "pallas":
        if native:
            return Decision(op, "pallas", interp, False, "policy:pallas")
        return Decision(
            op, "pallas", interp, True, f"policy:pallas, padded ({waste:.2f}x work)"
        )
    # auto
    if interp:
        return Decision(
            op, "ref", False, False, "auto:off-tpu (interpret is validation-only)"
        )
    if native:
        return Decision(op, "pallas", False, False, "auto:tpu, native tiles")
    if waste <= PAD_WASTE_MAX:
        return Decision(
            op, "pallas", False, True,
            f"auto:tpu, padded ({waste:.2f}x <= {PAD_WASTE_MAX}x)",
        )
    return Decision(
        op, "ref", False, False,
        f"auto:padding waste {waste:.2f}x > {PAD_WASTE_MAX}x",
    )


def _choose(op: str, native: bool, waste: float, backend: str | None) -> Decision:
    d = _decide(op, native, waste, backend)
    STATS.record(d)
    return d


# ---------------------------------------------------------------------------
# tile planning (shared with the per-kernel ops wrappers)
# ---------------------------------------------------------------------------


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def matmul_tiles(m: int, n: int, k: int) -> tuple[int, int, int]:
    """Largest power-of-two-halved MXU-aligned blocks dividing (m, n, k)."""
    bm = max(8, min(256, m))
    bn = min(256, n)
    bk = min(512, k)
    while m % bm:
        bm //= 2
    while n % bn:
        bn //= 2
    while k % bk:
        bk //= 2
    return bm, bn, bk


def lstm_tiles(b: int, h: int) -> tuple[int, int]:
    bb = 8
    while b % bb == 0 and bb < 128:
        bb *= 2
    if b % bb:
        bb //= 2
    bh = 128
    while h % bh == 0 and bh < 512:
        bh *= 2
    if h % bh:
        bh //= 2
    return bb, bh


def row_tile(rows: int) -> int:
    """Largest block <= 256 that divides ``rows`` by repeated halving (the
    flattened-2D elementwise kernels: quantize, qsigmoid)."""
    bm = min(256, rows)
    while rows % bm:
        bm //= 2
    return max(bm, 1)


def _matmul_geometry(m: int, k: int, n: int):
    """(native, padded-work ratio, padded dims) for an [M,K]x[K,N] call —
    the single source of the alignment arithmetic, shared by ``matmul`` and
    ``hoist_packed`` so the hoist prediction can never diverge from the
    per-call decision."""
    mp, kp, np_ = _ceil_to(max(m, 1), 8), _ceil_to(k, 128), _ceil_to(n, 128)
    native = (mp, kp, np_) == (m, k, n)
    waste = (mp * kp * np_) / max(m * k * n, 1)
    return native, waste, (mp, kp, np_)


# ---------------------------------------------------------------------------
# dispatched ops
# ---------------------------------------------------------------------------


def matmul(x, codes, bias, *, out_dtype=jnp.float32, precise: bool = True,
           compute_dtype=None, backend: str | None = None):
    """x [..., K] @ decode(codes [K, N]) -> [..., N], backend-resolved.

    ``precise=True`` issues the kernel's MXU dot in f32 (parity with the
    oracle to ~1e-6 relative); ``precise=False`` uses the bf16 issue dtype
    (full MXU rate, the paper's accumulate-in-f32 datapath). An explicit
    ``compute_dtype`` (e.g. a bf16-compute policy's cdt) overrides both.
    """
    if compute_dtype is None:
        compute_dtype = jnp.float32 if precise else jnp.bfloat16
    k = x.shape[-1]
    k2, n = codes.shape
    assert k == k2, (x.shape, codes.shape)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    native, waste, (mp, kp, np_) = _matmul_geometry(m, k, n)
    dec = _choose("floatsd_matmul", native, waste, backend)
    if dec.backend == "ref":
        y = floatsd_matmul_ref(x2, codes, bias, out_dtype)
    else:
        xx, cc = x2, codes
        if dec.padded:
            xx = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
            cc = jnp.pad(codes, ((0, kp - k), (0, np_ - n)), constant_values=ZERO_CODE)
        bm, bn, bk = matmul_tiles(xx.shape[0], cc.shape[1], xx.shape[1])
        y = floatsd_matmul_pallas(
            xx, cc, bias, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
            compute_dtype=compute_dtype,
            interpret=dec.interpret,
        )
        if dec.padded:
            y = y[:m, :n]
    return y.reshape(*lead, n)


def lstm_cell(z, c_prev, *, quantized: bool = True, c_dtype=jnp.float16,
              backend: str | None = None):
    """Fused gates -> (h, c), backend-resolved. z: [B, 4H] (i|f|g|o)."""
    b, h4 = z.shape
    h = h4 // 4
    bp, hp = _ceil_to(max(b, 1), 8), _ceil_to(max(h, 1), 128)
    native = (bp, hp) == (b, h)
    waste = (bp * hp) / max(b * h, 1)
    dec = _choose("lstm_cell", native, waste, backend)
    if dec.backend == "ref":
        return lstm_cell_ref(z, c_prev, quantized, c_dtype=c_dtype)
    zz, cc = z, c_prev
    if dec.padded:
        zz = jnp.pad(
            z.reshape(b, 4, h), ((0, bp - b), (0, 0), (0, hp - h))
        ).reshape(bp, 4 * hp)
        cc = jnp.pad(c_prev, ((0, bp - b), (0, hp - h)))
    bb, bh = lstm_tiles(bp, hp)
    h_t, c_t = lstm_cell_pallas(
        zz, cc, bb=bb, bh=bh, quantized=quantized, c_dtype=c_dtype,
        interpret=dec.interpret,
    )
    if dec.padded:
        h_t, c_t = h_t[:b, :h], c_t[:b, :h]
    return h_t, c_t


def quantize(x, bias=None, *, backend: str | None = None):
    """Any-shape tensor -> (uint8 FloatSD8 codes, int32 bias), resolved."""
    if bias is None:
        bias = floatsd.fit_bias(x)
    n = x.size
    # native = reshapes to [8k, 256] — rows a multiple of 8 so the layout is
    # TPU-tileable (f32 min tile is 8x128); anything else pads to that
    np_ = _ceil_to(max(n, 1), 8 * 256)
    native = n > 0 and n % (8 * 256) == 0
    waste = np_ / max(n, 1)
    dec = _choose("floatsd_quantize", native, waste, backend)
    if dec.backend == "ref":
        codes, _ = floatsd.encode(x, bias)
        return codes, bias
    flat = x.reshape(-1)
    if dec.padded:
        flat = jnp.pad(flat, (0, np_ - n))
    x2 = flat.reshape(-1, 256)
    codes2 = quantize_pallas(
        x2, bias, bm=row_tile(x2.shape[0]), bn=256, interpret=dec.interpret
    )
    return codes2.reshape(-1)[:n].reshape(x.shape), bias


def qsigmoid(x, *, backend: str | None = None):
    """Two-region FloatSD8 sigmoid for any-shape tensors, resolved."""
    n = x.size
    np_ = _ceil_to(max(n, 1), 8 * 256)
    native = n > 0 and n % (8 * 256) == 0
    waste = np_ / max(n, 1)
    dec = _choose("qsigmoid", native, waste, backend)
    if dec.backend == "ref":
        return qsigmoid_ref(x)
    flat = x.reshape(-1)
    if dec.padded:
        flat = jnp.pad(flat, (0, np_ - n))
    x2 = flat.reshape(-1, 256)
    y2 = qsigmoid_pallas(x2, bm=row_tile(x2.shape[0]), bn=256, interpret=dec.interpret)
    return y2.reshape(-1)[:n].reshape(x.shape)


# ---------------------------------------------------------------------------
# packed-weight entry points (the nn/serving hot paths)
# ---------------------------------------------------------------------------


def packed_einsum(eq: str, x, packed: PackedTensor, *, out_dtype=jnp.float32,
                  cast_dtype=None, backend: str | None = None):
    """The weight-site einsums over a PackedTensor, backend-resolved.

    Supports the two-operand contractions used at every weight site:
    ``...d,df->...f`` / ``bd,dk->bk`` (contract w's first axis) and
    ``...d,vd->...v`` (contract w's second axis — tied logits head). The
    ref path decodes and einsums (bit-identical to the old unpack-then-
    einsum serving step); the pallas path feeds the codes to the fused
    decode+matmul kernel, transposing the (1-byte) codes when w is stored
    [free, contract].
    """
    ins, out = eq.replace(" ", "").split("->")
    xl, wl = ins.split(",")
    cl = xl[-1]  # contraction label: x's last axis
    if len(wl) != 2 or cl not in wl:
        raise NotImplementedError(f"packed_einsum does not support {eq!r}")
    transpose = wl[1] == cl  # w stored [free, contract], e.g. "vd"
    wf = wl[0] if transpose else wl[1]
    if out != xl[:-1] + wf:
        raise NotImplementedError(f"packed_einsum does not support {eq!r}")
    dec_backend = backend_policy(backend)
    if dec_backend == "ref" or (dec_backend == "auto" and interpret_mode()):
        record("floatsd_matmul", "ref", reason=f"policy:{dec_backend} (packed einsum)")
        w = floatsd.decode(packed.codes, packed.bias, dtype=cast_dtype or jnp.float32)
        return jnp.einsum(
            eq, x, w, preferred_element_type=jnp.float32
        ).astype(out_dtype)
    codes = packed.codes.T if transpose else packed.codes
    # a non-f32 compute policy (e.g. floatsd8_tpu's bf16) keeps its issue
    # dtype on the kernel path too, matching the ref branch's decode cast
    cd = None if cast_dtype in (None, jnp.float32) else cast_dtype
    return matmul(
        x, codes, packed.bias, out_dtype=out_dtype, compute_dtype=cd,
        backend=backend,
    )


def hoist_packed(w, *, m: int | None = None, dtype=None,
                 backend: str | None = None):
    """Loop-hoist hint for packed weights used inside a time scan.

    When the per-call resolution will execute the matmuls on the ``ref``
    backend, decoding the codes once *outside* the scan beats decode-at-use
    every step; returns the dense decode then. On the pallas path the codes
    stay packed — decode-in-VMEM per tile is the kernel's whole point (2x
    less HBM weight traffic per step). Non-packed inputs pass through.

    ``m`` is the batch rows the scan-body matmuls will see; with it the
    prediction runs the SAME geometry rule as ``matmul`` (including the
    auto-mode padding-waste fallback), so a call site that would fall back
    to ref can never be left packed and pay a full decode per time step.
    """
    if not is_packed(w):
        return w
    if m is not None:
        k, n = w.codes.shape
        native, waste, _ = _matmul_geometry(m, k, n)
        d = _decide("floatsd_matmul", native, waste, backend)
    else:  # coarse: platform/policy only
        pol = backend_policy(backend)
        ref = pol == "ref" or (pol == "auto" and interpret_mode())
        d = Decision("floatsd_matmul", "ref" if ref else "pallas", False, False, "")
    if d.backend == "ref":
        return floatsd.decode(w.codes, w.bias, dtype=dtype or jnp.float32)
    return w


# ---------------------------------------------------------------------------
# op registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One dispatched op: its oracle, its Pallas kernel, and the resolved
    public entry point (what the hot paths call)."""

    name: str
    ref: Callable
    pallas: Callable
    dispatch: Callable


REGISTRY: dict[str, OpSpec] = {}


def register(name: str, ref: Callable, pallas: Callable, dispatch: Callable) -> None:
    REGISTRY[name] = OpSpec(name, ref, pallas, dispatch)


register("floatsd_matmul", floatsd_matmul_ref, floatsd_matmul_pallas, matmul)
register("lstm_cell", lstm_cell_ref, lstm_cell_pallas, lstm_cell)
register(
    "floatsd_quantize",
    lambda x, bias=None: floatsd.encode(x, bias),
    quantize_pallas,
    quantize,
)
register("qsigmoid", qsigmoid_ref, qsigmoid_pallas, qsigmoid)
