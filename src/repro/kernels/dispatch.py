"""Kernel dispatch layer: one registry routing every hot-path op to its
Pallas kernel or its jnp oracle.

The FloatSD8 kernels (``floatsd_matmul``, ``lstm_cell``, ``floatsd_quantize``,
``qsigmoid``) each register a ``ref`` oracle and a ``pallas`` implementation.
Resolution per call site weighs three things:

  * **backend policy** — ``REPRO_KERNEL_BACKEND=ref|pallas|auto`` (env), a
    ``use_backend(...)`` context override, or an explicit ``backend=``
    argument; precedence: argument > context > env; default ``auto``.
  * **platform** — Pallas runs compiled on TPU and in ``interpret=True``
    validation mode everywhere else (``REPRO_KERNEL_INTERPRET=0|1``
    overrides). ``auto`` therefore resolves to ``ref`` off-TPU — the
    interpreter is a correctness tool, not a fast path — and ``pallas`` on
    TPU. ``backend="pallas"`` forces the kernel path anywhere (interpreted
    off-TPU), which is how the parity suite exercises it.
  * **shape divisibility** — inputs the tiling doesn't divide are padded up
    to tile multiples (zero activations x zero-code weights contribute an
    exact 0.0) when the padded work stays under ``PAD_WASTE_MAX`` x the
    exact work, instead of silently falling back to the oracle.

Every resolution is recorded in ``STATS``: per-``(op, backend)`` counters
plus the last ``Decision`` per op. Tests assert on these, so a tiling
regression cannot quietly turn every call into jnp. Jit caveat: inside a
jitted caller the resolver runs at trace time, so the counters count
(shape-distinct) traces, not executions — which is exactly the granularity
at which the backend choice is made.

``PackedTensor`` lives here (re-exported by ``serving.weight_store``) so the
nn layer can consume packed weights without depending on the serving stack.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import functools
import os
import threading
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import floatsd
from ..obs import telemetry as obs_telemetry
from .floatsd_matmul.bwd import (
    matmul_dw_pallas,
    matmul_dw_ref,
    matmul_dx_pallas,
    matmul_dx_ref,
)
from .floatsd_matmul.kernel import floatsd_matmul_pallas
from .floatsd_matmul.ref import floatsd_matmul_ref
from .floatsd_quantize.kernel import quantize_pallas
from .lstm_cell.bwd import lstm_cell_bwd_pallas, lstm_cell_bwd_ref
from .lstm_cell.kernel import lstm_cell_pallas
from .lstm_cell.ref import lstm_cell_ref
from .qsigmoid.kernel import qsigmoid_pallas
from .qsigmoid.ref import qsigmoid_ref

__all__ = [
    "BACKENDS", "PAD_WASTE_MAX", "PackedTensor", "Decision", "DispatchStats",
    "STATS", "record", "backend_policy", "use_backend", "interpret_mode",
    "matmul", "lstm_cell", "quantize", "qsigmoid", "packed_einsum",
    "hoist_packed", "matmul_tiles", "lstm_tiles", "row_tile",
    "matmul_dx", "matmul_dw", "lstm_cell_grad", "train_matmul",
    "lstm_cell_train", "pack_train", "hoist_train", "inference_only",
    "OpSpec", "REGISTRY",
]

BACKENDS = ("ref", "pallas", "auto")

# auto mode pads to tile multiples only while padded_work / exact_work stays
# under this; beyond it the oracle is the better deal (forced pallas always
# pads).
PAD_WASTE_MAX = 2.0

# uint8 code that decodes to exactly 0.0 at any bias: e=0, mantissa index of
# 0.0 in the symmetric 31-entry grid.
ZERO_CODE = int(np.searchsorted(floatsd.MANTISSA_VALUES, 0.0))


class PackedTensor(NamedTuple):
    """A FloatSD8-packed tensor: uint8 codes + scalar int32 exponent bias.

    NamedTuple => a pytree node, so packed trees pass through jit/tree_map
    transparently with codes/bias as leaves.
    """

    codes: jax.Array  # uint8, same shape as the dense tensor
    bias: jax.Array  # int32 scalar (per-tensor exponent bias)


def is_packed(x: Any) -> bool:
    return isinstance(x, PackedTensor)


# ---------------------------------------------------------------------------
# backend policy + decision record
# ---------------------------------------------------------------------------


class Decision(NamedTuple):
    op: str
    backend: str  # "ref" | "pallas"
    interpret: bool
    padded: bool
    reason: str


class DispatchStats:
    """Per-(op, backend) resolution counters + the last Decision per op.

    Lock-guarded: resolutions happen at trace time on whatever thread is
    tracing (the serving pump worker, a test thread), while the /metrics
    scrape path reads ``snapshot()`` from the HTTP event loop — iterating
    the Counter during a concurrent ``record`` would be a data race."""

    def __init__(self):
        self.counts: collections.Counter = collections.Counter()
        self.last: dict[str, Decision] = {}
        self._lock = threading.Lock()

    def record(self, d: Decision) -> None:
        with self._lock:
            self.counts[(d.op, d.backend)] += 1
            self.last[d.op] = d

    def count(self, op: str | None = None, backend: str | None = None) -> int:
        with self._lock:
            return sum(
                n for (o, b), n in self.counts.items()
                if (op is None or o == op) and (backend is None or b == backend)
            )

    def reset(self) -> None:
        with self._lock:
            self.counts.clear()
            self.last.clear()

    def snapshot(self) -> dict:
        """{(op, backend): resolutions} — what /metrics exports as
        ``repro_dispatch_decisions_total{op,backend}``."""
        with self._lock:
            return dict(self.counts)


STATS = DispatchStats()


def record(op: str, backend: str, *, interpret: bool = False,
           padded: bool = False, reason: str = "") -> Decision:
    d = Decision(op, backend, interpret, padded, reason)
    STATS.record(d)
    return d


_OVERRIDE: list[str] = []  # use_backend() stack


def backend_policy(backend: str | None = None) -> str:
    """Effective policy: explicit argument > use_backend() > env > auto."""
    pol = backend or (_OVERRIDE[-1] if _OVERRIDE else None) or os.environ.get(
        "REPRO_KERNEL_BACKEND", "auto"
    ).lower()
    if pol not in BACKENDS:
        raise ValueError(f"REPRO_KERNEL_BACKEND must be one of {BACKENDS}, got {pol!r}")
    return pol


@contextlib.contextmanager
def use_backend(name: str):
    """Force a backend for all dispatch resolutions inside the context."""
    if name not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {name!r}")
    _OVERRIDE.append(name)
    try:
        yield
    finally:
        _OVERRIDE.pop()


def interpret_mode() -> bool:
    """Pallas execution mode for this process: compiled on TPU, interpreted
    elsewhere. REPRO_KERNEL_INTERPRET=0|1 overrides."""
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env != "0"
    return jax.default_backend() != "tpu"


def _decide(op: str, native: bool, waste: float, backend: str | None) -> Decision:
    """Pure resolution (no recording). ``native``: tiling divides as-is;
    ``waste``: padded/exact work ratio if padding were used."""
    pol = backend_policy(backend)
    interp = interpret_mode()
    if pol == "ref":
        return Decision(op, "ref", False, False, "policy:ref")
    if pol == "pallas":
        if native:
            return Decision(op, "pallas", interp, False, "policy:pallas")
        return Decision(
            op, "pallas", interp, True, f"policy:pallas, padded ({waste:.2f}x work)"
        )
    # auto
    if interp:
        return Decision(
            op, "ref", False, False, "auto:off-tpu (interpret is validation-only)"
        )
    if native:
        return Decision(op, "pallas", False, False, "auto:tpu, native tiles")
    if waste <= PAD_WASTE_MAX:
        return Decision(
            op, "pallas", False, True,
            f"auto:tpu, padded ({waste:.2f}x <= {PAD_WASTE_MAX}x)",
        )
    return Decision(
        op, "ref", False, False,
        f"auto:padding waste {waste:.2f}x > {PAD_WASTE_MAX}x",
    )


def _choose(op: str, native: bool, waste: float, backend: str | None) -> Decision:
    d = _decide(op, native, waste, backend)
    STATS.record(d)
    return d


# ---------------------------------------------------------------------------
# tile planning (shared with the per-kernel ops wrappers)
# ---------------------------------------------------------------------------


def _ceil_to(x: int, mult: int) -> int:
    return -(-x // mult) * mult


def matmul_tiles(m: int, n: int, k: int) -> tuple[int, int, int]:
    """Largest power-of-two-halved MXU-aligned blocks dividing (m, n, k)."""
    bm = max(8, min(256, m))
    bn = min(256, n)
    bk = min(512, k)
    while m % bm:
        bm //= 2
    while n % bn:
        bn //= 2
    while k % bk:
        bk //= 2
    return bm, bn, bk


def lstm_tiles(b: int, h: int) -> tuple[int, int]:
    bb = 8
    while b % bb == 0 and bb < 128:
        bb *= 2
    if b % bb:
        bb //= 2
    bh = 128
    while h % bh == 0 and bh < 512:
        bh *= 2
    if h % bh:
        bh //= 2
    return bb, bh


def row_tile(rows: int) -> int:
    """Largest block <= 256 that divides ``rows`` by repeated halving (the
    flattened-2D elementwise kernels: quantize, qsigmoid)."""
    bm = min(256, rows)
    while rows % bm:
        bm //= 2
    return max(bm, 1)


def _matmul_geometry(m: int, k: int, n: int):
    """(native, padded-work ratio, padded dims) for an [M,K]x[K,N] call —
    the single source of the alignment arithmetic, shared by ``matmul`` and
    ``hoist_packed`` so the hoist prediction can never diverge from the
    per-call decision."""
    mp, kp, np_ = _ceil_to(max(m, 1), 8), _ceil_to(k, 128), _ceil_to(n, 128)
    native = (mp, kp, np_) == (m, k, n)
    waste = (mp * kp * np_) / max(m * k * n, 1)
    return native, waste, (mp, kp, np_)


# ---------------------------------------------------------------------------
# dispatched ops
# ---------------------------------------------------------------------------


def matmul(x, codes, bias, *, out_dtype=jnp.float32, precise: bool = True,
           compute_dtype=None, backend: str | None = None):
    """x [..., K] @ decode(codes [K, N]) -> [..., N], backend-resolved.

    ``precise=True`` issues the kernel's MXU dot in f32 (parity with the
    oracle to ~1e-6 relative); ``precise=False`` uses the bf16 issue dtype
    (full MXU rate, the paper's accumulate-in-f32 datapath). An explicit
    ``compute_dtype`` (e.g. a bf16-compute policy's cdt) overrides both.
    """
    if compute_dtype is None:
        compute_dtype = jnp.float32 if precise else jnp.bfloat16
    k = x.shape[-1]
    k2, n = codes.shape
    assert k == k2, (x.shape, codes.shape)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k)
    m = x2.shape[0]
    native, waste, (mp, kp, np_) = _matmul_geometry(m, k, n)
    dec = _choose("floatsd_matmul", native, waste, backend)
    if dec.backend == "ref":
        y = floatsd_matmul_ref(x2, codes, bias, out_dtype)
    else:
        xx, cc = x2, codes
        if dec.padded:
            xx = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
            cc = jnp.pad(codes, ((0, kp - k), (0, np_ - n)), constant_values=ZERO_CODE)
        bm, bn, bk = matmul_tiles(xx.shape[0], cc.shape[1], xx.shape[1])
        y = floatsd_matmul_pallas(
            xx, cc, bias, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
            compute_dtype=compute_dtype,
            interpret=dec.interpret,
        )
        if dec.padded:
            y = y[:m, :n]
    return y.reshape(*lead, n)


def lstm_cell(z, c_prev, *, quantized: bool = True, c_dtype=jnp.float16,
              backend: str | None = None):
    """Fused gates -> (h, c), backend-resolved. z: [B, 4H] (i|f|g|o)."""
    b, h4 = z.shape
    h = h4 // 4
    bp, hp = _ceil_to(max(b, 1), 8), _ceil_to(max(h, 1), 128)
    native = (bp, hp) == (b, h)
    waste = (bp * hp) / max(b * h, 1)
    dec = _choose("lstm_cell", native, waste, backend)
    if dec.backend == "ref":
        return lstm_cell_ref(z, c_prev, quantized, c_dtype=c_dtype)
    zz, cc = z, c_prev
    if dec.padded:
        zz = jnp.pad(
            z.reshape(b, 4, h), ((0, bp - b), (0, 0), (0, hp - h))
        ).reshape(bp, 4 * hp)
        cc = jnp.pad(c_prev, ((0, bp - b), (0, hp - h)))
    bb, bh = lstm_tiles(bp, hp)
    h_t, c_t = lstm_cell_pallas(
        zz, cc, bb=bb, bh=bh, quantized=quantized, c_dtype=c_dtype,
        interpret=dec.interpret,
    )
    if dec.padded:
        h_t, c_t = h_t[:b, :h], c_t[:b, :h]
    return h_t, c_t


def quantize(x, bias=None, *, backend: str | None = None):
    """Any-shape tensor -> (uint8 FloatSD8 codes, int32 bias), resolved."""
    if bias is None:
        bias = floatsd.fit_bias(x)
    n = x.size
    # native = reshapes to [8k, 256] — rows a multiple of 8 so the layout is
    # TPU-tileable (f32 min tile is 8x128); anything else pads to that
    np_ = _ceil_to(max(n, 1), 8 * 256)
    native = n > 0 and n % (8 * 256) == 0
    waste = np_ / max(n, 1)
    dec = _choose("floatsd_quantize", native, waste, backend)
    if dec.backend == "ref":
        codes, _ = floatsd.encode(x, bias)
        return codes, bias
    flat = x.reshape(-1)
    if dec.padded:
        flat = jnp.pad(flat, (0, np_ - n))
    x2 = flat.reshape(-1, 256)
    codes2 = quantize_pallas(
        x2, bias, bm=row_tile(x2.shape[0]), bn=256, interpret=dec.interpret
    )
    return codes2.reshape(-1)[:n].reshape(x.shape), bias


def qsigmoid(x, *, backend: str | None = None):
    """Two-region FloatSD8 sigmoid for any-shape tensors, resolved."""
    n = x.size
    np_ = _ceil_to(max(n, 1), 8 * 256)
    native = n > 0 and n % (8 * 256) == 0
    waste = np_ / max(n, 1)
    dec = _choose("qsigmoid", native, waste, backend)
    if dec.backend == "ref":
        return qsigmoid_ref(x)
    flat = x.reshape(-1)
    if dec.padded:
        flat = jnp.pad(flat, (0, np_ - n))
    x2 = flat.reshape(-1, 256)
    y2 = qsigmoid_pallas(x2, bm=row_tile(x2.shape[0]), bn=256, interpret=dec.interpret)
    return y2.reshape(-1)[:n].reshape(x.shape)


# ---------------------------------------------------------------------------
# backward ops (the training hot path: fused quantized BPTT)
# ---------------------------------------------------------------------------


def matmul_dx(g, codes, bias, *, backend: str | None = None):
    """Activation gradient of the FloatSD8 matmul, backend-resolved:
    g [..., N] x decode(codes [K, N])^T -> [..., K] in f32 (the precise
    datapath — FP8 act-grad quantization lives at the act_quant STE nodes,
    not here). Pallas path reuses the forward decode-in-VMEM kernel on the
    transposed 1-byte codes."""
    k, n = codes.shape
    lead = g.shape[:-1]
    g2 = g.reshape(-1, n)
    m = g2.shape[0]
    # output [m, k], contraction over n
    native, waste, (mp, np_, kp) = _matmul_geometry(m, n, k)
    dec = _choose("floatsd_matmul_dx", native, waste, backend)
    if dec.backend == "ref":
        dx = matmul_dx_ref(g2, codes, bias)
    else:
        gg, cc = g2, codes
        if dec.padded:
            gg = jnp.pad(g2, ((0, mp - m), (0, np_ - n)))
            cc = jnp.pad(codes, ((0, kp - k), (0, np_ - n)), constant_values=ZERO_CODE)
        bm, bn, bk = matmul_tiles(mp, kp, np_)
        dx = matmul_dx_pallas(gg, cc, bias, bm=bm, bn=bn, bk=bk,
                              interpret=dec.interpret)
        if dec.padded:
            dx = dx[:m, :k]
    return dx.reshape(*lead, k)


def _dw_flush_telemetry(dw, quant: bool):
    """Quantizer-health hook at the matmul_dw flush: when the telemetry
    sink is enabled (checked at trace time — see ``KernelStats``), count
    saturated (|dw| at the e5m2 clamp) and zero (true zeros + underflow,
    already collapsed by the in-kernel quantizer) elements of the flushed
    dW and report them host-side via ``jax.debug.callback``."""
    if not (quant and obs_telemetry.KERNEL_STATS.enabled):
        return dw
    sat = jnp.sum(jnp.abs(dw) >= obs_telemetry.FP8_SAT_THRESHOLD)
    zero = jnp.sum(dw == 0)
    jax.debug.callback(
        functools.partial(
            obs_telemetry.KERNEL_STATS.record, "floatsd_matmul_dw", dw.size
        ),
        sat,
        zero,
    )
    return dw


def matmul_dw(x, g, *, quant: bool = True, backend: str | None = None):
    """Weight gradient of the FloatSD8 matmul, backend-resolved:
    x [..., K]^T x g [..., N] -> [K, N], f32 accumulation, the paper's FP8
    weight-gradient quantizer applied at the accumulator flush *inside* the
    kernel (``quant=False`` gives the raw f32 dw for parity oracles)."""
    k = x.shape[-1]
    n = g.shape[-1]
    x2 = x.reshape(-1, k)
    g2 = g.reshape(-1, n)
    m = x2.shape[0]
    assert g2.shape[0] == m, (x.shape, g.shape)
    # output [k, n], contraction over m (rows pad to 8, lanes to 128)
    native, waste, (kp, mp, np_) = _matmul_geometry(k, m, n)
    dec = _choose("floatsd_matmul_dw", native, waste, backend)
    if dec.backend == "ref":
        return _dw_flush_telemetry(matmul_dw_ref(x2, g2, quant=quant), quant)
    xx, gg = x2, g2
    if dec.padded:
        xx = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
        gg = jnp.pad(g2, ((0, mp - m), (0, np_ - n)))
    bm, bn, bk = matmul_tiles(kp, np_, mp)
    dw = matmul_dw_pallas(xx, gg, bm=bm, bn=bn, bk=bk, quant=quant,
                          interpret=dec.interpret)
    if dec.padded:
        dw = dw[:k, :n]
    return _dw_flush_telemetry(dw, quant)


def lstm_cell_grad(z, c_prev, dh, dc, *, quantized: bool = True,
                   c_dtype=jnp.float16, backend: str | None = None):
    """Recompute-gates backward of the fused cell, backend-resolved.
    z: [B, 4H], c_prev/dh/dc: [B, H] -> (dz [B, 4H] f32, dc_prev [B, H]).
    The only residuals it needs are (z, c_prev) — see kernels README,
    'backward ops'."""
    b, h4 = z.shape
    h = h4 // 4
    bp, hp = _ceil_to(max(b, 1), 8), _ceil_to(max(h, 1), 128)
    native = (bp, hp) == (b, h)
    waste = (bp * hp) / max(b * h, 1)
    dec = _choose("lstm_cell_grad", native, waste, backend)
    if dec.backend == "ref":
        return lstm_cell_bwd_ref(z, c_prev, dh, dc, quantized, c_dtype=c_dtype)
    zz, cc, dhh, dcc = z, c_prev, dh, dc
    if dec.padded:
        zz = jnp.pad(
            z.reshape(b, 4, h), ((0, bp - b), (0, 0), (0, hp - h))
        ).reshape(bp, 4 * hp)
        cc = jnp.pad(c_prev, ((0, bp - b), (0, hp - h)))
        dhh = jnp.pad(dh, ((0, bp - b), (0, hp - h)))
        dcc = jnp.pad(dc, ((0, bp - b), (0, hp - h)))
    bb, bh = lstm_tiles(bp, hp)
    dz, dcp = lstm_cell_bwd_pallas(
        zz, cc, dhh, dcc, bb=bb, bh=bh, quantized=quantized, c_dtype=c_dtype,
        interpret=dec.interpret,
    )
    if dec.padded:
        dz = dz.reshape(bp, 4, hp)[:b, :, :h].reshape(b, 4 * h)
        dcp = dcp[:b, :h]
    return dz, dcp


# ---------------------------------------------------------------------------
# custom-VJP training entry points: the whole train step resolves to
# registered kernels, forward AND backward
# ---------------------------------------------------------------------------


def pack_train(w) -> PackedTensor:
    """Encode a dense master weight to FloatSD8 codes for the fused training
    path (hoisted outside the time scan — encode is T-invariant). The codes
    carry the exact forward values: decode(encode(w)) == quantize(w).values
    bit-identically, so the fused path's loss trajectory matches the
    fake-quant STE path's. Gradients do not flow through the (integer)
    codes; ``train_matmul`` routes dw straight to the dense master (STE)."""
    codes, bias = floatsd.encode(jax.lax.stop_gradient(w))
    return PackedTensor(codes, bias)


def hoist_train(w, *, dtype=None, backend: str | None = None):
    """Scan-loop hoist for the fused TRAINING path — the gradient-side twin
    of ``hoist_packed``. When the resolved backend is ``ref``, the codes
    would be decoded per time step in BOTH scans (forward and backward), so
    quantize-at-use once outside the scan wins: returns the dense
    STE-fake-quantized weight (bit-identical values to decode(encode(w))).
    On the pallas path returns the ``PackedTensor`` — decode-in-VMEM per
    tile is the kernel's whole point, forward and backward alike."""
    pol = backend_policy(backend)
    ref = pol == "ref" or (pol == "auto" and interpret_mode())
    if ref:
        bias = jax.lax.stop_gradient(floatsd.fit_bias(w))
        wq = floatsd.quantize_ste(w, bias)
        return wq.astype(dtype or jnp.float32)
    return pack_train(w)


def _float0(x):
    return np.zeros(np.shape(x), jax.dtypes.float0)


@functools.lru_cache(maxsize=None)
def _make_train_matmul_packed(backend: str | None, w_dtype: str):
    """custom-VJP matmul over (x, w_master, codes, bias): forward is the
    dispatched decode+matmul on the codes; backward is the registered
    (floatsd_matmul_dx, floatsd_matmul_dw) op pair — dx f32, dw emitted
    through the FP8 gradient quantizer in-kernel and routed straight-through
    to the dense master weight."""

    @jax.custom_vjp
    def f(x, w, codes, bias):
        del w  # forward runs on the codes; w is the gradient target (STE)
        return matmul(x, codes, bias, out_dtype=jnp.float32, backend=backend)

    def fwd(x, w, codes, bias):
        del w
        y = matmul(x, codes, bias, out_dtype=jnp.float32, backend=backend)
        return y, (x, codes, bias)

    def bwd(res, g):
        x, codes, bias = res
        dx = matmul_dx(g, codes, bias, backend=backend).astype(x.dtype)
        dw = matmul_dw(x, g, backend=backend).astype(jnp.dtype(w_dtype))
        return dx, dw, _float0(codes), _float0(bias)

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def _make_train_matmul_dense(backend: str | None):
    """Dense-hoisted variant (the ref backend): forward is a plain f32 dot
    on the pre-quantized weight (decode hoisted out of the scan by
    ``hoist_train``); backward keeps the fused-BPTT contract — dx in f32,
    dw through the FP8 gradient quantizer (the registered op's oracle),
    flowing to the master via the hoisted STE node."""

    @jax.custom_vjp
    def f(x, wq):
        return jnp.dot(x, wq, preferred_element_type=jnp.float32).astype(
            jnp.float32
        )

    def fwd(x, wq):
        return f(x, wq), (x, wq)

    def bwd(res, g):
        x, wq = res
        record("floatsd_matmul_dx", "ref", reason="train:hoisted-dense")
        dx = jnp.dot(g, wq.T, preferred_element_type=jnp.float32).astype(x.dtype)
        dw = matmul_dw(x, g, backend=backend).astype(wq.dtype)
        return dx, dw

    f.defvjp(fwd, bwd)
    return f


def train_matmul(x, w, wq, *, backend: str | None = None):
    """Training-path matmul: x [..., K] @ quantized(w) with the fused
    backward contract. ``w`` is the dense master weight the FP8 dw flows
    to; ``wq`` is its hoisted quantization from ``hoist_train`` — a
    ``PackedTensor`` on the pallas path (decode-in-VMEM, in-kernel FP8 dw)
    or the dense STE value on ref (plain dots, oracle FP8 dw; dw reaches
    ``w`` through the hoisted STE node, so ``w`` itself is unused here)."""
    pol = backend_policy(backend)
    if is_packed(wq):
        return _make_train_matmul_packed(pol, jnp.dtype(w.dtype).name)(
            x, w, wq.codes, wq.bias
        )
    record("floatsd_matmul", "ref", reason="train:hoisted-dense")
    return _make_train_matmul_dense(pol)(x, wq)




@functools.lru_cache(maxsize=None)
def _make_lstm_cell_train(quantized: bool, c_dtype, backend: str | None):
    @jax.custom_vjp
    def f(z, c_prev):
        return lstm_cell(z, c_prev, quantized=quantized, c_dtype=c_dtype,
                         backend=backend)

    def fwd(z, c_prev):
        # residual contract: ONLY (z, c_prev); gates are recomputed in bwd
        return f(z, c_prev), (z, c_prev)

    def bwd(res, ct):
        z, c_prev = res
        dh, dc = ct
        dz, dc_prev = lstm_cell_grad(
            z, c_prev, dh, dc, quantized=quantized, c_dtype=c_dtype,
            backend=backend,
        )
        return dz.astype(z.dtype), dc_prev

    f.defvjp(fwd, bwd)
    return f


def lstm_cell_train(z, c_prev, *, quantized: bool = True,
                    c_dtype=jnp.float16, backend: str | None = None):
    """The fused cell with the recompute-gates custom VJP — the training
    twin of ``lstm_cell``: forward values identical (same dispatched op),
    backward is the registered ``lstm_cell_grad`` op pair, saving only
    (z, c_prev) instead of autodiff's ~13 per-gate residuals."""
    pol = backend_policy(backend)
    return _make_lstm_cell_train(quantized, c_dtype, pol)(z, c_prev)


# ---------------------------------------------------------------------------
# packed weights are inference-only: gradients must fail loudly
# ---------------------------------------------------------------------------

_PACKED_GRAD_MSG = (
    "packed FloatSD8 weights are inference-only: jax.grad reached a "
    "PackedTensor weight site. The uint8 codes have no VJP — train on dense "
    "master weights (Policy.weight_quant='floatsd8' fake-quant, or the "
    "fused train_matmul path) and pack with WeightStore.pack for serving."
)


@jax.custom_vjp
def inference_only(y):
    """Identity whose backward raises: marks values computed from packed
    (FloatSD8-coded) weights, where a silent zero/missing gradient would
    otherwise be the failure mode."""
    return y


def _io_fwd(y):
    return y, None


def _io_bwd(_, g):
    raise TypeError(_PACKED_GRAD_MSG)


inference_only.defvjp(_io_fwd, _io_bwd)


# ---------------------------------------------------------------------------
# packed-weight entry points (the nn/serving hot paths)
# ---------------------------------------------------------------------------


def packed_einsum(eq: str, x, packed: PackedTensor, *, out_dtype=jnp.float32,
                  cast_dtype=None, backend: str | None = None):
    """The weight-site einsums over a PackedTensor, backend-resolved.

    Supports the two-operand contractions used at every weight site:
    ``...d,df->...f`` / ``bd,dk->bk`` (contract w's first axis) and
    ``...d,vd->...v`` (contract w's second axis — tied logits head). The
    ref path decodes and einsums (bit-identical to the old unpack-then-
    einsum serving step); the pallas path feeds the codes to the fused
    decode+matmul kernel, transposing the (1-byte) codes when w is stored
    [free, contract].
    """
    ins, out = eq.replace(" ", "").split("->")
    xl, wl = ins.split(",")
    cl = xl[-1]  # contraction label: x's last axis
    if len(wl) != 2 or cl not in wl:
        raise NotImplementedError(f"packed_einsum does not support {eq!r}")
    transpose = wl[1] == cl  # w stored [free, contract], e.g. "vd"
    wf = wl[0] if transpose else wl[1]
    if out != xl[:-1] + wf:
        raise NotImplementedError(f"packed_einsum does not support {eq!r}")
    dec_backend = backend_policy(backend)
    if dec_backend == "ref" or (dec_backend == "auto" and interpret_mode()):
        record("floatsd_matmul", "ref", reason=f"policy:{dec_backend} (packed einsum)")
        w = floatsd.decode(packed.codes, packed.bias, dtype=cast_dtype or jnp.float32)
        y = jnp.einsum(
            eq, x, w, preferred_element_type=jnp.float32
        ).astype(out_dtype)
        return inference_only(y)
    codes = packed.codes.T if transpose else packed.codes
    # a non-f32 compute policy (e.g. floatsd8_tpu's bf16) keeps its issue
    # dtype on the kernel path too, matching the ref branch's decode cast
    cd = None if cast_dtype in (None, jnp.float32) else cast_dtype
    return inference_only(matmul(
        x, codes, packed.bias, out_dtype=out_dtype, compute_dtype=cd,
        backend=backend,
    ))


def hoist_packed(w, *, m: int | None = None, dtype=None,
                 backend: str | None = None):
    """Loop-hoist hint for packed weights used inside a time scan.

    When the per-call resolution will execute the matmuls on the ``ref``
    backend, decoding the codes once *outside* the scan beats decode-at-use
    every step; returns the dense decode then. On the pallas path the codes
    stay packed — decode-in-VMEM per tile is the kernel's whole point (2x
    less HBM weight traffic per step). Non-packed inputs pass through.

    ``m`` is the batch rows the scan-body matmuls will see; with it the
    prediction runs the SAME geometry rule as ``matmul`` (including the
    auto-mode padding-waste fallback), so a call site that would fall back
    to ref can never be left packed and pay a full decode per time step.
    """
    if not is_packed(w):
        return w
    if m is not None:
        k, n = w.codes.shape
        native, waste, _ = _matmul_geometry(m, k, n)
        d = _decide("floatsd_matmul", native, waste, backend)
    else:  # coarse: platform/policy only
        pol = backend_policy(backend)
        ref = pol == "ref" or (pol == "auto" and interpret_mode())
        d = Decision("floatsd_matmul", "ref" if ref else "pallas", False, False, "")
    if d.backend == "ref":
        # the decoded dense weight still came from inference-only codes: a
        # gradient reaching it must fail loudly, not silently vanish
        return inference_only(
            floatsd.decode(w.codes, w.bias, dtype=dtype or jnp.float32)
        )
    return w


# ---------------------------------------------------------------------------
# op registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One dispatched op: its oracle, its Pallas kernel, and the resolved
    public entry point (what the hot paths call)."""

    name: str
    ref: Callable
    pallas: Callable
    dispatch: Callable


REGISTRY: dict[str, OpSpec] = {}


def register(name: str, ref: Callable, pallas: Callable, dispatch: Callable) -> None:
    REGISTRY[name] = OpSpec(name, ref, pallas, dispatch)


register("floatsd_matmul", floatsd_matmul_ref, floatsd_matmul_pallas, matmul)
register("lstm_cell", lstm_cell_ref, lstm_cell_pallas, lstm_cell)
register(
    "floatsd_quantize",
    lambda x, bias=None: floatsd.encode(x, bias),
    quantize_pallas,
    quantize,
)
register("qsigmoid", qsigmoid_ref, qsigmoid_pallas, qsigmoid)
# backward op pairs: the training path's VJPs resolve through these, so the
# whole BPTT step — not just inference — runs on registered kernels
register("floatsd_matmul_dx", matmul_dx_ref, matmul_dx_pallas, matmul_dx)
register("floatsd_matmul_dw", matmul_dw_ref, matmul_dw_pallas, matmul_dw)
register("lstm_cell_grad", lstm_cell_bwd_ref, lstm_cell_bwd_pallas, lstm_cell_grad)
