"""Oracle for the fused LSTM element-wise cell (paper Eqs. 5-6 + q-sigmoid)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core.fp8 import quantize_fp8
from ...core.qsigmoid import qsigmoid_raw

__all__ = ["lstm_cell_ref"]


def lstm_cell_ref(z, c_prev, quantized: bool = True, c_dtype=jnp.float16):
    """z: [B, 4H] pre-activations (i|f|g|o), c_prev: [B, H].

    Returns (h [B,H], c [B,H]) with the paper's quantization (FloatSD8
    two-region sigmoid on gates, FP8 tanh LUT outputs, FP16 cell state).
    ``c_dtype`` is the cell-state storage dtype (f16 per the paper; f32 for
    fp32-master policies, so the dispatched cell matches any policy).
    """
    h4 = z.shape[-1]
    h = h4 // 4
    zi, zf, zg, zo = jnp.split(z, 4, axis=-1)
    if quantized:
        i_t, f_t, o_t = qsigmoid_raw(zi), qsigmoid_raw(zf), qsigmoid_raw(zo)
        g_t = quantize_fp8(jnp.tanh(zg))
    else:
        i_t, f_t, o_t = jax.nn.sigmoid(zi), jax.nn.sigmoid(zf), jax.nn.sigmoid(zo)
        g_t = jnp.tanh(zg)
    c_t = (f_t * c_prev.astype(f_t.dtype) + i_t * g_t).astype(c_dtype)
    tc = quantize_fp8(jnp.tanh(c_t.astype(z.dtype))) if quantized else jnp.tanh(c_t.astype(z.dtype))
    h_t = o_t * tc
    return h_t.astype(z.dtype), c_t
