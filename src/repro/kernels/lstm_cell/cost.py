"""CostSpec for the fused LSTM cell (fwd + recompute-gates grad).

Elementwise over [B, H]; single-pass traffic on both backends (grid
``(B/bb, H/bh)``, every operand block visited exactly once).

MAC counts are the paper's Table-7 elementwise lanes: Eq. (5)
``c = f*c_prev + i*g`` is 2 MACs/element and Eq. (6) ``h = o*tanh(c)``
1 more — ``CELL_MACS_PER_ELEM = 3``. The FLOP constants cover the
non-MAC work: 3 quantized sigmoids (the paper's 42-boundary two-region
LUT: 42 compares + 1 select each) and 2 tanh evaluations (~8 ops of
polynomial/rational approximation each).

The backward recomputes the gates from the (z, c_prev) residuals (the
forward constant again) and then runs the product-rule chain: 6 more
MACs/element (d-gate products, dc recurrence) and ~30 ops of sigmoid'/
tanh' arithmetic.
"""
from __future__ import annotations

from ...obs.costmodel import Cost

__all__ = [
    "lstm_cell_cost", "lstm_cell_grad_cost",
    "CELL_MACS_PER_ELEM", "CELL_FLOPS_PER_ELEM",
    "GRAD_MACS_PER_ELEM", "GRAD_FLOPS_PER_ELEM",
]

QSIG_FLOPS = 43  # 42 region-boundary compares + 1 select (two-region LUT)
TANH_FLOPS = 8

CELL_MACS_PER_ELEM = 3  # Eq.5: f*c + i*g (2), Eq.6: o*tanh(c) (1)
CELL_FLOPS_PER_ELEM = 3 * QSIG_FLOPS + 2 * TANH_FLOPS + 2 * CELL_MACS_PER_ELEM

GRAD_MACS_PER_ELEM = CELL_MACS_PER_ELEM + 6  # recompute + product-rule chain
GRAD_FLOPS_PER_ELEM = CELL_FLOPS_PER_ELEM + 2 * 6 + 30  # + sigmoid'/tanh'


def _cell_cost(b: int, h: int, *, read_per_elem_h: int, write_per_elem_h: int,
               z_bytes: int, macs_per_elem: int, flops_per_elem: int,
               backend: str, padded=None, tiles=None) -> Cost:
    """Shared shape: z [b, 4h] plus ``read_per_elem_h`` bytes of [b, h]
    reads and ``write_per_elem_h`` bytes of per-element writes (dz counts
    under z_bytes-shaped writes handled by the callers)."""
    def passes(bb: int, hh: int) -> tuple[int, int]:
        elems = bb * hh
        return (
            elems * 4 * z_bytes + elems * read_per_elem_h,
            elems * write_per_elem_h,
        )

    r_exact, w_exact = passes(b, h)
    if backend == "ref":
        return Cost(
            flops=flops_per_elem * b * h,
            macs=macs_per_elem * b * h,
            hbm_read_bytes=r_exact,
            hbm_write_bytes=w_exact,
        )
    assert padded is not None and tiles is not None
    bp, hp = padded
    bb, bh = tiles
    r_pad, w_pad = passes(bp, hp)
    r_tile, w_tile = passes(bb, bh)
    return Cost(
        flops=flops_per_elem * bp * hp,
        macs=macs_per_elem * bp * hp,
        hbm_read_bytes=r_pad,
        hbm_write_bytes=w_pad,
        # input tiles + output tiles + the 4 regrouped f32 gate tiles
        vmem_bytes=r_tile + w_tile + 4 * bb * bh * 4,
        pad_waste_flops=flops_per_elem * (bp * hp - b * h),
        pad_waste_bytes=(r_pad - r_exact) + (w_pad - w_exact),
    )


def lstm_cell_cost(b: int, h: int, *, backend: str, z_bytes: int = 4,
                   c_in_bytes: int = 2, h_out_bytes: int = 4,
                   c_out_bytes: int = 2, padded=None, tiles=None) -> Cost:
    """z [b, 4h], c_prev [b, h] -> h [b, h], c [b, h] (c in ``c_dtype``,
    f16 by default — the serving state blob)."""
    return _cell_cost(
        b, h, read_per_elem_h=c_in_bytes,
        write_per_elem_h=h_out_bytes + c_out_bytes, z_bytes=z_bytes,
        macs_per_elem=CELL_MACS_PER_ELEM, flops_per_elem=CELL_FLOPS_PER_ELEM,
        backend=backend, padded=padded, tiles=tiles,
    )


def lstm_cell_grad_cost(b: int, h: int, *, backend: str, z_bytes: int = 4,
                        c_in_bytes: int = 2, dh_bytes: int = 4,
                        dc_bytes: int = 4, dz_bytes: int = 4,
                        dcp_bytes: int = 4, padded=None, tiles=None) -> Cost:
    """(z, c_prev, dh, dc) -> (dz [b, 4h], dc_prev [b, h])."""
    return _cell_cost(
        b, h, read_per_elem_h=c_in_bytes + dh_bytes + dc_bytes,
        write_per_elem_h=4 * dz_bytes + dcp_bytes, z_bytes=z_bytes,
        macs_per_elem=GRAD_MACS_PER_ELEM, flops_per_elem=GRAD_FLOPS_PER_ELEM,
        backend=backend, padded=padded, tiles=tiles,
    )
