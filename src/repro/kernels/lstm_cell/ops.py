"""jit'd wrapper for the fused LSTM cell element-wise stage."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import lstm_cell_pallas
from .ref import lstm_cell_ref

__all__ = ["lstm_cell"]


@functools.partial(jax.jit, static_argnames=("quantized", "use_kernel", "interpret"))
def lstm_cell(z, c_prev, *, quantized: bool = True, use_kernel: bool = True,
              interpret: bool = True):
    """Fused gates -> (h, c). Oracle fallback on indivisible shapes."""
    b, h4 = z.shape
    h = h4 // 4
    if not use_kernel or b % 8 or h % 128:
        return lstm_cell_ref(z, c_prev, quantized)
    bb = 8
    while b % bb == 0 and bb < 128:
        bb *= 2
    if b % bb:
        bb //= 2
    bh = 128
    while h % bh == 0 and bh < 512:
        bh *= 2
    if h % bh:
        bh //= 2
    return lstm_cell_pallas(z, c_prev, bb=bb, bh=bh, quantized=quantized,
                            interpret=interpret)
