"""Public wrapper for the fused LSTM cell element-wise stage.

Explicit-control entry; ``kernels.dispatch.lstm_cell`` is the policy-aware
one. Backend choices are recorded in ``kernels.dispatch.STATS`` (op
``"lstm_cell"``) — fallbacks are observable, never silent.
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import dispatch
from .kernel import lstm_cell_pallas
from .ref import lstm_cell_ref

__all__ = ["lstm_cell"]


def lstm_cell(z, c_prev, *, quantized: bool = True, c_dtype=jnp.float16,
              use_kernel: bool = True, interpret: bool = True):
    """Fused gates -> (h, c). Oracle fallback on indivisible shapes."""
    b, h4 = z.shape
    h = h4 // 4
    if not use_kernel or b % 8 or h % 128:
        dispatch.record(
            "lstm_cell", "ref",
            reason="use_kernel=False" if not use_kernel
            else f"fallback: shape {(b, h)} not tile-divisible",
        )
        return lstm_cell_ref(z, c_prev, quantized, c_dtype=c_dtype)
    dispatch.record(
        "lstm_cell", "pallas", interpret=interpret, reason="explicit wrapper"
    )
    bb, bh = dispatch.lstm_tiles(b, h)
    return lstm_cell_pallas(z, c_prev, bb=bb, bh=bh, quantized=quantized,
                            c_dtype=c_dtype, interpret=interpret)
