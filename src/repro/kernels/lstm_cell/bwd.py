"""Recompute-gates backward for the fused LSTM element-wise cell.

Residual contract (the memory side of the tentpole): the forward saves ONLY
``(z, c_prev)`` — the pre-activations and the incoming cell state. Everything
autodiff would have stacked per time step (three sigmoid outputs, their
quantized values, g, tanh(c), c_t, the products...) is recomputed here from
z in one fused pass. That cuts BPTT residual memory from ~13 [B,H]-sized
tensors per step to 5 ([B,4H] z + [B,H] c_prev) and turns the backward into
a single VMEM-resident kernel instead of a chain of HBM round-trips.

Gradient semantics match the straight-through estimators of the inline
training math (``nn.lstm.LSTMCell.step``):

  * forward VALUES are the quantized ones (two-region FloatSD8 sigmoid,
    FP8 tanh) — they appear in the product rule terms;
  * derivative FACTORS are the smooth ones (sigma', tanh') — the STE
    wrappers route gradients through the exact nonlinearity.

One recorded deviation from the autodiff oracle: the chain through the
``c_t.astype(c_dtype)`` cast stays f32 here (autodiff rounds the tanh-path
cotangent to fp16 before summing when the cell state is fp16). dz is then
strictly *more* precise than the oracle; the parity tests pin the fp32-cell
policies tight and the fp16-cell policies to the fp16 rounding envelope.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ...core.fp8 import FP8_E5M2, quantize_fp8
from ...core.qsigmoid import qsigmoid_raw
from .kernel import _SIG_GRID, _SIG_MID, _q_sigmoid, _regroup_gates

__all__ = ["lstm_cell_bwd_ref", "lstm_cell_bwd_pallas"]


def lstm_cell_bwd_ref(z, c_prev, dh, dc, quantized: bool = True,
                      c_dtype=jnp.float16):
    """z: [B, 4H] (i|f|g|o), c_prev: [B, H], dh: [B, H] (cotangent of h_t),
    dc: [B, H] (cotangent of c_t from the carry). Returns (dz [B,4H] f32,
    dc_prev [B,H] in c_prev.dtype)."""
    h = c_prev.shape[-1]
    z32 = z.astype(jnp.float32)
    zi, zf, zg, zo = jnp.split(z32, 4, axis=-1)
    si, sf, so = jax.nn.sigmoid(zi), jax.nn.sigmoid(zf), jax.nn.sigmoid(zo)
    tg = jnp.tanh(zg)
    if quantized:
        i_t, f_t, o_t = qsigmoid_raw(zi), qsigmoid_raw(zf), qsigmoid_raw(zo)
        g_t = quantize_fp8(tg, FP8_E5M2)
    else:
        i_t, f_t, o_t, g_t = si, sf, so, tg
    c_prev32 = c_prev.astype(jnp.float32)
    # recompute the EXACT forward cell state, including the storage rounding
    c32 = (f_t * c_prev32 + i_t * g_t).astype(c_dtype).astype(jnp.float32)
    tanh_c = jnp.tanh(c32)
    tc = quantize_fp8(tanh_c, FP8_E5M2) if quantized else tanh_c

    dh32 = dh.astype(jnp.float32)
    dc32 = dc.astype(jnp.float32)
    dzo = (dh32 * tc) * so * (1.0 - so)
    dct = dc32 + dh32 * o_t * (1.0 - tanh_c * tanh_c)
    dzf = (dct * c_prev32) * sf * (1.0 - sf)
    dzi = (dct * g_t) * si * (1.0 - si)
    dzg = (dct * i_t) * (1.0 - tg * tg)
    dz = jnp.concatenate([dzi, dzf, dzg, dzo], axis=-1)
    dc_prev = (dct * f_t).astype(c_prev.dtype)
    del h
    return dz, dc_prev


def lstm_cell_bwd_kernel(z_ref, c_ref, dh_ref, dc_ref, mid_ref, grid_ref,
                         dz_ref, dcp_ref, *, quantized: bool, c_dtype):
    h = c_ref.shape[-1]
    z = z_ref[...].astype(jnp.float32)
    zi, zf, zg, zo = (z[:, i * h : (i + 1) * h] for i in range(4))
    si, sf, so = jax.nn.sigmoid(zi), jax.nn.sigmoid(zf), jax.nn.sigmoid(zo)
    tg = jnp.tanh(zg)
    if quantized:
        mid = mid_ref[0, :]
        grid = grid_ref[0, :]
        i_t = _q_sigmoid(zi, mid, grid)
        f_t = _q_sigmoid(zf, mid, grid)
        o_t = _q_sigmoid(zo, mid, grid)
        g_t = tg.astype(jnp.float8_e5m2).astype(jnp.float32)
    else:
        i_t, f_t, o_t, g_t = si, sf, so, tg
    c_prev = c_ref[...].astype(jnp.float32)
    c32 = (f_t * c_prev + i_t * g_t).astype(c_dtype).astype(jnp.float32)
    tanh_c = jnp.tanh(c32)
    tc = tanh_c.astype(jnp.float8_e5m2).astype(jnp.float32) if quantized else tanh_c

    dh = dh_ref[...].astype(jnp.float32)
    dc = dc_ref[...].astype(jnp.float32)
    dzo = (dh * tc) * so * (1.0 - so)
    dct = dc + dh * o_t * (1.0 - tanh_c * tanh_c)
    dzf = (dct * c_prev) * sf * (1.0 - sf)
    dzi = (dct * g_t) * si * (1.0 - si)
    dzg = (dct * i_t) * (1.0 - tg * tg)
    dz_ref[...] = jnp.concatenate([dzi, dzf, dzg, dzo], axis=-1).astype(dz_ref.dtype)
    dcp_ref[...] = (dct * f_t).astype(dcp_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bb", "bh", "quantized", "c_dtype", "interpret")
)
def lstm_cell_bwd_pallas(
    z, c_prev, dh, dc, *, bb: int = 128, bh: int = 512, quantized: bool = True,
    c_dtype=jnp.float16, interpret: bool = False,
):
    """Fused recompute-gates backward. z: [B, 4H], c_prev/dh/dc: [B, H] ->
    (dz [B, 4H] f32, dc_prev [B, H] in c_prev.dtype)."""
    b, h4 = z.shape
    h = h4 // 4
    bb, bh = min(bb, b), min(bh, h)
    assert b % bb == 0 and h % bh == 0, (b, h, bb, bh)
    grid = (b // bb, h // bh)
    nm = _SIG_MID.size

    dz_g, dcp = pl.pallas_call(
        functools.partial(lstm_cell_bwd_kernel, quantized=quantized,
                          c_dtype=c_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, 4 * bh), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
            pl.BlockSpec((1, nm), lambda i, j: (0, 0)),
            pl.BlockSpec((1, nm + 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, 4 * bh), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, 4 * h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), c_prev.dtype),
        ],
        interpret=interpret,
    )(
        _regroup_gates(z, h, bh),
        c_prev,
        dh,
        dc,
        jnp.asarray(_SIG_MID).reshape(1, -1),
        jnp.asarray(_SIG_GRID).reshape(1, -1),
    )
    return _ungroup_gates(dz_g, h, bh), dcp


def _ungroup_gates(zg, h, bh):
    """Inverse of ``kernel._regroup_gates``: blocked (jblock, gate, bh)
    columns back to the contiguous i|f|g|o gate layout."""
    b = zg.shape[0]
    zz = zg.reshape(b, h // bh, 4, bh)  # [B, jblock, gate, bh]
    zz = jnp.swapaxes(zz, 1, 2)  # [B, gate, jblock, bh]
    return zz.reshape(b, 4 * h)
