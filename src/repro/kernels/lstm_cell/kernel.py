"""Pallas TPU kernel: fused LSTM neuron element-wise stage (paper Fig. 9).

After the PE array produces the four gate pre-activations z = [i|f|g|o]
(the FloatSD8 matmuls), the neuron circuit applies: sigmoid LUT (two-region
FloatSD8 quantized, Eqs. 7-8), tanh LUT (FP8 output), the two element-wise
MACs of Eqs. (5)-(6), and the FP16 cell-state write-back. This kernel fuses
all of that into one VMEM pass — one read of z/c_prev, one write of h/c —
instead of the ~10 HBM round-trips the unfused XLA graph makes.

The FloatSD8 quantization of sigma(x) uses the same compare-count + LUT
trick as the quantize kernel, restricted to the 42-value non-positive branch
(paper: 'the depth of the LUT can be reduced').
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ...core import floatsd, qsigmoid

__all__ = ["lstm_cell_kernel", "lstm_cell_pallas"]

# non-negative representable values at the sigmoid LUT bias, in (0, 0.5]
_SIG_GRID = qsigmoid.sigmoid_lut_values().astype(np.float32)  # 43 incl. 0
_SIG_MID = ((_SIG_GRID[1:] + _SIG_GRID[:-1]) / 2).astype(np.float32)


def _q_sigmoid(x, mid, grid):
    """Two-region FloatSD8 sigmoid via compare-count on the 42-entry LUT."""
    s_neg = jax.nn.sigmoid(-jnp.abs(x))  # in (0, 0.5]
    gidx = jnp.sum((s_neg[..., None] > mid[None, None, :]).astype(jnp.int32), -1)
    q = jnp.take(grid, gidx)
    return jnp.where(x > 0, 1.0 - q, q)


def _q_tanh_fp8(x):
    t = jnp.tanh(x)
    return t.astype(jnp.float8_e5m2).astype(x.dtype)


def lstm_cell_kernel(z_ref, c_ref, mid_ref, grid_ref, h_ref, c_out_ref, *, quantized: bool):
    h = c_ref.shape[-1]
    z = z_ref[...].astype(jnp.float32)
    zi, zf, zg, zo = (z[:, i * h : (i + 1) * h] for i in range(4))
    if quantized:
        mid = mid_ref[0, :]
        grid = grid_ref[0, :]
        i_t = _q_sigmoid(zi, mid, grid)
        f_t = _q_sigmoid(zf, mid, grid)
        o_t = _q_sigmoid(zo, mid, grid)
        g_t = _q_tanh_fp8(zg)  # tanh LUT emitting FP8
    else:
        i_t, f_t, o_t = jax.nn.sigmoid(zi), jax.nn.sigmoid(zf), jax.nn.sigmoid(zo)
        g_t = jnp.tanh(zg)
    c_prev = c_ref[...].astype(jnp.float32)
    c_t = (f_t * c_prev + i_t * g_t).astype(c_out_ref.dtype)  # Eq. 5 state
    tc = jnp.tanh(c_t.astype(jnp.float32))
    if quantized:
        tc = tc.astype(jnp.float8_e5m2).astype(jnp.float32)
    h_t = o_t * tc  # Eq. 6
    h_ref[...] = h_t.astype(h_ref.dtype)
    c_out_ref[...] = c_t


@functools.partial(
    jax.jit, static_argnames=("bb", "bh", "quantized", "c_dtype", "interpret")
)
def lstm_cell_pallas(
    z, c_prev, *, bb: int = 128, bh: int = 512, quantized: bool = True,
    c_dtype=jnp.float16, interpret: bool = False,
):
    """z: [B, 4H], c_prev: [B, H] -> (h [B, H] z.dtype, c [B, H] c_dtype)."""
    b, h4 = z.shape
    h = h4 // 4
    bb, bh = min(bb, b), min(bh, h)
    assert b % bb == 0 and h % bh == 0, (b, h, bb, bh)
    grid = (b // bb, h // bh)
    nm = _SIG_MID.size

    return pl.pallas_call(
        functools.partial(lstm_cell_kernel, quantized=quantized),
        grid=grid,
        in_specs=[
            # gate-interleaved columns: each (i,j) tile needs the 4 gate
            # slices of its h-block — index_map picks the j-th h-block of
            # each gate via a strided custom block
            pl.BlockSpec((bb, 4 * bh), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
            pl.BlockSpec((1, nm), lambda i, j: (0, 0)),
            pl.BlockSpec((1, nm + 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h), z.dtype),
            jax.ShapeDtypeStruct((b, h), c_dtype),
        ],
        interpret=interpret,
    )(
        _regroup_gates(z, h, bh),
        c_prev,
        jnp.asarray(_SIG_MID).reshape(1, -1),
        jnp.asarray(_SIG_GRID).reshape(1, -1),
    )


def _regroup_gates(z, h, bh):
    """[B, i|f|g|o] -> blocks where the j-th 4*bh column group holds the
    j-th bh-slice of each gate (so one BlockSpec tile sees all 4 gates)."""
    b = z.shape[0]
    zz = z.reshape(b, 4, h // bh, bh)  # [B, gate, jblock, bh]
    zz = jnp.swapaxes(zz, 1, 2)  # [B, jblock, gate, bh]
    return zz.reshape(b, 4 * h)
