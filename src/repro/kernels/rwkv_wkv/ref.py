"""Pure-jnp oracle for the chunked wkv kernel: the per-token recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["wkv_ref"]


def wkv_ref(r, k, v, w, u):
    """r/k/w: [BH, S, K], v: [BH, S, V], u: [BH, K] -> [BH, S, V].

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    """
    bh, s, kk = r.shape
    vv = v.shape[-1]

    def step(st, t):
        rt, kt, vt, wt = t
        kv = jnp.einsum("bk,bv->bkv", kt.astype(jnp.float32), vt.astype(jnp.float32))
        y = jnp.einsum("bk,bkv->bv", rt.astype(jnp.float32),
                       st + u[:, :, None] * kv)
        return st * wt[..., None] + kv, y

    sw = lambda t: jnp.swapaxes(t, 0, 1)
    s0 = jnp.zeros((bh, kk, vv), jnp.float32)
    _, ys = jax.lax.scan(step, s0, (sw(r), sw(k), sw(v), sw(w.astype(jnp.float32))))
    return jnp.swapaxes(ys, 0, 1).astype(r.dtype)
