"""jit'd public wrapper for the chunked wkv kernel."""
from __future__ import annotations

import functools

import jax

from .kernel import wkv_pallas
from .ref import wkv_ref

__all__ = ["wkv"]


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel", "interpret"))
def wkv(r, k, v, w, u, *, chunk: int = 16, use_kernel: bool = True,
        interpret: bool = True):
    """[BH, S, K/V] chunked wkv. Oracle fallback on indivisible shapes."""
    s = r.shape[1]
    if not use_kernel or s % chunk:
        return wkv_ref(r, k, v, w, u)
    return wkv_pallas(r, k, v, w, u, chunk=chunk, interpret=interpret)
