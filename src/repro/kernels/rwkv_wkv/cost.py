"""CostSpec for the chunked RWKV-6 wkv kernel.

Shapes: r/k/w [BH, S, K], v [BH, S, V], u [BH, K] -> y [BH, S, V].

  * **ref** (per-token scan): each step builds the k v^T outer product
    (K*V MACs), contracts r against the state (K*V MACs), and applies the
    diagonal decay + bonus (~4 more ops per state element). Traffic is
    single-pass over every operand.
  * **pallas** (chunk L resident in VMEM, grid ``(BH, S/L)``): the
    inter-chunk term and the state update are two L x K x V contractions
    per chunk, plus the intra-chunk attention tile — L*L*K for the decay-
    weighted A matrix and L*L*V for A @ v. Traffic is the same single
    pass (every block visited once; the [K, V] state never leaves VMEM —
    that is the kernel's point), but the working set now includes the
    f32 state scratch and the [L, L, K] decay intermediate.
"""
from __future__ import annotations

from ...obs.costmodel import Cost

__all__ = ["wkv_cost"]


def wkv_cost(bh: int, s: int, dk: int, dv: int, *, backend: str,
             chunk: int = 16, elem_bytes: int = 4) -> Cost:
    io = Cost(
        hbm_read_bytes=(bh * s * (3 * dk + dv) + bh * dk) * elem_bytes,
        hbm_write_bytes=bh * s * dv * elem_bytes,
    )
    if backend == "ref":
        macs = 2 * bh * s * dk * dv
        return Cost(
            flops=2 * macs + 4 * bh * s * dk * dv + 2 * bh * s * dk,
            macs=macs,
            hbm_read_bytes=io.hbm_read_bytes,
            hbm_write_bytes=io.hbm_write_bytes,
        )
    nchunks = s // chunk
    macs = bh * nchunks * (
        2 * chunk * dk * dv  # inter-chunk y and the state update
        + chunk * chunk * (dk + dv)  # intra tile: A build + A @ v
    )
    # exp/cumsum decay arithmetic: the [L, L, K] ldiff tile + per-row terms
    exp_flops = bh * nchunks * (3 * chunk * chunk * dk + 6 * chunk * dk)
    return Cost(
        flops=2 * macs + exp_flops,
        macs=macs,
        hbm_read_bytes=io.hbm_read_bytes,
        hbm_write_bytes=io.hbm_write_bytes,
        vmem_bytes=(
            chunk * (3 * dk + dv) * elem_bytes  # r/k/w + v chunk tiles
            + dk * elem_bytes  # u
            + dk * dv * 4  # f32 state scratch
            + chunk * chunk * dk * 4  # ldiff/A intermediate
            + chunk * dv * (4 + elem_bytes)  # y accumulator + out tile
        ),
    )
