"""Pallas TPU kernel: chunked RWKV-6 wkv forward (state resident in VMEM).

The XLA chunked path (nn/rwkv.py `_wkv_chunked`, hillclimb #3) still
materializes its per-chunk [L, L, K] decay tile and the running state to
HBM at fusion boundaries; this kernel keeps BOTH in VMEM. Grid =
(batch*heads, S/L) with the chunk axis sequential, so the [K, V] state
scratch carries across chunk steps — same discipline as the flash kernel's
(m, l, acc) and the paper PE's output-stationary accumulator.

Math (per chunk, b = inclusive cumsum of log w):
  y_t  = (r_t . e^{b_{t-1}}) S
       + sum_{i<t} (sum_k r_tk k_ik e^{b_{t-1,k}-b_{i,k}}) v_i
       + (r_t . u . k_t) v_t
  S'   = diag(e^{b_{L-1}}) S + sum_i diag(e^{b_{L-1}-b_i}) k_i v_i^T
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["wkv_chunk_kernel", "wkv_pallas"]


def wkv_chunk_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_ref, *, L: int, K: int, V: int):
    cstep = pl.program_id(1)

    @pl.when(cstep == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)  # [L, K]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # [L, V]
    lw = jnp.log(jnp.maximum(w_ref[0].astype(jnp.float32), 1e-38))
    b = jnp.cumsum(lw, axis=0)  # [L, K] inclusive
    bprev = b - lw
    blast = b[L - 1]

    s = s_ref[...]  # [K, V]
    q_in = r * jnp.exp(bprev)
    y_inter = jax.lax.dot_general(
        q_in, s, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [L, V]
    # intra tile: A[t,i] = sum_k r_tk k_ik exp(b_{t-1,k} - b_{i,k}), i < t
    ldiff = bprev[:, None, :] - b[None, :, :]  # [L, L, K]
    a = jnp.sum(
        r[:, None, :] * k[None, :, :] * jnp.exp(jnp.minimum(ldiff, 0.0)), axis=-1
    )
    mask = (
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
        < jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    )
    a = jnp.where(mask, a, 0.0)
    y_intra = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    y_bonus = jnp.sum(r * u_ref[0] * k, axis=-1, keepdims=True) * v
    y_ref[0] = (y_inter + y_intra + y_bonus).astype(y_ref.dtype)

    kd = k * jnp.exp(blast[None, :] - b)  # [L, K]
    s_ref[...] = s * jnp.exp(blast)[:, None] + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(
    r: jax.Array,  # [BH, S, K]
    k: jax.Array,  # [BH, S, K]
    v: jax.Array,  # [BH, S, V]
    w: jax.Array,  # [BH, S, K] decay in (0, 1)
    u: jax.Array,  # [BH, K] bonus
    *,
    chunk: int = 16,
    interpret: bool = False,
):
    bh, s, kk = r.shape
    vv = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    grid = (bh, s // chunk)
    return pl.pallas_call(
        functools.partial(wkv_chunk_kernel, L=chunk, K=kk, V=vv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, kk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, kk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, vv), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, chunk, kk), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, kk), lambda h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, vv), lambda h, c: (h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, vv), r.dtype),
        scratch_shapes=[_vmem((kk, vv), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
