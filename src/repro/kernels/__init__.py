# Custom-kernel layer. Each op package ships <name>/kernel.py (Pallas) +
# ops.py (explicit wrapper) + ref.py (jnp oracle). `dispatch.py` is the
# execution backend: a registry + resolver that routes the nn/serving hot
# paths to the Pallas kernels (compiled on TPU, interpret elsewhere) or the
# oracles, with shape padding and recorded fallbacks. See kernels/README.md.
