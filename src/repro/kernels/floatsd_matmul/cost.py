"""CostSpec for the FloatSD8 matmul family (fwd / dx / dw).

One generic matmul-shaped model covers all three ops; they differ only in
which operand is the 1-byte packed codes, the contraction axis, and the
extra per-output work (the dw kernel's in-flush FP8 quantizer).

Traffic model (see the kernel docstrings for the grids):

  * **ref** — each operand read exactly once, the output written once:
    ``m*c*a_bytes + c*n*b_bytes + bias`` read, ``m*n*o_bytes`` written.
    The oracle's decode intermediate is XLA-fusible and excluded (the
    CostSpec contract), so ref predictions equal the ndarray ``nbytes``
    the dispatch actually touches — tolerance 0, tested.
  * **pallas** — output-stationary grid ``(M/bm, N/bn, C/bk)`` with the
    contraction innermost: the A tile is re-fetched once per N-block
    (``N/bn`` visits over the full A), the B tile once per M-block
    (``M/bm`` visits), the output written once. Padded dims are charged
    in full; the unique-byte and FLOP deltas vs the exact shape land in
    ``pad_waste_*``.
  * **VMEM** per grid step: A tile + B tile (+ the decoded-code tile in
    ``compute_dtype`` when the kernel decodes in VMEM) + the f32
    accumulator + the output tile.

FLOP constants: 2 FLOPs/MAC; ``DECODE_FLOPS_PER_CODE`` covers the
FloatSD8 code -> value unpack (mantissa LUT gather + exponent shift);
``FP8_QUANT_FLOPS_PER_OUT`` the dw flush's clamp+round.
"""
from __future__ import annotations

from ...obs.costmodel import Cost

__all__ = [
    "matmul_like_cost", "matmul_fwd_cost", "matmul_dx_cost",
    "matmul_dw_cost", "DECODE_FLOPS_PER_CODE", "FP8_QUANT_FLOPS_PER_OUT",
]

DECODE_FLOPS_PER_CODE = 4  # mask+gather mantissa, shift by (e - bias), scale
FP8_QUANT_FLOPS_PER_OUT = 3  # clamp to +-57344, round-to-nearest-even cast


def matmul_like_cost(
    m: int, c: int, n: int, *, backend: str,
    a_bytes: int = 4, b_bytes: int = 1, o_bytes: int = 4,
    bias_bytes: int = 4, compute_bytes: int = 4, decode: bool = True,
    quant_flops_per_out: int = 0,
    padded: tuple[int, int, int] | None = None,
    tiles: tuple[int, int, int] | None = None,
) -> Cost:
    """[m, c] x [c, n] -> [m, n]; ``c`` is the contraction axis.

    ``padded``/``tiles`` are required on the pallas backend:
    ``padded = (mp, cp, np)`` and ``tiles = (bm, bn, bk)`` with ``bm | mp``,
    ``bn | np``, ``bk | cp`` — exactly what ``dispatch.matmul_tiles``
    resolved for the (padded) call."""
    macs_exact = m * c * n
    if backend == "ref":
        flops = 2 * macs_exact + quant_flops_per_out * m * n
        if decode:
            flops += DECODE_FLOPS_PER_CODE * c * n
        return Cost(
            flops=flops,
            macs=macs_exact,
            hbm_read_bytes=m * c * a_bytes + c * n * b_bytes + bias_bytes,
            hbm_write_bytes=m * n * o_bytes,
        )
    assert padded is not None and tiles is not None, (
        "pallas matmul cost needs the padded dims and tile config"
    )
    mp, cp, np_ = padded
    bm, bn, bk = tiles
    macs = mp * cp * np_
    b_fetches = (mp // bm) * cp * np_  # B re-fetched once per M-block
    flops = 2 * macs + quant_flops_per_out * mp * np_
    if decode:
        flops += DECODE_FLOPS_PER_CODE * b_fetches  # decode happens per fetch
    read = (np_ // bn) * mp * cp * a_bytes + b_fetches * b_bytes + bias_bytes
    write = mp * np_ * o_bytes
    vmem = (
        bm * bk * a_bytes
        + bk * bn * b_bytes
        + (bk * bn * compute_bytes if decode else 0)
        + bm * bn * 4  # f32 accumulator scratch
        + bm * bn * o_bytes
    )
    return Cost(
        flops=flops,
        macs=macs,
        hbm_read_bytes=read,
        hbm_write_bytes=write,
        vmem_bytes=vmem,
        pad_waste_flops=2 * (macs - macs_exact),
        pad_waste_bytes=(
            (mp * cp - m * c) * a_bytes
            + (cp * np_ - c * n) * b_bytes
            + (mp * np_ - m * n) * o_bytes
        ),
    )


def matmul_fwd_cost(m: int, k: int, n: int, *, backend: str,
                    x_bytes: int = 4, out_bytes: int = 4,
                    compute_bytes: int = 4, codes_bytes: int = 1,
                    padded=None, tiles=None) -> Cost:
    """x [m, k] @ decode(codes [k, n]) -> [m, n]."""
    return matmul_like_cost(
        m, k, n, backend=backend, a_bytes=x_bytes, b_bytes=codes_bytes,
        o_bytes=out_bytes, compute_bytes=compute_bytes, decode=True,
        padded=padded, tiles=tiles,
    )


def matmul_dx_cost(m: int, n: int, k: int, *, backend: str,
                   g_bytes: int = 4, out_bytes: int = 4,
                   padded=None, tiles=None) -> Cost:
    """g [m, n] @ decode(codes [k, n])^T -> dx [m, k]; contraction over n.
    The pallas path reuses the forward kernel on the transposed codes, so
    the model is the forward model with (c, n) = (n, k)."""
    return matmul_like_cost(
        m, n, k, backend=backend, a_bytes=g_bytes, b_bytes=1,
        o_bytes=out_bytes, compute_bytes=4, decode=True,
        padded=padded, tiles=tiles,
    )


def matmul_dw_cost(k: int, m: int, n: int, *, backend: str,
                   x_bytes: int = 4, g_bytes: int = 4, out_bytes: int = 4,
                   quant: bool = True, padded=None, tiles=None) -> Cost:
    """x [m, k]^T @ g [m, n] -> dw [k, n]; contraction over m (the grid is
    ``(k/bm, n/bn, m/bk)`` — M innermost). Both operands are dense f32;
    ``quant`` adds the in-flush FP8 quantizer's per-output work."""
    return matmul_like_cost(
        k, m, n, backend=backend, a_bytes=x_bytes, b_bytes=g_bytes,
        o_bytes=out_bytes, bias_bytes=0, decode=False,
        quant_flops_per_out=FP8_QUANT_FLOPS_PER_OUT if quant else 0,
        padded=padded, tiles=tiles,
    )
