"""Pallas TPU kernel: fused FloatSD8-decode + matmul.

The paper's MAC multiplies FP8 activations by FloatSD8 weights using two
shifted partial products. The TPU-native adaptation (DESIGN.md §3.1): weights
travel HBM->VMEM as 1-byte codes (2x less bandwidth than bf16), are decoded
*in VMEM* by the VPU (a 32-entry mantissa LUT gather + exp2 scale — the
vector-unit analogue of the two shifts), and feed the MXU in bf16 with f32
accumulation.

Grid (M/bm, N/bn, K/bk); K is the innermost (sequential) axis so the f32
accumulator tile stays resident in VMEM across K steps (output-stationary,
exactly like the paper's PE). Block sizes default to MXU-aligned multiples
of 128; VMEM working set = bm*bk (x) + bk*bn (codes) + bm*bn*4 (acc)
= 256*512*1 + 512*256*1 + 256*256*4 ~= 0.5 MB « 16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ...core import floatsd

__all__ = ["floatsd_matmul_kernel", "floatsd_matmul_pallas"]

# 32-entry mantissa LUT (index 31 unused -> 0)
_LUT = np.zeros(32, np.float32)
_LUT[:31] = floatsd.MANTISSA_VALUES


def floatsd_matmul_kernel(
    x_ref, codes_ref, bias_ref, lut_ref, out_ref, acc_ref, *, n_k: int,
    compute_dtype=jnp.bfloat16,
):
    """One (bm x bn) output tile; accumulates over the K grid axis.

    x_ref:     [bm, bk]  activation tile (fp8/bf16/f32 storage)
    codes_ref: [bk, bn]  uint8 FloatSD8 codes
    bias_ref:  [1, 1]    int32 per-tensor exponent bias
    lut_ref:   [1, 32]   f32 mantissa LUT (pallas kernels take constants
                         as inputs)
    acc_ref:   [bm, bn]  f32 VMEM accumulator scratch

    ``compute_dtype`` is the MXU issue dtype: bf16 (default, full MXU rate)
    or f32 (bit-tight vs the oracle — the dispatch layer's parity mode).
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = codes_ref[...].astype(jnp.int32)
    m_idx = codes & 0x1F
    e = (codes >> 5).astype(jnp.float32)
    mant = jnp.take(lut_ref[0, :], m_idx)  # VPU gather, 32-entry table
    scale = jnp.exp2(e + bias_ref[0, 0].astype(jnp.float32))
    w = (mant * scale).astype(compute_dtype)  # decoded tile stays in VMEM

    x = x_ref[...].astype(compute_dtype)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _vmem_scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "compute_dtype", "interpret"),
)
def floatsd_matmul_pallas(
    x: jax.Array,  # [M, K]
    codes: jax.Array,  # [K, N] uint8
    bias: jax.Array,  # scalar int32
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    out_dtype=jnp.float32,
    compute_dtype=jnp.bfloat16,
    interpret: bool = False,
):
    m, k = x.shape
    k2, n = codes.shape
    assert k == k2, (x.shape, codes.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(
            floatsd_matmul_kernel, n_k=n_k, compute_dtype=compute_dtype
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((1, 1), lambda i, j, s: (0, 0)),
            pl.BlockSpec((1, 32), lambda i, j, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, jnp.reshape(bias.astype(jnp.int32), (1, 1)),
      jnp.asarray(_LUT).reshape(1, 32))
