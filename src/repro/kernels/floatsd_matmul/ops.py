"""jit'd public wrapper for the FloatSD8 matmul kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import floatsd
from .kernel import floatsd_matmul_pallas
from .ref import floatsd_matmul_ref

__all__ = ["floatsd_matmul", "floatsd_dense_forward"]


@functools.partial(jax.jit, static_argnames=("out_dtype", "use_kernel", "interpret"))
def floatsd_matmul(
    x, codes, bias, *, out_dtype=jnp.float32, use_kernel: bool = True,
    interpret: bool = True,
):
    """x [M,K] @ decode(codes [K,N]) -> [M,N].

    `interpret=True` is the CPU-validation mode; on real TPU pass
    interpret=False. Falls back to the jnp oracle when `use_kernel=False`
    (or for shapes the tiling doesn't divide).
    """
    m, k = x.shape
    _, n = codes.shape
    if not use_kernel or (m % 8 or n % 128 or k % 128):
        return floatsd_matmul_ref(x, codes, bias, out_dtype)
    bm = max(8, min(256, m))
    bn = min(256, n)
    bk = min(512, k)
    while m % bm:
        bm //= 2
    while n % bn:
        bn //= 2
    while k % bk:
        bk //= 2
    return floatsd_matmul_pallas(
        x, codes, bias, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
        interpret=interpret,
    )


def floatsd_dense_forward(x, w_f32, *, interpret: bool = True):
    """Encode-then-multiply convenience: the serving path where weights are
    stored pre-encoded. Returns (y, codes, bias)."""
    codes, bias = floatsd.encode(w_f32)
    y = floatsd_matmul(x, codes, bias, interpret=interpret)
    return y, codes, bias
