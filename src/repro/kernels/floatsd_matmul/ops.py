"""Public wrapper for the FloatSD8 matmul kernel.

This is the explicit-control entry (callers pick kernel/oracle and the
interpret mode); ``kernels.dispatch.matmul`` is the policy-aware entry the
nn/serving hot paths use. Either way the backend that actually ran is
recorded in ``kernels.dispatch.STATS`` under op ``"floatsd_matmul"`` — the
old silent oracle fallback is now observable and asserted on in tests.
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import dispatch
from ...core import floatsd
from .kernel import floatsd_matmul_pallas
from .ref import floatsd_matmul_ref

__all__ = ["floatsd_matmul", "floatsd_dense_forward"]


def floatsd_matmul(
    x, codes, bias, *, out_dtype=jnp.float32, use_kernel: bool = True,
    interpret: bool = True,
):
    """x [M,K] @ decode(codes [K,N]) -> [M,N].

    `interpret=True` is the CPU-validation mode; on real TPU pass
    interpret=False. Falls back to the jnp oracle when `use_kernel=False`
    or for shapes the tiling doesn't divide (recorded, never silent).
    """
    m, k = x.shape
    _, n = codes.shape
    if not use_kernel or (m % 8 or n % 128 or k % 128):
        dispatch.record(
            "floatsd_matmul", "ref",
            reason="use_kernel=False" if not use_kernel
            else f"fallback: shape {(m, k, n)} not tile-divisible",
        )
        return floatsd_matmul_ref(x, codes, bias, out_dtype)
    dispatch.record(
        "floatsd_matmul", "pallas", interpret=interpret, reason="explicit wrapper"
    )
    bm, bn, bk = dispatch.matmul_tiles(m, n, k)
    return floatsd_matmul_pallas(
        x, codes, bias, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
        interpret=interpret,
    )


def floatsd_dense_forward(x, w_f32, *, interpret: bool = True):
    """Encode-then-multiply convenience: the serving path where weights are
    stored pre-encoded. Returns (y, codes, bias)."""
    codes, bias = floatsd.encode(w_f32)
    y = floatsd_matmul(x, codes, bias, interpret=interpret)
    return y, codes, bias
