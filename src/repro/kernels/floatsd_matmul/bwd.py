"""Backward implementations for the FloatSD8 matmul (the training hot path).

Forward: y = x @ decode(codes). The VJP splits into two ops with different
precision contracts (paper §III-D):

  dx = g @ decode(codes)^T        — f32 issue + f32 accumulation: the
       activation-gradient path feeds the recurrent BPTT chain, so it runs
       the *precise* datapath; the FP8 activation-gradient quantization
       happens at the act_quant STE nodes, not here.
  dw = fp8(x^T @ g)               — f32 accumulation, then the paper's FP8
       weight-gradient quantizer applied AT THE FLUSH, inside the kernel:
       the gradient leaves VMEM already on the FP8 grid, so train_state
       no longer runs a separate full-tree ``grad_quant`` pass.

``dx`` reuses the forward fused decode+matmul kernel on transposed codes
(decode is element-wise: decode(codes)^T == decode(codes^T), and transposing
the 1-byte codes is 4x cheaper than transposing a decoded f32 tensor). ``dw``
is a dedicated kernel: both operands are dense floats (no decode), and the
FP8 grid-snap rides the accumulator flush for free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core.fp8 import FP8_E5M2, quantize_fp8
from .kernel import floatsd_matmul_pallas
from .ref import floatsd_matmul_ref

__all__ = [
    "matmul_dx_ref", "matmul_dx_pallas", "matmul_dw_ref", "matmul_dw_pallas",
]


# ---------------------------------------------------------------------------
# dx: g [M, N] x decode(codes [K, N])^T -> [M, K]
# ---------------------------------------------------------------------------


def matmul_dx_ref(g: jax.Array, codes: jax.Array, bias) -> jax.Array:
    """Oracle: g @ decode(codes)^T in f32 (precise datapath)."""
    return floatsd_matmul_ref(g, codes.T, bias, out_dtype=jnp.float32)


def matmul_dx_pallas(g: jax.Array, codes: jax.Array, bias, *, bm: int,
                     bn: int, bk: int, interpret: bool = False) -> jax.Array:
    """The forward fused decode-in-VMEM kernel on transposed codes, f32
    issue dtype (the gradient path is always precise)."""
    return floatsd_matmul_pallas(
        g, codes.T, bias, bm=bm, bn=bn, bk=bk, out_dtype=jnp.float32,
        compute_dtype=jnp.float32, interpret=interpret,
    )


# ---------------------------------------------------------------------------
# dw: x [M, K]^T x g [M, N] -> fp8-quantized f32 [K, N]
# ---------------------------------------------------------------------------


def matmul_dw_ref(x: jax.Array, g: jax.Array, quant: bool = True) -> jax.Array:
    """Oracle: x^T @ g with f32 accumulation, FP8-e5m2 grid snap on the way
    out (fake-quant: f32 storage, FP8 values — the optimizer consumes it
    directly)."""
    dw = jnp.dot(
        x.astype(jnp.float32).T, g.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return quantize_fp8(dw, FP8_E5M2) if quant else dw


def matmul_dw_kernel(xt_ref, g_ref, out_ref, acc_ref, *, n_k: int, quant: bool):
    """One (bk_w x bn) dw tile, accumulating over the M (batch*time) grid
    axis; the flush snaps the f32 accumulator to the FP8-e5m2 grid."""
    m_step = pl.program_id(2)

    @pl.when(m_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xt = xt_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.dot(xt, g, preferred_element_type=jnp.float32)

    @pl.when(m_step == n_k - 1)
    def _flush():
        acc = acc_ref[...]
        if quant:
            # saturating FP8 e5m2 round-trip == core.fp8.quantize_fp8
            acc = jnp.clip(acc, -57344.0, 57344.0)
            acc = acc.astype(jnp.float8_e5m2).astype(jnp.float32)
        out_ref[...] = acc.astype(out_ref.dtype)


def _vmem_scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "quant", "interpret")
)
def matmul_dw_pallas(
    x: jax.Array,  # [M, K]
    g: jax.Array,  # [M, N]
    *,
    bm: int = 256,  # tile over K (dw rows)
    bn: int = 256,  # tile over N (dw cols)
    bk: int = 512,  # tile over M (the contraction axis here)
    quant: bool = True,
    interpret: bool = False,
):
    m, k = x.shape
    m2, n = g.shape
    assert m == m2, (x.shape, g.shape)
    xt = x.T  # [K, M]
    bm, bn, bk = min(bm, k), min(bn, n), min(bk, m)
    assert k % bm == 0 and n % bn == 0 and m % bk == 0, (k, n, m, bm, bn, bk)
    n_k = m // bk
    grid = (k // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(matmul_dw_kernel, n_k=n_k, quant=quant),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float32),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xt, g)
