"""Pure-jnp oracle for the FloatSD8 matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import floatsd

__all__ = ["floatsd_matmul_ref"]


def floatsd_matmul_ref(x: jax.Array, codes: jax.Array, bias, out_dtype=jnp.float32):
    """x: [M, K] (fp8/bf16/f32), codes: [K, N] uint8 FloatSD8, bias: int32.

    Returns x @ decode(codes) in f32 accumulation, cast to out_dtype.
    """
    w = floatsd.decode(codes, bias, dtype=jnp.float32)
    return jnp.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    ).astype(out_dtype)
