"""Pallas TPU kernel: flash attention forward (online softmax, VMEM tiles).

This is the TPU-native artifact behind the roofline's kernel-substitution
model ('flashable' scope in nn/attention.py): score/probability tiles
[bq, bk] never leave VMEM; HBM traffic is exactly q + k + v reads and o
writes. Grid = (batch*heads, Sq/bq, Skv/bk) with the KV axis innermost so
the (m, l, acc) state tiles stay resident in VMEM scratch across KV steps —
the same output-stationary discipline as the paper's PE.

Causal/window masking is done on absolute positions derived from the grid
indices (contiguous-position training layout). MXU work is issued in bf16
with f32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_fwd_kernel", "flash_attention_pallas"]

NEG_INF = -1e30


def flash_fwd_kernel(
    q_ref,  # [1, bq, D]
    k_ref,  # [1, bk, D]
    v_ref,  # [1, bk, D]
    o_ref,  # [1, bq, D]
    m_ref,  # [bq, 1]   VMEM scratch: running max
    l_ref,  # [bq, 1]   VMEM scratch: running denom
    acc_ref,  # [bq, D] VMEM scratch: running numerator
    *,
    n_k: int,
    bq: int,
    bk: int,
    scale: float,
    causal: bool,
    window: int | None,
):
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale  # [bq, D]
    k = k_ref[0].astype(jnp.float32)  # [bk, D]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [bq, bk]

    # absolute positions of this tile
    qpos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = kstep * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...][:, 0]  # [bq]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])  # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)  # [bq]
    l_new = l_ref[...][:, 0] * alpha + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(
        p.astype(jnp.bfloat16), v_ref[0].astype(jnp.bfloat16),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )  # [bq, D]
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]

    @pl.when(kstep == n_k - 1)
    def _flush():
        o_ref[0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        ).astype(o_ref.dtype)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bq", "bk", "causal", "window", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # [BH, S, D]  (batch*heads flattened)
    k: jax.Array,  # [BH, S, D]
    v: jax.Array,  # [BH, S, D]
    *,
    bq: int = 256,
    bk: int = 512,
    causal: bool = True,
    window: int | None = None,
    interpret: bool = False,
):
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq, bk = min(bq, sq), min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    n_k = skv // bk
    grid = (bh, sq // bq, n_k)
    scale = 1.0 / (d**0.5)

    return pl.pallas_call(
        functools.partial(
            flash_fwd_kernel, n_k=n_k, bq=bq, bk=bk, scale=scale,
            causal=causal, window=window,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, s: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, s: (h, s, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, s: (h, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, s: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, 1), jnp.float32),
            _vmem((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
