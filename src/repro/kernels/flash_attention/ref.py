"""Pure-jnp oracle for the Pallas flash attention kernel (full scores)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref"]


def flash_attention_ref(q, k, v, causal: bool = True, window: int | None = None):
    """q/k/v: [BH, S, D] with contiguous positions. Returns [BH, Sq, D]."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    scale = 1.0 / (d**0.5)
    s = jnp.einsum(
        "bqd,bkd->bqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
