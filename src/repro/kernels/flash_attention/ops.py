"""jit'd public wrapper for the Pallas flash attention kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas
from .ref import flash_attention_ref

__all__ = ["flash_attention_kernel", "flash_tiles"]


def flash_tiles(sq: int, skv: int) -> tuple[int, int]:
    """(bq, bk) the kernel path will use: largest power-of-two blocks
    dividing the sequence dims, capped at (256, 512). Shared with the
    dispatch layer's cost model so predicted VMEM/traffic can never
    diverge from the launched grid."""
    bq = 8
    while sq % (bq * 2) == 0 and bq < 256:
        bq *= 2
    bk = 128
    while skv % (bk * 2) == 0 and bk < 512:
        bk *= 2
    return bq, bk


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "use_kernel", "interpret")
)
def flash_attention_kernel(
    q, k, v, *, causal: bool = True, window: int | None = None,
    use_kernel: bool = True, interpret: bool = True,
):
    """[BH, S, D] attention. `interpret=True` is the CPU-validation mode;
    pass interpret=False on real TPU. Oracle fallback on indivisible
    shapes (tiles must divide S and D should be lane-aligned)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    if not use_kernel or sq % 8 or skv % 128 or d % 8:
        return flash_attention_ref(q, k, v, causal, window)
    bq, bk = flash_tiles(sq, skv)
    return flash_attention_pallas(
        q, k, v, bq=bq, bk=bk, causal=causal, window=window, interpret=interpret
    )
