"""CostSpec for the flash-attention forward kernel.

Shapes: q [BH, Sq, D], k/v [BH, Skv, D] -> o [BH, Sq, D].

``attend_pairs`` counts the (query, key) pairs the mask actually admits —
the algorithmic minimum the **ref** model charges. The **pallas** kernel
visits every KV tile and masks with a ``where`` (no tile skipping), so it
is charged the full Sq x Skv rectangle, with the masked-out share
attributed to ``pad_waste_flops`` — on a long causal sequence that track
reads ~50% waste, which is the tile-skipping optimization the ledger
exists to motivate.

Traffic (grid ``(BH, Sq/bq, Skv/bk)``, KV innermost): q and o move once;
k and v are re-fetched once per q-block (``Sq/bq`` visits). Score and
probability tiles never leave VMEM.
"""
from __future__ import annotations

from ...obs.costmodel import Cost

__all__ = ["attend_pairs", "flash_attention_cost"]

SOFTMAX_FLOPS_PER_PAIR = 6  # max, sub, exp, sum-add, rescale mul, mask


def attend_pairs(sq: int, skv: int, causal: bool, window: int | None) -> int:
    """Exact count of (q, k) pairs the mask admits, matching the kernel's
    absolute-position masking: ``k <= q`` when causal, ``q - k < window``."""
    total = 0
    for q in range(sq):
        hi = min(skv - 1, q) if causal else skv - 1
        lo = max(0, q - window + 1) if window is not None else 0
        total += max(hi - lo + 1, 0)
    return total


def flash_attention_cost(bh: int, sq: int, skv: int, d: int, *, backend: str,
                         causal: bool = True, window: int | None = None,
                         elem_bytes: int = 4,
                         bq: int | None = None, bk: int | None = None) -> Cost:
    pairs = attend_pairs(sq, skv, causal, window)
    write = bh * sq * d * elem_bytes
    if backend == "ref":
        macs = 2 * bh * pairs * d  # QK^T + PV
        return Cost(
            flops=2 * macs + bh * (SOFTMAX_FLOPS_PER_PAIR * pairs + 2 * sq * d),
            macs=macs,
            hbm_read_bytes=bh * (sq + 2 * skv) * d * elem_bytes,
            hbm_write_bytes=write,
        )
    assert bq is not None and bk is not None
    full = sq * skv
    macs = 2 * bh * full * d
    return Cost(
        flops=2 * macs + bh * (SOFTMAX_FLOPS_PER_PAIR * full + 2 * sq * d),
        macs=macs,
        hbm_read_bytes=bh * (sq * d + 2 * (sq // bq) * skv * d) * elem_bytes,
        hbm_write_bytes=write,
        vmem_bytes=(
            (bq + 2 * bk) * d * elem_bytes  # q + k + v tiles
            + bq * bk * 4  # score/probability tile
            + bq * (d + 2) * 4  # (acc, m, l) scratch
            + bq * d * elem_bytes  # output tile
        ),
        # masked-out pairs the kernel computes anyway (no tile skipping)
        pad_waste_flops=(4 * d + SOFTMAX_FLOPS_PER_PAIR) * bh * (full - pairs),
    )
