"""Oracle: FloatSD8 encode (value -> uint8 codes) via the core library."""
from __future__ import annotations

import jax.numpy as jnp

from ...core import floatsd

__all__ = ["quantize_ref"]


def quantize_ref(x, bias):
    codes, _ = floatsd.encode(x, bias)
    return codes
