"""CostSpec for the FloatSD8 encoder: f32 -> 1-byte codes.

Elementwise over the flattened tensor; the pallas path reshapes to
[rows, 256] padded to ``8*256`` multiples. Per element the encoder does a
binary search over the 31-entry mantissa grid (~5 compares), exponent
extraction, and the bias shift — ``QUANT_FLOPS_PER_ELEM`` is that model
constant. Output is 1 byte/weight: this op is where the paper's 4x
resident-byte shrink enters the ledger.
"""
from __future__ import annotations

from ...obs.costmodel import Cost

__all__ = ["quantize_cost", "QUANT_FLOPS_PER_ELEM"]

QUANT_FLOPS_PER_ELEM = 12  # ~5-compare search over 31 mantissas + exp/bias


def quantize_cost(n: int, *, backend: str, x_bytes: int = 4,
                  bias_bytes: int = 4, padded_n: int | None = None,
                  tile_rows: int | None = None) -> Cost:
    if backend == "ref":
        return Cost(
            flops=QUANT_FLOPS_PER_ELEM * n,
            hbm_read_bytes=n * x_bytes + bias_bytes,
            hbm_write_bytes=n * 1,
        )
    assert padded_n is not None and tile_rows is not None
    return Cost(
        flops=QUANT_FLOPS_PER_ELEM * padded_n,
        hbm_read_bytes=padded_n * x_bytes + bias_bytes,
        hbm_write_bytes=padded_n * 1,
        vmem_bytes=tile_rows * 256 * (x_bytes + 1) + bias_bytes,
        pad_waste_flops=QUANT_FLOPS_PER_ELEM * (padded_n - n),
        pad_waste_bytes=(padded_n - n) * (x_bytes + 1),
    )
