"""Public wrapper: FloatSD8 quantization of arbitrary-shape tensors.

Explicit-control entry; ``kernels.dispatch.quantize`` is the policy-aware
one. Backend choices are recorded in ``kernels.dispatch.STATS`` (op
``"floatsd_quantize"``) — fallbacks are observable, never silent.
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import dispatch
from ...core import floatsd
from .kernel import quantize_pallas

__all__ = ["floatsd_quantize"]


def floatsd_quantize(x, bias=None, *, use_kernel: bool = True, interpret: bool = True):
    """Any-shape tensor -> (uint8 codes, int32 bias). Kernel path reshapes
    to 2D tiles; oracle fallback for indivisible shapes."""
    if bias is None:
        bias = floatsd.fit_bias(x)
    n = x.size
    # [8k, 256] layout: rows must be a multiple of 8 for the TPU tiling
    if not use_kernel or n % (8 * 256):
        dispatch.record(
            "floatsd_quantize", "ref",
            reason="use_kernel=False" if not use_kernel
            else f"fallback: size {n} % {8 * 256}",
        )
        codes, _ = floatsd.encode(x, bias)
        return codes, bias
    dispatch.record(
        "floatsd_quantize", "pallas", interpret=interpret, reason="explicit wrapper"
    )
    x2 = x.reshape(-1, 256)
    codes = quantize_pallas(x2, bias, bm=dispatch.row_tile(x2.shape[0]), bn=256,
                            interpret=interpret)
    return codes.reshape(x.shape), bias
