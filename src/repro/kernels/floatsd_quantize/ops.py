"""jit'd wrapper: FloatSD8 quantization of arbitrary-shape tensors."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core import floatsd
from .kernel import quantize_pallas
from .ref import quantize_ref

__all__ = ["floatsd_quantize"]


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def floatsd_quantize(x, bias=None, *, use_kernel: bool = True, interpret: bool = True):
    """Any-shape tensor -> (uint8 codes, int32 bias). Kernel path reshapes
    to 2D tiles; oracle fallback for indivisible shapes."""
    if bias is None:
        bias = floatsd.fit_bias(x)
    flat = x.reshape(-1)
    n = flat.shape[0]
    if not use_kernel or n % 256:
        codes, _ = floatsd.encode(x, bias)
        return codes, bias
    x2 = flat.reshape(-1, 256)
    codes = quantize_pallas(x2, bias, bm=min(256, x2.shape[0]), bn=256,
                            interpret=interpret)
    return codes.reshape(x.shape), bias
