"""Pallas TPU kernel: FloatSD8 quantization (master weights -> uint8 codes).

Runs after every optimizer step (paper §III-B: 'the master copy weights are
then quantized to FloatSD8 for the next iteration'). Pure VPU work:
nearest-grid-value rounding implemented as a broadcast compare-count against
the 64 grid midpoints (no searchsorted on TPU), then a gather of the
precomputed (exponent, mantissa-index) pair for the winning grid slot.

Tiles are [bm, bn] VMEM blocks of the (flattened-2D) weight; the three LUT
rows (midpoints / exponent / mantissa-idx) ride along as tiny inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ...core import floatsd

__all__ = ["quantize_kernel", "quantize_pallas"]

_GRID = floatsd._GRID_POS.astype(np.float32)  # 65 non-negative values
_MID = ((_GRID[1:] + _GRID[:-1]) / 2).astype(np.float32)  # 64 midpoints
_E = floatsd._GRID_E.astype(np.int32)
_MIDX = floatsd._GRID_MIDX.astype(np.int32)
_NG = _GRID.size  # 65


def quantize_kernel(x_ref, bias_ref, mid_ref, e_ref, midx_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    scale = jnp.exp2(-bias_ref[0, 0].astype(jnp.float32))
    n = jnp.abs(x) * scale
    n = jnp.minimum(n, _GRID[-1])
    # nearest-grid index: count midpoints below n (broadcast compare-sum)
    mids = mid_ref[0, :]  # [64]
    gidx = jnp.sum(
        (n[..., None] > mids[None, None, :]).astype(jnp.int32), axis=-1
    )  # [bm, bn] in [0, 64]
    e = jnp.take(e_ref[0, :], gidx)
    midx = jnp.take(midx_ref[0, :], gidx)
    midx_signed = jnp.where(x < 0, 30 - midx, midx)
    out_ref[...] = ((e << 5) | midx_signed).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def quantize_pallas(x, bias, *, bm: int = 256, bn: int = 256, interpret: bool = False):
    m, n = x.shape
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 64), lambda i, j: (0, 0)),
            pl.BlockSpec((1, _NG), lambda i, j: (0, 0)),
            pl.BlockSpec((1, _NG), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.uint8),
        interpret=interpret,
    )(
        x,
        jnp.reshape(bias.astype(jnp.int32), (1, 1)),
        jnp.asarray(_MID).reshape(1, -1),
        jnp.asarray(_E).reshape(1, -1),
        jnp.asarray(_MIDX).reshape(1, -1),
    )
