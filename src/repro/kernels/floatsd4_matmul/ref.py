"""Pure-jnp oracle for the FloatSD4 packed matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import floatsd4

__all__ = ["floatsd4_matmul_ref"]


def floatsd4_matmul_ref(x: jax.Array, codes: jax.Array, exps: jax.Array,
                        k: int, out_dtype=jnp.float32):
    """x: [M, K], codes: [ceil(K/2), N] nibble-packed uint8 FloatSD4,
    exps: [ceil(K/GROUP), N] int8 per-group exponents.

    Returns x @ decode(codes) in f32 accumulation, cast to out_dtype.
    ``k`` is the true (unpadded) contraction length — the packed stream
    may carry a trailing ZERO_CODE nibble when K is odd.
    """
    w = floatsd4.decode_packed(codes, exps, k, dtype=jnp.float32)
    return jnp.dot(
        x.astype(jnp.float32), w, preferred_element_type=jnp.float32
    ).astype(out_dtype)
