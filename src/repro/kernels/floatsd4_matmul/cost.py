"""CostSpec for the FloatSD4 packed matmul.

Same shape of model as ``floatsd_matmul.cost`` with one structural change:
the weight stream is *sub-byte*. A [c, n] FloatSD4 weight costs
``ceil(c/2) * n`` code bytes (two 4-bit codes per byte, packed along the
contraction axis) plus ``ceil(c/GROUP) * n`` int8 group-exponent bytes —
~0.53 bytes/weight vs FloatSD8's 1 byte/weight.

  * **ref** — each operand read exactly once, output written once; the
    oracle's unpack/decode intermediates are XLA-fusible and excluded, so
    ref predictions equal the ndarray ``nbytes`` the dispatch actually
    touches — tolerance 0, tested in tests/test_costmodel.py.
  * **pallas** — output-stationary grid ``(M/bm, N/bn, C/bk)``: the x tile
    re-fetched once per N-block, the packed codes + exponents once per
    M-block, output written once. Padded dims charged in full with the
    delta in ``pad_waste_*``.
  * **VMEM** per grid step: x tile + packed-byte tile + exponent tile +
    the unpacked decoded tile (compute dtype) + f32 accumulator + output.

``DECODE4_FLOPS_PER_CODE`` covers the in-VMEM nibble unpack (mask/shift),
the 16-entry LUT gather, the group-exponent exp2 and the scale multiply.
"""
from __future__ import annotations

from ...core import floatsd4
from ...obs.costmodel import Cost

__all__ = ["matmul4_fwd_cost", "DECODE4_FLOPS_PER_CODE"]

DECODE4_FLOPS_PER_CODE = 5  # nibble mask+shift, LUT gather, exp2, scale


def _codes_rows(c: int) -> int:
    return -(-c // 2)


def _exp_rows(c: int) -> int:
    return -(-c // floatsd4.GROUP)


def matmul4_fwd_cost(
    m: int, c: int, n: int, *, backend: str,
    x_bytes: int = 4, out_bytes: int = 4, compute_bytes: int = 4,
    wt_nbytes: int | None = None,
    padded: tuple[int, int, int] | None = None,
    tiles: tuple[int, int, int] | None = None,
) -> Cost:
    """x [m, c] @ decode4(codes [ceil(c/2), n], exps [ceil(c/G), n]).

    ``wt_nbytes`` overrides the computed packed-stream bytes for layouts
    where the packing axis is not the contraction axis (the tied-head
    ``...d,vd->...v`` einsum decodes a [v, d] tensor packed along v, whose
    ceil rounding differs from ceil(c/2)*n when the free axis is odd) —
    the ref tolerance-0 contract needs the actual array bytes.
    """
    macs_exact = m * c * n
    wt_bytes = _codes_rows(c) * n + _exp_rows(c) * n  # the halved stream
    if wt_nbytes is not None:
        wt_bytes = wt_nbytes
    if backend == "ref":
        return Cost(
            flops=2 * macs_exact + DECODE4_FLOPS_PER_CODE * c * n,
            macs=macs_exact,
            hbm_read_bytes=m * c * x_bytes + wt_bytes,
            hbm_write_bytes=m * n * out_bytes,
        )
    assert padded is not None and tiles is not None, (
        "pallas matmul4 cost needs the padded dims and tile config"
    )
    mp, cp, np_ = padded
    bm, bn, bk = tiles
    macs = mp * cp * np_
    wt_padded = _codes_rows(cp) * np_ + _exp_rows(cp) * np_
    wt_fetches = (mp // bm) * wt_padded  # weight stream once per M-block
    flops = 2 * macs + DECODE4_FLOPS_PER_CODE * (mp // bm) * cp * np_
    read = (np_ // bn) * mp * cp * x_bytes + wt_fetches
    write = mp * np_ * out_bytes
    vmem = (
        bm * bk * x_bytes
        + (bk // 2) * bn  # packed-byte tile
        + (bk // floatsd4.GROUP) * bn  # exponent tile
        + bk * bn * compute_bytes  # unpacked decoded tile
        + bm * bn * 4  # f32 accumulator scratch
        + bm * bn * out_bytes
    )
    return Cost(
        flops=flops,
        macs=macs,
        hbm_read_bytes=read,
        hbm_write_bytes=write,
        vmem_bytes=vmem,
        pad_waste_flops=2 * (macs - macs_exact),
        pad_waste_bytes=(
            (mp * cp - m * c) * x_bytes
            + (wt_padded - wt_bytes)
            + (mp * np_ - m * n) * out_bytes
        ),
    )
