"""Pallas TPU kernel: fused FloatSD4 nibble-unpack + decode + matmul.

Sub-byte sibling of ``floatsd_matmul.kernel``: weights travel HBM->VMEM as
*half* a byte per code (two 4-bit codes per byte, packed along K) plus one
int8 exponent per GROUP x column, are unpacked and decoded in VMEM by the
VPU (nibble mask/shift, a 16-entry mantissa LUT gather, exp2 of the
group exponent), and feed the MXU with f32 accumulation.

Grid (M/bm, N/bn, K/bk), K innermost (output-stationary, accumulator tile
resident in VMEM). The packed-code BlockSpec is (bk/2, bn) and the
exponent BlockSpec (bk/GROUP, bn): the dispatch layer always pads K to a
multiple of 128, so every resolved bk (128/256/512) is divisible by both
2 and GROUP=32. VMEM working set ~= bm*bk (x) + bk/2*bn (bytes) +
bk/32*bn (exps) + bk*bn (decoded, compute dtype) + bm*bn*4 (acc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ...core import floatsd4

__all__ = ["floatsd4_matmul_kernel", "floatsd4_matmul_pallas"]


def floatsd4_matmul_kernel(
    x_ref, codes_ref, exps_ref, lut_ref, out_ref, acc_ref, *, n_k: int,
    group: int, compute_dtype=jnp.bfloat16,
):
    """One (bm x bn) output tile; accumulates over the K grid axis.

    x_ref:     [bm, bk]        activation tile
    codes_ref: [bk//2, bn]     nibble-packed uint8 FloatSD4 codes
    exps_ref:  [bk//group, bn] int8 per-group exponents
    lut_ref:   [1, 16]         f32 mantissa LUT (constants ride as inputs)
    acc_ref:   [bm, bn]        f32 VMEM accumulator scratch
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = codes_ref[...].astype(jnp.int32)  # [bk//2, bn]
    bk2, bn = packed.shape
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    # interleave rows: unpacked[2i] = lo[i], unpacked[2i+1] = hi[i]
    idx = jnp.stack([lo, hi], axis=1).reshape(2 * bk2, bn)
    mant = jnp.take(lut_ref[0, :], idx)  # VPU gather, 16-entry table
    e = exps_ref[...].astype(jnp.float32)  # [bk//group, bn]
    scale = jnp.broadcast_to(
        e[:, None, :], (e.shape[0], group, bn)
    ).reshape(2 * bk2, bn)
    w = (mant * jnp.exp2(scale)).astype(compute_dtype)

    x = x_ref[...].astype(compute_dtype)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(k_step == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def _vmem_scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "compute_dtype", "interpret"),
)
def floatsd4_matmul_pallas(
    x: jax.Array,  # [M, K]
    codes: jax.Array,  # [K//2, N] uint8, nibble-packed along K
    exps: jax.Array,  # [K//GROUP, N] int8
    *,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    out_dtype=jnp.float32,
    compute_dtype=jnp.bfloat16,
    interpret: bool = False,
):
    m, k = x.shape
    k2, n = codes.shape
    g = floatsd4.GROUP
    assert k == 2 * k2, (x.shape, codes.shape)
    assert exps.shape == (k // g, n), (exps.shape, k, n)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    assert bk % 2 == 0 and bk % g == 0, (bk, g)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    return pl.pallas_call(
        functools.partial(
            floatsd4_matmul_kernel, n_k=n_k, group=g,
            compute_dtype=compute_dtype,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bk // g, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((1, 16), lambda i, j, s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, exps, jnp.asarray(floatsd4.LUT16).reshape(1, 16))
