"""Public wrapper for the FloatSD4 packed matmul kernel.

Explicit-control entry (callers pick kernel/oracle and interpret mode);
``kernels.dispatch.matmul4`` is the policy-aware entry the nn/serving hot
paths use. Either way the backend that ran is recorded in
``kernels.dispatch.STATS`` under op ``"floatsd4_matmul"``.
"""
from __future__ import annotations

import jax.numpy as jnp

from .. import dispatch
from ...core import floatsd4
from .kernel import floatsd4_matmul_pallas
from .ref import floatsd4_matmul_ref

__all__ = ["floatsd4_matmul", "floatsd4_dense_forward"]


def floatsd4_matmul(
    x, codes, exps, k=None, *, out_dtype=jnp.float32, use_kernel: bool = True,
    interpret: bool = True,
):
    """x [M,K] @ decode4(codes [ceil(K/2),N], exps) -> [M,N].

    ``k`` defaults to x's contraction length. Falls back to the jnp oracle
    when ``use_kernel=False`` or for shapes the tiling doesn't divide
    (odd K, unaligned N — recorded, never silent).
    """
    m, xk = x.shape
    _, n = codes.shape
    k = xk if k is None else k
    assert k == xk, (x.shape, k)
    g = floatsd4.GROUP
    if not use_kernel or (m % 8 or n % 128 or k % 128):
        dispatch.record(
            "floatsd4_matmul", "ref",
            reason="use_kernel=False" if not use_kernel
            else f"fallback: shape {(m, k, n)} not tile-divisible",
        )
        return floatsd4_matmul_ref(x, codes, exps, k, out_dtype)
    dispatch.record(
        "floatsd4_matmul", "pallas", interpret=interpret,
        reason="explicit wrapper",
    )
    bm, bn, bk = dispatch.matmul_tiles(m, n, k)
    assert bk % 2 == 0 and bk % g == 0, (bk, g)
    return floatsd4_matmul_pallas(
        x, codes, exps, bm=bm, bn=bn, bk=bk, out_dtype=out_dtype,
        interpret=interpret,
    )


def floatsd4_dense_forward(x, w_f32, *, interpret: bool = True):
    """Encode-then-multiply convenience: returns (y, packed_codes, exps)."""
    codes, exps = floatsd4.encode(w_f32)
    packed = floatsd4.pack_nibbles(codes)
    y = floatsd4_matmul(
        x, packed, exps, w_f32.shape[0], interpret=interpret
    )
    return y, packed, exps
