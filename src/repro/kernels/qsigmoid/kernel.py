"""Pallas TPU kernel: two-region FloatSD8 sigmoid (paper Eqs. 7-8).

The standalone version of the sigmoid stage inside the fused LSTM-cell
kernel: sigma(-|x|) lands in (0, 0.5], is rounded to the nearest entry of
the 42-value non-positive-branch LUT by a broadcast compare-count against
the 42 midpoints (the VPU analogue of the paper's reduced-depth LUT), and
the positive region is mirrored as 1 - Q(sigma(-x)). Registered in
``kernels.dispatch`` so gate activations outside the fused cell (e.g. the
RWKV receptance gate) can run the same datapath.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ...core import qsigmoid as _qs

__all__ = ["qsigmoid_kernel", "qsigmoid_pallas"]

_SIG_GRID = _qs.sigmoid_lut_values().astype(np.float32)  # 43 incl. 0
_SIG_MID = ((_SIG_GRID[1:] + _SIG_GRID[:-1]) / 2).astype(np.float32)


def qsigmoid_kernel(x_ref, mid_ref, grid_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    s_neg = jax.nn.sigmoid(-jnp.abs(x))  # in (0, 0.5]
    gidx = jnp.sum(
        (s_neg[..., None] > mid_ref[0, :][None, None, :]).astype(jnp.int32), -1
    )
    q = jnp.take(grid_ref[0, :], gidx)
    out_ref[...] = jnp.where(x > 0, 1.0 - q, q).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def qsigmoid_pallas(x, *, bm: int = 256, bn: int = 256, interpret: bool = False):
    """x: [M, N] -> quantized sigmoid, same shape/dtype."""
    m, n = x.shape
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    nm = _SIG_MID.size
    return pl.pallas_call(
        qsigmoid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, nm), lambda i, j: (0, 0)),
            pl.BlockSpec((1, nm + 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(
        x,
        jnp.asarray(_SIG_MID).reshape(1, -1),
        jnp.asarray(_SIG_GRID).reshape(1, -1),
    )
