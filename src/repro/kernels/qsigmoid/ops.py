"""Wrapper: two-region FloatSD8 sigmoid for arbitrary-shape tensors."""
from __future__ import annotations


from .kernel import qsigmoid_pallas
from .ref import qsigmoid_ref

__all__ = ["qsigmoid"]


def qsigmoid(x, *, use_kernel: bool = True, interpret: bool = True):
    """Any-shape tensor -> quantized sigmoid. Kernel path reshapes to 2D
    tiles; oracle fallback for indivisible sizes. The backend actually used
    is recorded in ``kernels.dispatch.STATS`` (op ``"qsigmoid"``)."""
    from .. import dispatch

    n = x.size
    # [8k, 256] layout: rows must be a multiple of 8 for the TPU tiling
    if not use_kernel or n % (8 * 256):
        dispatch.record(
            "qsigmoid", "ref",
            reason="use_kernel=False" if not use_kernel
            else f"fallback: size {n} % {8 * 256}",
        )
        return qsigmoid_ref(x)
    dispatch.record("qsigmoid", "pallas", interpret=interpret, reason="explicit wrapper")
    x2 = x.reshape(-1, 256)
    bm = dispatch.row_tile(x2.shape[0])
    return qsigmoid_pallas(x2, bm=bm, bn=256, interpret=interpret).reshape(x.shape)
