"""CostSpec for the two-region quantized sigmoid.

Elementwise over the flattened tensor ([rows, 256] padded to ``8*256``
multiples on pallas). Per element: the paper's 42-boundary two-region
LUT — 42 compares + 1 select (``QSIG_FLOPS_PER_ELEM``).
"""
from __future__ import annotations

from ...obs.costmodel import Cost

__all__ = ["qsigmoid_cost", "QSIG_FLOPS_PER_ELEM"]

QSIG_FLOPS_PER_ELEM = 43  # 42 region-boundary compares + 1 select


def qsigmoid_cost(n: int, *, backend: str, x_bytes: int = 4,
                  y_bytes: int = 4, padded_n: int | None = None,
                  tile_rows: int | None = None) -> Cost:
    if backend == "ref":
        return Cost(
            flops=QSIG_FLOPS_PER_ELEM * n,
            hbm_read_bytes=n * x_bytes,
            hbm_write_bytes=n * y_bytes,
        )
    assert padded_n is not None and tile_rows is not None
    return Cost(
        flops=QSIG_FLOPS_PER_ELEM * padded_n,
        hbm_read_bytes=padded_n * x_bytes,
        hbm_write_bytes=padded_n * y_bytes,
        vmem_bytes=tile_rows * 256 * (x_bytes + y_bytes),
        pad_waste_flops=QSIG_FLOPS_PER_ELEM * (padded_n - n),
        pad_waste_bytes=(padded_n - n) * (x_bytes + y_bytes),
    )
