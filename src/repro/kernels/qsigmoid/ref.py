"""Oracle: two-region FloatSD8 sigmoid via the core library."""
from __future__ import annotations

from ...core.qsigmoid import qsigmoid_raw

__all__ = ["qsigmoid_ref"]


def qsigmoid_ref(x):
    return qsigmoid_raw(x)
