"""Hoisted weight-quantization (perf hillclimb #2) must be numerically
identical to the naive quantize-inside-step path: same forward outputs,
same gradients to the master weights (STE)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import get_policy
from repro.nn import lstm as lstm_mod
from repro.nn.lstm import LSTMLayer

pytestmark = pytest.mark.slow  # tier-2: see pyproject markers


def _run(hoist: bool, policy_name="floatsd8_table6"):
    old = lstm_mod.HOIST_WQUANT
    lstm_mod.HOIST_WQUANT = hoist
    try:
        policy = get_policy(policy_name)
        layer = LSTMLayer(12, 16)
        p = layer.init(jax.random.PRNGKey(0))
        xs = jax.random.normal(jax.random.PRNGKey(1), (4, 9, 12))

        def loss(p):
            h, _ = layer.apply(p, xs, policy)
            return jnp.sum(h.astype(jnp.float32) ** 2)

        val, grads = jax.value_and_grad(loss)(p)
        h, fin = layer.apply(p, xs, policy)
        return val, grads, h, fin
    finally:
        lstm_mod.HOIST_WQUANT = old


def test_hoist_matches_naive_forward_and_grads():
    v0, g0, h0, f0 = _run(False)
    v1, g1, h1, f1 = _run(True)
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(v0), float(v1), rtol=1e-6)
    for k in g0:
        np.testing.assert_allclose(
            np.asarray(g0[k], np.float32), np.asarray(g1[k], np.float32),
            rtol=1e-5, atol=1e-6, err_msg=k,
        )


def test_hoist_matches_fp32_policy_too():
    v0, g0, h0, _ = _run(False, "fp32")
    v1, g1, h1, _ = _run(True, "fp32")
    np.testing.assert_allclose(np.asarray(h0), np.asarray(h1), rtol=1e-6)
    for k in g0:
        np.testing.assert_allclose(np.asarray(g0[k]), np.asarray(g1[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)
