"""Cancellation and preemption matrix: engine-level cancel in every
request state (queued / prefilling / decoding / already-done), lane reuse
after a cancel with no state bleed, preempt→resume token agreement,
router-level cancellation (explicit, abandoned stream, mid-flight
deadline), drain with cancelled work in flight, and the HTTP DELETE
endpoint with its metrics scrape-diff acceptance check (a cancel frees
the lane without further decode steps)."""
import asyncio
import re
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import get_policy
from repro.models.lstm_models import WikiText2LM
from repro.serving import PrefixCache, Router, ServeEngine
from repro.serving.frontend import AsyncRouter
from repro.serving.http import Client, HttpError, HttpServer

POLICY = get_policy("floatsd8_table6")


def tiny_model():
    return WikiText2LM(vocab=300, emb=32, hidden=32, n_layers=2)


_PARAMS = {}


def tiny_params(model, seed=0):
    key = (model.vocab, model.emb, model.hidden, model.n_layers, seed)
    if key not in _PARAMS:
        _PARAMS[key] = model.init(jax.random.PRNGKey(seed))
    return _PARAMS[key]


_TRAINED = {}


def trained_params(model):
    """Briefly-pretrained params (see test_serving.py): decisive argmax
    margins, so the FP8 snapshot/restore perturbation of preemption must
    not flip any greedy choice."""
    key = (model.vocab, model.emb, model.hidden, model.n_layers)
    if key not in _TRAINED:
        from repro.data import synthetic
        from repro.optim import sgd
        from repro.optim.train_state import init_state, make_train_step

        data = synthetic.wikitext2(batch=32, seq=24, vocab=model.vocab)
        opt = sgd(0.9)
        state = init_state(model.init(jax.random.PRNGKey(0)), opt, POLICY)
        step_fn = jax.jit(make_train_step(model.loss, opt, POLICY, lr=1.0))
        for _ in range(30):
            batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
            state, _ = step_fn(state, batch)
        _TRAINED[key] = state.params
    return _TRAINED[key]


def make_engine(params=None, **kw):
    model = tiny_model()
    return ServeEngine(
        model, params if params is not None else tiny_params(model),
        POLICY, **kw,
    )


def prompt_of(length, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 300, length).astype(np.int32)


# ---------------------------------------------------------------------------
# engine-level cancel matrix
# ---------------------------------------------------------------------------


def test_cancel_queued_unknown_and_done_are_idempotent():
    eng = make_engine(lanes=1, chunk=4)
    a = eng.submit(prompt_of(6, 1), max_new=3)
    b = eng.submit(prompt_of(6, 2), max_new=3)

    # b still queued: scheduler removal, no lane or device work involved
    assert eng.cancel(b.rid) is True
    assert b.status == "cancelled" and b.cancel_reason == "cancelled"
    assert eng.cancel(b.rid) is False  # second cancel is a no-op
    assert eng.cancel(12345) is False  # unknown rid

    m = eng.run()
    assert a.status == "done" and len(a.out) == 3
    assert eng.cancel(a.rid) is False  # already retired
    assert m.cancelled == 1 and m.cancelled_by_reason == {"cancelled": 1}
    assert m.retired == 1  # cancelled requests are not "retired" work


def test_cancel_mid_decode_frees_lane_with_zero_extra_steps():
    """The acceptance invariant: cancelling a decoding request releases
    its lane immediately (host-side) and the engine does NOT spend a
    single further device step on it — run() after the cancel has nothing
    to do."""
    eng = make_engine(lanes=1, chunk=4)
    a = eng.submit(prompt_of(6, 3), max_new=64)
    while len(a.out) < 3:
        assert eng.step_once()
    steps0 = eng.metrics.steps

    assert eng.cancel(a.rid) is True
    assert eng.free_lanes == 1  # lane released before any next step
    eng.run()  # nothing left: must not step at all
    assert eng.metrics.steps == steps0
    assert a.status == "cancelled" and 3 <= len(a.out) < 64


def test_cancel_mid_prefill_releases_lane_without_cache_insert():
    """A lane cancelled while still consuming its prompt has produced no
    tokens; the retire path must free it without salvaging a bogus cache
    entry (the final-state insert requires >= 2 emitted tokens and a
    finished prefill)."""
    cache = PrefixCache(block=4)
    eng = make_engine(lanes=1, chunk=4, prefix_cache=cache)
    a = eng.submit(prompt_of(24, 4), max_new=8)
    assert eng.step_once()  # 4 of 24 prompt tokens consumed: prefilling
    assert a.out == []

    inserts_before = cache.stats()["entries"]
    assert eng.cancel(a.rid) is True
    assert eng.free_lanes == 1 and a.status == "cancelled"
    # block-boundary snapshots taken DURING prefill are legitimate; the
    # cancel itself must not have added a terminal entry keyed by
    # prompt+out (out is empty)
    assert cache.stats()["entries"] == inserts_before


def test_cancel_after_full_cache_hit_retire_returns_false():
    """A full-hit admission with max_new=1 retires at admission time with
    zero device steps; a cancel arriving after that finds nothing."""
    cache = PrefixCache(block=4)
    warm = make_engine(lanes=1, chunk=4, prefix_cache=cache)
    p = prompt_of(8, 5)
    warm.submit(p, max_new=4)
    warm.run()  # stores state-after-prompt + its greedy continuation

    eng = make_engine(lanes=1, chunk=4, prefix_cache=cache)
    r = eng.submit(p, max_new=1)
    assert eng.step_once() is False  # retired at admission, nothing ran
    assert r.status == "done" and len(r.out) == 1
    assert eng.cancel(r.rid) is False


@pytest.mark.slow
def test_lane_reuse_after_cancel_has_no_state_bleed():
    """Cancel A mid-decode on a single-lane engine, then serve C on the
    reused lane: C's tokens must be identical to a fresh engine serving
    only C — the masked reset really wipes A's recurrent state."""
    model = tiny_model()
    params = trained_params(model)
    pC = prompt_of(10, 7)

    eng = ServeEngine(model, params, POLICY, lanes=1, chunk=4)
    a = eng.submit(prompt_of(12, 6), max_new=48)
    while len(a.out) < 4:
        eng.step_once()
    assert eng.cancel(a.rid) is True
    c = eng.submit(pC, max_new=16)
    eng.run()

    ref_eng = ServeEngine(model, params, POLICY, lanes=1, chunk=4)
    ref = ref_eng.submit(pC, max_new=16)
    ref_eng.run()

    assert c.status == "done" and c.out == ref.out and len(c.out) == 16


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_preempt_resume_token_agreement_and_bounded_displacement():
    """A long decode is preempted for a short arrival (sjf_work), resumed
    from its FP8 snapshot, and still produces EXACTLY the tokens of an
    undisturbed run — the snapshot round-trip must not flip any greedy
    argmax. Displacement is bounded by preempt_max."""
    model = tiny_model()
    params = trained_params(model)
    pL, pS = prompt_of(8, 8), prompt_of(4, 9)

    eng = ServeEngine(
        model, params, POLICY, lanes=1, chunk=4,
        admission="sjf_work", preempt=True, preempt_margin=2, preempt_max=2,
    )
    long = eng.submit(pL, max_new=24)
    while not long.out:  # TTFT banked: the lane is now a preemption candidate
        eng.step_once()
    short = eng.submit(pS, max_new=2)
    eng.run()

    assert eng.metrics.preemptions >= 1 and eng.metrics.resumes >= 1
    assert eng.metrics.preemptions == eng.metrics.resumes
    assert 1 <= long.preempt_count <= 2
    assert short.status == "done" and len(short.out) == 2
    assert long.status == "done" and len(long.out) == 24

    ref_eng = ServeEngine(model, params, POLICY, lanes=1, chunk=4)
    ref = ref_eng.submit(pL, max_new=24)
    ref_eng.run()
    assert long.out == ref.out  # 100% agreement through snapshot/restore


def test_admit_pace_limits_admissions_per_step():
    eng = make_engine(lanes=4, chunk=4, admit_pace=1)
    for s in range(3):
        eng.submit(prompt_of(6, 10 + s), max_new=8)
    eng.step_once()
    assert eng.active_lanes == 1  # one admission despite 4 free lanes
    eng.step_once()
    assert eng.active_lanes == 2

    with pytest.raises(ValueError):
        make_engine(lanes=2, admit_pace=0)


# ---------------------------------------------------------------------------
# router-level cancellation
# ---------------------------------------------------------------------------


def test_router_explicit_cancel_stops_decode_and_is_idempotent():
    router = Router([make_engine(lanes=1, chunk=4)])
    t = router.submit(prompt_of(6, 11), max_new=64)
    while len(t.req.out) < 3:
        router.pump()
    steps0 = router.engines[0].metrics.steps

    assert router.cancel(t.rid) is True
    assert t.status == "cancelled" and t.reason == "client_cancel"
    assert t.tokens  # partial output stays readable on the ticket
    while router.pump():
        pass
    assert router.engines[0].metrics.steps == steps0  # no work after cancel
    assert router.cancel(t.rid) is False
    assert router.cancellations == {"client_cancel": 1}
    assert router.stats()["cancellations"] == {"client_cancel": 1}
    assert router.report()["cancellations"] == {"client_cancel": 1}


def test_router_cancels_expired_deadline_mid_flight():
    """Deadlines used to be enforced only at submit and dispatch; a
    request whose deadline expires AFTER lane binding must now be
    cancelled by the pump instead of decoding to max_new."""
    router = Router([make_engine(lanes=1, chunk=4)])
    t = router.submit(
        prompt_of(6, 12), max_new=4096,
        deadline=time.monotonic() + 0.05,
    )
    deadline_wall = time.monotonic() + 30.0
    while t.status not in ("done", "cancelled", "rejected"):
        assert time.monotonic() < deadline_wall, "pump never cancelled"
        router.pump()
    assert t.status == "cancelled" and t.reason == "deadline_expired"
    assert len(t.tokens) < 4096
    assert router.cancellations == {"deadline_expired": 1}


def test_abandoned_stream_is_cancelled_inside_the_engine():
    """Breaking out of ar.stream() marks the ticket abandoned; the next
    pump (here: driven by a later generate) cancels it in the engine,
    freeing the lane instead of decoding 64 tokens for nobody."""
    router = Router([make_engine(lanes=2, chunk=4)])
    ar = AsyncRouter(router)

    async def main():
        async for _ in ar.stream(prompt_of(6, 13), max_new=64):
            break  # consumer disconnects after the first token
        t = await ar.generate(prompt_of(6, 14), max_new=2)
        return t

    t = asyncio.run(main())
    assert t.status == "done" and len(t.tokens) == 2
    assert router.cancellations == {"abandoned": 1}
    assert router.idle  # nothing left decoding for the dead consumer


def test_drain_completes_with_abandoned_and_cancelled_work_in_flight():
    router = Router([make_engine(lanes=2, chunk=4)])
    t1 = router.submit(prompt_of(6, 15), max_new=64)
    t2 = router.submit(prompt_of(6, 16), max_new=64)
    t3 = router.submit(prompt_of(6, 17), max_new=4)
    while len(t1.req.out) < 1:
        router.pump()
    assert router.cancel(t1.rid) is True
    t2.abandoned = True  # simulate a consumer disconnect

    router.drain()
    assert router.idle
    assert t1.status == "cancelled" and t2.status == "cancelled"
    assert t3.status == "done" and len(t3.tokens) == 4
    assert router.cancellations == {"client_cancel": 1, "abandoned": 1}


# ---------------------------------------------------------------------------
# HTTP DELETE endpoint
# ---------------------------------------------------------------------------


def _counter(metrics_text, name, labels=""):
    pat = rf"^{re.escape(name + labels)} (\d+)$"
    m = re.search(pat, metrics_text, re.MULTILINE)
    return int(m.group(1)) if m else 0


@pytest.mark.slow
def test_http_delete_cancels_mid_stream_and_frees_the_lane():
    """DELETE /v1/requests/{rid} from a second connection ends an active
    stream with a terminal done(status=cancelled) event; the scrape-diff
    acceptance check: after the cancel, decode steps stop advancing for
    the dead request and the lane count is fully restored."""
    prompt = prompt_of(6, 18)

    async def main():
        router = Router([make_engine(lanes=2, chunk=4)])
        server = await HttpServer(router, port=0).start()
        task = asyncio.create_task(server.serve_forever())
        streamer = Client(server.host, server.port)
        admin = Client(server.host, server.port)
        try:
            gen = streamer.stream(prompt, max_new=512)
            start = await gen.__anext__()
            assert start[0] == "start"
            rid = start[1]["rid"]
            first = await gen.__anext__()
            assert first[0] == "message"

            resp = await admin.cancel(rid)
            assert resp == {"rid": rid, "cancelled": True}

            events = [ev async for ev in gen]
            done = events[-1]
            assert done[0] == "done"
            assert done[1]["status"] == "cancelled"
            assert done[1]["reason"] == "client_cancel"
            assert 1 <= done[1]["n_tokens"] < 512

            # idempotent over the wire: the rid is gone now
            with pytest.raises(HttpError) as ei:
                await admin.cancel(rid)
            assert ei.value.status == 404
            with pytest.raises(HttpError):
                await admin.cancel(999999)  # never existed

            # scrape-diff: the cancelled request contributes zero decode
            # steps after its cancel — a follow-up max_new=1 request costs
            # only prefill (prompt of 6, chunk 4 -> 2 steps, first token
            # emitted on the last prefill step)
            m1 = await admin.metrics()
            d1 = _counter(m1, "repro_decode_steps_total")
            assert _counter(
                m1, "repro_cancelled_total", '{reason="client_cancel"}'
            ) == 1
            assert _counter(m1, "repro_free_lanes") == 2  # lane restored
            await admin.generate(prompt, max_new=1)
            m2 = await admin.metrics()
            assert _counter(m2, "repro_decode_steps_total") == d1
            return True
        finally:
            await streamer.close()
            await admin.close()
            server.shutdown()
            await asyncio.wait_for(task, timeout=30)

    assert asyncio.run(main())


@pytest.mark.slow
def test_http_stream_mid_flight_deadline_maps_to_504_error_event():
    """A deadline that expires after the stream started (lane bound,
    tokens possibly flowing) surfaces as the terminal SSE error event
    with the deadline_expired mapping, not as a silent truncation."""
    prompt = prompt_of(6, 19)

    async def main():
        router = Router([make_engine(lanes=1, chunk=4)])
        server = await HttpServer(router, port=0).start()
        task = asyncio.create_task(server.serve_forever())
        try:
            async with Client(server.host, server.port) as c:
                with pytest.raises(HttpError) as ei:
                    async for _ in c.stream(
                        prompt, max_new=512, deadline_ms=150
                    ):
                        pass
                return ei.value.status, ei.value.reason
        finally:
            server.shutdown()
            await asyncio.wait_for(task, timeout=30)

    status, reason = asyncio.run(main())
    assert status == 504 and reason == "deadline_expired"
