"""Paper §IV-C: FP16 accumulation suffices for all LSTM training ops.

The TPU port keeps f32 MXU accumulation (free in hardware; DESIGN.md §3.3
records the deviation) — these tests validate the PAPER'S claim separately:
explicit fp16 accumulation over the paper's actual reduction sizes stays
within fp16 tolerance of the f32 result, and a training step built on fp16
accumulation still learns.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import floatsd
from repro.core.fp8 import FP8_E5M2, quantize_fp8


def fp16_dot(x8, w_codes, bias):
    """The paper's MAC (Fig. 8): 4 (input, weight) pairs per cycle, partial
    products merged in a Wallace carry-save tree (EXACT), the result rounded
    and normalized to FP16 once per cycle — i.e. exact 4-term sums with one
    fp16 rounding each, accumulated sequentially in fp16."""
    w = floatsd.decode(w_codes, bias, dtype=jnp.float32)
    x = x8.astype(jnp.float32)
    k = x.shape[1]
    assert k % 4 == 0
    # [B, k/4, 4] x [k/4, 4, N] -> exact per-4 sums, rounded to fp16
    prods = x.reshape(x.shape[0], k // 4, 4)[:, :, :, None] * \
        w.reshape(k // 4, 4, -1)[None]
    cyc = jnp.sum(prods, axis=2).astype(jnp.float16)  # [B, k/4, N]

    def add(acc, c):  # sequential fp16 accumulation across cycles
        return (acc + c).astype(jnp.float16), None

    acc0 = jnp.zeros((x.shape[0], w.shape[1]), jnp.float16)
    out, _ = jax.lax.scan(add, acc0, jnp.moveaxis(cyc, 1, 0))
    return out


@pytest.mark.parametrize("k", [128, 1024, 4096])  # LSTM gate fan-ins
def test_fp16_accumulation_matches_f32_within_tolerance(k):
    rng = np.random.default_rng(k)
    # activation/weight magnitudes as in a trained LSTM (post-quant scales)
    x = quantize_fp8(jnp.asarray(rng.standard_normal((8, k)) * 0.5, jnp.float32),
                     FP8_E5M2)
    w = jnp.asarray(rng.standard_normal((k, 16)) * (1.0 / np.sqrt(k)), jnp.float32)
    codes, bias = floatsd.encode(w)

    y16 = np.asarray(fp16_dot(x, codes, bias), np.float32)
    wd = floatsd.decode(codes, bias)
    y32 = np.asarray(x.astype(jnp.float32) @ wd, np.float32)
    # paper's claim: fp16 accumulate preserves training-relevant precision.
    # Error model: ~k/4 sequential fp16 roundings of a ~N(0, |x||w|sqrt(k))
    # running sum -> relative p99 well under a few percent.
    denom = np.maximum(np.abs(y32), 1e-1)
    rel = np.abs(y16 - y32) / denom
    assert np.percentile(rel, 99) < 0.05, (k, float(np.percentile(rel, 99)))


def test_fp16_master_update_addition():
    """§IV-C: 'addition of the FP16 master copy weight and the FP8 gradient
    ... realized by FP16 addition' — an FP16 master + fp16 add training step
    moves weights identically to the library's f32-add-then-round within one
    fp16 ulp."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal(4096) * 0.1, jnp.float16)
    g = quantize_fp8(jnp.asarray(rng.standard_normal(4096) * 1e-3, jnp.float32),
                     FP8_E5M2)
    lr = jnp.float16(0.1)
    upd16 = (w - lr * g.astype(jnp.float16)).astype(jnp.float16)
    upd32 = (w.astype(jnp.float32) - 0.1 * g.astype(jnp.float32)).astype(jnp.float16)
    np.testing.assert_allclose(
        np.asarray(upd16, np.float32), np.asarray(upd32, np.float32),
        rtol=2e-3, atol=2e-6,  # one extra fp16 rounding (lr*g product)
    )


def test_training_converges_with_fp16_accum_semantics():
    """A tiny regression task where every matmul emits fp16 (the closest
    jit-able analogue of fp16 accumulation) still converges."""
    rng = np.random.default_rng(3)
    X = jnp.asarray(rng.standard_normal((256, 32)), jnp.float16)
    true_w = jnp.asarray(rng.standard_normal((32, 1)) * 0.5, jnp.float16)
    y = X @ true_w

    w = jnp.zeros((32, 1), jnp.float16)

    @jax.jit
    def step(w):
        def loss(w):
            pred = jnp.matmul(X, w.astype(jnp.float16),
                              preferred_element_type=jnp.float16)
            return jnp.mean((pred - y).astype(jnp.float32) ** 2)

        l, g = jax.value_and_grad(loss)(w)
        return (w.astype(jnp.float32) - 0.01 * g.astype(jnp.float32)).astype(
            jnp.float16
        ), l

    first = None
    for i in range(300):
        w, l = step(w)
        if first is None:
            first = float(l)
    assert float(l) < 0.05 * first, (first, float(l))
