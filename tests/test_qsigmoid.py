"""Tests for two-region sigmoid quantization (paper §III-C, Figs. 4-5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import floatsd, qsigmoid


def test_lut_has_42_entries_for_nonpositive_inputs():
    # Paper: "there are only 42 possible values in a quantized sigmoid output
    # when the input is non-positive"
    vals = qsigmoid.sigmoid_lut_values()
    positive = vals[vals > 0]
    assert positive.size == 42


def test_two_region_symmetry():
    # Eq. 7/8: qs(x) + qs(-x) == 1 exactly
    x = jnp.linspace(-10, 10, 4001)
    y = qsigmoid.qsigmoid_raw(x)
    np.testing.assert_allclose(np.asarray(y + y[::-1]), 1.0, atol=1e-7)


def test_error_balanced_vs_naive():
    # Fig. 4 vs Fig. 5: naive quantization error *grows* with x>0 (log-linear
    # grid is coarse near 1.0) while the mirrored quantizer error *shrinks*
    # (sigma(-x) -> 0 lands on the fine end of the grid).
    x = jnp.linspace(2.0, 8.0, 1000)  # the tail region of Fig. 4
    s = jax.nn.sigmoid(x)
    naive = floatsd.quantize(s, bias=qsigmoid.SIGMOID_LUT_BIAS).values
    two_region = qsigmoid.qsigmoid_raw(x)
    err_naive = float(jnp.max(jnp.abs(naive - s)))
    err_two = float(jnp.max(jnp.abs(two_region - s)))
    assert err_two < err_naive / 4  # dramatic balance improvement
    # worst-case error anywhere is one half-step of the coarsest grid cell
    # the sigmoid output crosses (the 2.5->3.5 mantissa hole): 4/128
    xw = jnp.linspace(-8.0, 8.0, 4000)
    err_all = float(jnp.max(jnp.abs(qsigmoid.qsigmoid_raw(xw) - jax.nn.sigmoid(xw))))
    assert err_all <= 4.0 / 128 + 1e-6


def test_outputs_in_unit_interval_and_monotone():
    x = jnp.linspace(-20, 20, 8001)
    y = np.asarray(qsigmoid.qsigmoid_raw(x))
    assert y.min() >= 0.0 and y.max() <= 1.0
    assert np.all(np.diff(y) >= -1e-7)


def test_gradient_is_exact_sigmoid_derivative():
    x = jnp.asarray([-2.0, -0.1, 0.0, 0.1, 3.0])
    g = jax.vmap(jax.grad(qsigmoid.qsigmoid))(x)
    s = jax.nn.sigmoid(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(s * (1 - s)), rtol=1e-6)


def test_negative_branch_on_lut():
    # every output for x<=0 must be one of the 42 LUT values (or 0)
    lut = qsigmoid.sigmoid_lut_values()
    x = jnp.linspace(-30, 0, 2000)
    y = np.asarray(qsigmoid.qsigmoid_raw(x))
    for v in y:
        assert np.min(np.abs(lut - v)) < 1e-7


@settings(max_examples=100, deadline=None)
@given(st.floats(-50, 50, allow_nan=False, width=32))
def test_property_close_to_sigmoid(x):
    xv = jnp.float32(x)
    y = float(qsigmoid.qsigmoid_raw(xv))
    s = float(jax.nn.sigmoid(xv))
    assert abs(y - s) <= 4.0 / 128 + 1e-6  # half the widest grid cell


def test_qtanh_fp8_matches_fp8_cast():
    x = jnp.linspace(-4, 4, 101)
    y = np.asarray(qsigmoid.qtanh_fp8(x))
    ref = np.asarray(jnp.tanh(x).astype(jnp.float8_e5m2).astype(jnp.float32))
    np.testing.assert_allclose(y, ref, atol=1e-7)


def test_folded_quantizer_exact_vs_generic_grid():
    """The octave-folded _Q (perf hillclimb #3 it.2) must equal the generic
    64-midpoint FloatSD8 quantizer exactly over a dense sweep of (0, 0.5]."""
    import numpy as np
    from repro.core import floatsd
    from repro.core.qsigmoid import SIGMOID_LUT_BIAS, _Q

    v = jnp.linspace(0.0, 0.5, 300001)
    got = _Q(v)
    want = floatsd.quantize(v, bias=SIGMOID_LUT_BIAS).values
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
