"""Observability subsystem tests: the span tracer (thread/async safety,
ring bounds, Chrome trace-event export invariants, the near-zero
disabled-path cost bound), the quantization-health telemetry stats, the
TelemetryLogger JSONL aggregation, and the in-kernel FP8 flush hook."""
import asyncio
import importlib.util
import json
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import telemetry as tel
from repro.obs.trace import Tracer

# scripts/check_trace.py doubles as the importable trace validator
_spec = importlib.util.spec_from_file_location(
    "check_trace", Path(__file__).parent.parent / "scripts" / "check_trace.py"
)
check_trace = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_trace)
validate_trace = check_trace.validate_trace


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_span_emits_matched_pair_and_aggregates():
    t = Tracer()
    t.enable()
    with t.span("work", cat="test", rid=7):
        t.instant("tick", cat="test")
    evs = t.events()
    assert [e["ph"] for e in evs] == ["B", "i", "E"]
    assert evs[0]["name"] == evs[2]["name"] == "work"
    assert evs[0]["args"] == {"rid": 7}
    s = t.stats()
    assert s["spans"]["work"]["count"] == 1
    assert s["spans"]["work"]["total_s"] >= 0
    assert s["spans"]["tick"]["count"] == 1
    assert validate_trace(t.chrome_trace()) == []


def test_disabled_tracer_emits_nothing_and_reuses_null_span():
    t = Tracer()
    assert not t.enabled
    s1 = t.span("a", rid=1)
    s2 = t.span("b")
    assert s1 is s2  # the cached null span: no allocation per call
    with s1:
        pass
    t.instant("x")
    t.counter("c", v=1)
    assert t.events() == [] and t.stats()["emitted"] == 0


def test_ring_bound_counts_drops_but_keeps_aggregates():
    t = Tracer(capacity=8)
    t.enable()
    for i in range(20):
        with t.span("w"):
            pass
    assert len(t.events()) == 8
    s = t.stats()
    assert s["dropped"] == 40 - 8 and s["emitted"] == 40
    # aggregate counts survive eviction even though events fell out
    assert s["spans"]["w"]["count"] == 20


def test_complete_events_resort_monotone_and_validate():
    """Retroactive X events (async scopes) are pushed at completion with
    an earlier start ts; the exporter must restore monotone order."""
    t = Tracer()
    t.enable()
    t0 = time.monotonic_ns() // 1000
    with t.span("inner"):
        pass
    t.complete("outer", t0, (time.monotonic_ns() // 1000) - t0, cat="http")
    raw = t.events()
    # pushed after inner's B/E, but starts before them
    assert raw[-1]["ph"] == "X" and raw[-1]["ts"] <= raw[0]["ts"]
    exported = t.chrome_trace()["traceEvents"]
    assert validate_trace({"traceEvents": exported}) == []
    assert [e["ts"] for e in exported] == sorted(e["ts"] for e in exported)
    assert t.stats()["spans"]["outer"]["count"] == 1


def test_export_sanitizes_orphan_E_and_unterminated_B():
    """Ring eviction can orphan half a B/E pair; the export must still be
    bracket-matched (what Perfetto and check_trace.py require)."""
    t = Tracer(capacity=3)
    t.enable()
    with t.span("evicted"):  # B will fall out of the 3-slot ring...
        with t.span("kept"):
            pass
        # ...leaving its E an orphan among ["kept" B, "kept" E, "evicted" E]
    raw = t.events()
    assert [e["ph"] for e in raw] == ["B", "E", "E"]
    exported = t.chrome_trace()["traceEvents"]
    assert validate_trace({"traceEvents": exported}) == []
    assert [e["name"] for e in exported] == ["kept", "kept"]

    t2 = Tracer()
    t2.enable()
    cm = t2.span("open")
    cm.__enter__()  # never exited: unterminated B must be dropped
    with t2.span("closed"):
        pass
    exported = t2.chrome_trace()["traceEvents"]
    assert validate_trace({"traceEvents": exported}) == []
    assert [e["name"] for e in exported] == ["closed", "closed"]


def test_clear_resets_buffer_and_counters():
    t = Tracer()
    t.enable()
    with t.span("w"):
        pass
    t.clear()
    s = t.stats()
    assert t.events() == [] and s["emitted"] == 0 and s["spans"] == {}


def test_validator_rejects_broken_traces():
    ok = {"traceEvents": [
        {"name": "a", "ph": "B", "ts": 1, "pid": 0, "tid": 1},
        {"name": "a", "ph": "E", "ts": 2, "pid": 0, "tid": 1},
    ]}
    assert validate_trace(ok) == []
    bad_order = {"traceEvents": [
        {"name": "a", "ph": "i", "ts": 5, "pid": 0, "tid": 1, "s": "t"},
        {"name": "b", "ph": "i", "ts": 1, "pid": 0, "tid": 1, "s": "t"},
    ]}
    assert any("backwards" in p for p in validate_trace(bad_order))
    orphan = {"traceEvents": [
        {"name": "a", "ph": "E", "ts": 1, "pid": 0, "tid": 1},
    ]}
    assert any("no open B" in p for p in validate_trace(orphan))
    unterminated = {"traceEvents": [
        {"name": "a", "ph": "B", "ts": 1, "pid": 0, "tid": 1},
    ]}
    assert any("unterminated" in p for p in validate_trace(unterminated))
    missing = {"traceEvents": [{"ph": "B", "ts": 1}]}
    assert any("missing keys" in p for p in validate_trace(missing))


# ---------------------------------------------------------------------------
# concurrency: engine worker thread + asyncio pump interleave
# ---------------------------------------------------------------------------


def test_tracer_thread_and_asyncio_interleave_stays_consistent():
    """The serving shape: worker threads emit nested B/E spans while
    event-loop coroutines emit retroactive X completes, all into one
    tracer. Nothing may corrupt — counts exact, export valid."""
    t = Tracer(capacity=100_000)
    t.enable()
    N, WORKERS, COROS = 200, 4, 8

    def worker(w):
        for i in range(N):
            with t.span("step", worker=w, i=i):
                with t.span("inner"):
                    pass

    async def coro(c):
        for i in range(N):
            t0 = time.monotonic_ns() // 1000
            await asyncio.sleep(0)  # force interleaving on the loop thread
            t.complete("request", t0, (time.monotonic_ns() // 1000) - t0,
                       coro=c, i=i)

    async def main():
        threads = [
            threading.Thread(target=worker, args=(w,)) for w in range(WORKERS)
        ]
        for th in threads:
            th.start()
        await asyncio.gather(*(coro(c) for c in range(COROS)))
        for th in threads:
            th.join()

    asyncio.run(main())
    s = t.stats()
    assert s["dropped"] == 0
    assert s["spans"]["step"]["count"] == WORKERS * N
    assert s["spans"]["inner"]["count"] == WORKERS * N
    assert s["spans"]["request"]["count"] == COROS * N
    exported = t.chrome_trace()
    assert validate_trace(exported) == []
    assert len(exported["traceEvents"]) == 4 * WORKERS * N + COROS * N


def test_disabled_tracer_overhead_is_negligible():
    """The <2% serving bound, asserted arithmetically with huge margin:
    an engine step is >= 1ms of device work and crosses a handful of
    trace sites; a disabled site must cost well under 50us per call
    (measured mean is ~100ns), so tracing-off overhead is < 0.1%."""
    t = Tracer()
    calls = 20_000
    t0 = time.perf_counter()
    for i in range(calls):
        with t.span("hot"):
            pass
    per_call = (time.perf_counter() - t0) / calls
    assert per_call < 50e-6, f"disabled span cost {per_call*1e6:.1f}us/call"
    assert t.events() == []


# ---------------------------------------------------------------------------
# quantization-health telemetry
# ---------------------------------------------------------------------------


def test_fp8_grad_stats_fractions():
    g = {"w": jnp.asarray([0.0, 1e-20, 60000.0, 1.0], jnp.float32)}
    out = jax.device_get(tel.fp8_grad_stats(g))
    assert out["fp8_sat_frac"] == pytest.approx(0.25)
    assert out["fp8_underflow_frac"] == pytest.approx(0.25)
    assert out["fp8_zero_frac"] == pytest.approx(0.25)


def test_layer_grad_norms_per_top_level_key():
    g = {
        "a": {"w": jnp.asarray([3.0, 4.0])},
        "b": jnp.asarray([5.0]),
    }
    out = jax.device_get(tel.layer_grad_norms(g))
    assert out["a"] == pytest.approx(5.0)
    assert out["b"] == pytest.approx(5.0)
    flat = jax.device_get(tel.layer_grad_norms(jnp.asarray([6.0, 8.0])))
    assert flat["all"] == pytest.approx(10.0)


def test_floatsd_update_stats_carry_and_clamp():
    old = {"w": jnp.full((4, 4), 1.0, jnp.float32)}
    moved = {"w": jnp.full((4, 4), 1.3, jnp.float32)}  # different grid point
    out = jax.device_get(tel.floatsd_update_stats(old, moved))
    assert out["sd_carry_frac"] == pytest.approx(1.0)
    same = jax.device_get(tel.floatsd_update_stats(old, old))
    assert same["sd_carry_frac"] == 0.0 and same["sd_clamp_frac"] == 0.0
    # 1-D leaves (biases) are excluded from the weight-update stats
    bias_only = jax.device_get(tel.floatsd_update_stats(
        {"b": jnp.asarray([1.0])}, {"b": jnp.asarray([2.0])}
    ))
    assert bias_only["sd_carry_frac"] == 0.0


def test_train_step_telemetry_metrics_shape():
    from repro.core.policy import get_policy
    from repro.models.lstm_models import WikiText2LM
    from repro.optim import sgd
    from repro.optim.train_state import init_state, make_train_step

    policy = get_policy("floatsd8_table6")
    model = WikiText2LM(vocab=64, emb=16, hidden=16, n_layers=1)
    opt = sgd(0.9)
    params = model.init(jax.random.PRNGKey(0))
    state = init_state(params, opt, policy)
    step = make_train_step(model.loss, opt, policy, lr=0.5, telemetry=True)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 64, (2, 8)), jnp.int32),
    }
    state, m = step(state, batch)
    t = jax.device_get(m["tel"])
    for k in ("fp8_sat_frac", "fp8_underflow_frac", "fp8_zero_frac",
              "sd_carry_frac", "sd_clamp_frac"):
        assert 0.0 <= float(t[k]) <= 1.0, k
    assert set(t["grad_norm"]) == set(params)
    # telemetry=False must not add the key
    state2 = init_state(params, opt, policy)
    _, m2 = make_train_step(model.loss, opt, policy, lr=0.5)(state2, batch)
    assert "tel" not in m2


def test_telemetry_logger_aggregates_and_writes_jsonl(tmp_path):
    path = tmp_path / "tel.jsonl"
    log = tel.TelemetryLogger(path=str(path))
    for step in range(1, 5):
        log.update(step, {
            "loss": 2.0, "grads_finite": step != 2,  # one skipped step
            "loss_scale": 1024.0 if step < 3 else 512.0,  # one backoff
            "tel": {
                "fp8_sat_frac": 0.1, "fp8_underflow_frac": 0.0,
                "fp8_zero_frac": 0.5, "sd_carry_frac": 0.25,
                "sd_clamp_frac": 0.0,
                "grad_norm": {"lstm0": 1.5},
            },
        })
    rec = log.emit(4)
    assert rec.window_steps == 4 and rec.loss_mean == pytest.approx(2.0)
    assert rec.nonfinite_steps == 1 and rec.scale_downs == 1
    assert rec.fp8_sat_frac == pytest.approx(0.1)
    assert rec.sd_carry_frac == pytest.approx(0.25)
    assert rec.grad_norms == {"lstm0": 1.5}
    [line] = path.read_text().splitlines()
    assert json.loads(line)["step"] == 4
    assert "sat" in log.format(rec)
    # window resets: a second emit covers only what came after
    log.update(5, {"loss": 4.0, "grads_finite": True, "loss_scale": 512.0})
    rec2 = log.emit(5)
    assert rec2.window_steps == 1 and rec2.loss_mean == pytest.approx(4.0)
    assert rec2.nonfinite_steps == 1  # cumulative counters persist
    assert len(path.read_text().splitlines()) == 2


def test_kernel_flush_hook_records_dw_stats():
    """matmul_dw with the sink enabled reports flush counts through
    jax.debug.callback; disabled, the hook stages out entirely."""
    from repro.kernels import dispatch as kd

    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    g = jnp.asarray(np.random.default_rng(1).normal(size=(4, 8)), jnp.float32)
    tel.KERNEL_STATS.reset()
    tel.KERNEL_STATS.enable()
    try:
        dw = jax.jit(lambda a, b: kd.matmul_dw(a, b, backend="ref"))(x, g)
        jax.block_until_ready(dw)
    finally:
        tel.KERNEL_STATS.disable()
    snap = tel.KERNEL_STATS.snapshot()
    assert snap["floatsd_matmul_dw"]["calls"] == 1
    assert snap["floatsd_matmul_dw"]["elems"] == dw.size
    assert 0.0 <= snap["floatsd_matmul_dw"]["zero_frac"] <= 1.0

    tel.KERNEL_STATS.reset()
    dw2 = jax.jit(lambda a, b: kd.matmul_dw(a, b, backend="ref"))(x, g)
    jax.block_until_ready(dw2)
    assert tel.KERNEL_STATS.snapshot() == {}  # disabled: staged out
