"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref.py oracle.

Every kernel is swept over shapes x dtypes and asserted allclose against its
oracle, per the deliverable spec. Property tests (hypothesis) cover the
tiling-independence invariant: block shape must never change the result.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import floatsd
from repro.kernels.floatsd_matmul.kernel import floatsd_matmul_pallas
from repro.kernels.floatsd_matmul.ops import floatsd_matmul
from repro.kernels.floatsd_matmul.ref import floatsd_matmul_ref
from repro.kernels.floatsd_quantize.kernel import quantize_pallas
from repro.kernels.floatsd_quantize.ops import floatsd_quantize
from repro.kernels.floatsd_quantize.ref import quantize_ref
from repro.kernels.lstm_cell.kernel import lstm_cell_pallas
from repro.kernels.lstm_cell.ops import lstm_cell
from repro.kernels.lstm_cell.ref import lstm_cell_ref

def _w(shape, scale=1.0, dtype=np.float32):
    # order-independent: seed from the call signature, not shared state
    seed = (hash((shape, float(scale))) & 0x7FFFFFFF) or 1
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# floatsd_quantize
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(8, 256), (256, 256), (64, 512), (2, 1024)])
@pytest.mark.parametrize("scale", [1e-3, 1.0, 37.5])
def test_quantize_kernel_matches_oracle(shape, scale):
    x = jnp.asarray(_w(shape, scale))
    bias = floatsd.fit_bias(x)
    got = quantize_pallas(x, bias, bm=min(256, shape[0]), bn=256, interpret=True)
    want = quantize_ref(x, bias)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_quantize_kernel_dtypes(dtype):
    x = jnp.asarray(_w((64, 256))).astype(dtype)
    bias = floatsd.fit_bias(x)
    got = quantize_pallas(x, bias, bm=64, bn=256, interpret=True)
    want = quantize_ref(x, bias)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize(
    "shape", [(3,), (5, 7), (4, 64), (2, 3, 256), (1024,), (16, 16, 16)]
)
def test_quantize_wrapper_any_shape(shape):
    """ops.floatsd_quantize handles arbitrary shapes (kernel or fallback) and
    decode(quantize(x)) == quantize(x).values exactly."""
    x = jnp.asarray(_w(shape))
    codes, bias = floatsd_quantize(x, interpret=True)
    assert codes.shape == x.shape and codes.dtype == jnp.uint8
    dec = floatsd.decode(codes, bias)
    want = floatsd.quantize(x, bias).values
    np.testing.assert_allclose(np.asarray(dec), np.asarray(want), rtol=0, atol=0)


@settings(max_examples=30, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32, 64, 128, 256]),
    bn=st.sampled_from([256]),
    scale=st.floats(1e-4, 1e3),
)
def test_quantize_tiling_independence(bm, bn, scale):
    """Property: block shape never changes the quantization result."""
    x = jnp.asarray(_w((256, 256), scale))
    bias = floatsd.fit_bias(x)
    a = quantize_pallas(x, bias, bm=bm, bn=bn, interpret=True)
    b = quantize_ref(x, bias)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# floatsd_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,k,n", [(8, 128, 128), (32, 256, 256), (128, 512, 256), (256, 1024, 512)]
)
def test_matmul_kernel_matches_oracle(m, k, n):
    x = jnp.asarray(_w((m, k), 0.5))
    wts = jnp.asarray(_w((k, n), 0.05))
    codes, bias = floatsd.encode(wts)
    got = floatsd_matmul(x, codes, bias, interpret=True)
    want = floatsd_matmul_ref(x, codes, bias)
    # kernel computes in bf16 (MXU issue dtype), oracle in f32: bf16 has 8
    # mantissa bits -> rtol ~ 2^-7 per element, K-sum in f32 keeps it tight
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16, jnp.float8_e5m2])
def test_matmul_kernel_activation_dtypes(xdtype):
    """The paper's MAC takes FP8 activations; bf16/f32 also supported."""
    x = jnp.asarray(_w((32, 256), 0.5)).astype(xdtype)
    wts = jnp.asarray(_w((256, 128), 0.05))
    codes, bias = floatsd.encode(wts)
    got = floatsd_matmul(x, codes, bias, interpret=True)
    want = floatsd_matmul_ref(x, codes, bias)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.float16, jnp.bfloat16])
def test_matmul_kernel_out_dtypes(out_dtype):
    x = jnp.asarray(_w((16, 128), 0.5))
    wts = jnp.asarray(_w((128, 128), 0.05))
    codes, bias = floatsd.encode(wts)
    got = floatsd_matmul(x, codes, bias, out_dtype=out_dtype, interpret=True)
    assert got.dtype == out_dtype
    want = floatsd_matmul_ref(x, codes, bias, out_dtype)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2,
    )


@settings(max_examples=20, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 64, 128]),
    bn=st.sampled_from([128, 256]),
    bk=st.sampled_from([128, 256, 512]),
)
def test_matmul_tiling_independence(bm, bn, bk):
    """Property: (bm, bn, bk) tiling never changes the accumulated result
    beyond bf16 rounding of the decoded weight tile (which is tile-invariant
    because decode is element-wise)."""
    x = jnp.asarray(_w((128, 512), 0.5))
    wts = jnp.asarray(_w((512, 256), 0.05))
    codes, bias = floatsd.encode(wts)
    got = floatsd_matmul_pallas(x, codes, bias, bm=bm, bn=bn, bk=bk, interpret=True)
    ref = floatsd_matmul_pallas(
        x, codes, bias, bm=128, bn=256, bk=512, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_matmul_fallback_indivisible_shapes():
    x = jnp.asarray(_w((7, 130), 0.5))
    wts = jnp.asarray(_w((130, 66), 0.05))
    codes, bias = floatsd.encode(wts)
    got = floatsd_matmul(x, codes, bias, interpret=True)
    want = floatsd_matmul_ref(x, codes, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


# ---------------------------------------------------------------------------
# lstm_cell (fused element-wise neuron stage)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h", [(8, 128), (32, 256), (128, 512), (16, 1024)])
@pytest.mark.parametrize("quantized", [True, False])
def test_lstm_cell_kernel_matches_oracle(b, h, quantized):
    z = jnp.asarray(_w((b, 4 * h), 1.5))
    c = jnp.asarray(_w((b, h), 0.8))
    h_got, c_got = lstm_cell(z, c, quantized=quantized, interpret=True)
    h_want, c_want = lstm_cell_ref(z, c, quantized)
    assert c_got.dtype == jnp.float16  # paper: FP16 cell state
    # h tolerance: one FP16 rounding of c feeding tanh can differ by half an
    # ulp between the fused and unfused compute orders -> rel ~6e-4
    np.testing.assert_allclose(
        np.asarray(h_got), np.asarray(h_want), rtol=1e-3, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(c_got, np.float32), np.asarray(c_want, np.float32),
        rtol=1e-3, atol=1e-4,
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lstm_cell_kernel_dtypes(dtype):
    z = jnp.asarray(_w((8, 4 * 128), 1.5)).astype(dtype)
    c = jnp.asarray(_w((8, 128), 0.8)).astype(dtype)
    h_got, c_got = lstm_cell(z, c, quantized=True, interpret=True)
    h_want, c_want = lstm_cell_ref(z, c, True)
    assert h_got.dtype == dtype
    got = np.asarray(h_got, np.float32)
    want = np.asarray(h_want, np.float32)
    if dtype == jnp.float32:
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    else:
        # bf16: the kernel computes sigma in f32 while the oracle's sigma is
        # bf16-rounded — inputs that straddle a quantizer midpoint flip by
        # one FloatSD8 grid step. Require: <5% boundary flips, each within
        # one grid step (~0.094 around sigma ~ 0.3), everything else tight.
        diff = np.abs(got - want)
        bad = diff > 2e-2 + 2e-2 * np.abs(want)
        assert bad.mean() < 0.05, bad.mean()
        assert diff.max() <= 0.13, diff.max()  # max FloatSD8 grid gap in (0,1)


@settings(max_examples=15, deadline=None)
@given(
    bb=st.sampled_from([8, 16, 32]),
    bh=st.sampled_from([128, 256]),
)
def test_lstm_cell_tiling_independence(bb, bh):
    z = jnp.asarray(_w((32, 4 * 256), 1.5))
    c = jnp.asarray(_w((32, 256), 0.8))
    h_got, c_got = lstm_cell_pallas(z, c, bb=bb, bh=bh, quantized=True, interpret=True)
    h_want, c_want = lstm_cell_ref(z, c, True)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(c_got, np.float32), np.asarray(c_want, np.float32), rtol=1e-3, atol=1e-4
    )


def test_lstm_cell_fallback_indivisible():
    z = jnp.asarray(_w((5, 4 * 70), 1.5))
    c = jnp.asarray(_w((5, 70), 0.8))
    h_got, c_got = lstm_cell(z, c, quantized=True, interpret=True)
    h_want, c_want = lstm_cell_ref(z, c, True)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want), rtol=1e-6)


def test_lstm_cell_gate_saturation():
    """Saturated gates: f=1,i=0 must preserve c exactly (memory retention),
    f=0,i=1 must overwrite with g — the LSTM invariant the paper's FP16 cell
    state must not break."""
    b, h = 8, 128
    big = 30.0
    c = jnp.asarray(_w((b, h), 0.4))
    # z layout: [i | f | g | o]
    z_keep = jnp.concatenate(
        [jnp.full((b, h), -big), jnp.full((b, h), big),
         jnp.zeros((b, h)), jnp.full((b, h), big)], axis=-1
    ).astype(jnp.float32)
    _, c_keep = lstm_cell(z_keep, c, quantized=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(c_keep, np.float32), np.asarray(c, np.float32),
        rtol=1e-3, atol=1e-3,  # one FP16 round of c
    )
    g_val = 0.75
    zg = jnp.arctanh(jnp.asarray(g_val, jnp.float32))
    z_over = jnp.concatenate(
        [jnp.full((b, h), big), jnp.full((b, h), -big),
         jnp.full((b, h), zg), jnp.full((b, h), big)], axis=-1
    ).astype(jnp.float32)
    _, c_over = lstm_cell(z_over, c, quantized=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(c_over, np.float32), g_val, rtol=3e-2, atol=1e-2  # FP8 tanh LUT
    )


# ---------------------------------------------------------------------------
# cross-kernel integration: quantize -> matmul == fake-quant dense
# ---------------------------------------------------------------------------


def test_quantize_then_matmul_equals_fakequant_dense():
    x = jnp.asarray(_w((32, 256), 0.5))
    wts = jnp.asarray(_w((256, 128), 0.05))
    codes, bias = floatsd_quantize(wts, interpret=True)
    y_kernel = floatsd_matmul(x, codes, bias, interpret=True)
    wq = floatsd.quantize(wts).values
    y_fake = x @ wq
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_fake), rtol=2e-2, atol=2e-2
    )
