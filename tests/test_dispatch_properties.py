"""Property-based sweeps (hypothesis) for the dispatch layer and the
FloatSD8 encode/decode round-trip.

Behind the importorskip guard like the other hypothesis suites: containers
without hypothesis skip this module; the deterministic parity grid in
tests/test_dispatch_parity.py still runs everywhere.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import floatsd, floatsd4
from repro.kernels import dispatch as kd
from repro.kernels.floatsd_matmul import cost as fm_cost

pytestmark = pytest.mark.slow  # interpret-mode pallas sweeps are tier-2


@settings(max_examples=40, deadline=None)
@given(
    dims=st.lists(st.integers(1, 8), min_size=1, max_size=3),
    scale=st.floats(1e-6, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_encode_decode_roundtrip_equals_fake_quant(dims, scale, seed):
    """decode(encode(x)) must be bit-identical to quantize(x).values for
    arbitrary shapes and magnitude windows — the serving weight-store
    invariant."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.standard_normal(tuple(dims)) * scale).astype(np.float32))
    codes, bias = floatsd.encode(x)
    np.testing.assert_array_equal(
        np.asarray(floatsd.decode(codes, bias)),
        np.asarray(floatsd.quantize(x, bias).values),
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 12),
    k=st.integers(1, 160),
    n=st.integers(1, 160),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_pad_then_crop_equals_oracle(m, k, n, seed):
    """Property: the padded-then-cropped pallas result equals the unpadded
    oracle for arbitrary M/K/N (zero activations x zero-code weights add an
    exact 0.0)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32) * 0.5)
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32) * 0.05)
    codes, bias = floatsd.encode(w)
    with kd.use_backend("pallas"):
        got = kd.matmul(x, codes, bias)
    want = kd.matmul(x, codes, bias, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 10),
    h=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_lstm_cell_pad_then_crop_equals_oracle(b, h, seed):
    rng = np.random.default_rng(seed)
    z = jnp.asarray(rng.standard_normal((b, 4 * h)).astype(np.float32) * 1.5)
    c = jnp.asarray(rng.standard_normal((b, h)).astype(np.float32) * 0.8)
    with kd.use_backend("pallas"):
        h_got, c_got = kd.lstm_cell(z, c)
    h_want, c_want = kd.lstm_cell(z, c, backend="ref")
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want), rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(c_got, np.float32), np.asarray(c_want, np.float32),
        rtol=1e-3, atol=1e-4,
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 16),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_ref_predicted_bytes_equal_touched_for_arbitrary_matmul(m, k, n, seed):
    """Cost-model property: on the ref backend the analytical HBM-byte
    prediction equals the ndarray bytes the dispatch actually handed the
    oracle — exactly, for arbitrary shapes (the tolerance-0 contract the
    parametrized grid in tests/test_costmodel.py spot-checks)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32) * 0.5)
    codes, bias = floatsd.encode(
        jnp.asarray(rng.standard_normal((k, n)).astype(np.float32) * 0.05)
    )
    kd.STATS.reset()
    kd.matmul(x, codes, bias, backend="ref")
    (row,) = kd.LEDGER.rows()
    assert row["backend"] == "ref"
    assert row["hbm_bytes"] == row["touched_bytes"]
    assert row["bytes_rel_err"] == 0.0


# ---------------------------------------------------------------------------
# FloatSD4 sub-byte properties
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(1, 130),
    n=st.integers(1, 48),
    scale=st.floats(1e-6, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_floatsd4_pack_unpack_roundtrip_bit_identical(k, n, scale, seed):
    """Nibble pack -> unpack returns the exact uint8 code array for any K
    parity (odd K pads one ZERO_CODE row, cropped back out)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.standard_normal((k, n)) * scale).astype(np.float32))
    codes, _ = floatsd4.encode(x)
    packed = floatsd4.pack_nibbles(codes)
    assert packed.shape == (-(-k // 2), n) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(floatsd4.unpack_nibbles(packed, k)), np.asarray(codes)
    )


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(1, 130),
    n=st.integers(1, 48),
    scale=st.floats(1e-6, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_floatsd4_decode_encode_idempotent(k, n, scale, seed):
    """encode(decode(encode(x))) reproduces codes AND group exponents bit
    for bit: the FloatSD4 grid is a fixed point of its own quantizer (the
    same invariant FloatSD8 serving relies on)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.standard_normal((k, n)) * scale).astype(np.float32))
    codes, exps = floatsd4.encode(x)
    w = floatsd4.decode(codes, exps)
    codes2, exps2 = floatsd4.encode(w)
    np.testing.assert_array_equal(np.asarray(codes2), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(exps2), np.asarray(exps))
    np.testing.assert_array_equal(
        np.asarray(floatsd4.decode(codes2, exps2)), np.asarray(w)
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 12),
    k=st.integers(1, 160),
    n=st.integers(1, 160),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul4_pad_then_crop_equals_oracle(m, k, n, seed):
    """Property: padded-then-cropped pallas matmul4 equals the unpadded
    decode-then-dot oracle for arbitrary M/K/N — odd K covers the nibble
    pad row, arbitrary K covers partial exponent groups."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32) * 0.5)
    w4 = kd.pack4(jnp.asarray(rng.standard_normal((k, n)).astype(np.float32) * 0.05))
    with kd.use_backend("pallas"):
        got = kd.matmul4(x, w4)
    want = kd.matmul4(x, w4, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 16),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul4_ref_predicted_bytes_equal_touched(m, k, n, seed):
    """Tolerance-0 cost contract for the sub-byte op: predicted HBM bytes
    (ceil(K/2)*N codes + ceil(K/GROUP)*N exps + x + y) equal the ndarray
    bytes handed to the oracle, for arbitrary shapes."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32) * 0.5)
    w4 = kd.pack4(jnp.asarray(rng.standard_normal((k, n)).astype(np.float32) * 0.05))
    kd.STATS.reset()
    kd.matmul4(x, w4, backend="ref")
    (row,) = kd.LEDGER.rows()
    assert row["backend"] == "ref"
    assert row["hbm_bytes"] == row["touched_bytes"]
    assert row["bytes_rel_err"] == 0.0


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 256),
    n=st.integers(1, 256),
    dm=st.integers(0, 64),
    dk=st.integers(0, 128),
    dn=st.integers(0, 128),
)
def test_growing_padding_never_decreases_predicted_waste(m, k, n, dm, dk, dn):
    """Cost-model property: padding dims further out (pad-then-crop with a
    bigger pad) can only grow the predicted waste, never shrink it — the
    monotonicity the dispatch's tile-rounding relies on when attributing
    pad_waste_* to a Decision."""
    base = fm_cost.matmul_fwd_cost(
        m, k, n, backend="pallas", padded=(m, k, n), tiles=(1, 1, 1)
    )
    grown = fm_cost.matmul_fwd_cost(
        m, k, n, backend="pallas",
        padded=(m + dm, k + dk, n + dn), tiles=(1, 1, 1),
    )
    assert grown.pad_waste_bytes >= base.pad_waste_bytes
    assert grown.pad_waste_flops >= base.pad_waste_flops
    # and with zero extra padding the waste is zero on both axes
    assert base.pad_waste_flops == 0
