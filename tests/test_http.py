"""HTTP/SSE serving layer tests: ephemeral-port server over a real
socket — happy path + token agreement vs the in-process AsyncRouter, SSE
wire framing, the four reject-reason → distinct-status mappings under
induced overload, concurrent tenants, drain semantics, and a /metrics
scrape that parses as Prometheus text exposition."""
import asyncio
import json
import re

import jax
import numpy as np
import pytest

from repro.core.policy import get_policy
from repro.models.lstm_models import WikiText2LM
from repro.serving import PrefixCache, Router, ServeEngine
from repro.serving.frontend import AsyncRouter
from repro.serving.http import Client, HttpError, HttpServer, REASON_STATUS
from repro.serving.http.protocol import HttpRequest, ProtocolError

POLICY = get_policy("floatsd8_table6")


def tiny_model():
    return WikiText2LM(vocab=300, emb=32, hidden=32, n_layers=2)


_PARAMS = {}


def tiny_params(model, seed=0):
    key = (model.vocab, model.emb, model.hidden, model.n_layers, seed)
    if key not in _PARAMS:
        _PARAMS[key] = model.init(jax.random.PRNGKey(seed))
    return _PARAMS[key]


def make_router(replicas=1, lanes=2, chunk=4, cache=None, **router_kw):
    model = tiny_model()
    params = tiny_params(model)
    engines = [
        ServeEngine(model, params, POLICY, lanes=lanes, chunk=chunk,
                    prefix_cache=cache)
        for _ in range(replicas)
    ]
    return Router(engines, **router_kw)


async def start_server(router, **kw):
    server = await HttpServer(router, port=0, **kw).start()
    task = asyncio.create_task(server.serve_forever())
    return server, task


async def stop_server(server, task):
    server.shutdown()
    await asyncio.wait_for(task, timeout=30)


def prompts_for(model, n, length=6, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, model.vocab, length).astype(np.int32) for _ in range(n)]


# ---------------------------------------------------------------------------
# happy path + agreement with the in-process router
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_generate_over_socket_agrees_with_in_process_router():
    """The acceptance bar: /v1/generate through a real TCP socket returns
    exactly the tokens the in-process AsyncRouter produces for the same
    prompts (same params, fresh identical routers)."""
    prompts = prompts_for(tiny_model(), 3, seed=3)

    async def via_http():
        server, task = await start_server(make_router())
        try:
            async with Client(server.host, server.port) as c:
                out = [await c.generate(p, max_new=5) for p in prompts]
            return out
        finally:
            await stop_server(server, task)

    async def via_router():
        ar = AsyncRouter(make_router())
        return [await ar.generate(p, max_new=5) for p in prompts]

    http_out = asyncio.run(via_http())
    tickets = asyncio.run(via_router())

    for resp, ticket in zip(http_out, tickets):
        assert resp["tokens"] == ticket.tokens and len(resp["tokens"]) == 5
        assert resp["n_tokens"] == 5
        assert 0 <= resp["ttft_ms"] <= resp["latency_ms"]
        assert resp["tenant"] == "default"


def test_sse_stream_framing_and_generate_consistency():
    """SSE frames parse (index/token per frame, terminal done event) and
    the streamed tokens equal /v1/generate's for the same prompt; the raw
    wire bytes follow the documented event-stream framing."""
    [prompt] = prompts_for(tiny_model(), 1, seed=4)

    async def main():
        server, task = await start_server(make_router())
        try:
            async with Client(server.host, server.port) as c:
                gen = await c.generate(prompt, max_new=4)
                events = [ev async for ev in c.stream(prompt, max_new=4)]

            # raw-socket view of the same stream: exact wire framing
            reader, writer = await asyncio.open_connection(
                server.host, server.port
            )
            body = json.dumps({"prompt": prompt.tolist(), "max_new": 2})
            writer.write(
                (
                    "POST /v1/stream HTTP/1.1\r\nHost: t\r\n"
                    "Content-Type: application/json\r\n"
                    f"Content-Length: {len(body)}\r\n\r\n{body}"
                ).encode()
            )
            await writer.drain()
            raw = await reader.read()  # server closes after the stream
            writer.close()
            return gen, events, raw
        finally:
            await stop_server(server, task)

    gen, events, raw = asyncio.run(main())

    start, *token_events, done = events
    assert start[0] == "start" and isinstance(start[1]["rid"], int)
    assert done[0] == "done" and done[1]["n_tokens"] == 4
    assert [e for e, _ in token_events] == ["message"] * 4
    assert [d["index"] for _, d in token_events] == [0, 1, 2, 3]
    assert [d["token"] for _, d in token_events] == gen["tokens"]
    assert done[1]["ttft_ms"] <= done[1]["latency_ms"]

    head, _, payload = raw.partition(b"\r\n\r\n")
    assert b"200" in head.split(b"\r\n")[0]
    assert b"content-type: text/event-stream" in head.lower()
    assert b"connection: close" in head.lower()
    frames = [f for f in payload.decode().split("\n\n") if f]
    assert len(frames) == 4  # start + 2 tokens + done
    assert frames[0].startswith("event: start\ndata: ")
    for f in frames[1:-1]:
        assert f.startswith("data: ")
        json.loads(f.split("data: ", 1)[1])
    assert frames[-1].startswith("event: done\ndata: ")


# ---------------------------------------------------------------------------
# reject reasons -> distinct status codes
# ---------------------------------------------------------------------------


def test_reject_reasons_map_to_distinct_status_codes():
    assert len(set(REASON_STATUS.values())) == 4  # distinct by construction

    async def main():
        statuses = {}
        # induced overload: a zero-length router queue bounces everything
        server, task = await start_server(make_router(max_queue=0))
        try:
            async with Client(server.host, server.port) as c:
                with pytest.raises(HttpError) as ei:
                    await c.generate([1, 2, 3], max_new=1)
                statuses["queue_full"] = (ei.value.status, ei.value.body["error"])
        finally:
            await stop_server(server, task)

        # tenant over quota (admission checks quota before validating the
        # request, so this needs its own non-overloaded router)
        server, task = await start_server(
            make_router(max_queue=8, tenant_quota=0)
        )
        try:
            async with Client(server.host, server.port) as c:
                with pytest.raises(HttpError) as ei:
                    await c.generate([1, 2, 3], max_new=1, tenant="t0")
                statuses["tenant_quota"] = (ei.value.status, ei.value.body["error"])
        finally:
            await stop_server(server, task)

        # empty prompt + dead-on-arrival deadline on a healthy router
        server, task = await start_server(make_router())
        try:
            async with Client(server.host, server.port) as c:
                with pytest.raises(HttpError) as ei:
                    await c.generate([], max_new=1)
                statuses["bad_request"] = (ei.value.status, ei.value.body["error"])
                with pytest.raises(HttpError) as ei:
                    await c.generate([1, 2, 3], max_new=1, deadline_ms=-1000)
                statuses["deadline_expired"] = (
                    ei.value.status, ei.value.body["error"],
                )
                # HTTP-level (pre-router) validation is 400 too
                status, _, body = await c.request(
                    "POST", "/v1/generate", {"max_new": 1}
                )
                assert status == 400
                assert "prompt" in json.loads(body)["detail"]
        finally:
            await stop_server(server, task)
        return statuses

    statuses = asyncio.run(main())
    for reason, (status, err) in statuses.items():
        assert status == REASON_STATUS[reason], (reason, status)
        assert err == reason
    assert len({s for s, _ in statuses.values()}) == 4  # distinct on the wire


@pytest.mark.slow
def test_stream_rejected_after_admission_sends_error_event():
    """A stream whose deadline expires while queued was admitted before
    the 200 preamble went out; the mapped status must arrive as a
    terminal SSE `error` event (the client raises HttpError from it)."""
    model = tiny_model()
    long_p, short_p = prompts_for(model, 2, seed=9)

    async def main():
        # one lane: the first request occupies it, the second queues
        server, task = await start_server(make_router(lanes=1))
        try:
            blocker = Client(server.host, server.port)
            victim = Client(server.host, server.port)
            gen = blocker.stream(long_p, max_new=64)
            await gen.__anext__()  # start event (pre-admission)
            await gen.__anext__()  # first token: lane busy for ~63 pumps
            try:
                with pytest.raises(HttpError) as ei:
                    # expires while queued: 63 pumps >> 5ms, but the
                    # submit itself happens microseconds after parse, so
                    # it is never dead-on-arrival
                    async for _ in victim.stream(
                        short_p, max_new=1, deadline_ms=5
                    ):
                        pass
                status = ei.value.status
                reason = ei.value.body["error"]
            finally:
                async for _ in gen:  # let the blocker finish
                    pass
                await blocker.close()
                await victim.close()
            return status, reason
        finally:
            await stop_server(server, task)

    status, reason = asyncio.run(main())
    assert status == REASON_STATUS["deadline_expired"] == 504
    assert reason == "deadline_expired"


def test_protocol_errors_and_unknown_routes():
    async def main():
        server, task = await start_server(make_router())
        try:
            async with Client(server.host, server.port) as c:
                s1, _, _ = await c.request("GET", "/nope")
                s2, _, _ = await c.request("GET", "/v1/generate")  # wrong verb
                # malformed JSON body
                reader, writer = await asyncio.open_connection(
                    server.host, server.port
                )
                writer.write(
                    b"POST /v1/generate HTTP/1.1\r\nHost: t\r\n"
                    b"Content-Length: 4\r\n\r\n{oop"
                )
                await writer.drain()
                line = await reader.readline()
                writer.close()
                return s1, s2, line
        finally:
            await stop_server(server, task)

    s1, s2, line = asyncio.run(main())
    assert s1 == 404 and s2 == 405
    assert b"400" in line


def test_protocol_request_parsing_units():
    """protocol.py parsing units, no socket: header casing, query strip,
    json() validation."""
    req = HttpRequest(
        method="POST",
        target="/v1/generate?x=1",
        headers={"x-tenant": "a", "connection": "close"},
        body=b'{"prompt": [1]}',
    )
    assert req.path == "/v1/generate"
    assert not req.keep_alive
    assert req.json() == {"prompt": [1]}
    with pytest.raises(ProtocolError) as ei:
        HttpRequest("POST", "/", {}, b"[1, 2]").json()
    assert ei.value.status == 400
    with pytest.raises(ProtocolError):
        HttpRequest("POST", "/", {}, b"{nope").json()


# ---------------------------------------------------------------------------
# concurrency + tenants
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_concurrent_tenants_over_http():
    prompts = prompts_for(tiny_model(), 4, seed=5)

    async def main():
        server, task = await start_server(make_router(lanes=2))
        try:
            async def one(i, prompt):
                async with Client(
                    server.host, server.port, tenant=("a", "b")[i % 2]
                ) as c:
                    return await c.generate(prompt, max_new=3)

            results = await asyncio.gather(
                *(one(i, p) for i, p in enumerate(prompts))
            )
            async with Client(server.host, server.port) as c:
                health = await c.healthz()
            return results, health, server.router.report()
        finally:
            await stop_server(server, task)

    results, health, report = asyncio.run(main())
    assert all(len(r["tokens"]) == 3 for r in results)
    assert {r["tenant"] for r in results} == {"a", "b"}
    assert health["status"] == "ok" and health["inflight"] == 0
    assert health["free_lanes"] == health["lanes"] == 2
    assert report["tenants"]["a"]["completed"] == 2
    assert report["tenants"]["b"]["completed"] == 2


# ---------------------------------------------------------------------------
# drain semantics
# ---------------------------------------------------------------------------


def test_drain_stops_admission_finishes_inflight_and_exits():
    model = tiny_model()
    [prompt] = prompts_for(model, 1, seed=6)

    async def main():
        server, task = await start_server(make_router())
        admin = Client(server.host, server.port)
        streamer = Client(server.host, server.port)
        try:
            gen = streamer.stream(prompt, max_new=12)
            start = await gen.__anext__()
            assert start[0] == "start"
            first = await gen.__anext__()  # request is now in flight
            assert first[0] == "message"

            d = await admin.drain()
            assert d["status"] == "draining" and d["inflight"] == 1
            # admission is stopped: new work bounces with 503 draining
            with pytest.raises(HttpError) as ei:
                await admin.generate(prompt, max_new=1)
            assert ei.value.status == 503
            assert ei.value.body["error"] == "draining"
            health = await admin.healthz()
            assert health["status"] == "draining"
            # drain is idempotent
            assert (await admin.drain())["status"] == "draining"

            # ...but the in-flight stream runs to completion
            events = [first] + [ev async for ev in gen]
            *toks, done = events
            assert done[0] == "done" and len(toks) == 12

            # and the server exits cleanly once idle
            await asyncio.wait_for(task, timeout=30)
            return True
        finally:
            await admin.close()
            await streamer.close()
            if not task.done():
                await stop_server(server, task)

    assert asyncio.run(main())


# ---------------------------------------------------------------------------
# metrics exposition
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? [-+]?[0-9.e+-]+(e[+-]?\d+)?$"
)


def test_metrics_scrape_parses_as_prometheus_text():
    [prompt] = prompts_for(tiny_model(), 1, seed=7)

    async def main():
        # admit_retries=0: each in-server retry is a fresh router
        # submission and would inflate the rejection counter below
        server, task = await start_server(
            make_router(cache=PrefixCache(block=4), max_queue=0),
            admit_retries=0,
        )
        # max_queue=0 also records one rejection for the counter below
        try:
            async with Client(server.host, server.port) as c:
                with pytest.raises(HttpError):
                    await c.generate(prompt, max_new=1)
                status, hdrs, data = await c.request("GET", "/metrics")
            return status, hdrs, data.decode()
        finally:
            await stop_server(server, task)

    status, hdrs, text = asyncio.run(main())
    assert status == 200
    assert hdrs["content-type"].startswith("text/plain; version=0.0.4")

    samples = {}
    for line in text.strip().splitlines():
        if line.startswith("#"):
            assert line.startswith(("# HELP ", "# TYPE "))
            continue
        assert _SAMPLE_RE.match(line), line
        name_labels, value = line.rsplit(" ", 1)
        samples[name_labels] = float(value)

    assert samples["repro_up"] == 1.0
    assert samples["repro_requests_total"] == 0.0
    assert samples["repro_free_lanes"] == 2.0
    assert samples['repro_rejections_total{reason="queue_full"}'] == 1.0
    # prefix-cache gauges present when a cache is attached
    assert "repro_cache_entries" in samples
    assert samples["repro_cache_budget_bytes"] > 0
    assert "repro_cache_hits_total" in samples


@pytest.mark.slow
def test_metrics_tenant_percentiles_after_traffic():
    [prompt] = prompts_for(tiny_model(), 1, seed=8)

    async def main():
        server, task = await start_server(make_router())
        try:
            async with Client(server.host, server.port, tenant="acme") as c:
                await c.generate(prompt, max_new=2)
                return await c.metrics()
        finally:
            await stop_server(server, task)

    text = asyncio.run(main())
    assert 'repro_tenant_completed_total{tenant="acme"} 1' in text
    assert 'repro_tenant_ttft_seconds{tenant="acme",quantile="0.95"}' in text
    assert 'repro_tenant_latency_seconds{tenant="acme",quantile="0.5"}' in text


def test_metrics_latency_histograms_and_cost_ledger_exposition():
    """TTFT/TPOT land as cumulative Prometheus histograms (monotone
    ``_bucket{le=...}`` series capped by +Inf == ``_count``) and the kernel
    cost ledger is exported per (op, backend)."""
    [prompt] = prompts_for(tiny_model(), 1, seed=9)

    async def main():
        server, task = await start_server(make_router())
        try:
            async with Client(server.host, server.port) as c:
                await c.generate(prompt, max_new=4)
                return await c.metrics()
        finally:
            await stop_server(server, task)

    text = asyncio.run(main())

    for name in ("repro_ttft_ms", "repro_tpot_ms"):
        buckets = []  # (le, value) in exposition order
        for line in text.splitlines():
            if line.startswith(f"{name}_bucket{{"):
                le = line.split('le="', 1)[1].split('"', 1)[0]
                buckets.append((le, float(line.rsplit(" ", 1)[1])))
        assert buckets, f"{name}_bucket series missing"
        assert buckets[-1][0] == "+Inf"
        values = [v for _, v in buckets]
        assert values == sorted(values), f"{name} buckets not cumulative"
        count = float(
            next(l for l in text.splitlines() if l.startswith(f"{name}_count"))
            .rsplit(" ", 1)[1]
        )
        assert values[-1] == count, f"{name} +Inf bucket != _count"
        assert count >= 1  # one request retired → at least one observation

    # the cost-model observatory: predicted-cost counters per (op, backend)
    assert 'repro_cost_flops_total{op="floatsd_matmul"' in text
    assert 'repro_cost_flops_total{op="lstm_cell"' in text
    assert "repro_cost_hbm_read_bytes_total{" in text
    assert "repro_cost_arithmetic_intensity{" in text


# ---------------------------------------------------------------------------
# observability: /admin/trace, debug phase breakdowns, scrape consistency
# ---------------------------------------------------------------------------

import importlib.util as _ilu
from pathlib import Path as _Path

from repro.obs.trace import TRACER

_spec = _ilu.spec_from_file_location(
    "check_trace", _Path(__file__).parent.parent / "scripts" / "check_trace.py"
)
_check_trace = _ilu.module_from_spec(_spec)
_spec.loader.exec_module(_check_trace)
validate_trace = _check_trace.validate_trace


def test_admin_trace_exports_valid_chrome_trace_with_lifecycle_spans():
    """GET /admin/trace after real traffic: the export validates (required
    keys, monotone ts, matched B/E) and carries the request-lifecycle
    span names end to end."""
    [prompt] = prompts_for(tiny_model(), 1, seed=11)

    async def main():
        server, task = await start_server(make_router(cache=PrefixCache(block=4)))
        TRACER.clear()
        try:
            async with Client(server.host, server.port) as c:
                await c.generate(prompt, max_new=3)
                return await c.trace()
        finally:
            await stop_server(server, task)

    trace = asyncio.run(main())
    assert validate_trace(trace) == []
    evs = trace["traceEvents"]
    names = {e["name"] for e in evs}
    for expected in ("http.request", "router.submit", "router.dispatch",
                     "router.pump", "engine.admit", "cache.lookup",
                     "engine.step", "engine.retire"):
        assert expected in names, expected
    # engine.step carries per-lane attribution and prefill/decode kind
    step_args = [e["args"] for e in evs
                 if e["name"] == "engine.step" and e["ph"] == "B"]
    assert any(a.get("kind") == "prefill" for a in step_args)
    assert any(a.get("kind") == "decode" for a in step_args)
    assert all("lanes" in a for a in step_args)


def test_debug_flag_returns_phase_breakdown():
    """`"debug": true` adds the queue/prefill/decode decomposition to
    /v1/generate and to the SSE terminal done event; absent by default;
    non-bool debug is a 400."""
    [prompt] = prompts_for(tiny_model(), 1, seed=12)

    async def main():
        server, task = await start_server(make_router(cache=PrefixCache(block=4)))
        try:
            async with Client(server.host, server.port) as c:
                plain = await c.generate(prompt, max_new=3)
                dbg = await c.generate(prompt, max_new=3, debug=True)
                done = {}
                async for ev, data in c.stream(prompt, max_new=3, debug=True):
                    if ev == "done":
                        done = data
                status, _, _ = await c.request(
                    "POST", "/v1/generate",
                    {"prompt": prompt.tolist(), "debug": "yes"},
                )
            return plain, dbg, done, status
        finally:
            await stop_server(server, task)

    plain, dbg, done, bad_status = asyncio.run(main())
    assert "phases" not in plain
    assert bad_status == 400
    for resp in (dbg, done):
        ph = resp["phases"]
        for k in ("queue_ms", "prefill_ms", "decode_ms", "total_ms"):
            assert ph[k] >= 0.0, (k, ph)
        assert ph["queue_ms"] + ph["prefill_ms"] + ph["decode_ms"] == pytest.approx(
            ph["total_ms"], abs=0.1
        )
        assert ph["total_ms"] >= resp["ttft_ms"] - 0.1
    # third identical prompt hit the cache warmed by the first two
    assert done["phases"]["cache_hit"]
    assert done["phases"]["cache_saved_tokens"] > 0


def test_metrics_export_dispatch_and_trace_stats():
    """Satellite: kernels.dispatch.STATS and tracer aggregates surface in
    /metrics with op/backend and span-name labels."""
    [prompt] = prompts_for(tiny_model(), 1, seed=13)

    async def main():
        server, task = await start_server(make_router())
        try:
            async with Client(server.host, server.port) as c:
                await c.generate(prompt, max_new=2)
                return await c.metrics()
        finally:
            await stop_server(server, task)

    text = asyncio.run(main())
    assert "repro_trace_enabled 1" in text
    m = re.findall(r'repro_dispatch_decisions_total\{op="([^"]+)",backend="([^"]+)"\} (\d+)', text)
    assert m, "dispatch decisions missing from /metrics"
    assert all(int(v) > 0 for _, _, v in m)
    assert re.search(r'repro_trace_spans_total\{name="engine\.step"\} \d+', text)
    assert re.search(r'repro_trace_span_seconds_total\{name="engine\.step"\} \d', text)
    assert re.search(r'repro_request_phase_seconds\{phase="prefill",quantile="0\.95"\}', text)


@pytest.mark.slow
def test_metrics_scrape_consistent_under_concurrent_load():
    """Regression (scrape-path races): hammer /metrics while streams are
    in flight. Every scrape must parse as Prometheus text with sane,
    monotone counters — one locked Router.scrape() snapshot per scrape."""
    model = tiny_model()
    prompts = prompts_for(model, 8, seed=14)

    async def main():
        server, task = await start_server(
            make_router(lanes=2, cache=PrefixCache(block=4), max_queue=64)
        )
        try:
            scrapes = []
            done = asyncio.Event()

            async def scraper():
                async with Client(server.host, server.port) as c:
                    while not done.is_set():
                        scrapes.append(await c.metrics())
                        await asyncio.sleep(0.005)
                    scrapes.append(await c.metrics())

            async def one(i, p):
                async with Client(server.host, server.port) as c:
                    return [t async for t in _collect(c, p)]

            async def _collect(c, p):
                async for ev, data in c.stream(p, max_new=4):
                    if ev == "message":
                        yield data["token"]

            scrape_task = asyncio.create_task(scraper())
            outs = await asyncio.gather(*(one(i, p) for i, p in enumerate(prompts)))
            done.set()
            await scrape_task
            return outs, scrapes
        finally:
            await stop_server(server, task)

    outs, scrapes = asyncio.run(main())
    assert all(len(t) == 4 for t in outs)
    assert len(scrapes) >= 2
    last_requests = -1.0
    for text in scrapes:
        samples = {}
        for line in text.strip().splitlines():
            if line.startswith("#"):
                continue
            assert _SAMPLE_RE.match(line), line
            name_labels, value = line.rsplit(" ", 1)
            samples[name_labels] = float(value)
        assert samples["repro_up"] == 1.0
        # counters never go backwards across interleaved scrapes
        assert samples["repro_requests_total"] >= last_requests
        last_requests = samples["repro_requests_total"]
    assert scrapes[-1].count("repro_requests_total 8") == 1
