"""hlo_analysis cross-checks (DESIGN.md §9): dot FLOPs vs XLA cost_analysis
on scan-free modules, while-loop trip multiplication, collective wire-byte
formulas, and fusion-boundary byte accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _analyze(fn, *args, n_partitions=1):
    compiled = jax.jit(fn).lower(*args).compile()
    return analyze_hlo(compiled.as_text(), n_partitions=n_partitions), compiled


def test_dot_flops_match_cost_analysis_scanfree():
    m, k, n = 64, 128, 32
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.float32)

    h, compiled = _analyze(lambda a, b: a @ b, x, w)
    want = 2.0 * m * k * n
    assert h.dot_flops == want, (h.dot_flops, want)
    ca = compiled.cost_analysis()
    if ca and "flops" in ca:
        np.testing.assert_allclose(h.dot_flops, float(ca["flops"]), rtol=0.01)


def test_while_trip_count_multiplies():
    """An 8-iteration scan over a matmul must report 8x the single-step
    FLOPs (the cost_analysis() deficiency this module exists to fix)."""
    k = 64
    w = jax.ShapeDtypeStruct((8, k, k), jnp.float32)
    x = jax.ShapeDtypeStruct((4, k), jnp.float32)

    def scanned(w, x):
        def body(c, wi):
            return c @ wi, None

        out, _ = jax.lax.scan(body, x, w)
        return out

    h, compiled = _analyze(scanned, w, x)
    per_step = 2.0 * 4 * k * k
    assert h.dot_flops == 8 * per_step, (h.dot_flops, 8 * per_step)
    ca = compiled.cost_analysis()
    if ca and "flops" in ca:  # document the discrepancy we correct
        assert float(ca["flops"]) < h.dot_flops


def test_elementwise_bytes_not_double_counted_inside_fusions():
    """Bytes are charged at fusion boundaries; a chain of elementwise ops
    must cost ~input+output, not per-op."""
    n = 1 << 16
    x = jax.ShapeDtypeStruct((n,), jnp.float32)

    def chain(x):
        for _ in range(10):
            x = jnp.tanh(x) * 1.5 + 0.1
        return x

    h, _ = _analyze(chain, x)
    assert h.bytes_accessed <= 6 * n * 4, h.bytes_accessed  # few buffers, not 30


def test_collective_wire_bytes_all_reduce():
    """psum over an 8-device axis: ring all-reduce moves 2*(n-1)/n * bytes."""
    import os

    if len(jax.devices()) < 8:
        pytest.skip("needs >= 8 host devices (run under dryrun env)")
    mesh = jax.make_mesh((8,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)

    def f(x):
        return jnp.sum(x, axis=0)

    with mesh:
        compiled = (
            jax.jit(f, in_shardings=NamedSharding(mesh, P("d", None)),
                    out_shardings=NamedSharding(mesh, P(None)))
            .lower(x).compile()
        )
    h = analyze_hlo(compiled.as_text(), n_partitions=8)
    # one all-reduce (or reduce-scatter+all-gather) of the [128] f32 result
    assert h.collective_count >= 1
    assert h.collective_bytes > 0


def test_trip_count_parse_robust_to_nested():
    """Nested scans multiply: outer 4 x inner 8 over a matmul = 32x."""
    k = 32
    w = jax.ShapeDtypeStruct((4, 8, k, k), jnp.float32)
    x = jax.ShapeDtypeStruct((2, k), jnp.float32)

    def nested(w, x):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, None

            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, None

        out, _ = jax.lax.scan(outer, x, w)
        return out

    h, _ = _analyze(nested, w, x)
    per = 2.0 * 2 * k * k
    assert h.dot_flops == 32 * per, (h.dot_flops, 32 * per)
