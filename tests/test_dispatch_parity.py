"""Golden-parity harness for the kernel dispatch layer.

For EVERY op in ``kernels.dispatch.REGISTRY``: pallas(interpret) vs the jnp
oracle across a shape grid that includes non-tile-divisible (padded) shapes,
plus assertions on the dispatch decisions themselves — which backend ran,
whether padding kicked in, and that fallbacks are recorded, never silent.

The grid runs without hypothesis (the property sweeps live in
tests/test_dispatch_properties.py behind the importorskip guard) so parity
stays in the < 2 min smoke tier.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import floatsd, floatsd4
from repro.kernels import dispatch as kd
from repro.kernels.floatsd_matmul.ops import floatsd_matmul


def _w(shape, scale=1.0, dtype=np.float32, seed_extra=0):
    seed = (hash((shape, float(scale), seed_extra)) & 0x7FFFFFFF) or 1
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# the per-op parity grids; completeness against the registry is asserted so
# a newly registered op without a grid fails loudly
# ---------------------------------------------------------------------------

MATMUL_SHAPES = [
    (8, 128, 128),   # native tiles
    (32, 256, 256),  # native tiles
    (7, 130, 66),    # all three axes padded
    (1, 32, 48),     # tiny, heavily padded
    (30, 100, 200),  # mixed
]

# FloatSD4 grid mirrors MATMUL_SHAPES but forces odd K twice: K=101 / 127
# exercise the nibble pad (one ZERO_CODE row -> 0x77 pad byte) AND a
# non-multiple-of-GROUP row count for the group exponents
MATMUL4_SHAPES = [
    (8, 128, 128),   # native tiles, K % 2 == 0, K % GROUP == 0
    (32, 256, 256),  # native tiles
    (7, 130, 66),    # all three axes padded, K even but K % GROUP != 0
    (1, 32, 48),     # tiny, heavily padded
    (30, 101, 200),  # odd K: packed stream carries a half-empty last byte
    (5, 127, 96),    # odd K and last group only 31 rows deep
]

LSTM_SHAPES = [(8, 128), (32, 256), (5, 70), (3, 200)]

ELEMWISE_SHAPES = [(8, 256), (7, 33), (1000,), (2, 3, 7), (64, 512)]

# (bh, s, dk, dv); last shape is chunk-indivisible -> recorded ref fallback
WKV_SHAPES = [(2, 32, 8, 16), (1, 48, 16, 16), (2, 30, 8, 8)]

# (bh, sq, skv, d); last shape is misaligned -> recorded ref fallback
FLASH_SHAPES = [(2, 16, 128, 8), (1, 32, 256, 16), (2, 10, 100, 8)]

GRIDS = {
    "floatsd_matmul": MATMUL_SHAPES,
    "floatsd4_matmul": MATMUL4_SHAPES,
    "lstm_cell": LSTM_SHAPES,
    "floatsd_quantize": ELEMWISE_SHAPES,
    "qsigmoid": ELEMWISE_SHAPES,
    # backward op pairs (the fused-BPTT training path)
    "floatsd_matmul_dx": MATMUL_SHAPES,
    "floatsd_matmul_dw": MATMUL_SHAPES,
    "lstm_cell_grad": LSTM_SHAPES,
    # fallback-only dispatch (no padding path): pallas iff tiles divide
    "rwkv_wkv": WKV_SHAPES,
    "flash_attention": FLASH_SHAPES,
}


def test_every_registered_op_has_a_parity_grid():
    assert set(GRIDS) == set(kd.REGISTRY), (
        "every op registered in kernels.dispatch must have a parity grid here"
    )


def _expect_padded(m, k, n):
    return bool(m % 8 or k % 128 or n % 128)


@pytest.mark.parametrize("m,k,n", MATMUL_SHAPES)
def test_matmul_parity_and_decision(m, k, n):
    x = jnp.asarray(_w((m, k), 0.5))
    wts = jnp.asarray(_w((k, n), 0.05))
    codes, bias = floatsd.encode(wts)
    with kd.use_backend("pallas"):
        got = kd.matmul(x, codes, bias)
        dec = kd.STATS.last["floatsd_matmul"]
    want = kd.matmul(x, codes, bias, backend="ref")
    assert dec.backend == "pallas"
    assert dec.padded == _expect_padded(m, k, n), dec
    # precise (f32-issue) kernel: <= 1e-5 deviation across the grid
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


def test_matmul_batched_leading_dims():
    """dispatch.matmul flattens [..., K] leading dims like the weight sites."""
    x = jnp.asarray(_w((2, 3, 130), 0.5))
    wts = jnp.asarray(_w((130, 66), 0.05))
    codes, bias = floatsd.encode(wts)
    with kd.use_backend("pallas"):
        got = kd.matmul(x, codes, bias)
    want = kd.matmul(x, codes, bias, backend="ref")
    assert got.shape == (2, 3, 66)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,h", LSTM_SHAPES)
@pytest.mark.parametrize("quantized", [True, False])
def test_lstm_cell_parity_and_decision(b, h, quantized):
    z = jnp.asarray(_w((b, 4 * h), 1.5))
    c = jnp.asarray(_w((b, h), 0.8))
    with kd.use_backend("pallas"):
        h_got, c_got = kd.lstm_cell(z, c, quantized=quantized)
        dec = kd.STATS.last["lstm_cell"]
    h_want, c_want = kd.lstm_cell(z, c, quantized=quantized, backend="ref")
    assert dec.backend == "pallas"
    assert dec.padded == bool(b % 8 or h % 128), dec
    assert c_got.dtype == jnp.float16 and c_want.dtype == jnp.float16
    np.testing.assert_allclose(
        np.asarray(h_got), np.asarray(h_want), rtol=1e-3, atol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(c_got, np.float32), np.asarray(c_want, np.float32),
        rtol=1e-3, atol=1e-4,
    )


@pytest.mark.parametrize("m,k,n", MATMUL_SHAPES)
def test_matmul_dx_parity_and_decision(m, k, n):
    """Backward dx op: g [M,N] x decode(codes [K,N])^T, f32 precise path."""
    g = jnp.asarray(_w((m, n), 0.5))
    wts = jnp.asarray(_w((k, n), 0.05))
    codes, bias = floatsd.encode(wts)
    with kd.use_backend("pallas"):
        got = kd.matmul_dx(g, codes, bias)
        dec = kd.STATS.last["floatsd_matmul_dx"]
    want = kd.matmul_dx(g, codes, bias, backend="ref")
    assert dec.backend == "pallas"
    assert dec.padded == _expect_padded(m, n, k), dec
    assert got.shape == (m, k) and got.dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize("m,k,n", MATMUL_SHAPES)
def test_matmul_dw_parity_and_decision(m, k, n):
    """Backward dw op: x^T g with the FP8 grid snap applied in-kernel —
    outputs must land EXACTLY on the fp8-e5m2 grid on both backends."""
    x = jnp.asarray(_w((m, k), 0.5))
    g = jnp.asarray(_w((m, n), 0.5))
    with kd.use_backend("pallas"):
        got = kd.matmul_dw(x, g)
        dec = kd.STATS.last["floatsd_matmul_dw"]
    want = kd.matmul_dw(x, g, backend="ref")
    assert dec.backend == "pallas"
    assert dec.padded == bool(k % 8 or m % 128 or n % 128), dec
    assert got.shape == (k, n)
    # the in-kernel quantizer really ran: every value is fp8-representable
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(got.astype(jnp.float8_e5m2), np.float32)
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,h", LSTM_SHAPES)
@pytest.mark.parametrize("quantized", [True, False])
@pytest.mark.parametrize("c_dtype", [jnp.float32, jnp.float16])
def test_lstm_cell_grad_parity_and_decision(b, h, quantized, c_dtype):
    """Recompute-gates backward: pallas(interpret) vs the jnp oracle.

    f32 cell state: tight (pure f32 elementwise chain). f16 cell state:
    f16-rounding envelope — the recomputed c_t can land one f16 ulp apart
    between lowerings (fma/fusion), which the tanh path amplifies to ~1e-3
    relative (same envelope as the forward cell parity above).
    """
    z = jnp.asarray(_w((b, 4 * h), 1.5))
    c = jnp.asarray(_w((b, h), 0.8)).astype(c_dtype)
    dh = jnp.asarray(_w((b, h), 1.0, seed_extra=1))
    dc = jnp.asarray(_w((b, h), 1.0, seed_extra=2)).astype(c_dtype)
    with kd.use_backend("pallas"):
        dz_got, dcp_got = kd.lstm_cell_grad(
            z, c, dh, dc, quantized=quantized, c_dtype=c_dtype
        )
        dec = kd.STATS.last["lstm_cell_grad"]
    dz_want, dcp_want = kd.lstm_cell_grad(
        z, c, dh, dc, quantized=quantized, c_dtype=c_dtype, backend="ref"
    )
    assert dec.backend == "pallas"
    assert dec.padded == bool(b % 8 or h % 128), dec
    assert dz_got.shape == (b, 4 * h) and dcp_got.shape == (b, h)
    assert dcp_got.dtype == c_dtype
    tol = dict(rtol=1e-5, atol=1e-6) if c_dtype == jnp.float32 else dict(
        rtol=2e-3, atol=1e-5
    )
    np.testing.assert_allclose(np.asarray(dz_got), np.asarray(dz_want), **tol)
    np.testing.assert_allclose(
        np.asarray(dcp_got, np.float32), np.asarray(dcp_want, np.float32),
        rtol=2e-3, atol=1e-4,
    )


def test_lstm_cell_c_dtype_follows_policy():
    """fp32-master policies keep the cell state f32 through the dispatch."""
    z = jnp.asarray(_w((8, 4 * 128), 1.5))
    c = jnp.asarray(_w((8, 128), 0.8))
    for backend in ("ref", "pallas"):
        _, c_out = kd.lstm_cell(z, c, c_dtype=jnp.float32, backend=backend)
        assert c_out.dtype == jnp.float32, backend


@pytest.mark.parametrize("shape", ELEMWISE_SHAPES)
def test_quantize_parity_and_decision(shape):
    x = jnp.asarray(_w(shape, 0.7))
    with kd.use_backend("pallas"):
        codes, bias = kd.quantize(x)
        dec = kd.STATS.last["floatsd_quantize"]
    ref_codes, ref_bias = kd.quantize(x, backend="ref")
    assert dec.backend == "pallas"
    assert dec.padded == bool(x.size % (8 * 256)), dec
    assert codes.shape == x.shape and codes.dtype == jnp.uint8
    assert int(bias) == int(ref_bias)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(ref_codes))


@pytest.mark.parametrize("shape", ELEMWISE_SHAPES)
def test_qsigmoid_parity_and_decision(shape):
    x = jnp.asarray(_w(shape, 2.0))
    with kd.use_backend("pallas"):
        got = kd.qsigmoid(x)
        dec = kd.STATS.last["qsigmoid"]
    want = kd.qsigmoid(x, backend="ref")
    assert dec.backend == "pallas"
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bh,s,dk,dv", WKV_SHAPES)
def test_rwkv_wkv_parity_and_decision(bh, s, dk, dv):
    """No padding path: pallas when S % chunk == 0, recorded ref fallback
    otherwise (never silent)."""
    rng = np.random.default_rng(7 + bh + s + dk)
    r = jnp.asarray(rng.standard_normal((bh, s, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, dv)), jnp.float32)
    w = jnp.asarray(
        np.exp(-np.exp(rng.standard_normal((bh, s, dk)) * 0.3 - 2.0)),
        jnp.float32,
    )
    u = jnp.asarray(rng.standard_normal((bh, dk)) * 0.1, jnp.float32)
    with kd.use_backend("pallas"):
        got = kd.rwkv_wkv(r, k, v, w, u, chunk=16)
        dec = kd.STATS.last["rwkv_wkv"]
    want = kd.rwkv_wkv(r, k, v, w, u, chunk=16, backend="ref")
    if s % 16 == 0:
        assert dec.backend == "pallas", dec
    else:
        assert dec.backend == "ref" and "oracle" in dec.reason, dec
    assert got.shape == (bh, s, dv)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


@pytest.mark.parametrize("bh,sq,skv,d", FLASH_SHAPES)
def test_flash_attention_parity_and_decision(bh, sq, skv, d):
    """No padding path: pallas when (Sq, Skv, D) are tile-aligned, recorded
    ref fallback otherwise (never silent)."""
    rng = np.random.default_rng(11 + bh + sq + d)
    q = jnp.asarray(rng.standard_normal((bh, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    with kd.use_backend("pallas"):
        got = kd.flash_attention(q, k, v, causal=False)
        dec = kd.STATS.last["flash_attention"]
    want = kd.flash_attention(q, k, v, causal=False, backend="ref")
    if sq % 8 == 0 and skv % 128 == 0 and d % 8 == 0:
        assert dec.backend == "pallas", dec
    else:
        assert dec.backend == "ref" and "oracle" in dec.reason, dec
    assert got.shape == (bh, sq, d)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-2, atol=6e-3
    )


# ---------------------------------------------------------------------------
# the dispatch decision logic itself
# ---------------------------------------------------------------------------


def test_auto_resolves_to_ref_off_tpu():
    x = jnp.asarray(_w((8, 128), 0.5))
    wts = jnp.asarray(_w((128, 128), 0.05))
    codes, bias = floatsd.encode(wts)
    kd.matmul(x, codes, bias)  # default policy: auto
    dec = kd.STATS.last["floatsd_matmul"]
    assert dec.backend == "ref" and dec.reason.startswith("auto:off-tpu")


def test_backend_precedence_argument_over_context_over_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas")
    assert kd.backend_policy() == "pallas"
    with kd.use_backend("ref"):
        assert kd.backend_policy() == "ref"
        assert kd.backend_policy("auto") == "auto"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "bogus")
    with pytest.raises(ValueError):
        kd.backend_policy()


def test_auto_padding_profitability_on_tpu(monkeypatch):
    """With compiled pallas available (simulated), auto pads only while the
    padded work stays under PAD_WASTE_MAX; beyond it the oracle wins."""
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "0")  # pretend compiled
    d = kd._choose("x", native=True, waste=1.0, backend="auto")
    assert d.backend == "pallas" and not d.padded
    d = kd._choose("x", native=False, waste=kd.PAD_WASTE_MAX - 0.1, backend="auto")
    assert d.backend == "pallas" and d.padded
    d = kd._choose("x", native=False, waste=kd.PAD_WASTE_MAX + 0.1, backend="auto")
    assert d.backend == "ref" and "waste" in d.reason


def test_stats_counters_accumulate():
    x = jnp.asarray(_w((8, 128), 0.5))
    wts = jnp.asarray(_w((128, 128), 0.05))
    codes, bias = floatsd.encode(wts)
    before = kd.STATS.count("floatsd_matmul", "ref")
    kd.matmul(x, codes, bias, backend="ref")
    kd.matmul(x, codes, bias, backend="ref")
    assert kd.STATS.count("floatsd_matmul", "ref") == before + 2


def test_ops_wrapper_records_fallback_not_silent():
    """The legacy wrapper's oracle fallback is observable via STATS — a
    tiling regression can't quietly turn every call into jnp."""
    x = jnp.asarray(_w((7, 130), 0.5))
    wts = jnp.asarray(_w((130, 66), 0.05))
    codes, bias = floatsd.encode(wts)
    floatsd_matmul(x, codes, bias, interpret=True)
    dec = kd.STATS.last["floatsd_matmul"]
    assert dec.backend == "ref" and "fallback" in dec.reason
    x2 = jnp.asarray(_w((8, 128), 0.5))
    wts2 = jnp.asarray(_w((128, 128), 0.05))
    codes2, bias2 = floatsd.encode(wts2)
    floatsd_matmul(x2, codes2, bias2, interpret=True)
    assert kd.STATS.last["floatsd_matmul"].backend == "pallas"


# ---------------------------------------------------------------------------
# packed-weight entry points
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("eq,xshape,wshape", [
    ("bd,dk->bk", (4, 80), (80, 96)),
    ("...d,df->...f", (2, 3, 80), (80, 96)),
    ("...d,vd->...v", (2, 3, 80), (96, 80)),  # tied logits head layout
])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_packed_einsum_matches_dense(eq, xshape, wshape, backend):
    x = jnp.asarray(_w(xshape, 0.5))
    w = jnp.asarray(_w(wshape, 0.05))
    pt = kd.PackedTensor(*floatsd.encode(w))
    with kd.use_backend(backend):
        got = kd.packed_einsum(eq, x, pt)
    wq = floatsd.decode(pt.codes, pt.bias)
    want = jnp.einsum(eq, x, wq, preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_packed_einsum_rejects_unsupported_eq():
    w = jnp.asarray(_w((8, 8), 0.05))
    pt = kd.PackedTensor(*floatsd.encode(w))
    with pytest.raises(NotImplementedError):
        kd.packed_einsum("ab,bcd->acd", jnp.zeros((2, 8)), pt)


def test_hoist_packed_decodes_for_ref_keeps_codes_for_pallas():
    w = jnp.asarray(_w((16, 32), 0.05))
    pt = kd.PackedTensor(*floatsd.encode(w))
    with kd.use_backend("ref"):
        dense = kd.hoist_packed(pt)
    assert not kd.is_packed(dense)
    np.testing.assert_array_equal(
        np.asarray(dense), np.asarray(floatsd.decode(pt.codes, pt.bias))
    )
    with kd.use_backend("pallas"):
        assert kd.hoist_packed(pt) is pt
    # non-packed passthrough
    assert kd.hoist_packed(w) is w


def test_zero_code_pads_decode_to_exact_zero():
    codes = jnp.full((4, 4), kd.ZERO_CODE, jnp.uint8)
    for bias in (-30, 0, 25):
        np.testing.assert_array_equal(
            np.asarray(floatsd.decode(codes, bias)), 0.0
        )


# ---------------------------------------------------------------------------
# FloatSD4 sub-byte packed entry points (2 codes/byte)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", MATMUL4_SHAPES)
def test_matmul4_parity_and_decision(m, k, n):
    """Compiled-vs-ref golden parity for the nibble-packed GEMM, mirroring
    the FloatSD8 grid: the kernel's in-VMEM LUT unpack + group-exponent
    scale must match the decode-then-dot oracle on padded and odd-K
    shapes alike."""
    x = jnp.asarray(_w((m, k), 0.5))
    wts = jnp.asarray(_w((k, n), 0.05))
    w4 = kd.pack4(wts)
    with kd.use_backend("pallas"):
        got = kd.matmul4(x, w4)
        dec = kd.STATS.last["floatsd4_matmul"]
    want = kd.matmul4(x, w4, backend="ref")
    assert dec.backend == "pallas"
    assert dec.padded == _expect_padded(m, k, n), dec
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6
    )


def test_matmul4_batched_leading_dims():
    x = jnp.asarray(_w((2, 3, 101), 0.5))
    wts = jnp.asarray(_w((101, 66), 0.05))
    w4 = kd.pack4(wts)
    with kd.use_backend("pallas"):
        got = kd.matmul4(x, w4)
    want = kd.matmul4(x, w4, backend="ref")
    assert got.shape == (2, 3, 66)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_matmul4_ref_is_decode_then_dot():
    """The ref oracle is literally decode_packed + jnp.dot — anchor the
    dispatched ref branch to the layer-0 definition."""
    x = jnp.asarray(_w((6, 70), 0.5))
    wts = jnp.asarray(_w((70, 40), 0.05))
    w4 = kd.pack4(wts)
    got = kd.matmul4(x, w4, backend="ref")
    wq = floatsd4.decode_packed(w4.codes, w4.exps, w4.k)
    want = jnp.dot(x, wq, preferred_element_type=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_matmul4_stats_counters_accumulate():
    x = jnp.asarray(_w((8, 128), 0.5))
    w4 = kd.pack4(jnp.asarray(_w((128, 128), 0.05)))
    before = kd.STATS.count("floatsd4_matmul", "ref")
    kd.matmul4(x, w4, backend="ref")
    kd.matmul4(x, w4, backend="ref")
    assert kd.STATS.count("floatsd4_matmul", "ref") == before + 2


@pytest.mark.parametrize("eq,xshape,wshape", [
    ("bd,dk->bk", (4, 80), (80, 96)),
    ("...d,df->...f", (2, 3, 80), (80, 96)),
    ("...d,vd->...v", (2, 3, 80), (96, 80)),  # tied logits head layout
    ("...d,vd->...v", (2, 3, 81), (95, 81)),  # odd dims: nbyte asymmetry
])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_packed4_einsum_matches_dense(eq, xshape, wshape, backend):
    x = jnp.asarray(_w(xshape, 0.5))
    w = jnp.asarray(_w(wshape, 0.05))
    p4 = kd.pack4(w)
    with kd.use_backend(backend):
        got = kd.packed_einsum(eq, x, p4)
        dec = kd.STATS.last["floatsd4_matmul"]
    assert "packed4" in dec.reason or dec.backend == "pallas", dec
    wq = floatsd4.decode_packed(p4.codes, p4.exps, p4.k)
    want = jnp.einsum(eq, x, wq, preferred_element_type=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_packed4_einsum_transpose_fallback_is_recorded():
    """The tied-head layout cannot stream nibbles transposed; the
    decode+einsum fallback must be a recorded Decision, never silent."""
    x = jnp.asarray(_w((2, 80), 0.5))
    p4 = kd.pack4(jnp.asarray(_w((96, 80), 0.05)))
    with kd.use_backend("pallas"):
        kd.packed_einsum("...d,vd->...v", x, p4)
        dec = kd.STATS.last["floatsd4_matmul"]
    assert dec.backend == "ref" and "transpose" in dec.reason, dec


def test_hoist_packed4_decodes_for_ref_keeps_codes_for_pallas():
    w = jnp.asarray(_w((33, 32), 0.05))  # odd K: crop must survive hoist
    p4 = kd.pack4(w)
    with kd.use_backend("ref"):
        dense = kd.hoist_packed(p4)
    assert not kd.is_packed4(dense)
    assert dense.shape == (33, 32)
    np.testing.assert_array_equal(
        np.asarray(dense),
        np.asarray(floatsd4.decode_packed(p4.codes, p4.exps, p4.k)),
    )
    with kd.use_backend("pallas"):
        assert kd.hoist_packed(p4) is p4


def test_zero_byte4_pads_decode_to_exact_zero():
    """Tile padding for the packed stream uses ZERO_BYTE4 = two ZERO_CODE
    nibbles; both nibbles must decode to exactly 0 at any group exponent."""
    assert kd.ZERO_BYTE4 == (floatsd4.ZERO_CODE << 4) | floatsd4.ZERO_CODE
    packed = jnp.full((4, 4), kd.ZERO_BYTE4, jnp.uint8)
    for e in (-30, 0, 25):
        exps = jnp.full((1, 4), e, jnp.int8)
        np.testing.assert_array_equal(
            np.asarray(floatsd4.decode_packed(packed, exps, 8)), 0.0
        )


def test_packed4_bytes_resident_exactly_half():
    """Acceptance criterion: the packed code stream is exactly
    ceil(K/2) * N bytes vs K * N for FloatSD8, at even and odd K."""
    for k, n in [(128, 96), (101, 66), (33, 32)]:
        w = jnp.asarray(_w((k, n), 0.05))
        p8 = kd.PackedTensor(*floatsd.encode(w))
        p4 = kd.pack4(w)
        assert p8.codes.nbytes == k * n
        assert p4.codes.nbytes == -(-k // 2) * n
        assert p4.exps.nbytes == -(-k // floatsd4.GROUP) * n
        assert p4.shape == (k, n)
