"""repro.serving tests: weight-store round-trip vs fake-quant, masked lane
reset isolation, chunked-prefill equivalence vs token-by-token feeding, and
scheduler/engine arm-retire ordering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import floatsd
from repro.core.policy import get_policy
from repro.models.lstm_models import WikiText2LM
from repro.serving import (
    Request,
    Scheduler,
    ServeEngine,
    WeightStore,
    masked_reset,
    pack_tree,
    synthetic_prompts,
    unpack_tree,
)

POLICY = get_policy("floatsd8_table6")


def tiny_model():
    return WikiText2LM(vocab=300, emb=32, hidden=32, n_layers=2)


def tiny_params(model, seed=0):
    return model.init(jax.random.PRNGKey(seed))


_TRAINED = {}


def trained_params(model):
    """Briefly-pretrained params: an untrained model's logits are near-ties
    everywhere, which makes greedy streams meaninglessly sensitive to 1-ulp
    lowering noise; ~30 SGD steps give decisive argmax margins."""
    key = (model.vocab, model.emb, model.hidden, model.n_layers)
    if key not in _TRAINED:
        from repro.data import synthetic
        from repro.optim import sgd
        from repro.optim.train_state import init_state, make_train_step

        data = synthetic.wikitext2(batch=32, seq=24, vocab=model.vocab)
        opt = sgd(0.9)
        state = init_state(model.init(jax.random.PRNGKey(0)), opt, POLICY)
        step_fn = jax.jit(make_train_step(model.loss, opt, POLICY, lr=1.0))
        for _ in range(30):
            batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
            state, _ = step_fn(state, batch)
        _TRAINED[key] = state.params
    return _TRAINED[key]


def make_prompts(n, vocab, rng, lo=2, hi=14):
    return synthetic_prompts(n, vocab, rng, lo=lo, hi=hi)


# ---------------------------------------------------------------------------
# weight store
# ---------------------------------------------------------------------------


def test_exp2i_exact_powers_of_two():
    ks = jnp.arange(-126, 128)
    want = (2.0 ** np.arange(-126, 128, dtype=np.float64)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(floatsd.exp2i(ks)), want)


@pytest.mark.slow
def test_weight_store_roundtrip_matches_fake_quant():
    """decode(encode(w)) must be BIT-identical to the training-time
    fake-quant path — the invariant that lets the engine serve from codes
    with weight_quant dropped."""
    model = tiny_model()
    params = tiny_params(model)
    store = WeightStore.pack(params)
    dense = store.materialize()
    for path, w in jax.tree_util.tree_leaves_with_path(params):
        if w.ndim < 2:
            continue
        dec = dense
        for k in path:
            dec = dec[k.key]
        fq = floatsd.quantize(w).values
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(fq), err_msg=str(path))


def test_weight_store_roundtrip_tiny_magnitudes():
    """fit_bias would hit < -126 for near-subnormal tensors; the shared
    bias clamp must keep decode(encode(w)) == quantize(w).values there."""
    w = jnp.array([[1e-36, 2.5e-37], [5e-37, 9e-37]], jnp.float32)
    codes, bias = floatsd.encode(w)
    np.testing.assert_array_equal(
        np.asarray(floatsd.decode(codes, bias)),
        np.asarray(floatsd.quantize(w).values),
    )


def test_weight_store_packs_matmul_sites_only():
    model = tiny_model()
    params = tiny_params(model)
    store = WeightStore.pack(params)
    # every >=2-D float leaf became uint8 codes; 1-D biases stayed dense
    assert store.n_packed == sum(
        1 for l in jax.tree_util.tree_leaves(params) if l.ndim >= 2
    )
    from repro.serving import PackedTensor

    packed_leaves = jax.tree_util.tree_leaves(
        store.tree, is_leaf=lambda x: isinstance(x, PackedTensor)
    )
    for l in packed_leaves:
        if isinstance(l, PackedTensor):
            assert l.codes.dtype == jnp.uint8
            assert l.bias.dtype == jnp.int32
        else:
            assert l.ndim < 2  # only sub-matmul leaves stay dense
    # ~4x smaller overall (weight matrices dominate the tiny LM less than
    # the real one, so allow slack)
    assert store.compression > 3.0
    # unpack is identity on dense trees
    same = unpack_tree(params)
    for a, b in zip(jax.tree_util.tree_leaves(same), jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pack_tree_roundtrip_under_jit():
    """unpack_tree(packed) must be traceable (decode-at-use inside jit)."""
    w = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    packed = pack_tree({"w": w})

    @jax.jit
    def use(p):
        return unpack_tree(p)["w"].sum()

    ref = np.asarray(floatsd.quantize(w).values).sum()
    np.testing.assert_allclose(float(use(packed)), ref, rtol=1e-6)


# ---------------------------------------------------------------------------
# state pool
# ---------------------------------------------------------------------------


def test_masked_reset_isolates_lanes():
    key = jax.random.PRNGKey(0)
    caches = {
        "a": jax.random.normal(key, (3, 4)),
        "nested": [jax.random.normal(key, (3, 2, 5))],
    }
    out = masked_reset(caches, jnp.array([0, 1, 0]))
    np.testing.assert_array_equal(np.asarray(out["a"][1]), 0.0)
    np.testing.assert_array_equal(np.asarray(out["nested"][0][1]), 0.0)
    # untouched lanes are bit-identical
    np.testing.assert_array_equal(np.asarray(out["a"][0]), np.asarray(caches["a"][0]))
    np.testing.assert_array_equal(np.asarray(out["a"][2]), np.asarray(caches["a"][2]))
    np.testing.assert_array_equal(
        np.asarray(out["nested"][0][2]), np.asarray(caches["nested"][0][2])
    )


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_chunked_prefill_state_equivalence():
    """Feeding a prompt in one lengths-masked chunk must produce the SAME
    recurrent state as feeding it token by token: the per-step matmul inside
    the scan is shape-identical either way, so states match bitwise."""
    model = tiny_model()
    params = tiny_params(model)
    B = 2
    rng = np.random.default_rng(0)
    lens = [7, 3]
    prompts = [rng.integers(0, model.vocab, l).astype(np.int32) for l in lens]

    # token-by-token
    states = model.init_cache(B, POLICY)
    for t in range(max(lens)):
        toks = np.zeros((B, 1), np.int32)
        k = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            if t < len(p):
                toks[i, 0] = p[t]
                k[i] = 1
        _, states = model.decode_step(
            params, jnp.asarray(toks), states, POLICY, lengths=jnp.asarray(k)
        )

    # one chunked step with per-lane lengths
    S = max(lens)
    toks = np.zeros((B, S), np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
    states2 = model.init_cache(B, POLICY)
    _, states2 = model.decode_step(
        params, jnp.asarray(toks), states2, POLICY,
        lengths=jnp.asarray(lens, np.int32),
    )

    for s1, s2 in zip(states, states2):
        np.testing.assert_array_equal(np.asarray(s1.h), np.asarray(s2.h))
        np.testing.assert_array_equal(np.asarray(s1.c), np.asarray(s2.c))


def _reference_rollout(model, params, prompt, max_new, margin_floor=1e-5):
    """Single-lane greedy rollout -> (tokens, n_decisive).

    n_decisive = length of the stream prefix where every argmax had a top-2
    logit margin > margin_floor. Within that prefix the greedy stream is
    invariant to XLA lowering differences (reduction-order noise is ~1e-7
    absolute); past it, argmax near-ties make exact comparison meaningless.
    """
    ones = jnp.ones((1,), jnp.int32)

    def step(tok, states):
        lg, st = model.decode_step(
            params, jnp.asarray([[tok]], jnp.int32), states, POLICY, lengths=ones
        )
        return np.asarray(lg[0, -1, :]), st

    states = model.init_cache(1, POLICY)
    logits = None
    for t in prompt:
        logits, states = step(int(t), states)
    out, n_decisive, decisive = [], 0, True
    for _ in range(max_new):
        top2 = np.sort(logits)[-2:]
        decisive = decisive and (top2[1] - top2[0]) > margin_floor
        nxt = int(logits.argmax())
        out.append(nxt)
        if decisive:
            n_decisive += 1
        logits, states = step(nxt, states)
    return out, n_decisive


@pytest.mark.slow
def test_chunked_prefill_tokens_match_token_by_token():
    """End-to-end engine equivalence on the tiny model: for every request,
    the greedy streams from chunk in {1, 3, 8} x {packed, dense} engines all
    match the single-lane reference over its margin-decisive prefix."""
    model = tiny_model()
    params = trained_params(model)
    rng = np.random.default_rng(0)
    prompts = make_prompts(8, model.vocab, rng)
    max_new = 5

    refs = [_reference_rollout(model, params, p, max_new) for p in prompts]
    # the trained model must give us something substantive to compare
    assert sum(n for _, n in refs) >= max_new * len(prompts) // 2

    for kw in (
        dict(chunk=1, packed=True),
        dict(chunk=3, packed=True),
        dict(chunk=8, packed=True),
        dict(chunk=8, packed=False),
    ):
        eng = ServeEngine(model, params, POLICY, lanes=3, **kw)
        reqs = eng.submit_all([p.copy() for p in prompts], max_new=max_new)
        eng.run()
        for r in sorted(reqs, key=lambda r: r.rid):
            ref_out, n = refs[r.rid]
            assert len(r.out) == max_new
            assert r.out[:n] == ref_out[:n], (kw, r.rid)


@pytest.mark.slow
def test_chunked_prefill_strictly_fewer_steps():
    model = tiny_model()
    params = tiny_params(model)
    rng = np.random.default_rng(1)
    prompts = make_prompts(10, model.vocab, rng, lo=6, hi=20)

    steps = {}
    for chunk in (1, 8):
        eng = ServeEngine(model, params, POLICY, lanes=4, chunk=chunk, packed=True)
        eng.submit_all([p.copy() for p in prompts], max_new=4)
        m = eng.run()
        assert m.emitted == 10 * 4
        steps[chunk] = m.steps
    assert steps[8] < steps[1], steps


# ---------------------------------------------------------------------------
# scheduler / engine lifecycle
# ---------------------------------------------------------------------------


def test_scheduler_fifo_and_sjf_ordering():
    lens = [5, 2, 9, 1, 2]
    fifo, sjf = Scheduler("fifo"), Scheduler("sjf")
    for sched in (fifo, sjf):
        for i, l in enumerate(lens):
            sched.submit(Request(rid=i, prompt=np.zeros(l, np.int32), max_new=1))
    assert [fifo.pop().rid for _ in lens] == [0, 1, 2, 3, 4]
    # sjf: by prompt length, arrival order breaks ties (rid 1 before rid 4)
    assert [sjf.pop().rid for _ in lens] == [3, 1, 4, 0, 2]
    assert fifo.pop() is None and sjf.pop() is None


def test_scheduler_rejects_bad_requests():
    with pytest.raises(ValueError):
        Request(rid=0, prompt=np.zeros(0, np.int32), max_new=1)
    with pytest.raises(ValueError):
        Request(rid=0, prompt=np.zeros(3, np.int32), max_new=0)
    with pytest.raises(ValueError):
        Scheduler("lifo")


def test_scheduler_sjf_equal_lengths_pop_in_arrival_order():
    """sjf tie-breaking is arrival-ordered: a stream of equal-length
    prompts drains FIFO — no request starves behind a later arrival."""
    sched = Scheduler("sjf")
    for i in range(20):
        sched.submit(Request(rid=i, prompt=np.zeros(5, np.int32), max_new=1))
    assert [sched.pop().rid for _ in range(20)] == list(range(20))


def test_scheduler_edf_orders_by_deadline():
    sched = Scheduler("edf")
    deadlines = [5.0, 1.0, None, 3.0, None, 1.0]
    for i, d in enumerate(deadlines):
        sched.submit(
            Request(rid=i, prompt=np.zeros(4, np.int32), max_new=1, deadline=d)
        )
    # earliest deadline first; equal deadlines by arrival; None (no
    # deadline) last, also by arrival
    assert [sched.pop().rid for _ in deadlines] == [1, 5, 3, 0, 2, 4]
    assert sched.pop() is None


def test_synthetic_prompts_deterministic_for_fixed_rng():
    a = synthetic_prompts(6, 500, np.random.default_rng(42))
    b = synthetic_prompts(6, 500, np.random.default_rng(42))
    assert len(a) == 6
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)

    from repro.serving import zipf_prefix_prompts

    kw = dict(n_prefixes=3, prefix_len=12, prefix_seed=9)
    za = zipf_prefix_prompts(8, 500, np.random.default_rng(1), **kw)
    zb = zipf_prefix_prompts(8, 500, np.random.default_rng(1), **kw)
    for x, y in zip(za, zb):
        np.testing.assert_array_equal(x, y)
    # prefix_seed pins the system-prompt pool across rng seeds
    zc = zipf_prefix_prompts(8, 500, np.random.default_rng(2), **kw)
    assert all(
        any(np.array_equal(p[:12], q[:12]) for q in za) for p in zc
    )
    # ... while the suffixes are fresh draws
    assert not all(
        any(np.array_equal(p, q) for q in za) for p in zc
    )


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_metrics_zero_division_safety():
    """Summary properties and report() must be total: zero steps, zero
    retired requests, never-started clocks."""
    from repro.serving import ServeMetrics, tenant_summary

    m = ServeMetrics(lanes=4)
    assert m.slot_util == 0.0
    assert m.lane_occupancy == 0.0
    assert m.cache_hit_rate == 0.0
    assert m.elapsed == 0.0  # never started: rates report 0, not 1e9 junk
    rep = m.report()
    assert rep["requests"] == 0 and rep["steps"] == 0
    assert rep["gen_tok_per_s"] == 0.0 and rep["slot_util"] == 0.0
    assert rep["ttft_mean_s"] == 0.0 and rep["ttft_p95_s"] == 0.0
    assert rep["latency_mean_s"] == 0.0 and rep["latency_p95_s"] == 0.0
    assert m.format()  # renders without raising
    assert m.per_tenant() == {} and tenant_summary([]) == {}
    # started-but-idle (stop before any step) is equally safe
    m.start()
    m.stop()
    assert np.isfinite(list(v for v in m.report().values() if isinstance(v, float))).all()


@pytest.mark.slow
def test_engine_arm_retire_ordering_and_completion():
    """More requests than lanes: every request completes with exactly
    max_new tokens, FIFO admission binds in rid order, and freed lanes are
    re-armed with the next queued request."""
    model = tiny_model()
    params = tiny_params(model)
    rng = np.random.default_rng(2)
    # equal-length prompts => deterministic retire order == admission order
    prompts = [rng.integers(0, model.vocab, 6).astype(np.int32) for _ in range(7)]
    eng = ServeEngine(model, params, POLICY, lanes=2, chunk=4, admission="fifo")
    eng.submit_all(prompts, max_new=3)
    m = eng.run()
    assert len(m.records) == 7
    assert all(r.new_tokens == 3 for r in m.records)
    assert [r.rid for r in m.records] == sorted(r.rid for r in m.records)
    # all lanes drained
    assert all(l is None for l in eng._lanes)
    assert not eng.scheduler


@pytest.mark.slow
def test_engine_sjf_admits_short_prompts_first():
    model = tiny_model()
    params = tiny_params(model)
    rng = np.random.default_rng(3)
    lens = [12, 3, 12, 3, 12, 3]
    prompts = [rng.integers(0, model.vocab, l).astype(np.int32) for l in lens]
    eng = ServeEngine(model, params, POLICY, lanes=1, chunk=4, admission="sjf")
    reqs = eng.submit_all(prompts, max_new=2)
    eng.run()
    order = sorted(reqs, key=lambda r: r.t_first)
    # the three short prompts (rids 1,3,5) finish prefill before any long one
    assert [r.rid for r in order[:3]] == [1, 3, 5]


def test_engine_rejects_packed_with_unquantized_policy():
    """packed=True under a policy that doesn't quantize weights would
    silently change served outputs — must refuse."""
    model = tiny_model()
    params = tiny_params(model)
    with pytest.raises(ValueError):
        ServeEngine(model, params, get_policy("fp32"), lanes=2, packed=True)
    ServeEngine(model, params, get_policy("fp32"), lanes=2, packed=False)


def test_engine_fails_fast_when_cache_not_rearmable():
    """A model whose cache can't be reset per-lane must refuse more
    requests than lanes up front, not mid-run after work is done."""
    model = tiny_model()
    params = tiny_params(model)
    eng = ServeEngine(model, params, POLICY, lanes=2)
    eng._rearmable = False  # simulate a shared-leaf (e.g. KV pos) cache
    eng.submit_all([np.ones(3, np.int32)] * 3, max_new=2)
    with pytest.raises(ValueError):
        eng.run()
    assert eng.metrics.steps == 0  # refused before any device work


@pytest.mark.slow
def test_model_decode_step_accepts_packed_store():
    """decode_step works with a packed weight-store tree directly (no
    engine), matching the dense fake-quant path."""
    model = tiny_model()
    params = tiny_params(model)
    store = WeightStore.pack(params)
    toks = jnp.asarray([[1], [2]], jnp.int32)
    ones = jnp.ones((2,), jnp.int32)
    lg_p, _ = model.decode_step(
        store.tree, toks, model.init_cache(2, POLICY),
        POLICY.replace(weight_quant="none"), lengths=ones,
    )
    lg_d, _ = model.decode_step(
        params, toks, model.init_cache(2, POLICY), POLICY, lengths=ones
    )
    np.testing.assert_allclose(np.asarray(lg_p), np.asarray(lg_d), rtol=1e-5)


@pytest.mark.slow
def test_engine_metrics_token_accounting():
    model = tiny_model()
    params = tiny_params(model)
    rng = np.random.default_rng(4)
    prompts = make_prompts(5, model.vocab, rng)
    eng = ServeEngine(model, params, POLICY, lanes=2, chunk=4)
    eng.submit_all([p.copy() for p in prompts], max_new=3)
    m = eng.run()
    rep = m.report()
    assert rep["emitted_tokens"] == 5 * 3
    assert rep["prompt_tokens"] == sum(len(p) for p in prompts)
    assert rep["steps"] == rep["prefill_steps"] + rep["decode_steps"]
    assert 0.0 < rep["slot_util"] <= 1.0
    assert 0.0 < rep["lane_occupancy"] <= 1.0
    assert all(r.ttft <= r.latency for r in m.records)


# ---------------------------------------------------------------------------
# FloatSD4 serving: byte footprint + accuracy gate
# ---------------------------------------------------------------------------

#: declared accuracy-gate tolerance: absolute wikitext2 eval-loss delta a
#: FloatSD4 re-quantization of the FloatSD8 master may cost vs FloatSD8
#: serving (the 15-level grid's documented accuracy/footprint trade)
FLOATSD4_LOSS_TOL = 0.25


def test_floatsd4_store_bytes_resident():
    """Acceptance criterion at the store level: FloatSD4 code streams are
    exactly ceil(K/2)*N bytes (vs K*N for FloatSD8) at every packed leaf,
    and the whole-store footprint shrinks accordingly."""
    from repro.serving import PackedTensor4

    model = tiny_model()
    params = tiny_params(model)
    s8 = WeightStore.pack(params)
    s4 = WeightStore.pack(params, fmt="floatsd4")
    assert (s8.fmt, s4.fmt) == ("floatsd8", "floatsd4")
    assert s4.n_packed == s8.n_packed
    leaves4 = {
        jax.tree_util.keystr(p): l
        for p, l in jax.tree_util.tree_leaves_with_path(
            s4.tree, is_leaf=lambda x: isinstance(x, PackedTensor4)
        )
        if isinstance(l, PackedTensor4)
    }
    assert leaves4
    for path, w in jax.tree_util.tree_leaves_with_path(params):
        if w.ndim < 2:
            continue
        l4 = leaves4[jax.tree_util.keystr(path)]
        k, n = w.shape
        assert l4.codes.nbytes == -(-k // 2) * n, path
    assert s4.packed_nbytes < s8.packed_nbytes


def test_weight_store_rejects_unknown_format():
    with pytest.raises(ValueError, match="weight format"):
        WeightStore.pack(tiny_params(tiny_model()), fmt="int3")


@pytest.mark.slow
def test_floatsd4_eval_loss_within_declared_tolerance():
    """Accuracy gate: serve a FloatSD8-trained model re-quantized to
    FloatSD4 and require the wikitext2 eval loss to stay within
    FLOATSD4_LOSS_TOL of FloatSD8 serving. Control: the FloatSD8 store
    evaluates to the exact fake-quant loss (same function, decoded)."""
    from repro.data import synthetic

    model = tiny_model()
    params = trained_params(model)
    data = synthetic.wikitext2(batch=32, seq=24, vocab=model.vocab)
    batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
    eval_policy = POLICY.replace(weight_quant="none")  # stores pre-quantize

    loss_fq = float(model.loss(params, batch, POLICY))
    s8 = WeightStore.pack(params)
    loss8 = float(model.loss(s8.tree, batch, eval_policy))
    np.testing.assert_allclose(loss8, loss_fq, rtol=1e-6)

    s4 = WeightStore.pack(params, fmt="floatsd4")
    loss4 = float(model.loss(s4.tree, batch, eval_policy))
    assert np.isfinite(loss4)
    assert abs(loss4 - loss8) <= FLOATSD4_LOSS_TOL, (
        f"FloatSD4 eval loss {loss4:.4f} drifted more than "
        f"{FLOATSD4_LOSS_TOL} from FloatSD8 serving loss {loss8:.4f}"
    )


@pytest.mark.slow
def test_floatsd4_engine_serves_with_floatsd8_token_control():
    """Engine-level gate: the FloatSD8 packed path must agree 100% with
    dense fake-quant greedy streams (the control that catches a broken
    store wiring), while weight_format='floatsd4' serves complete streams
    from the halved-footprint store."""
    model = tiny_model()
    params = trained_params(model)
    rng = np.random.default_rng(7)
    prompts = make_prompts(6, model.vocab, rng)

    def serve(**kw):
        eng = ServeEngine(model, params, POLICY, lanes=3, chunk=4, **kw)
        reqs = eng.submit_all([p.copy() for p in prompts], max_new=8)
        eng.run()
        return eng, [tuple(r.out) for r in sorted(reqs, key=lambda r: r.rid)]

    _, outs_dense = serve(packed=False)
    eng8, outs8 = serve(weight_format="floatsd8")
    eng4, outs4 = serve(weight_format="floatsd4")
    assert outs8 == outs_dense  # 100% token agreement: the control
    assert all(len(o) == 8 for o in outs4)
    assert eng4.store.packed_nbytes < eng8.store.packed_nbytes
