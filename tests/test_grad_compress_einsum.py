"""Gradient-compressed einsum (explicit-transpose VJP, bf16 dW emission):
forward identical; gradients match the plain einsum to bf16 tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.linear import _make_einsum_gc

EQS = [
    ("bd,dk->bk", (4, 16), (16, 8)),
    ("bsd,dk->bsk", (2, 6, 16), (16, 8)),
    ("gecd,edh->gech", (2, 3, 5, 8), (3, 8, 7)),
    ("...d,df->...f", (2, 3, 16), (16, 4)),
]


@pytest.mark.parametrize("eq,xs,ws", EQS)
def test_forward_identical(eq, xs, ws):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(xs), jnp.float32)
    w = jnp.asarray(rng.standard_normal(ws), jnp.float32)
    got = _make_einsum_gc(eq)(x, w)
    want = jnp.einsum(eq, x, w, preferred_element_type=jnp.float32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("eq,xs,ws", EQS)
def test_grads_match_to_bf16(eq, xs, ws):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(xs), jnp.float32)
    w = jnp.asarray(rng.standard_normal(ws), jnp.float32)

    def f_gc(x, w):
        return jnp.sum(_make_einsum_gc(eq)(x, w) ** 2)

    def f_plain(x, w):
        return jnp.sum(jnp.einsum(eq, x, w, preferred_element_type=jnp.float32) ** 2)

    gx1, gw1 = jax.grad(f_gc, argnums=(0, 1))(x, w)
    gx0, gw0 = jax.grad(f_plain, argnums=(0, 1))(x, w)
    # dx path is exact (f32 both ways)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx0), rtol=1e-5, atol=1e-5)
    # dw path: bf16 emission -> 2^-8 relative
    np.testing.assert_allclose(np.asarray(gw1), np.asarray(gw0), rtol=1e-2, atol=1e-2)
