"""Optimizer, train-step, and loss-goes-down integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import FLOATSD8_TABLE2, FLOATSD8_TABLE6, FP32
from repro.models.lstm_models import WikiText2LM
from repro.optim import adafactor, adam, init_state, make_train_step, sgd


def _toy_problem():
    """tiny quadratic: params w, loss = ||w - target||^2."""
    target = jnp.asarray([1.0, -2.0, 3.0])

    def loss(p, batch, policy):
        del batch, policy
        return jnp.sum((p["w"] - target) ** 2)

    return {"w": jnp.zeros(3)}, loss, target


@pytest.mark.parametrize("optname", ["sgd", "adam", "adafactor"])
def test_optimizers_converge_on_quadratic(optname):
    params, loss, target = _toy_problem()
    opt = {"sgd": sgd(0.9), "adam": adam(), "adafactor": adafactor()}[optname]
    pol = FP32
    state = init_state(params, opt, pol)
    step = jax.jit(make_train_step(loss, opt, pol, lr=0.1, grad_clip=None))
    for _ in range(200):
        state, m = step(state, None)
    np.testing.assert_allclose(np.asarray(state.params["w"]), np.asarray(target), atol=0.05)


def test_fp16_master_and_fp8_grads_still_converge():
    params, loss, target = _toy_problem()
    pol = FLOATSD8_TABLE6  # fp16 master, fp8 grads, ls=1024
    opt = adam()
    state = init_state(params, opt, pol)
    assert state.params["w"].dtype == jnp.float16
    step = jax.jit(make_train_step(loss, opt, pol, lr=0.05, grad_clip=None))
    for _ in range(300):
        state, m = step(state, None)
    assert bool(m["grads_finite"])
    np.testing.assert_allclose(
        np.asarray(state.params["w"], np.float32), np.asarray(target), atol=0.1
    )


def test_nonfinite_grads_skip_update():
    def loss(p, batch, policy):
        # batch == inf poisons the gradient itself (where() would not)
        return jnp.sum(p["w"] ** 2) * batch

    params = {"w": jnp.ones(2)}
    pol = FP32
    opt = sgd()
    state = init_state(params, opt, pol)
    step = jax.jit(make_train_step(loss, opt, pol, lr=0.1, grad_clip=None))
    state1, m1 = step(state, jnp.float32(jnp.inf))  # inf grads -> skip
    assert not bool(m1["grads_finite"])
    np.testing.assert_array_equal(np.asarray(state1.params["w"]), 1.0)
    state2, m2 = step(state1, jnp.float32(1.0))
    assert bool(m2["grads_finite"])
    assert float(state2.params["w"][0]) < 1.0


def _lm_batches(vocab, batch=8, seq=24, seed=0, noise=0.1):
    """successor-function stream (10% noise): quickly learnable, so the test
    checks optimization, not model capacity."""
    rng = np.random.default_rng(seed)
    while True:
        toks = np.zeros((batch, seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, batch)
        for t in range(1, seq + 1):
            nxt = (toks[:, t - 1] * 7 + 3) % vocab
            flip = rng.random(batch) < noise
            toks[:, t] = np.where(flip, rng.integers(0, vocab, batch), nxt)
        yield {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }


@pytest.mark.slow
@pytest.mark.parametrize("polname", ["fp32", "floatsd8_table6"])
def test_lstm_lm_loss_decreases(polname):
    """End-to-end: the paper's WikiText-2 model (reduced) trains under both
    FP32 and the FloatSD8 Table-VI policy; loss must drop substantially."""
    from repro.core.policy import get_policy

    pol = get_policy(polname)
    model = WikiText2LM(vocab=64, emb=32, hidden=48, n_layers=2)
    params = model.init(jax.random.PRNGKey(0))
    opt = adam()
    state = init_state(params, opt, pol)
    step = jax.jit(make_train_step(model.loss, opt, pol, lr=1e-2))
    gen = _lm_batches(64)
    first = None
    losses = []
    for i in range(120):
        state, m = step(state, next(gen))
        losses.append(float(m["loss"]))
        if first is None:
            first = float(m["loss"])
    last = float(np.mean(losses[-10:]))
    assert last < first - 1.0, (first, last)
    assert np.isfinite(last)
