"""Scatter-add MoE combine (perf hillclimb #4) vs the gather-based baseline:
identical outputs and gradients; top-k routing invariants hold."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import get_policy
from repro.nn.moe import MoE

pytestmark = pytest.mark.slow  # tier-2: see pyproject markers

POLICY = get_policy("fp32")
M = MoE(dim=32, hidden=48, n_experts=8, top_k=2, dispatch_groups=2)


def _run(gather: bool, seed=0):
    os.environ["REPRO_MOE_GATHER_COMBINE"] = "1" if gather else "0"
    try:
        p = M.init(jax.random.PRNGKey(seed))
        x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 12, 32))

        def f(p, x):
            y, aux = M.apply(p, x, POLICY)
            return jnp.sum(y**2) + aux, y

        (val, y), grads = jax.value_and_grad(f, has_aux=True)(p, x)
        return val, y, grads
    finally:
        os.environ.pop("REPRO_MOE_GATHER_COMBINE", None)


def test_combine_paths_identical():
    v0, y0, g0 = _run(True)
    v1, y1, g1 = _run(False)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(v0), float(v1), rtol=1e-6)
    for k in g0:
        np.testing.assert_allclose(
            np.asarray(g0[k]), np.asarray(g1[k]), rtol=1e-4, atol=1e-6, err_msg=k
        )


def test_moe_matches_dense_reference_routing():
    """y == sum_k gate_tk * expert_{e_tk}(x_t) for the realized routing
    (exact dense-MoE reference; no capacity drops at this size)."""
    from repro.nn.ffn import _silu

    p = M.init(jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 32)) * 0.3
    y, _ = M.apply(p, x, POLICY)

    xf = x.reshape(-1, 32)
    logits = jnp.einsum("td,de->te", xf, p["router"])
    gate, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), M.top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    # dense reference: run every expert on every token, pick routed ones
    all_e = jnp.stack([
        (_silu(xf @ p["wg"][e], False) * (xf @ p["wi"][e])) @ p["wo"][e]
        for e in range(M.n_experts)
    ])  # [E, t, d]
    want = sum(
        gate[:, k, None] * all_e[idx[:, k], jnp.arange(xf.shape[0])]
        for k in range(M.top_k)
    )
    np.testing.assert_allclose(
        np.asarray(y.reshape(-1, 32)), np.asarray(want), rtol=2e-3, atol=2e-4
    )


def test_capacity_overflow_drops_tokens_not_crashes():
    tiny = MoE(dim=16, hidden=16, n_experts=2, top_k=2, capacity_factor=0.1,
               dispatch_groups=1)
    p = tiny.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 16))
    y, aux = tiny.apply(p, x, POLICY)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
