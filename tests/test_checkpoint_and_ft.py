"""Checkpoint/restart, atomicity, keep-N, elastic reshard, straggler tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import FP32
from repro.distributed import checkpointing as ckpt
from repro.distributed.fault_tolerance import (
    PreemptionSignal,
    RestartableLoop,
    SimulatedFailure,
    StragglerMonitor,
)
from repro.optim import adam, init_state, make_train_step


def _setup(tmp_path):
    def loss(p, batch, policy):
        return jnp.sum((p["w"] - batch) ** 2)

    opt = adam()

    def init_fn():
        return init_state({"w": jnp.zeros(4)}, opt, FP32)

    step = jax.jit(make_train_step(loss, opt, FP32, lr=0.05, grad_clip=None))

    def batches():
        while True:
            yield jnp.asarray([1.0, 2.0, 3.0, 4.0])

    mgr = ckpt.CheckpointManager(str(tmp_path / "ck"), keep=2, async_write=False)
    return mgr, init_fn, step, batches


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float16(2.5)}}
    ckpt.save(str(tmp_path), tree, 7)
    out, step = ckpt.restore(str(tmp_path), tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(6).reshape(2, 3))
    assert out["b"]["c"].dtype == np.float16


def test_atomicity_tmp_never_visible(tmp_path):
    tree = {"a": jnp.zeros(3)}
    ckpt.save(str(tmp_path), tree, 1)
    # a stale tmp dir from a crashed save must not be picked up
    os.makedirs(str(tmp_path / "step_00000009.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_keep_n_gc(tmp_path):
    mgr = ckpt.CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save({"w": jnp.full(2, float(s))}, s)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000003", "step_00000004"]


def test_restart_resumes_bitwise(tmp_path):
    """Train 10 steps with a crash at 7 (ckpt cadence 5), relaunch, and
    compare against an uninterrupted 10-step run — bitwise equal."""
    mgr, init_fn, step, batches = _setup(tmp_path)
    loop = RestartableLoop(mgr, init_fn, save_every=5)
    with pytest.raises(SimulatedFailure):
        loop.run(step, batches(), n_steps=10, fail_at=7)
    # relaunch: resumes from step 5
    loop2 = RestartableLoop(mgr, init_fn, save_every=5)
    assert loop2.resumed and loop2.start_step == 5
    state, last = loop2.run(step, batches(), n_steps=10)
    assert last == 10
    # uninterrupted reference
    mgr2, init_fn2, step2, batches2 = _setup(tmp_path / "ref")
    ref_loop = RestartableLoop(mgr2, init_fn2, save_every=100)
    ref_state, _ = ref_loop.run(step2, batches2(), n_steps=10)
    np.testing.assert_array_equal(
        np.asarray(state.params["w"]), np.asarray(ref_state.params["w"])
    )
    np.testing.assert_array_equal(
        np.asarray(state.opt_state.mu["w"]), np.asarray(ref_state.opt_state.mu["w"])
    )


def test_preemption_checkpoint_and_exit(tmp_path):
    mgr, init_fn, step, batches = _setup(tmp_path)
    pre = PreemptionSignal()
    loop = RestartableLoop(mgr, init_fn, save_every=1000, preemption=pre)

    seen = []

    def on_metrics(s, m):
        seen.append(s)
        if s == 3:
            pre.set()  # SIGTERM arrives mid-run

    state, last = loop.run(step, batches(), n_steps=100, on_metrics=on_metrics)
    assert last == 3
    assert mgr.latest_step() == 3  # grace-window checkpoint happened


def test_elastic_reshard_restore(tmp_path):
    """Checkpoint written under one topology restores onto another mesh."""
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(str(tmp_path), tree, 1)
    devs = jax.devices()
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    mesh = Mesh(np.array(devs[:1]).reshape(1, 1), ("data", "model"))
    sh = NamedSharding(mesh, P("data", None))
    out, _ = ckpt.restore(str(tmp_path), tree, shardings={"w": sh})
    assert out["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(window=20, threshold=4.0)
    flagged = []
    for i in range(20):
        flagged.append(mon.record(i, 0.10 + 0.001 * (i % 3)))
    assert not any(flagged)
    assert mon.record(20, 0.50)  # 5x step time -> straggler
    assert mon.flagged[-1][0] == 20
