"""Pallas chunked-wkv kernel (interpret mode) vs the per-token recurrence."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.rwkv_wkv.kernel import wkv_pallas
from repro.kernels.rwkv_wkv.ops import wkv
from repro.kernels.rwkv_wkv.ref import wkv_ref


def _mk(bh, s, k, v=None, w0=-2.0, seed=0):
    rng = np.random.default_rng(seed + bh + s + k)
    v = v or k
    r = jnp.asarray(rng.standard_normal((bh, s, k)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((bh, s, k)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((bh, s, v)), jnp.float32)
    w = jnp.asarray(np.exp(-np.exp(rng.standard_normal((bh, s, k)) * 0.3 + w0)),
                    jnp.float32)
    u = jnp.asarray(rng.standard_normal((bh, k)) * 0.1, jnp.float32)
    return r, kk, vv, w, u


@pytest.mark.parametrize("bh,s,k", [(2, 64, 32), (4, 128, 64), (1, 32, 128)])
@pytest.mark.parametrize("w0", [-6.0, -2.0, 1.0])
def test_kernel_matches_recurrence(bh, s, k, w0):
    r, kk, vv, w, u = _mk(bh, s, k, w0=w0)
    got = wkv(r, kk, vv, w, u, chunk=16, interpret=True)
    want = wkv_ref(r, kk, vv, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_kernel_chunk_independence(chunk):
    r, kk, vv, w, u = _mk(1, 64, 32)
    a = wkv_pallas(r, kk, vv, w, u, chunk=chunk, interpret=True)
    b = wkv_ref(r, kk, vv, w, u)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_kernel_fallback_indivisible():
    r, kk, vv, w, u = _mk(2, 50, 32)
    got = wkv(r, kk, vv, w, u, chunk=16, interpret=True)
    want = wkv_ref(r, kk, vv, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_kernel_agrees_with_model_chunked_path():
    """kernel == nn/rwkv.py's XLA chunked path (same math, per head)."""
    from repro.nn.rwkv import RWKV6TimeMix

    tm = RWKV6TimeMix(dim=64, head_dim=32)  # 2 heads
    bh, s, hd = 2 * 2, 32, 32  # B=2 x H=2 flattened
    r, kk, vv, w, u = _mk(bh, s, hd, seed=3)
    got = wkv(r, kk, vv, w, u, chunk=16, interpret=True)

    b, h = 2, 2
    rs = r.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    ks = kk.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    vs = vv.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    ws = w.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    us = u.reshape(b, h, hd)[0]  # heads share per-head u rows in this test
    ys, _ = tm._wkv_chunked(rs, ks, vs, ws, us, jnp.zeros((b, h, hd, hd)), 16)
    want = ys.transpose(0, 2, 1, 3).reshape(bh, s, hd)
    # u differs per (b,h) row in `got` vs shared in model path; rebuild got
    got2 = wkv(r, kk, vv, w, jnp.tile(us, (b, 1)), chunk=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
