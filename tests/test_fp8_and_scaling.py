"""FP8 cast, loss scaling, and FP16-accumulation-sufficiency (paper §IV-C)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fp8, loss_scaling


def test_fp8_e5m2_structure():
    # 1-5-2: max normal 57344, saturating cast
    x = jnp.asarray([1e9, -1e9, 0.1], jnp.float32)
    q = np.asarray(fp8.quantize_fp8(x))
    assert q[0] == 57344.0 and q[1] == -57344.0
    assert abs(q[2] - 0.1) < 0.01
    assert np.all(np.isfinite(q))


def test_act_quant_quantizes_fwd_and_bwd():
    x = jnp.asarray([0.3333], jnp.float32)

    def f(v):
        return jnp.sum(fp8.act_quant(v) * 1.2345)

    y, g = jax.value_and_grad(f)(x)
    # forward went through fp8
    assert float(y) == float(
        x.astype(jnp.float8_e5m2).astype(jnp.float32)[0] * 1.2345
    )
    # backward cotangent quantized to fp8 grid
    expected = np.float32(1.2345)
    q_expected = jnp.asarray(expected).astype(jnp.float8_e5m2).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(g), float(q_expected))


def test_act_quant_fp16_site():
    x = jnp.asarray([1.0 + 2.0**-12], jnp.float32)
    y = fp8.act_quant(x, jnp.float16, jnp.float16)
    assert float(y[0]) == 1.0  # rounded in fp16


def test_static_loss_scale_roundtrip():
    st = loss_scaling.static_init(1024.0)
    loss = jnp.float32(0.5)
    scaled = loss_scaling.scale_loss(loss, st)
    assert float(scaled) == 512.0
    grads = {"w": jnp.asarray([1024.0, 2048.0])}
    un, ok = loss_scaling.unscale_and_check(grads, st)
    assert bool(ok)
    np.testing.assert_allclose(np.asarray(un["w"]), [1.0, 2.0])
    st2 = loss_scaling.adjust(st, ok)
    assert float(st2.scale) == 1024.0  # static: never changes


def test_dynamic_loss_scale_backoff_and_growth():
    st = loss_scaling.dynamic_init(2.0**10)
    bad = jnp.asarray(False)
    st_bad = loss_scaling.adjust(st, bad)
    assert float(st_bad.scale) == 2.0**9
    good = jnp.asarray(True)
    st_g = st
    for _ in range(3):
        st_g = loss_scaling.adjust(st_g, good, growth_interval=3)
    assert float(st_g.scale) == 2.0**11


def test_fp16_accumulation_sufficient_for_lstm_dot():
    """Paper §IV-C: 'FP16 accumulation is sufficient for all operations'.

    Emulate the MAC: FloatSD8 weight x FP8 act partial sums accumulated in
    fp16 vs fp32 reference — relative error stays small at LSTM-typical
    fan-in (4096).
    """
    from repro.core import floatsd

    rng = np.random.default_rng(0)
    k = 4096
    w = floatsd.quantize(jnp.asarray(rng.normal(0, 0.1, k), jnp.float32)).values
    a = (
        jnp.asarray(rng.normal(0, 1.0, k), jnp.float32)
        .astype(jnp.float8_e5m2)
        .astype(jnp.float32)
    )
    prods = w * a
    acc16 = jnp.cumsum(prods.astype(jnp.float16))[-1]
    acc32 = jnp.sum(prods)
    rel = abs(float(acc16) - float(acc32)) / (abs(float(acc32)) + 1e-9)
    assert rel < 0.05
