"""Fault-injection layer + self-healing recovery paths.

Covers the robustness contracts end to end:

  * ``repro.faults``: plan parsing (``@N`` / ``%p`` / ``:key`` / ``:n`` /
    payload args), seeded determinism, unknown-point rejection, and the
    disarmed zero-overhead state.
  * engine: injected nonfinite logits retire the lane with a terminal
    ``numeric_error`` ticket (never a hang, never poisoned tokens) — and
    the same guard trips on REAL NaN state reaching the decode step, not
    just on the injected host-side flag.
  * prefix cache: a corrupted entry is detected by checksum at lookup,
    served as a miss, and evicted.
  * router: a crashed replica is ejected, its in-flight work resubmitted
    with results identical to a fault-free run; transient step failures
    eject after ``eject_after`` strikes and a later probe reinstates.
  * checkpointing: a torn write (crash between arrays and manifest) is
    invisible to ``latest_step``/``restore``; re-saving over the torn tmp
    succeeds.
  * numeric guards: cast_fp8/quantize_fp8/grad_quant never silently turn
    inf/NaN finite; pack_tree (the deployment path) raises instead.
  * ServeMetrics: an all-errored window still reports safely.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fp8
from repro.core.policy import get_policy
from repro.distributed import checkpointing as ckpt
from repro.faults import FAULTS, FaultPlan, Faults, InjectedFault
from repro.models.lstm_models import WikiText2LM
from repro.serving import PrefixCache, Router
from repro.serving.metrics import ServeMetrics
from repro.serving.weight_store import pack_tree

POLICY = get_policy("floatsd8_table6")


@pytest.fixture(autouse=True)
def _disarm():
    """No test may leak an armed plan into the rest of the suite."""
    yield
    FAULTS.disarm()


def tiny_model():
    return WikiText2LM(vocab=300, emb=32, hidden=32, n_layers=2)


def prompts_for(n, seed=0, lo=4, hi=10, vocab=300):
    r = np.random.default_rng(seed)
    return [
        r.integers(0, vocab, size=int(r.integers(lo, hi))).astype(np.int32)
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# plan parsing / firing semantics
# ---------------------------------------------------------------------------


def test_plan_rejects_unknown_point():
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultPlan.parse("seed=1;bogus_point@1")


def test_at_rule_fires_once_on_nth_arrival():
    f = Faults()
    f.arm("seed=1;engine_step_raise@3")
    fires = [f.fire("engine_step_raise") is not None for _ in range(6)]
    assert fires == [False, False, True, False, False, False]
    assert f.stats()["injected"] == {"engine_step_raise": 1}
    assert f.stats()["arrivals"]["engine_step_raise"] == 6


def test_key_filter_counts_only_matching_arrivals():
    f = Faults()
    f.arm("seed=1;replica_crash@2:key=1")
    for _ in range(5):  # wrong replica: never counts, never fires
        assert f.fire("replica_crash", key=0) is None
    assert f.fire("replica_crash", key=1) is None  # 1st matching arrival
    assert f.fire("replica_crash", key=1) is not None  # 2nd: fires
    assert f.stats()["arrivals"]["replica_crash"] == 2


def test_prob_rule_is_deterministic_given_seed():
    def run(seed):
        f = Faults()
        f.arm(f"seed={seed};engine_step_slow%0.3:n=1000")
        return [f.fire("engine_step_slow") is not None for _ in range(200)]

    a, b = run(42), run(42)
    assert a == b, "same seed must replay the identical fire sequence"
    assert 20 < sum(a) < 120  # ~Bernoulli(0.3), loose sanity bounds
    assert run(43) != a, "different seed must give a different sequence"


def test_payload_args_and_max_fires():
    f = Faults()
    f.arm("seed=1;engine_step_slow%1.0:ms=40:n=2")
    p1 = f.fire("engine_step_slow")
    assert p1 is not None and float(p1["ms"]) == 40.0
    assert p1["point"] == "engine_step_slow"
    assert f.fire("engine_step_slow") is not None
    assert f.fire("engine_step_slow") is None, ":n=2 caps total fires"


def test_disarmed_registry_is_off_and_inert():
    f = Faults()
    assert not f.enabled
    assert f.fire("engine_step_raise") is None
    f.arm("seed=1;engine_step_raise@1")
    assert f.enabled
    f.disarm()
    assert not f.enabled
    assert f.fire("engine_step_raise") is None


# ---------------------------------------------------------------------------
# engine: nonfinite-logit guard
# ---------------------------------------------------------------------------


def test_injected_nonfinite_logits_retire_numeric_error():
    model = tiny_model()
    router = Router.build(
        model, model.init(jax.random.PRNGKey(0)), POLICY, lanes=2, chunk=4
    )
    FAULTS.arm("seed=1;nonfinite_logits@1")
    tickets = [router.submit(p, max_new=6) for p in prompts_for(4)]
    router.drain()  # the poisoned lane must resolve, not hang the pump
    statuses = [t.status for t in tickets]
    assert statuses.count("numeric_error") == 1, statuses
    assert all(s in ("done", "numeric_error") for s in statuses)
    bad = next(t for t in tickets if t.status == "numeric_error")
    assert bad.reason == "nonfinite_logits"
    assert router.report()["numeric_errors"] == 1


def test_real_nan_state_trips_the_isfinite_guard():
    """Pin the ``jnp.isfinite`` leg with genuine NaNs, not the injected
    host-side flag: a full-hit cache entry whose stored state is NaN gets
    injected into the lane, the next decode step computes NaN logits, and
    the engine must retire the request as numeric_error."""
    model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    cache = PrefixCache(block=4)
    router = Router.build(
        model, params, POLICY, prefix_cache=cache, lanes=2, chunk=4
    )
    prompt = np.arange(1, 9, dtype=np.int32)
    # a full-prompt entry (has next_token) whose state is all-NaN
    warm = router.submit(prompt, max_new=4)
    router.drain()
    assert warm.status == "done"
    entry = cache._entry_at(prompt, len(prompt))
    assert entry is not None and entry.next_token is not None
    nan_states = jax.tree_util.tree_map(
        lambda a: np.full_like(a, np.nan), entry.states_fp8
    )
    cache.insert(prompt, nan_states, next_token=entry.next_token)

    poisoned = router.submit(prompt, max_new=4)
    router.drain()
    assert poisoned.status == "numeric_error"
    assert router.report()["numeric_errors"] == 1


# ---------------------------------------------------------------------------
# prefix cache: corrupt-as-miss
# ---------------------------------------------------------------------------


def test_cache_corruption_detected_as_miss_and_evicted():
    cache = PrefixCache(block=4)
    key = np.arange(8, dtype=np.int32)
    states = [{"h": jnp.ones((4,), jnp.float32)}]
    FAULTS.arm("seed=1;cache_corrupt%1.0")
    cache.insert(key, states, next_token=7)
    FAULTS.disarm()
    assert cache.lookup(key) is None, "corrupt entry must be served as a miss"
    s = cache.stats()
    assert s["corruptions"] == 1 and s["misses"] == 1 and s["hits"] == 0
    assert len(cache) == 0, "the damaged entry must be evicted"
    cache.lookup(key)
    assert cache.stats()["corruptions"] == 1, "evicted: no repeat detection"


def test_cache_uncorrupted_insert_still_hits():
    cache = PrefixCache(block=4)
    key = np.arange(8, dtype=np.int32)
    cache.insert(key, [{"h": jnp.ones((4,), jnp.float32)}], next_token=7)
    hit = cache.lookup(key)
    assert hit is not None and hit.next_token == 7
    assert cache.stats()["corruptions"] == 0


# ---------------------------------------------------------------------------
# router: ejection, resubmission, reinstatement
# ---------------------------------------------------------------------------


def test_replica_crash_ejects_resubmits_and_matches_fault_free_tokens():
    model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    ps = prompts_for(8, seed=3)

    def serve(arm):
        router = Router.build(model, params, POLICY, replicas=2,
                              lanes=2, chunk=4)
        if arm:
            FAULTS.arm("seed=1;replica_crash@2:key=1")
        try:
            ts = [router.submit(p, max_new=6) for p in ps]
            router.drain()
        finally:
            FAULTS.disarm()
        return ts, router.stats()

    ref, _ = serve(arm=False)
    got, stats = serve(arm=True)
    assert [t.status for t in got] == ["done"] * 8
    assert stats["ejections"] == 1
    assert stats["healthy_replicas"] == 1
    assert stats["faults"]["injected"] == {"replica_crash": 1}
    for a, b in zip(ref, got):
        assert a.tokens == b.tokens, "recovery must not change results"


def test_transient_failures_eject_then_probe_reinstates():
    model = tiny_model()
    router = Router.build(
        model, model.init(jax.random.PRNGKey(0)), POLICY, replicas=2,
        lanes=2, chunk=4, router_kw={"eject_after": 2, "probe_every": 3},
    )
    # exactly eject_after transient raises on replica 1, then clean again
    FAULTS.arm("seed=1;engine_step_raise%1.0:key=1:n=2")
    tickets = [router.submit(p, max_new=6) for p in prompts_for(8, seed=5)]
    router.drain()
    FAULTS.disarm()
    assert [t.status for t in tickets] == ["done"] * 8
    stats = router.stats()
    assert stats["ejections"] == 1
    # the fault plan exhausted itself (:n=2), so a probe during the same
    # drain (or the next batch) brings the replica back
    more = [router.submit(p, max_new=6) for p in prompts_for(4, seed=6)]
    router.drain()
    assert [t.status for t in more] == ["done"] * 4
    stats = router.stats()
    assert stats["reinstatements"] >= 1
    assert stats["healthy_replicas"] == 2


# ---------------------------------------------------------------------------
# checkpointing: torn write
# ---------------------------------------------------------------------------


def test_torn_checkpoint_invisible_and_resavable(tmp_path):
    path = str(tmp_path)
    tree = {"w": jnp.arange(6, dtype=jnp.float32)}
    ckpt.save(path, tree, step=1)
    FAULTS.arm("seed=1;ckpt_torn_write@1")
    with pytest.raises(InjectedFault):
        ckpt.save(path, {"w": jnp.arange(6, dtype=jnp.float32) * 2}, step=2)
    FAULTS.disarm()
    assert (tmp_path / "step_00000002.tmp").is_dir(), "torn tmp left behind"
    assert ckpt.latest_step(path) == 1, "torn write must stay unpublished"
    out, step = ckpt.restore(path, tree)
    assert step == 1 and np.array_equal(np.asarray(out["w"]), np.arange(6))
    # re-saving the same step over the torn tmp dir must succeed
    ckpt.save(path, {"w": jnp.arange(6, dtype=jnp.float32) * 2}, step=2)
    assert ckpt.latest_step(path) == 2
    out, _ = ckpt.restore(path, tree)
    assert np.array_equal(np.asarray(out["w"]), np.arange(6) * 2)


# ---------------------------------------------------------------------------
# numeric guards: quantizers never silently finite-ize inf/NaN
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [fp8.FP8_E5M2, fp8.FP8_E4M3])
def test_cast_fp8_preserves_nonfinite(dtype):
    x = jnp.asarray([1.0, jnp.nan, jnp.inf, -jnp.inf, -2.0], jnp.float32)
    y = np.asarray(cast := fp8.cast_fp8(x, dtype), jnp.float32)
    assert cast.dtype == dtype
    assert np.isfinite(y[[0, 4]]).all()
    assert not np.isfinite(y[1:4]).any(), (
        f"nonfinite inputs must stay nonfinite, got {y}"
    )


@pytest.mark.parametrize("dtype", [fp8.FP8_E5M2, fp8.FP8_E4M3])
def test_quantize_fp8_preserves_nonfinite(dtype):
    x = jnp.asarray([jnp.nan, jnp.inf, 3.0], jnp.float32)
    y = np.asarray(fp8.quantize_fp8(x, dtype), jnp.float32)
    assert not np.isfinite(y[:2]).any() and np.isfinite(y[2])


def test_grad_quant_preserves_nonfinite():
    g = {"w": jnp.asarray([[jnp.nan, 1.0], [jnp.inf, -1.0]], jnp.float32)}
    q = fp8.grad_quant(g)
    y = np.asarray(q["w"], np.float32)
    assert not np.isfinite(y[0, 0]) and not np.isfinite(y[1, 0])
    assert np.isfinite(y[0, 1]) and np.isfinite(y[1, 1])


def test_pack_tree_raises_on_nonfinite_weights():
    params = {"emb": jnp.ones((4, 4), jnp.float32).at[1, 2].set(jnp.nan)}
    with pytest.raises(ValueError, match="nonfinite"):
        pack_tree(params)


# ---------------------------------------------------------------------------
# state pool: stale/damaged snapshots fail loudly at the boundary
# ---------------------------------------------------------------------------


def test_state_pool_inject_rejects_mismatched_snapshot():
    from repro.serving import StatePool

    pool = StatePool({"h": jnp.zeros((2, 4), jnp.float32)}, lanes=2)
    with pytest.raises(ValueError, match="does not match"):
        pool.inject(0, {"h": jnp.zeros((5,), jnp.float32)})
    with pytest.raises(ValueError, match="out of range"):
        pool.inject(3, {"h": jnp.zeros((4,), jnp.float32)})
    pool.inject(1, {"h": jnp.ones((4,), jnp.float32)})  # matching: fine
    assert np.array_equal(np.asarray(pool.caches["h"][1]), np.ones(4))


# ---------------------------------------------------------------------------
# metrics: all-errored window stays total
# ---------------------------------------------------------------------------


def test_metrics_report_safe_when_every_request_errored():
    m = ServeMetrics(lanes=2)
    m.start()
    m.on_step(width=1, active=2, useful=2, any_prefill=False)
    for _ in range(3):
        m.on_numeric_error(req=None)
    m.stop()
    rep = m.report()
    assert rep["numeric_errors"] == 3
    assert rep["requests"] == 0
    # percentile summaries over the (empty) record window must be total
    assert m.per_tenant() == {}
    assert rep["gen_tok_per_s"] >= 0.0
    assert 0.0 <= m.slot_util <= 1.0
