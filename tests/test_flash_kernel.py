"""Pallas flash-attention kernel (interpret mode) vs full-score oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ops import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def _mk(bh, s, d, skv=None, seed=0):
    rng = np.random.default_rng(seed + bh + s + d)
    skv = skv or s
    q = jnp.asarray(rng.standard_normal((bh, s, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, skv, d)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("bh,s,d", [(2, 128, 64), (4, 256, 32), (1, 512, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_kernel_matches_oracle(bh, s, d, causal):
    q, k, v = _mk(bh, s, d)
    got = flash_attention_kernel(q, k, v, causal=causal, interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=6e-3)


def test_kernel_sliding_window():
    q, k, v = _mk(2, 256, 64)
    got = flash_attention_kernel(q, k, v, causal=True, window=64, interpret=True)
    want = flash_attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=6e-3)


@pytest.mark.parametrize("bq,bk", [(8, 128), (32, 128), (64, 256)])
def test_kernel_tiling_independence(bq, bk):
    q, k, v = _mk(1, 256, 64)
    a = flash_attention_pallas(q, k, v, bq=bq, bk=bk, causal=True, interpret=True)
    b = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-2, atol=6e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    q, k, v = _mk(2, 128, 64)
    q, k, v = q.astype(dtype), k.astype(dtype), v.astype(dtype)
    got = flash_attention_kernel(q, k, v, interpret=True)
    assert got.dtype == dtype
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=2e-2,
    )


def test_kernel_fallback_indivisible():
    q, k, v = _mk(2, 100, 48)
    got = flash_attention_kernel(q, k, v, interpret=True)
    want = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_kernel_agrees_with_model_flash_path():
    """The Pallas kernel and the model's XLA flash_attention compute the
    same function (MHA case: Kh groups folded into BH)."""
    from repro.nn.attention import flash_attention as model_flash

    bh, s, d = 2, 128, 32
    q, k, v = _mk(bh, s, d)
    kq = flash_attention_kernel(q, k, v, causal=True, interpret=True)
    # model path shapes: q [B,S,Kh,G,D], k/v [B,S,Kh,D] with B=bh,Kh=G=1
    qm = q[:, :, None, None, :]
    km = k[:, :, None, :]
    vm = v[:, :, None, :]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bh, s))
    om = model_flash(qm, km, vm, pos, pos, causal=True, chunk=64, kv_chunk=64)
    np.testing.assert_allclose(
        np.asarray(kq), np.asarray(om[:, :, 0, 0, :]), rtol=2e-2, atol=6e-3
    )
