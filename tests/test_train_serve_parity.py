"""Train -> serve parity across dispatch backends.

Trains a tiny WikiText-2 LM a few steps, packs the master weights into the
serving WeightStore, and asserts the ServeEngine's greedy token streams
match the training-time fake-quant model's streams exactly under BOTH the
``ref`` and ``pallas`` dispatch backends — plus that the pallas run really
did resolve to the Pallas kernels (a tiling regression that silently turned
every call into jnp would fail the counter assertions, not just slow down).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import get_policy
from repro.kernels import dispatch as kd
from repro.models.lstm_models import WikiText2LM
from repro.serving import ServeEngine, WeightStore, synthetic_prompts

pytestmark = pytest.mark.slow  # trains a model; tier-2

POLICY = get_policy("floatsd8_table6")


@pytest.fixture(scope="module")
def trained():
    from repro.data import synthetic
    from repro.optim import sgd
    from repro.optim.train_state import init_state, make_train_step

    model = WikiText2LM(vocab=300, emb=32, hidden=32, n_layers=2)
    data = synthetic.wikitext2(batch=32, seq=24, vocab=model.vocab)
    opt = sgd(0.9)
    state = init_state(model.init(jax.random.PRNGKey(0)), opt, POLICY)
    step_fn = jax.jit(make_train_step(model.loss, opt, POLICY, lr=1.0))
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
        state, _ = step_fn(state, batch)
    return model, state.params


def _fake_quant_rollout(model, params, prompt, max_new, margin_floor=1e-5):
    """Greedy rollout on the training-time fake-quant path (dense params,
    weight_quant='floatsd8') -> (tokens, n_decisive). n_decisive bounds the
    prefix where every argmax had a top-2 margin > margin_floor, i.e. where
    the stream is invariant to sub-1e-5 lowering noise."""
    ones = jnp.ones((1,), jnp.int32)

    def step(tok, states):
        lg, st = model.decode_step(
            params, jnp.asarray([[tok]], jnp.int32), states, POLICY, lengths=ones
        )
        return np.asarray(lg[0, -1, :]), st

    states = model.init_cache(1, POLICY)
    logits = None
    for t in prompt:
        logits, states = step(int(t), states)
    out, n_decisive, decisive = [], 0, True
    for _ in range(max_new):
        top2 = np.sort(logits)[-2:]
        decisive = decisive and (top2[1] - top2[0]) > margin_floor
        nxt = int(logits.argmax())
        out.append(nxt)
        if decisive:
            n_decisive += 1
        logits, states = step(nxt, states)
    return out, n_decisive


def test_packed_serve_matches_fake_quant_under_both_backends(trained):
    model, params = trained
    rng = np.random.default_rng(0)
    prompts = synthetic_prompts(6, model.vocab, rng, lo=2, hi=12)
    max_new = 5

    refs = [_fake_quant_rollout(model, params, p, max_new) for p in prompts]
    # the trained model must give decisive margins for the comparison to bite
    assert sum(n for _, n in refs) >= max_new * len(prompts) // 2

    store = WeightStore.pack(params)
    assert store.n_packed > 0

    streams = {}
    for backend in ("ref", "pallas"):
        kd.STATS.reset()
        with kd.use_backend(backend):
            eng = ServeEngine(model, params, POLICY, lanes=3, chunk=4, packed=True)
            reqs = eng.submit_all([p.copy() for p in prompts], max_new=max_new)
            eng.run()
        streams[backend] = [tuple(r.out) for r in sorted(reqs, key=lambda r: r.rid)]
        for r in sorted(reqs, key=lambda r: r.rid):
            ref_out, n = refs[r.rid]
            assert len(r.out) == max_new
            assert list(r.out[:n]) == ref_out[:n], (backend, r.rid)
        if backend == "pallas":
            # the kernels actually ran — matmuls AND the fused cell
            assert kd.STATS.count("floatsd_matmul", "pallas") > 0
            assert kd.STATS.count("lstm_cell", "pallas") > 0
            assert kd.STATS.count("floatsd_matmul", "ref") == 0
        else:
            assert kd.STATS.count("floatsd_matmul", "pallas") == 0

    # ref and pallas serve the same packed codes: full-stream agreement
    assert streams["ref"] == streams["pallas"]


def test_packed_weights_are_inference_only(trained):
    """Satellite guard: differentiating through a PackedTensor weight site
    must raise a clear error instead of silently yielding zero/missing
    grads (the codes have no VJP)."""
    model, params = trained
    store = WeightStore.pack(params)
    packed_params = store.tree

    # grads w.r.t. a DENSE input while packed weights sit in the graph:
    # the silent-zero hazard. The dispatch guard must raise.
    def loss_wrt_x(x):
        pt = packed_params["lstm0"]["wx"]
        return jnp.sum(kd.packed_einsum("bd,dk->bk", x, pt))

    x = jnp.ones((2, 32), jnp.float32)
    with pytest.raises(TypeError, match="inference-only"):
        jax.grad(loss_wrt_x)(x)

    # and through a whole packed LSTM layer (the hoist_packed decode path):
    # a training-style grad w.r.t. the sequence input must also fail loudly
    from repro.nn.lstm import LSTMLayer

    layer = LSTMLayer(model.emb, model.hidden)

    def loss_wrt_xs(xs):
        h, _ = layer.apply(packed_params["lstm0"], xs, POLICY)
        return jnp.sum(h.astype(jnp.float32) ** 2)

    xs = jnp.ones((2, 4, model.emb), jnp.float32)
    with pytest.raises(TypeError, match="inference-only"):
        jax.grad(loss_wrt_xs)(xs)


def test_engine_default_backend_unchanged_tokens(trained):
    """auto (the default) must serve the exact same streams as forced ref on
    CPU — the dispatch layer cannot change served outputs by default."""
    model, params = trained
    rng = np.random.default_rng(1)
    prompts = synthetic_prompts(4, model.vocab, rng, lo=2, hi=10)

    def serve(backend):
        with kd.use_backend(backend):
            eng = ServeEngine(model, params, POLICY, lanes=2, chunk=4, packed=True)
            reqs = eng.submit_all([p.copy() for p in prompts], max_new=4)
            eng.run()
        return [tuple(r.out) for r in sorted(reqs, key=lambda r: r.rid)]

    assert serve("auto") == serve("ref")
