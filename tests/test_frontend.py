"""repro.serving.frontend tests: FP8 prefix-cache trie semantics, LRU
eviction, StatePool inject/extract, router admission/backpressure/
streaming/balancing, the asyncio facade, and the acceptance bar — a warm
prefix cache serves a zipf-prefix workload with >= 30% fewer prefill steps
and 100% token agreement vs the cold path."""
import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policy import get_policy
from repro.models.lstm_models import WikiText2LM
from repro.serving import (
    PrefixCache,
    Router,
    ServeEngine,
    StatePool,
    zipf_prefix_prompts,
)
from repro.serving.frontend import AsyncRouter

POLICY = get_policy("floatsd8_table6")


def tiny_model():
    return WikiText2LM(vocab=300, emb=32, hidden=32, n_layers=2)


def tiny_params(model, seed=0):
    return model.init(jax.random.PRNGKey(seed))


_TRAINED = {}


def trained_params(model):
    """Briefly-pretrained params (see test_serving.py): decisive argmax
    margins, which the FP8 state-rounding perturbation must not flip."""
    key = (model.vocab, model.emb, model.hidden, model.n_layers)
    if key not in _TRAINED:
        from repro.data import synthetic
        from repro.optim import sgd
        from repro.optim.train_state import init_state, make_train_step

        data = synthetic.wikitext2(batch=32, seq=24, vocab=model.vocab)
        opt = sgd(0.9)
        state = init_state(model.init(jax.random.PRNGKey(0)), opt, POLICY)
        step_fn = jax.jit(make_train_step(model.loss, opt, POLICY, lr=1.0))
        for _ in range(30):
            batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
            state, _ = step_fn(state, batch)
        _TRAINED[key] = state.params
    return _TRAINED[key]


def fake_states(seed=0, hidden=4):
    """A snapshot-shaped pytree: two layers of (h f32, c f16)."""
    r = np.random.default_rng(seed)
    return [
        {
            "h": jnp.asarray(r.normal(size=hidden), jnp.float32),
            "c": jnp.asarray(r.normal(size=hidden), jnp.float16),
        }
        for _ in range(2)
    ]


# ---------------------------------------------------------------------------
# prefix cache
# ---------------------------------------------------------------------------


def test_prefix_cache_longest_prefix_lookup():
    cache = PrefixCache(block=4)
    seq = np.arange(20, dtype=np.int32)
    cache.insert(seq[:8], fake_states(1))
    cache.insert(seq[:16], fake_states(2))

    hit = cache.lookup(seq)  # both are proper prefixes; deepest wins
    assert hit is not None and hit.match_len == 16 and hit.next_token is None

    div = seq.copy()
    div[12] += 1  # diverges inside (8, 16) -> only the 8-entry matches
    assert cache.lookup(div).match_len == 8
    assert cache.lookup(np.arange(5, 25, dtype=np.int32)) is None
    assert cache.stats()["misses"] == 1 and cache.stats()["hits"] == 2


def test_prefix_cache_full_hit_requires_next_token():
    cache = PrefixCache(block=4)
    seq = np.arange(12, dtype=np.int32)
    cache.insert(seq[:8], fake_states(1))
    cache.insert(seq, fake_states(2))  # full-length entry, NO next_token

    # a bare state can't produce the first generated token -> fall back
    hit = cache.lookup(seq)
    assert hit.match_len == 8 and not hit.full

    cache.insert(seq, fake_states(2), next_token=42)
    hit = cache.lookup(seq)
    assert hit.match_len == 12 and hit.full and hit.next_token == 42
    # ...but the same entry is NOT a full hit for an extending query
    hit = cache.lookup(np.concatenate([seq, np.asarray([7], np.int32)]))
    assert hit.match_len == 12 and hit.next_token is None


def test_prefix_cache_fp8_storage_and_dtype_restore():
    cache = PrefixCache(block=4)
    states = fake_states(3)
    cache.insert(np.arange(8, dtype=np.int32), states)
    entry = next(iter(cache._lru.values()))
    for leaf in jax.tree_util.tree_leaves(entry.states_fp8):
        assert leaf.dtype.itemsize == 1  # genuinely stored as 1-byte FP8

    # query extends the key: the entry is a proper prefix -> usable hit
    hit = cache.lookup(np.arange(9, dtype=np.int32))
    assert hit.match_len == 8
    for got, want in zip(
        jax.tree_util.tree_leaves(hit.states), jax.tree_util.tree_leaves(states)
    ):
        assert got.dtype == want.dtype  # pool dtypes restored
        # e4m3: 3-bit mantissa -> relative error <= 2^-4 (+ subnormal floor)
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(want, np.float32),
            rtol=2**-4,
            atol=2**-10,
        )


def test_prefix_cache_lru_eviction_under_byte_budget():
    probe = fake_states(0, hidden=64)
    per_entry = sum(
        l.size for l in jax.tree_util.tree_leaves(probe)
    ) + 8 * 4  # fp8 payload + key tokens

    def ext(k):  # query = key + one diverging token -> proper-prefix hit
        return np.concatenate([k, np.asarray([9999], np.int32)])

    cache = PrefixCache(budget_bytes=3 * per_entry, block=4)
    keys = [np.arange(i * 100, i * 100 + 8, dtype=np.int32) for i in range(5)]
    for i, k in enumerate(keys[:3]):
        cache.insert(k, fake_states(i, hidden=64))
    assert len(cache) == 3
    cache.lookup(ext(keys[0]))  # refresh entry 0: now entry 1 is LRU
    cache.insert(keys[3], fake_states(3, hidden=64))
    assert cache.stats()["evictions"] == 1
    assert cache.lookup(ext(keys[1])) is None  # evicted
    assert cache.lookup(ext(keys[0])) is not None  # protected by recency
    assert cache.nbytes <= cache.budget_bytes


def test_prefix_cache_upgrades_block_snapshot_with_next_token():
    """A next_token-less block snapshot occupying a key must stay
    upgradeable (wants() True), or a prompt whose length lands on a
    snapshotted block boundary could never gain the full-hit path."""
    cache = PrefixCache(block=8)
    seq = np.arange(16, dtype=np.int32)
    cache.insert(seq[:8], fake_states(0))  # block snapshot, no next_token
    assert cache.lookup(seq[:8]) is None  # full-length, unusable
    assert cache.wants(seq[:8], 8)  # ...so an upgrade is wanted
    cache.insert(seq[:8], fake_states(0), next_token=5)
    hit = cache.lookup(seq[:8])
    assert hit.full and hit.next_token == 5
    assert not cache.wants(seq[:8], 8) and len(cache) == 1


def test_prefix_cache_wants_snapshot_block_alignment():
    cache = PrefixCache(block=8)
    seq = np.arange(24, dtype=np.int32)
    assert not cache.wants_snapshot(seq, 4)  # unaligned
    assert not cache.wants_snapshot(seq, 0)
    assert cache.wants_snapshot(seq, 8) and cache.wants_snapshot(seq, 16)
    cache.insert(seq[:8], fake_states(0))
    assert not cache.wants_snapshot(seq, 8)  # already cached
    assert cache.wants(seq, 24) and not cache.wants(seq, 0)


# ---------------------------------------------------------------------------
# state pool inject/extract
# ---------------------------------------------------------------------------


def test_state_pool_inject_extract_roundtrip():
    key = jax.random.PRNGKey(0)
    caches = {
        "a": jax.random.normal(key, (3, 4)),
        "b": [jax.random.normal(key, (3, 2, 5), dtype=jnp.float16)],
    }
    pool = StatePool(caches, lanes=3)
    before = jax.tree_util.tree_map(np.asarray, pool.caches)
    snap = jax.tree_util.tree_map(lambda c: c[0] * 2 + 1, caches)
    pool.inject(1, snap)
    got = pool.extract(1)
    for g, s in zip(jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(snap)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(s.astype(g.dtype)))
    # neighbours untouched
    for lane in (0, 2):
        for g, b in zip(
            jax.tree_util.tree_leaves(pool.extract(lane)),
            jax.tree_util.tree_leaves(
                jax.tree_util.tree_map(lambda c: c[lane], before)
            ),
        ):
            np.testing.assert_array_equal(np.asarray(g), b)


# ---------------------------------------------------------------------------
# router: admission, backpressure, accounting
# ---------------------------------------------------------------------------


def test_router_backpressure_and_rejection_reasons():
    model = tiny_model()
    params = tiny_params(model)
    eng = ServeEngine(model, params, POLICY, lanes=2)
    router = Router([eng], max_queue=2, tenant_quota=2)

    ok1 = router.submit(np.ones(3, np.int32), max_new=1, tenant="a")
    ok2 = router.submit(np.ones(3, np.int32), max_new=1, tenant="b")
    full = router.submit(np.ones(3, np.int32), max_new=1, tenant="a")
    assert ok1.ok and ok2.ok
    assert full.status == "rejected" and full.reason == "queue_full"

    bad = Router([eng], max_queue=8).submit(np.zeros(0, np.int32), max_new=1)
    assert bad.status == "rejected" and bad.reason == "bad_request"

    r2 = Router([ServeEngine(model, params, POLICY, lanes=2)],
                max_queue=8, tenant_quota=1)
    a1 = r2.submit(np.ones(3, np.int32), max_new=1, tenant="a")
    a2 = r2.submit(np.ones(3, np.int32), max_new=1, tenant="a")
    b1 = r2.submit(np.ones(3, np.int32), max_new=1, tenant="b")
    assert a1.ok and b1.ok
    assert a2.status == "rejected" and a2.reason == "tenant_quota"
    assert r2.tenants["a"]["rejected"] == 1 and r2.tenants["b"]["rejected"] == 0


def test_router_deadline_expired_rejected_at_dispatch():
    import time

    model = tiny_model()
    params = tiny_params(model)
    router = Router(
        [ServeEngine(model, params, POLICY, lanes=2)], admission="edf"
    )
    dead = router.submit(
        np.ones(3, np.int32), max_new=1, deadline=time.monotonic() - 1.0
    )
    live = router.submit(np.ones(3, np.int32), max_new=2)
    router.drain()
    assert dead.status == "rejected" and dead.reason == "deadline_expired"
    assert live.status == "done" and len(live.tokens) == 2
    assert router.rejections == {"deadline_expired": 1}


def test_router_queue_pressure_purges_expired_before_rejecting():
    """Under saturation, queued dead work (expired deadlines) must not
    hold the slots backpressure is rationing — a fresh serviceable
    request purges it instead of bouncing with queue_full."""
    import time

    model = tiny_model()
    params = tiny_params(model)
    router = Router(
        [ServeEngine(model, params, POLICY, lanes=2)],
        max_queue=2, admission="edf",
    )
    far = time.monotonic() + 1e3
    t1 = router.submit(np.ones(3, np.int32), max_new=1, deadline=far)
    # expires "in the queue": a future deadline at submit, passed by the
    # time pressure hits (simulated with an already-elapsed instant —
    # submit-time DOA rejection is a separate check below)
    t2 = router.submit(np.ones(3, np.int32), max_new=1)
    t2.req.deadline = time.monotonic() - 1.0  # expired while queued
    t3 = router.submit(np.ones(3, np.int32), max_new=1, deadline=far)
    assert t1.ok and t3.ok  # t3 displaced the dead t2 instead of bouncing
    assert t2.status == "rejected" and t2.reason == "deadline_expired"
    # dead on arrival is rejected at submit, before counting against queue
    doa = router.submit(
        np.ones(3, np.int32), max_new=1, deadline=time.monotonic() - 1.0
    )
    assert doa.status == "rejected" and doa.reason == "deadline_expired"
    router.drain()
    assert t1.status == "done" and t3.status == "done"


@pytest.mark.slow
def test_router_streaming_callbacks_and_per_tenant_report():
    model = tiny_model()
    params = tiny_params(model)
    router = Router([ServeEngine(model, params, POLICY, lanes=2, chunk=4)])
    rng = np.random.default_rng(0)
    streamed = {}
    tickets = []
    for i in range(5):
        tenant = ("a", "b")[i % 2]
        streamed[i] = []
        tickets.append(
            router.submit(
                rng.integers(0, model.vocab, 6).astype(np.int32),
                max_new=4,
                tenant=tenant,
                on_token=streamed[i].append,
            )
        )
    router.drain()
    for i, t in enumerate(tickets):
        assert t.status == "done"
        assert streamed[i] == t.tokens and len(t.tokens) == 4
    rep = router.report()
    assert rep["requests"] == 5
    assert rep["tenants"]["a"]["completed"] == 3
    assert rep["tenants"]["b"]["completed"] == 2
    assert rep["tenants"]["a"]["tokens"] == 12
    assert all(
        rep["tenants"][t]["ttft_p95_s"] <= rep["tenants"][t]["latency_p95_s"]
        for t in ("a", "b")
    )


@pytest.mark.slow
def test_router_least_loaded_across_replicas():
    model = tiny_model()
    params = tiny_params(model)
    engines = [
        ServeEngine(model, params, POLICY, lanes=2, chunk=4) for _ in range(2)
    ]
    router = Router(engines)
    rng = np.random.default_rng(1)
    for _ in range(6):
        router.submit(rng.integers(0, model.vocab, 6).astype(np.int32), max_new=3)
    router.drain()
    done = [len(e.metrics.records) for e in engines]
    assert sum(done) == 6
    assert all(n >= 2 for n in done), done  # both replicas pulled weight


@pytest.mark.slow
def test_async_router_concurrent_generate_and_stream():
    model = tiny_model()
    params = tiny_params(model)
    router = Router([ServeEngine(model, params, POLICY, lanes=2, chunk=4)])
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, model.vocab, 5).astype(np.int32) for _ in range(3)]

    async def main():
        ar = AsyncRouter(router)

        async def consume_stream():
            toks = []
            async for tok in ar.stream(prompts[2], max_new=3):
                toks.append(tok)
            return toks

        t1, t2, toks = await asyncio.gather(
            ar.generate(prompts[0], max_new=3),
            ar.generate(prompts[1], max_new=3),
            consume_stream(),
        )
        # early consumer exit closes the generator promptly (abandoned
        # flag, not a blocking wait for the whole generation)
        first = None
        async for tok in ar.stream(prompts[0], max_new=8):
            first = tok
            break
        return t1, t2, toks, first

    t1, t2, toks, first = asyncio.run(main())
    assert t1.status == "done" and len(t1.tokens) == 3
    assert t2.status == "done" and len(t2.tokens) == 3
    assert len(toks) == 3
    assert first is not None


# ---------------------------------------------------------------------------
# engine x prefix cache semantics
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_full_hit_skips_prefill_entirely():
    """Resubmitting an identical prompt: the cached full-prefix entry's
    stored next_token is emitted at admission, prefill costs zero steps,
    and the streams match exactly (greedy continuation is deterministic)."""
    model = tiny_model()
    params = trained_params(model)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, model.vocab, 9).astype(np.int32) for _ in range(3)]
    cache = PrefixCache(block=4)

    eng1 = ServeEngine(model, params, POLICY, lanes=2, chunk=4, prefix_cache=cache)
    reqs1 = eng1.submit_all([p.copy() for p in prompts], max_new=4)
    m1 = eng1.run()
    assert m1.cache_hits == 0 and m1.prefill_steps > 0

    eng2 = ServeEngine(model, params, POLICY, lanes=2, chunk=4, prefix_cache=cache)
    reqs2 = eng2.submit_all([p.copy() for p in prompts], max_new=4)
    m2 = eng2.run()
    assert m2.cache_full_hits == 3 and m2.prefill_steps == 0
    assert m2.prompt_tokens == 0  # no prompt token ever touched the device
    assert m2.prefill_tokens_saved == sum(len(p) for p in prompts)
    for r1, r2 in zip(
        sorted(reqs1, key=lambda r: r.rid), sorted(reqs2, key=lambda r: r.rid)
    ):
        # the first token is architecturally exact (the stored next_token,
        # recorded from the unperturbed run); later tokens decode from the
        # FP8-rounded injected state — end-to-end 100% stream agreement on
        # decisive-margin models is locked by the zipf acceptance test below
        assert r1.out[0] == r2.out[0]
        assert len(r2.out) == len(r1.out)
    # full hit with max_new=1 completes with zero device steps
    eng3 = ServeEngine(model, params, POLICY, lanes=2, chunk=4, prefix_cache=cache)
    [r] = eng3.submit_all([prompts[0].copy()], max_new=1)
    m3 = eng3.run()
    assert m3.steps == 0 and r.out == reqs1[0].out[:1]


@pytest.mark.slow
def test_block_aligned_prompt_gains_full_hit_after_upgrade():
    """Serving a long prompt leaves next_token-less block snapshots at 8
    and 16; a later prompt equal to the 16-token prefix must upgrade that
    entry at prefill-done, and the next resubmission is a full hit."""
    model = tiny_model()
    params = tiny_params(model)
    rng = np.random.default_rng(5)
    long_prompt = rng.integers(0, model.vocab, 24).astype(np.int32)
    prefix = long_prompt[:16]
    cache = PrefixCache(block=8)

    def serve_one(prompt):
        eng = ServeEngine(
            model, params, POLICY, lanes=2, chunk=8, prefix_cache=cache
        )
        eng.submit(prompt.copy(), max_new=2)
        return eng.run()

    serve_one(long_prompt)
    m2 = serve_one(prefix)  # partial hit at 8, upgrades the 16-entry
    assert m2.cache_full_hits == 0 and m2.prefill_steps == 1
    m3 = serve_one(prefix)  # upgraded entry -> prefill-free full hit
    assert m3.cache_full_hits == 1 and m3.prefill_steps == 0


def test_engine_rejects_cache_with_non_rearmable_pool():
    model = tiny_model()
    params = tiny_params(model)

    class NoLengths:
        """Model facade whose decode_step lacks `lengths` -> lockstep only."""

        supports_packed = True

        def __init__(self, inner):
            self._inner = inner

        def __getattr__(self, k):
            return getattr(self._inner, k)

        def decode_step(self, p, tokens, states, policy):
            return self._inner.decode_step(p, tokens, states, policy)

    with pytest.raises(ValueError, match="lane-major"):
        ServeEngine(
            NoLengths(model), params, POLICY, lanes=2,
            prefix_cache=PrefixCache(),
        )


# ---------------------------------------------------------------------------
# acceptance: zipf-prefix workload, warm vs cold
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_zipf_prefix_warm_cache_saves_30pct_prefill_with_exact_tokens():
    """The frontend acceptance bar (mirrors bench_serving --workload
    zipf-prefix): on a shared-system-prompt workload, a warm FP8 prefix
    cache yields >= 30% fewer prefill steps than the cold path with 100%
    token agreement."""
    model = tiny_model()
    params = trained_params(model)
    wkw = dict(
        n_prefixes=3, prefix_len=16, suffix_lo=2, suffix_hi=6, prefix_seed=7
    )
    warmup = zipf_prefix_prompts(16, model.vocab, np.random.default_rng(1), **wkw)
    measure = zipf_prefix_prompts(16, model.vocab, np.random.default_rng(2), **wkw)

    def serve(prompts, cache):
        eng = ServeEngine(
            model, params, POLICY, lanes=4, chunk=8, prefix_cache=cache
        )
        reqs = eng.submit_all([p.copy() for p in prompts], max_new=6)
        m = eng.run()
        return [tuple(r.out) for r in sorted(reqs, key=lambda r: r.rid)], m

    cold_outs, cold = serve(measure, None)
    cache = PrefixCache(block=8)
    serve(warmup, cache)  # same system prompts, all-fresh suffixes
    warm_outs, warm = serve(measure, cache)

    assert warm.prefill_steps <= 0.7 * cold.prefill_steps, (
        warm.prefill_steps, cold.prefill_steps,
    )
    assert warm.prefill_tokens_saved > 0 and warm.cache_hit_rate > 0.5
    assert warm_outs == cold_outs  # 100% token agreement, FP8-stored states
