"""Per-assigned-architecture smoke tests (deliverable f).

Each arch instantiates its REDUCED same-family config (ArchConfig.reduced():
small width, few experts, tiny vocab, stub frontends) and runs one
forward/train step and one decode step on CPU, asserting output shapes and
finiteness. The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.core.policy import get_policy
from repro.models import build
from repro.optim import adam
from repro.optim.train_state import init_state, make_train_step

pytestmark = pytest.mark.slow  # tier-2: see pyproject markers

POLICY = get_policy("floatsd8_table6")
B, S = 2, 16


def _batch(cfg, rng):
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "audio":
        b["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)), jnp.float32
        )
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build(cfg)
    rng = np.random.default_rng(hash(arch) % 2**31)
    batch = _batch(cfg, rng)

    params = model.init(jax.random.PRNGKey(0))
    # forward: loss is finite
    loss = model.loss(params, batch, POLICY)
    assert jnp.isfinite(loss), (arch, float(loss))

    # one optimizer step under the paper's Table-VI policy
    opt = adam()
    state = init_state(params, opt, POLICY)
    step = jax.jit(make_train_step(model.loss, opt, POLICY, lr=1e-3))
    state, metrics = step(state, batch)
    assert bool(metrics["grads_finite"]), arch
    assert jnp.isfinite(metrics["loss"]), arch
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize(
    "arch", [a for a in ARCH_IDS if get_config(a).family != "audio"]
)
def test_reduced_config_decode_step(arch):
    """One serve_step: new token against a small cache; shapes + finite."""
    cfg = get_config(arch).reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    if cfg.family == "lstm":
        caches = model.init_cache(B, POLICY)
    else:
        caches = model.init_cache(B, 32)
    tokens = jnp.zeros((B, 1), jnp.int32)
    logits, new_caches = model.decode_step(params, tokens, caches, POLICY)
    vpad = cfg.vocab if cfg.family == "lstm" else cfg.vocab_padded()
    assert logits.shape == (B, 1, vpad), (arch, logits.shape)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    # cache structure is preserved
    assert jax.tree_util.tree_structure(caches) == jax.tree_util.tree_structure(
        new_caches
    )


def test_whisper_decode_with_encoder_context():
    """Whisper's decode: encoder once -> cross-KV prefill -> token steps."""
    cfg = get_config("whisper_large_v3").reduced()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(0)
    frames = jnp.asarray(
        rng.standard_normal((B, cfg.enc_seq, cfg.d_model)), jnp.float32
    )
    enc = model.encode(params, frames, POLICY)
    assert enc.shape == (B, cfg.enc_seq, cfg.d_model)
    caches = model.init_cache(B, 32)
    caches = model.prefill_cross(params, frames, caches, POLICY)
    logits, _ = model.decode_step(
        params, jnp.zeros((B, 1), jnp.int32), caches, POLICY
    )
    assert logits.shape[0] == B and bool(jnp.all(jnp.isfinite(logits)))
