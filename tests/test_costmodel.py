"""Cost-model observatory tests: CostSpec registry coverage, Decision cost
attribution, the ref-backend exactness contract (predicted HBM bytes ==
ndarray bytes actually touched, tolerance 0), pallas padding-waste/VMEM
accounting, the Table-7 MAC tie, the CostLedger join, and the
check_bench/check_trace CI gates (injected regressions must fail with the
op named)."""
import importlib.util
import json
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import floatsd
from repro.kernels import dispatch as kd
from repro.kernels.floatsd4_matmul import cost as fm4_cost
from repro.kernels.floatsd_matmul import cost as fm_cost
from repro.kernels.lstm_cell import cost as lc_cost
from repro.obs import costmodel
from repro.obs.trace import Tracer

_ROOT = Path(__file__).parent.parent


def _load(name: str, rel: str):
    spec = importlib.util.spec_from_file_location(name, _ROOT / rel)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


check_bench = _load("check_bench", "scripts/check_bench.py")
check_trace = _load("check_trace", "scripts/check_trace.py")
table7 = _load("table7_mac", "benchmarks/table7_mac.py")


def _w(shape, scale=1.0, seed_extra=0):
    seed = (hash((shape, float(scale), seed_extra)) & 0x7FFFFFFF) or 1
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal(shape) * scale).astype(np.float32))


def _run_all_ops(backend: str) -> None:
    """One call of every registered op under ``backend`` (ref-friendly
    odd shapes; wkv/flash shapes chosen tile-divisible so pallas does not
    fall back)."""
    m, k, n = 5, 37, 19
    x = _w((m, k), 0.5)
    codes, bias = floatsd.encode(_w((k, n), 0.05))
    g = _w((m, n), 0.5, seed_extra=1)
    b, h = 3, 70
    z = _w((b, 4 * h), 1.5)
    c = _w((b, h), 0.8)
    with kd.use_backend(backend):
        kd.matmul(x, codes, bias)
        kd.matmul4(x, kd.pack4(_w((k, n), 0.05)))
        kd.matmul_dx(g, codes, bias)
        kd.matmul_dw(x, g)
        kd.lstm_cell(z, c)
        kd.lstm_cell_grad(z, c, _w((b, h), 1.0, 2), _w((b, h), 1.0, 3))
        kd.quantize(_w((7, 33), 0.7))
        kd.qsigmoid(_w((7, 33), 2.0))
        rng = np.random.default_rng(11)
        decay = jnp.asarray(
            np.exp(-np.exp(rng.standard_normal((2, 32, 8)) * 0.3 - 2.0)),
            jnp.float32,
        )
        kd.rwkv_wkv(_w((2, 32, 8)), _w((2, 32, 8), 1.0, 4),
                    _w((2, 32, 8), 1.0, 5), decay, _w((2, 8), 0.1))
        kd.flash_attention(_w((2, 16, 8)), _w((2, 128, 8), 1.0, 6),
                           _w((2, 128, 8), 1.0, 7))


# ---------------------------------------------------------------------------
# registry coverage + decision attribution
# ---------------------------------------------------------------------------


def test_every_registered_op_has_a_costspec():
    for name, spec in kd.REGISTRY.items():
        assert isinstance(spec.cost, costmodel.CostSpec), (
            f"op {name!r} registered without a CostSpec — every kernel "
            "package must contribute its analytical cost model"
        )
        assert spec.cost.op == name
        assert callable(spec.cost.fn)
        assert spec.cost.notes  # the model's assumptions, documented


def test_decisions_carry_cost():
    kd.STATS.reset()
    _run_all_ops("ref")
    for op in kd.REGISTRY:
        dec = kd.STATS.last[op]
        assert isinstance(dec.cost, costmodel.Cost), op
        assert dec.cost.flops > 0 and dec.cost.hbm_bytes > 0, op
        assert dec.cost.vmem_bytes == 0, f"{op}: ref has no VMEM working set"


# ---------------------------------------------------------------------------
# the ref exactness contract: predicted bytes == bytes actually touched
# ---------------------------------------------------------------------------


def test_ref_predicted_bytes_equal_touched_bytes_exactly():
    """On the ref backend the model counts each operand and result once —
    it must agree with the ndarray nbytes the dispatch handed the oracle
    to the byte (tolerance 0), for EVERY registered op."""
    kd.STATS.reset()
    _run_all_ops("ref")
    rows = kd.LEDGER.rows()
    assert {r["op"] for r in rows} == set(kd.REGISTRY)
    for r in rows:
        assert r["backend"] == "ref"
        assert r["touched_bytes"] > 0, r["op"]
        assert r["bytes_rel_err"] == 0.0, (
            f"{r['op']}: predicted {r['hbm_bytes']} != touched "
            f"{r['touched_bytes']} ({r['bytes_rel_err']:+.2%})"
        )


def test_pallas_padding_waste_and_vmem_accounted():
    kd.STATS.reset()
    with kd.use_backend("pallas"):
        x = _w((7, 130), 0.5)
        codes, bias = floatsd.encode(_w((130, 66), 0.05))
        kd.matmul(x, codes, bias)
    dec = kd.STATS.last["floatsd_matmul"]
    assert dec.backend == "pallas" and dec.padded
    cost = dec.cost
    assert cost.vmem_bytes > 0
    assert cost.pad_waste_bytes > 0 and cost.pad_waste_flops > 0
    # padded traffic dominates the exact-shape ref prediction
    ref = fm_cost.matmul_fwd_cost(7, 130, 66, backend="ref")
    assert cost.hbm_read_bytes > ref.hbm_read_bytes
    assert cost.macs > ref.macs


def test_matmul4_ref_predicted_bytes_exact_and_padding_accounted():
    """Sub-byte op: tolerance-0 ref exactness (packed codes + group exps
    counted at their real nbytes) plus pallas waste/VMEM attribution on a
    padded odd-K shape."""
    kd.STATS.reset()
    x = _w((7, 101), 0.5)
    w4 = kd.pack4(_w((101, 66), 0.05))
    kd.matmul4(x, w4, backend="ref")
    (row,) = kd.LEDGER.rows()
    assert row["bytes_rel_err"] == 0.0, row
    with kd.use_backend("pallas"):
        kd.matmul4(x, w4)
    dec = kd.STATS.last["floatsd4_matmul"]
    assert dec.backend == "pallas" and dec.padded
    assert dec.cost.vmem_bytes > 0
    assert dec.cost.pad_waste_bytes > 0 and dec.cost.pad_waste_flops > 0


@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (5, 37, 19), (30, 101, 200)])
def test_matmul4_weight_stream_half_of_floatsd8(m, k, n):
    """The FloatSD4 CostSpec's weight-stream term must reflect the halved
    packed stream: ceil(K/2)*N codes + ceil(K/GROUP)*N exps, vs K*N + 4
    for FloatSD8 at equal shape — ~0.53 byte/weight against 1."""
    c4 = fm4_cost.matmul4_fwd_cost(m, k, n, backend="ref")
    c8 = fm_cost.matmul_fwd_cost(m, k, n, backend="ref")
    act = m * k * 4 + m * n * 4  # x read + y write, identical in both
    wt4 = c4.hbm_read_bytes + c4.hbm_write_bytes - act
    wt8 = c8.hbm_read_bytes + c8.hbm_write_bytes - act
    assert wt4 == -(-k // 2) * n + -(-k // 32) * n
    assert wt8 == k * n + 4
    # halved stream + 1/32 exponent overhead: strictly within (0.5, 0.6)
    assert 0.5 < wt4 / (k * n) < 0.6


def test_flash_attention_masked_pairs_charged_to_waste():
    """The pallas flash kernel visits every KV tile (no tile skipping):
    the causally masked-out pairs must land in pad_waste_flops."""
    kd.STATS.reset()
    with kd.use_backend("pallas"):
        q = _w((1, 16, 8))
        kd.flash_attention(q, _w((1, 128, 8), 1.0, 1), _w((1, 128, 8), 1.0, 2),
                           causal=True)
    dec = kd.STATS.last["flash_attention"]
    assert dec.backend == "pallas"
    assert dec.cost.pad_waste_flops > 0


# ---------------------------------------------------------------------------
# the Table-7 tie: ledger MACs argue in the paper's currency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,h,batch", [(256, 256, 1), (200, 650, 16), (28, 128, 4)])
def test_costmodel_macs_reproduce_table7_per_timestep(d, h, batch):
    per = table7.per_timestep_macs(d, h, batch=batch)
    # the two gate GEMMs one timestep dispatches: x_t @ W [D,4H] and
    # h_{t-1} @ U [H,4H]
    gemm = (
        fm_cost.matmul_fwd_cost(batch, d, 4 * h, backend="ref").macs
        + fm_cost.matmul_fwd_cost(batch, h, 4 * h, backend="ref").macs
    )
    assert gemm == per["gemm"]
    cell = lc_cost.lstm_cell_cost(batch, h, backend="ref").macs
    assert cell == per["elementwise"]


def test_cost_merge_sums_flows_maxes_vmem():
    a = costmodel.Cost(flops=10, macs=5, hbm_read_bytes=100,
                       hbm_write_bytes=50, vmem_bytes=1000)
    b = costmodel.Cost(flops=1, macs=1, hbm_read_bytes=1,
                       hbm_write_bytes=1, vmem_bytes=2000, pad_waste_bytes=7)
    m = a + b
    assert m.flops == 11 and m.macs == 6
    assert m.hbm_read_bytes == 101 and m.hbm_write_bytes == 51
    assert m.vmem_bytes == 2000  # peak, not sum
    assert m.pad_waste_bytes == 7
    d = m.to_dict()
    assert d["hbm_bytes"] == 152 and d["arithmetic_intensity"] == 11 / 152


# ---------------------------------------------------------------------------
# the ledger join
# ---------------------------------------------------------------------------


def test_ledger_rows_table_json_and_measured_rate():
    kd.STATS.reset()
    with kd.use_backend("ref"):
        x = _w((8, 128), 0.5)
        codes, bias = floatsd.encode(_w((128, 128), 0.05))
        kd.matmul(x, codes, bias)
        kd.matmul(x, codes, bias)
    kd.STATS.add_time("floatsd_matmul", "ref", 0.01)
    rows = kd.LEDGER.rows()
    assert len(rows) == 1
    r = rows[0]
    assert r["calls"] == 2 and r["timed_calls"] == 1
    per_call_flops = r["flops"] / 2
    assert r["measured_flops_per_s"] == pytest.approx(per_call_flops / 0.01)
    table = kd.LEDGER.table()
    assert "floatsd_matmul" in table and "exact" in table
    blob = kd.LEDGER.to_json(meta={"who": "test"})
    assert blob["meta"] == {"who": "test"}
    json.dumps(blob)  # must be JSON-serializable as-is
    assert blob["rows"][0]["op"] == "floatsd_matmul"


def test_ledger_emit_counters_monotone_trace_tracks():
    kd.STATS.reset()
    tracer = Tracer()
    tracer.enable()
    with kd.use_backend("ref"):
        x = _w((8, 128), 0.5)
        codes, bias = floatsd.encode(_w((128, 128), 0.05))
        kd.matmul(x, codes, bias)
        assert kd.LEDGER.emit_counters(tracer) == 1
        kd.matmul(x, codes, bias)
        assert kd.LEDGER.emit_counters(tracer) == 1
    evs = [e for e in tracer.events() if e["ph"] == "C"]
    assert [e["name"] for e in evs] == ["cost.floatsd_matmul"] * 2
    assert evs[1]["args"]["flops"] == 2 * evs[0]["args"]["flops"]
    assert evs[1]["args"]["calls"] == 2
    # the exported trace passes the cost-counter validation
    assert check_trace.validate_trace(tracer.chrome_trace()) == []


def test_ledger_emit_counters_disabled_tracer_is_noop():
    assert kd.LEDGER.emit_counters(Tracer()) == 0


# ---------------------------------------------------------------------------
# check_bench: the CI perf-regression gate
# ---------------------------------------------------------------------------


def _train_baseline() -> dict:
    with open(_ROOT / "BENCH_train.json") as f:
        return json.load(f)


def test_check_bench_passes_on_identical_reports():
    base = _train_baseline()
    assert check_bench.check_train(json.loads(json.dumps(base)), base) == []


def test_check_bench_fails_injected_time_regression_naming_variant():
    base = _train_baseline()
    cur = json.loads(json.dumps(base))
    cur["results"][0]["fused"]["warm_step_s"] = (
        base["results"][0]["fused"]["warm_step_s"] * 10
    )
    probs = check_bench.check_train(cur, base)
    assert probs and "warm_step_s" in probs[0] and "fused" in probs[0]


def test_check_bench_fails_injected_ledger_regression_naming_op():
    kd.STATS.reset()
    with kd.use_backend("ref"):
        x = _w((8, 128), 0.5)
        codes, bias = floatsd.encode(_w((128, 128), 0.05))
        kd.matmul(x, codes, bias)
    rows = kd.LEDGER.rows()
    assert check_bench.check_ledger(rows) == []  # honest rows pass
    bad = json.loads(json.dumps(rows))
    bad[0]["bytes_rel_err"] = 0.30  # model drifted 30% from measured
    probs = check_bench.check_ledger(bad)
    assert len(probs) == 1
    assert "op=floatsd_matmul" in probs[0]
    assert "predicted" in probs[0] and "measured" in probs[0]
    assert "+30.00%" in probs[0]


def test_check_bench_fails_injected_floatsd4_regression_naming_op():
    """The BENCH_ledger baseline gate: a FloatSD4 cost-model or traced-path
    change drifts the per-call prediction and must fail naming the op."""
    kd.STATS.reset()
    with kd.use_backend("ref"):
        kd.matmul4(_w((8, 128), 0.5), kd.pack4(_w((128, 128), 0.05)))
    rows = kd.LEDGER.rows()
    assert check_bench.check_ledger(rows) == []  # honest rows pass
    assert check_bench._ledger_drift(rows, json.loads(json.dumps(rows)), 0.5) == []
    bad = json.loads(json.dumps(rows))
    bad[0]["hbm_bytes"] *= 3  # e.g. the packed stream silently widened
    probs = check_bench._ledger_drift(bad, rows, 0.5)
    assert len(probs) == 1
    assert "op=floatsd4_matmul" in probs[0] and "hbm_bytes" in probs[0]


def test_check_bench_fails_ledger_per_call_drift_naming_op():
    base_rows = [{"op": "lstm_cell", "backend": "ref", "calls": 2,
                  "flops": 1000, "hbm_bytes": 500}]
    cur_rows = [{"op": "lstm_cell", "backend": "ref", "calls": 2,
                 "flops": 4000, "hbm_bytes": 500}]
    probs = check_bench._ledger_drift(cur_rows, base_rows, 0.5)
    assert probs and "op=lstm_cell" in probs[0] and "flops" in probs[0]


def test_check_bench_tolerances_env_overridable(monkeypatch):
    monkeypatch.setenv("REPRO_BENCH_TOL_BYTES", "0.5")
    assert check_bench.tolerances()["bytes"] == 0.5
    kd.STATS.reset()
    with kd.use_backend("ref"):
        x = _w((8, 128), 0.5)
        codes, bias = floatsd.encode(_w((128, 128), 0.05))
        kd.matmul(x, codes, bias)
    bad = json.loads(json.dumps(kd.LEDGER.rows()))
    bad[0]["bytes_rel_err"] = 0.30
    assert check_bench.check_ledger(bad) == []  # inside the widened gate


# ---------------------------------------------------------------------------
# check_trace: cost.* counter validation
# ---------------------------------------------------------------------------


def _ev(name, ph, ts, **extra):
    return {"name": name, "ph": ph, "ts": ts, "pid": 1, "tid": 1, **extra}


def test_check_trace_accepts_monotone_cost_counters():
    trace = {"traceEvents": [
        _ev("cost.floatsd_matmul", "C", 1, args={"flops": 10, "calls": 1}),
        _ev("cost.floatsd_matmul", "C", 2, args={"flops": 20, "calls": 2}),
    ]}
    assert check_trace.validate_trace(trace) == []


def test_check_trace_rejects_decreasing_cost_counter():
    trace = {"traceEvents": [
        _ev("cost.lstm_cell", "C", 1, args={"flops": 20}),
        _ev("cost.lstm_cell", "C", 2, args={"flops": 10}),
    ]}
    probs = check_trace.validate_trace(trace)
    assert probs and "decreased" in probs[0] and "cost.lstm_cell" in probs[0]


def test_check_trace_rejects_non_numeric_counter_args():
    trace = {"traceEvents": [
        _ev("cost.qsigmoid", "C", 1, args={"flops": "lots"}),
    ]}
    probs = check_trace.validate_trace(trace)
    assert probs and "non-numeric" in probs[0]


def test_check_trace_requires_cost_tracks_next_to_engine_steps():
    trace = {"traceEvents": [
        _ev("engine.step", "B", 1),
        _ev("engine.step", "E", 2),
    ]}
    probs = check_trace.validate_trace(trace)
    assert any("cost.floatsd_matmul" in p for p in probs)
    assert any("cost.lstm_cell" in p for p in probs)
    # ...and is satisfied once the tracks are present
    trace["traceEvents"] += [
        _ev("cost.floatsd_matmul", "C", 3, args={"flops": 1}),
        _ev("cost.lstm_cell", "C", 3, args={"flops": 1}),
    ]
    assert check_trace.validate_trace(trace) == []
