"""Chunked wkv evaluation (perf hillclimb #3) vs the sequential scan oracle:
exact equivalence across decay regimes, chunk sizes, and carried state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.rwkv import RWKV6TimeMix

pytestmark = pytest.mark.slow  # tier-2: see pyproject markers

TM = RWKV6TimeMix(dim=128, head_dim=32)  # 4 heads


def _mk(b, s, w0, seed=0):
    rng = np.random.default_rng(seed)
    h, hd = TM.heads, TM.head_dim
    rh = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    kh = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    vh = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
    # decay w = exp(-exp(w0 + noise)): w0=-6 -> ~0.998 (slow), w0=1 -> ~0.07
    wl = rng.standard_normal((b, s, h, hd)) * 0.3 + w0
    wh = jnp.asarray(np.exp(-np.exp(wl)), jnp.float32)
    u = jnp.asarray(rng.standard_normal((h, hd)) * 0.1, jnp.float32)
    s0 = jnp.asarray(rng.standard_normal((b, h, hd, hd)) * 0.2, jnp.float32)
    return rh, kh, vh, wh, u, s0


@pytest.mark.parametrize("w0", [-6.0, -2.0, 1.0])  # slow / medium / fast decay
@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_chunked_matches_sequential(w0, chunk):
    rh, kh, vh, wh, u, s0 = _mk(2, 64, w0)
    y_seq, s_seq = TM._wkv_sequential(rh, kh, vh, wh, u, s0)
    y_chk, s_chk = TM._wkv_chunked(rh, kh, vh, wh, u, s0, chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_seq),
                               rtol=1e-4, atol=1e-4)


def test_chunked_zero_state_start():
    rh, kh, vh, wh, u, _ = _mk(1, 32, -3.0, seed=5)
    s0 = jnp.zeros_like(_mk(1, 32, -3.0)[5])
    y_seq, s_seq = TM._wkv_sequential(rh, kh, vh, wh, u, s0)
    y_chk, s_chk = TM._wkv_chunked(rh, kh, vh, wh, u, s0, 16)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_seq),
                               rtol=1e-4, atol=1e-4)


def test_chunked_gradients_match():
    rh, kh, vh, wh, u, s0 = _mk(1, 32, -2.0, seed=9)

    def loss_seq(r, k, v, w):
        y, _ = TM._wkv_sequential(r, k, v, w, u, s0)
        return jnp.sum(y**2)

    def loss_chk(r, k, v, w):
        y, _ = TM._wkv_chunked(r, k, v, w, u, s0, 8)
        return jnp.sum(y**2)

    gs = jax.grad(loss_seq, argnums=(0, 1, 2, 3))(rh, kh, vh, wh)
    gc = jax.grad(loss_chk, argnums=(0, 1, 2, 3))(rh, kh, vh, wh)
    for a, b, nm in zip(gs, gc, "rkvw"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4, err_msg=nm)


def test_full_layer_chunked_vs_sequential():
    """End-to-end RWKV6TimeMix.apply equivalence via the module flag."""
    from repro.core.policy import get_policy
    from repro.nn import rwkv as rwkv_mod

    policy = get_policy("fp32")
    p = TM.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 128))
    old = rwkv_mod.RWKV_CHUNK
    try:
        rwkv_mod.RWKV_CHUNK = 0
        y0, (s0_, _) = TM.apply(p, x, policy)
        rwkv_mod.RWKV_CHUNK = 16
        y1, (s1_, _) = TM.apply(p, x, policy)
    finally:
        rwkv_mod.RWKV_CHUNK = old
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s0_), np.asarray(s1_), rtol=1e-4, atol=1e-4)
