"""flash_attention (chunked online-softmax + custom flash VJP) vs naive
attention oracle: forward and gradients, across masks/GQA/window shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import flash_attention

pytestmark = pytest.mark.slow  # tier-2: see pyproject markers

RNG = np.random.default_rng(7)  # unused; kept for seed stability of _mk


def naive_attention(q, k, v, q_pos, k_pos, causal=True, window=None):
    """Full-score reference. q: [B,Sq,Kh,G,D], k/v: [B,Skv,Kh,D]."""
    b, sq, kh, g, d = q.shape
    scale = 1.0 / np.sqrt(d)
    s = jnp.einsum("bqkgd,bckd->bkgqc", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    mask = jnp.ones((b, sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        mask &= q_pos[:, :, None] - k_pos[:, None, :] < window
    s = jnp.where(mask[:, None, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqc,bckd->bqkgd", p, v.astype(jnp.float32)).astype(q.dtype)


def _mk(b, s, kh, g, d, skv=None):
    skv = skv or s
    rng = np.random.default_rng(b * 1000 + s * 10 + kh + g + d)  # order-independent
    q = jnp.asarray(rng.standard_normal((b, s, kh, g, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, skv, kh, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, skv, kh, d)), jnp.float32)
    qp = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    kp = jnp.broadcast_to(jnp.arange(skv, dtype=jnp.int32), (b, skv))
    return q, k, v, qp, kp


@pytest.mark.parametrize("b,s,kh,g,d", [(2, 64, 2, 2, 16), (1, 128, 1, 4, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_forward_matches_naive(b, s, kh, g, d, causal):
    q, k, v, qp, kp = _mk(b, s, kh, g, d)
    got = flash_attention(q, k, v, qp, kp, causal=causal, chunk=32, kv_chunk=16)
    want = naive_attention(q, k, v, qp, kp, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=6e-3)


def test_forward_sliding_window():
    q, k, v, qp, kp = _mk(2, 96, 2, 1, 16)
    got = flash_attention(q, k, v, qp, kp, causal=True, window=24, chunk=32, kv_chunk=32)
    want = naive_attention(q, k, v, qp, kp, causal=True, window=24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=6e-3)


@pytest.mark.parametrize("chunk,kv_chunk", [(16, 16), (32, 64), (128, 128)])
def test_chunking_independence(chunk, kv_chunk):
    q, k, v, qp, kp = _mk(1, 128, 2, 2, 16)
    a = flash_attention(q, k, v, qp, kp, chunk=chunk, kv_chunk=kv_chunk)
    b_ = flash_attention(q, k, v, qp, kp, chunk=128, kv_chunk=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-2, atol=6e-3)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24), (False, None)])
def test_gradients_match_naive(causal, window):
    """The custom flash VJP (tile recompute, no T^2 residuals) must produce
    the same dq/dk/dv as autodiff through the naive reference."""
    q, k, v, qp, kp = _mk(2, 64, 2, 2, 16)

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, qp, kp, causal=causal, window=window,
                            chunk=32, kv_chunk=16)
        return jnp.sum(jnp.sin(o))  # nontrivial cotangent

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(naive_attention(q, k, v, qp, kp, causal, window)))

    g_flash = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g_naive = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for gf, gn, nm in zip(g_flash, g_naive, "qkv"):
        np.testing.assert_allclose(
            np.asarray(gf), np.asarray(gn), rtol=4e-2, atol=8e-3,
            err_msg=f"d{nm} mismatch",
        )


def test_gradients_cross_attention_shape():
    """Skv != Sq (cross-attention) path."""
    q, k, v, qp, kp = _mk(1, 32, 2, 1, 16, skv=96)

    def f(q, k, v):
        return jnp.sum(
            flash_attention(q, k, v, qp, kp, causal=False, chunk=16, kv_chunk=32) ** 2
        )

    def fn(q, k, v):
        return jnp.sum(naive_attention(q, k, v, qp, kp, causal=False) ** 2)

    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(fn, argnums=(0, 1, 2))(q, k, v)
    for a, b_, nm in zip(gf, gn, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=4e-2,
                                   atol=3e-3, err_msg=nm)
