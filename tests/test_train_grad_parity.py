"""Gradient parity for the fused quantized-BPTT path.

The oracle is plain autodiff through the inline STE math (the pre-fusion
training path). The fused path must produce, on BOTH dispatch backends:

  * bit-identical FORWARD values (decode(encode(w)) == quantize(w).values),
  * weight gradients equal to fp8(oracle dW) — exactly when the cell state
    is f32 (table2-style policies) and the oracle's bf16 dW emission is off;
    within the fp16-rounding envelope when the cell state is fp16 (the fused
    dc chain stays f32 where autodiff rounds through the fp16 cell — the
    recorded deviation in kernels/lstm_cell/bwd.py),

across the plain scan, the lengths-masked scan, a reverse layer, a padded
(non-tile-multiple) hidden size, and both remat modes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import floatsd
from repro.core.fp8 import quantize_fp8
from repro.core.policy import get_policy
from repro.kernels import dispatch as kd
from repro.nn import linear as lin
from repro.nn import lstm as lstm_mod
from repro.nn.lstm import LSTMLayer

T2 = get_policy("floatsd8_table2")  # fp32 master -> f32 cell state
T6 = get_policy("floatsd8_table6")  # fp16 master -> fp16 cell state


@pytest.fixture
def no_bf16_dw():
    """Disable the oracle's bf16 dW emission so fp8(oracle) is exact."""
    old = lin.GRAD_REDUCE_BF16
    lin.GRAD_REDUCE_BF16 = False
    yield
    lin.GRAD_REDUCE_BF16 = old


@pytest.fixture(params=[False, True], ids=["save-z", "remat"])
def remat(request):
    old = lstm_mod.BPTT_REMAT
    lstm_mod.BPTT_REMAT = request.param
    yield request.param
    lstm_mod.BPTT_REMAT = old


# ---------------------------------------------------------------------------
# unit level: the dispatch custom-VJP wrappers vs the autodiff oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("m,k,n", [(8, 128, 128), (6, 20, 28)])
def test_train_matmul_grads_vs_ste_oracle(backend, m, k, n):
    """dx matches the STE oracle exactly (f32); dw == fp8(oracle dw)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32) * 0.05)

    def f_fused(x, w):
        with kd.use_backend(backend):
            wq = kd.hoist_train(w)
            return jnp.sum(kd.train_matmul(x, w, wq) ** 2)

    def f_oracle(x, w):
        bias = jax.lax.stop_gradient(floatsd.fit_bias(w))
        wq = floatsd.quantize_ste(w, bias)
        return jnp.sum(jnp.dot(x, wq, preferred_element_type=jnp.float32) ** 2)

    gx1, gw1 = jax.grad(f_fused, (0, 1))(x, w)
    gx0, gw0 = jax.grad(f_oracle, (0, 1))(x, w)
    np.testing.assert_allclose(np.asarray(gx1), np.asarray(gx0),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(gw1), np.asarray(quantize_fp8(gw0)), rtol=1e-5, atol=1e-7
    )


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("b,h", [(8, 128), (5, 70)])  # native + padded
def test_lstm_cell_train_grads_vs_ste_oracle(backend, b, h):
    """The recompute-gates cell VJP == autodiff through the inline STE cell
    (f32 cell state -> no fp16-chain deviation; pallas tolerance is kernel
    lowering noise)."""
    from repro.core.qsigmoid import qsigmoid, qtanh_fp8

    rng = np.random.default_rng(1)
    z = jnp.asarray(rng.standard_normal((b, 4 * h)).astype(np.float32))
    c = jnp.asarray(rng.standard_normal((b, h)).astype(np.float32))

    def f_fused(z, c):
        with kd.use_backend(backend):
            h_t, c_t = kd.lstm_cell_train(z, c, quantized=True,
                                          c_dtype=jnp.float32)
        return jnp.sum(h_t ** 2) + jnp.sum(c_t.astype(jnp.float32) ** 2)

    def f_oracle(z, c):
        zi, zf, zg, zo = jnp.split(z, 4, axis=-1)
        i_t, f_t, o_t = qsigmoid(zi), qsigmoid(zf), qsigmoid(zo)
        g_t = qtanh_fp8(zg)
        c_t = (f_t * c + i_t * g_t).astype(jnp.float32)
        h_t = o_t * qtanh_fp8(c_t)
        return jnp.sum(h_t ** 2) + jnp.sum(c_t ** 2)

    gz1, gc1 = jax.grad(f_fused, (0, 1))(z, c)
    gz0, gc0 = jax.grad(f_oracle, (0, 1))(z, c)
    tol = dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(gz1), np.asarray(gz0), **tol)
    np.testing.assert_allclose(np.asarray(gc1), np.asarray(gc0), **tol)


# ---------------------------------------------------------------------------
# layer level: the scan engine vs autodiff through the whole BPTT
# ---------------------------------------------------------------------------


def _layer_losses(layer, xs, lengths=None):
    def make(policy):
        def loss(p):
            h, fin = layer.apply(p, xs, policy, lengths=lengths)
            return (jnp.sum(h.astype(jnp.float32) ** 2)
                    + jnp.sum(fin.c.astype(jnp.float32) ** 2))
        return loss
    return make


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("variant", ["plain", "masked", "reverse", "padded"])
def test_fused_layer_grads_match_fp8_of_oracle(no_bf16_dw, remat, backend,
                                               variant):
    """Full-scan gradient grid: fused engine vs fp8(autodiff oracle), exact
    for the f32-cell policy, on both backends, incl. the lengths-masked
    scan and a padded (non-tile-multiple) hidden size."""
    hidden = 70 if variant == "padded" else 16
    layer = LSTMLayer(12, hidden, reverse=(variant == "reverse"))
    p = layer.init(jax.random.PRNGKey(0))
    xs = jax.random.normal(jax.random.PRNGKey(1), (4, 9, 12))
    lengths = (jnp.asarray([3, 9, 5, 7], jnp.int32)
               if variant == "masked" else None)
    make = _layer_losses(layer, xs, lengths)

    # forward bit-parity first (fused routing must not change values)
    h0, _ = layer.apply(p, xs, T2, lengths=lengths)
    with kd.use_backend(backend):
        h1, _ = layer.apply(p, xs, T2.replace(grad_quant="fp8_kernel"),
                            lengths=lengths)
    if backend == "ref":
        np.testing.assert_array_equal(np.asarray(h0), np.asarray(h1))
    else:
        np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                                   rtol=2e-3, atol=1e-5)

    v0, g0 = jax.value_and_grad(make(T2))(p)
    with kd.use_backend(backend):
        v1, g1 = jax.value_and_grad(
            make(T2.replace(grad_quant="fp8_kernel"))
        )(p)
    kwargs = (dict(rtol=0, atol=0) if backend == "ref"
              else dict(rtol=2e-3, atol=1e-5))
    if backend == "ref":
        assert float(v0) == float(v1)
    for key in ("wx", "wh"):
        # dW: in-kernel FP8 emission == fp8(oracle dW)
        np.testing.assert_allclose(
            np.asarray(g1[key]), np.asarray(quantize_fp8(g0[key])),
            err_msg=f"{variant}/{key}", **kwargs,
        )
    # bias: no kernel emission at layer level — raw vs raw (train_state's
    # idempotent tree pass quantizes both identically afterwards)
    np.testing.assert_allclose(
        np.asarray(g1["b"]), np.asarray(g0["b"]),
        err_msg=f"{variant}/b", **(dict(rtol=1e-6, atol=1e-6)
                                   if backend == "ref" else kwargs),
    )


def test_fused_layer_grads_fp16_cell_within_envelope(remat):
    """table6 (fp16 cell state): the fused dc chain stays f32 where autodiff
    rounds through fp16 — gradients agree within the fp16 envelope after
    removing the fp8 binning (compare pre-optimizer cosine + max rel)."""
    layer = LSTMLayer(12, 16)
    p = layer.init(jax.random.PRNGKey(0))
    xs = jax.random.normal(jax.random.PRNGKey(1), (4, 9, 12))
    make = _layer_losses(layer, xs)
    v0, g0 = jax.value_and_grad(make(T6))(p)
    v1, g1 = jax.value_and_grad(make(T6.replace(grad_quant="fp8_kernel")))(p)
    assert float(v0) == float(v1)  # forward identical
    for key in ("wx", "wh", "b"):
        oracle = quantize_fp8(g0[key]) if key != "b" else g0[key]
        a = np.asarray(oracle, np.float32).ravel()
        c = np.asarray(g1[key], np.float32).ravel()
        cos = np.dot(a, c) / max(np.linalg.norm(a) * np.linalg.norm(c), 1e-12)
        assert cos > 0.999, (key, cos)


def test_engine_residuals_shrink_vs_autodiff(remat):
    """The residual contract is real: saved forward->backward bytes of the
    fused engine are well below autodiff's per-gate stacking (>=2x; ~4x
    under remat)."""
    layer = LSTMLayer(32, 32)
    p = layer.init(jax.random.PRNGKey(0))
    xs = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32))

    def res_bytes(policy):
        _, vjp_fn = jax.vjp(
            lambda p: jnp.sum(layer.apply(p, xs, policy)[0] ** 2), p
        )
        return sum(l.size * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(vjp_fn)
                   if hasattr(l, "size"))

    base = res_bytes(T6)
    fused = res_bytes(T6.replace(grad_quant="fp8_kernel"))
    floor = 2.0 if not remat else 3.5
    assert base / fused >= floor, (base, fused, base / fused)


# ---------------------------------------------------------------------------
# trajectory level (slow tier): determinism + cross-backend divergence
# ---------------------------------------------------------------------------


def _train_losses(steps, backend, seed=0):
    from repro.data import synthetic
    from repro.models.lstm_models import WikiText2LM
    from repro.optim import sgd
    from repro.optim.train_state import init_state, make_train_step

    model = WikiText2LM(vocab=128, emb=16, hidden=16, n_layers=2)
    data = synthetic.wikitext2(batch=8, seq=16, vocab=model.vocab, seed=seed)
    opt = sgd(0.9)
    with kd.use_backend(backend):
        state = init_state(model.init(jax.random.PRNGKey(seed)), opt, T6)
        step = make_train_step(model.loss, opt, T6, lr=0.5, fused=True,
                               donate=True)
        losses = []
        for _ in range(steps):
            batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
    return losses


@pytest.mark.slow
def test_fused_loss_trajectory_deterministic_on_ref():
    """Deterministic recompute: two identical fused runs on ref are
    bit-identical."""
    assert _train_losses(10, "ref") == _train_losses(10, "ref")


@pytest.mark.slow
def test_fused_loss_trajectory_ref_vs_pallas_interpret():
    """<= 1e-3 relative loss divergence over 50 steps between the ref
    backward kernels and the Pallas(interpret) ones (acceptance bound)."""
    ref = np.asarray(_train_losses(50, "ref"))
    pal = np.asarray(_train_losses(50, "pallas"))
    rel = np.max(np.abs(ref - pal) / np.maximum(np.abs(ref), 1e-9))
    assert rel <= 1e-3, rel
