"""Unit + property tests for the FloatSD8 format (paper §III-A, Table I)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import floatsd

jax.config.update("jax_enable_x64", False)


def test_mantissa_set_has_31_distinct_values():
    # Paper: "out of the 35 combinations, only 31 distinct combinations exist"
    assert floatsd.MANTISSA_VALUES.size == 31
    assert floatsd.MANTISSA_VALUES.min() == -4.5
    assert floatsd.MANTISSA_VALUES.max() == 4.5
    # symmetric set
    np.testing.assert_allclose(
        floatsd.MANTISSA_VALUES, -floatsd.MANTISSA_VALUES[::-1]
    )


def test_msg_values_match_table1():
    # Table I: 3-digit group values are exactly {+-4, +-2, +-1, 0}
    msgs = sorted({m for (m, s) in floatsd.MANTISSA_TO_SD.values()})
    assert msgs == [-4, -2, -1, 0, 1, 2, 4]
    sgs = sorted({s for (m, s) in floatsd.MANTISSA_TO_SD.values()})
    assert sgs == [-2, -1, 0, 1, 2]


def test_at_most_two_partial_products():
    # the entire hardware claim: <= 2 non-zero SD digits per weight
    for v, (m, s) in floatsd.MANTISSA_TO_SD.items():
        assert (m != 0) + (s != 0) <= 2
        assert m + s / 4.0 == v


def test_exact_values_roundtrip():
    # every representable value must quantize to itself
    for bias in (-10, -7, 0, 3):
        grid = floatsd.floatsd8_value_grid(bias)
        x = jnp.asarray(np.concatenate([grid, -grid]), jnp.float32)
        q = floatsd.quantize(x, bias=bias).values
        np.testing.assert_array_equal(np.asarray(q), np.asarray(x))


def test_encode_decode_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(512,)).astype(np.float32))
    codes, bias = floatsd.encode(x)
    back = floatsd.decode(codes, bias)
    q = floatsd.quantize(x, bias=bias).values
    np.testing.assert_allclose(np.asarray(back), np.asarray(q), rtol=0, atol=0)
    assert codes.dtype == jnp.uint8


def test_quantize_is_nearest_value():
    # brute-force nearest against the full grid
    rng = np.random.default_rng(1)
    x = rng.uniform(-6, 6, size=(4096,)).astype(np.float32)
    bias = 0
    grid = floatsd.floatsd8_value_grid(bias)
    full = np.concatenate([-grid[::-1], grid])
    q = np.asarray(floatsd.quantize(jnp.asarray(x), bias=bias).values)
    dist_q = np.abs(x - q)
    dist_best = np.min(np.abs(x[:, None] - full[None, :]), axis=1)
    np.testing.assert_allclose(dist_q, dist_best, rtol=1e-6, atol=1e-7)


def test_hole_in_grid_handled():
    # 3.0 is exactly representable as 1.5 * 2^1 even though the bias-0
    # mantissa grid jumps 2.5 -> 3.5
    q = floatsd.quantize(jnp.asarray([3.0, -3.0]), bias=0).values
    np.testing.assert_array_equal(np.asarray(q), [3.0, -3.0])


def test_auto_bias_covers_tensor():
    rng = np.random.default_rng(2)
    for scale in (1e-3, 1.0, 37.0):
        x = jnp.asarray(rng.normal(scale=scale, size=(1024,)).astype(np.float32))
        q, bias = floatsd.quantize(x)
        amax = float(jnp.max(jnp.abs(x)))
        # top of range covers max|x| and is tight (within one exponent step)
        top = 4.5 * 2.0 ** (7 + int(bias))
        assert top >= amax * 0.999
        assert top <= amax * 2 * 1.001
        # relative error bounded: worst-case mantissa gap is 1.0 around 3.0
        rel = np.abs(np.asarray(q) - np.asarray(x)) / (np.abs(np.asarray(x)) + 1e-30)
        big = np.abs(np.asarray(x)) > 2.0 ** (int(bias) + 2)
        assert rel[big].max() < 0.25


def test_ste_gradient_is_identity():
    x = jnp.asarray([0.3, -1.7, 2.2], jnp.float32)
    g = jax.grad(lambda v: jnp.sum(floatsd.quantize_ste(v, jnp.int32(-3)) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_zero_and_saturation():
    q = floatsd.quantize(jnp.asarray([0.0, 1e9, -1e9]), bias=0).values
    np.testing.assert_array_equal(np.asarray(q), [0.0, 576.0, -576.0])


@settings(max_examples=200, deadline=None)
@given(
    st.lists(
        st.floats(-1e4, 1e4, allow_nan=False, width=32), min_size=1, max_size=64
    ),
    st.integers(-12, 4),
)
def test_property_quantization_invariants(xs, bias):
    x = jnp.asarray(np.asarray(xs, np.float32))
    q = np.asarray(floatsd.quantize(x, bias=bias).values)
    grid = floatsd.floatsd8_value_grid(bias)
    full = np.concatenate([-grid[::-1], grid])
    # 1) idempotent  2) sign-preserving  3) output on the representable grid
    q2 = np.asarray(floatsd.quantize(jnp.asarray(q), bias=bias).values)
    np.testing.assert_array_equal(q, q2)
    assert np.all(np.sign(q) * np.sign(np.asarray(x)) >= 0)
    for v in q:
        assert np.min(np.abs(full - v)) < 1e-6 * max(1.0, abs(v))


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_property_encode_decode_consistent(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(scale=rng.uniform(0.01, 10), size=(64,)), jnp.float32)
    codes, bias = floatsd.encode(x)
    np.testing.assert_array_equal(
        np.asarray(floatsd.decode(codes, bias)),
        np.asarray(floatsd.quantize(x, bias=bias).values),
    )


def test_partial_product_count_le_2():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
    codes, _ = floatsd.encode(x)
    pp = np.asarray(floatsd.partial_product_count(codes))
    assert pp.max() <= 2
    assert pp.min() >= 0
