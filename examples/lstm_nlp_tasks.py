"""End-to-end driver: the paper's Fig. 6 experiment.

Trains one of the four LSTM tasks under FP32 and FloatSD8 (Table VI) with
identical init/data/hyperparameters and prints the two loss curves side by
side — the reproduction claim is that they track each other.

    PYTHONPATH=src python examples/lstm_nlp_tasks.py --task udpos --steps 150
    PYTHONPATH=src python examples/lstm_nlp_tasks.py --task wikitext2 \
        --steps 300 --full     # the paper-scale 85M-param LM

(--full trains the ~100M-class model; default is the reduced config.)
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import get_policy
from repro.models.task_zoo import make_task
from repro.optim.train_state import init_state, make_train_step


def train_curve(task, policy_name, steps, seed, full, log_every):
    model, data, opt, lr, metric = make_task(task, full)
    policy = get_policy(policy_name)
    params = model.init(jax.random.PRNGKey(seed))
    state = init_state(params, opt, policy)
    step_fn = jax.jit(make_train_step(model.loss, opt, policy, lr=lr))
    curve = []
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
        state, m = step_fn(state, batch)
        curve.append(float(m["loss"]))
        if (i + 1) % log_every == 0:
            print(f"  [{policy_name:18s}] step {i+1:4d} "
                  f"loss {np.mean(curve[-log_every:]):.4f}", flush=True)
    # final eval
    vals = []
    for _ in range(8):
        b = {k: jnp.asarray(v) for k, v in next(data.eval_batches).items()}
        vals.append(float(getattr(model, metric)(state.params, b, policy)))
    return curve, metric, float(np.mean(vals))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="udpos",
                    choices=["udpos", "snli", "multi30k", "wikitext2"])
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--log-every", type=int, default=25)
    a = ap.parse_args()

    print(f"== {a.task}: FP32 baseline ==")
    c32, metric, v32 = train_curve(a.task, "fp32", a.steps, a.seed, a.full, a.log_every)
    print(f"== {a.task}: FloatSD8 Table-VI ==")
    cq, _, vq = train_curve(a.task, "floatsd8_table6", a.steps, a.seed, a.full, a.log_every)

    print("\nloss curves (mean per decile):")
    dec = max(1, a.steps // 10)
    print(f"  {'steps':>10s} {'fp32':>9s} {'floatsd8':>9s}")
    for i in range(0, a.steps, dec):
        print(f"  {i:5d}-{min(i+dec,a.steps):4d} "
              f"{np.mean(c32[i:i+dec]):9.4f} {np.mean(cq[i:i+dec]):9.4f}")
    print(f"\nfinal eval {metric}: fp32={v32:.4f}  floatsd8_table6={vq:.4f}")
    print("(paper Table IV: the two columns should be comparable)")


if __name__ == "__main__":
    main()
