"""HTTP serving demo: the FloatSD8 LSTM behind a real network API.

Spins up the full stack in-process — packed FloatSD8 weights, two engine
replicas sharing an FP8 LSTM-state prefix cache, the async router, and
the stdlib HTTP/SSE server on an ephemeral port — then talks to it the
way an operator would: /healthz, a blocking /v1/generate, a token-by-
token /v1/stream (watch the repeated prompt come back with ~zero TTFT
thanks to the prefix cache), a Prometheus /metrics scrape, and a
graceful /admin/drain. Every call prints the equivalent `curl` line so
you can drive a standalone server by hand:

    PYTHONPATH=src python -m repro.launch.serve --http --port 8000
    PYTHONPATH=src python examples/http_client.py --connect 127.0.0.1:8000

Run without --connect to let the demo host its own server:

    PYTHONPATH=src python examples/http_client.py
"""
from __future__ import annotations

import argparse
import asyncio
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import get_policy
from repro.models.lstm_models import WikiText2LM
from repro.serving import PrefixCache, Router
from repro.serving.http import Client, HttpServer


def small_trained_model(steps=150, seed=0):
    from repro.data import synthetic
    from repro.optim import sgd
    from repro.optim.train_state import init_state, make_train_step

    policy = get_policy("floatsd8_table6")
    model = WikiText2LM(vocab=1000, emb=96, hidden=96, n_layers=2)
    data = synthetic.wikitext2(batch=32, seq=24, vocab=model.vocab)
    opt = sgd(0.9)
    state = init_state(model.init(jax.random.PRNGKey(seed)), opt, policy)
    step_fn = jax.jit(make_train_step(model.loss, opt, policy, lr=1.0))
    for _ in range(steps):
        batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
        state, _ = step_fn(state, batch)
    return model, state.params, policy


def show_curl(method, path, port, body=None, tenant=None):
    parts = [f"curl -s http://127.0.0.1:{port}{path}"]
    if method != "GET":
        parts.append(f"-X {method}")
    if tenant:
        parts.append(f"-H 'X-Tenant: {tenant}'")
    if body is not None:
        parts.append(f"-d '{json.dumps(body)}'")
    print("  $ " + " ".join(parts), flush=True)


async def demo(host: str, port: int, own_server: bool):
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 1000, 12).tolist()

    async with Client(host, port, tenant="demo") as c:
        print("\n-- GET /healthz: liveness + capacity --", flush=True)
        show_curl("GET", "/healthz", port)
        print("  ", json.dumps(await c.healthz()), flush=True)

        print("\n-- POST /v1/generate: blocking JSON completion --", flush=True)
        body = {"prompt": prompt, "max_new": 12}
        show_curl("POST", "/v1/generate", port, body, tenant="demo")
        resp = await c.generate(prompt, max_new=12)
        print(f"   rid={resp['rid']} tokens={resp['tokens']}", flush=True)
        print(f"   ttft {resp['ttft_ms']:.1f}ms, latency "
              f"{resp['latency_ms']:.1f}ms", flush=True)

        print("\n-- POST /v1/stream: SSE, one event per token --", flush=True)
        show_curl("POST", "/v1/stream", port, body, tenant="demo")
        print("   ", end="", flush=True)
        async for event, data in c.stream(prompt, max_new=12):
            if event == "message":
                print(data["token"], end=" ", flush=True)
            else:  # the identical resubmitted prompt is a FULL prefix-cache
                print(f"\n   done: ttft {data['ttft_ms']:.1f}ms "
                      f"(prefill skipped by the FP8 prefix cache)", flush=True)

        print("\n-- GET /metrics: Prometheus text exposition --", flush=True)
        show_curl("GET", "/metrics", port)
        metrics = await c.metrics()
        wanted = ("repro_requests_total", "repro_cache_full_hits_total",
                  "repro_prefill_tokens_saved_total", "repro_free_lanes")
        for line in metrics.splitlines():
            if line.startswith(wanted):
                print("  ", line, flush=True)

        if own_server:
            print("\n-- POST /admin/drain: graceful shutdown --", flush=True)
            show_curl("POST", "/admin/drain", port)
            print("  ", json.dumps(await c.drain()), flush=True)
        else:
            print("\n(skipping /admin/drain: not our server)", flush=True)


async def hosted_demo():
    print("pretraining a small FloatSD8 LSTM (~150 steps, decisive greedy margins) ...", flush=True)
    model, params, policy = small_trained_model()
    router = Router.build(
        model, params, policy,
        replicas=2,
        prefix_cache=PrefixCache(budget_bytes=8 * 2**20, block=8),
        lanes=4, chunk=8,
    )
    server = await HttpServer(router, port=0).start()
    print(f"serving on http://{server.host}:{server.port} "
          f"(2 replicas x 4 lanes, shared FP8 prefix cache)", flush=True)
    serve_task = asyncio.create_task(server.serve_forever())
    await demo(server.host, server.port, own_server=True)
    await asyncio.wait_for(serve_task, timeout=60)
    print("server drained and exited cleanly", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--connect", default=None, metavar="HOST:PORT",
                    help="talk to an already-running serve --http instance "
                         "instead of hosting one in-process")
    args = ap.parse_args()
    if args.connect:
        host, _, port = args.connect.rpartition(":")
        asyncio.run(demo(host or "127.0.0.1", int(port), own_server=False))
    else:
        asyncio.run(hosted_demo())


if __name__ == "__main__":
    main()
