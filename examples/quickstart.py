"""Quickstart: the FloatSD8 number format and a quantized training step.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's pieces in ~30 lines of API:
  1. FloatSD8 quantize / encode / decode  (§III-A)
  2. two-region quantized sigmoid          (§III-C)
  3. a FloatSD8 x FP8 dense layer          (§III-D)
  4. one full Table-VI training step       (§III-B, §IV)
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import floatsd
from repro.core.policy import FLOATSD8_TABLE6, FP32
from repro.core.qsigmoid import qsigmoid
from repro.nn.linear import QuantDense
from repro.nn.lstm import LSTMLayer
from repro.optim import adam
from repro.optim.train_state import init_state, make_train_step

# --- 1. the number format ---------------------------------------------------
w = jax.random.normal(jax.random.PRNGKey(0), (4, 4)) * 0.1
q = floatsd.quantize(w)  # fake-quant: nearest representable value
codes, bias = floatsd.encode(w)  # 1 byte/weight storage format
print("weights:\n", np.asarray(w).round(4))
print("FloatSD8:\n", np.asarray(q.values).round(4), f"\n(bias={int(q.bias)})")
print("codes (uint8):\n", np.asarray(codes))
assert jnp.allclose(floatsd.decode(codes, bias), q.values)
print("max partial products per weight:",
      int(floatsd.partial_product_count(codes).max()), "(always <= 2)\n")

# --- 2. the quantized sigmoid ----------------------------------------------
x = jnp.linspace(-4, 4, 9)
print("sigma(x)  :", np.asarray(jax.nn.sigmoid(x)).round(4))
print("Q(sigma)  :", np.asarray(qsigmoid(x)).round(4), "\n")

# --- 3. a quantized layer -----------------------------------------------
layer = QuantDense(16, 8)
params = layer.init(jax.random.PRNGKey(1))
y_fp32 = layer.apply(params, jnp.ones((2, 16)), FP32)
y_q = layer.apply(params, jnp.ones((2, 16)), FLOATSD8_TABLE6)
print("dense fp32 vs floatsd8 outputs (row 0):")
print(" ", np.asarray(y_fp32[0]).round(4))
print(" ", np.asarray(y_q[0], np.float32).round(4), "\n")

# --- 4. one training step under the paper's Table-VI scheme -----------------
lstm = LSTMLayer(16, 32)
head = QuantDense(32, 4)


def loss_fn(p, batch, policy):
    h, _ = lstm.apply(p["lstm"], batch["x"], policy)
    logits = head.apply(p["head"], h[:, -1], policy, site="last")
    return -jnp.mean(
        jnp.take_along_axis(jax.nn.log_softmax(logits), batch["y"][:, None], 1)
    )


params = {"lstm": lstm.init(jax.random.PRNGKey(2)),
          "head": head.init(jax.random.PRNGKey(3))}
state = init_state(params, adam(), FLOATSD8_TABLE6)
step = jax.jit(make_train_step(loss_fn, adam(), FLOATSD8_TABLE6, lr=1e-3))
batch = {"x": jax.random.normal(jax.random.PRNGKey(4), (8, 12, 16)),
         "y": jnp.arange(8) % 4}
for i in range(5):
    state, m = step(state, batch)
    print(f"step {i}: loss={float(m['loss']):.4f} "
          f"scale={float(m['loss_scale']):.0f} master_dtype="
          f"{jax.tree_util.tree_leaves(state.params)[0].dtype}")
print("\nquickstart OK")
