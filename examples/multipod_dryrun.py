"""Multi-pod dry-run walkthrough for one (arch x shape) cell.

Lowers + compiles a production-mesh training step for an assigned
architecture using ShapeDtypeStruct stand-ins (no allocation) and prints the
memory analysis, cost analysis, and the three roofline terms.

    PYTHONPATH=src python examples/multipod_dryrun.py \
        --arch stablelm_3b --shape train_4k --multi-pod
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
import argparse
import json

from repro.launch.dryrun import run_cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    a = ap.parse_args()
    rec = run_cell(a.arch, a.shape, multi_pod=a.multi_pod)
    print(json.dumps({k: v for k, v in rec.items() if k != "traceback"},
                     indent=1, default=str))


if __name__ == "__main__":
    main()
