"""Serving demo: FloatSD8 deployment format + continuous batching +
the multi-tenant frontend with its FP8 LSTM-state prefix cache.

Shows the inference-accelerator story of paper §V end-to-end: a quick
pretrain, then the model is packed to 1-byte FloatSD8 codes and served
through ``repro.serving.ServeEngine`` — continuous batching, chunked
prefill, decode-at-use from uint8 codes (the PE's VMEM decode). A second
phase serves a shared-system-prompt workload through the frontend router:
two engine replicas share one prefix cache, so the per-layer ``(h, c)``
snapshot at a hot prefix (stored in FP8) replaces that prefix's prefill
with a single state injection — and an identical resubmitted prompt skips
prefill entirely.

    PYTHONPATH=src python examples/serve_floatsd8.py --requests 8 --batch 4
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import get_policy
from repro.models.task_zoo import make_task
from repro.serving import (
    PrefixCache,
    Router,
    ServeEngine,
    synthetic_prompts,
    zipf_prefix_prompts,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--steps-pretrain", type=int, default=40)
    a = ap.parse_args()

    policy = get_policy("floatsd8_table6")
    model, data, opt, lr, _ = make_task("wikitext2", full=False)

    # quick pretrain so generation isn't pure noise
    from repro.optim.train_state import init_state, make_train_step

    params = model.init(jax.random.PRNGKey(0))
    state = init_state(params, opt, policy)
    step_fn = jax.jit(make_train_step(model.loss, opt, policy, lr=lr))
    for _ in range(a.steps_pretrain):
        batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
        state, _ = step_fn(state, batch)
    params = state.params

    # --- deployment format + serving loop, all inside the engine ----------
    engine = ServeEngine(
        model, params, policy, lanes=a.batch, chunk=a.chunk, packed=True
    )
    s = engine.store
    print(
        f"weights: {s.dense_nbytes/2**20:.1f} MiB dense -> "
        f"{s.packed_nbytes/2**20:.1f} MiB FloatSD8 "
        f"({s.compression:.2f}x smaller)"
    )

    rng = np.random.default_rng(0)
    prompts = synthetic_prompts(a.requests, model.vocab, rng, lo=4, hi=16)
    reqs = engine.submit_all(prompts, max_new=a.max_new)
    metrics = engine.run()
    print(metrics.format())
    for r in sorted(reqs, key=lambda r: r.rid)[:4]:
        print(f"  request {r.rid} (prompt {r.prompt_len} tok): {r.out[:12]}...")

    # --- frontend: router + shared FP8 prefix cache ------------------------
    # Shared-system-prompt traffic over two replicas; the cache stores the
    # constant-size (h, c) snapshot per hot prefix, so repeated prefixes
    # skip their prefill regardless of which replica warmed them.
    print("\nfrontend: 2 replicas, shared FP8 LSTM-state prefix cache")
    cache = PrefixCache(block=a.chunk)
    router = Router.build(
        model, params, policy,
        replicas=2, prefix_cache=cache,
        # the whole workload is submitted before the first pump — size the
        # admission queue to hold it or the overflow is (correctly) rejected
        router_kw=dict(max_queue=2 * a.requests + 8),
        lanes=a.batch, chunk=a.chunk, packed=True,
    )
    zipf = zipf_prefix_prompts(
        2 * a.requests, model.vocab, rng, prefix_len=2 * a.chunk, prefix_seed=0
    )
    streamed = []
    router.submit(
        zipf[0], max_new=a.max_new, tenant="alice", on_token=streamed.append
    )
    for i, p in enumerate(zipf[1:]):
        router.submit(p, max_new=a.max_new, tenant=("alice", "bob")[i % 2])
    router.drain()

    # resubmit the first prompt: fully cached now -> prefill-free
    t = router.submit(zipf[0], max_new=4, tenant="alice")
    router.drain()
    rep = router.report()
    print(
        f"cache hit rate {rep['cache_hit_rate']:.0%} "
        f"({rep['cache_full_hits']} full hits, "
        f"{rep['prefill_tokens_saved']} prefill tok saved, "
        f"{cache.stats()['entries']} entries / {cache.nbytes/1024:.1f} KiB fp8)"
    )
    print(f"streamed request: {streamed[:8]}... ({len(streamed)} tokens)")
    print(f"resubmitted prompt (full hit, prefill skipped): {t.tokens}")
    for tenant, tr in rep["tenants"].items():
        print(
            f"  {tenant}: {tr['completed']} requests, {tr['tokens']} tok, "
            f"ttft p95 {tr.get('ttft_p95_s', 0.0)*1e3:.0f}ms"
        )
    print("serve demo OK")


if __name__ == "__main__":
    main()
