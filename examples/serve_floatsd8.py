"""Serving demo: FloatSD8 deployment format + batched generation.

Shows the inference-accelerator story of paper §V: weights stored as 1-byte
FloatSD8 codes (7.66x-smaller MAC on the ASIC; 2x HBM traffic reduction on
TPU), decode-at-use, batched multi-request generation through the LSTM LM's
recurrent cache.

    PYTHONPATH=src python examples/serve_floatsd8.py --requests 8 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import floatsd
from repro.core.policy import get_policy
from repro.models.task_zoo import make_task


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--steps-pretrain", type=int, default=40)
    a = ap.parse_args()

    policy = get_policy("floatsd8_table6")
    model, data, opt, lr, _ = make_task("wikitext2", full=False)

    # quick pretrain so generation isn't pure noise
    from repro.optim.train_state import init_state, make_train_step

    params = model.init(jax.random.PRNGKey(0))
    state = init_state(params, opt, policy)
    step_fn = jax.jit(make_train_step(model.loss, opt, policy, lr=lr))
    for _ in range(a.steps_pretrain):
        batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
        state, _ = step_fn(state, batch)
    params = state.params

    # --- deployment format: every weight matrix -> uint8 codes + bias -------
    leaves = jax.tree_util.tree_leaves(params)
    n_bytes_fp32 = sum(l.size * 4 for l in leaves)
    packed = jax.tree_util.tree_map(
        lambda w: floatsd.encode(w) if w.ndim >= 2 else w, params,
    )
    n_bytes_fsd8 = sum(
        (l.size if l.dtype == jnp.uint8 else l.size * l.dtype.itemsize)
        for l in jax.tree_util.tree_leaves(packed)
    )
    print(f"weights: {n_bytes_fp32/2**20:.1f} MiB fp32 -> "
          f"{n_bytes_fsd8/2**20:.1f} MiB FloatSD8 "
          f"({n_bytes_fp32/n_bytes_fsd8:.2f}x smaller)")

    # decode-at-use (the PE's VMEM decode): unpack back to dense for serving
    serving_params = jax.tree_util.tree_map(
        lambda w: floatsd.decode(*w, dtype=jnp.float32) if isinstance(w, tuple) else w,
        packed, is_leaf=lambda x: isinstance(x, tuple),
    )

    # --- batched generation --------------------------------------------------
    B = a.batch
    caches = model.init_cache(B, policy)

    @jax.jit
    def decode(params, toks, caches):
        return model.decode_step(params, toks, caches, policy)

    rng = np.random.default_rng(0)
    cur = jnp.asarray(rng.integers(0, model.vocab, (B, 1)), jnp.int32)
    outs = [[] for _ in range(B)]
    t0 = time.time()
    for _ in range(a.max_new):
        logits, caches = decode(serving_params, cur, caches)
        nxt = jnp.argmax(logits[:, -1, :], -1)
        for i in range(B):
            outs[i].append(int(nxt[i]))
        cur = nxt[:, None].astype(jnp.int32)
    dt = time.time() - t0
    print(f"generated {B}x{a.max_new} tokens in {dt:.1f}s "
          f"({B*a.max_new/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:4]):
        print(f"  lane {i}: {o[:12]}...")
    print("serve demo OK")


if __name__ == "__main__":
    main()
