"""Serving demo: FloatSD8 deployment format + continuous batching.

Shows the inference-accelerator story of paper §V end-to-end: a quick
pretrain, then the model is packed to 1-byte FloatSD8 codes and served
through ``repro.serving.ServeEngine`` — continuous batching, chunked
prefill, decode-at-use from uint8 codes (the PE's VMEM decode).

    PYTHONPATH=src python examples/serve_floatsd8.py --requests 8 --batch 4
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import get_policy
from repro.models.task_zoo import make_task
from repro.serving import ServeEngine, synthetic_prompts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--steps-pretrain", type=int, default=40)
    a = ap.parse_args()

    policy = get_policy("floatsd8_table6")
    model, data, opt, lr, _ = make_task("wikitext2", full=False)

    # quick pretrain so generation isn't pure noise
    from repro.optim.train_state import init_state, make_train_step

    params = model.init(jax.random.PRNGKey(0))
    state = init_state(params, opt, policy)
    step_fn = jax.jit(make_train_step(model.loss, opt, policy, lr=lr))
    for _ in range(a.steps_pretrain):
        batch = {k: jnp.asarray(v) for k, v in next(data.batches).items()}
        state, _ = step_fn(state, batch)
    params = state.params

    # --- deployment format + serving loop, all inside the engine ----------
    engine = ServeEngine(
        model, params, policy, lanes=a.batch, chunk=a.chunk, packed=True
    )
    s = engine.store
    print(
        f"weights: {s.dense_nbytes/2**20:.1f} MiB dense -> "
        f"{s.packed_nbytes/2**20:.1f} MiB FloatSD8 "
        f"({s.compression:.2f}x smaller)"
    )

    rng = np.random.default_rng(0)
    prompts = synthetic_prompts(a.requests, model.vocab, rng, lo=4, hi=16)
    reqs = engine.submit_all(prompts, max_new=a.max_new)
    metrics = engine.run()
    print(metrics.format())
    for r in sorted(reqs, key=lambda r: r.rid)[:4]:
        print(f"  request {r.rid} (prompt {r.prompt_len} tok): {r.out[:12]}...")
    print("serve demo OK")


if __name__ == "__main__":
    main()
