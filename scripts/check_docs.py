#!/usr/bin/env python
"""Docs link/path check: every file path named in the repo's markdown
must actually exist.

Scans all tracked *.md files (repo root, docs/, nested READMEs) for

  * backtick code spans containing something that looks like a repo file
    path (has a known extension: .py/.md/.sh/.json/.yml/.toml/.txt), and
  * relative markdown link targets ``[text](path)``,

then resolves each candidate against (a) the repo root, (b) ``src/repro/``
(module docstrings and EXPERIMENTS.md cite paths relative to the
package), and (c) the markdown file's own directory. Anything that
resolves nowhere is reported and the script exits 1 — so renaming a file
without fixing the docs that cite it fails CI rather than rotting the
documentation. Placeholders (globs, <vars>, {braces}) are skipped.

    python scripts/check_docs.py            # from the repo root
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

EXTS = ("py", "md", "sh", "json", "yml", "yaml", "toml", "txt")
PATH_RE = re.compile(
    r"[A-Za-z0-9_][A-Za-z0-9_./-]*\.(?:%s)\b" % "|".join(EXTS)
)
CODE_SPAN_RE = re.compile(r"`([^`]+)`")
MD_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_CHARS = set("*<>{}$")

# cited but intentionally absent: ROADMAP "ground" references point into
# the external /root/related/ reference checkout, not this repo, and the
# *_ci.json benchmark reports exist only as CI run artifacts by design
# (the checked-in baselines they are diffed against have no _ci suffix)
ALLOWLIST: set = {
    "torch/distributed/_tensor/placement_types.py",
    "maedoc__loopy/test/test_statistics.py",
    "BENCH_train_ci.json",
    "BENCH_http_ci.json",
    "BENCH_ledger_ci.json",
}

# not about THIS repo's files: the per-PR task spec and the external-repo
# reference digests cite paths that live elsewhere by design
EXCLUDE = {"ISSUE.md", "SNIPPETS.md", "PAPERS.md"}


def md_files():
    for p in sorted(ROOT.rglob("*.md")):
        if ".git" in p.parts or ".claude" in p.parts or "node_modules" in p.parts:
            continue
        if p.name in EXCLUDE:
            continue
        yield p


def _basenames() -> set:
    names = set()
    for p in ROOT.rglob("*"):
        if ".git" in p.parts:
            continue
        if p.is_file():
            names.add(p.name)
    return names


BASENAMES = _basenames()


def resolves(path: str, base: Path) -> bool:
    cand = path.lstrip("./")
    if "/" not in cand:
        # bare filename cited in running text (directory clear from
        # context): must exist SOMEWHERE in the repo, catching renames
        return cand in BASENAMES
    return any(
        (root / c).exists()
        for c in (cand, "." + cand)  # ".github/..." loses its dot to the regex
        for root in (ROOT, ROOT / "src" / "repro", ROOT / "src", base)
    )


def candidates(text: str):
    # file-looking tokens inside backtick spans
    for span in CODE_SPAN_RE.findall(text):
        if SKIP_CHARS & set(span):
            continue
        for m in PATH_RE.finditer(span.split("::")[0]):
            yield m.group(0)
    # relative markdown links
    for target in MD_LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        if SKIP_CHARS & set(target):
            continue
        yield target.split("#")[0]


def main() -> int:
    missing = []
    checked = 0
    for md in md_files():
        text = md.read_text(encoding="utf-8")
        seen = set()
        for cand in candidates(text):
            if not cand or cand in seen or cand in ALLOWLIST:
                continue
            seen.add(cand)
            checked += 1
            if not resolves(cand, md.parent):
                missing.append((md.relative_to(ROOT), cand))
    if missing:
        print(f"check_docs: {len(missing)} dangling path reference(s):")
        for md, cand in missing:
            print(f"  {md}: {cand}")
        return 1
    print(f"check_docs: OK ({checked} path references across "
          f"{len(list(md_files()))} markdown files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
