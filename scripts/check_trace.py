#!/usr/bin/env python
"""Validate a Chrome trace-event JSON export (what GET /admin/trace and
bench_http --trace-out produce).

Checks the invariants Perfetto / chrome://tracing rely on, so a broken
export fails in CI instead of failing silently in the viewer:

  * top level is ``{"traceEvents": [...]}``
  * every event has the required keys (name/ph/ts/pid/tid), sane types,
    and a known phase (B, E, X, i, C)
  * timestamps are monotone non-decreasing in array order (the exporter
    sorts; Perfetto tolerates disorder but our exporter promises order)
  * per (pid, tid), B/E events pair up like brackets: no E without a
    matching B, matching names, nothing left open at the end
  * X (complete) events carry a non-negative ``dur``
  * request-lifecycle instants (engine.cancel / engine.preempt /
    engine.resume / engine.numeric_error / router.cancel /
    router.resubmit) are ``i``-phase and carry the rid in their args —
    the attribution the cancellation and failure runbooks grep for
  * ``C`` (counter) events carry numeric args, and ``cost.*`` counter
    tracks — the cost-model observatory's cumulative FLOP/byte ledgers —
    are monotone non-decreasing per (track, series); a trace that ran
    engine steps must carry the matmul + lstm_cell cost tracks

Usage:
    scripts/check_trace.py trace.json
    curl -fsS http://host:port/admin/trace | scripts/check_trace.py -

Importable too: ``validate_trace(obj) -> list[str]`` returns problems
(empty list = valid), used by the test suite and smoke script.
"""
from __future__ import annotations

import json
import sys

REQUIRED_KEYS = ("name", "ph", "ts", "pid", "tid")
KNOWN_PHASES = {"B", "E", "X", "i", "C"}
# Cancellation/preemption lifecycle markers: always instants, always
# rid-attributed (a cancel event without a rid cannot be joined against
# the request it released).
RID_INSTANTS = {
    "engine.cancel",
    "engine.preempt",
    "engine.resume",
    "engine.numeric_error",
    "router.cancel",
    "router.resubmit",
}


def validate_trace(obj) -> list:
    """Return a list of problem strings (empty = valid trace)."""
    problems = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with a 'traceEvents' array"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be an array"]

    last_ts = None
    stacks: dict = {}  # (pid, tid) -> [(name, idx), ...] open B spans
    counters: dict = {}  # (name, series key) -> last value (monotonicity)
    span_names: set = set()  # names seen on B/X events
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event {i}: not an object")
            continue
        missing = [k for k in REQUIRED_KEYS if k not in ev]
        if missing:
            problems.append(f"event {i}: missing keys {missing}")
            continue
        ph = ev["ph"]
        if ph not in KNOWN_PHASES:
            problems.append(f"event {i}: unknown phase {ph!r}")
            continue
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            problems.append(
                f"event {i}: ts {ts} goes backwards (prev {last_ts})"
            )
        last_ts = ts
        key = (ev["pid"], ev["tid"])
        if ph == "B":
            stacks.setdefault(key, []).append((ev["name"], i))
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                problems.append(
                    f"event {i}: E {ev['name']!r} with no open B on tid {key}"
                )
            else:
                name, j = stack.pop()
                if name != ev["name"]:
                    problems.append(
                        f"event {i}: E {ev['name']!r} closes B {name!r} "
                        f"(event {j}) on tid {key}"
                    )
        elif ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: X without non-negative dur")
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                problems.append(
                    f"event {i}: C {ev['name']!r} needs a non-empty args dict"
                )
            else:
                for k, v in args.items():
                    if not isinstance(v, (int, float)):
                        problems.append(
                            f"event {i}: C {ev['name']!r} series {k!r} "
                            f"non-numeric value {v!r}"
                        )
                    elif ev["name"].startswith("cost."):
                        # cumulative ledger totals: never decrease
                        prev = counters.get((ev["name"], k))
                        if prev is not None and v < prev:
                            problems.append(
                                f"event {i}: cost counter {ev['name']!r} "
                                f"series {k!r} decreased ({prev} -> {v})"
                            )
                        counters[(ev["name"], k)] = v
        if ph in ("B", "X"):
            span_names.add(ev["name"])
        if ev["name"] in RID_INSTANTS:
            if ph != "i":
                problems.append(
                    f"event {i}: {ev['name']!r} must be an instant "
                    f"(ph 'i'), got {ph!r}"
                )
            elif "rid" not in (ev.get("args") or {}):
                problems.append(
                    f"event {i}: {ev['name']!r} instant missing args.rid"
                )

    for key, stack in stacks.items():
        for name, j in stack:
            problems.append(
                f"unterminated B {name!r} (event {j}) on tid {key}"
            )
    # a trace that ran device steps must carry the hot-path cost tracks —
    # if the ledger wiring regresses, the trace loses its predicted-cost
    # attribution silently otherwise
    if "engine.step" in span_names:
        tracks = {name for (name, _k) in counters}
        for required in ("cost.floatsd_matmul", "cost.lstm_cell"):
            if required not in tracks:
                problems.append(
                    f"trace has engine.step spans but no {required!r} "
                    "counter track (cost-ledger emission missing)"
                )
    return problems


def main(argv) -> int:
    if len(argv) != 2 or argv[1] in ("-h", "--help"):
        print(__doc__, file=sys.stderr)
        return 2
    raw = (
        sys.stdin.read() if argv[1] == "-" else open(argv[1]).read()
    )
    try:
        obj = json.loads(raw)
    except json.JSONDecodeError as e:
        print(f"check_trace: not JSON: {e}", file=sys.stderr)
        return 1
    problems = validate_trace(obj)
    if problems:
        for p in problems[:20]:
            print(f"check_trace: {p}", file=sys.stderr)
        if len(problems) > 20:
            print(f"check_trace: ... {len(problems) - 20} more", file=sys.stderr)
        return 1
    n = len(obj["traceEvents"])
    print(f"check_trace: OK ({n} events)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
